"""AOT compiler: lower every model entry point to HLO text + manifest.

Python's only job in this repo — runs once at build time (`make artifacts`)
and never again; the rust binary is self-contained afterwards.

Interchange is HLO **text**, not `lowered.compile().serialize()`: jax >= 0.5
emits HloModuleProtos with 64-bit instruction ids which the crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`).  The text parser
reassigns ids, so text round-trips cleanly (see /opt/xla-example/README.md).

Usage:
    python -m compile.aot --out-dir ../artifacts [--groups s3d_hbae_L128 ...]

Output layout:
    artifacts/manifest.json
    artifacts/<group>/<entry>.hlo.txt
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import configs, model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sig(args) -> list:
    return [{"shape": list(a.shape), "dtype": str(a.dtype)} for a in args]


def _out_sig(fn, args) -> list:
    outs = jax.eval_shape(fn, *args)
    if not isinstance(outs, tuple):
        outs = (outs,)
    return [{"shape": list(o.shape), "dtype": str(o.dtype)} for o in outs]


def lower_group(group: str, entries, out_dir: str, manifest: dict,
                extra: dict) -> None:
    gdir = os.path.join(out_dir, group)
    os.makedirs(gdir, exist_ok=True)
    ginfo = {"entries": {}, **extra}
    for name, fn, args in entries:
        t0 = time.time()
        # wrap so every entry returns a tuple (return_tuple=True unwrap on
        # the rust side is uniform: to_tuple()).
        def tup_fn(*a, _fn=fn):
            out = _fn(*a)
            return out if isinstance(out, tuple) else (out,)
        lowered = jax.jit(tup_fn).lower(*args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(gdir, fname), "w") as f:
            f.write(text)
        ginfo["entries"][name] = {
            "file": f"{group}/{fname}",
            "inputs": _sig(args),
            "outputs": _out_sig(tup_fn, args),
            "hlo_bytes": len(text),
        }
        print(f"  {group}/{name}: {len(text)/1e3:.0f} kB "
              f"({time.time()-t0:.1f}s)", flush=True)
    manifest["groups"][group] = ginfo


def input_fingerprint() -> str:
    """Hash of the compile-path sources; lets `make artifacts` skip cleanly."""
    h = hashlib.sha256()
    base = os.path.dirname(os.path.abspath(__file__))
    for root, _, files in os.walk(base):
        if "__pycache__" in root:
            continue
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(root, f), "rb") as fh:
                    h.update(fh.read())
    return h.hexdigest()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--groups", nargs="*", default=None,
                    help="subset of group names to (re)build")
    args = ap.parse_args()
    out_dir = args.out_dir
    os.makedirs(out_dir, exist_ok=True)

    hbaes, baes, pipes = configs.default_groups()
    manifest = {
        "version": 1,
        "fingerprint": input_fingerprint(),
        "jax_version": jax.__version__,
        "adam": {"b1": model.ADAM_B1, "b2": model.ADAM_B2,
                 "eps": model.ADAM_EPS},
        "groups": {},
    }

    want = set(args.groups) if args.groups else None
    t0 = time.time()
    for cfg in hbaes:
        if want and cfg.group not in want:
            continue
        print(f"[aot] {cfg.group} (param_dim={model.hbae_spec(cfg).total})",
              flush=True)
        lower_group(cfg.group, model.hbae_entries(cfg), out_dir, manifest,
                    {"kind": "hbae", "config": configs.to_manifest_dict(cfg),
                     "param_dim": model.hbae_spec(cfg).total,
                     "layout": model.hbae_spec(cfg).layout()})
    for cfg in baes:
        if want and cfg.group not in want:
            continue
        print(f"[aot] {cfg.group} (param_dim={model.bae_spec(cfg).total})",
              flush=True)
        lower_group(cfg.group, model.bae_entries(cfg), out_dir, manifest,
                    {"kind": "bae", "config": configs.to_manifest_dict(cfg),
                     "param_dim": model.bae_spec(cfg).total,
                     "layout": model.bae_spec(cfg).layout()})
    for pc in pipes:
        if want and pc.group not in want:
            continue
        print(f"[aot] {pc.group}", flush=True)
        lower_group(pc.group, model.pipe_entries(pc.hbae, pc.bae), out_dir,
                    manifest,
                    {"kind": "pipe", "config": configs.to_manifest_dict(pc),
                     "hbae_group": pc.hbae.group, "bae_group": pc.bae.group})

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {len(manifest['groups'])} groups in "
          f"{time.time()-t0:.0f}s -> {out_dir}/manifest.json", flush=True)


if __name__ == "__main__":
    main()
