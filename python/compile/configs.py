"""Shared model/dataset configuration for the AOT compile path.

These configs are the single source of truth for every shape that crosses
the python -> rust boundary. `aot.py` serializes them into
``artifacts/manifest.json``; the rust side (`rust/src/config`) mirrors the
same presets and validates against the manifest at load time.

Scales:
  * ``bench`` (default) — sizes that let the full experiment suite run on a
    CPU box in minutes.  Block shapes per dataset keep the paper's geometry
    (S3D species x 5 x 4 x 4, E3SM 6 x 16 x 16, XGC 39 x 39) but shrink the
    species count / field extent.
  * ``paper`` — the paper's full shapes (S3D 58x50x640x640 etc.); same
    artifacts work because blocks, not fields, are the unit of compute.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Tuple


@dataclasses.dataclass(frozen=True)
class HbaeConfig:
    """Hyper-block autoencoder (paper §II-B1).

    Encoder: block_dim -> hidden -> (ReLU) -> embed; LayerNorm; one
    self-attention layer over the k block embeddings with a residual
    connection (Eq. 6); flatten k*embed -> latent.  Decoder mirrors.
    """

    name: str
    block_dim: int          # flattened AE block size
    k: int                  # blocks per hyper-block
    hidden: int             # encoder/decoder hidden width
    embed: int              # per-block embedding dim (d in the paper)
    latent: int             # L_h
    batch: int              # hyper-blocks per AOT call
    attention: bool = True  # False => 'HBAE-woa' ablation variant (Fig. 5)

    @property
    def group(self) -> str:
        suffix = "" if self.attention else "_woa"
        return f"{self.name}_hbae_L{self.latent}{suffix}"


@dataclasses.dataclass(frozen=True)
class BaeConfig:
    """Block-wise residual autoencoder (paper §II-C, Eqs. 7-8)."""

    name: str
    block_dim: int
    hidden: int
    latent: int             # L_b
    batch: int              # blocks per AOT call

    @property
    def group(self) -> str:
        return f"{self.name}_bae_L{self.latent}"


@dataclasses.dataclass(frozen=True)
class PipeConfig:
    """Fused HBAE -> residual -> BAE -> reconstruction forward pass.

    One artifact for the compression hot path so the rust coordinator makes
    a single PJRT call per hyper-block batch instead of four.
    """

    hbae: HbaeConfig
    bae: BaeConfig

    @property
    def group(self) -> str:
        return f"{self.hbae.name}_pipe_L{self.hbae.latent}_{self.bae.latent}"


# ---------------------------------------------------------------------------
# Dataset presets (bench scale).  Geometry mirrors the paper §III-A.
# ---------------------------------------------------------------------------

def s3d_hbae(latent: int = 128, attention: bool = True,
             species: int = 16) -> HbaeConfig:
    # paper: 58 species, AE block 58x5x4x4, k=10 temporal blocks/hyper-block
    return HbaeConfig(
        name="s3d", block_dim=species * 5 * 4 * 4, k=10,
        hidden=512, embed=128, latent=latent, batch=32, attention=attention,
    )


def s3d_bae(latent: int = 16, species: int = 16) -> BaeConfig:
    return BaeConfig(name="s3d", block_dim=species * 5 * 4 * 4,
                     hidden=256, latent=latent, batch=320)


def e3sm_hbae(latent: int = 64) -> HbaeConfig:
    # paper: PSL blocks 6x16x16, 5 blocks/hyper-block
    return HbaeConfig(name="e3sm", block_dim=6 * 16 * 16, k=5,
                      hidden=512, embed=128, latent=latent, batch=32)


def e3sm_bae(latent: int = 16) -> BaeConfig:
    return BaeConfig(name="e3sm", block_dim=6 * 16 * 16,
                     hidden=256, latent=latent, batch=160)


def xgc_hbae(latent: int = 64) -> HbaeConfig:
    # paper: one 39x39 velocity histogram per block, 8 toroidal copies per
    # hyper-block
    return HbaeConfig(name="xgc", block_dim=39 * 39, k=8,
                      hidden=512, embed=128, latent=latent, batch=32)


def xgc_bae(latent: int = 16) -> BaeConfig:
    return BaeConfig(name="xgc", block_dim=39 * 39,
                     hidden=256, latent=latent, batch=256)


def default_groups() -> Tuple[List[HbaeConfig], List[BaeConfig], List[PipeConfig]]:
    """Everything `make artifacts` builds.

    Includes the three dataset presets, the Fig.-4 latent sweep variants,
    and the Fig.-5 no-attention ablation.
    """
    hbaes: List[HbaeConfig] = [
        s3d_hbae(128), e3sm_hbae(64), xgc_hbae(64),
        # Fig. 4: HierAE-{32,64,256} (128 already present)
        s3d_hbae(32), s3d_hbae(64), s3d_hbae(256),
        # Fig. 5: HBAE without self-attention, full latent sweep
        s3d_hbae(32, attention=False), s3d_hbae(64, attention=False),
        s3d_hbae(128, attention=False), s3d_hbae(256, attention=False),
    ]
    baes: List[BaeConfig] = [
        s3d_bae(16), e3sm_bae(16), xgc_bae(16),
        # Fig. 4: BAE latent sweep
        s3d_bae(8), s3d_bae(32), s3d_bae(64), s3d_bae(128),
    ]
    pipes: List[PipeConfig] = [
        PipeConfig(s3d_hbae(128), s3d_bae(16)),
        PipeConfig(e3sm_hbae(64), e3sm_bae(16)),
        PipeConfig(xgc_hbae(64), xgc_bae(16)),
    ]
    return hbaes, baes, pipes


def to_manifest_dict(cfg) -> Dict:
    d = dataclasses.asdict(cfg)
    if isinstance(cfg, PipeConfig):
        d = {"hbae": dataclasses.asdict(cfg.hbae),
             "bae": dataclasses.asdict(cfg.bae)}
    d["group"] = cfg.group
    return d


if __name__ == "__main__":  # quick inspection helper
    h, b, p = default_groups()
    print(json.dumps({"hbae": [to_manifest_dict(c) for c in h],
                      "bae": [to_manifest_dict(c) for c in b],
                      "pipe": [to_manifest_dict(c) for c in p]}, indent=2))
