"""Layer-2 JAX model: HBAE, BAE, Adam train steps, fused pipeline.

Everything here is written against a **single flat float32 parameter
vector** per model, with static pack/unpack offsets, so the rust FFI
surface stays tiny: every AOT entry point takes/returns a handful of
literals instead of a pytree.  The layout is recorded in
``artifacts/manifest.json`` and mirrored by ``rust/src/model``.

Architecture (paper §II-B/C):

  HBAE  encode:  block --E--> embed --LN--> self-attention (+residual,
                 Eq. 6) --> flatten k*d --> linear --> latent L_h
        decode:  L_h --> linear --> reshape k x d --> LN --> attention
                 (+residual) --> D --> blocks
  BAE   encode:  LN(residual) --E--> latent L_b
        decode:  L_b --D--> residual estimate (original scale; Eq. 8)

E and D are two fully-connected layers with a ReLU in the middle (paper
§II-B1).  All dense layers, layernorms and attention run through the
Pallas kernels in ``kernels/`` (interpret=True), forward and backward.
"""

from __future__ import annotations

import zlib
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .configs import BaeConfig, HbaeConfig
from .kernels import attention, linear, layernorm

# Adam defaults — paper §III-C uses Adam with lr 1e-3; lr arrives as a
# runtime scalar so the rust trainer can schedule it.
ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8


# ---------------------------------------------------------------------------
# Flat parameter packing
# ---------------------------------------------------------------------------

class ParamSpec:
    """Ordered (name, shape) list with static offsets into a flat vector."""

    def __init__(self) -> None:
        self._entries: List[Tuple[str, Tuple[int, ...], int]] = []
        self._total = 0

    def add(self, name: str, shape: Tuple[int, ...]) -> None:
        size = 1
        for s in shape:
            size *= s
        self._entries.append((name, shape, self._total))
        self._total += size

    @property
    def total(self) -> int:
        return self._total

    def unpack(self, flat: jax.Array) -> Dict[str, jax.Array]:
        out = {}
        for name, shape, off in self._entries:
            size = 1
            for s in shape:
                size *= s
            out[name] = jax.lax.slice(flat, (off,), (off + size,)).reshape(shape)
        return out

    def init(self, key: jax.Array) -> jax.Array:
        """Glorot-uniform weights, zero biases, unit gammas — concatenated."""
        parts = []
        for name, shape, _ in self._entries:
            key, sub = jax.random.split(key)
            if name.endswith("_g"):                     # layernorm gamma
                parts.append(jnp.ones(shape, jnp.float32).ravel())
            elif len(shape) == 1:                        # biases / beta
                parts.append(jnp.zeros(shape, jnp.float32))
            else:
                fan_in, fan_out = shape[0], shape[1]
                lim = (6.0 / (fan_in + fan_out)) ** 0.5
                parts.append(jax.random.uniform(
                    sub, shape, jnp.float32, -lim, lim).ravel())
        return jnp.concatenate(parts)

    def layout(self) -> List[Dict]:
        return [{"name": n, "shape": list(s), "offset": o}
                for n, s, o in self._entries]


def hbae_spec(cfg: HbaeConfig) -> ParamSpec:
    sp = ParamSpec()
    bd, h, d, kd, lh = (cfg.block_dim, cfg.hidden, cfg.embed,
                        cfg.k * cfg.embed, cfg.latent)
    sp.add("enc_w1", (bd, h)); sp.add("enc_b1", (h,))
    sp.add("enc_w2", (h, d)); sp.add("enc_b2", (d,))
    if cfg.attention:
        sp.add("ln1_g", (d,)); sp.add("ln1_b", (d,))
        sp.add("wq1", (d, d)); sp.add("wk1", (d, d)); sp.add("wv1", (d, d))
    sp.add("proj_w", (kd, lh)); sp.add("proj_b", (lh,))
    sp.add("dep_w", (lh, kd)); sp.add("dep_b", (kd,))
    if cfg.attention:
        sp.add("ln2_g", (d,)); sp.add("ln2_b", (d,))
        sp.add("wq2", (d, d)); sp.add("wk2", (d, d)); sp.add("wv2", (d, d))
    sp.add("dec_w1", (d, h)); sp.add("dec_b1", (h,))
    sp.add("dec_w2", (h, bd)); sp.add("dec_b2", (bd,))
    return sp


def bae_spec(cfg: BaeConfig) -> ParamSpec:
    sp = ParamSpec()
    bd, h, lb = cfg.block_dim, cfg.hidden, cfg.latent
    sp.add("ln_g", (bd,)); sp.add("ln_b", (bd,))
    sp.add("enc_w1", (bd, h)); sp.add("enc_b1", (h,))
    sp.add("enc_w2", (h, lb)); sp.add("enc_b2", (lb,))
    sp.add("dec_w1", (lb, h)); sp.add("dec_b1", (h,))
    sp.add("dec_w2", (h, bd)); sp.add("dec_b2", (bd,))
    return sp


# ---------------------------------------------------------------------------
# HBAE forward
# ---------------------------------------------------------------------------

def _attend(e2: jax.Array, p: Dict[str, jax.Array], which: str,
            nh: int, k: int, d: int) -> jax.Array:
    """Eq. 6: Atten(norm(e)) + e over the k embeddings of each hyper-block."""
    ln = layernorm(e2, p[f"ln{which}_g"], p[f"ln{which}_b"])
    zb = jnp.zeros((d,), jnp.float32)
    q = linear(ln, p[f"wq{which}"], zb)
    kk = linear(ln, p[f"wk{which}"], zb)
    v = linear(ln, p[f"wv{which}"], zb)
    att = attention(q.reshape(nh, k, d), kk.reshape(nh, k, d),
                    v.reshape(nh, k, d))
    return att.reshape(nh * k, d) + e2


def hbae_encode(cfg: HbaeConfig, theta: jax.Array,
                batch: jax.Array) -> jax.Array:
    """[Nh, k, block_dim] -> [Nh, L_h]."""
    p = hbae_spec(cfg).unpack(theta)
    nh, k, bd = batch.shape
    d = cfg.embed
    x = batch.reshape(nh * k, bd)
    hid = linear(x, p["enc_w1"], p["enc_b1"], "relu")
    e = linear(hid, p["enc_w2"], p["enc_b2"])
    if cfg.attention:
        e = _attend(e, p, "1", nh, k, d)
    flat = e.reshape(nh, k * d)
    return linear(flat, p["proj_w"], p["proj_b"])


def hbae_decode(cfg: HbaeConfig, theta: jax.Array,
                lat: jax.Array) -> jax.Array:
    """[Nh, L_h] -> [Nh, k, block_dim]."""
    p = hbae_spec(cfg).unpack(theta)
    nh = lat.shape[0]
    k, d, bd = cfg.k, cfg.embed, cfg.block_dim
    z = linear(lat, p["dep_w"], p["dep_b"]).reshape(nh * k, d)
    if cfg.attention:
        z = _attend(z, p, "2", nh, k, d)
    hid = linear(z, p["dec_w1"], p["dec_b1"], "relu")
    out = linear(hid, p["dec_w2"], p["dec_b2"])
    return out.reshape(nh, k, bd)


def hbae_apply(cfg: HbaeConfig, theta: jax.Array,
               batch: jax.Array) -> jax.Array:
    return hbae_decode(cfg, theta, hbae_encode(cfg, theta, batch))


# ---------------------------------------------------------------------------
# BAE forward
# ---------------------------------------------------------------------------

def bae_encode(cfg: BaeConfig, phi: jax.Array, r: jax.Array) -> jax.Array:
    """Residual blocks [Nb, block_dim] -> latents [Nb, L_b] (Eq. 7)."""
    p = bae_spec(cfg).unpack(phi)
    xn = layernorm(r, p["ln_g"], p["ln_b"])
    hid = linear(xn, p["enc_w1"], p["enc_b1"], "relu")
    return linear(hid, p["enc_w2"], p["enc_b2"])


def bae_decode(cfg: BaeConfig, phi: jax.Array, lat: jax.Array) -> jax.Array:
    """Latents -> residual estimate in the original scale (Eq. 8)."""
    p = bae_spec(cfg).unpack(phi)
    hid = linear(lat, p["dec_w1"], p["dec_b1"], "relu")
    return linear(hid, p["dec_w2"], p["dec_b2"])


def bae_apply(cfg: BaeConfig, phi: jax.Array, r: jax.Array) -> jax.Array:
    return bae_decode(cfg, phi, bae_encode(cfg, phi, r))


# ---------------------------------------------------------------------------
# Adam train steps
# ---------------------------------------------------------------------------

def _adam_step(loss_fn, theta, m, v, t, lr, batch):
    loss, g = jax.value_and_grad(loss_fn)(theta, batch)
    t = t + 1.0
    m = ADAM_B1 * m + (1.0 - ADAM_B1) * g
    v = ADAM_B2 * v + (1.0 - ADAM_B2) * g * g
    mhat = m / (1.0 - ADAM_B1 ** t)
    vhat = v / (1.0 - ADAM_B2 ** t)
    theta = theta - lr * mhat / (jnp.sqrt(vhat) + ADAM_EPS)
    return theta, m, v, t, loss


def hbae_train_step(cfg: HbaeConfig, theta, m, v, t, lr, batch):
    """One Adam step on MSE(hbae(batch), batch); batch [Nh, k, block_dim]."""
    def loss_fn(th, b):
        return jnp.mean(jnp.square(hbae_apply(cfg, th, b) - b))
    return _adam_step(loss_fn, theta, m, v, t, lr, batch)


def bae_train_step(cfg: BaeConfig, phi, m, v, t, lr, rbatch):
    """One Adam step on MSE(bae(r), r); rbatch [Nb, block_dim]."""
    def loss_fn(ph, r):
        return jnp.mean(jnp.square(bae_apply(cfg, ph, r) - r))
    return _adam_step(loss_fn, phi, m, v, t, lr, rbatch)


# ---------------------------------------------------------------------------
# Fused pipeline entry points (compression / decompression hot path)
# ---------------------------------------------------------------------------

def _quantize(x: jax.Array, bin_size: jax.Array) -> jax.Array:
    """Mid-tread uniform quantization to bin centers; bin<=0 disables.

    Returns the *dequantized* values.  The rust side recovers the integer
    codes exactly as round(x_q / bin) for entropy coding (§II-E).
    """
    q = jnp.round(x / jnp.where(bin_size > 0, bin_size, 1.0)) * bin_size
    return jnp.where(bin_size > 0, q, x)


def pipe_forward(hcfg: HbaeConfig, bcfg: BaeConfig, theta, phi,
                 batch, bin_h, bin_b):
    """Full compression forward: batch [Nh, k, Bd], scalar quant bins.

    Returns (L_h_q, L_b_q, recon) where latents are already dequantized
    through the same bins the reconstruction used, so the stored codes and
    the reported error are consistent (paper §III-E / Table II).
    """
    nh, k, bd = batch.shape
    lh = _quantize(hbae_encode(hcfg, theta, batch), bin_h)
    y = hbae_decode(hcfg, theta, lh)
    r = (batch - y).reshape(nh * k, bd)
    lb = _quantize(bae_encode(bcfg, phi, r), bin_b)
    rhat = bae_decode(bcfg, phi, lb).reshape(nh, k, bd)
    return lh, lb, y + rhat


def pipe_decode(hcfg: HbaeConfig, bcfg: BaeConfig, theta, phi, lh, lb):
    """Decompression: dequantized latents -> reconstruction [Nh, k, Bd]."""
    y = hbae_decode(hcfg, theta, lh)
    rhat = bae_decode(bcfg, phi, lb).reshape(y.shape)
    return y + rhat


# ---------------------------------------------------------------------------
# Entry-point builders for aot.py
# ---------------------------------------------------------------------------

def hbae_entries(cfg: HbaeConfig):
    """(name, fn, example_args) tuples to lower for one HBAE group."""
    sp = hbae_spec(cfg)
    pdim = sp.total
    f32 = jnp.float32
    vec = lambda n: jax.ShapeDtypeStruct((n,), f32)
    scal = jax.ShapeDtypeStruct((), f32)
    batch = jax.ShapeDtypeStruct((cfg.batch, cfg.k, cfg.block_dim), f32)
    lat = jax.ShapeDtypeStruct((cfg.batch, cfg.latent), f32)
    seed = zlib.crc32(cfg.group.encode()) & 0x7FFFFFFF  # stable across runs

    def init():
        return (sp.init(jax.random.PRNGKey(seed)),)

    return [
        ("init", init, ()),
        ("train_step",
         lambda th, m, v, t, lr, b: hbae_train_step(cfg, th, m, v, t, lr, b),
         (vec(pdim), vec(pdim), vec(pdim), scal, scal, batch)),
        ("encode", lambda th, b: (hbae_encode(cfg, th, b),),
         (vec(pdim), batch)),
        ("decode", lambda th, l: (hbae_decode(cfg, th, l),),
         (vec(pdim), lat)),
    ]


def bae_entries(cfg: BaeConfig):
    sp = bae_spec(cfg)
    pdim = sp.total
    f32 = jnp.float32
    vec = lambda n: jax.ShapeDtypeStruct((n,), f32)
    scal = jax.ShapeDtypeStruct((), f32)
    rbatch = jax.ShapeDtypeStruct((cfg.batch, cfg.block_dim), f32)
    lat = jax.ShapeDtypeStruct((cfg.batch, cfg.latent), f32)
    seed = zlib.crc32(cfg.group.encode()) & 0x7FFFFFFF  # stable across runs

    def init():
        return (sp.init(jax.random.PRNGKey(seed)),)

    return [
        ("init", init, ()),
        ("train_step",
         lambda ph, m, v, t, lr, r: bae_train_step(cfg, ph, m, v, t, lr, r),
         (vec(pdim), vec(pdim), vec(pdim), scal, scal, rbatch)),
        ("encode", lambda ph, r: (bae_encode(cfg, ph, r),),
         (vec(pdim), rbatch)),
        ("decode", lambda ph, l: (bae_decode(cfg, ph, l),),
         (vec(pdim), lat)),
    ]


def pipe_entries(hcfg: HbaeConfig, bcfg: BaeConfig):
    assert hcfg.block_dim == bcfg.block_dim
    assert bcfg.batch == hcfg.batch * hcfg.k, \
        "pipe requires BAE batch == Nh * k"
    f32 = jnp.float32
    vec = lambda n: jax.ShapeDtypeStruct((n,), f32)
    scal = jax.ShapeDtypeStruct((), f32)
    batch = jax.ShapeDtypeStruct((hcfg.batch, hcfg.k, hcfg.block_dim), f32)
    lath = jax.ShapeDtypeStruct((hcfg.batch, hcfg.latent), f32)
    latb = jax.ShapeDtypeStruct((bcfg.batch, bcfg.latent), f32)
    ph, pb = hbae_spec(hcfg).total, bae_spec(bcfg).total
    return [
        ("forward",
         lambda th, phi, b, bh, bb: pipe_forward(hcfg, bcfg, th, phi, b, bh, bb),
         (vec(ph), vec(pb), batch, scal, scal)),
        ("decode",
         lambda th, phi, lh, lb: (pipe_decode(hcfg, bcfg, th, phi, lh, lb),),
         (vec(ph), vec(pb), lath, latb)),
    ]
