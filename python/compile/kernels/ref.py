"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here written
with nothing but `jax.numpy`.  pytest (python/tests/test_kernels.py) sweeps
shapes with hypothesis and asserts the kernel output — and the custom-VJP
gradients — match these oracles to float32 tolerance.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Scaled dot-product self-attention, batched: [B, n, d] x3 -> [B, n, d].

    Eq. 3 of the paper: softmax(QK^T / sqrt(d_k)) V, computed per
    hyper-block over its n block embeddings.
    """
    d = q.shape[-1]
    s = jnp.einsum("bnd,bmd->bnm", q, k) / jnp.sqrt(jnp.float32(d))
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bnm,bmd->bnd", p, v)


def linear_ref(x: jax.Array, w: jax.Array, b: jax.Array,
               act: str = "none") -> jax.Array:
    """Fused y = act(x @ w + b), x: [B, K], w: [K, N], b: [N]."""
    y = x @ w + b
    if act == "relu":
        y = jnp.maximum(y, 0.0)
    elif act != "none":
        raise ValueError(f"unknown activation {act!r}")
    return y


def layernorm_ref(x: jax.Array, gamma: jax.Array, beta: jax.Array,
                  eps: float = 1e-5) -> jax.Array:
    """Row-wise LayerNorm over the last dim: x [B, D], gamma/beta [D]."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * gamma + beta
