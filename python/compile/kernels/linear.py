"""Fused linear(+bias)(+ReLU) Pallas kernel with Pallas backward kernels.

Forward: ``y = act(x @ w + b)`` with x ``[B, K]``, w ``[K, N]``.  The grid
tiles rows of ``x`` and columns of ``w``; each program keeps an
``[tm, K]`` x ``[K, tn]`` working set in VMEM and emits one ``[tm, tn]``
output tile — the MXU-shaped inner product.  Tile sizes are chosen as the
largest divisors of B and N below caps so every shape in the model (block
dims 1280/1536/1521, hiddens 512/256, embeds/latents down to 8) tiles
exactly with no padding logic in-kernel.

Backward:
  * ``dx = g @ wᵀ`` reuses the forward matmul kernel (bias-free, no act).
  * ``dw = xᵀ @ g`` has its own kernel gridded over (K-tiles, N-tiles) with
    the full batch resident per program.
  * ``db = Σ_B g`` is a row-sum kernel gridded over N-tiles.

where ``g = dy * 1[y > 0]`` for ReLU (mask applied in the dw/db/dx feeds).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _tile(dim: int, cap: int) -> int:
    """Largest divisor of `dim` that is <= cap (>=1)."""
    t = min(dim, cap)
    while dim % t != 0:
        t -= 1
    return t


def _matmul_kernel(x_ref, w_ref, b_ref, o_ref, *, act: str):
    y = jnp.dot(x_ref[...], w_ref[...])            # [tm, K] @ [K, tn]
    y = y + b_ref[...]                             # [1, tn] broadcast
    if act == "relu":
        y = jnp.maximum(y, 0.0)
    o_ref[...] = y


def _matmul(x, w, b, act: str):
    bsz, kdim = x.shape
    ndim = w.shape[1]
    tm, tn = _tile(bsz, 128), _tile(ndim, 256)
    b2 = b.reshape(1, ndim)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, act=act),
        grid=(bsz // tm, ndim // tn),
        in_specs=[
            pl.BlockSpec((tm, kdim), lambda i, j: (i, 0)),
            pl.BlockSpec((kdim, tn), lambda i, j: (0, j)),
            pl.BlockSpec((1, tn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bsz, ndim), x.dtype),
        interpret=True,
    )(x, w, b2)


def _dw_kernel(x_ref, g_ref, dw_ref):
    dw_ref[...] = jnp.dot(x_ref[...].T, g_ref[...])   # [tk, B]ᵀ… -> [tk, tn]


def _dw(x, g):
    bsz, kdim = x.shape
    ndim = g.shape[1]
    tk, tn = _tile(kdim, 256), _tile(ndim, 256)
    return pl.pallas_call(
        _dw_kernel,
        grid=(kdim // tk, ndim // tn),
        in_specs=[
            pl.BlockSpec((bsz, tk), lambda i, j: (0, i)),
            pl.BlockSpec((bsz, tn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((tk, tn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((kdim, ndim), x.dtype),
        interpret=True,
    )(x, g)


def _db_kernel(g_ref, db_ref):
    db_ref[...] = jnp.sum(g_ref[...], axis=0, keepdims=True)


def _db(g):
    bsz, ndim = g.shape
    tn = _tile(ndim, 512)
    out = pl.pallas_call(
        _db_kernel,
        grid=(ndim // tn,),
        in_specs=[pl.BlockSpec((bsz, tn), lambda j: (0, j))],
        out_specs=pl.BlockSpec((1, tn), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, ndim), g.dtype),
        interpret=True,
    )(g)
    return out.reshape(ndim)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def linear(x: jax.Array, w: jax.Array, b: jax.Array,
           act: str = "none") -> jax.Array:
    """Fused y = act(x @ w + b); act in {"none", "relu"}."""
    return _matmul(x, w, b, act)


def _linear_fwd(x, w, b, act):
    y = _matmul(x, w, b, act)
    return y, (x, w, y)


def _linear_bwd(act, res, dy):
    x, w, y = res
    g = jnp.where(y > 0.0, dy, 0.0) if act == "relu" else dy
    # dx = g @ wᵀ — forward kernel with zero bias, no activation.
    zb = jnp.zeros((w.shape[0],), dtype=x.dtype)
    dx = _matmul(g, w.T, zb, "none")
    return dx, _dw(x, g), _db(g)


linear.defvjp(_linear_fwd, _linear_bwd)
