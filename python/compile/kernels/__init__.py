"""Layer-1 Pallas kernels (build-time only; lowered into the model HLO).

All kernels run under ``interpret=True`` so they lower to plain HLO that the
CPU PJRT plugin (and the rust `xla` crate) can execute.  Each exposes a
jax-differentiable entry point via ``jax.custom_vjp`` whose forward AND
backward passes are themselves Pallas kernels.

Hardware adaptation note (DESIGN.md §3): the paper trains on A100s; here
tiles are sized for a TPU-style VMEM scratchpad (~16 MB) and the MXU, with
BlockSpec index maps expressing the HBM<->VMEM schedule the CUDA version
would express with threadblocks.
"""

from .attention import attention
from .linear import linear
from .layernorm import layernorm

__all__ = ["attention", "linear", "layernorm"]
