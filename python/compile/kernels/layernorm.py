"""Row-wise LayerNorm Pallas kernel with a Pallas backward pass.

Used twice in the model: Eq. 6's `norm` over the k block embeddings before
self-attention, and the BAE's residual re-scaling (paper §II-C).  Rows are
independent, so the grid tiles the batch dimension; each program holds a
``[tm, D]`` tile plus the ``[D]`` affine params in VMEM.

Forward saves the per-row mean and reciprocal std (2 floats/row) so the
backward kernel skips the reduction re-derivation.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _tile(dim: int, cap: int) -> int:
    t = min(dim, cap)
    while dim % t != 0:
        t -= 1
    return t


def _fwd_kernel(x_ref, g_ref, b_ref, y_ref, mu_ref, rs_ref, *, eps: float):
    x = x_ref[...]                                     # [tm, D]
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    rstd = jax.lax.rsqrt(var + eps)
    y_ref[...] = (x - mu) * rstd * g_ref[...] + b_ref[...]
    mu_ref[...] = mu
    rs_ref[...] = rstd


def _bwd_kernel(x_ref, g_ref, mu_ref, rs_ref, dy_ref,
                dx_ref, dg_ref, db_ref):
    x = x_ref[...]
    gamma = g_ref[...]
    mu = mu_ref[...]
    rstd = rs_ref[...]
    dy = dy_ref[...]
    xhat = (x - mu) * rstd                             # [tm, D]
    dg_ref[...] = jnp.sum(dy * xhat, axis=0, keepdims=True)
    db_ref[...] = jnp.sum(dy, axis=0, keepdims=True)
    dxh = dy * gamma
    d = x.shape[-1]
    # dx = rstd * (dxh - mean(dxh) - xhat * mean(dxh * xhat))
    m1 = jnp.sum(dxh, axis=-1, keepdims=True) / d
    m2 = jnp.sum(dxh * xhat, axis=-1, keepdims=True) / d
    dx_ref[...] = rstd * (dxh - m1 - xhat * m2)


def _fwd_impl(x, gamma, beta, eps):
    bsz, d = x.shape
    tm = _tile(bsz, 128)
    g2, b2 = gamma.reshape(1, d), beta.reshape(1, d)
    return pl.pallas_call(
        functools.partial(_fwd_kernel, eps=eps),
        grid=(bsz // tm,),
        in_specs=[
            pl.BlockSpec((tm, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((tm, d), lambda i: (i, 0)),
            pl.BlockSpec((tm, 1), lambda i: (i, 0)),
            pl.BlockSpec((tm, 1), lambda i: (i, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((bsz, d), x.dtype),
            jax.ShapeDtypeStruct((bsz, 1), x.dtype),
            jax.ShapeDtypeStruct((bsz, 1), x.dtype),
        ),
        interpret=True,
    )(x, g2, b2)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def layernorm(x: jax.Array, gamma: jax.Array, beta: jax.Array,
              eps: float = 1e-5) -> jax.Array:
    """Row-wise layernorm over the last axis of x [B, D]."""
    y, _, _ = _fwd_impl(x, gamma, beta, eps)
    return y


def _layernorm_fwd(x, gamma, beta, eps):
    y, mu, rstd = _fwd_impl(x, gamma, beta, eps)
    return y, (x, gamma, mu, rstd)


def _layernorm_bwd(eps, res, dy):
    x, gamma, mu, rstd = res
    bsz, d = x.shape
    tm = bsz  # single tile: dgamma/dbeta reduce over the whole batch
    g2 = gamma.reshape(1, d)
    dx, dg, db = pl.pallas_call(
        _bwd_kernel,
        grid=(bsz // tm,),
        in_specs=[
            pl.BlockSpec((tm, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((tm, 1), lambda i: (i, 0)),
            pl.BlockSpec((tm, 1), lambda i: (i, 0)),
            pl.BlockSpec((tm, d), lambda i: (i, 0)),
        ],
        out_specs=(
            pl.BlockSpec((tm, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((bsz, d), x.dtype),
            jax.ShapeDtypeStruct((1, d), x.dtype),
            jax.ShapeDtypeStruct((1, d), x.dtype),
        ),
        interpret=True,
    )(x, g2, mu, rstd, dy)
    return dx, dg.reshape(d), db.reshape(d)


layernorm.defvjp(_layernorm_fwd, _layernorm_bwd)
