"""Pallas self-attention kernel (Eq. 3) with a Pallas backward pass.

The HBAE applies attention over the ``n`` block embeddings of one
hyper-block (n = k <= 10, d = 128), so a whole hyper-block tile
``[n, d]`` is tiny (n*d*4 B ~ 5 KB) and trivially VMEM-resident.  The grid
axis is the hyper-block batch: program ``i`` owns hyper-block ``i`` — the
BlockSpec index map is the HBM->VMEM schedule.  On a real TPU the two
``[n,d] @ [d,n]``-shaped contractions map onto the MXU; here we lower with
``interpret=True`` (mandatory for CPU PJRT — see DESIGN.md §3).

Forward saves the softmax matrix ``p`` (n x n, negligible) so the backward
kernel avoids recomputing the row-max/exp reduction.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, p_ref, *, scale: float):
    q = q_ref[0]                        # [n, d]
    k = k_ref[0]
    v = v_ref[0]
    s = jnp.dot(q, k.T) * scale         # [n, n]
    s = s - jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    p_ref[0] = p
    o_ref[0] = jnp.dot(p, v)


def _bwd_kernel(q_ref, k_ref, v_ref, p_ref, do_ref,
                dq_ref, dk_ref, dv_ref, *, scale: float):
    q = q_ref[0]
    k = k_ref[0]
    v = v_ref[0]
    p = p_ref[0]
    do = do_ref[0]
    dv_ref[0] = jnp.dot(p.T, do)                            # [n, d]
    dp = jnp.dot(do, v.T)                                   # [n, n]
    # softmax jacobian-vector product: ds = p * (dp - sum(dp * p, -1))
    ds = p * (dp - jnp.sum(dp * p, axis=-1, keepdims=True))
    ds = ds * scale
    dq_ref[0] = jnp.dot(ds, k)
    dk_ref[0] = jnp.dot(ds.T, q)


def _row_spec(n: int, d: int) -> pl.BlockSpec:
    return pl.BlockSpec((1, n, d), lambda i: (i, 0, 0))


def _sq_spec(n: int) -> pl.BlockSpec:
    return pl.BlockSpec((1, n, n), lambda i: (i, 0, 0))


def _attention_fwd_impl(q, k, v):
    bsz, n, d = q.shape
    scale = 1.0 / float(d) ** 0.5
    out_shapes = (
        jax.ShapeDtypeStruct((bsz, n, d), q.dtype),
        jax.ShapeDtypeStruct((bsz, n, n), q.dtype),
    )
    o, p = pl.pallas_call(
        functools.partial(_fwd_kernel, scale=scale),
        grid=(bsz,),
        in_specs=[_row_spec(n, d)] * 3,
        out_specs=(_row_spec(n, d), _sq_spec(n)),
        out_shape=out_shapes,
        interpret=True,
    )(q, k, v)
    return o, p


@jax.custom_vjp
def attention(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """softmax(q kᵀ / sqrt(d)) v over [B, n, d] inputs (Eq. 3)."""
    o, _ = _attention_fwd_impl(q, k, v)
    return o


def _attention_fwd(q, k, v):
    o, p = _attention_fwd_impl(q, k, v)
    return o, (q, k, v, p)


def _attention_bwd(res, do):
    q, k, v, p = res
    bsz, n, d = q.shape
    scale = 1.0 / float(d) ** 0.5
    out_shapes = tuple(jax.ShapeDtypeStruct((bsz, n, d), q.dtype)
                       for _ in range(3))
    dq, dk, dv = pl.pallas_call(
        functools.partial(_bwd_kernel, scale=scale),
        grid=(bsz,),
        in_specs=[_row_spec(n, d), _row_spec(n, d), _row_spec(n, d),
                  _sq_spec(n), _row_spec(n, d)],
        out_specs=(_row_spec(n, d), _row_spec(n, d), _row_spec(n, d)),
        out_shape=out_shapes,
        interpret=True,
    )(q, k, v, p, do)
    return dq, dk, dv


attention.defvjp(_attention_fwd, _attention_bwd)
