"""L2 correctness: parameter packing, shapes, training dynamics, pipeline."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, model

jax.config.update("jax_platform_name", "cpu")

TINY_H = configs.HbaeConfig(name="tiny", block_dim=40, k=4, hidden=32,
                            embed=16, latent=8, batch=4)
TINY_H_WOA = configs.HbaeConfig(name="tiny", block_dim=40, k=4, hidden=32,
                                embed=16, latent=8, batch=4, attention=False)
TINY_B = configs.BaeConfig(name="tiny", block_dim=40, hidden=24, latent=4,
                           batch=16)


def batch_for(cfg, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed),
                             (cfg.batch, cfg.k, cfg.block_dim), jnp.float32)


# ---------------------------------------------------------------------------
# Param spec / packing
# ---------------------------------------------------------------------------

def test_spec_offsets_are_contiguous():
    for sp in (model.hbae_spec(TINY_H), model.hbae_spec(TINY_H_WOA),
               model.bae_spec(TINY_B)):
        expect = 0
        for ent in sp.layout():
            assert ent["offset"] == expect
            n = 1
            for s in ent["shape"]:
                n *= s
            expect += n
        assert sp.total == expect


def test_unpack_round_trips_values():
    sp = model.bae_spec(TINY_B)
    flat = jnp.arange(sp.total, dtype=jnp.float32)
    parts = sp.unpack(flat)
    # reassemble in layout order and compare
    re = jnp.concatenate([parts[e["name"]].ravel() for e in sp.layout()])
    np.testing.assert_array_equal(re, flat)


def test_init_deterministic_and_scaled():
    sp = model.hbae_spec(TINY_H)
    a = sp.init(jax.random.PRNGKey(7))
    b = sp.init(jax.random.PRNGKey(7))
    np.testing.assert_array_equal(a, b)
    parts = sp.unpack(a)
    assert float(jnp.max(jnp.abs(parts["enc_w1"]))) < 1.0  # glorot bounded
    np.testing.assert_array_equal(parts["enc_b1"], 0.0)
    np.testing.assert_array_equal(parts["ln1_g"], 1.0)


def test_woa_spec_has_no_attention_params():
    names = {e["name"] for e in model.hbae_spec(TINY_H_WOA).layout()}
    assert not names & {"wq1", "wk1", "wv1", "wq2", "wk2", "wv2",
                        "ln1_g", "ln2_g"}
    assert model.hbae_spec(TINY_H_WOA).total < model.hbae_spec(TINY_H).total


# ---------------------------------------------------------------------------
# Forward shapes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cfg", [TINY_H, TINY_H_WOA])
def test_hbae_shapes(cfg):
    theta = model.hbae_spec(cfg).init(jax.random.PRNGKey(0))
    b = batch_for(cfg)
    lat = model.hbae_encode(cfg, theta, b)
    assert lat.shape == (cfg.batch, cfg.latent)
    y = model.hbae_decode(cfg, theta, lat)
    assert y.shape == b.shape


def test_bae_shapes():
    phi = model.bae_spec(TINY_B).init(jax.random.PRNGKey(0))
    r = jax.random.normal(jax.random.PRNGKey(1),
                          (TINY_B.batch, TINY_B.block_dim))
    lat = model.bae_encode(TINY_B, phi, r)
    assert lat.shape == (TINY_B.batch, TINY_B.latent)
    rhat = model.bae_decode(TINY_B, phi, lat)
    assert rhat.shape == r.shape


def test_dataset_preset_shapes_consistent():
    """Presets must satisfy the pipe constraint Nb == Nh * k."""
    for h, b in [(configs.s3d_hbae(), configs.s3d_bae()),
                 (configs.e3sm_hbae(), configs.e3sm_bae()),
                 (configs.xgc_hbae(), configs.xgc_bae())]:
        assert h.block_dim == b.block_dim
        assert b.batch == h.batch * h.k


# ---------------------------------------------------------------------------
# Training dynamics
# ---------------------------------------------------------------------------

def run_steps(step_fn, theta, batch, n, lr=1e-2):
    m = jnp.zeros_like(theta)
    v = jnp.zeros_like(theta)
    t = jnp.float32(0)
    losses = []
    for _ in range(n):
        theta, m, v, t, loss = step_fn(theta, m, v, t, jnp.float32(lr), batch)
        losses.append(float(loss))
    return theta, losses


def test_hbae_training_reduces_loss():
    theta = model.hbae_spec(TINY_H).init(jax.random.PRNGKey(0))
    step = jax.jit(lambda *a: model.hbae_train_step(TINY_H, *a))
    _, losses = run_steps(step, theta, batch_for(TINY_H), 40)
    assert losses[-1] < 0.5 * losses[0]


def test_hbae_woa_training_reduces_loss():
    theta = model.hbae_spec(TINY_H_WOA).init(jax.random.PRNGKey(0))
    step = jax.jit(lambda *a: model.hbae_train_step(TINY_H_WOA, *a))
    _, losses = run_steps(step, theta, batch_for(TINY_H_WOA), 40)
    assert losses[-1] < 0.5 * losses[0]


def test_bae_training_reduces_loss():
    phi = model.bae_spec(TINY_B).init(jax.random.PRNGKey(0))
    r = 0.1 * jax.random.normal(jax.random.PRNGKey(3),
                                (TINY_B.batch, TINY_B.block_dim))
    step = jax.jit(lambda *a: model.bae_train_step(TINY_B, *a))
    _, losses = run_steps(step, phi, r, 40)
    assert losses[-1] < 0.5 * losses[0]


def test_adam_step_counter_increments():
    theta = model.bae_spec(TINY_B).init(jax.random.PRNGKey(0))
    r = jnp.ones((TINY_B.batch, TINY_B.block_dim))
    m = jnp.zeros_like(theta)
    v = jnp.zeros_like(theta)
    _, _, _, t, _ = model.bae_train_step(TINY_B, theta, m, v,
                                         jnp.float32(4.0), jnp.float32(1e-3), r)
    assert float(t) == 5.0


# ---------------------------------------------------------------------------
# Fused pipeline
# ---------------------------------------------------------------------------

def test_pipe_forward_decode_consistent():
    theta = model.hbae_spec(TINY_H).init(jax.random.PRNGKey(0))
    phi = model.bae_spec(TINY_B).init(jax.random.PRNGKey(1))
    b = batch_for(TINY_H)
    lh, lb, recon = model.pipe_forward(TINY_H, TINY_B, theta, phi, b,
                                       jnp.float32(0.0), jnp.float32(0.0))
    recon2 = model.pipe_decode(TINY_H, TINY_B, theta, phi, lh, lb)
    np.testing.assert_allclose(recon, recon2, rtol=1e-5, atol=1e-5)


def test_pipe_quantization_snaps_latents():
    theta = model.hbae_spec(TINY_H).init(jax.random.PRNGKey(0))
    phi = model.bae_spec(TINY_B).init(jax.random.PRNGKey(1))
    b = batch_for(TINY_H)
    bin_h = 0.25
    lh, lb, _ = model.pipe_forward(TINY_H, TINY_B, theta, phi, b,
                                   jnp.float32(bin_h), jnp.float32(0.1))
    codes = np.asarray(lh) / bin_h
    np.testing.assert_allclose(codes, np.round(codes), atol=1e-4)


def test_pipe_zero_bin_means_no_quantization():
    theta = model.hbae_spec(TINY_H).init(jax.random.PRNGKey(0))
    phi = model.bae_spec(TINY_B).init(jax.random.PRNGKey(1))
    b = batch_for(TINY_H)
    lh, _, _ = model.pipe_forward(TINY_H, TINY_B, theta, phi, b,
                                  jnp.float32(0.0), jnp.float32(0.0))
    np.testing.assert_allclose(lh, model.hbae_encode(TINY_H, theta, b),
                               rtol=1e-6, atol=1e-6)


def test_pipe_quantization_error_bounded_by_half_bin():
    theta = model.hbae_spec(TINY_H).init(jax.random.PRNGKey(0))
    phi = model.bae_spec(TINY_B).init(jax.random.PRNGKey(1))
    b = batch_for(TINY_H)
    raw = np.asarray(model.hbae_encode(TINY_H, theta, b))
    for bin_h in (0.05, 0.5):
        lh, _, _ = model.pipe_forward(TINY_H, TINY_B, theta, phi, b,
                                      jnp.float32(bin_h), jnp.float32(0.0))
        assert np.max(np.abs(np.asarray(lh) - raw)) <= bin_h / 2 + 1e-6
