"""AOT path: lowering produces parseable HLO text + a consistent manifest."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import configs, model
from compile.aot import to_hlo_text, input_fingerprint, lower_group

jax.config.update("jax_platform_name", "cpu")

TINY = configs.HbaeConfig(name="tiny", block_dim=20, k=3, hidden=16,
                          embed=8, latent=4, batch=2)
TINY_B = configs.BaeConfig(name="tiny", block_dim=20, hidden=12, latent=4,
                           batch=6)


def test_hlo_text_is_hlo(tmp_path):
    lowered = jax.jit(lambda x: (x * 2.0,)).lower(
        jax.ShapeDtypeStruct((4,), jnp.float32))
    text = to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "ENTRY" in text


def test_lower_group_writes_all_entries(tmp_path):
    man = {"groups": {}}
    lower_group(TINY.group, model.hbae_entries(TINY), str(tmp_path), man,
                {"kind": "hbae", "param_dim": model.hbae_spec(TINY).total})
    ginfo = man["groups"][TINY.group]
    assert set(ginfo["entries"]) == {"init", "train_step", "encode", "decode"}
    for name, ent in ginfo["entries"].items():
        path = tmp_path / ent["file"]
        assert path.exists() and path.stat().st_size == ent["hlo_bytes"]
        assert path.read_text().startswith("HloModule")


def test_manifest_signatures_match_specs(tmp_path):
    man = {"groups": {}}
    lower_group(TINY_B.group, model.bae_entries(TINY_B), str(tmp_path), man,
                {"kind": "bae"})
    ent = man["groups"][TINY_B.group]["entries"]["train_step"]
    pdim = model.bae_spec(TINY_B).total
    assert ent["inputs"][0]["shape"] == [pdim]          # phi
    assert ent["inputs"][5]["shape"] == [TINY_B.batch, TINY_B.block_dim]
    assert ent["outputs"][0]["shape"] == [pdim]          # phi'
    assert ent["outputs"][4]["shape"] == []              # scalar loss
    enc = man["groups"][TINY_B.group]["entries"]["encode"]
    assert enc["outputs"][0]["shape"] == [TINY_B.batch, TINY_B.latent]


def test_fingerprint_stable():
    assert input_fingerprint() == input_fingerprint()


def test_default_groups_unique_names():
    h, b, p = configs.default_groups()
    names = [c.group for c in h] + [c.group for c in b] + [c.group for c in p]
    assert len(names) == len(set(names))


@pytest.mark.skipif(not os.path.exists(
    os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")),
    reason="artifacts not built")
def test_built_manifest_covers_default_groups():
    path = os.path.join(os.path.dirname(__file__),
                        "../../artifacts/manifest.json")
    man = json.load(open(path))
    h, b, p = configs.default_groups()
    for cfg in list(h) + list(b) + list(p):
        assert cfg.group in man["groups"], cfg.group
    # every referenced file exists
    root = os.path.dirname(path)
    for g in man["groups"].values():
        for ent in g["entries"].values():
            assert os.path.exists(os.path.join(root, ent["file"]))
