"""L1 correctness: Pallas kernels vs pure-jnp oracles, fwd and bwd.

Hypothesis sweeps shapes; every property asserts allclose against ref.py.
This is the core correctness signal for everything the rust runtime
executes — the kernels lower into every artifact.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention, linear, layernorm
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

TOL = dict(rtol=2e-4, atol=2e-5)


def rng(seed, *shape):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(b=st.integers(1, 6), n=st.integers(1, 12),
       d=st.sampled_from([1, 3, 8, 16, 64, 128]), seed=st.integers(0, 99))
def test_attention_fwd_matches_ref(b, n, d, seed):
    q, k, v = rng(seed, b, n, d), rng(seed + 1, b, n, d), rng(seed + 2, b, n, d)
    np.testing.assert_allclose(attention(q, k, v),
                               ref.attention_ref(q, k, v), **TOL)


@settings(max_examples=10, deadline=None)
@given(b=st.integers(1, 4), n=st.integers(2, 10),
       d=st.sampled_from([4, 16, 32]), seed=st.integers(0, 99))
def test_attention_grads_match_ref(b, n, d, seed):
    q, k, v = rng(seed, b, n, d), rng(seed + 1, b, n, d), rng(seed + 2, b, n, d)

    def f(fn):
        return lambda *a: jnp.sum(jnp.sin(fn(*a)))

    g = jax.grad(f(attention), argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f(ref.attention_ref), argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(g, gr):
        np.testing.assert_allclose(a, b_, rtol=1e-3, atol=1e-4)


def test_attention_rows_sum_preserved():
    """Attention output of constant V rows is that constant (softmax sums 1)."""
    q, k = rng(0, 2, 5, 8), rng(1, 2, 5, 8)
    v = jnp.ones((2, 5, 8)) * 3.25
    np.testing.assert_allclose(attention(q, k, v), v, **TOL)


def test_attention_permutation_equivariance():
    """Permuting the n axis of q permutes outputs the same way."""
    q, k, v = rng(3, 1, 6, 16), rng(4, 1, 6, 16), rng(5, 1, 6, 16)
    perm = jnp.array([3, 1, 5, 0, 4, 2])
    out = attention(q, k, v)
    out_p = attention(q[:, perm], k, v)
    np.testing.assert_allclose(out[:, perm], out_p, **TOL)


# ---------------------------------------------------------------------------
# linear
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(bsz=st.sampled_from([1, 3, 7, 32, 130]),
       kdim=st.sampled_from([1, 5, 17, 64]),
       ndim=st.sampled_from([1, 8, 39, 257]),
       act=st.sampled_from(["none", "relu"]), seed=st.integers(0, 99))
def test_linear_fwd_matches_ref(bsz, kdim, ndim, act, seed):
    x, w = rng(seed, bsz, kdim), rng(seed + 1, kdim, ndim)
    b = rng(seed + 2, ndim)
    np.testing.assert_allclose(linear(x, w, b, act),
                               ref.linear_ref(x, w, b, act), **TOL)


@settings(max_examples=10, deadline=None)
@given(bsz=st.sampled_from([2, 9, 32]), kdim=st.sampled_from([3, 16]),
       ndim=st.sampled_from([2, 13]), act=st.sampled_from(["none", "relu"]),
       seed=st.integers(0, 99))
def test_linear_grads_match_ref(bsz, kdim, ndim, act, seed):
    x, w = rng(seed, bsz, kdim), rng(seed + 1, kdim, ndim)
    b = rng(seed + 2, ndim)

    def f(fn):
        return lambda *a: jnp.sum(jnp.cos(fn(*a, act)))

    g = jax.grad(f(linear), argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(f(ref.linear_ref), argnums=(0, 1, 2))(x, w, b)
    for a, b_ in zip(g, gr):
        np.testing.assert_allclose(a, b_, rtol=1e-3, atol=1e-4)


def test_linear_relu_clamps():
    x = jnp.array([[-10.0, 10.0]])
    w = jnp.eye(2)
    b = jnp.zeros(2)
    out = np.asarray(linear(x, w, b, "relu"))
    assert out[0, 0] == 0.0 and out[0, 1] == 10.0


def test_linear_model_shapes():
    """The exact shapes the three dataset presets feed the kernel."""
    for bsz, kdim, ndim in [(320, 1280, 512), (512, 128, 512),
                            (160, 1536, 256), (256, 1521, 256),
                            (32, 1280, 128), (256, 256, 16)]:
        x, w = rng(0, bsz, kdim), rng(1, kdim, ndim)
        b = rng(2, ndim)
        np.testing.assert_allclose(linear(x, w, b, "relu"),
                                   ref.linear_ref(x, w, b, "relu"), **TOL)


# ---------------------------------------------------------------------------
# layernorm
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(bsz=st.sampled_from([1, 2, 7, 33, 128]),
       d=st.sampled_from([2, 3, 17, 128, 1521]), seed=st.integers(0, 99))
def test_layernorm_fwd_matches_ref(bsz, d, seed):
    x = rng(seed, bsz, d)
    g, b = rng(seed + 1, d), rng(seed + 2, d)
    np.testing.assert_allclose(layernorm(x, g, b),
                               ref.layernorm_ref(x, g, b), **TOL)


@settings(max_examples=10, deadline=None)
@given(bsz=st.sampled_from([2, 9]), d=st.sampled_from([4, 33]),
       seed=st.integers(0, 99))
def test_layernorm_grads_match_ref(bsz, d, seed):
    x = rng(seed, bsz, d)
    g, b = rng(seed + 1, d), rng(seed + 2, d)

    def f(fn):
        return lambda *a: jnp.sum(jnp.tanh(fn(*a)))

    gr1 = jax.grad(f(layernorm), argnums=(0, 1, 2))(x, g, b)
    gr2 = jax.grad(f(ref.layernorm_ref), argnums=(0, 1, 2))(x, g, b)
    for a, b_ in zip(gr1, gr2):
        np.testing.assert_allclose(a, b_, rtol=1e-3, atol=1e-4)


def test_layernorm_output_standardized():
    x = rng(7, 16, 256)
    y = np.asarray(layernorm(x, jnp.ones(256), jnp.zeros(256)))
    np.testing.assert_allclose(y.mean(axis=-1), 0.0, atol=1e-5)
    np.testing.assert_allclose(y.std(axis=-1), 1.0, atol=1e-3)


def test_layernorm_affine():
    x = rng(8, 4, 32)
    g = jnp.full((32,), 2.0)
    b = jnp.full((32,), -1.0)
    base = np.asarray(layernorm(x, jnp.ones(32), jnp.zeros(32)))
    out = np.asarray(layernorm(x, g, b))
    np.testing.assert_allclose(out, base * 2.0 - 1.0, rtol=1e-5, atol=1e-5)
