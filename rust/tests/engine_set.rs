//! Dataset-level engine acceptance tests: multi-field Archive v2
//! containers round-trip every field within the stated bound from the
//! serialized bytes alone, v1 single-field archives stay readable, and
//! compression is byte-deterministic across thread counts for every
//! codec.
//!
//! `sz3` / `zfp` are pure rust and run everywhere; `hier` / `gbae` need
//! the PJRT artifacts and skip (like the other integration tests) when
//! `artifacts/manifest.json` is absent.

use std::rc::Rc;

use attn_reduce::codec::{archive_stats, Codec, CodecBuilder, CodecKind, ErrorBound};
use attn_reduce::compressor::Archive;
use attn_reduce::config::{dataset_preset, DatasetKind, Scale, TrainConfig};
use attn_reduce::data;
use attn_reduce::engine::{compress_set_parallel, CodecExt, FieldSet};
use attn_reduce::runtime::Runtime;
use attn_reduce::util::parallel::with_thread_limit;

fn runtime() -> Option<Rc<Runtime>> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if !std::path::Path::new(dir).join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    std::env::set_var("ATTN_REDUCE_QUIET", "1");
    Some(Rc::new(Runtime::open(dir).expect("open artifacts")))
}

fn ckpt_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("attn_reduce_engine_{tag}"));
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// The acceptance scenario: one multi-species S3D set -> one Archive v2
/// that round-trips every field within the bound, restored from the
/// bytes alone via `for_archive`.
#[test]
fn s3d_multi_species_set_round_trips_within_bound() {
    let set = FieldSet::generate(DatasetKind::S3d, Scale::Smoke, 5);
    let bound = ErrorBound::Nrmse(1e-3);
    let mut b = CodecBuilder::new().scale(Scale::Smoke);
    let codec = b.build(CodecKind::Sz3, DatasetKind::S3d, set.field(0)).unwrap();
    let archive = codec.compress_set(&set, &bound).unwrap();
    assert!(archive.is_multi_field());
    assert_eq!(archive.field_count(), 5);

    // serialize, reparse, rebuild the codec from the container header
    let bytes = archive.to_bytes();
    let archive2 = Archive::from_bytes(&bytes).unwrap();
    let codec2 = b.for_archive(&archive2).unwrap();
    let back = codec2.decompress_set(&archive2).unwrap();
    assert_eq!(back.names(), set.names());
    let dataset = dataset_preset(DatasetKind::S3d, Scale::Smoke);
    for (i, (name, orig)) in set.iter().enumerate() {
        assert!(
            bound.satisfied_by(orig, back.field(i), &dataset),
            "field {name} violates {bound}"
        );
    }

    // set-level stats: CR numerator covers all fields, payload all
    // per-field payload sections
    let stats = archive_stats(&archive2).unwrap();
    assert!(stats.cr > 1.0, "set should compress: CR {}", stats.cr);
    let per_field_payload: usize = (0..5)
        .map(|i| archive2.field_archive(i).unwrap().cr_payload_bytes())
        .sum();
    assert_eq!(stats.cr_payload_bytes, per_field_payload);
}

#[test]
fn zfp_set_round_trips_and_certifies() {
    let set = FieldSet::generate(DatasetKind::E3sm, Scale::Smoke, 3);
    let bound = ErrorBound::Nrmse(1e-3);
    let mut b = CodecBuilder::new().scale(Scale::Smoke);
    let codec = b.build(CodecKind::Zfp, DatasetKind::E3sm, set.field(0)).unwrap();
    let archive = codec.compress_set(&set, &bound).unwrap();
    let back = b
        .for_archive(&archive)
        .unwrap()
        .decompress_set(&archive)
        .unwrap();
    for (i, (_, orig)) in set.iter().enumerate() {
        let e = attn_reduce::compressor::nrmse(orig, back.field(i));
        assert!(e <= 1e-3, "field {i}: NRMSE {e}");
    }
}

#[test]
fn single_field_archives_still_decompress_via_for_archive() {
    // the single-field path is untouched by the engine refactor; since
    // the block-index PR the pure codecs write v3 (v1 backward
    // compatibility is pinned byte-for-byte by tests/golden)
    let cfg = dataset_preset(DatasetKind::E3sm, Scale::Smoke);
    let field = data::generate(&cfg);
    let mut b = CodecBuilder::new().scale(Scale::Smoke);
    let codec = b.build(CodecKind::Sz3, DatasetKind::E3sm, &field).unwrap();
    let archive = codec.compress(&field, &ErrorBound::Nrmse(1e-3)).unwrap();
    assert_eq!(archive.version(), 3);
    let bytes = archive.to_bytes();
    let archive2 = Archive::from_bytes(&bytes).unwrap();
    let recon = b.for_archive(&archive2).unwrap().decompress(&archive2).unwrap();
    assert!(attn_reduce::compressor::nrmse(&field, &recon) <= 1e-3);
}

/// Determinism: compressing the same input with 1 thread and N threads
/// must produce byte-identical archives. Covers the pure codecs on both
/// the single-field and the fieldset paths.
#[test]
fn sz3_and_zfp_archives_byte_identical_across_thread_counts() {
    for kind in [DatasetKind::S3d, DatasetKind::E3sm] {
        let set = FieldSet::generate(kind, Scale::Smoke, 3);
        let bound = ErrorBound::Nrmse(1e-3);
        for ck in [CodecKind::Sz3, CodecKind::Zfp] {
            let mut b = CodecBuilder::new().scale(Scale::Smoke);
            let codec = b.build(ck, kind, set.field(0)).unwrap();
            let parallel = codec.compress_set(&set, &bound).unwrap().to_bytes();
            let serial = with_thread_limit(1, || {
                codec.compress_set(&set, &bound).unwrap().to_bytes()
            });
            assert_eq!(parallel, serial, "{ck:?} {kind:?} set archives differ");

            let single = codec.compress(set.field(0), &bound).unwrap().to_bytes();
            let single_serial = with_thread_limit(1, || {
                codec.compress(set.field(0), &bound).unwrap().to_bytes()
            });
            assert_eq!(single, single_serial, "{ck:?} {kind:?} single-field differ");
        }
    }
}

#[test]
fn field_parallel_path_matches_serial_packing() {
    let set = FieldSet::generate(DatasetKind::Xgc, Scale::Smoke, 4);
    let bound = ErrorBound::Nrmse(5e-3);
    let codec =
        attn_reduce::codec::Sz3Codec::new(dataset_preset(DatasetKind::Xgc, Scale::Smoke));
    let a = codec.compress_set(&set, &bound).unwrap().to_bytes();
    let b = compress_set_parallel(&codec, &set, &bound).unwrap().to_bytes();
    assert_eq!(a, b);
}

/// Learned codecs: same determinism guarantee, gated on artifacts.
#[test]
fn hier_and_gbae_archives_byte_identical_across_thread_counts() {
    let Some(rt) = runtime() else { return };
    let kind = DatasetKind::E3sm;
    let cfg = dataset_preset(kind, Scale::Smoke);
    let field = data::generate(&cfg);
    let train = TrainConfig { steps: 20, log_every: 1000, ..TrainConfig::default() };
    let bound = ErrorBound::Nrmse(1e-2);
    for ck in [CodecKind::Hier, CodecKind::Gbae] {
        let mut b = CodecBuilder::new()
            .scale(Scale::Smoke)
            .runtime(rt.clone())
            .ckpt_dir(ckpt_dir("determinism"))
            .train(train.clone());
        let codec = b.build(ck, kind, &field).unwrap();
        let parallel = codec.compress(&field, &bound).unwrap().to_bytes();
        let serial =
            with_thread_limit(1, || codec.compress(&field, &bound).unwrap().to_bytes());
        assert_eq!(parallel, serial, "{ck:?} archives differ across thread counts");
    }
}
