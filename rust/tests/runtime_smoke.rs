//! Integration smoke test: load real AOT artifacts, execute them via PJRT,
//! and check numerics against invariants the python tests established.
//!
//! Requires `make artifacts` (skipped otherwise).

use attn_reduce::runtime::{HostTensor, Runtime};

fn runtime() -> Option<Runtime> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if !std::path::Path::new(dir).join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    std::env::set_var("ATTN_REDUCE_QUIET", "1");
    Some(Runtime::open(dir).expect("open artifacts"))
}

#[test]
fn init_encode_decode_round_trip_shapes() {
    let Some(rt) = runtime() else { return };
    let group = "s3d_bae_L16";
    let pdim = rt.param_dim(group).unwrap();

    let init = rt.load(group, "init").unwrap();
    let theta = &init.run(&[]).unwrap()[0];
    assert_eq!(theta.shape, vec![pdim]);
    // glorot weights bounded; layernorm gammas are exactly 1
    let mx = theta.data.iter().fold(0f32, |a, &b| a.max(b.abs()));
    assert!(mx > 0.0 && mx <= 1.0, "max |theta| = {mx}");

    let enc = rt.load(group, "encode").unwrap();
    let batch_sig = &enc.info.inputs[1];
    let n: usize = batch_sig.len();
    let r = HostTensor::new(batch_sig.shape.clone(),
                            (0..n).map(|i| (i as f32 * 0.37).sin() * 0.1).collect());
    let lat = &enc.run(&[theta.clone(), r.clone()]).unwrap()[0];
    assert_eq!(lat.shape, enc.info.outputs[0].shape);

    let dec = rt.load(group, "decode").unwrap();
    let rhat = &dec.run(&[theta.clone(), lat.clone()]).unwrap()[0];
    assert_eq!(rhat.shape, r.shape);
    assert!(rhat.data.iter().all(|v| v.is_finite()));
}

#[test]
fn train_step_decreases_loss_via_pjrt() {
    let Some(rt) = runtime() else { return };
    let group = "s3d_bae_L16";
    let pdim = rt.param_dim(group).unwrap();
    let init = rt.load(group, "init").unwrap();
    let step = rt.load(group, "train_step").unwrap();

    let mut theta = init.run(&[]).unwrap().remove(0);
    let mut m = HostTensor::vec(vec![0.0; pdim]);
    let mut v = HostTensor::vec(vec![0.0; pdim]);
    let mut t = HostTensor::scalar(0.0);
    let lr = HostTensor::scalar(1e-3);
    let bs = step.info.inputs[5].clone();
    let batch = HostTensor::new(
        bs.shape.clone(),
        (0..bs.len()).map(|i| ((i % 97) as f32 / 97.0 - 0.5) * 0.2).collect(),
    );

    let mut losses = Vec::new();
    for _ in 0..8 {
        let mut out = step
            .run(&[theta.clone(), m.clone(), v.clone(), t.clone(), lr.clone(), batch.clone()])
            .unwrap();
        let loss = out.pop().unwrap().scalar_value();
        t = out.pop().unwrap();
        v = out.pop().unwrap();
        m = out.pop().unwrap();
        theta = out.pop().unwrap();
        losses.push(loss);
    }
    assert_eq!(t.scalar_value(), 8.0, "adam step counter");
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "loss should drop: {losses:?}"
    );
}

#[test]
fn pipe_forward_matches_separate_calls() {
    let Some(rt) = runtime() else { return };
    let hg = "s3d_hbae_L128";
    let bg = "s3d_bae_L16";
    let pg = "s3d_pipe_L128_16";

    let theta = rt.load(hg, "init").unwrap().run(&[]).unwrap().remove(0);
    let phi = rt.load(bg, "init").unwrap().run(&[]).unwrap().remove(0);

    let fwd = rt.load(pg, "forward").unwrap();
    let bsig = fwd.info.inputs[2].clone();
    let batch = HostTensor::new(
        bsig.shape.clone(),
        (0..bsig.len()).map(|i| ((i * 31 % 101) as f32 / 101.0 - 0.5)).collect(),
    );
    let zero = HostTensor::scalar(0.0);
    let outs = fwd
        .run(&[theta.clone(), phi.clone(), batch.clone(), zero.clone(), zero.clone()])
        .unwrap();
    let (lh, lb, recon) = (&outs[0], &outs[1], &outs[2]);

    // separate-call path must agree
    let enc = rt.load(hg, "encode").unwrap();
    let lh2 = &enc.run(&[theta.clone(), batch.clone()]).unwrap()[0];
    let max_d = lh
        .data
        .iter()
        .zip(&lh2.data)
        .fold(0f32, |a, (x, y)| a.max((x - y).abs()));
    assert!(max_d < 1e-4, "hbae latents disagree by {max_d}");

    // pipe decode(lh, lb) must reproduce recon
    let dec = rt.load(pg, "decode").unwrap();
    let recon2 = &dec.run(&[theta, phi, lh.clone(), lb.clone()]).unwrap()[0];
    let max_r = recon
        .data
        .iter()
        .zip(&recon2.data)
        .fold(0f32, |a, (x, y)| a.max((x - y).abs()));
    assert!(max_r < 1e-4, "pipe decode disagrees by {max_r}");
}

#[test]
fn quantized_latents_snap_to_bins() {
    let Some(rt) = runtime() else { return };
    let pg = "s3d_pipe_L128_16";
    let theta = rt.load("s3d_hbae_L128", "init").unwrap().run(&[]).unwrap().remove(0);
    let phi = rt.load("s3d_bae_L16", "init").unwrap().run(&[]).unwrap().remove(0);
    let fwd = rt.load(pg, "forward").unwrap();
    let bsig = fwd.info.inputs[2].clone();
    let batch = HostTensor::new(
        bsig.shape.clone(),
        (0..bsig.len()).map(|i| ((i * 13 % 89) as f32 / 89.0 - 0.5)).collect(),
    );
    let bin = 0.05f32;
    let outs = fwd
        .run(&[theta, phi, batch, HostTensor::scalar(bin), HostTensor::scalar(0.0)])
        .unwrap();
    for &x in &outs[0].data {
        let code = x / bin;
        assert!((code - code.round()).abs() < 1e-3, "latent {x} not on grid");
    }
}

#[test]
fn manifest_rejects_bad_shapes() {
    let Some(rt) = runtime() else { return };
    let enc = rt.load("s3d_bae_L16", "encode").unwrap();
    let bad = HostTensor::new(vec![1], vec![0.0]);
    assert!(enc.run(&[bad.clone(), bad]).is_err());
}
