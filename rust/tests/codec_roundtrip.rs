//! Unified-codec acceptance tests: every codec compresses under a typed
//! `ErrorBound` into a self-describing archive, and `for_archive`
//! restores the field from the serialized bytes alone (no dataset or
//! preset flags) with the stated bound verified.
//!
//! `sz3` / `zfp` are pure rust and run everywhere; `hier` / `gbae` need
//! the PJRT artifacts and skip (like the other integration tests) when
//! `artifacts/manifest.json` is absent.

use std::rc::Rc;

use attn_reduce::codec::{archive_stats, Codec, CodecBuilder, CodecKind, ErrorBound};
use attn_reduce::compressor::{nrmse, Archive};
use attn_reduce::config::{dataset_preset, DatasetKind, Scale, TrainConfig};
use attn_reduce::data;
use attn_reduce::runtime::Runtime;
use attn_reduce::tensor::Tensor;

fn runtime() -> Option<Rc<Runtime>> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if !std::path::Path::new(dir).join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    std::env::set_var("ATTN_REDUCE_QUIET", "1");
    Some(Rc::new(Runtime::open(dir).expect("open artifacts")))
}

fn ckpt_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("attn_reduce_codec_{tag}"));
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Serialize, reparse, rebuild the codec from the header alone, decode,
/// and verify the bound against the original field.
fn round_trip_and_verify(
    builder: &mut CodecBuilder,
    archive: Archive,
    field: &Tensor,
    bound: &ErrorBound,
    kind: DatasetKind,
    slack: f64,
) -> Tensor {
    let bytes = archive.to_bytes();
    let archive2 = Archive::from_bytes(&bytes).expect("reparse archive");
    // decode knowing NOTHING but the bytes (+ checkpoint dir for learned)
    let codec = builder.for_archive(&archive2).expect("rebuild codec from header");
    let recon = codec.decompress(&archive2).expect("decompress");
    assert_eq!(recon.shape(), field.shape());

    let dataset = dataset_preset(kind, Scale::Smoke);
    match *bound {
        ErrorBound::Nrmse(t) => {
            let e = nrmse(field, &recon);
            assert!(e <= t * slack, "NRMSE {e} > {t} (codec {})", codec.id());
            assert!(e > 0.0, "lossy codec should not be exact");
        }
        _ => {
            assert!(
                bound.satisfied_by(field, &recon, &dataset),
                "bound {bound} violated by codec {}",
                codec.id()
            );
        }
    }
    let stats = archive_stats(&archive2).expect("stats from header");
    assert!(stats.cr > 1.0, "should actually compress: CR {}", stats.cr);
    recon
}

#[test]
fn sz3_codec_meets_nrmse_bound_from_archive_alone() {
    for kind in [DatasetKind::S3d, DatasetKind::E3sm, DatasetKind::Xgc] {
        let field = data::generate(&dataset_preset(kind, Scale::Smoke));
        let mut b = CodecBuilder::new().scale(Scale::Smoke);
        let bound = ErrorBound::Nrmse(1e-3);
        let codec = b.build(CodecKind::Sz3, kind, &field).unwrap();
        assert_eq!(codec.id(), "sz3");
        let archive = codec.compress(&field, &bound).unwrap();
        round_trip_and_verify(&mut b, archive, &field, &bound, kind, 1.0001);
    }
}

#[test]
fn sz3_codec_honors_abs_and_tau_bounds() {
    let kind = DatasetKind::E3sm;
    let field = data::generate(&dataset_preset(kind, Scale::Smoke));
    let mut b = CodecBuilder::new().scale(Scale::Smoke);
    let codec = b.build(CodecKind::Sz3, kind, &field).unwrap();
    let abs = ErrorBound::PointwiseAbs((1e-3 * field.range()) as f64);
    let archive = codec.compress(&field, &abs).unwrap();
    round_trip_and_verify(&mut b, archive, &field, &abs, kind, 1.0);
    let tau = ErrorBound::L2Tau((5e-3 * field.range()) as f64);
    let archive = codec.compress(&field, &tau).unwrap();
    round_trip_and_verify(&mut b, archive, &field, &tau, kind, 1.0);
}

#[test]
fn zfp_codec_certifies_bounds_by_precision_search() {
    let kind = DatasetKind::E3sm;
    let field = data::generate(&dataset_preset(kind, Scale::Smoke));
    let mut b = CodecBuilder::new().scale(Scale::Smoke);
    let codec = b.build(CodecKind::Zfp, kind, &field).unwrap();
    assert_eq!(codec.id(), "zfp");
    for bound in [
        ErrorBound::Nrmse(1e-3),
        ErrorBound::PointwiseAbs((5e-3 * field.range()) as f64),
    ] {
        let archive = codec.compress(&field, &bound).unwrap();
        let p = archive.header.req("precision").unwrap().as_usize().unwrap();
        assert!((1..=26).contains(&p), "certified precision {p}");
        round_trip_and_verify(&mut b, archive, &field, &bound, kind, 1.0001);
    }
    // tighter bound must certify at >= precision of a looser one
    let loose = codec.compress(&field, &ErrorBound::Nrmse(1e-2)).unwrap();
    let tight = codec.compress(&field, &ErrorBound::Nrmse(1e-4)).unwrap();
    let lp = loose.header.req("precision").unwrap().as_usize().unwrap();
    let tp = tight.header.req("precision").unwrap().as_usize().unwrap();
    assert!(tp >= lp, "tight {tp} vs loose {lp}");
}

#[test]
fn baseline_archives_are_self_describing() {
    let kind = DatasetKind::S3d;
    let field = data::generate(&dataset_preset(kind, Scale::Smoke));
    let mut b = CodecBuilder::new().scale(Scale::Smoke);
    let codec = b.build(CodecKind::Sz3, kind, &field).unwrap();
    let archive = codec.compress(&field, &ErrorBound::Nrmse(1e-3)).unwrap();
    assert_eq!(archive.header_str("codec").unwrap(), "sz3");
    assert_eq!(
        archive.header.req("dataset").unwrap().req("kind").unwrap().as_str(),
        Some("s3d")
    );
    let bound = attn_reduce::codec::archive_bound(&archive);
    assert_eq!(bound, ErrorBound::Nrmse(1e-3));
}

#[test]
fn hier_codec_end_to_end_with_header_only_restore() {
    let Some(rt) = runtime() else { return };
    let kind = DatasetKind::S3d;
    let field = data::generate(&dataset_preset(kind, Scale::Smoke));
    let ckpt = ckpt_dir("hier");
    let mut b = CodecBuilder::new()
        .runtime(rt)
        .scale(Scale::Smoke)
        .ckpt_dir(&ckpt)
        .train(TrainConfig { steps: 25, log_every: 1000, ..TrainConfig::default() });
    let codec = b.build(CodecKind::Hier, kind, &field).unwrap();
    assert_eq!(codec.id(), "hier");
    let bound = ErrorBound::Nrmse(2e-3);
    let (archive, recon) = codec.compress_with_recon(&field, &bound).unwrap();
    let e = nrmse(&field, &recon);
    assert!(e <= 2e-3 * 1.01, "NRMSE {e}");

    let restored = round_trip_and_verify(&mut b, archive, &field, &bound, kind, 1.01);
    // header-only restore agrees with the compressor's reconstruction
    let max_d = recon
        .data()
        .iter()
        .zip(restored.data())
        .fold(0f32, |a, (x, y)| a.max((x - y).abs()));
    assert!(max_d <= 2e-5 * field.range(), "restore disagrees by {max_d}");

    // the typed L2Tau bound holds per GAE block too
    let dataset = dataset_preset(kind, Scale::Smoke);
    let tau = bound.gae_tau(&dataset, field.range() as f64);
    assert!(ErrorBound::L2Tau(tau as f64 * 1.001).satisfied_by(&field, &restored, &dataset));
}

#[test]
fn gbae_codec_end_to_end_with_header_only_restore() {
    let Some(rt) = runtime() else { return };
    let kind = DatasetKind::S3d;
    let field = data::generate(&dataset_preset(kind, Scale::Smoke));
    let ckpt = ckpt_dir("gbae");
    let mut b = CodecBuilder::new()
        .runtime(rt)
        .scale(Scale::Smoke)
        .ckpt_dir(&ckpt)
        .train(TrainConfig { steps: 25, log_every: 1000, ..TrainConfig::default() });
    let codec = b.build(CodecKind::Gbae, kind, &field).unwrap();
    assert_eq!(codec.id(), "gbae");
    let bound = ErrorBound::Nrmse(2e-3);
    let (archive, recon) = codec.compress_with_recon(&field, &bound).unwrap();
    assert!(archive.has_section("GLAT"));
    let e = nrmse(&field, &recon);
    assert!(e <= 2e-3 * 1.01, "NRMSE {e}");

    let restored = round_trip_and_verify(&mut b, archive, &field, &bound, kind, 1.01);
    let max_d = recon
        .data()
        .iter()
        .zip(restored.data())
        .fold(0f32, |a, (x, y)| a.max((x - y).abs()));
    assert!(max_d <= 2e-5 * field.range(), "restore disagrees by {max_d}");
}

#[test]
fn streaming_archive_matches_one_shot() {
    let Some(rt) = runtime() else { return };
    let kind = DatasetKind::E3sm;
    let field = data::generate(&dataset_preset(kind, Scale::Smoke));
    let ckpt = ckpt_dir("stream");
    let mut b = CodecBuilder::new()
        .runtime(rt)
        .scale(Scale::Smoke)
        .ckpt_dir(&ckpt)
        .train(TrainConfig { steps: 25, log_every: 1000, ..TrainConfig::default() });
    let codec = b.build_hier(kind, &field).unwrap();

    // AE-only (bound None): streamed archive decodes to the sequential
    // path's reconstruction (GAE disabled so the comparison is exact up
    // to fused-vs-unfused float ordering)
    let (stream_archive, stats) =
        codec.compress_streaming(&field, &ErrorBound::None, 4).unwrap();
    assert!(stats.batches > 0);
    let stream_recon = codec.decompress(&stream_archive).unwrap();
    let (_, seq_recon) = codec.compress_with_recon(&field, &ErrorBound::None).unwrap();
    let max_d = seq_recon
        .data()
        .iter()
        .zip(stream_recon.data())
        .fold(0f32, |a, (x, y)| a.max((x - y).abs()));
    assert!(max_d <= 1e-4 * field.range(), "stream vs one-shot differ by {max_d}");

    // and under a real bound, the streamed archive honors it on its own
    let bound = ErrorBound::Nrmse(2e-3);
    let (bounded_archive, _) = codec.compress_streaming(&field, &bound, 4).unwrap();
    let bounded_recon = codec.decompress(&bounded_archive).unwrap();
    let e = nrmse(&field, &bounded_recon);
    assert!(e <= 2e-3 * 1.01, "streamed NRMSE {e}");
}

#[test]
fn unknown_codec_id_is_rejected() {
    let mut archive = Archive::new(attn_reduce::util::json::obj(vec![]));
    archive.set_header("codec", attn_reduce::util::json::s("quantum"));
    archive.set_header(
        "dataset",
        dataset_preset(DatasetKind::S3d, Scale::Smoke).to_json(),
    );
    let err = CodecBuilder::new().for_archive(&archive).unwrap_err();
    assert!(format!("{err:#}").contains("quantum"), "{err:#}");
}
