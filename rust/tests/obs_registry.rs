//! Observability subsystem: histogram bucket semantics, quantile
//! estimation, concurrent counting from the executor pool, exposition
//! rendering (text + JSON), and logger level filtering.
//!
//! Every test registers its families in a *local* [`Registry`] (the
//! type is the same one behind `Registry::global`), so tests stay
//! independent of each other and of instrumented library code running
//! in the same process.

use attn_reduce::engine::Executor;
use attn_reduce::obs::log::Level;
use attn_reduce::obs::registry::{Registry, SeriesValue};
use attn_reduce::obs::{expo, log};
use attn_reduce::util::json::Value;

#[test]
fn histogram_bucket_boundaries_are_le() {
    let reg = Registry::new();
    let h = reg.histogram("test_h", "h", &[], &[10, 100, 1000], 1.0);
    // `le` semantics: a value equal to a bound lands in that bucket
    h.observe(10);
    h.observe(11);
    h.observe(100);
    h.observe(1000);
    h.observe(1001); // +Inf bucket
    assert_eq!(h.bucket_counts(), vec![1, 2, 1, 1]);
    assert_eq!(h.count(), 5);
    assert_eq!(h.sum_raw(), 10 + 11 + 100 + 1000 + 1001);

    // the snapshot renders cumulative buckets ending at +Inf
    let snap = reg.snapshot();
    assert_eq!(snap.len(), 1);
    let SeriesValue::Histogram { buckets, sum, count } = &snap[0].series[0].value else {
        panic!("expected histogram snapshot");
    };
    let cums: Vec<u64> = buckets.iter().map(|(_, c)| *c).collect();
    assert_eq!(cums, vec![1, 3, 4, 5], "cumulative, monotone");
    assert!(buckets.last().unwrap().0.is_infinite());
    assert_eq!(*count, 5);
    assert!((sum - 2122.0).abs() < 1e-9);
}

#[test]
fn histogram_quantiles_interpolate_and_clamp() {
    let reg = Registry::new();
    let h = reg.histogram("test_q", "h", &[], &[100, 200, 400], 1.0);
    assert_eq!(h.quantile(0.5), 0.0, "empty histogram reports 0");
    // 100 observations spread evenly through (100, 200]
    for i in 0..100 {
        h.observe(101 + i);
    }
    let p50 = h.quantile(0.5);
    assert!(
        (100.0..=200.0).contains(&p50),
        "median must land inside the containing bucket, got {p50}"
    );
    assert!((p50 - 150.0).abs() <= 2.0, "linear interpolation: got {p50}");
    // an observation past every bound clamps to the largest finite bound
    let reg2 = Registry::new();
    let h2 = reg2.histogram("test_q2", "h", &[], &[100], 1.0);
    h2.observe(1_000_000);
    assert_eq!(h2.quantile(0.99), 100.0);
    // unit scale applies to quantiles too
    let reg3 = Registry::new();
    let h3 = reg3.histogram("test_q3", "h", &[], &[1000, 2000], 1e-3);
    for _ in 0..10 {
        h3.observe(1500);
    }
    let q = h3.quantile(0.5);
    assert!((1.0..=2.0).contains(&q), "scaled quantile in seconds, got {q}");
}

#[test]
fn concurrent_counter_increments_from_executor_workers() {
    let reg = Registry::new();
    let c = reg.counter("test_conc", "c", &[]);
    let h = reg.histogram("test_conc_h", "h", &[], &[1_000_000], 1.0);
    const TASKS: usize = 64;
    const PER_TASK: usize = 1000;
    Executor::global().par_map(TASKS, |_| {
        for _ in 0..PER_TASK {
            c.inc();
            h.observe(1);
        }
    });
    assert_eq!(c.get(), (TASKS * PER_TASK) as u64, "no lost counter updates");
    assert_eq!(h.count(), (TASKS * PER_TASK) as u64, "no lost observations");
    assert_eq!(h.sum_raw(), (TASKS * PER_TASK) as u64);
}

#[test]
fn registering_the_same_series_twice_returns_one_handle() {
    let reg = Registry::new();
    let a = reg.counter("test_dup", "c", &[("k", "v")]);
    let b = reg.counter("test_dup", "c", &[("k", "v")]);
    a.inc();
    b.inc();
    assert_eq!(a.get(), 2, "both handles hit the same series");
    let other = reg.counter("test_dup", "c", &[("k", "w")]);
    assert_eq!(other.get(), 0, "a different label set is a new series");
}

#[test]
fn text_exposition_golden() {
    let reg = Registry::new();
    reg.counter("attn_test_requests_total", "Requests", &[("status", "2xx")]).add(7);
    reg.gauge("attn_test_entries", "Entries", &[]).set(3);
    // unit scale 0.25 is exact in binary, so the rendered le bounds and
    // sum are bit-deterministic across platforms
    let h = reg.histogram("attn_test_latency_seconds", "Latency", &[], &[1, 2], 0.25);
    h.observe(1); // -> le=1 bucket (0.25 s scaled)
    h.observe(3); // -> +Inf bucket
    let text = expo::render_text(&reg.snapshot());
    let expected = "\
# HELP attn_test_entries Entries
# TYPE attn_test_entries gauge
attn_test_entries 3
# HELP attn_test_latency_seconds Latency
# TYPE attn_test_latency_seconds histogram
attn_test_latency_seconds_bucket{le=\"0.25\"} 1
attn_test_latency_seconds_bucket{le=\"0.5\"} 1
attn_test_latency_seconds_bucket{le=\"+Inf\"} 2
attn_test_latency_seconds_sum 1
attn_test_latency_seconds_count 2
# HELP attn_test_requests_total Requests
# TYPE attn_test_requests_total counter
attn_test_requests_total{status=\"2xx\"} 7
";
    assert_eq!(text, expected);
}

#[test]
fn json_exposition_mirrors_the_snapshot() {
    let reg = Registry::new();
    reg.counter("attn_test_c", "C", &[("mode", "rans")]).add(4);
    let h = reg.histogram("attn_test_h", "H", &[], &[100], 1.0);
    h.observe(50);
    let doc = expo::render_json(&reg.snapshot());
    // round-trip through the serializer to prove it stays valid JSON
    let parsed = Value::parse(&doc.to_string_pretty()).expect("valid JSON");
    let families = match parsed.get("families") {
        Some(Value::Arr(fams)) => fams,
        other => panic!("families array missing: {other:?}"),
    };
    assert_eq!(families.len(), 2);
    let c = &families[0];
    assert_eq!(c.get("name").and_then(|v| v.as_str()), Some("attn_test_c"));
    assert_eq!(c.get("type").and_then(|v| v.as_str()), Some("counter"));
    let hist = &families[1];
    assert_eq!(hist.get("type").and_then(|v| v.as_str()), Some("histogram"));
    let series = match hist.get("series") {
        Some(Value::Arr(s)) => s,
        other => panic!("series missing: {other:?}"),
    };
    let buckets = match series[0].get("buckets") {
        Some(Value::Arr(b)) => b,
        other => panic!("buckets missing: {other:?}"),
    };
    assert_eq!(buckets.len(), 2, "finite bound + +Inf");
    assert_eq!(
        buckets[1].get("le").and_then(|v| v.as_str()),
        Some("+Inf"),
        "infinite bound spelled as a string"
    );
}

#[test]
fn composed_expositions_sort_across_sources() {
    let reg = Registry::new();
    reg.counter("attn_z_total", "Z", &[]).inc();
    let mut fams = reg.snapshot();
    fams.push(expo::counter_family("attn_a_total", "A", 5));
    fams.push(expo::gauge_family("attn_m_gauge", "M", 2.5));
    let text = expo::render_text(&fams);
    let a = text.find("attn_a_total").unwrap();
    let m = text.find("attn_m_gauge").unwrap();
    let z = text.find("attn_z_total").unwrap();
    assert!(a < m && m < z, "one sorted document regardless of source order");
    assert!(text.contains("attn_m_gauge 2.5"));
}

#[test]
fn log_level_filtering() {
    assert!(Level::parse("warn") == Some(Level::Warn));
    assert!(Level::parse("loud").is_none());
    let prev = log::level();
    log::set_level(Level::Warn);
    assert!(log::enabled(Level::Error));
    assert!(log::enabled(Level::Warn));
    assert!(!log::enabled(Level::Info));
    assert!(!log::enabled(Level::Debug));
    log::set_level(prev);
    let a = log::next_request_id();
    let b = log::next_request_id();
    assert!(b > a, "request ids are monotonic");
}

#[test]
fn stage_spans_record_into_the_global_histogram() {
    use attn_reduce::obs::stages;
    let h = stages::STREAM_EXTRACT.hist();
    let before = h.count();
    {
        let _span = stages::STREAM_EXTRACT.span();
        std::hint::black_box(42);
    }
    assert!(h.count() > before, "dropping the span records an observation");
}
