//! CLI behavior tests over the real binary: exit codes (unknown
//! subcommands must fail non-zero) and the full
//! generate → compress → decompress round trip for the pure-rust codecs,
//! with decompression driven by the archive header alone.

use std::process::Command;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_attn-reduce"))
}

fn tmp(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("attn_reduce_cli_test");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

fn read_f32(path: &std::path::Path) -> Vec<f32> {
    std::fs::read(path)
        .unwrap()
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect()
}

#[test]
fn unknown_subcommand_exits_nonzero_with_usage() {
    let out = bin().arg("frobnicate").output().unwrap();
    assert!(!out.status.success(), "unknown command must fail");
    assert_eq!(out.status.code(), Some(2), "usage errors exit 2");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("unknown command"), "{stderr}");
    assert!(stderr.contains("USAGE"), "{stderr}");
}

#[test]
fn no_args_exits_nonzero() {
    let out = bin().output().unwrap();
    assert_eq!(out.status.code(), Some(2));
    assert!(String::from_utf8_lossy(&out.stderr).contains("USAGE"));
}

#[test]
fn help_exits_zero() {
    for spelling in ["help", "--help", "-h"] {
        let out = bin().arg(spelling).output().unwrap();
        assert!(out.status.success(), "{spelling} is not an error");
        assert!(String::from_utf8_lossy(&out.stdout).contains("USAGE"), "{spelling}");
    }
}

#[test]
fn bad_bound_flag_exits_nonzero() {
    let out = bin()
        .args(["compress", "--codec", "sz3", "--bound", "l7:0.1"])
        .output()
        .unwrap();
    assert_eq!(out.status.code(), Some(1));
    assert!(String::from_utf8_lossy(&out.stderr).contains("bound"));
}

#[test]
fn sz3_cli_round_trip_restores_from_header_alone() {
    let field_p = tmp("field.f32");
    let archive_p = tmp("field.ardc");
    let recon_p = tmp("recon.f32");

    let out = bin()
        .args(["generate", "--dataset", "e3sm", "--scale", "smoke", "--out"])
        .arg(&field_p)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    let out = bin()
        .args([
            "compress", "--codec", "sz3", "--bound", "nrmse:1e-3", "--dataset", "e3sm",
            "--scale", "smoke", "--in",
        ])
        .arg(&field_p)
        .arg("--out")
        .arg(&archive_p)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("codec = sz3"), "{stdout}");

    // decompress: ONLY --in/--out — dataset, scale, codec all come from
    // the archive header
    let out = bin()
        .arg("decompress")
        .arg("--in")
        .arg(&archive_p)
        .arg("--out")
        .arg(&recon_p)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    let orig = read_f32(&field_p);
    let recon = read_f32(&recon_p);
    assert_eq!(orig.len(), recon.len());
    let (lo, hi) = orig
        .iter()
        .fold((f32::INFINITY, f32::NEG_INFINITY), |(lo, hi), &v| (lo.min(v), hi.max(v)));
    let range = (hi - lo) as f64;
    let mse: f64 = orig
        .iter()
        .zip(&recon)
        .map(|(&a, &b)| ((a - b) as f64).powi(2))
        .sum::<f64>()
        / orig.len() as f64;
    let nrmse = mse.sqrt() / range;
    assert!(nrmse <= 1e-3 * 1.0001, "CLI round trip NRMSE {nrmse}");
}

#[test]
fn all_vars_cli_builds_one_v2_archive_and_restores_every_field() {
    let archive_p = tmp("multis3d.ardc");
    let recon_p = tmp("multirecon.f32");

    // one invocation, multi-species synthetic S3D config -> one archive
    let out = bin()
        .args([
            "compress",
            "--all-vars",
            "--vars",
            "3",
            "--codec",
            "sz3",
            "--bound",
            "nrmse:1e-3",
            "--dataset",
            "s3d",
            "--scale",
            "smoke",
            "--threads",
            "2",
            "--out",
        ])
        .arg(&archive_p)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("fields = 3"), "{stdout}");
    assert!(stdout.contains("var00"), "{stdout}");

    // decompress from the container alone: one .f32 per field
    let out = bin()
        .arg("decompress")
        .arg("--in")
        .arg(&archive_p)
        .arg("--out")
        .arg(&recon_p)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("3 fields restored"), "{stdout}");
    for name in ["var00", "var01", "var02"] {
        let p = recon_p.with_file_name(format!("multirecon.{name}.f32"));
        assert!(p.exists(), "missing per-field output {}", p.display());
        assert!(!read_f32(&p).is_empty());
    }
}

#[test]
fn extract_cli_decodes_a_region_matching_the_full_decode() {
    let archive_p = tmp("xfield.ardc");
    let recon_p = tmp("xrecon.f32");
    let region_p = tmp("xregion.f32");

    // e3sm smoke is [24, 32, 32]
    let out = bin()
        .args([
            "compress", "--codec", "sz3", "--bound", "nrmse:1e-3", "--dataset", "e3sm",
            "--scale", "smoke", "--out",
        ])
        .arg(&archive_p)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    assert!(bin()
        .arg("decompress")
        .arg("--in")
        .arg(&archive_p)
        .arg("--out")
        .arg(&recon_p)
        .status()
        .unwrap()
        .success());

    // extract a sub-cube; like decompress it needs only --in (+ region)
    let out = bin()
        .args(["extract", "--region", "2:10,4:20,8:24", "--in"])
        .arg(&archive_p)
        .arg("--out")
        .arg(&region_p)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("region"), "{stdout}");

    // the extracted region equals the crop of the full decode, bit for bit
    let full = read_f32(&recon_p);
    let part = read_f32(&region_p);
    assert_eq!(part.len(), 8 * 16 * 16);
    let (h, w) = (32, 32);
    let mut want = Vec::new();
    for i in 2..10 {
        for j in 4..20 {
            for k in 8..24 {
                want.push(full[(i * h + j) * w + k]);
            }
        }
    }
    assert_eq!(part.len(), want.len());
    for (a, b) in part.iter().zip(&want) {
        assert_eq!(a.to_bits(), b.to_bits());
    }

    let out = bin().args(["extract", "--in"]).arg(&archive_p).output().unwrap();
    assert_eq!(out.status.code(), Some(1));
    assert!(String::from_utf8_lossy(&out.stderr).contains("--region"));
}

#[test]
fn malformed_region_is_a_usage_error_with_exit_2() {
    // reversed range (i1 < i0): exit 2 with a one-line pinned message —
    // and the check runs before --in is touched, so no archive is needed
    let out = bin()
        .args(["extract", "--region", "9:1,0:4,0:4", "--in", "does-not-matter.ardc"])
        .output()
        .unwrap();
    assert_eq!(out.status.code(), Some(2), "reversed range is a usage error");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert_eq!(stderr.lines().count(), 1, "one-line error, got: {stderr}");
    assert!(
        stderr.contains("error: bad --region \"9:1,0:4,0:4\": region dim 0 is empty (9:1)"),
        "pinned message drifted: {stderr}"
    );

    // missing ':' separator
    let out = bin()
        .args(["extract", "--region", "0-4,0:4", "--in", "x.ardc"])
        .output()
        .unwrap();
    assert_eq!(out.status.code(), Some(2), "missing colon is a usage error");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert_eq!(stderr.lines().count(), 1, "one-line error, got: {stderr}");
    assert!(
        stderr.contains("bad region component \"0-4\" (expected lo:hi)"),
        "pinned message drifted: {stderr}"
    );

    // empty range and garbage bounds take the same path
    for bad in ["2:2", "a:b,0:4"] {
        let out = bin()
            .args(["extract", "--region", bad, "--in", "x.ardc"])
            .output()
            .unwrap();
        assert_eq!(out.status.code(), Some(2), "{bad:?} should exit 2");
    }
}

#[test]
fn stream_cli_appends_incrementally_and_extracts_regions() {
    let stream_p = tmp("cli_stream.tstr");
    std::fs::remove_file(&stream_p).ok(); // stale runs would reopen it
    let frame_p = tmp("cli_stream_frame.f32");
    let region_p = tmp("cli_stream_region.f32");

    // create: 5 synthesized smoothly-evolving steps, keyint 3
    let out = bin()
        .args([
            "stream", "append", "--codec", "sz3", "--bound", "nrmse:1e-3", "--dataset",
            "e3sm", "--scale", "smoke", "--keyint", "3", "--steps", "5", "--out",
        ])
        .arg(&stream_p)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("appended steps 0..4"), "{stdout}");

    // append again: codec/bound/keyint come from the stream header now
    let out = bin()
        .args(["stream", "append", "--steps", "2", "--out"])
        .arg(&stream_p)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("appended steps 5..6"), "{stdout}");
    assert!(stdout.contains("7 steps"), "{stdout}");

    // info: timeline with keyframes at 0, 3, 6
    let out = bin().args(["stream", "info", "--in"]).arg(&stream_p).output().unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("steps = 7 (3 keyframes)"), "{stdout}");
    assert!(stdout.contains("codec = sz3"), "{stdout}");

    // extract a full frame, then a region of the same step: the region
    // must be the bit-exact crop of the frame (e3sm smoke frame is 32x32)
    let out = bin()
        .args(["stream", "extract", "--step", "4", "--in"])
        .arg(&stream_p)
        .arg("--out")
        .arg(&frame_p)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let out = bin()
        .args(["stream", "extract", "--step", "4", "--region", "8:24,16:32", "--in"])
        .arg(&stream_p)
        .arg("--out")
        .arg(&region_p)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("chain: 2 steps"), "{stdout}");

    let full = read_f32(&frame_p);
    let part = read_f32(&region_p);
    assert_eq!(full.len(), 32 * 32);
    assert_eq!(part.len(), 16 * 16);
    for i in 0..16 {
        for j in 0..16 {
            let want = full[(i + 8) * 32 + (j + 16)];
            assert_eq!(part[i * 16 + j].to_bits(), want.to_bits(), "({i},{j})");
        }
    }

    // malformed region in stream extract is the same exit-2 usage error
    let out = bin()
        .args(["stream", "extract", "--step", "1", "--region", "5:2", "--in"])
        .arg(&stream_p)
        .output()
        .unwrap();
    assert_eq!(out.status.code(), Some(2));

    // unknown stream subcommand exits 2
    let out = bin().args(["stream", "frobnicate"]).output().unwrap();
    assert_eq!(out.status.code(), Some(2));
    assert!(String::from_utf8_lossy(&out.stderr).contains("stream subcommand"));
}

#[test]
fn info_reports_per_section_byte_breakdown() {
    let archive_p = tmp("info_field.ardc");

    // e3sm smoke [24, 32, 32] with ae_block [6, 16, 16] -> 16 tiles
    let out = bin()
        .args([
            "compress", "--codec", "sz3", "--bound", "nrmse:1e-3", "--dataset", "e3sm",
            "--scale", "smoke", "--out",
        ])
        .arg(&archive_p)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    let out = bin().args(["info", "--in"]).arg(&archive_p).output().unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    // pinned format: archive line, per-section classes, framing delta,
    // and the per-tile entropy split
    assert!(stdout.contains("archive: v3, codec = sz3"), "{stdout}");
    assert!(stdout.contains("section SZ3B:"), "{stdout}");
    assert!(stdout.contains("bytes [payload]"), "{stdout}");
    assert!(stdout.contains("section BIDX:"), "{stdout}");
    assert!(stdout.contains("bytes [index]"), "{stdout}");
    assert!(stdout.contains("header + framing:"), "{stdout}");
    assert!(stdout.contains("entropy: 16 tiles (plain "), "{stdout}");
    assert!(stdout.contains(", rans "), "{stdout}");
    assert!(stdout.contains("tables "), "{stdout}");
    assert!(stdout.contains("symbols "), "{stdout}");

    // the same flag on a v4 stream reports record/index/framing classes
    let stream_p = tmp("info_stream.tstr");
    std::fs::remove_file(&stream_p).ok();
    let out = bin()
        .args([
            "stream", "append", "--codec", "sz3", "--bound", "nrmse:1e-3", "--dataset",
            "e3sm", "--scale", "smoke", "--keyint", "2", "--steps", "4", "--out",
        ])
        .arg(&stream_p)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let out = bin().args(["info", "--in"]).arg(&stream_p).output().unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("stream: v4, codec = sz3"), "{stdout}");
    assert!(stdout.contains("4 steps (2 keyframes)"), "{stdout}");
    assert!(stdout.contains("step records:"), "{stdout}");
    assert!(stdout.contains("bytes [payload]"), "{stdout}");
    assert!(stdout.contains("timeline (TIDX):"), "{stdout}");
    assert!(stdout.contains("bytes [index]"), "{stdout}");
}

#[test]
fn info_json_pins_the_machine_readable_breakdown() {
    let archive_p = tmp("info_json_field.ardc");
    let out = bin()
        .args([
            "compress", "--codec", "sz3", "--bound", "nrmse:1e-3", "--dataset", "e3sm",
            "--scale", "smoke", "--out",
        ])
        .arg(&archive_p)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    let out = bin().args(["info", "--json", "--in"]).arg(&archive_p).output().unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    // pinned keys of the document (the /v1/archives/{name}/info route
    // returns exactly this body): kind/version/codec, classed sections,
    // framing delta, entropy split — integers print without decimals
    assert!(stdout.contains("\"kind\": \"archive\""), "{stdout}");
    assert!(stdout.contains("\"version\": 3"), "{stdout}");
    assert!(stdout.contains("\"codec\": \"sz3\""), "{stdout}");
    assert!(stdout.contains("\"tag\": \"SZ3B\""), "{stdout}");
    assert!(stdout.contains("\"class\": \"payload\""), "{stdout}");
    assert!(stdout.contains("\"tag\": \"BIDX\""), "{stdout}");
    assert!(stdout.contains("\"class\": \"index\""), "{stdout}");
    assert!(stdout.contains("\"framing_bytes\": "), "{stdout}");
    assert!(stdout.contains("\"entropy\": "), "{stdout}");
    assert!(stdout.contains("\"tiles\": 16"), "{stdout}");
    assert!(stdout.contains("\"rans\": "), "{stdout}");
    assert!(stdout.contains("\"rans_lanes\": "), "{stdout}");
    assert!(stdout.contains("\"symbol_bytes\": "), "{stdout}");
    // the file size in the document matches the file on disk
    let bytes = std::fs::metadata(&archive_p).unwrap().len();
    assert!(stdout.contains(&format!("\"bytes\": {bytes}")), "{stdout}");

    // the same flag on a v4 stream
    let stream_p = tmp("info_json_stream.tstr");
    std::fs::remove_file(&stream_p).ok();
    let out = bin()
        .args([
            "stream", "append", "--codec", "sz3", "--bound", "nrmse:1e-3", "--dataset",
            "e3sm", "--scale", "smoke", "--keyint", "2", "--steps", "4", "--out",
        ])
        .arg(&stream_p)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let out = bin().args(["info", "--json", "--in"]).arg(&stream_p).output().unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("\"kind\": \"stream\""), "{stdout}");
    assert!(stdout.contains("\"version\": 4"), "{stdout}");
    assert!(stdout.contains("\"steps\": 4"), "{stdout}");
    assert!(stdout.contains("\"keyframes\": 2"), "{stdout}");
    assert!(stdout.contains("\"record_payload_bytes\": "), "{stdout}");
    assert!(stdout.contains("\"tidx_bytes\": "), "{stdout}");

    // --json without --in is a runtime error, not silence
    let out = bin().args(["info", "--json"]).output().unwrap();
    assert_eq!(out.status.code(), Some(1));
    assert!(String::from_utf8_lossy(&out.stderr).contains("--in"));
}

#[test]
fn stream_extract_step_out_of_range_is_a_usage_error_with_exit_2() {
    let stream_p = tmp("cli_oor_stream.tstr");
    std::fs::remove_file(&stream_p).ok();
    let out = bin()
        .args([
            "stream", "append", "--codec", "sz3", "--bound", "nrmse:1e-3", "--dataset",
            "e3sm", "--scale", "smoke", "--keyint", "2", "--steps", "3", "--out",
        ])
        .arg(&stream_p)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    // step 3 of a 3-step stream: one pinned line on stderr, exit 2 —
    // the same contract as a malformed --region
    let out = bin()
        .args(["stream", "extract", "--step", "3", "--in"])
        .arg(&stream_p)
        .output()
        .unwrap();
    assert_eq!(out.status.code(), Some(2), "out-of-range step is a usage error");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert_eq!(stderr.lines().count(), 1, "one-line error, got: {stderr}");
    assert!(
        stderr.contains("error: --step 3 out of range (3 steps in stream)"),
        "pinned message drifted: {stderr}"
    );

    // in-range steps still work after the check
    let out = bin()
        .args(["stream", "extract", "--step", "2", "--in"])
        .arg(&stream_p)
        .arg("--out")
        .arg(tmp("cli_oor_frame.f32"))
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
}

#[test]
fn threads_flag_rejects_garbage() {
    let out = bin()
        .args(["compress", "--codec", "sz3", "--scale", "smoke", "--threads", "zero"])
        .output()
        .unwrap();
    assert_eq!(out.status.code(), Some(1));
    assert!(String::from_utf8_lossy(&out.stderr).contains("threads"));
}

#[test]
fn adaptive_cli_round_trip_and_info_pin_the_codec_split() {
    let field_p = tmp("afield.f32");
    let archive_p = tmp("afield.ardc");
    let recon_p = tmp("arecon.f32");
    let region_p = tmp("aregion.f32");

    assert!(bin()
        .args(["generate", "--dataset", "e3sm", "--scale", "smoke", "--out"])
        .arg(&field_p)
        .status()
        .unwrap()
        .success());
    let out = bin()
        .args([
            "compress", "--codec", "adaptive", "--bound", "nrmse:1e-3", "--dataset",
            "e3sm", "--scale", "smoke", "--in",
        ])
        .arg(&field_p)
        .arg("--out")
        .arg(&archive_p)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("codec = adaptive"), "{stdout}");

    // decompress and extract need only the archive header
    let out = bin()
        .arg("decompress")
        .arg("--in")
        .arg(&archive_p)
        .arg("--out")
        .arg(&recon_p)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let orig = read_f32(&field_p);
    let recon = read_f32(&recon_p);
    assert_eq!(orig.len(), recon.len());

    // a region extract is the bit-exact crop of the full decode, with
    // every touched tile dispatched on its recorded codec id
    let out = bin()
        .args(["extract", "--region", "2:10,4:20,8:24", "--in"])
        .arg(&archive_p)
        .arg("--out")
        .arg(&region_p)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let full = read_f32(&recon_p);
    let part = read_f32(&region_p);
    let (h, w) = (32, 32);
    let mut want = Vec::new();
    for i in 2..10 {
        for j in 4..20 {
            for k in 8..24 {
                want.push(full[(i * h + j) * w + k]);
            }
        }
    }
    assert_eq!(part.len(), want.len());
    for (a, b) in part.iter().zip(&want) {
        assert_eq!(a.to_bits(), b.to_bits());
    }

    // info: the pinned text format gains a per-codec tile breakdown
    // whose counts sum to the 16 tiles of e3sm smoke
    let out = bin().args(["info", "--in"]).arg(&archive_p).output().unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("archive: v3, codec = adaptive"), "{stdout}");
    assert!(stdout.contains("section ADPB:"), "{stdout}");
    let line = stdout
        .lines()
        .find(|l| l.starts_with("tile codecs: sz3 "))
        .unwrap_or_else(|| panic!("no tile-codec line in: {stdout}"));
    let tok: Vec<&str> = line.split_whitespace().collect();
    // "tile codecs: sz3 {n} tiles ({b} B), zfp {m} tiles ({b} B)"
    let sz3: usize = tok[3].parse().unwrap();
    let zfp: usize = tok[8].parse().unwrap();
    assert_eq!(sz3 + zfp, 16, "split covers every tile: {line}");

    // --json carries the same split under "tile_codecs"
    let out = bin().args(["info", "--json", "--in"]).arg(&archive_p).output().unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("\"codec\": \"adaptive\""), "{stdout}");
    assert!(stdout.contains("\"tile_codecs\": "), "{stdout}");
    assert!(stdout.contains(&format!("\"sz3_tiles\": {sz3}")), "{stdout}");
    assert!(stdout.contains(&format!("\"zfp_tiles\": {zfp}")), "{stdout}");
    assert!(stdout.contains("\"sz3_bytes\": "), "{stdout}");
    assert!(stdout.contains("\"zfp_bytes\": "), "{stdout}");
}

#[test]
fn info_on_the_mixed_golden_pins_exact_codec_counts() {
    // the frozen conformance golden has exactly one sz3 tile and one zfp
    // tile, so the breakdown's counts are pinned byte-for-byte forever
    let golden =
        std::path::Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/tests/golden"))
            .join("v3_adaptive.ardc");
    let out = bin().args(["info", "--in"]).arg(&golden).output().unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("archive: v3, codec = adaptive"), "{stdout}");
    assert!(stdout.contains("tile codecs: sz3 1 tiles ("), "{stdout}");
    assert!(stdout.contains(", zfp 1 tiles ("), "{stdout}");
    let out = bin().args(["info", "--json", "--in"]).arg(&golden).output().unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("\"sz3_tiles\": 1"), "{stdout}");
    assert!(stdout.contains("\"zfp_tiles\": 1"), "{stdout}");
}

#[test]
fn stream_cli_accepts_the_adaptive_codec() {
    let stream_p = tmp("cli_adaptive_stream.tstr");
    std::fs::remove_file(&stream_p).ok();
    let frame_p = tmp("cli_adaptive_frame.f32");

    let out = bin()
        .args([
            "stream", "append", "--codec", "adaptive", "--bound", "nrmse:1e-3",
            "--dataset", "e3sm", "--scale", "smoke", "--keyint", "2", "--steps", "3",
            "--out",
        ])
        .arg(&stream_p)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains("appended steps 0..2"));

    let out = bin().args(["stream", "info", "--in"]).arg(&stream_p).output().unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains("codec = adaptive"));

    // a residual-chain frame decodes through the per-tile dispatch
    let out = bin()
        .args(["stream", "extract", "--step", "1", "--in"])
        .arg(&stream_p)
        .arg("--out")
        .arg(&frame_p)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert_eq!(read_f32(&frame_p).len(), 32 * 32);
}

#[test]
fn zfp_cli_round_trip_restores_from_header_alone() {
    let field_p = tmp("zfield.f32");
    let archive_p = tmp("zfield.ardc");
    let recon_p = tmp("zrecon.f32");

    assert!(bin()
        .args(["generate", "--dataset", "s3d", "--scale", "smoke", "--out"])
        .arg(&field_p)
        .status()
        .unwrap()
        .success());
    let out = bin()
        .args([
            "compress", "--codec", "zfp", "--bound", "nrmse:1e-3", "--dataset", "s3d",
            "--scale", "smoke", "--in",
        ])
        .arg(&field_p)
        .arg("--out")
        .arg(&archive_p)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(bin()
        .arg("decompress")
        .arg("--in")
        .arg(&archive_p)
        .arg("--out")
        .arg(&recon_p)
        .status()
        .unwrap()
        .success());
    let orig = read_f32(&field_p);
    let recon = read_f32(&recon_p);
    assert_eq!(orig.len(), recon.len());
}
