//! Entropy-coder overhaul integration tests (ISSUE 5):
//!
//! * the table-driven Huffman decoder is equivalent to the pre-overhaul
//!   bit-at-a-time decoder on random streams AND on the frozen golden
//!   corpus (every committed archive's entropy stream, tile by tile);
//! * decode is pinned ≥ 2× faster than the bit-at-a-time oracle on a
//!   zero-peaked residual-shaped stream;
//! * residual GOP payloads under the auto-selected zero-run/const modes
//!   are pinned ≥ 20% smaller than the forced-plain (PR-4) framing at
//!   the same error bound.
//!
//! ISSUE 7 adds the interleaved rANS legs: forced-rANS streams are
//! value-identical to forced-plain on random streams AND on the frozen
//! golden corpus's symbol content, and dense-stream rANS decode is
//! pinned ≥ 1.5× over the LUT-Huffman decoder at matched (within 1%)
//! compressed size.

use attn_reduce::codec::{Codec, ErrorBound, Sz3Codec};
use attn_reduce::coder::{
    compress_symbols, compress_symbols_mode, decompress_symbols, huffman_decode,
    huffman_decode_bitwise, huffman_encode, lossless_decompress, rans_decode_into, rans_encode,
    with_symbol_mode, RansScratch, SymbolMode, MAGIC_RANS,
};
use attn_reduce::compressor::Archive;
use attn_reduce::config::{dataset_preset, DatasetConfig, DatasetKind, Scale};
use attn_reduce::stream::{StreamReader, StreamWriter};
use attn_reduce::tensor::Tensor;
use attn_reduce::util::parallel::with_thread_limit;
use attn_reduce::util::rng::Rng;

fn golden_path(name: &str) -> std::path::PathBuf {
    std::path::Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/tests/golden")).join(name)
}

fn assert_decoders_agree(vals: &[i32], what: &str) {
    let enc = huffman_encode(vals);
    let (a, ua) = huffman_decode(&enc).unwrap_or_else(|e| panic!("{what}: lut: {e:#}"));
    let (b, ub) =
        huffman_decode_bitwise(&enc).unwrap_or_else(|e| panic!("{what}: bitwise: {e:#}"));
    assert_eq!(a, vals, "{what}: lut decode wrong");
    assert_eq!(b, vals, "{what}: bitwise decode wrong");
    assert_eq!(ua, ub, "{what}: consumed bytes differ");
    assert_eq!(ua, enc.len(), "{what}: consumed != stream length");
}

#[test]
fn lut_decoder_matches_bitwise_oracle_on_random_streams() {
    let mut rng = Rng::new(20260730);
    // peaked alphabets of several widths (short codes, LUT-resident)
    for sigma in [0.4f64, 3.0, 25.0] {
        let vals: Vec<i32> = (0..20_000).map(|_| (rng.normal() * sigma).round() as i32).collect();
        assert_decoders_agree(&vals, &format!("peaked sigma={sigma}"));
    }
    // uniform small alphabet
    let vals: Vec<i32> = (0..10_000).map(|_| rng.below(64) as i32 - 32).collect();
    assert_decoders_agree(&vals, "uniform-64");
    // wide near-distinct alphabet: code lengths beyond the 12-bit LUT
    // exercise the canonical fallback walk on every symbol
    let vals: Vec<i32> = (0..50_000)
        .map(|_| (rng.next_u64() % 30_000) as i32 - 15_000)
        .collect();
    assert_decoders_agree(&vals, "wide-alphabet");
    // residual-shaped: long zero runs, tiny literal alphabet
    let vals: Vec<i32> = (0..30_000)
        .map(|_| if rng.below(15) == 0 { (rng.below(5) as i32) - 2 } else { 0 })
        .collect();
    assert_decoders_agree(&vals, "zero-peaked");
}

/// The Huffman bytes inside one sz3 stream (golden corpus framing:
/// eps | rank | dims | n_raw | raws | zlen | lossless(huffman)).
fn sz3_entropy_stream(stream: &[u8]) -> Vec<u8> {
    let rank = u32::from_le_bytes(stream[4..8].try_into().unwrap()) as usize;
    let mut off = 8 + rank * 8;
    let n_raw = u64::from_le_bytes(stream[off..off + 8].try_into().unwrap()) as usize;
    off += 8 + n_raw * 4;
    let zlen = u64::from_le_bytes(stream[off..off + 8].try_into().unwrap()) as usize;
    off += 8;
    let z = &stream[off..off + zlen];
    // frozen corpus predates the zero-run mode: always plain LZSS
    assert_eq!(z[0], 0xB3, "golden entropy streams are plain LZSS");
    lossless_decompress(z, 1 << 20).unwrap()
}

/// Per-tile entropy streams of one sz3 archive (v1: whole stream, v3:
/// one per block-index entry).
fn sz3_streams(archive: &Archive) -> Vec<Vec<u8>> {
    let payload = archive.section("SZ3B").unwrap();
    match archive.block_index().unwrap() {
        Some(ix) => ix
            .entries
            .iter()
            .map(|&(o, l)| payload[o as usize..o as usize + l as usize].to_vec())
            .collect(),
        None => vec![payload.to_vec()],
    }
}

#[test]
fn lut_decoder_matches_bitwise_oracle_on_golden_corpus() {
    // every committed archive's entropy stream, tile by tile
    for name in ["v1_sz3.ardc", "v3_sz3.ardc"] {
        let bytes = std::fs::read(golden_path(name)).unwrap();
        let archive = Archive::from_bytes(&bytes).unwrap();
        for (ti, s) in sz3_streams(&archive).iter().enumerate() {
            let huff = sz3_entropy_stream(s);
            let (a, ua) = huffman_decode(&huff).unwrap();
            let (b, ub) = huffman_decode_bitwise(&huff).unwrap();
            assert_eq!(a, b, "{name} tile {ti}: decoders disagree");
            assert_eq!(ua, ub, "{name} tile {ti}: consumed bytes differ");
            assert!(!a.is_empty(), "{name} tile {ti}: empty code stream");
        }
    }
    // the v4 stream's embedded step archives too (keyframes + residuals)
    let reader = StreamReader::open(golden_path("v4_stream.ardc")).unwrap();
    for step in 0..reader.n_steps() {
        let sub = reader.step_archive(step).unwrap();
        for (ti, s) in sz3_streams(&sub).iter().enumerate() {
            let huff = sz3_entropy_stream(s);
            let (a, _) = huffman_decode(&huff).unwrap();
            let (b, _) = huffman_decode_bitwise(&huff).unwrap();
            assert_eq!(a, b, "v4 step {step} tile {ti}: decoders disagree");
        }
    }
}

#[test]
fn rans_mode_is_value_identical_to_plain_on_random_streams() {
    let mut rng = Rng::new(20260807);
    let mut streams: Vec<(String, Vec<i32>)> = Vec::new();
    for sigma in [0.4f64, 3.0, 25.0] {
        let vals: Vec<i32> =
            (0..20_000).map(|_| (rng.normal() * sigma).round() as i32).collect();
        streams.push((format!("peaked sigma={sigma}"), vals));
    }
    streams.push((
        "uniform-64".into(),
        (0..10_000).map(|_| rng.below(64) as i32 - 32).collect(),
    ));
    streams.push((
        "zero-peaked".into(),
        (0..30_000)
            .map(|_| if rng.below(15) == 0 { (rng.below(5) as i32) - 2 } else { 0 })
            .collect(),
    ));
    for n in 1..=5usize {
        streams.push((format!("tiny n={n}"), (0..n as i32).collect()));
    }
    for (what, vals) in &streams {
        let plain = compress_symbols_mode(vals, SymbolMode::Plain)
            .unwrap_or_else(|e| panic!("{what}: plain: {e:#}"));
        let rans = compress_symbols_mode(vals, SymbolMode::Rans)
            .unwrap_or_else(|e| panic!("{what}: rans: {e:#}"));
        assert_eq!(rans[0], MAGIC_RANS, "{what}: wrong container magic");
        let a = decompress_symbols(&plain, vals.len()).unwrap();
        let b = decompress_symbols(&rans, vals.len()).unwrap();
        assert_eq!(&a, vals, "{what}: plain decode wrong");
        assert_eq!(&b, vals, "{what}: rans decode wrong");
    }
    // alphabets beyond the rANS table cap reject the bare mode but
    // degrade gracefully (to an eligible mode) under the forced override
    let wide: Vec<i32> =
        (0..50_000).map(|_| (rng.next_u64() % 30_000) as i32 - 15_000).collect();
    assert!(compress_symbols_mode(&wide, SymbolMode::Rans).is_err());
    let forced = with_symbol_mode(SymbolMode::Rans, || compress_symbols(&wide).unwrap());
    assert_ne!(forced[0], MAGIC_RANS, "wide alphabet cannot ride rANS");
    assert_eq!(decompress_symbols(&forced, wide.len()).unwrap(), wide);
}

#[test]
fn rans_round_trips_the_golden_corpus_symbol_content() {
    // the frozen archives' symbol streams (decoded through their
    // committed Huffman framing) must survive a rANS round trip
    // wherever the alphabet fits the table — i.e. the new mode could
    // have carried the same data
    let mut round_tripped = 0usize;
    let mut check = |vals: &[i32], what: &str| {
        let mut distinct = vals.to_vec();
        distinct.sort_unstable();
        distinct.dedup();
        if vals.is_empty() || distinct.len() > 4096 {
            return;
        }
        let enc = rans_encode(vals).unwrap_or_else(|e| panic!("{what}: encode: {e:#}"));
        let mut out = Vec::new();
        rans_decode_into(&enc, vals.len(), &mut out, &mut RansScratch::default())
            .unwrap_or_else(|e| panic!("{what}: decode: {e:#}"));
        assert_eq!(out, vals, "{what}: rans round trip differs");
        round_tripped += 1;
    };
    for name in ["v1_sz3.ardc", "v3_sz3.ardc"] {
        let bytes = std::fs::read(golden_path(name)).unwrap();
        let archive = Archive::from_bytes(&bytes).unwrap();
        for (ti, s) in sz3_streams(&archive).iter().enumerate() {
            let (vals, _) = huffman_decode(&sz3_entropy_stream(s)).unwrap();
            check(&vals, &format!("{name} tile {ti}"));
        }
    }
    let reader = StreamReader::open(golden_path("v4_stream.ardc")).unwrap();
    for step in 0..reader.n_steps() {
        let sub = reader.step_archive(step).unwrap();
        for (ti, s) in sz3_streams(&sub).iter().enumerate() {
            let (vals, _) = huffman_decode(&sz3_entropy_stream(s)).unwrap();
            check(&vals, &format!("v4 step {step} tile {ti}"));
        }
    }
    assert!(round_tripped > 0, "no golden stream fit the rANS table");
}

#[test]
fn rans_decode_is_at_least_1_5x_faster_than_huffman_lut_on_dense_streams() {
    // dense near-gaussian codes: many distinct symbols, ~8 bits each —
    // the stream shape the auto-selection sends to rANS
    let mut rng = Rng::new(7);
    let vals: Vec<i32> =
        (0..300_000).map(|_| (rng.normal() * 40.0).round() as i32).collect();
    let huff = huffman_encode(&vals);
    let renc = rans_encode(&vals).unwrap();
    // the speed must not be bought with size: matched CR within 1%
    assert!(
        (renc.len() as f64) <= huff.len() as f64 * 1.01,
        "rans stream {} B vs huffman {} B: size not within 1%",
        renc.len(),
        huff.len()
    );
    fn best_of(f: &mut dyn FnMut()) -> f64 {
        let mut best = f64::INFINITY;
        for _ in 0..3 {
            let t0 = std::time::Instant::now();
            f();
            best = best.min(t0.elapsed().as_secs_f64());
        }
        best
    }
    let lut = best_of(&mut || {
        std::hint::black_box(huffman_decode(std::hint::black_box(&huff)).unwrap());
    });
    let mut scratch = RansScratch::default();
    let mut out = Vec::new();
    let rans = best_of(&mut || {
        rans_decode_into(std::hint::black_box(&renc), vals.len(), &mut out, &mut scratch)
            .unwrap();
        std::hint::black_box(out.len());
    });
    assert!(
        rans * 1.5 <= lut,
        "rans decode {:.2} ms must be >= 1.5x faster than huffman LUT {:.2} ms",
        rans * 1e3,
        lut * 1e3
    );
}

#[test]
fn lut_decode_is_at_least_2x_faster_than_bitwise_on_peaked_streams() {
    // zero-peaked residual-shaped codes, large enough to dominate any
    // constant setup cost; best-of-3 on each side
    let mut rng = Rng::new(99);
    let vals: Vec<i32> =
        (0..300_000).map(|_| (rng.normal() * 0.6).round() as i32).collect();
    let enc = huffman_encode(&vals);
    let best_of = |f: &dyn Fn()| -> f64 {
        let mut best = f64::INFINITY;
        for _ in 0..3 {
            let t0 = std::time::Instant::now();
            f();
            best = best.min(t0.elapsed().as_secs_f64());
        }
        best
    };
    let lut = best_of(&|| {
        std::hint::black_box(huffman_decode(std::hint::black_box(&enc)).unwrap());
    });
    let bitwise = best_of(&|| {
        std::hint::black_box(huffman_decode_bitwise(std::hint::black_box(&enc)).unwrap());
    });
    assert!(
        lut * 2.0 <= bitwise,
        "LUT decode {:.2} ms must be >= 2x faster than bitwise {:.2} ms",
        lut * 1e3,
        bitwise * 1e3
    );
}

/// A rank-1 single-tile geometry: the entropy stage (not per-tile
/// container framing) dominates the payload, like large residual GOPs.
fn spike_cfg() -> DatasetConfig {
    let mut cfg = dataset_preset(DatasetKind::E3sm, Scale::Smoke);
    let n = cfg.total_points();
    cfg.dims = vec![n];
    cfg.ae_block = cfg.dims.clone();
    cfg.gae_block = cfg.dims.clone();
    cfg
}

/// Frames whose residuals are sparse spike fields over a zero keyframe:
/// under `abs:0.01` each spike of amplitude `0.1·m` codes to exactly
/// two nonzero symbols (+5m at the spike, −5m one step later, where the
/// Lorenzo prediction re-zeros) with jittered ~24-symbol spacing and
/// varied amplitudes — the zero-peaked residual regime the ROADMAP's
/// entropy item describes, with neither the run structure nor the
/// literal pattern repetitive enough for the plain framing's LZSS pass
/// to exploit.
fn zero_spike_frames(cfg: &DatasetConfig, steps: usize) -> Vec<Tensor> {
    let n: usize = cfg.dims.iter().product();
    let mut rng = Rng::new(42);
    let mut frames = vec![Tensor::new(cfg.dims.clone(), vec![0f32; n])];
    for _ in 1..steps {
        let mut next = frames.last().unwrap().clone();
        let data = next.data_mut();
        let mut k = 0usize;
        loop {
            let pos = k * 24 + rng.below(8);
            if pos >= n {
                break;
            }
            data[pos] += 0.1 * (1 + rng.below(10)) as f32;
            k += 1;
        }
        frames.push(next);
    }
    frames
}

/// Summed CR-payload bytes of the residual (non-keyframe) steps of one
/// stream write, with the symbol-container mode optionally forced.
fn residual_payload(
    frames: &[Tensor],
    cfg: &DatasetConfig,
    mode: Option<SymbolMode>,
    tag: &str,
) -> usize {
    let codec = Sz3Codec::new(cfg.clone());
    let bound = ErrorBound::PointwiseAbs(0.01);
    let dir = std::env::temp_dir().join("attn_reduce_coder_entropy");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(format!("residual_{tag}.tstr"));
    std::fs::remove_file(&path).ok();
    // thread-limit 1 so pool batches run inline and inherit the
    // thread-local mode override
    with_thread_limit(1, || {
        let run = || {
            let mut w =
                StreamWriter::create(&path, codec.id(), cfg.clone(), bound, frames.len())
                    .unwrap();
            let stats = w.append_frames(&codec, frames).unwrap();
            w.finish().unwrap();
            stats
                .iter()
                .filter(|s| !s.keyframe)
                .map(|s| s.payload_bytes)
                .sum()
        };
        match mode {
            Some(m) => with_symbol_mode(m, run),
            None => run(),
        }
    })
}

#[test]
fn residual_payload_shrinks_at_least_20_percent_vs_plain() {
    let cfg = spike_cfg();
    let frames = zero_spike_frames(&cfg, 8);
    let plain = residual_payload(&frames, &cfg, Some(SymbolMode::Plain), "plain");
    let auto = residual_payload(&frames, &cfg, None, "auto");
    assert!(plain > 0 && auto > 0, "plain {plain}, auto {auto}");
    assert!(
        (auto as f64) <= plain as f64 * 0.8,
        "auto residual payload {auto} B is not >= 20% under the PR-4 plain \
         framing {plain} B at the same bound"
    );
}

#[test]
fn residual_streams_decode_identically_under_every_mode() {
    // the payload shrink must be free: plain-forced and auto-selected
    // streams reconstruct every absolute frame bit-identically
    let cfg = spike_cfg();
    let frames = zero_spike_frames(&cfg, 4);
    let codec = Sz3Codec::new(cfg.clone());
    let bound = ErrorBound::PointwiseAbs(0.01);
    let dir = std::env::temp_dir().join("attn_reduce_coder_entropy");
    std::fs::create_dir_all(&dir).unwrap();
    let mut decoded: Vec<Vec<Vec<f32>>> = Vec::new();
    with_thread_limit(1, || {
        for (tag, mode) in [("dp", Some(SymbolMode::Plain)), ("da", None)] {
            let path = dir.join(format!("decode_{tag}.tstr"));
            std::fs::remove_file(&path).ok();
            let write = || {
                let mut w =
                    StreamWriter::create(&path, codec.id(), cfg.clone(), bound, 4).unwrap();
                w.append_frames(&codec, &frames).unwrap();
                w.finish().unwrap();
            };
            match mode {
                Some(m) => with_symbol_mode(m, write),
                None => write(),
            }
            let reader = StreamReader::open(&path).unwrap();
            decoded.push(
                (0..reader.n_steps())
                    .map(|t| reader.frame(&codec, t).unwrap().data().to_vec())
                    .collect(),
            );
        }
    });
    for (t, (a, b)) in decoded[0].iter().zip(&decoded[1]).enumerate() {
        let identical = a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits());
        assert!(identical, "step {t}: auto-mode decode differs from plain");
    }
}
