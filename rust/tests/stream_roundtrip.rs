//! Temporal stream integration tests: the acceptance contract of the v4
//! subsystem.
//!
//! * Streaming CR on a smoothly-evolving 64-step field beats
//!   independent-per-step v3 archives by ≥ 1.5× at the same bound (the
//!   `stream_throughput` bench reports the same quantity).
//! * `(step, region)` extraction decodes only the keyframe + residual
//!   blocks intersecting the region — byte accounting asserted against
//!   each chain archive's `BIDX`.
//! * Every reconstructed frame of a residual chain satisfies the typed
//!   `ErrorBound`, for both pure-rust codecs.
//! * Streams are self-describing: the reader rebuilds the codec from
//!   the first step archive's header alone.

use attn_reduce::codec::{Codec, CodecBuilder, ErrorBound, Sz3Codec, ZfpCodec};
use attn_reduce::config::{stream_frame_preset, DatasetKind, Scale};
use attn_reduce::data::{region_tile_ids, timeseries, Region};
use attn_reduce::stream::{SharedReader, StreamReader, StreamWriter};

fn tmp(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("attn_reduce_stream_it");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

/// The acceptance benchmark, pinned as a test: 64 smoothly-evolving
/// steps, keyframe interval 8, same NRMSE bound both ways.
#[test]
fn streaming_cr_beats_independent_per_step_archives() {
    let cfg = stream_frame_preset(DatasetKind::E3sm, Scale::Smoke);
    let codec = Sz3Codec::new(cfg.clone());
    let bound = ErrorBound::Nrmse(1e-3);
    let steps = 64usize;
    let frames = timeseries::generate_frames(&cfg.dims, cfg.seed, 0, steps);

    let independent_payload: usize = frames
        .iter()
        .map(|f| codec.compress(f, &bound).unwrap().cr_payload_bytes())
        .sum();

    let path = tmp("cr64.tstr");
    let mut w = StreamWriter::create(&path, codec.id(), cfg.clone(), bound, 8).unwrap();
    w.append_frames(&codec, &frames).unwrap();
    w.finish().unwrap();
    let reader = StreamReader::open(&path).unwrap();
    let stats = reader.stats().unwrap();
    assert_eq!(stats.steps, steps);
    assert_eq!(stats.keyframes, 8);

    let ratio = independent_payload as f64 / stats.payload_bytes as f64;
    assert!(
        ratio >= 1.5,
        "stream payload {} vs independent {} — only {ratio:.2}x better",
        stats.payload_bytes,
        independent_payload
    );

    // and the bound still holds on every absolute frame of every chain
    for (t, orig) in frames.iter().enumerate() {
        let recon = reader.frame(&codec, t).unwrap();
        assert!(
            ErrorBound::Nrmse(1e-3 * 1.0001).satisfied_by(orig, &recon, &cfg),
            "step {t} violates the stream bound"
        );
    }
}

#[test]
fn region_extraction_touches_only_intersecting_chain_blocks() {
    let cfg = stream_frame_preset(DatasetKind::E3sm, Scale::Smoke); // [32, 32], 16x16 tiles
    let codec = Sz3Codec::new(cfg.clone());
    let frames = timeseries::generate_frames(&cfg.dims, cfg.seed, 0, 10);
    let path = tmp("region.tstr");
    let mut w =
        StreamWriter::create(&path, codec.id(), cfg.clone(), ErrorBound::Nrmse(1e-3), 4).unwrap();
    w.append_frames(&codec, &frames).unwrap();
    w.finish().unwrap();

    let reader = StreamReader::open(&path).unwrap();
    // self-describing: rebuild the codec from the stream itself
    let codec = reader.build_codec(&mut CodecBuilder::new()).unwrap();
    // one tile of the 2x2 tiling
    let region = Region::parse("16:32,0:16").unwrap();
    let step = 6; // chain 4..=6
    let cost = reader.region_cost(step, &region).unwrap();
    assert_eq!(cost.steps, 3);
    assert_eq!(cost.blocks_total, 3 * 4);
    assert_eq!(cost.blocks_touched, 3 * 1, "one tile per chain archive");

    // byte accounting: exactly the BIDX entries of the intersecting tile
    // in each chain archive, nothing else
    let mut want = 0usize;
    for s in 4..=step {
        let idx = reader.step_archive(s).unwrap().block_index().unwrap().unwrap();
        let ids = region_tile_ids(&cfg.dims, &idx.tile, &region);
        assert_eq!(ids.len(), 1);
        want += idx.bytes_for(&ids);
    }
    assert_eq!(cost.bytes_touched, want);
    assert!(
        cost.bytes_touched * 2 < cost.bytes_total,
        "a 1-of-4-tiles region should touch well under half the chain payload \
         ({} of {})",
        cost.bytes_touched,
        cost.bytes_total
    );

    // and the decoded region is bit-identical to cropping the full frame
    let part = reader.extract(&*codec, step, &region).unwrap();
    let full = reader.frame(&*codec, step).unwrap();
    let crop = region.crop(&full).unwrap();
    assert_eq!(part.shape(), crop.shape());
    for (a, b) in part.data().iter().zip(crop.data()) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
}

#[test]
fn zfp_streams_respect_the_bound_across_chains() {
    let cfg = stream_frame_preset(DatasetKind::E3sm, Scale::Smoke);
    let codec = ZfpCodec::new(cfg.clone());
    let frames = timeseries::generate_frames(&cfg.dims, cfg.seed + 1, 0, 6);
    let range = frames[0].range() as f64;
    let bound = ErrorBound::PointwiseAbs(1e-3 * range);
    let path = tmp("zfp.tstr");
    let mut w = StreamWriter::create(&path, codec.id(), cfg.clone(), bound, 3).unwrap();
    for f in &frames {
        w.append(&codec, f).unwrap();
    }
    w.finish().unwrap();
    let reader = StreamReader::open(&path).unwrap();
    assert_eq!(reader.codec_id(), "zfp");
    for (t, orig) in frames.iter().enumerate() {
        let recon = reader.frame(&codec, t).unwrap();
        let slack = ErrorBound::PointwiseAbs(1e-3 * range * 1.0001);
        assert!(slack.satisfied_by(orig, &recon, &cfg), "zfp step {t}");
    }
    // residual steps carry the translated bound in their own headers
    assert_eq!(reader.step_bound(0).unwrap(), bound, "keyframe keeps the stream bound");
    assert_eq!(
        reader.step_bound(1).unwrap(),
        bound.for_residual(frames[1].range() as f64),
        "residual records its translated bound"
    );
}

/// The serving layer shares one open reader across its worker pool;
/// this pins the contract that makes it sound: a `StreamReader` behind
/// an `Arc` serves overlapping `(step, region)` decodes from multiple
/// threads with output byte-identical to the same decodes run
/// sequentially.
#[test]
fn shared_reader_decodes_identically_across_threads() {
    let cfg = stream_frame_preset(DatasetKind::E3sm, Scale::Smoke);
    let codec = Sz3Codec::new(cfg.clone());
    let frames = timeseries::generate_frames(&cfg.dims, cfg.seed + 3, 0, 8);
    let path = tmp("shared.tstr");
    let mut w =
        StreamWriter::create(&path, codec.id(), cfg.clone(), ErrorBound::Nrmse(1e-3), 3).unwrap();
    w.append_frames(&codec, &frames).unwrap();
    w.finish().unwrap();

    let reader: SharedReader = std::sync::Arc::new(StreamReader::open(&path).unwrap());
    // overlapping work items: repeated steps, nested + identical regions
    let jobs: Vec<(usize, &str)> = vec![
        (7, "0:16,0:16"),
        (7, "0:16,0:16"),
        (7, "0:32,0:32"),
        (5, "16:32,0:16"),
        (5, "0:16,0:16"),
        (0, "0:16,16:32"),
        (3, "8:24,8:24"),
        (7, "8:24,8:24"),
    ];

    // sequential reference decodes first
    let want: Vec<Vec<f32>> = jobs
        .iter()
        .map(|&(step, spec)| {
            let region = Region::parse(spec).unwrap();
            reader.extract(&codec, step, &region).unwrap().data().to_vec()
        })
        .collect();

    // then the same jobs concurrently, one thread per job, all through
    // the one shared reader (each thread builds its own codec — codecs
    // hold scratch state; readers are immutable)
    let got: Vec<Vec<f32>> = std::thread::scope(|scope| {
        let handles: Vec<_> = jobs
            .iter()
            .map(|&(step, spec)| {
                let r = reader.clone();
                let cfg = cfg.clone();
                scope.spawn(move || {
                    let codec = Sz3Codec::new(cfg);
                    let region = Region::parse(spec).unwrap();
                    r.extract(&codec, step, &region).unwrap().data().to_vec()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    for (i, (w, g)) in want.iter().zip(&got).enumerate() {
        assert_eq!(w.len(), g.len(), "job {i} length");
        for (a, b) in w.iter().zip(g) {
            assert_eq!(a.to_bits(), b.to_bits(), "job {i} diverged across threads");
        }
    }
}

#[test]
fn stream_iterator_matches_random_access_across_gops() {
    let cfg = stream_frame_preset(DatasetKind::E3sm, Scale::Smoke);
    let codec = Sz3Codec::new(cfg.clone());
    let frames = timeseries::generate_frames(&cfg.dims, cfg.seed + 2, 0, 9);
    let path = tmp("iter.tstr");
    let mut w =
        StreamWriter::create(&path, codec.id(), cfg.clone(), ErrorBound::Nrmse(1e-3), 4).unwrap();
    w.append_frames(&codec, &frames).unwrap();
    w.finish().unwrap();
    let reader = StreamReader::open(&path).unwrap();
    let played: Vec<_> = reader.frames(&codec).map(|f| f.unwrap()).collect();
    assert_eq!(played.len(), 9);
    for (t, via_iter) in played.iter().enumerate() {
        let via_chain = reader.frame(&codec, t).unwrap();
        assert_eq!(via_iter.data(), via_chain.data(), "step {t}");
    }
    // out-of-range access is a typed error, not a panic
    assert!(reader.frame(&codec, 9).is_err());
    assert!(reader.extract(&codec, 9, &Region::parse("0:8,0:8").unwrap()).is_err());
    // a region outside the frame is rejected before any decode
    assert!(reader
        .extract(&codec, 0, &Region::parse("0:64,0:64").unwrap())
        .is_err());
}
