//! Decoder corruption fuzzing, extending the `archive_format.rs`-style
//! sweeps to every untrusted byte stream a consumer can hand the crate:
//! the chunked lossless container (magic 0xB4), the bit-level Huffman
//! stage, the interleaved rANS container (magic 0xB7), the SZ3/ZFP
//! baseline streams, the v3 `BIDX` block index, and the index's
//! per-tile codec-id trailer (mixed-codec adaptive archives).
//!
//! Contract: **truncated** input always returns `Err`; **mutated** input
//! must never panic and never balloon memory (every length that sizes an
//! allocation is capped by the declared geometry before use). Bit flips
//! in opaque payload bytes may legally decode to different values — the
//! invariant there is no-panic plus a well-formed result.

use attn_reduce::baselines::{Sz3Like, ZfpLike};
use attn_reduce::codec::{AdaptiveCodec, Codec, CodecBuilder, ErrorBound, Sz3Codec};
use attn_reduce::coder::{
    compress_symbols, compress_symbols_mode, decompress_symbols, huffman_decode,
    huffman_encode, lossless_compress, lossless_decompress, SymbolMode,
};
use attn_reduce::compressor::{Archive, BlockIndex};
use attn_reduce::config::{dataset_preset, DatasetKind, Scale};
use attn_reduce::data::{self, Region};
use attn_reduce::tensor::Tensor;
use attn_reduce::util::rng::Rng;

/// Evenly-spaced sample of cut points (full sweeps are quadratic in
/// stream size; sampling keeps the test fast while covering every
/// framing field of interest via the dense prefix).
fn cuts(len: usize) -> Vec<usize> {
    let mut out: Vec<usize> = (0..len.min(64)).collect();
    let step = (len / 199).max(1);
    out.extend((64..len).step_by(step));
    out.push(len.saturating_sub(1));
    out
}

fn smooth_field(shape: Vec<usize>, seed: u64) -> Tensor {
    let n: usize = shape.iter().product();
    let mut rng = Rng::new(seed);
    let (a, b) = (rng.uniform() * 5.0 + 1.0, rng.uniform());
    let data: Vec<f32> = (0..n)
        .map(|i| {
            let x = i as f64 / 57.0;
            ((a * x).sin() + 0.2 * (b + x).cos()) as f32
        })
        .collect();
    Tensor::new(shape, data)
}

#[test]
fn chunked_lossless_truncations_always_error() {
    // > PAR_CHUNK so the 0xB4 chunked container is exercised
    let mut rng = Rng::new(11);
    let mut raw = Vec::with_capacity(attn_reduce::coder::lossless::PAR_CHUNK + 5000);
    while raw.len() < attn_reduce::coder::lossless::PAR_CHUNK + 5000 {
        let run = 1 + (rng.next_u64() % 40) as usize;
        let byte = (rng.next_u64() % 5) as u8 * 50;
        raw.extend(std::iter::repeat(byte).take(run));
    }
    let c = lossless_compress(&raw).unwrap();
    assert_eq!(c[0], 0xB4, "large input should use the chunked container");
    for cut in cuts(c.len()) {
        assert!(
            lossless_decompress(&c[..cut], raw.len()).is_err(),
            "chunked cut {cut} of {} parsed",
            c.len()
        );
    }
}

#[test]
fn chunked_lossless_bitflips_never_panic_and_respect_cap() {
    let raw: Vec<u8> = (0..attn_reduce::coder::lossless::PAR_CHUNK + 777)
        .map(|i| (i % 251) as u8)
        .collect();
    let c = lossless_compress(&raw).unwrap();
    let mut rng = Rng::new(23);
    for _ in 0..400 {
        let mut m = c.clone();
        let pos = rng.below(m.len());
        m[pos] ^= 1 << rng.below(8);
        // Err or Ok — never panic, and Ok output never exceeds the cap
        if let Ok(out) = lossless_decompress(&m, raw.len()) {
            assert!(out.len() <= raw.len());
        }
    }
}

#[test]
fn huffman_bitstream_fuzz_never_panics() {
    let mut rng = Rng::new(37);
    let values: Vec<i32> = (0..4000)
        .map(|_| (rng.next_u64() % 23) as i32 - 11)
        .collect();
    let enc = huffman_encode(&values);
    // truncations: structured Err or a shorter-but-well-formed decode,
    // never a panic (trailing padding cuts can still satisfy n_values)
    for cut in cuts(enc.len()) {
        if let Ok((vals, used)) = huffman_decode(&enc[..cut]) {
            assert_eq!(vals.len(), values.len());
            assert!(used <= cut);
        }
    }
    // bit flips across table, counts, and bitstream
    for _ in 0..500 {
        let mut m = enc.clone();
        let pos = rng.below(m.len());
        m[pos] ^= 1 << rng.below(8);
        let _ = huffman_decode(&m); // must not panic
    }
}

#[test]
fn huffman_hostile_counts_error_before_allocating() {
    // a declared table size far beyond the bytes present must be a clean
    // error before `Vec::with_capacity` can run (the old decoder
    // allocated first and only then noticed the truncation)
    let mut s = Vec::new();
    s.extend_from_slice(&u32::MAX.to_le_bytes());
    s.extend_from_slice(&[0u8; 256]);
    assert!(huffman_decode(&s).is_err());
    // a degenerate single-symbol stream claiming u64::MAX values must
    // not size the output allocation either
    let mut s = Vec::new();
    s.extend_from_slice(&1u32.to_le_bytes());
    s.extend_from_slice(&7i32.to_le_bytes());
    s.push(0);
    s.extend_from_slice(&u64::MAX.to_le_bytes());
    assert!(huffman_decode(&s).is_err());
}

#[test]
fn zero_run_container_truncations_and_flips_never_panic() {
    // a residual-shaped stream that selects the 0xB5 zero-run container
    let mut rng = Rng::new(71);
    let values: Vec<i32> = (0..8000)
        .map(|_| if rng.below(10) == 0 { (rng.below(7) as i32) - 3 } else { 0 })
        .collect();
    let enc = compress_symbols_mode(&values, SymbolMode::ZeroRun).unwrap();
    assert_eq!(enc[0], 0xB5);
    // truncations: structured Err, or a decode whose expansion still
    // matched the declared count — never a panic
    for cut in cuts(enc.len()) {
        if let Ok(out) = decompress_symbols(&enc[..cut], values.len()) {
            assert_eq!(out.len(), values.len());
        }
    }
    // bit flips across the count, table, and transformed bitstream
    for _ in 0..500 {
        let mut m = enc.clone();
        let pos = rng.below(m.len());
        m[pos] ^= 1 << rng.below(8);
        if let Ok(out) = decompress_symbols(&m, values.len()) {
            assert!(out.len() <= values.len());
        }
    }
    // the constant container (0xB6) under the same sweeps
    let zeros = vec![0i32; 4096];
    let konst = compress_symbols(&zeros).unwrap();
    assert_eq!(konst[0], 0xB6);
    for cut in 0..konst.len() {
        let _ = decompress_symbols(&konst[..cut], 4096);
    }
    for _ in 0..100 {
        let mut m = konst.clone();
        let pos = rng.below(m.len());
        m[pos] ^= 1 << rng.below(8);
        if let Ok(out) = decompress_symbols(&m, 4096) {
            assert!(out.len() <= 4096);
        }
    }
}

#[test]
fn rans_container_truncations_and_flips_never_panic() {
    // a dense near-gaussian stream that rides the 0xB7 rANS container
    let mut rng = Rng::new(83);
    let values: Vec<i32> =
        (0..8000).map(|_| (rng.normal() * 30.0).round() as i32).collect();
    let enc = compress_symbols_mode(&values, SymbolMode::Rans).unwrap();
    assert_eq!(enc[0], 0xB7);
    // truncations: structured Err or a decode whose length still matched
    // the declared count — never a panic, never an oversized allocation
    for cut in cuts(enc.len()) {
        if let Ok(out) = decompress_symbols(&enc[..cut], values.len()) {
            assert_eq!(out.len(), values.len());
        }
    }
    // bit flips across header, frequency table, states, and lane bytes
    for _ in 0..500 {
        let mut m = enc.clone();
        let pos = rng.below(m.len());
        m[pos] ^= 1 << rng.below(8);
        if let Ok(out) = decompress_symbols(&m, values.len()) {
            assert!(out.len() <= values.len());
        }
    }
    // crafted corrupt frequency tables (layout: magic | u64 n | u8
    // scale_bits | u32 n_syms | n_syms x (i32 sym, u16 freq) | ...):
    // a zero frequency must error before any decode state is built
    let mut m = enc.clone();
    m[18] = 0;
    m[19] = 0;
    assert!(decompress_symbols(&m, values.len()).is_err(), "zero freq must error");
    // frequencies that do not sum to the scale must error
    let mut m = enc.clone();
    m[19] = m[19].wrapping_add(0x10);
    assert!(decompress_symbols(&m, values.len()).is_err(), "bad freq sum must error");
    // a declared count beyond the caller cap errors before allocation
    let mut m = enc.clone();
    m[1..9].copy_from_slice(&u64::MAX.to_le_bytes());
    assert!(decompress_symbols(&m, values.len()).is_err(), "count cap must hold");
    // lane desync: swapping two unequal lane byte-lengths keeps the
    // total consistent but desynchronizes the interleave — the final
    // state / consumption checks must reject it
    let n_syms = u32::from_le_bytes(enc[10..14].try_into().unwrap()) as usize;
    let lens_off = 14 + n_syms * 6 + 16;
    let lens: Vec<u32> = (0..4)
        .map(|i| {
            u32::from_le_bytes(enc[lens_off + 4 * i..lens_off + 4 * i + 4].try_into().unwrap())
        })
        .collect();
    let pair = (0..4)
        .flat_map(|a| (a + 1..4).map(move |b| (a, b)))
        .find(|&(a, b)| lens[a] != lens[b]);
    if let Some((a, b)) = pair {
        let mut m = enc.clone();
        m[lens_off + 4 * a..lens_off + 4 * a + 4].copy_from_slice(&lens[b].to_le_bytes());
        m[lens_off + 4 * b..lens_off + 4 * b + 4].copy_from_slice(&lens[a].to_le_bytes());
        assert!(
            decompress_symbols(&m, values.len()).is_err(),
            "lane desync must error"
        );
    }
}

#[test]
fn sz3_stream_truncations_error_and_flips_never_panic() {
    let t = smooth_field(vec![6, 16, 16], 5);
    let stream = Sz3Like::new(1e-3).compress(&t).unwrap();
    for cut in cuts(stream.len()) {
        assert!(
            Sz3Like::decompress(&stream[..cut]).is_err(),
            "sz3 cut {cut} of {} parsed",
            stream.len()
        );
    }
    let mut rng = Rng::new(41);
    for _ in 0..400 {
        let mut m = stream.clone();
        let pos = rng.below(m.len());
        m[pos] ^= 1 << rng.below(8);
        // tight cap: a corrupt header may not allocate past the true size
        let _ = Sz3Like::decompress_capped(&m, t.len());
    }
}

#[test]
fn zfp_stream_truncations_error_and_flips_never_panic() {
    let t = smooth_field(vec![5, 12, 12], 7);
    let stream = ZfpLike::new(14).compress(&t).unwrap();
    for cut in cuts(stream.len()) {
        assert!(
            ZfpLike::decompress(&stream[..cut]).is_err(),
            "zfp cut {cut} of {} parsed",
            stream.len()
        );
    }
    let mut rng = Rng::new(43);
    for _ in 0..400 {
        let mut m = stream.clone();
        let pos = rng.below(m.len());
        m[pos] ^= 1 << rng.below(8);
        let _ = ZfpLike::decompress_capped(&m, t.len());
    }
}

/// A real v3 archive with its BIDX section located in the serialized
/// bytes, so the index itself can be attacked in place.
fn v3_archive_bytes() -> (Vec<u8>, usize, usize) {
    let cfg = dataset_preset(DatasetKind::E3sm, Scale::Smoke);
    let field = data::generate(&cfg);
    let codec = Sz3Codec::new(cfg);
    let archive = codec.compress(&field, &ErrorBound::Nrmse(1e-3)).unwrap();
    let bytes = archive.to_bytes();
    let tag_pos = bytes
        .windows(4)
        .position(|w| w == b"BIDX")
        .expect("v3 archive has an index section");
    let len = u64::from_le_bytes(bytes[tag_pos + 4..tag_pos + 12].try_into().unwrap());
    (bytes, tag_pos + 12, len as usize)
}

#[test]
fn v3_index_corruption_never_panics_and_oob_extents_error() {
    let (bytes, idx_off, idx_len) = v3_archive_bytes();
    let region = Region::parse("0:6,0:16,0:16").unwrap();
    let mut rng = Rng::new(47);
    let mut builder = CodecBuilder::new();
    // dense flip sweep over the entire index section
    for pos in idx_off..idx_off + idx_len {
        for _ in 0..2 {
            let mut m = bytes.clone();
            m[pos] ^= 1 << rng.below(8);
            let Ok(archive) = Archive::from_bytes(&m) else {
                continue;
            };
            let Ok(codec) = builder.for_archive(&archive) else {
                continue;
            };
            // Err or Ok with the right shape — never a panic
            if let Ok(t) = codec.decompress(&archive) {
                assert_eq!(t.shape(), &[24, 32, 32]);
            }
            if let Ok(t) = codec.decompress_region(&archive, &region) {
                assert_eq!(t.shape(), &region.shape()[..]);
            }
        }
    }
    // an index whose extents point past the payload must error cleanly
    let mut m = bytes.clone();
    // first entry offset lives right after rank(4) + tile dims(3 x 4) + count(8)
    let first_entry = idx_off + 4 + 3 * 4 + 8;
    m[first_entry..first_entry + 8].copy_from_slice(&u64::MAX.to_le_bytes());
    if let Ok(archive) = Archive::from_bytes(&m) {
        let codec = builder.for_archive(&archive).unwrap();
        assert!(codec.decompress(&archive).is_err(), "oob extent must error");
        assert!(codec.decompress_region(&archive, &region).is_err());
    }
    // truncating anywhere inside the archive still always errors
    for cut in cuts(bytes.len()) {
        assert!(Archive::from_bytes(&bytes[..cut]).is_err(), "cut {cut}");
    }
}

/// A small sealed v4 stream (4 steps, keyint 2) as raw bytes, plus the
/// offset of its `TIDX` record. `name` must be unique per caller: the
/// fuzz tests run on parallel threads and a shared path would race
/// (File::create truncates under a concurrent fs::read).
fn v4_stream_bytes(name: &str) -> (Vec<u8>, usize) {
    use attn_reduce::config::{stream_frame_preset, Scale};
    use attn_reduce::stream::StreamWriter;
    let cfg = stream_frame_preset(DatasetKind::E3sm, Scale::Smoke);
    let codec = Sz3Codec::new(cfg.clone());
    let frames = attn_reduce::data::timeseries::generate_frames(&cfg.dims, cfg.seed, 0, 4);
    let dir = std::env::temp_dir().join("attn_reduce_fuzz_stream");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(name);
    let mut w =
        StreamWriter::create(&path, codec.id(), cfg, ErrorBound::Nrmse(1e-3), 2).unwrap();
    w.append_frames(&codec, &frames).unwrap();
    w.finish().unwrap();
    let bytes = std::fs::read(&path).unwrap();
    // the footer's u64 locates the TIDX record
    let foot = &bytes[bytes.len() - 12..];
    assert_eq!(&foot[8..12], b"TEND");
    let tidx_off = u64::from_le_bytes(foot[0..8].try_into().unwrap()) as usize;
    assert_eq!(&bytes[tidx_off..tidx_off + 4], b"TIDX");
    (bytes, tidx_off)
}

#[test]
fn v4_timeline_corruption_never_panics() {
    use attn_reduce::stream::StreamReader;
    let (bytes, tidx_off) = v4_stream_bytes("timeline.tstr");
    let mut rng = Rng::new(59);
    let mut builder = CodecBuilder::new();
    // dense flip sweep over the TIDX record and the footer: the reader
    // must never panic — it either errors, falls back to the recovery
    // scan, or reads a stream whose frames still decode to frame shape
    for pos in tidx_off..bytes.len() {
        for _ in 0..2 {
            let mut m = bytes.clone();
            m[pos] ^= 1 << rng.below(8);
            let Ok(reader) = StreamReader::from_bytes(m) else {
                continue;
            };
            let Ok(codec) = reader.build_codec(&mut builder) else {
                continue;
            };
            for step in 0..reader.n_steps() {
                if let Ok(t) = reader.frame(&*codec, step) {
                    assert_eq!(t.shape(), reader.dataset().dims.as_slice());
                }
            }
        }
    }
}

#[test]
fn v4_truncations_and_residual_payload_cuts_never_panic() {
    use attn_reduce::stream::StreamReader;
    let (bytes, _) = v4_stream_bytes("truncation.tstr");
    let full = StreamReader::from_bytes(bytes.clone()).unwrap();
    assert_eq!(full.n_steps(), 4);
    let mut builder = CodecBuilder::new();
    // every truncation: clean error or a recovered stream with fewer
    // steps, whose surviving frames all still decode
    for cut in cuts(bytes.len()) {
        let Ok(reader) = StreamReader::from_bytes(bytes[..cut].to_vec()) else {
            continue;
        };
        assert!(reader.n_steps() <= 4);
        let Ok(codec) = reader.build_codec(&mut builder) else {
            continue;
        };
        for step in 0..reader.n_steps() {
            let t = reader
                .frame(&*codec, step)
                .unwrap_or_else(|e| panic!("recovered step {step} at cut {cut}: {e:#}"));
            assert_eq!(t.shape(), reader.dataset().dims.as_slice());
        }
    }
    // bit flips inside a residual step's archive payload: parsing and
    // chain decodes must never panic (values may legally differ)
    let entry = full.timeline().entries[1];
    assert!(!entry.keyframe, "step 1 of a keyint-2 stream is a residual");
    let (off, len) = (entry.offset as usize, entry.len as usize);
    let mut rng = Rng::new(61);
    for _ in 0..300 {
        let mut m = bytes.clone();
        let pos = off + rng.below(len);
        m[pos] ^= 1 << rng.below(8);
        let Ok(reader) = StreamReader::from_bytes(m) else {
            continue;
        };
        let Ok(codec) = reader.build_codec(&mut builder) else {
            continue;
        };
        let region = Region::parse("0:16,8:32").unwrap();
        for step in 0..reader.n_steps() {
            let _ = reader.frame(&*codec, step);
            let _ = reader.extract(&*codec, step, &region);
        }
    }
}

/// A real adaptive (mixed-codec-capable) v3 archive with its `BIDX`
/// section and codec-id trailer located in the serialized bytes, so the
/// index extension itself can be attacked in place. Returns
/// `(bytes, idx_off, idx_len, trailer_off)` where `trailer_off` is the
/// absolute offset of the trailer's minor-version byte.
fn adaptive_archive_bytes() -> (Vec<u8>, usize, usize, usize) {
    let cfg = dataset_preset(DatasetKind::E3sm, Scale::Smoke);
    let field = data::generate(&cfg);
    let codec = AdaptiveCodec::new(cfg);
    let archive = codec.compress(&field, &ErrorBound::Nrmse(1e-3)).unwrap();
    let index = archive.block_index().unwrap().expect("adaptive archive has index");
    let n = index.entries.len();
    assert!(index.codecs.is_some(), "adaptive archive records codec ids");
    let bytes = archive.to_bytes();
    let tag_pos = bytes
        .windows(4)
        .position(|w| w == b"BIDX")
        .expect("adaptive archive has an index section");
    let idx_len =
        u64::from_le_bytes(bytes[tag_pos + 4..tag_pos + 12].try_into().unwrap()) as usize;
    let idx_off = tag_pos + 12;
    // trailer = u8 minor | n x u8 id, after rank | tile dims | count | entries
    let trailer_off = idx_off + 4 + index.tile.len() * 4 + 8 + n * 16;
    assert_eq!(trailer_off + 1 + n, idx_off + idx_len, "trailer spans the section tail");
    assert_eq!(bytes[trailer_off], 1, "codec-id extension minor version");
    (bytes, idx_off, idx_len, trailer_off)
}

#[test]
fn adaptive_unknown_codec_ids_are_typed_errors_and_scoped_per_tile() {
    let (bytes, _, _, trailer_off) = adaptive_archive_bytes();
    let mut builder = CodecBuilder::new();
    let archive = Archive::from_bytes(&bytes).unwrap();
    let index = archive.block_index().unwrap().unwrap();
    let n = index.entries.len();
    let codec = builder.for_archive(&archive).unwrap();
    let clean = codec.decompress(&archive).unwrap();
    assert_eq!(clean.shape(), &[24, 32, 32]);
    // a region entirely inside tile 0 (tile dims never exceed field dims)
    let tile0 = Region::parse(&format!(
        "0:{},0:{},0:{}",
        index.tile[0], index.tile[1], index.tile[2]
    ))
    .unwrap();
    // every out-of-range id value on the *first* tile is a typed error
    // from full decode and from any region touching that tile
    for bad in [2u8, 3, 127, 255] {
        let mut m = bytes.clone();
        m[trailer_off + 1] = bad;
        let archive = Archive::from_bytes(&m).unwrap();
        let codec = builder.for_archive(&archive).unwrap();
        let err = codec.decompress(&archive).unwrap_err().to_string();
        assert!(
            err.contains(&format!("unknown per-tile codec id {bad}")),
            "full decode: {err}"
        );
        let err = codec.decompress_region(&archive, &tile0).unwrap_err().to_string();
        assert!(err.contains("unknown per-tile codec id"), "region decode: {err}");
    }
    // a bad id on the *last* tile leaves a tile-0 region decode intact —
    // dispatch only consults the ids of the tiles a region touches
    let mut m = bytes.clone();
    m[trailer_off + n] = 255;
    let archive = Archive::from_bytes(&m).unwrap();
    let codec = builder.for_archive(&archive).unwrap();
    assert!(codec.decompress(&archive).is_err(), "full decode hits the bad tile");
    let part = codec.decompress_region(&archive, &tile0).expect("tile-0 region");
    assert_eq!(part.data(), tile0.crop(&clean).unwrap().data());
}

#[test]
fn adaptive_id_payload_mismatches_never_panic() {
    let (bytes, _, _, trailer_off) = adaptive_archive_bytes();
    let mut builder = CodecBuilder::new();
    let archive = Archive::from_bytes(&bytes).unwrap();
    let n = archive.block_index().unwrap().unwrap().entries.len();
    let region = Region::parse("0:6,0:16,0:16").unwrap();
    // flipping a valid id to the *other* valid id routes that tile's
    // payload to the wrong decoder: a structured Err or a wrong-valued
    // decode of the right shape — never a panic, never an allocation
    // past the tile volume (both decoders are capped by the geometry)
    for i in 0..n {
        let mut m = bytes.clone();
        m[trailer_off + 1 + i] ^= 1;
        let archive = Archive::from_bytes(&m).unwrap();
        let codec = builder.for_archive(&archive).unwrap();
        if let Ok(t) = codec.decompress(&archive) {
            assert_eq!(t.shape(), &[24, 32, 32]);
        }
        if let Ok(t) = codec.decompress_region(&archive, &region) {
            assert_eq!(t.shape(), &region.shape()[..]);
        }
    }
}

#[test]
fn adaptive_index_trailer_truncations_and_versions_error() {
    let (bytes, idx_off, idx_len, trailer_off) = adaptive_archive_bytes();
    let idx = &bytes[idx_off..idx_off + idx_len];
    let n = BlockIndex::from_bytes(idx).unwrap().entries.len();
    let base = trailer_off - idx_off;
    // dropping the whole trailer is the legal homogeneous encoding...
    let legacy = BlockIndex::from_bytes(&idx[..base]).unwrap();
    assert!(legacy.codecs.is_none());
    // ...but a *partial* trailer is always a typed error: every cut that
    // leaves the minor byte with fewer than n ids must name the deficit
    for cut in base + 1..idx_len {
        let err = BlockIndex::from_bytes(&idx[..cut]).unwrap_err().to_string();
        assert!(
            err.contains("codec-id extension has"),
            "cut {cut}: {err}"
        );
    }
    // surplus ids are rejected the same way, and an unsupported minor
    // version errors before any id is interpreted
    let mut extra = idx.to_vec();
    extra.push(0);
    let err = BlockIndex::from_bytes(&extra).unwrap_err().to_string();
    assert!(err.contains("codec-id extension has"), "{err}");
    for minor in [0u8, 2, 255] {
        let mut m = idx.to_vec();
        m[base] = minor;
        let err = BlockIndex::from_bytes(&m).unwrap_err().to_string();
        assert!(
            err.contains(&format!("extension version {minor} unsupported")),
            "{err}"
        );
    }
    // an adaptive archive whose index *lost* its trailer (a legal legacy
    // index) is a typed error at decode, not a misdispatch: the codec
    // refuses to guess per-tile formats
    let mut m = bytes.clone();
    let tag_pos = idx_off - 12;
    m.drain(trailer_off..trailer_off + 1 + n);
    m[tag_pos + 4..tag_pos + 12].copy_from_slice(&((idx_len - 1 - n) as u64).to_le_bytes());
    let archive = Archive::from_bytes(&m).expect("legacy index still parses");
    let codec = CodecBuilder::new().for_archive(&archive).unwrap();
    let err = codec.decompress(&archive).unwrap_err().to_string();
    assert!(err.contains("missing per-tile codec ids"), "{err}");
}

#[test]
fn adaptive_index_and_payload_bitflips_never_panic() {
    let (bytes, idx_off, idx_len, _) = adaptive_archive_bytes();
    let region = Region::parse("0:6,0:16,0:16").unwrap();
    let mut rng = Rng::new(67);
    let mut builder = CodecBuilder::new();
    // dense flip sweep over the extended index section, trailer included
    for pos in idx_off..idx_off + idx_len {
        for _ in 0..2 {
            let mut m = bytes.clone();
            m[pos] ^= 1 << rng.below(8);
            let Ok(archive) = Archive::from_bytes(&m) else {
                continue;
            };
            let Ok(codec) = builder.for_archive(&archive) else {
                continue;
            };
            if let Ok(t) = codec.decompress(&archive) {
                assert_eq!(t.shape(), &[24, 32, 32]);
            }
            if let Ok(t) = codec.decompress_region(&archive, &region) {
                assert_eq!(t.shape(), &region.shape()[..]);
            }
        }
    }
    // random flips across the mixed ADPB payload: the per-tile cap keeps
    // every dispatch (right codec or wrong) inside the geometry
    let payload_pos = bytes
        .windows(4)
        .position(|w| w == b"ADPB")
        .expect("adaptive payload section")
        + 12;
    for _ in 0..300 {
        let mut m = bytes.clone();
        let pos = payload_pos + rng.below(bytes.len() - payload_pos);
        m[pos] ^= 1 << rng.below(8);
        let Ok(archive) = Archive::from_bytes(&m) else {
            continue;
        };
        let Ok(codec) = builder.for_archive(&archive) else {
            continue;
        };
        let _ = codec.decompress(&archive);
        let _ = codec.decompress_region(&archive, &region);
    }
}

#[test]
fn xsum_archive_every_single_byte_flip_is_a_typed_error() {
    use attn_reduce::compressor::format::is_corruption;
    // a real (smoke-scale) checksummed sz3 archive, as `save` writes it
    let cfg = dataset_preset(DatasetKind::E3sm, Scale::Smoke);
    let field = data::generate(&cfg);
    let archive = Sz3Codec::new(cfg).compress(&field, &ErrorBound::Nrmse(1e-3)).unwrap();
    let bytes = archive.to_bytes_checked();
    assert!(Archive::from_bytes(&bytes).unwrap().checksummed());
    // every single-byte flip anywhere in the file must parse to an
    // error — the whole-file CRC covers [0..len-8], the stored CRC and
    // XEND cover themselves — and most land as typed Corruption
    let mut corruption_hits = 0usize;
    for pos in 0..bytes.len() {
        let mut m = bytes.clone();
        m[pos] ^= 0x10;
        let err = Archive::from_bytes(&m)
            .err()
            .unwrap_or_else(|| panic!("flip at byte {pos} parsed clean"));
        corruption_hits += is_corruption(&err) as usize;
    }
    assert!(
        corruption_hits > bytes.len() / 2,
        "most flips should surface as typed Corruption, got {corruption_hits}/{}",
        bytes.len()
    );
}

#[test]
fn torn_checked_stream_reopens_cleanly_and_appends() {
    use attn_reduce::config::stream_frame_preset;
    use attn_reduce::stream::{StreamReader, StreamWriter};
    let cfg = stream_frame_preset(DatasetKind::E3sm, Scale::Smoke);
    let codec = Sz3Codec::new(cfg.clone());
    let frames = data::timeseries::generate_frames(&cfg.dims, cfg.seed, 0, 5);
    let dir = std::env::temp_dir().join("attn_reduce_fuzz_torn_reopen");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("torn.tstr");
    // an unsealed stream (no finish), as a crash mid-run leaves it
    let mut w =
        StreamWriter::create(&path, codec.id(), cfg, ErrorBound::Nrmse(1e-3), 2).unwrap();
    w.append_frames(&codec, &frames[..4]).unwrap();
    drop(w);
    let full = std::fs::read(&path).unwrap();
    let last = *StreamReader::from_bytes(full.clone()).unwrap().timeline().entries.last().unwrap();
    // tear the tail mid-final-record (checked framing: payload + CRC)
    let torn_at = last.offset as usize + last.len as usize / 2;
    std::fs::write(&path, &full[..torn_at]).unwrap();
    // the reader's recovery scan drops the torn step; reopen + append
    // must continue the chain as if the torn step never happened
    let r = StreamReader::open(&path).unwrap();
    assert_eq!(r.n_steps(), 3, "torn final record dropped by the scan");
    let mut w = StreamWriter::reopen_from(&path, r, &codec).unwrap();
    w.append_frames(&codec, &frames[3..]).unwrap();
    w.finish().unwrap();
    let r = StreamReader::open(&path).unwrap();
    assert_eq!(r.n_steps(), 5, "reopen resumed after the torn tail");
    let mut builder = CodecBuilder::new();
    let c = r.build_codec(&mut builder).unwrap();
    for step in 0..r.n_steps() {
        let t = r.frame(&*c, step).unwrap();
        assert_eq!(t.shape(), r.dataset().dims.as_slice());
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn v3_payload_bitflips_never_panic() {
    let (bytes, _, _) = v3_archive_bytes();
    let payload_pos = bytes
        .windows(4)
        .position(|w| w == b"SZ3B")
        .expect("payload section")
        + 12;
    let mut rng = Rng::new(53);
    let mut builder = CodecBuilder::new();
    let region = Region::parse("2:20,0:8,8:30").unwrap();
    for _ in 0..300 {
        let mut m = bytes.clone();
        let pos = payload_pos + rng.below(bytes.len() - payload_pos);
        m[pos] ^= 1 << rng.below(8);
        let Ok(archive) = Archive::from_bytes(&m) else {
            continue;
        };
        let Ok(codec) = builder.for_archive(&archive) else {
            continue;
        };
        let _ = codec.decompress(&archive);
        let _ = codec.decompress_region(&archive, &region);
    }
}
