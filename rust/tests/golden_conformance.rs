//! Golden-archive conformance: the committed v1 / v2 / v3 archives under
//! `tests/golden/` must decode to their committed expected outputs,
//! bit-for-bit, forever. This pins decoder backward compatibility so
//! format-touching PRs cannot silently break old archives (see
//! `tests/golden/README.md` for the corpus and its regeneration policy).

use attn_reduce::codec::{Codec, CodecBuilder};
use attn_reduce::compressor::Archive;
use attn_reduce::data::Region;
use attn_reduce::engine::CodecExt;
use attn_reduce::tensor::Tensor;

fn golden_path(name: &str) -> std::path::PathBuf {
    std::path::Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/tests/golden")).join(name)
}

fn golden_archive(name: &str) -> Archive {
    let bytes = std::fs::read(golden_path(name)).expect("read golden archive");
    Archive::from_bytes(&bytes).expect("parse golden archive")
}

fn expected_f32(name: &str) -> Vec<f32> {
    std::fs::read(golden_path(name))
        .expect("read expected output")
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect()
}

/// Bit-exact comparison (a golden must not drift by even one ULP).
fn assert_bits_equal(got: &Tensor, want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (g, w)) in got.data().iter().zip(want).enumerate() {
        assert_eq!(
            g.to_bits(),
            w.to_bits(),
            "{what}: value {i} decoded {g}, expected {w}"
        );
    }
}

fn codec_for(archive: &Archive) -> Box<dyn Codec> {
    CodecBuilder::new()
        .for_archive(archive)
        .expect("rebuild codec from golden header")
}

#[test]
fn v1_golden_decodes_unchanged() {
    let archive = golden_archive("v1_sz3.ardc");
    assert_eq!(archive.version(), 1);
    assert!(archive.block_index().unwrap().is_none(), "v1 has no index");
    let codec = codec_for(&archive);
    let recon = codec.decompress(&archive).expect("decode v1 golden");
    assert_eq!(recon.shape(), &[6, 8]);
    assert_bits_equal(&recon, &expected_f32("v1_sz3.expected.f32"), "v1");
    // the region API works on v1 via full-decode + crop
    let region = Region::parse("1:5,2:7").unwrap();
    let part = codec.decompress_region(&archive, &region).expect("v1 region");
    assert_bits_equal(
        &part,
        region.crop(&recon).unwrap().data(),
        "v1 region fallback",
    );
}

#[test]
fn v2_golden_decodes_unchanged() {
    let archive = golden_archive("v2_sz3.ardc");
    assert_eq!(archive.version(), 2);
    assert_eq!(archive.field_names().unwrap(), vec!["temp", "pressure"]);
    let codec = codec_for(&archive);
    let set = codec.decompress_set(&archive).expect("decode v2 golden");
    assert_eq!(set.names(), &["temp", "pressure"]);
    assert_bits_equal(
        set.by_name("temp").unwrap(),
        &expected_f32("v2_sz3.temp.expected.f32"),
        "v2 temp",
    );
    assert_bits_equal(
        set.by_name("pressure").unwrap(),
        &expected_f32("v2_sz3.pressure.expected.f32"),
        "v2 pressure",
    );
    // set-level region decode agrees with the pinned outputs
    let region = Region::parse("0:6,4:8").unwrap();
    let parts = codec.decompress_set_region(&archive, &region).unwrap();
    for (name, t) in &parts {
        let want = expected_f32(&format!("v2_sz3.{name}.expected.f32"));
        let full = Tensor::new(vec![6, 8], want);
        assert_bits_equal(t, region.crop(&full).unwrap().data(), name);
    }
}

#[test]
fn v3_golden_decodes_unchanged_and_region_touches_less() {
    let archive = golden_archive("v3_sz3.ardc");
    assert_eq!(archive.version(), 3);
    let index = archive.block_index().unwrap().expect("v3 golden has index");
    assert_eq!(index.tile, vec![6, 4]);
    assert_eq!(index.entries.len(), 2);
    let codec = codec_for(&archive);
    let recon = codec.decompress(&archive).expect("decode v3 golden");
    let want = expected_f32("v3_sz3.expected.f32");
    assert_bits_equal(&recon, &want, "v3");
    // region covering only the second tile: identical to the crop and
    // touching only that tile's bytes
    let region = Region::parse("0:6,4:8").unwrap();
    let part = codec.decompress_region(&archive, &region).expect("v3 region");
    assert_bits_equal(&part, region.crop(&recon).unwrap().data(), "v3 region");
    let ids = attn_reduce::data::region_tile_ids(&[6, 8], &index.tile, &region);
    assert_eq!(ids, vec![1]);
    assert!(index.bytes_for(&ids) < index.total_bytes());
}

#[test]
fn v4_stream_golden_decodes_unchanged_across_chains() {
    use attn_reduce::stream::StreamReader;
    let reader = StreamReader::open(golden_path("v4_stream.ardc")).expect("open v4 golden");
    assert!(reader.is_finished(), "golden stream is sealed");
    assert_eq!(reader.n_steps(), 4);
    assert_eq!(reader.keyframe_interval(), 2);
    assert_eq!(reader.codec_id(), "sz3");
    let flags: Vec<bool> = reader.timeline().entries.iter().map(|e| e.keyframe).collect();
    assert_eq!(flags, vec![true, false, true, false]);
    let codec = reader
        .build_codec(&mut CodecBuilder::new())
        .expect("rebuild codec from golden stream");
    // every absolute frame — keyframes and residual-chain sums — decodes
    // to its pinned output bit-for-bit
    for step in 0..4 {
        let frame = reader.frame(&*codec, step).expect("decode golden step");
        assert_eq!(frame.shape(), &[6, 8]);
        assert_bits_equal(
            &frame,
            &expected_f32(&format!("v4_stream.step{step}.expected.f32")),
            &format!("v4 step {step}"),
        );
    }
    // region covering only the second tile: bit-identical to the crop,
    // touching only that tile's bytes in each chain archive
    let region = Region::parse("0:6,4:8").unwrap();
    let part = reader.extract(&*codec, 3, &region).expect("v4 region");
    let full = reader.frame(&*codec, 3).unwrap();
    assert_bits_equal(&part, region.crop(&full).unwrap().data(), "v4 region");
    let cost = reader.region_cost(3, &region).unwrap();
    assert_eq!(cost.steps, 2, "chain of step 3 is keyframe 2 + residual 3");
    assert_eq!(cost.blocks_touched, 2, "one tile per chain archive");
    assert_eq!(cost.blocks_total, 4);
    assert!(cost.bytes_touched < cost.bytes_total);
    // playback agrees with random access
    for (step, f) in reader.frames(&*codec).enumerate() {
        assert_bits_equal(
            &f.unwrap(),
            &expected_f32(&format!("v4_stream.step{step}.expected.f32")),
            &format!("v4 playback step {step}"),
        );
    }
}

#[test]
fn v3_adaptive_golden_decodes_unchanged_per_codec() {
    let archive = golden_archive("v3_adaptive.ardc");
    assert_eq!(archive.version(), 3);
    let index = archive.block_index().unwrap().expect("adaptive golden has index");
    assert_eq!(index.tile, vec![6, 4]);
    assert_eq!(
        index.codecs.as_deref(),
        Some(&[0u8, 1][..]),
        "sz3 tile 0, zfp tile 1"
    );
    let codec = codec_for(&archive);
    assert_eq!(codec.id(), "adaptive");
    let recon = codec.decompress(&archive).expect("decode adaptive golden");
    assert_eq!(recon.shape(), &[6, 8]);
    let want = expected_f32("v3_adaptive.expected.f32");
    assert_bits_equal(&recon, &want, "v3 adaptive");
    // each region decode dispatches on the recorded per-tile codec id:
    // the sz3 half, the zfp half, and a straddling region all match the
    // crop of the full decode bit-for-bit
    for spec in ["0:6,0:4", "0:6,4:8", "1:5,2:6"] {
        let region = Region::parse(spec).unwrap();
        let part = codec.decompress_region(&archive, &region).expect("adaptive region");
        assert_bits_equal(
            &part,
            region.crop(&recon).unwrap().data(),
            &format!("adaptive region {spec}"),
        );
    }
    // the zfp-only region touches only that tile's bytes
    let region = Region::parse("0:6,4:8").unwrap();
    let ids = attn_reduce::data::region_tile_ids(&[6, 8], &index.tile, &region);
    assert_eq!(ids, vec![1]);
    assert!(index.bytes_for(&ids) < index.total_bytes());
}

#[test]
fn v4_adaptive_stream_golden_decodes_unchanged() {
    use attn_reduce::stream::StreamReader;
    let reader =
        StreamReader::open(golden_path("v4_adaptive.ardc")).expect("open adaptive stream");
    assert!(reader.is_finished(), "golden stream is sealed");
    assert_eq!(reader.n_steps(), 2);
    assert_eq!(reader.codec_id(), "adaptive");
    let codec = reader
        .build_codec(&mut CodecBuilder::new())
        .expect("rebuild adaptive codec from stream");
    for step in 0..2 {
        let frame = reader.frame(&*codec, step).expect("decode adaptive step");
        assert_bits_equal(
            &frame,
            &expected_f32(&format!("v4_adaptive.step{step}.expected.f32")),
            &format!("v4 adaptive step {step}"),
        );
    }
    // region decode through the keyframe+residual chain dispatches per
    // tile in each chain archive (the codec assignment swaps between
    // the keyframe and the residual)
    let region = Region::parse("0:6,4:8").unwrap();
    let part = reader.extract(&*codec, 1, &region).expect("adaptive chain region");
    let full = reader.frame(&*codec, 1).unwrap();
    assert_bits_equal(&part, region.crop(&full).unwrap().data(), "v4 adaptive region");
}

#[test]
fn goldens_are_reparse_fixed_points() {
    // serializing a parsed golden reproduces its bytes exactly — the
    // container writer has not drifted either (v3_adaptive carries the
    // extended BIDX section, so its trailer bytes survive verbatim)
    for name in ["v1_sz3.ardc", "v2_sz3.ardc", "v3_sz3.ardc", "v3_adaptive.ardc"] {
        let bytes = std::fs::read(golden_path(name)).unwrap();
        let archive = Archive::from_bytes(&bytes).unwrap();
        assert_eq!(archive.to_bytes(), bytes, "{name} round-trip drifted");
    }
}
