//! Archive container robustness: byte-level round trips, corruption and
//! truncation always returning `Err` (never panicking), and
//! unknown-section tolerance for forward compatibility.

use attn_reduce::compressor::Archive;
use attn_reduce::util::json;

fn sample() -> Archive {
    let mut a = Archive::new(json::obj(vec![
        ("codec", json::s("sz3")),
        ("tau", json::num(0.5)),
        ("note", json::s("round-trip \"quoted\" + unicode é")),
    ]));
    a.add_section("HLAT", (0u16..700).flat_map(|v| v.to_le_bytes()).collect());
    a.add_section("GBAS", vec![9; 100]);
    a.add_section("GIDX", vec![]);
    a
}

#[test]
fn byte_round_trip_preserves_everything() {
    let a = sample();
    let bytes = a.to_bytes();
    assert_eq!(bytes.len(), a.total_bytes());
    let b = Archive::from_bytes(&bytes).unwrap();
    assert_eq!(b.header_str("codec").unwrap(), "sz3");
    assert_eq!(
        b.header_str("note").unwrap(),
        "round-trip \"quoted\" + unicode é"
    );
    assert_eq!(b.section("HLAT").unwrap(), a.section("HLAT").unwrap());
    assert_eq!(b.section("GBAS").unwrap().len(), 100);
    assert_eq!(b.section("GIDX").unwrap().len(), 0);
    // and the round trip is a fixed point
    assert_eq!(b.to_bytes(), bytes);
}

#[test]
fn unknown_sections_are_tolerated_and_preserved() {
    // a future writer adds sections this reader has never heard of
    let mut a = sample();
    a.add_section("XNEW", vec![1, 2, 3, 4, 5]);
    a.add_section("YNEW", vec![]);
    let b = Archive::from_bytes(&a.to_bytes()).unwrap();
    assert_eq!(b.section("XNEW").unwrap(), &[1, 2, 3, 4, 5]);
    assert!(b.has_section("YNEW"));
    // known sections still decode
    assert_eq!(b.section("HLAT").unwrap(), a.section("HLAT").unwrap());
    // and re-serializing keeps them
    let c = Archive::from_bytes(&b.to_bytes()).unwrap();
    assert!(c.has_section("XNEW"));
}

#[test]
fn every_truncation_errors_never_panics() {
    let bytes = sample().to_bytes();
    for cut in 0..bytes.len() {
        let r = Archive::from_bytes(&bytes[..cut]);
        assert!(r.is_err(), "prefix of {cut} bytes should not parse");
    }
}

#[test]
fn corrupted_fields_error_never_panic() {
    let good = sample().to_bytes();

    // bad magic
    let mut b = good.clone();
    b[0] = b'X';
    assert!(Archive::from_bytes(&b).is_err());

    // unsupported version
    let mut b = good.clone();
    b[4] = 0xFF;
    b[5] = 0xFF;
    assert!(Archive::from_bytes(&b).is_err());

    // header length pointing past the end
    let mut b = good.clone();
    b[6..10].copy_from_slice(&u32::MAX.to_le_bytes());
    assert!(Archive::from_bytes(&b).is_err());

    // absurd section count
    let hlen = u32::from_le_bytes(good[6..10].try_into().unwrap()) as usize;
    let mut b = good.clone();
    b[10 + hlen..10 + hlen + 4].copy_from_slice(&u32::MAX.to_le_bytes());
    assert!(Archive::from_bytes(&b).is_err());

    // section length overflowing the buffer
    let mut b = good.clone();
    let sec0 = 10 + hlen + 4; // first section header: tag + u64 len
    b[sec0 + 4..sec0 + 12].copy_from_slice(&u64::MAX.to_le_bytes());
    assert!(Archive::from_bytes(&b).is_err());

    // header JSON corrupted (turn a quote into garbage)
    let mut b = good.clone();
    b[10] = 0xFB; // invalid UTF-8 start byte inside the header
    assert!(Archive::from_bytes(&b).is_err());

    // empty input
    assert!(Archive::from_bytes(&[]).is_err());
}

#[test]
fn single_byte_flips_never_panic() {
    // not every flip must fail (payload bytes are opaque), but none may
    // panic; headers/framing flips must keep returning structured errors
    let good = sample().to_bytes();
    for i in 0..good.len() {
        let mut b = good.clone();
        b[i] ^= 0xA5;
        let _ = Archive::from_bytes(&b); // Err or Ok — just must not panic
    }
}
