//! In-tree property harness: randomized dims / blocking / field counts /
//! bounds across the codecs, asserting (1) round-trip through serialized
//! bytes, (2) the typed error bound holds on the reconstruction, and
//! (3) v3 region decode is bit-identical to full-decode-then-crop on
//! random regions.
//!
//! No external crates: cases come from `util::propgen` (seeded — CI pins
//! `ATTN_REDUCE_PROP_SEED`), and a failing case shrinks by halving its
//! dims until the failure disappears, panicking with the smallest
//! reproduction.
//!
//! `sz3` / `zfp` run everywhere with fully random geometry. `hier` /
//! `gbae` need the PJRT artifacts and trained checkpoints, so they run
//! on the smoke preset geometry and skip (like the other integration
//! tests) when `artifacts/manifest.json` is absent.

use std::rc::Rc;

use attn_reduce::codec::{Codec, CodecBuilder, CodecKind, ErrorBound};
use attn_reduce::compressor::Archive;
use attn_reduce::config::{dataset_preset, DatasetConfig, DatasetKind, Scale, TrainConfig};
use attn_reduce::data::Region;
use attn_reduce::runtime::Runtime;
use attn_reduce::tensor::Tensor;
use attn_reduce::util::propgen::{seed_from_env, shrink, CaseGen};

const DEFAULT_SEED: u64 = 20260730;

/// The four bound variants, sized to the field so every codec can
/// certify them (zfp is near-lossless, not lossless).
fn bounds_for(field: &Tensor, gae_len: usize) -> [ErrorBound; 4] {
    let range = field.range() as f64;
    [
        ErrorBound::Nrmse(1e-3),
        ErrorBound::L2Tau(1e-2 * range * (gae_len as f64).sqrt()),
        ErrorBound::PointwiseAbs(1e-3 * range),
        ErrorBound::None,
    ]
}

/// The bound with the same 1.0001 measurement slack the unit tests use:
/// ε/τ derivations round through f32, so a reconstruction can sit a few
/// ULPs past the exact bound without being a real violation.
fn relaxed(b: &ErrorBound) -> ErrorBound {
    const SLACK: f64 = 1.0 + 1e-4;
    match *b {
        ErrorBound::Nrmse(t) => ErrorBound::Nrmse(t * SLACK),
        ErrorBound::L2Tau(t) => ErrorBound::L2Tau(t * SLACK),
        ErrorBound::PointwiseAbs(a) => ErrorBound::PointwiseAbs(a * SLACK),
        ErrorBound::None => ErrorBound::None,
    }
}

/// One full property check. Returns a failure description instead of
/// panicking so the caller can shrink first.
fn check_case(
    codec: &dyn Codec,
    cfg: &DatasetConfig,
    field: &Tensor,
    bound: &ErrorBound,
    region: &Region,
) -> Result<(), String> {
    let archive = codec
        .compress(field, bound)
        .map_err(|e| format!("compress failed: {e:#}"))?;
    // round-trip through serialized bytes, like a real consumer
    let archive = Archive::from_bytes(&archive.to_bytes())
        .map_err(|e| format!("reparse failed: {e:#}"))?;
    let recon = codec
        .decompress(&archive)
        .map_err(|e| format!("decompress failed: {e:#}"))?;
    if recon.shape() != field.shape() {
        return Err(format!(
            "shape mismatch: {:?} != {:?}",
            recon.shape(),
            field.shape()
        ));
    }
    if !relaxed(bound).satisfied_by(field, &recon, cfg) {
        return Err(format!("bound {bound} violated by reconstruction"));
    }
    // region decode ≡ full decode + crop, bit for bit
    let via_region = codec
        .decompress_region(&archive, region)
        .map_err(|e| format!("region decompress failed: {e:#}"))?;
    let via_crop = region
        .crop(&recon)
        .map_err(|e| format!("crop failed: {e:#}"))?;
    if via_region.shape() != via_crop.shape() {
        return Err(format!(
            "region shape mismatch: {:?} != {:?}",
            via_region.shape(),
            via_crop.shape()
        ));
    }
    let identical = via_region
        .data()
        .iter()
        .zip(via_crop.data())
        .all(|(a, b)| a.to_bits() == b.to_bits());
    if !identical {
        return Err(format!("region {:?}:{:?} decode != cropped full decode", region.lo, region.hi));
    }
    Ok(())
}

/// Run `check_case`; on failure, shrink the geometry by halving dims
/// while the failure persists and panic with the smallest reproduction.
fn check_shrinking(
    make_codec: &dyn Fn(&DatasetConfig) -> Box<dyn Codec>,
    cg: &mut CaseGen,
    cfg: DatasetConfig,
    bound_idx: usize,
    label: &str,
    seed: u64,
    case: usize,
) {
    let field = cg.field(&cfg.dims);
    let bound = bounds_for(&field, cfg.gae_block_len())[bound_idx];
    let region = cg.region(&cfg.dims);
    let codec = make_codec(&cfg);
    let Err(mut failure) = check_case(&*codec, &cfg, &field, &bound, &region) else {
        return;
    };
    // shrink: halve dims until the failure disappears
    let mut smallest = cfg.clone();
    let mut cur = cfg;
    while let Some(candidate) = shrink(&cur) {
        let field = cg.field(&candidate.dims);
        let bound = bounds_for(&field, candidate.gae_block_len())[bound_idx];
        let region = cg.region(&candidate.dims);
        let codec = make_codec(&candidate);
        match check_case(&*codec, &candidate, &field, &bound, &region) {
            Err(e) => {
                failure = e;
                smallest = candidate.clone();
                cur = candidate;
            }
            Ok(()) => break,
        }
    }
    panic!(
        "property failure [{label}, seed {seed}, case {case}]: {failure}\n\
         smallest failing geometry: dims {:?}, ae_block {:?}, gae_block {:?}, bound #{bound_idx}",
        smallest.dims, smallest.ae_block, smallest.gae_block
    );
}

fn run_pure_codec(label: &str, make: impl Fn(&DatasetConfig) -> Box<dyn Codec>, cases: usize) {
    let seed = seed_from_env(DEFAULT_SEED);
    let mut cg = CaseGen::new(seed);
    for case in 0..cases {
        let cfg = cg.dataset();
        // every case cycles through all four ErrorBound variants
        check_shrinking(&make, &mut cg, cfg, case % 4, label, seed, case);
    }
}

#[test]
fn sz3_random_geometry_roundtrip_bound_and_region() {
    run_pure_codec(
        "sz3",
        |cfg| Box::new(attn_reduce::codec::Sz3Codec::new(cfg.clone())),
        12,
    );
}

#[test]
fn zfp_random_geometry_roundtrip_bound_and_region() {
    // fewer cases: each one runs the precision certification search
    run_pure_codec(
        "zfp",
        |cfg| Box::new(attn_reduce::codec::ZfpCodec::new(cfg.clone())),
        8,
    );
}

#[test]
fn adaptive_random_geometry_roundtrip_bound_and_region() {
    // fewer cases: every tile runs the per-tile zfp certification search
    run_pure_codec(
        "adaptive",
        |cfg| Box::new(attn_reduce::codec::AdaptiveCodec::new(cfg.clone())),
        8,
    );
}

/// Selection quality: the adaptive payload can never exceed either
/// forced-codec payload. Propgen tiles sit far below the sampling gate,
/// so the selector fully encodes both candidates per tile and the
/// per-tile min is *exact* — the inequality has no slack term. Forcing
/// sz3 everywhere must also reproduce the pure `Sz3Codec` tile payload
/// byte-for-byte (same ε, same tiling, same streams).
#[test]
fn adaptive_payload_never_exceeds_either_forced_codec() {
    use attn_reduce::codec::{with_tile_codec, AdaptiveCodec, TileCodec};
    let seed = seed_from_env(DEFAULT_SEED);
    let mut cg = CaseGen::new(seed ^ 0xADA7);
    for case in 0..6 {
        let cfg = cg.dataset();
        let field = cg.field(&cfg.dims);
        let bound = bounds_for(&field, cfg.gae_block_len())[case % 4];
        let codec = AdaptiveCodec::new(cfg.clone());
        let ctx = format!(
            "[adaptive-min, seed {seed}, case {case}, dims {:?}, bound {bound}]",
            cfg.dims
        );
        let auto = codec
            .compress(&field, &bound)
            .unwrap_or_else(|e| panic!("{ctx} auto: {e:#}"));
        let forced_sz3 = with_tile_codec(TileCodec::Sz3, || codec.compress(&field, &bound))
            .unwrap_or_else(|e| panic!("{ctx} forced sz3: {e:#}"));
        let forced_zfp = with_tile_codec(TileCodec::Zfp, || codec.compress(&field, &bound))
            .unwrap_or_else(|e| panic!("{ctx} forced zfp: {e:#}"));
        let (a, s, z) = (
            auto.cr_payload_bytes(),
            forced_sz3.cr_payload_bytes(),
            forced_zfp.cr_payload_bytes(),
        );
        assert!(a <= s.min(z), "{ctx} auto payload {a} > min(sz3 {s}, zfp {z})");
        // the forced archives round-trip under the bound too (forced zfp
        // degrades per tile to sz3 where zfp cannot certify ε)
        for (label, archive) in [("sz3", &forced_sz3), ("zfp", &forced_zfp)] {
            let parsed = Archive::from_bytes(&archive.to_bytes()).unwrap();
            let recon = codec.decompress(&parsed).unwrap();
            assert!(
                relaxed(&bound).satisfied_by(&field, &recon, &cfg),
                "{ctx} forced {label} violates the bound"
            );
        }
        let pure = attn_reduce::codec::Sz3Codec::new(cfg.clone())
            .compress(&field, &bound)
            .unwrap();
        assert_eq!(
            forced_sz3.section("ADPB").unwrap(),
            pure.section("SZ3B").unwrap(),
            "{ctx} forced-sz3 payload differs from Sz3Codec"
        );
    }
}

/// The forcing hooks (`with_symbol_mode`, `with_tile_codec`) are
/// thread-local, and the executor snapshots them at batch submission and
/// installs them on every participating worker — so a forced compress
/// must be byte-identical at every thread count, and two OS threads
/// forcing *different* codecs concurrently must each get exactly the
/// archive they would get alone.
#[test]
fn forcing_contexts_propagate_to_pool_workers() {
    use attn_reduce::codec::{with_tile_codec, AdaptiveCodec, TileCodec};
    use attn_reduce::coder::{with_symbol_mode, SymbolMode};
    use attn_reduce::util::parallel::with_thread_limit;
    let seed = seed_from_env(DEFAULT_SEED);
    let mut cg = CaseGen::new(seed ^ 0xF0CE);
    let cfg = cg.dataset();
    let field = cg.field(&cfg.dims);
    let bound = ErrorBound::PointwiseAbs(1e-3 * field.range() as f64);
    let codec = AdaptiveCodec::new(cfg.clone());
    let zfp_t1 = with_thread_limit(1, || {
        with_tile_codec(TileCodec::Zfp, || codec.compress(&field, &bound))
            .unwrap()
            .to_bytes()
    });
    let zfp_t4 = with_thread_limit(4, || {
        with_tile_codec(TileCodec::Zfp, || codec.compress(&field, &bound))
            .unwrap()
            .to_bytes()
    });
    assert_eq!(zfp_t1, zfp_t4, "tile-codec forcing lost on pool workers [seed {seed}]");
    let sz3 = attn_reduce::codec::Sz3Codec::new(cfg.clone());
    let zr_t1 = with_thread_limit(1, || {
        with_symbol_mode(SymbolMode::ZeroRun, || sz3.compress(&field, &bound))
            .unwrap()
            .to_bytes()
    });
    let zr_t4 = with_thread_limit(4, || {
        with_symbol_mode(SymbolMode::ZeroRun, || sz3.compress(&field, &bound))
            .unwrap()
            .to_bytes()
    });
    assert_eq!(zr_t1, zr_t4, "symbol-mode forcing lost on pool workers [seed {seed}]");
    let sz3_forced = with_tile_codec(TileCodec::Sz3, || codec.compress(&field, &bound))
        .unwrap()
        .to_bytes();
    std::thread::scope(|sc| {
        let ha = sc.spawn(|| {
            with_tile_codec(TileCodec::Sz3, || {
                AdaptiveCodec::new(cfg.clone()).compress(&field, &bound)
            })
            .unwrap()
            .to_bytes()
        });
        let hb = sc.spawn(|| {
            with_tile_codec(TileCodec::Zfp, || {
                AdaptiveCodec::new(cfg.clone()).compress(&field, &bound)
            })
            .unwrap()
            .to_bytes()
        });
        assert_eq!(
            ha.join().unwrap(),
            sz3_forced,
            "concurrent sz3 forcing saw the other thread's codec [seed {seed}]"
        );
        assert_eq!(
            hb.join().unwrap(),
            zfp_t1,
            "concurrent zfp forcing saw the other thread's codec [seed {seed}]"
        );
    });
}

/// Multi-field property: random field counts packed into one v2
/// container, round-tripped per field, with set-level region decode
/// matching per-field crops.
#[test]
fn fieldset_random_field_counts_roundtrip_and_region() {
    use attn_reduce::engine::{CodecExt, FieldSet};
    let seed = seed_from_env(DEFAULT_SEED);
    let mut cg = CaseGen::new(seed ^ 0xF1E1D);
    for case in 0..4 {
        let cfg = cg.dataset();
        let n_fields = 1 + (case % 3);
        let mut set = FieldSet::new(cfg.clone());
        for f in 0..n_fields {
            set.push(format!("v{f}"), cg.field(&cfg.dims)).unwrap();
        }
        let codec = attn_reduce::codec::Sz3Codec::new(cfg.clone());
        let bound = ErrorBound::Nrmse(1e-3);
        let archive = codec.compress_set(&set, &bound).unwrap();
        let archive = Archive::from_bytes(&archive.to_bytes()).unwrap();
        let back = codec.decompress_set(&archive).unwrap();
        assert_eq!(back.names(), set.names(), "case {case}");
        let region = cg.region(&cfg.dims);
        let parts = codec.decompress_set_region(&archive, &region).unwrap();
        for (i, (name, t)) in parts.iter().enumerate() {
            assert_eq!(name, &set.names()[i]);
            assert!(relaxed(&bound).satisfied_by(set.field(i), back.field(i), &cfg));
            let cropped = region.crop(back.field(i)).unwrap();
            assert_eq!(t.data(), cropped.data(), "case {case} field {i}");
        }
    }
}

/// Entropy-mode property: forcing the zero-run or rANS symbol container
/// must be bit-equivalent to plain end to end — same reconstructions out
/// of all archives, across random geometry and all four bounds, for both
/// pure-rust codecs. (`with_symbol_mode` is thread-local; the executor
/// now propagates it to pool workers per batch, so the
/// `with_thread_limit(1)` here is just a fixed configuration, not a
/// correctness requirement — `forcing_contexts_propagate_to_pool_workers`
/// pins the multi-thread case. A forced mode degrades per stream when a
/// tile is ineligible — e.g. rANS on an over-wide alphabet — which is
/// exactly the production behavior this pins.)
#[test]
fn entropy_mode_forcing_is_bit_equivalent_end_to_end() {
    use attn_reduce::coder::{with_symbol_mode, SymbolMode};
    use attn_reduce::util::parallel::with_thread_limit;
    let seed = seed_from_env(DEFAULT_SEED);
    with_thread_limit(1, || {
        let mut cg = CaseGen::new(seed ^ 0x2E80);
        for case in 0..4 {
            let cfg = cg.dataset();
            let field = cg.field(&cfg.dims);
            let bound = bounds_for(&field, cfg.gae_block_len())[case % 4];
            let codecs: [(&str, Box<dyn Codec>); 2] = [
                ("sz3", Box::new(attn_reduce::codec::Sz3Codec::new(cfg.clone()))),
                ("zfp", Box::new(attn_reduce::codec::ZfpCodec::new(cfg.clone()))),
            ];
            for (label, codec) in &codecs {
                // zfp runs its certification search per compress; keep
                // its legs to the cheap bounds
                if *label == "zfp" && !matches!(bound, ErrorBound::None) && case != 1 {
                    continue;
                }
                let ctx = format!("[entropy-mode {label}, seed {seed}, case {case}]");
                let plain = with_symbol_mode(SymbolMode::Plain, || codec.compress(&field, &bound));
                let plain = plain.unwrap_or_else(|e| panic!("{ctx} plain: {e:#}"));
                let plain_parsed = Archive::from_bytes(&plain.to_bytes()).unwrap();
                let d_plain = codec.decompress(&plain_parsed).unwrap();
                for (mname, mode) in
                    [("zero-run", SymbolMode::ZeroRun), ("rans", SymbolMode::Rans)]
                {
                    let forced = with_symbol_mode(mode, || codec.compress(&field, &bound));
                    let forced = forced.unwrap_or_else(|e| panic!("{ctx} {mname}: {e:#}"));
                    let forced_parsed = Archive::from_bytes(&forced.to_bytes()).unwrap();
                    let d_forced = codec.decompress(&forced_parsed).unwrap();
                    let identical = d_plain
                        .data()
                        .iter()
                        .zip(d_forced.data())
                        .all(|(a, b)| a.to_bits() == b.to_bits());
                    assert!(
                        identical,
                        "{ctx} {mname} decode differs from plain (dims {:?}, bound {bound})",
                        cfg.dims
                    );
                }
                // auto selection also reconstructs identically, and never
                // regresses the payload beyond estimate noise
                let auto = codec.compress(&field, &bound).unwrap();
                let d_auto = codec.decompress(&auto).unwrap();
                let auto_identical = d_auto
                    .data()
                    .iter()
                    .zip(d_plain.data())
                    .all(|(a, b)| a.to_bits() == b.to_bits());
                assert!(auto_identical, "{ctx} auto decode differs");
                let auto_payload = auto.cr_payload_bytes();
                let plain_payload = plain.cr_payload_bytes();
                assert!(
                    auto_payload as f64 <= plain_payload as f64 * 1.25,
                    "{ctx} auto payload {auto_payload} regressed past plain {plain_payload}"
                );
            }
        }
    });
}

// --- temporal streams: keyframe/residual coding over random geometry ---

/// With K = 1 every step is a keyframe, and a stream must degenerate to
/// independent per-step archives *exactly*: step archives byte-identical
/// to `Codec::compress` of the same frame, and stream reads bit-identical
/// to independent decompression.
#[test]
fn stream_k1_is_bit_identical_to_independent_compression() {
    use attn_reduce::stream::{StreamReader, StreamWriter};
    let seed = seed_from_env(DEFAULT_SEED);
    let mut cg = CaseGen::new(seed ^ 0x57AE);
    let dir = std::env::temp_dir().join("attn_reduce_prop_stream");
    std::fs::create_dir_all(&dir).unwrap();
    for case in 0..4 {
        let cfg = cg.dataset();
        let codec = attn_reduce::codec::Sz3Codec::new(cfg.clone());
        let frames: Vec<Tensor> = (0..3).map(|_| cg.field(&cfg.dims)).collect();
        let bound = bounds_for(&frames[0], cfg.gae_block_len())[case % 4];
        let path = dir.join(format!("k1_{seed}_{case}.tstr"));
        let mut w = StreamWriter::create(&path, codec.id(), cfg.clone(), bound, 1)
            .unwrap_or_else(|e| panic!("[stream-k1, seed {seed}, case {case}] create: {e:#}"));
        for f in &frames {
            w.append(&codec, f)
                .unwrap_or_else(|e| panic!("[stream-k1, seed {seed}, case {case}] append: {e:#}"));
        }
        w.finish().unwrap();
        let reader = StreamReader::open(&path).unwrap();
        assert_eq!(reader.n_steps(), 3);
        for (t, frame) in frames.iter().enumerate() {
            let independent = codec.compress(frame, &bound).unwrap();
            let step = reader.step_archive(t).unwrap();
            assert_eq!(
                step.to_bytes(),
                independent.to_bytes(),
                "[stream-k1, seed {seed}, case {case}] step {t} archive differs \
                 from independent compression (dims {:?})",
                cfg.dims
            );
            let via_stream = reader.frame(&codec, t).unwrap();
            let via_codec = codec.decompress(&independent).unwrap();
            let identical = via_stream
                .data()
                .iter()
                .zip(via_codec.data())
                .all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(
                identical,
                "[stream-k1, seed {seed}, case {case}] step {t} decode differs"
            );
        }
    }
}

/// Residual chains must satisfy all four `ErrorBound` variants on every
/// *absolute* reconstructed frame, and `(step, region)` extraction must
/// equal the cropped full decode bit-for-bit on random regions.
#[test]
fn stream_residual_chains_respect_all_bounds_and_regions() {
    use attn_reduce::data::timeseries;
    use attn_reduce::stream::{StreamReader, StreamWriter};
    let seed = seed_from_env(DEFAULT_SEED);
    let mut cg = CaseGen::new(seed ^ 0xD1FF);
    let dir = std::env::temp_dir().join("attn_reduce_prop_stream");
    std::fs::create_dir_all(&dir).unwrap();
    for case in 0..4 {
        let cfg = cg.dataset();
        let codec = attn_reduce::codec::Sz3Codec::new(cfg.clone());
        // smoothly-evolving frames so residuals carry real structure
        let frames = timeseries::generate_frames(&cfg.dims, cfg.seed, 0, 5);
        let bound = bounds_for(&frames[0], cfg.gae_block_len())[case % 4];
        let path = dir.join(format!("chain_{seed}_{case}.tstr"));
        let mut w = StreamWriter::create(&path, codec.id(), cfg.clone(), bound, 3)
            .unwrap_or_else(|e| panic!("[stream-chain, seed {seed}, case {case}] create: {e:#}"));
        w.append_frames(&codec, &frames)
            .unwrap_or_else(|e| panic!("[stream-chain, seed {seed}, case {case}] append: {e:#}"));
        w.finish().unwrap();
        let reader = StreamReader::open(&path).unwrap();
        for (t, orig) in frames.iter().enumerate() {
            let recon = reader.frame(&codec, t).unwrap();
            assert!(
                relaxed(&bound).satisfied_by(orig, &recon, &cfg),
                "[stream-chain, seed {seed}, case {case}] bound {bound} violated \
                 at step {t} (dims {:?}, ae_block {:?})",
                cfg.dims,
                cfg.ae_block
            );
            let region = cg.region(&cfg.dims);
            let part = reader.extract(&codec, t, &region).unwrap();
            let crop = region.crop(&recon).unwrap();
            let identical = part
                .data()
                .iter()
                .zip(crop.data())
                .all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(
                identical,
                "[stream-chain, seed {seed}, case {case}] step {t} region \
                 {:?}:{:?} != cropped decode",
                region.lo,
                region.hi
            );
        }
    }
}

// --- learned codecs: preset geometry, gated on the PJRT artifacts ------

fn runtime() -> Option<Rc<Runtime>> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if !std::path::Path::new(dir).join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    std::env::set_var("ATTN_REDUCE_QUIET", "1");
    Some(Rc::new(Runtime::open(dir).expect("open artifacts")))
}

fn run_learned_codec(kind: CodecKind, label: &str) {
    let Some(rt) = runtime() else { return };
    let seed = seed_from_env(DEFAULT_SEED);
    let mut cg = CaseGen::new(seed ^ 0xAE);
    let cfg = dataset_preset(DatasetKind::E3sm, Scale::Smoke);
    let ckpt = std::env::temp_dir().join(format!("attn_reduce_prop_{label}"));
    std::fs::create_dir_all(&ckpt).unwrap();
    let mut b = CodecBuilder::new()
        .runtime(rt)
        .ckpt_dir(&ckpt)
        .scale(Scale::Smoke)
        .train(TrainConfig { steps: 40, ..TrainConfig::default() });
    let field = attn_reduce::data::generate(&cfg);
    let codec = b.build(kind, DatasetKind::E3sm, &field).expect("build codec");
    for (case, bound) in bounds_for(&field, cfg.gae_block_len()).iter().enumerate() {
        if matches!(bound, ErrorBound::None) {
            continue; // learned codecs quantize; None gives no guarantee to check
        }
        let region = cg.region(&cfg.dims);
        if let Err(e) = check_case(&*codec, &cfg, &field, bound, &region) {
            panic!("property failure [{label}, seed {seed}, case {case}]: {e}");
        }
    }
}

#[test]
fn hier_preset_geometry_roundtrip_bound_and_region() {
    run_learned_codec(CodecKind::Hier, "hier");
}

#[test]
fn gbae_preset_geometry_roundtrip_bound_and_region() {
    run_learned_codec(CodecKind::Gbae, "gbae");
}
