//! Region-of-interest decode acceptance: on a v3 block-indexed archive,
//! `decompress_region` over a region covering <10% of the blocks is
//! bit-identical to cropping a full decode while touching <25% of the
//! payload bytes — and v1 whole-stream archives transparently fall back
//! to full decode + crop through the same API.

use attn_reduce::baselines::Sz3Like;
use attn_reduce::codec::{Codec, CodecBuilder, ErrorBound, Sz3Codec, ZfpCodec};
use attn_reduce::compressor::Archive;
use attn_reduce::config::{dataset_preset, DatasetConfig, DatasetKind, Scale};
use attn_reduce::data::{self, region_tile_ids, Region};
use attn_reduce::tensor::Tensor;
use attn_reduce::util::json;

fn assert_bit_identical(a: &Tensor, b: &Tensor, what: &str) {
    assert_eq!(a.shape(), b.shape(), "{what}: shape");
    for (i, (x, y)) in a.data().iter().zip(b.data()).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: value {i}: {x} vs {y}");
    }
}

/// The acceptance contract, checked for one codec on one geometry.
fn check_acceptance(
    codec: &dyn Codec,
    cfg: &DatasetConfig,
    field: &Tensor,
    bound: &ErrorBound,
    region: &Region,
) {
    let archive = codec.compress(field, bound).expect("compress");
    let archive = Archive::from_bytes(&archive.to_bytes()).expect("reparse");
    assert_eq!(archive.version(), 3, "pure codecs write v3");

    let full = codec.decompress(&archive).expect("full decode");
    let part = codec.decompress_region(&archive, region).expect("region decode");
    assert_bit_identical(&part, &region.crop(&full).unwrap(), "region vs crop");

    // the region covers <10% of the blocks and touches <25% of payload
    let index = archive.block_index().unwrap().expect("v3 index");
    let ids = region_tile_ids(&cfg.dims, &index.tile, region);
    let n_blocks = index.entries.len();
    assert!(
        ids.len() * 10 < n_blocks,
        "test region must cover <10% of blocks ({} of {n_blocks})",
        ids.len()
    );
    let touched = index.bytes_for(&ids);
    let payload = index.total_bytes();
    assert!(
        touched * 4 < payload,
        "region touched {touched} of {payload} payload bytes (>= 25%)"
    );

    // the decode restored from the header alone agrees too
    let rebuilt = CodecBuilder::new().for_archive(&archive).expect("for_archive");
    let part2 = rebuilt.decompress_region(&archive, region).expect("region via header");
    assert_bit_identical(&part2, &part, "header-rebuilt codec");
}

#[test]
fn sz3_region_decode_is_cheap_and_exact() {
    // s3d smoke: 1 x 2 x 4 x 4 = 32 tiles; the region intersects 1 (3.1%)
    let cfg = dataset_preset(DatasetKind::S3d, Scale::Smoke);
    let field = data::generate(&cfg);
    let region = Region::parse("0:16,1:5,2:4,0:3").unwrap();
    check_acceptance(
        &Sz3Codec::new(cfg.clone()),
        &cfg,
        &field,
        &ErrorBound::Nrmse(1e-3),
        &region,
    );
}

#[test]
fn zfp_region_decode_is_cheap_and_exact() {
    // e3sm bench geometry at smoke scale has only 16 tiles (6.25% each),
    // so use the bench dims tiling on a synthetic field: 20 x 6 x 12 =
    // 1440 tiles, region covers 2 x 1 x 2 = 4 of them (0.3%)
    let cfg = dataset_preset(DatasetKind::E3sm, Scale::Bench);
    let field = data::generate(&cfg);
    let region = Region::parse("3:12,0:10,16:48").unwrap();
    check_acceptance(
        &ZfpCodec::new(cfg.clone()),
        &cfg,
        &field,
        &ErrorBound::None,
        &region,
    );
}

#[test]
fn unaligned_regions_spanning_tile_borders_match_crop() {
    let cfg = dataset_preset(DatasetKind::E3sm, Scale::Smoke);
    let field = data::generate(&cfg);
    let codec = Sz3Codec::new(cfg.clone());
    let archive = codec.compress(&field, &ErrorBound::PointwiseAbs(1e-3)).unwrap();
    let full = codec.decompress(&archive).unwrap();
    for spec in ["0:24,0:32,0:32", "5:19,7:25,15:17", "23:24,31:32,0:1", "0:1,0:1,0:1"] {
        let region = Region::parse(spec).unwrap();
        let part = codec.decompress_region(&archive, &region).unwrap();
        assert_bit_identical(&part, &region.crop(&full).unwrap(), spec);
    }
    // out-of-bounds / wrong-rank regions are typed errors
    assert!(codec
        .decompress_region(&archive, &Region::parse("0:25,0:32,0:32").unwrap())
        .is_err());
    assert!(codec
        .decompress_region(&archive, &Region::parse("0:8,0:8").unwrap())
        .is_err());
}

#[test]
fn v1_whole_stream_archives_fall_back_to_full_decode_plus_crop() {
    // a legacy v1 archive exactly as the pre-index sz3 codec wrote it:
    // one whole-field stream, no BIDX section
    let cfg = dataset_preset(DatasetKind::E3sm, Scale::Smoke);
    let field = data::generate(&cfg);
    let bound = ErrorBound::Nrmse(1e-3);
    let eps = bound.pointwise_eps(&cfg, field.range() as f64);
    let mut archive = Archive::new(json::obj(vec![
        ("codec", json::s("sz3")),
        ("bound", bound.to_json()),
        ("dataset", cfg.to_json()),
        ("eps", json::num(eps as f64)),
    ]));
    archive.add_section("SZ3B", Sz3Like::new(eps).compress(&field).unwrap());
    let archive = Archive::from_bytes(&archive.to_bytes()).unwrap();
    assert_eq!(archive.version(), 1);
    assert!(archive.block_index().unwrap().is_none());

    let codec = CodecBuilder::new().for_archive(&archive).unwrap();
    let full = codec.decompress(&archive).unwrap();
    let region = Region::parse("2:9,8:24,16:32").unwrap();
    let part = codec.decompress_region(&archive, &region).unwrap();
    assert_bit_identical(&part, &region.crop(&full).unwrap(), "v1 fallback");
}
