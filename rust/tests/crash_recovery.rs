//! Crash-recovery and fault-injection suite: the durability layer's
//! contract, proven byte-by-byte.
//!
//! Three attack surfaces:
//!
//! 1. **Atomic saves** — `Archive::save` (and every other
//!    `durable::write_atomic` caller, including `POST /v1/compress`)
//!    swept with torn writes, fsync refusals and rename refusals: a
//!    final filename must always hold complete bytes (the previous
//!    version, or nothing for a first write) and no temp sibling may
//!    be left behind.
//! 2. **Kill -9 mid-append** — a real `stream append` CLI run is shot
//!    dead by an `ATTN_FAILPOINT=stream.write=after:N:exit:42` budget
//!    inherited through the environment. The torn stream must reopen
//!    via the reader's recovery scan, green up under
//!    `cli verify --repair`, and accept further appends that seal.
//! 3. **`cli verify` exit codes** — 0 on a clean tree, non-zero while
//!    damage exists (even after a quarantine, which is data loss),
//!    0 again once the tree holds only clean + repaired files.
//!
//! Failpoint state is process-global, so every test here serializes
//! through one file-local lock — an armed hook must never bleed into
//! another test's writes.

use std::path::PathBuf;
use std::process::Command;
use std::sync::Mutex;

use attn_reduce::compressor::Archive;
use attn_reduce::stream::StreamReader;
use attn_reduce::util::durable::{FP_DIR_FSYNC, FP_FSYNC, FP_RENAME, FP_WRITE};
use attn_reduce::util::{failpoint, json};
use attn_reduce::verify;

static FP_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    FP_LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_attn-reduce"))
}

fn tmp_root(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("attn_crash_{name}"));
    std::fs::remove_dir_all(&d).ok();
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn small_archive() -> Archive {
    let mut a = Archive::new(json::obj(vec![("codec", json::s("sz3"))]));
    a.add_section("SZ3B", (0u16..600).flat_map(u16::to_le_bytes).collect());
    a
}

#[test]
fn injected_save_failures_never_tear_or_litter() {
    let _g = lock();
    failpoint::disarm_all();
    let d = tmp_root("save_sweep");
    let p = d.join("field.ardc");
    let a = small_archive();
    a.save(&p).unwrap();
    let committed = std::fs::read(&p).unwrap();
    let total = committed.len();

    // torn writes across the file: the final name keeps the previous
    // complete bytes and the temp sibling is cleaned up, whether the
    // tear lands in the header, a section payload, or the XSUM trailer
    for n in [0, 1, 7, total / 4, total / 2, total - 1] {
        failpoint::arm(FP_WRITE, &format!("after:{n}")).unwrap();
        let err = a.save(&p).unwrap_err();
        failpoint::disarm_all();
        assert!(err.to_string().contains("writing"), "budget {n}: {err:#}");
        assert_eq!(std::fs::read(&p).unwrap(), committed, "budget {n}: final name torn");
        assert_eq!(std::fs::read_dir(&d).unwrap().count(), 1, "budget {n}: temp litter");
    }

    // fsync / rename refusals: same contract
    for fp in [FP_FSYNC, FP_RENAME] {
        failpoint::arm(fp, "error").unwrap();
        assert!(a.save(&p).is_err(), "{fp} must surface");
        failpoint::disarm_all();
        assert_eq!(std::fs::read(&p).unwrap(), committed, "{fp}: final name torn");
        assert_eq!(std::fs::read_dir(&d).unwrap().count(), 1, "{fp}: temp litter");
    }

    // a first-time save that fails must leave the name absent, not a stub
    let q = d.join("new.ardc");
    failpoint::arm(FP_RENAME, "error").unwrap();
    assert!(a.save(&q).is_err());
    failpoint::disarm_all();
    assert!(!q.exists(), "failed first save must not create the file");

    // dir-fsync failure fires after the rename: the new bytes are
    // already complete under the final name; the caller only learns the
    // rename may not yet be durable
    let mut b = small_archive();
    b.add_section("EXTR", vec![9; 64]);
    failpoint::arm(FP_DIR_FSYNC, "error").unwrap();
    assert!(b.save(&p).is_err());
    failpoint::disarm_all();
    let now = std::fs::read(&p).unwrap();
    assert_ne!(now, committed, "dir-fsync failure happens post-rename");
    assert!(
        Archive::from_bytes(&now).is_ok_and(|a| a.checksummed()),
        "post-rename bytes are a complete checked archive"
    );

    // after the whole gauntlet, fsck agrees the tree is clean
    let rep = verify::verify_root(&d, false).unwrap();
    assert!(rep.all_ok(), "{rep:?}");
    std::fs::remove_dir_all(&d).ok();
}

#[test]
fn kill_nine_mid_append_leaves_a_recoverable_stream() {
    let _g = lock();
    let d = tmp_root("kill9");
    let p = d.join("run.tstr");
    let clean = d.join("clean.tstr");
    let create = [
        "stream", "append", "--codec", "sz3", "--bound", "nrmse:1e-3", "--dataset", "e3sm",
        "--scale", "smoke", "--keyint", "3", "--steps", "6", "--out",
    ];

    // dry run with identical parameters to learn the sealed size — the
    // synthesized frames are closed-form in (seed, step), so the byte
    // budget transfers exactly to the second run
    let out = bin().args(create).arg(&clean).output().unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let sealed_len = std::fs::metadata(&clean).unwrap().len();
    std::fs::remove_file(&clean).unwrap();

    // same run, process killed without unwinding halfway through its bytes
    let out = bin()
        .args(create)
        .arg(&p)
        .env("ATTN_FAILPOINT", format!("stream.write=after:{}:exit:42", sealed_len / 2))
        .output()
        .unwrap();
    assert_eq!(out.status.code(), Some(42), "{}", String::from_utf8_lossy(&out.stderr));
    let torn_len = std::fs::metadata(&p).unwrap().len();
    assert!(torn_len < sealed_len, "crash really tore the file ({torn_len}/{sealed_len})");

    // recovery scan: the torn file opens and serves every complete step
    let r = StreamReader::open(&p).unwrap();
    let recovered = r.n_steps();
    assert!((1..6).contains(&recovered), "recovered {recovered} of 6 steps");
    assert!(!r.is_finished(), "a crashed run can never look sealed");
    drop(r);

    // fsck sees a torn tail (or a clean unsealed stream when the cut
    // happened to land on a record boundary), never corruption, and
    // --repair greens the tree either way
    let rep = verify::verify_root(&d, true).unwrap();
    assert_eq!(rep.corrupt, 0, "a kill -9 tears, it must not corrupt: {rep:?}");
    assert!(rep.all_ok(), "repair must green the tree: {rep:?}");

    // appending to the repaired file continues the chain and seals
    let out = bin().args(["stream", "append", "--steps", "4", "--out"]).arg(&p).output().unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let r = StreamReader::open(&p).unwrap();
    assert!(r.is_finished(), "resumed stream seals normally");
    assert_eq!(r.n_steps(), recovered + 4, "append continued at the recovered step");
    let mut builder = attn_reduce::codec::CodecBuilder::new();
    let c = r.build_codec(&mut builder).unwrap();
    let t = r.frame(&*c, r.n_steps() - 1).unwrap();
    assert_eq!(t.shape(), r.dataset().dims.as_slice(), "post-crash steps decode");
    std::fs::remove_dir_all(&d).ok();
}

#[test]
fn cli_verify_exit_codes_and_repair_flow() {
    let _g = lock();
    let d = tmp_root("fsck_cli");
    let s = d.join("run.tstr");
    let out = bin()
        .args([
            "stream", "append", "--codec", "sz3", "--bound", "nrmse:1e-3", "--dataset", "e3sm",
            "--scale", "smoke", "--keyint", "2", "--steps", "4", "--out",
        ])
        .arg(&s)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let a = d.join("field.ardc");
    small_archive().save(&a).unwrap();

    // clean tree → exit 0
    let out = bin().args(["verify", "--root"]).arg(&d).output().unwrap();
    assert!(out.status.success(), "clean tree: {}", String::from_utf8_lossy(&out.stdout));

    // damage both: tear the sealed stream mid-final-record, flip one
    // payload byte in the checked archive
    let bytes = std::fs::read(&s).unwrap();
    let last = *StreamReader::from_bytes(bytes.clone()).unwrap().timeline().entries.last().unwrap();
    let cut = (last.offset + last.len / 2) as usize;
    std::fs::write(&s, &bytes[..cut]).unwrap();
    let mut ab = std::fs::read(&a).unwrap();
    let mid = ab.len() / 2;
    ab[mid] ^= 0x20;
    std::fs::write(&a, &ab).unwrap();

    // read-only verify: non-zero exit, both files called out, nothing touched
    let out = bin().args(["verify", "--root"]).arg(&d).output().unwrap();
    assert!(!out.status.success(), "damaged tree must fail");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("TORN"), "{stdout}");
    assert!(stdout.contains("CORRUPT"), "{stdout}");
    assert_eq!(std::fs::read(&s).unwrap().len(), cut, "read-only mode must not modify files");
    assert!(a.exists(), "read-only mode must not quarantine");

    // --repair: torn stream truncated back to its complete prefix, the
    // unrecoverable archive quarantined — which is data loss, so the
    // exit code still reports damage
    let out = bin().args(["verify", "--repair", "--root"]).arg(&d).output().unwrap();
    assert!(!out.status.success(), "quarantine still reports damage");
    assert!(d.join("field.ardc.quarantine").exists(), "archive moved aside");
    assert!(!a.exists());
    let r = StreamReader::open(&s).unwrap();
    assert!(!r.is_finished(), "repair leaves an unsealed, appendable stream");
    assert_eq!(r.n_steps(), 3, "torn step dropped, complete steps kept");
    drop(r);

    // second pass: repaired stream is clean, the quarantined file is
    // skipped — the tree is green again
    let out = bin().args(["verify", "--root"]).arg(&d).output().unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stdout));
    std::fs::remove_dir_all(&d).ok();
}
