//! Baseline compressors on real synthetic fields: error-bound / rate
//! behaviour that Fig. 6 depends on.

use std::rc::Rc;

use attn_reduce::baselines::{GbaeCompressor, Sz3Like, ZfpLike};
use attn_reduce::compressor::nrmse;
use attn_reduce::config::{dataset_preset, DatasetKind, Scale, TrainConfig};
use attn_reduce::data;
use attn_reduce::runtime::Runtime;

fn runtime() -> Option<Rc<Runtime>> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if !std::path::Path::new(dir).join("manifest.json").exists() {
        return None;
    }
    std::env::set_var("ATTN_REDUCE_QUIET", "1");
    Some(Rc::new(Runtime::open(dir).expect("open artifacts")))
}

#[test]
fn sz3_like_bound_and_monotone_rate_on_all_datasets() {
    for kind in [DatasetKind::S3d, DatasetKind::E3sm, DatasetKind::Xgc] {
        let cfg = dataset_preset(kind, Scale::Smoke);
        let field = data::generate(&cfg);
        let range = field.range();
        let mut last_bytes = usize::MAX;
        for rel_eps in [1e-2f32, 1e-3, 1e-4] {
            let eps = rel_eps * range;
            let bytes = Sz3Like::new(eps).compress(&field).unwrap();
            let back = Sz3Like::decompress(&bytes).unwrap();
            let max_err = field
                .data()
                .iter()
                .zip(back.data())
                .map(|(&a, &b)| (a - b).abs())
                .fold(0f32, f32::max);
            assert!(max_err <= eps * 1.0001, "{kind:?} eps={eps}: {max_err}");
            assert!(
                bytes.len() >= last_bytes.min(bytes.len()),
                "rate should grow as eps shrinks"
            );
            last_bytes = bytes.len();
        }
    }
}

#[test]
fn zfp_like_rate_distortion_on_e3sm() {
    let cfg = dataset_preset(DatasetKind::E3sm, Scale::Smoke);
    let field = data::generate(&cfg);
    let mut last_err = f64::INFINITY;
    let mut last_bytes = 0usize;
    for p in [6u32, 12, 20] {
        let bytes = ZfpLike::new(p).compress(&field).unwrap();
        let back = ZfpLike::decompress(&bytes).unwrap();
        let e = nrmse(&field, &back);
        assert!(e < last_err, "p={p}: {e} !< {last_err}");
        assert!(bytes.len() > last_bytes);
        last_err = e;
        last_bytes = bytes.len();
    }
    assert!(last_err < 1e-4, "high precision should be accurate: {last_err}");
}

#[test]
fn gbae_baseline_trains_and_bounds() {
    let Some(rt) = runtime() else { return };
    let cfg = dataset_preset(DatasetKind::S3d, Scale::Smoke);
    let field = data::generate(&cfg);
    let train = TrainConfig { steps: 20, log_every: 1000, ..TrainConfig::default() };
    let ckpt = std::env::temp_dir().join("attn_reduce_gbae_test");
    std::fs::create_dir_all(&ckpt).unwrap();
    let (gbae, reports) = GbaeCompressor::prepare(
        &rt,
        &cfg,
        "s3d_bae_L16",
        &ckpt,
        &field,
        &train,
        None,
    )
    .unwrap();
    for r in &reports {
        assert!(r.final_loss < r.losses[0].1);
    }
    // without GAE: lossy recon, some payload
    let res = gbae.compress(&field, 0.0, 0.0).unwrap();
    assert_eq!(res.recon.shape(), field.shape());
    let e0 = nrmse(&field, &res.recon);
    assert!(e0 > 0.0 && e0 < 0.5, "plausible AE error: {e0}");

    // with GAE at a bound: error drops below the bound-implied NRMSE
    let tau = attn_reduce::config::PipelineConfig::tau_for_nrmse(
        2e-3,
        field.range() as f64,
        cfg.gae_block_len(),
    );
    let res2 = gbae.compress(&field, 0.0, tau).unwrap();
    let e = nrmse(&field, &res2.recon);
    assert!(e <= 2e-3 * 1.01, "GAE-bounded NRMSE {e}");
    assert!(res2.payload_bytes > res.payload_bytes);
    assert!(res2.gae_coeffs > 0);
}

#[test]
fn hier_beats_gbae_at_matched_payload_shape() {
    // the paper's central claim at ablation level: hierarchical (HBAE+BAE)
    // reaches lower NRMSE than the block-AE baseline at comparable payload.
    // At smoke scale + few steps we only assert the qualitative ordering
    // of AE reconstruction error with the same latent budget per block.
    let Some(rt) = runtime() else { return };
    let cfg = dataset_preset(DatasetKind::Xgc, Scale::Smoke);
    let field = data::generate(&cfg);
    let train = TrainConfig { steps: 30, log_every: 1000, ..TrainConfig::default() };

    let ckpt = std::env::temp_dir().join("attn_reduce_cmp_test");
    std::fs::create_dir_all(&ckpt).unwrap();

    let pcfg = attn_reduce::config::PipelineConfig {
        dataset: cfg.clone(),
        model: attn_reduce::config::model_preset(DatasetKind::Xgc),
        train: train.clone(),
        tau: 0.0,
    };
    let (hier, _) =
        attn_reduce::compressor::HierCompressor::prepare(&rt, &pcfg, &ckpt, &field).unwrap();
    let (_, hier_recon) = hier.compress(&field, 0.0).unwrap();
    let e_hier = nrmse(&field, &hier_recon);

    let (gbae, _) = GbaeCompressor::prepare(
        &rt, &cfg, "xgc_bae_L16", &ckpt, &field, &train, None,
    )
    .unwrap();
    let res = gbae.compress(&field, 0.0, 0.0).unwrap();
    let e_gbae = nrmse(&field, &res.recon);

    eprintln!("hier NRMSE {e_hier:.3e} vs gbae NRMSE {e_gbae:.3e}");
    // hier uses HBAE latent (64/hyper-block) + BAE latent (16/block) vs
    // gbae 16/block: hier has more capacity and inter-block context; it
    // should reconstruct better.
    assert!(e_hier < e_gbae, "hierarchical should beat block baseline");
}
