#!/usr/bin/env python3
"""Generate the golden conformance corpus (see README.md in this dir).

The archives are handcrafted minimal-but-valid instances of the ARDC
container formats:

  v1_sz3.ardc   -- version-1 single-field archive, whole-stream SZ3B payload
  v2_sz3.ardc   -- version-2 multi-field container embedding two v1 archives
  v3_sz3.ardc   -- version-3 block-indexed archive (per-tile SZ3B + BIDX)
  v4_stream.ardc -- version-4 temporal stream (TSTR framing): 4 steps at
                    keyframe interval 2, each step an embedded v3 archive
                    (keyframes absolute, residuals against the previous
                    reconstruction), sealed with a TIDX record + footer
  v3_adaptive.ardc -- version-3 archive with the per-tile codec-id index
                    extension (BIDX minor version 1): tile 0 is an SZ3
                    stream (id 0), tile 1 a ZFP stream (id 1), payload
                    under the ADPB section tag
  v4_adaptive.ardc -- version-4 stream whose steps are adaptive v3
                    archives: a mixed-codec keyframe plus a mixed-codec
                    residual (codec assignments swapped between steps)

The ZFP tiles store all-zero coefficient codes with all-zero block
exponents: zero codes survive any exponent and precision through the
inverse lifting transform, so the tile decodes to exactly +0.0
everywhere and the expected outputs stay closed-form while the stream
still exercises the real ZFP header parse, exponent-plane LZSS, and
symbol-container decode.

Each SZ3 stream stores row 0 of its lattice as raw ("unpredictable")
values and codes every later row as Lorenzo code 0, which makes the
decoded field an exact row-0 repeat -- so the expected outputs are known
in closed form and the streams still exercise the real decode machinery:
container framing, header JSON, the canonical two-symbol Huffman table,
the LZSS literal path, the Lorenzo predictor, and the raw-value path.

These files are *frozen*: they pin decoder backward compatibility
byte-for-byte. Never regenerate an existing golden after its format has
shipped -- add a new one instead when a new container version lands.
"""

import json
import os
import struct

HERE = os.path.dirname(os.path.abspath(__file__))

I32_MIN = -(1 << 31)  # the SZ3 "unpredictable" sentinel code


def varint(v: int) -> bytes:
    out = bytearray()
    while True:
        b = v & 0x7F
        v >>= 7
        if v == 0:
            out.append(b)
            return bytes(out)
        out.append(b | 0x80)


def lzss_literals(data: bytes) -> bytes:
    """LZSS stream using only literal tokens (always valid, never optimal)."""
    out = bytearray([0xB3])
    out += varint(len(data))
    for g in range(0, len(data), 8):
        chunk = data[g : g + 8]
        out.append((1 << len(chunk)) - 1)  # flag bits: all literals
        out += chunk
    return bytes(out)


def huffman_two_symbol(n_unpred: int, n_zero: int) -> bytes:
    """Huffman stream for [UNPRED]*n_unpred + [0]*n_zero (in that order).

    Canonical table sorted by (len, symbol): UNPRED (i32 MIN) gets code 0,
    symbol 0 gets code 1; both length 1. Bits are packed LSB-first.
    """
    out = bytearray()
    out += struct.pack("<I", 2)
    out += struct.pack("<i", I32_MIN) + b"\x01"
    out += struct.pack("<i", 0) + b"\x01"
    out += struct.pack("<Q", n_unpred + n_zero)
    bits = [0] * n_unpred + [1] * n_zero
    for g in range(0, len(bits), 8):
        byte = 0
        for j, bit in enumerate(bits[g : g + 8]):
            byte |= bit << j
        out.append(byte)
    return bytes(out)


def sz3_stream(eps: float, dims: list[int], row0: list[float]) -> bytes:
    """SZ3 payload over `dims` (rank 2: [rows, cols]) decoding to a field
    whose every row equals `row0` (row 0 raw, later rows Lorenzo code 0)."""
    rows, cols = dims
    assert len(row0) == cols
    out = bytearray()
    out += struct.pack("<f", eps)
    out += struct.pack("<I", len(dims))
    for d in dims:
        out += struct.pack("<Q", d)
    out += struct.pack("<Q", cols)  # n_raw = row 0
    for v in row0:
        out += struct.pack("<f", v)
    z = lzss_literals(huffman_two_symbol(cols, (rows - 1) * cols))
    out += struct.pack("<Q", len(z))
    out += z
    return bytes(out)


def zfp_zero_stream(precision: int, dims: list[int]) -> bytes:
    """ZFP-like stream over `dims` decoding to all zeros.

    Layout: u8 precision | u32 rank | rank x u64 dims | u64 n_exp |
    u64 zexp_len | LZSS(i16-LE exponents) | u64 z_len | symbol stream.
    All-zero codes shift/unlift/scale to +0.0 whatever the exponents,
    so zero exponents + zero codes decode to an all-zero tile exactly.
    """
    rank = len(dims)
    d = min(rank, 3)
    lattice = dims[rank - d :]
    batch = 1
    for s in dims[: rank - d]:
        batch *= s
    n_blocks = batch
    for s in lattice:
        n_blocks *= -(-s // 4)  # ceil-div: 4^d blocks per axis
    n_codes = n_blocks * 4**d
    out = bytearray([precision])
    out += struct.pack("<I", rank)
    for s in dims:
        out += struct.pack("<Q", s)
    out += struct.pack("<Q", n_blocks)
    zexp = lzss_literals(b"\x00\x00" * n_blocks)  # i16 exponents, all zero
    out += struct.pack("<Q", len(zexp))
    out += zexp
    z = lzss_literals(huffman_two_symbol(0, n_codes))  # every code = symbol 0
    out += struct.pack("<Q", len(z))
    out += z
    return bytes(out)


def dataset_json(dims, ae_block):
    return {
        "kind": "e3sm",
        "dims": dims,
        "ae_block": ae_block,
        "k": 2,
        "hyper_axis": 0,
        "gae_block": [1, 4],
        "normalization": "z_score",
        "seed": 1,
    }


def archive(version: int, header: dict, sections: list[tuple[str, bytes]]) -> bytes:
    hdr = json.dumps(header, separators=(",", ":")).encode()
    out = bytearray(b"ARDC")
    out += struct.pack("<H", version)
    out += struct.pack("<I", len(hdr))
    out += hdr
    out += struct.pack("<I", len(sections))
    for tag, payload in sections:
        assert len(tag) == 4
        out += tag.encode()
        out += struct.pack("<Q", len(payload))
        out += payload
    return bytes(out)


def block_index(tile: list[int], entries: list[tuple[int, int]]) -> bytes:
    out = bytearray(struct.pack("<I", len(tile)))
    for t in tile:
        out += struct.pack("<I", t)
    out += struct.pack("<Q", len(entries))
    for off, ln in entries:
        out += struct.pack("<Q", off) + struct.pack("<Q", ln)
    return bytes(out)


def f32s(values) -> bytes:
    return b"".join(struct.pack("<f", v) for v in values)


def write(name: str, data: bytes):
    path = os.path.join(HERE, name)
    with open(path, "wb") as f:
        f.write(data)
    print(f"wrote {name} ({len(data)} bytes)")


EPS = 0.001
BOUND = {"kind": "nrmse", "value": 0.001}

# ---- v1: single field [6, 8], whole-stream payload ----------------------
DIMS = [6, 8]
ROW0_V1 = [1.5, -2.25, 0.75, 3.0, -0.5, 2.0, 1.25, -1.0]
v1 = archive(
    1,
    {
        "codec": "sz3",
        "bound": BOUND,
        "dataset": dataset_json(DIMS, [2, 4]),
        "eps": EPS,
    },
    [("SZ3B", sz3_stream(EPS, DIMS, ROW0_V1))],
)
write("v1_sz3.ardc", v1)
write("v1_sz3.expected.f32", f32s(ROW0_V1 * DIMS[0]))

# ---- v2: two fields, each an embedded v1 archive ------------------------
ROW0_TEMP = [0.5, 1.5, 2.5, 3.5, -4.5, 5.5, -6.5, 7.5]
ROW0_PRES = [-8.0, 0.25, 16.0, -0.125, 4.0, 1.0, -2.0, 0.0625]


def v1_field(row0):
    return archive(
        1,
        {
            "codec": "sz3",
            "bound": BOUND,
            "dataset": dataset_json(DIMS, [2, 4]),
            "eps": EPS,
        },
        [("SZ3B", sz3_stream(EPS, DIMS, row0))],
    )


v2 = archive(
    2,
    {
        "codec": "sz3",
        "bound": BOUND,
        "dataset": dataset_json(DIMS, [2, 4]),
        "fields": ["temp", "pressure"],
        # integral values stay ints: the in-repo JSON writer re-emits
        # integral floats without a ".0", and the conformance test pins
        # parse -> serialize as a byte fixed point
        "stats": {
            "temp": {"min": -6.5, "max": 7.5, "range": 14},
            "pressure": {"min": -8, "max": 16, "range": 24},
        },
    },
    [("F000", v1_field(ROW0_TEMP)), ("F001", v1_field(ROW0_PRES))],
)
write("v2_sz3.ardc", v2)
write("v2_sz3.temp.expected.f32", f32s(ROW0_TEMP * DIMS[0]))
write("v2_sz3.pressure.expected.f32", f32s(ROW0_PRES * DIMS[0]))

# ---- v3: block-indexed payload, tile = ae_block [6, 4] ------------------
TILE = [6, 4]
ROW0_T0 = [1.5, 2.5, -3.5, 0.25]
ROW0_T1 = [4.0, -0.125, 0.5, 8.0]
tile0 = sz3_stream(EPS, TILE, ROW0_T0)
tile1 = sz3_stream(EPS, TILE, ROW0_T1)
payload = tile0 + tile1
v3 = archive(
    3,
    {
        "codec": "sz3",
        "bound": BOUND,
        "dataset": dataset_json(DIMS, TILE),
        "eps": EPS,
    },
    [
        ("SZ3B", payload),
        ("BIDX", block_index(TILE, [(0, len(tile0)), (len(tile0), len(tile1))])),
    ],
)
write("v3_sz3.ardc", v3)
write("v3_sz3.expected.f32", f32s((ROW0_T0 + ROW0_T1) * DIMS[0]))

# ---- v4: temporal stream (TSTR framing), 4 steps, keyint 2 ---------------
# Steps 0/2 are keyframes, 1/3 residuals. Every step is a v3 block-indexed
# archive over the same [6, 8] field with [6, 4] tiles. All values are
# small dyadics, so the chain additions (frame = prev + residual) are
# exact in f32 and the expected frames are known in closed form.


def stream_record(tag: str, payload: bytes) -> bytes:
    return tag.encode() + struct.pack("<Q", len(payload)) + payload


def v3_step(row0_t0, row0_t1, extra: dict) -> bytes:
    t0 = sz3_stream(EPS, TILE, row0_t0)
    t1 = sz3_stream(EPS, TILE, row0_t1)
    hdr = {
        "codec": "sz3",
        "bound": BOUND,
        "dataset": dataset_json(DIMS, TILE),
        "eps": EPS,
    }
    hdr.update(extra)
    return archive(
        3,
        hdr,
        [
            ("SZ3B", t0 + t1),
            ("BIDX", block_index(TILE, [(0, len(t0)), (len(t0), len(t1))])),
        ],
    )


K0_T0 = [1.5, 2.5, -3.5, 0.25]
K0_T1 = [4.0, -0.125, 0.5, 8.0]
R1_T0 = [0.25, -0.5, 0.75, 0.125]
R1_T1 = [-1.0, 0.25, 0.5, -0.25]
K2_T0 = [2.0, 1.0, -1.5, 0.5]
K2_T1 = [0.0, 3.25, -2.0, 1.0]
R3_T0 = [-0.25, 0.5, 0.25, -0.125]
R3_T1 = [0.75, -0.5, 1.25, 0.0]

RES_BOUND = {"kind": "abs", "value": 0.01}  # the translated residual bound
STEPS = [
    (True, v3_step(K0_T0, K0_T1, {})),
    (False, v3_step(R1_T0, R1_T1, {"bound": RES_BOUND, "temporal": "residual"})),
    (True, v3_step(K2_T0, K2_T1, {})),
    (False, v3_step(R3_T0, R3_T1, {"bound": RES_BOUND, "temporal": "residual"})),
]

stream_hdr = json.dumps(
    {
        "codec": "sz3",
        "bound": BOUND,
        "dataset": dataset_json(DIMS, TILE),
        "keyint": 2,
    },
    separators=(",", ":"),
).encode()
v4 = bytearray(b"TSTR")
v4 += struct.pack("<H", 4)
v4 += struct.pack("<I", len(stream_hdr))
v4 += stream_hdr
entries = []
for keyframe, ar in STEPS:
    entries.append((keyframe, len(v4) + 12, len(ar)))
    v4 += stream_record("KSTP" if keyframe else "RSTP", ar)
tidx_off = len(v4)
tidx = struct.pack("<I", 2) + struct.pack("<Q", len(entries))
for keyframe, off, ln in entries:
    tidx += struct.pack("<B", 1 if keyframe else 0)
    tidx += struct.pack("<Q", off) + struct.pack("<Q", ln)
v4 += stream_record("TIDX", tidx)
v4 += struct.pack("<Q", tidx_off) + b"TEND"
write("v4_stream.ardc", bytes(v4))


def frame_rows(t0, t1):
    return (t0 + t1) * DIMS[0]


def add(a, b):
    return [x + y for x, y in zip(a, b)]


F0 = frame_rows(K0_T0, K0_T1)
F1 = add(F0, frame_rows(R1_T0, R1_T1))
F2 = frame_rows(K2_T0, K2_T1)
F3 = add(F2, frame_rows(R3_T0, R3_T1))
for i, frame in enumerate([F0, F1, F2, F3]):
    write(f"v4_stream.step{i}.expected.f32", f32s(frame))

# ---- v3 adaptive: mixed-codec tiles behind the BIDX codec-id trailer -----
# Tile 0 is an SZ3 row-repeat stream (codec id 0), tile 1 a ZFP all-zero
# stream (codec id 1). The index gains the minor-version-1 extension:
# legacy entries, then u8 0x01, then one codec-id byte per tile.

ZFP_PRECISION = 12


def adaptive_archive(tiles: list[tuple[bytes, int]], extra: dict) -> bytes:
    payload = b"".join(t for t, _ in tiles)
    entries, off = [], 0
    for t, _ in tiles:
        entries.append((off, len(t)))
        off += len(t)
    hdr = {
        "codec": "adaptive",
        "bound": BOUND,
        "dataset": dataset_json(DIMS, TILE),
        "eps": EPS,
    }
    hdr.update(extra)
    bidx = block_index(TILE, entries) + b"\x01" + bytes(i for _, i in tiles)
    return archive(3, hdr, [("ADPB", payload), ("BIDX", bidx)])


ADP_T0 = [2.5, -1.25, 0.5, 3.0]
v3a = adaptive_archive(
    [(sz3_stream(EPS, TILE, ADP_T0), 0), (zfp_zero_stream(ZFP_PRECISION, TILE), 1)],
    {},
)
write("v3_adaptive.ardc", v3a)
write("v3_adaptive.expected.f32", f32s((ADP_T0 + [0.0] * TILE[1]) * DIMS[0]))

# ---- v4 adaptive: stream of mixed-codec steps ----------------------------
# Keyframe 0: sz3 tile + zfp-zero tile. Residual 1: the assignment swaps
# (zfp-zero tile + sz3 tile), so both step kinds carry both codec ids and
# frame 1 = frame 0 + residual stays exact in f32 (dyadic values).

AK0_T0 = [1.5, -0.5, 2.0, 0.25]
AR1_T1 = [0.5, 1.25, -0.75, 0.125]
ASTEPS = [
    (
        True,
        adaptive_archive(
            [
                (sz3_stream(EPS, TILE, AK0_T0), 0),
                (zfp_zero_stream(ZFP_PRECISION, TILE), 1),
            ],
            {},
        ),
    ),
    (
        False,
        adaptive_archive(
            [
                (zfp_zero_stream(ZFP_PRECISION, TILE), 1),
                (sz3_stream(EPS, TILE, AR1_T1), 0),
            ],
            {"bound": RES_BOUND, "temporal": "residual"},
        ),
    ),
]

astream_hdr = json.dumps(
    {
        "codec": "adaptive",
        "bound": BOUND,
        "dataset": dataset_json(DIMS, TILE),
        "keyint": 2,
    },
    separators=(",", ":"),
).encode()
v4a = bytearray(b"TSTR")
v4a += struct.pack("<H", 4)
v4a += struct.pack("<I", len(astream_hdr))
v4a += astream_hdr
aentries = []
for keyframe, ar in ASTEPS:
    aentries.append((keyframe, len(v4a) + 12, len(ar)))
    v4a += stream_record("KSTP" if keyframe else "RSTP", ar)
atidx_off = len(v4a)
atidx = struct.pack("<I", 2) + struct.pack("<Q", len(aentries))
for keyframe, off, ln in aentries:
    atidx += struct.pack("<B", 1 if keyframe else 0)
    atidx += struct.pack("<Q", off) + struct.pack("<Q", ln)
v4a += stream_record("TIDX", atidx)
v4a += struct.pack("<Q", atidx_off) + b"TEND"
write("v4_adaptive.ardc", bytes(v4a))

AF0 = frame_rows(AK0_T0, [0.0] * TILE[1])
AF1 = add(AF0, frame_rows([0.0] * TILE[1], AR1_T1))
for i, frame in enumerate([AF0, AF1]):
    write(f"v4_adaptive.step{i}.expected.f32", f32s(frame))
