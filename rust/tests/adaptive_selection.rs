//! Selection-quality harness for the adaptive per-tile codec: on the
//! generator fields the CI smoke legs compress, the adaptive archive
//! must never be larger than the better of the two forced single-codec
//! archives (the acceptance bar is "CR within 1% of the best single
//! codec"; at smoke scale every tile is below the sampling gate, so the
//! comparison is exact), and the per-tile choices themselves must be
//! optimal: each recorded stream is the shorter of the two candidates.
//!
//! `prop_roundtrip.rs` covers the same invariants on random geometries;
//! this harness pins them on the named dataset presets, plus the mixed
//! archive's bit-exact round trip through serialized bytes.

use attn_reduce::codec::{
    with_tile_codec, AdaptiveCodec, Codec, CodecBuilder, ErrorBound, Sz3Codec, TileCodec,
};
use attn_reduce::compressor::{nrmse, Archive};
use attn_reduce::config::{dataset_preset, DatasetKind, Scale};
use attn_reduce::data;

#[test]
fn adaptive_payload_matches_or_beats_the_best_single_codec_on_presets() {
    for kind in [DatasetKind::E3sm, DatasetKind::S3d] {
        let cfg = dataset_preset(kind, Scale::Smoke);
        let field = data::generate(&cfg);
        let bound = ErrorBound::Nrmse(1e-3);
        let codec = AdaptiveCodec::new(cfg.clone());
        let auto = codec.compress(&field, &bound).unwrap();
        let forced_sz3 =
            with_tile_codec(TileCodec::Sz3, || codec.compress(&field, &bound)).unwrap();
        let forced_zfp =
            with_tile_codec(TileCodec::Zfp, || codec.compress(&field, &bound)).unwrap();
        let (a, s, z) = (
            auto.cr_payload_bytes(),
            forced_sz3.cr_payload_bytes(),
            forced_zfp.cr_payload_bytes(),
        );
        assert!(
            a <= s.min(z),
            "{kind:?}: adaptive payload {a} > min(sz3 {s}, zfp {z})"
        );

        // "best single codec" genuinely includes the standalone archives:
        // the forced-sz3 adaptive payload is byte-identical to what the
        // pure sz3 codec writes at the same bound
        let pure = Sz3Codec::new(cfg.clone()).compress(&field, &bound).unwrap();
        assert_eq!(
            forced_sz3.section("ADPB").unwrap(),
            pure.section("SZ3B").unwrap(),
            "{kind:?}: forced-sz3 payload drifted from the pure sz3 codec"
        );

        // per-tile optimality: every recorded stream is the shorter of
        // the two candidates, and the recorded id says which one it is
        let ia = auto.block_index().unwrap().unwrap();
        let is3 = forced_sz3.block_index().unwrap().unwrap();
        let izf = forced_zfp.block_index().unwrap().unwrap();
        let (ids_a, ids_z) = (ia.codecs.as_ref().unwrap(), izf.codecs.as_ref().unwrap());
        assert_eq!(ia.entries.len(), is3.entries.len());
        for i in 0..ia.entries.len() {
            let (al, sl, zl) = (ia.entries[i].1, is3.entries[i].1, izf.entries[i].1);
            match TileCodec::from_id(ids_a[i]).unwrap() {
                TileCodec::Zfp => {
                    assert_eq!(al, zl, "{kind:?} tile {i}: zfp pick, wrong stream");
                    assert!(zl < sl, "{kind:?} tile {i}: zfp picked without winning");
                    assert_eq!(ids_z[i], TileCodec::Zfp.id(), "tile {i} certifiable");
                }
                TileCodec::Sz3 => {
                    assert_eq!(al, sl, "{kind:?} tile {i}: sz3 pick, wrong stream");
                    // sz3 wins ties; zfp may also have degraded to sz3
                    assert!(sl <= zl, "{kind:?} tile {i}: sz3 kept while zfp smaller");
                }
            }
        }

        // the mixed archive round-trips bit-exactly through its bytes,
        // rebuilt from the header alone, and honors the bound
        let recon = codec.decompress(&auto).unwrap();
        let re = Archive::from_bytes(&auto.to_bytes()).unwrap();
        let rebuilt = CodecBuilder::new().for_archive(&re).unwrap();
        assert_eq!(rebuilt.id(), "adaptive");
        let recon2 = rebuilt.decompress(&re).unwrap();
        for (x, y) in recon.data().iter().zip(recon2.data()) {
            assert_eq!(x.to_bits(), y.to_bits(), "{kind:?}: reparse decode drifted");
        }
        let e = nrmse(&field, &recon);
        assert!(e <= 1e-3 * 1.0001, "{kind:?}: NRMSE {e} exceeds the bound");
    }
}

#[test]
fn forced_zfp_still_honors_the_bound_via_per_tile_degradation() {
    // forcing zfp must not trade the guarantee away: tiles the transform
    // cannot certify at ε fall back to sz3, and the archive still meets
    // the typed bound end to end
    let cfg = dataset_preset(DatasetKind::E3sm, Scale::Smoke);
    let field = data::generate(&cfg);
    let bound = ErrorBound::Nrmse(1e-3);
    let codec = AdaptiveCodec::new(cfg);
    let forced =
        with_tile_codec(TileCodec::Zfp, || codec.compress(&field, &bound)).unwrap();
    let recon = codec.decompress(&forced).unwrap();
    let e = nrmse(&field, &recon);
    assert!(e <= 1e-3 * 1.0001, "forced-zfp NRMSE {e} exceeds the bound");
}
