//! End-to-end integration: generate → train → compress → decompress →
//! verify the per-block error bound and metrics, at smoke scale, for all
//! three dataset presets. Requires `make artifacts`.

use std::rc::Rc;

use attn_reduce::compressor::{gae_taus, nrmse, Archive, HierCompressor};
use attn_reduce::config::{dataset_preset, model_preset, DatasetKind, PipelineConfig, Scale};
use attn_reduce::data::{self, Normalizer};
use attn_reduce::linalg::norm2_f32;
use attn_reduce::runtime::Runtime;
use attn_reduce::tensor::{block_origins, extract_block};

fn runtime() -> Option<Rc<Runtime>> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if !std::path::Path::new(dir).join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    std::env::set_var("ATTN_REDUCE_QUIET", "1");
    Some(Rc::new(Runtime::open(dir).expect("open artifacts")))
}

fn smoke_cfg(kind: DatasetKind) -> PipelineConfig {
    let mut cfg = PipelineConfig {
        dataset: dataset_preset(kind, Scale::Smoke),
        model: model_preset(kind),
        train: Default::default(),
        tau: 0.0,
    };
    cfg.train.steps = 25;
    cfg.train.log_every = 1000;
    cfg
}

fn ckpt_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("attn_reduce_e2e_{tag}"));
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Core assertion: the per-GAE-block ℓ2 bound holds in the ORIGINAL domain.
fn assert_bound_holds(
    cfg: &PipelineConfig,
    field: &attn_reduce::tensor::Tensor,
    recon: &attn_reduce::tensor::Tensor,
    tau: f32,
) {
    let d = cfg.dataset.gae_block_len();
    let origins = block_origins(&cfg.dataset.dims, &cfg.dataset.gae_block);
    let mut a = vec![0f32; d];
    let mut b = vec![0f32; d];
    let mut worst = 0f64;
    for o in &origins {
        extract_block(field, o, &cfg.dataset.gae_block, &mut a);
        extract_block(recon, o, &cfg.dataset.gae_block, &mut b);
        let diff: Vec<f32> = a.iter().zip(&b).map(|(&x, &y)| x - y).collect();
        let e = norm2_f32(&diff);
        worst = worst.max(e / tau as f64);
        assert!(
            e <= tau as f64 * 1.001,
            "block at {o:?}: ||err|| = {e} > tau = {tau}"
        );
    }
    eprintln!("worst block error / tau = {worst:.3}");
}

fn run_dataset(kind: DatasetKind, tag: &str) {
    let Some(rt) = runtime() else { return };
    let cfg = smoke_cfg(kind);
    let field = data::generate(&cfg.dataset);
    let ckpt = ckpt_dir(tag);
    let (comp, reports) =
        HierCompressor::prepare(&rt, &cfg, &ckpt, &field).expect("prepare");
    // training ran (first time) and reduced loss
    for r in &reports {
        assert!(r.final_loss < r.losses[0].1, "{}", r.summary());
    }

    let tau = PipelineConfig::tau_for_nrmse(
        2e-3,
        field.range() as f64,
        cfg.dataset.gae_block_len(),
    );
    let (archive, recon) = comp.compress(&field, tau).expect("compress");
    assert_eq!(recon.shape(), field.shape());
    assert_bound_holds(&cfg, &field, &recon, tau);

    // NRMSE consistent with the bound construction (Eq. 11): if every
    // block is at most tau, dataset NRMSE <= target
    let e = nrmse(&field, &recon);
    assert!(e <= 2e-3 * 1.01, "NRMSE {e}");
    assert!(e > 0.0, "lossy compressor should not be exact");

    // archive round-trips through bytes
    let bytes = archive.to_bytes();
    let archive2 = Archive::from_bytes(&bytes).expect("parse");

    // decompress (now a method, symmetric with compress) reproduces the
    // compressor's reconstruction from the parsed archive
    let recon2 = comp.decompress(&archive2).expect("decompress");
    let max_d = recon
        .data()
        .iter()
        .zip(recon2.data())
        .fold(0f32, |a, (x, y)| a.max((x - y).abs()));
    let scale = field.range();
    assert!(
        max_d <= 2e-5 * scale,
        "decompress disagrees with compress by {max_d} (range {scale})"
    );
    // the decompressed output satisfies the bound too
    assert_bound_holds(&cfg, &field, &recon2, tau);

    // compression actually compresses (paper accounting)
    let stats = comp.stats(&archive);
    assert!(stats.cr > 1.0, "CR = {}", stats.cr);
}

#[test]
fn s3d_end_to_end() {
    run_dataset(DatasetKind::S3d, "s3d");
}

#[test]
fn e3sm_end_to_end() {
    run_dataset(DatasetKind::E3sm, "e3sm");
}

#[test]
fn xgc_end_to_end() {
    run_dataset(DatasetKind::Xgc, "xgc");
}

#[test]
fn tighter_tau_gives_lower_error_and_bigger_archive() {
    let Some(rt) = runtime() else { return };
    let cfg = smoke_cfg(DatasetKind::S3d);
    let field = data::generate(&cfg.dataset);
    let ckpt = ckpt_dir("s3d_tau");
    let (comp, _) = HierCompressor::prepare(&rt, &cfg, &ckpt, &field).unwrap();
    let range = field.range() as f64;
    let d = cfg.dataset.gae_block_len();
    let tau_loose = PipelineConfig::tau_for_nrmse(5e-3, range, d);
    let tau_tight = PipelineConfig::tau_for_nrmse(5e-4, range, d);
    let (a_loose, r_loose) = comp.compress(&field, tau_loose).unwrap();
    let (a_tight, r_tight) = comp.compress(&field, tau_tight).unwrap();
    assert!(nrmse(&field, &r_tight) < nrmse(&field, &r_loose));
    assert!(a_tight.cr_payload_bytes() > a_loose.cr_payload_bytes());
}

#[test]
fn gae_disabled_when_tau_zero() {
    let Some(rt) = runtime() else { return };
    let cfg = smoke_cfg(DatasetKind::S3d);
    let field = data::generate(&cfg.dataset);
    let ckpt = ckpt_dir("s3d_notau");
    let (comp, _) = HierCompressor::prepare(&rt, &cfg, &ckpt, &field).unwrap();
    let (archive, _) = comp.compress(&field, 0.0).unwrap();
    assert!(!archive.has_section("GCOF"));
    assert!(!archive.has_section("GBAS"));
}

#[test]
fn streaming_coordinator_matches_sequential() {
    let Some(rt) = runtime() else { return };
    let cfg = smoke_cfg(DatasetKind::E3sm);
    let field = data::generate(&cfg.dataset);
    let ckpt = ckpt_dir("e3sm_stream");
    let (comp, _) = HierCompressor::prepare(&rt, &cfg, &ckpt, &field).unwrap();
    let out = attn_reduce::coordinator::stream_compress(&comp, &field, 4).unwrap();
    // same AE stack sequentially (tau=0 so recon is the AE output)
    let (_, recon_seq) = comp.compress(&field, 0.0).unwrap();
    // stream recon is normalized-domain; denormalize to compare
    let stats = Normalizer::fit(cfg.dataset.normalization, &field);
    let mut stream_recon = out.recon;
    Normalizer::invert(&stats, &mut stream_recon);
    let max_d = recon_seq
        .data()
        .iter()
        .zip(stream_recon.data())
        .fold(0f32, |a, (x, y)| a.max((x - y).abs()));
    assert!(
        max_d <= 1e-4 * field.range(),
        "stream vs sequential differ by {max_d}"
    );
    assert!(out.stats.batches > 0);
    // e3sm smoke: 24/6 = 4 temporal blocks -> 1 padded hyper-group x 2x2 tiles
    assert_eq!(out.stats.hyperblocks, 4);
    eprintln!("{}", out.stats.summary());
}

#[test]
fn normalized_taus_transfer_to_original_domain() {
    // unit-level check of the tau conversion the bound relies on
    let cfg = smoke_cfg(DatasetKind::S3d);
    let field = data::generate(&cfg.dataset);
    let stats = Normalizer::fit(cfg.dataset.normalization, &field);
    let origins = block_origins(&cfg.dataset.dims, &cfg.dataset.gae_block);
    let taus = gae_taus(&cfg.dataset, &stats, 0.5, &origins);
    for (o, &t) in origins.iter().zip(&taus) {
        let ch = o[0];
        let scale = stats.channels[ch].1;
        assert!((t as f64 * scale - 0.5).abs() < 1e-6);
    }
}
