//! End-to-end tests of the serving layer over real sockets: a server
//! per test on an OS-assigned port, a minimal in-test HTTP client, and
//! the acceptance contract pinned — `/v1/streams/{name}/extract` bytes
//! are identical to `cli stream extract`, a warm repeat is a cache hit
//! that decodes zero keyframe payload bytes, and `/info` returns the
//! exact document `cli info --json` prints.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::{Path, PathBuf};
use std::process::Command;

use attn_reduce::codec::{Codec, ErrorBound, Sz3Codec};
use attn_reduce::compressor::Archive;
use attn_reduce::config::{dataset_preset, stream_frame_preset, DatasetKind, Scale};
use attn_reduce::data::timeseries;
use attn_reduce::engine::{CodecExt, FieldSet};
use attn_reduce::serve::{ServeConfig, Server, StopHandle};
use attn_reduce::stream::StreamWriter;
use attn_reduce::util::json::Value;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_attn-reduce"))
}

fn root(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("attn_reduce_serve_it").join(name);
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// A 6-step sz3 stream with keyframe interval 2 at `dir/name`.
fn make_stream(dir: &Path, name: &str) -> PathBuf {
    let cfg = stream_frame_preset(DatasetKind::E3sm, Scale::Smoke);
    let codec = Sz3Codec::new(cfg.clone());
    let frames = timeseries::generate_frames(&cfg.dims, cfg.seed, 0, 6);
    let path = dir.join(name);
    let mut w =
        StreamWriter::create(&path, codec.id(), cfg, ErrorBound::Nrmse(1e-3), 2).unwrap();
    w.append_frames(&codec, &frames).unwrap();
    w.finish().unwrap();
    path
}

/// A single-field v3 sz3 archive at `dir/name`.
fn make_archive(dir: &Path, name: &str) -> PathBuf {
    let cfg = dataset_preset(DatasetKind::E3sm, Scale::Smoke);
    let field = attn_reduce::data::generate(&cfg);
    let archive = Sz3Codec::new(cfg).compress(&field, &ErrorBound::Nrmse(1e-3)).unwrap();
    let path = dir.join(name);
    archive.save(&path).unwrap();
    path
}

/// A two-field v2 sz3 archive at `dir/name`.
fn make_multi_archive(dir: &Path, name: &str) -> PathBuf {
    let set = FieldSet::generate(DatasetKind::E3sm, Scale::Smoke, 2);
    let codec = Sz3Codec::new(set.dataset().clone());
    let archive = codec.compress_set(&set, &ErrorBound::Nrmse(1e-3)).unwrap();
    let path = dir.join(name);
    archive.save(&path).unwrap();
    path
}

/// A server running on its own thread; stopped and joined on drop.
struct Running {
    addr: SocketAddr,
    stop: StopHandle,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl Running {
    fn start(root: &Path) -> Running {
        std::env::set_var("ATTN_REDUCE_QUIET", "1");
        let server = Server::bind(ServeConfig::new(root, "127.0.0.1:0")).unwrap();
        let addr = server.local_addr();
        let stop = server.stop_handle();
        let thread = std::thread::spawn(move || server.run().unwrap());
        Running { addr, stop, thread: Some(thread) }
    }
}

impl Drop for Running {
    fn drop(&mut self) {
        self.stop.stop();
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

struct Reply {
    status: u16,
    headers: Vec<(String, String)>,
    body: Vec<u8>,
}

impl Reply {
    fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, v)| v.as_str())
    }

    fn text(&self) -> String {
        String::from_utf8_lossy(&self.body).into_owned()
    }
}

fn send(addr: SocketAddr, head: &str, body: &[u8]) -> Reply {
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(head.as_bytes()).unwrap();
    s.write_all(body).unwrap();
    let mut raw = Vec::new();
    s.read_to_end(&mut raw).unwrap(); // connection: close delimits
    let split = raw
        .windows(4)
        .position(|w| w == b"\r\n\r\n")
        .expect("no header/body split in response");
    let head_text = String::from_utf8_lossy(&raw[..split]).into_owned();
    let mut lines = head_text.split("\r\n");
    let status: u16 = lines
        .next()
        .unwrap()
        .split_whitespace()
        .nth(1)
        .expect("no status code")
        .parse()
        .unwrap();
    let headers = lines
        .filter_map(|l| l.split_once(':'))
        .map(|(k, v)| (k.trim().to_ascii_lowercase(), v.trim().to_string()))
        .collect();
    Reply { status, headers, body: raw[split + 4..].to_vec() }
}

fn get(addr: SocketAddr, target: &str) -> Reply {
    send(addr, &format!("GET {target} HTTP/1.1\r\nhost: test\r\n\r\n"), &[])
}

fn post(addr: SocketAddr, target: &str, body: &[u8]) -> Reply {
    let head = format!(
        "POST {target} HTTP/1.1\r\nhost: test\r\ncontent-length: {}\r\n\r\n",
        body.len()
    );
    send(addr, &head, body)
}

/// The value of a bare (unlabeled) series in a text exposition.
fn metric_value(text: &str, series: &str) -> u64 {
    text.lines()
        .find_map(|l| l.strip_prefix(series).and_then(|r| r.strip_prefix(' ')))
        .unwrap_or_else(|| panic!("series {series} missing from exposition"))
        .trim()
        .parse()
        .unwrap()
}

/// The acceptance criterion: server extract bytes == CLI extract bytes,
/// and a warm repeat is a cache hit that decodes no keyframe payload.
#[test]
fn stream_extract_matches_cli_and_warm_repeat_skips_keyframe_decode() {
    let dir = root("accept");
    let stream_p = make_stream(&dir, "run.tstr");
    let srv = Running::start(&dir);

    // reference bytes straight from the CLI (step 3 chains from the
    // keyframe at step 2; the region covers 2 of the 4 16x16 tiles)
    let cli_out = dir.join("cli_region.f32");
    let out = bin()
        .args(["stream", "extract", "--step", "3", "--region", "8:24,0:16", "--in"])
        .arg(&stream_p)
        .arg("--out")
        .arg(&cli_out)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let want = std::fs::read(&cli_out).unwrap();

    let cold = get(srv.addr, "/v1/streams/run.tstr/extract?step=3&region=8:24,0:16");
    assert_eq!(cold.status, 200, "{}", cold.text());
    assert_eq!(cold.body, want, "served bytes differ from the CLI decode");
    assert_eq!(cold.header("x-cache"), Some("miss"));
    assert_eq!(cold.header("x-chain-steps"), Some("2"));
    let kf_bytes: usize = cold
        .header("x-keyframe-payload-bytes")
        .unwrap()
        .parse()
        .unwrap();
    assert!(kf_bytes > 0, "a cold decode must touch keyframe payload");

    // warm repeat: same bytes, cache hit, zero keyframe payload decoded
    let warm = get(srv.addr, "/v1/streams/run.tstr/extract?step=3&region=8:24,0:16");
    assert_eq!(warm.status, 200);
    assert_eq!(warm.body, want, "warm decode diverged");
    assert_eq!(warm.header("x-cache"), Some("hit"));
    assert_eq!(warm.header("x-keyframe-payload-bytes"), Some("0"));

    // a keyframe step itself is served straight from the cached frame
    let kf = get(srv.addr, "/v1/streams/run.tstr/extract?step=2&region=8:24,0:16");
    assert_eq!(kf.status, 200);
    assert_eq!(kf.header("x-cache"), Some("hit"), "same (keyframe, region) class");
    assert_eq!(kf.header("x-chain-steps"), Some("1"));

    // the steps route reflects the stream's timeline
    let steps = get(srv.addr, "/v1/streams/run.tstr/steps");
    assert_eq!(steps.status, 200);
    let text = steps.text();
    assert!(text.contains("\"n_steps\": 6"), "{text}");
    assert!(text.contains("\"keyint\": 2"), "{text}");
    assert!(text.contains("\"keyframe\": true"), "{text}");
    assert!(text.contains("\"codec\": \"sz3\""), "{text}");

    // stats: the cold request missed twice (reader + keyframe); the
    // warm and keyframe extracts hit both, the steps route hit the
    // reader — and the total keyframe payload decoded equals the one
    // cold decode
    let stats = get(srv.addr, "/v1/stats");
    assert_eq!(stats.status, 200);
    let text = stats.text();
    assert!(text.contains("\"hits\": 5"), "{text}");
    assert!(text.contains("\"misses\": 2"), "{text}");
    assert!(
        text.contains(&format!("\"keyframe_payload_bytes_decoded\": {kf_bytes}")),
        "{text}"
    );
}

#[test]
fn archive_routes_list_info_and_extract_match_the_cli() {
    let dir = root("archive");
    let archive_p = make_archive(&dir, "field.ardc");
    let srv = Running::start(&dir);

    // listing: one archive, classified by magic
    let list = get(srv.addr, "/v1/archives");
    assert_eq!(list.status, 200);
    let text = list.text();
    assert!(text.contains("\"name\": \"field.ardc\""), "{text}");
    assert!(text.contains("\"kind\": \"archive\""), "{text}");
    assert!(text.contains("\"total\": 1"), "{text}");

    // /info body is byte-identical to `cli info --json --in`
    let out = bin().args(["info", "--json", "--in"]).arg(&archive_p).output().unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let info = get(srv.addr, "/v1/archives/field.ardc/info");
    assert_eq!(info.status, 200);
    assert_eq!(info.body, out.stdout, "route and CLI JSON drifted apart");

    // region extract equals the CLI's file output bit for bit
    let cli_out = dir.join("cli_region.f32");
    let out = bin()
        .args(["extract", "--region", "2:10,4:20,8:24", "--in"])
        .arg(&archive_p)
        .arg("--out")
        .arg(&cli_out)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let reply = get(srv.addr, "/v1/archives/field.ardc/extract?region=2:10,4:20,8:24");
    assert_eq!(reply.status, 200, "{}", reply.text());
    assert_eq!(reply.body, std::fs::read(&cli_out).unwrap());
    assert_eq!(reply.header("x-points"), Some("2048")); // 8*16*16

    // no region = full decode, matching `cli decompress`
    let cli_full = dir.join("cli_full.f32");
    let out = bin()
        .arg("decompress")
        .arg("--in")
        .arg(&archive_p)
        .arg("--out")
        .arg(&cli_full)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let reply = get(srv.addr, "/v1/archives/field.ardc/extract");
    assert_eq!(reply.status, 200);
    assert_eq!(reply.body, std::fs::read(&cli_full).unwrap());
}

#[test]
fn adaptive_archive_routes_match_the_cli_and_expose_the_codec_split() {
    let dir = root("adaptive");
    // the frozen conformance golden is a guaranteed-mixed archive: one
    // sz3 tile, one zfp tile, with pinned expected output bytes
    let golden_dir = Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/tests/golden"));
    let golden_p = dir.join("mixed.ardc");
    std::fs::copy(golden_dir.join("v3_adaptive.ardc"), &golden_p).unwrap();
    let srv = Running::start(&dir);

    // /info body is byte-identical to `cli info --json --in` — the route
    // and the CLI share one document builder, codec split included
    let out = bin().args(["info", "--json", "--in"]).arg(&golden_p).output().unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let info = get(srv.addr, "/v1/archives/mixed.ardc/info");
    assert_eq!(info.status, 200, "{}", info.text());
    assert_eq!(info.body, out.stdout, "route and CLI JSON drifted apart");
    let text = info.text();
    assert!(text.contains("\"codec\": \"adaptive\""), "{text}");
    assert!(text.contains("\"tile_codecs\": "), "{text}");
    assert!(text.contains("\"sz3_tiles\": 1"), "{text}");
    assert!(text.contains("\"zfp_tiles\": 1"), "{text}");

    // full extract serves the golden's pinned expected output bytes
    let want = std::fs::read(golden_dir.join("v3_adaptive.expected.f32")).unwrap();
    let reply = get(srv.addr, "/v1/archives/mixed.ardc/extract");
    assert_eq!(reply.status, 200, "{}", reply.text());
    assert_eq!(reply.body, want, "served mixed decode drifted from the golden");

    // a region covering only the zfp tile dispatches on its codec id
    // (golden dims are [6, 8], tiled [6, 4]: columns 4..8 are tile 1)
    let reply = get(srv.addr, "/v1/archives/mixed.ardc/extract?region=0:6,4:8");
    assert_eq!(reply.status, 200, "{}", reply.text());
    let crop: Vec<u8> = want
        .chunks_exact(4)
        .enumerate()
        .filter(|(i, _)| i % 8 >= 4)
        .flat_map(|(_, b)| b.to_vec())
        .collect();
    assert_eq!(reply.body, crop, "zfp-tile region drifted from the golden");

    // POST /v1/compress accepts the adaptive codec and the result is
    // servable like any other archive
    let cfg = dataset_preset(DatasetKind::E3sm, Scale::Smoke);
    let field = attn_reduce::data::generate(&cfg);
    let mut body = Vec::with_capacity(field.len() * 4);
    for v in field.data() {
        body.extend_from_slice(&v.to_le_bytes());
    }
    let target = "/v1/compress?name=posted_adaptive.ardc&codec=adaptive&dataset=e3sm\
                  &scale=smoke&bound=nrmse:1e-3";
    let r = post(srv.addr, target, &body);
    assert_eq!(r.status, 200, "{}", r.text());
    assert!(r.text().contains("\"codec\": \"adaptive\""), "{}", r.text());
    let r = get(srv.addr, "/v1/archives/posted_adaptive.ardc/extract");
    assert_eq!(r.status, 200);
    assert_eq!(r.body.len(), cfg.total_points() * 4);
}

/// `/v1/metrics` exposes the full family catalog in Prometheus text,
/// its cache counters move in lockstep with the LRU (a warm extract
/// repeat is exactly two hits: reader probe + keyframe region), and
/// `?format=json` is the same snapshot as parseable JSON.
#[test]
fn metrics_exposition_covers_the_catalog_and_pins_cache_hits() {
    let dir = root("metrics");
    make_stream(&dir, "run.tstr");
    let srv = Running::start(&dir);

    // cold: populates the reader + keyframe cache entries
    let cold = get(srv.addr, "/v1/streams/run.tstr/extract?step=3&region=8:24,0:16");
    assert_eq!(cold.status, 200, "{}", cold.text());

    let scrape = get(srv.addr, "/v1/metrics");
    assert_eq!(scrape.status, 200);
    assert!(
        scrape.header("content-type").unwrap().starts_with("text/plain"),
        "prometheus text content type"
    );
    let text = scrape.text();
    // the catalog: per-server request metrics, the cache's snapshot
    // families, and the preregistered global stage/entropy/adaptive
    // families — all present on the first scrape, before any traffic
    // has exercised them
    for needle in [
        "# TYPE attn_requests_total counter",
        "attn_requests_total{status=\"2xx\"}",
        "# TYPE attn_request_duration_seconds histogram",
        "attn_request_duration_seconds_bucket{route=\"stream_extract\",le=",
        "# TYPE attn_cache_hits_total counter",
        "attn_cache_misses_total",
        "attn_cache_refusals_total",
        "attn_cache_invalidations_total",
        "attn_cache_resident_bytes",
        "# TYPE attn_stage_duration_seconds histogram",
        "attn_stage_duration_seconds_bucket{stage=\"stream.extract\",le=",
        "attn_entropy_streams_total{mode=\"rans\",dir=\"decode\"}",
        "attn_adaptive_tiles_total{codec=\"sz3\"}",
        "attn_keyframe_payload_bytes_total",
    ] {
        assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
    }

    let hits_before = metric_value(&text, "attn_cache_hits_total");
    let warm = get(srv.addr, "/v1/streams/run.tstr/extract?step=3&region=8:24,0:16");
    assert_eq!(warm.header("x-cache"), Some("hit"));
    let text = get(srv.addr, "/v1/metrics").text();
    let hits_after = metric_value(&text, "attn_cache_hits_total");
    assert_eq!(hits_after - hits_before, 2, "reader hit + keyframe hit, nothing else");
    assert_eq!(metric_value(&text, "attn_cache_refusals_total"), 0);

    // /v1/stats carries the new cache counters alongside the old keys
    let stats = get(srv.addr, "/v1/stats").text();
    assert!(stats.contains("\"refusals\": 0"), "{stats}");
    assert!(stats.contains("\"invalidations\": 0"), "{stats}");

    // the JSON rendering is the same snapshot, machine-parseable
    let json = get(srv.addr, "/v1/metrics?format=json");
    assert_eq!(json.status, 200);
    let doc = Value::parse(&json.text()).expect("valid JSON");
    let families = match doc.get("families") {
        Some(Value::Arr(f)) => f,
        other => panic!("families array missing: {other:?}"),
    };
    let names: Vec<&str> = families
        .iter()
        .filter_map(|f| f.get("name").and_then(|v| v.as_str()))
        .collect();
    assert!(names.contains(&"attn_cache_hits_total"), "{names:?}");
    assert!(names.contains(&"attn_request_duration_seconds"), "{names:?}");
    assert!(names.contains(&"attn_stage_duration_seconds"), "{names:?}");

    // unknown rendering: 400
    assert_eq!(get(srv.addr, "/v1/metrics?format=xml").status, 400);
}

#[test]
fn error_paths_return_typed_statuses() {
    let dir = root("errors");
    make_stream(&dir, "run.tstr");
    make_archive(&dir, "field.ardc");
    make_multi_archive(&dir, "multi.ardc");
    let srv = Running::start(&dir);

    // unknown file: 404
    let r = get(srv.addr, "/v1/archives/nope.ardc/info");
    assert_eq!(r.status, 404, "{}", r.text());

    // unknown route: 404; wrong method: 405
    assert_eq!(get(srv.addr, "/nope").status, 404);
    assert_eq!(get(srv.addr, "/v1/compress").status, 405);
    assert_eq!(
        send(srv.addr, "DELETE /v1/archives HTTP/1.1\r\nhost: t\r\n\r\n", &[]).status,
        405
    );

    // step out of range: 400 with the same message shape as the CLI
    let r = get(srv.addr, "/v1/streams/run.tstr/extract?step=99");
    assert_eq!(r.status, 400);
    assert!(r.text().contains("step 99 out of range (6 steps in stream)"), "{}", r.text());

    // missing step / malformed region: 400
    assert_eq!(get(srv.addr, "/v1/streams/run.tstr/extract").status, 400);
    let r = get(srv.addr, "/v1/streams/run.tstr/extract?step=1&region=9:1");
    assert_eq!(r.status, 400);
    assert!(r.text().contains("bad region"), "{}", r.text());

    // path traversal in the name segment: 400, nothing leaks
    let r = get(srv.addr, "/v1/archives/%2e%2e%2fsecret/info");
    assert_eq!(r.status, 400);

    // out-of-range field index: typed 400 naming the field count (the
    // CLI's exit-2 contract, HTTP-shaped); an unknown field *name* is
    // a 404; field= on a single-field archive is a 400
    let r = get(srv.addr, "/v1/archives/multi.ardc/extract?field=9");
    assert_eq!(r.status, 400);
    assert!(
        r.text().contains("field index 9 out of range: archive has 2 fields (0..2)"),
        "{}",
        r.text()
    );
    let r = get(srv.addr, "/v1/archives/multi.ardc/extract?field=nope");
    assert_eq!(r.status, 404, "{}", r.text());
    let r = get(srv.addr, "/v1/archives/field.ardc/extract?field=0");
    assert_eq!(r.status, 400);
    assert!(r.text().contains("multi-field"), "{}", r.text());

    // wrong route family for the file type: 400 pointing at the other
    let r = get(srv.addr, "/v1/archives/run.tstr/extract");
    assert_eq!(r.status, 400);
    assert!(r.text().contains("temporal stream"), "{}", r.text());
    let r = get(srv.addr, "/v1/streams/field.ardc/extract?step=0");
    assert_eq!(r.status, 400);
    assert!(r.text().contains("not a temporal stream"), "{}", r.text());

    // garbage on the wire: 400, the server survives
    let r = send(srv.addr, "BROKEN\r\n\r\n", &[]);
    assert_eq!(r.status, 400);
    assert_eq!(get(srv.addr, "/v1/stats").status, 200, "server still up");
}

/// Corruption detected while serving is a 422 — "the file is damaged,
/// run `cli verify`" — distinct from a real 500, and the durability /
/// integrity counter families are in the catalog from the first scrape.
#[test]
fn corrupt_files_return_422_and_durability_metrics_are_cataloged() {
    let dir = root("corrupt");
    let archive_p = make_archive(&dir, "field.ardc");
    let srv = Running::start(&dir);

    // flip one payload byte: the checked container's XSUM catches it,
    // and the changed (len, mtime) stamp guarantees a cache miss even
    // though the server never saw the overwrite
    let mut bytes = std::fs::read(&archive_p).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x40;
    std::fs::write(&archive_p, &bytes).unwrap();

    let r = get(srv.addr, "/v1/archives/field.ardc/extract");
    assert_eq!(r.status, 422, "{}", r.text());
    assert!(r.text().contains("checksum"), "{}", r.text());

    // the server survives, the counter moved, and the new families are
    // all present in the exposition
    let text = get(srv.addr, "/v1/metrics").text();
    for needle in [
        "# TYPE attn_corruption_detected_total counter",
        "attn_durable_writes_total{outcome=\"committed\"}",
        "attn_durable_writes_total{outcome=\"failed\"}",
        "# TYPE attn_requests_shed_total counter",
    ] {
        assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
    }
    assert!(metric_value(&text, "attn_corruption_detected_total") >= 1, "{text}");
    assert_eq!(get(srv.addr, "/v1/stats").status, 200, "server still up");
}

#[test]
fn post_compress_writes_a_servable_archive() {
    let dir = root("compress");
    let srv = Running::start(&dir);

    let cfg = dataset_preset(DatasetKind::E3sm, Scale::Smoke);
    let field = attn_reduce::data::generate(&cfg);
    let mut body = Vec::with_capacity(field.len() * 4);
    for v in field.data() {
        body.extend_from_slice(&v.to_le_bytes());
    }

    let target = "/v1/compress?name=posted.ardc&codec=sz3&dataset=e3sm&scale=smoke\
                  &bound=nrmse:1e-3";
    let r = post(srv.addr, target, &body);
    assert_eq!(r.status, 200, "{}", r.text());
    let text = r.text();
    assert!(text.contains("\"name\": \"posted.ardc\""), "{text}");
    assert!(text.contains("\"codec\": \"sz3\""), "{text}");
    assert!(text.contains("\"cr\": "), "{text}");

    // the archive landed under the root, loadable and servable
    let archive = Archive::load(dir.join("posted.ardc")).unwrap();
    assert_eq!(archive.header.get("codec").and_then(|v| v.as_str()), Some("sz3"));
    let r = get(srv.addr, "/v1/archives/posted.ardc/extract");
    assert_eq!(r.status, 200);
    assert_eq!(r.body.len(), cfg.total_points() * 4);

    // wrong body size is a 400 naming the expected geometry
    let r = post(srv.addr, target, &body[..100]);
    assert_eq!(r.status, 400);
    assert!(r.text().contains("dims"), "{}", r.text());

    // a traversal name never reaches the filesystem
    let r = post(srv.addr, "/v1/compress?name=../evil.ardc", &body);
    assert_eq!(r.status, 400);
}
