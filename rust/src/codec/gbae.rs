//! [`Codec`] adapter for the GBAE block-autoencoder baseline.
//!
//! The old `GbaeCompressor::compress(field, latent_bin, tau)` only
//! *accounted* payload bytes and had no decompression at all; this
//! adapter produces a full self-describing archive (sections `GLAT`,
//! optional `GCLT`, plus the GAE trio) and implements the symmetric
//! decode path, so the baseline now round-trips exactly like the
//! hierarchical codec it is compared against.

use crate::baselines::GbaeCompressor;
use crate::coder::{decode_latents, encode_latents, Quantizer};
use crate::compressor::{gae_bound_stage, gae_restore_stage_region, Archive};
use crate::data::{NormStats, Normalizer, Region};
use crate::tensor::Tensor;
use crate::util::json::{self, Value};
use crate::Result;
use anyhow::ensure;

use super::{base_header, Codec, ErrorBound};

/// Block-AE baseline codec (GBAE; with a corrector it is GAETC-like).
pub struct GbaeCodec {
    comp: GbaeCompressor,
    /// Latent quantization bin (0 = raw f32 latents).
    latent_bin: f32,
}

impl GbaeCodec {
    pub fn new(comp: GbaeCompressor, latent_bin: f32) -> Self {
        Self { comp, latent_bin }
    }

    /// The underlying baseline compressor.
    pub fn compressor(&self) -> &GbaeCompressor {
        &self.comp
    }
}

impl Codec for GbaeCodec {
    fn id(&self) -> &str {
        "gbae"
    }

    fn compress(&self, field: &Tensor, bound: &ErrorBound) -> Result<Archive> {
        self.compress_with_recon(field, bound).map(|(archive, _)| archive)
    }

    fn compress_with_recon(
        &self,
        field: &Tensor,
        bound: &ErrorBound,
    ) -> Result<(Archive, Tensor)> {
        let dataset = &self.comp.dataset;
        ensure!(
            field.shape() == &dataset.dims[..],
            "field shape {:?} != dataset dims {:?}",
            field.shape(),
            dataset.dims
        );
        let stats = Normalizer::fit(dataset.normalization, field);
        let mut norm = field.clone();
        Normalizer::apply(&stats, &mut norm);

        let q = Quantizer::new(self.latent_bin.max(0.0));
        let (lat_rows, corr_rows, mut recon) = self.comp.forward(&norm, q)?;

        let tau = bound.gae_tau(dataset, field.range() as f64);
        let gae = gae_bound_stage(dataset, &stats, tau, &norm, &mut recon)?;

        let mut header = base_header(self.id(), dataset, bound);
        header.push(("norm".to_string(), stats.to_json()));
        header.push(("tau".to_string(), json::num(tau as f64)));
        header.push(("latent_bin".to_string(), json::num(self.latent_bin as f64)));
        header.push(("ae_group".to_string(), json::s(self.comp.ae.group.as_str())));
        header.push((
            "corrector_group".to_string(),
            self.comp
                .corrector
                .as_ref()
                .map(|c| json::s(c.group.as_str()))
                .unwrap_or(Value::Null),
        ));
        let mut archive = Archive::new(Value::Obj(header));
        archive.add_section("GLAT", encode_latents(&lat_rows, q));
        if let Some(c) = &corr_rows {
            archive.add_section("GCLT", encode_latents(c, q));
        }
        if let Some(g) = gae {
            archive.add_section("GCOF", g.gcof);
            archive.add_section("GIDX", g.gidx);
            archive.add_section("GBAS", g.gbas);
        }

        Normalizer::invert(&stats, &mut recon);
        Ok((archive, recon))
    }

    fn decompress(&self, archive: &Archive) -> Result<Tensor> {
        self.decompress_inner(archive, None)
    }

    fn decompress_region(&self, archive: &Archive, region: &Region) -> Result<Tensor> {
        // latents are whole-stream coded (the AE decodes fully); the GAE
        // correction stage runs only on the region's blocks, then crop
        let full = self.decompress_inner(archive, Some(region))?;
        region.crop(&full)
    }
}

impl GbaeCodec {
    fn decompress_inner(
        &self,
        archive: &Archive,
        region: Option<&Region>,
    ) -> Result<Tensor> {
        let h = &archive.header;
        let dataset = crate::config::DatasetConfig::from_json(h.req("dataset")?)?;
        let stats = NormStats::from_json(h.req("norm")?)?;
        let tau = h.req("tau")?.as_f64().unwrap_or(0.0) as f32;
        let bin = h.req("latent_bin")?.as_f64().unwrap_or(0.0) as f32;
        ensure!(
            h.req("ae_group")?.as_str().unwrap_or("") == self.comp.ae.group,
            "archive AE group mismatch"
        );
        if let Some(r) = region {
            r.validate_in(&dataset.dims)?;
        }
        let q = Quantizer::new(bin.max(0.0));
        let lat_rows = decode_latents(archive.section("GLAT")?, q)?;
        let corr_rows = if archive.has_section("GCLT") {
            Some(decode_latents(archive.section("GCLT")?, q)?)
        } else {
            None
        };
        let mut recon = self.comp.decode(&lat_rows, corr_rows.as_deref())?;
        gae_restore_stage_region(&dataset, &stats, tau, archive, &mut recon, region)?;
        Normalizer::invert(&stats, &mut recon);
        Ok(recon)
    }
}
