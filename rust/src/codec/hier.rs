//! [`Codec`] adapter for the paper's hierarchical attention pipeline.
//!
//! Owns a trained [`HierCompressor`] (runtime handle + HBAE/BAE params)
//! and maps the typed [`ErrorBound`] onto the per-GAE-block ℓ2 τ the
//! pipeline guarantees. Also hosts the streaming entry point that routes
//! the L3 coordinator through the same archive assembly as the one-shot
//! path, so streaming and sequential compression share all config code.

use crate::coder::Quantizer;
use crate::compressor::{gae_bound_stage, Archive, HierCompressor};
use crate::coordinator::{stream_forward, StreamStats};
use crate::data::Normalizer;
use crate::tensor::Tensor;
use crate::Result;
use anyhow::ensure;

use super::{Codec, ErrorBound};

/// Hierarchical (HBAE + BAE + GAE) codec.
pub struct HierCodec {
    comp: HierCompressor,
}

impl HierCodec {
    pub fn new(comp: HierCompressor) -> Self {
        Self { comp }
    }

    /// The underlying pipeline (for experiment runners that sweep
    /// quantization bins or inspect the trained stack).
    pub fn compressor(&self) -> &HierCompressor {
        &self.comp
    }

    pub fn compressor_mut(&mut self) -> &mut HierCompressor {
        &mut self.comp
    }

    /// Compress through the streaming coordinator (pipelined gather →
    /// PJRT → sink stages over bounded channels) instead of the
    /// sequential loop. Produces the **same self-describing archive** as
    /// [`Codec::compress`]; returns the per-stage timing alongside.
    pub fn compress_streaming(
        &self,
        field: &Tensor,
        bound: &ErrorBound,
        queue_depth: usize,
    ) -> Result<(Archive, StreamStats)> {
        let dataset = &self.comp.dataset;
        ensure!(field.shape() == &dataset.dims[..], "field shape mismatch");
        let qh = Quantizer::new(self.comp.model.bin_hbae.max(0.0));
        let qb = Quantizer::new(self.comp.model.bin_bae.max(0.0));
        ensure!(
            qh.enabled() && qb.enabled(),
            "streaming archive path requires quantized latents (bins > 0)"
        );

        let stats = Normalizer::fit(dataset.normalization, field);
        let mut norm = field.clone();
        Normalizer::apply(&stats, &mut norm);

        let out = stream_forward(&self.comp, &norm, queue_depth)?;
        let lh_all = qh.dequant_all(&out.lh_codes);
        let lb_all = vec![qb.dequant_all(&out.lb_codes)];

        let tau = bound.gae_tau(dataset, field.range() as f64);
        let mut recon = out.recon;
        let gae = gae_bound_stage(dataset, &stats, tau, &norm, &mut recon)?;
        let mut archive = self.comp.build_archive(&stats, tau, &lh_all, &lb_all, gae);
        archive.set_header("bound", bound.to_json());
        Ok((archive, out.stats))
    }
}

impl Codec for HierCodec {
    fn id(&self) -> &str {
        "hier"
    }

    fn compress(&self, field: &Tensor, bound: &ErrorBound) -> Result<Archive> {
        self.compress_with_recon(field, bound).map(|(archive, _)| archive)
    }

    fn compress_with_recon(
        &self,
        field: &Tensor,
        bound: &ErrorBound,
    ) -> Result<(Archive, Tensor)> {
        let tau = bound.gae_tau(&self.comp.dataset, field.range() as f64);
        let (mut archive, recon) = self.comp.compress(field, tau)?;
        archive.set_header("bound", bound.to_json());
        Ok((archive, recon))
    }

    fn decompress(&self, archive: &Archive) -> Result<Tensor> {
        self.comp.decompress(archive)
    }

    fn decompress_region(
        &self,
        archive: &Archive,
        region: &crate::data::Region,
    ) -> Result<Tensor> {
        // AE latents are whole-stream coded, so the stack decodes fully;
        // the GAE correction stage runs only on the region's blocks
        self.comp.decompress_region(archive, region)
    }
}
