//! Unified compression API: one [`Codec`] trait + typed [`ErrorBound`]
//! across the hierarchical pipeline, every baseline, and the streaming
//! coordinator.
//!
//! The paper's headline claim is a comparison of error-bounded
//! compressors under shared accounting; this module is that comparison's
//! API surface. Every compressor is a [`Codec`]:
//!
//! ```text
//!   fn id(&self) -> &str;
//!   fn compress(&self, field, bound: &ErrorBound) -> Result<Archive>;
//!   fn decompress(&self, archive) -> Result<Tensor>;
//! ```
//!
//! Archives are **self-describing**: the header records the codec id, the
//! full [`DatasetConfig`], normalization stats, and model group names, so
//! [`CodecBuilder::for_archive`] restores a field from the bytes alone —
//! no preset flags. Construction goes through [`CodecBuilder`], which
//! resolves presets, lazily opens the PJRT runtime (only learned codecs
//! need it), and caches training checkpoints.
//!
//! | old entry point                              | unified API |
//! |----------------------------------------------|-------------|
//! | `HierCompressor::prepare` + `compress(f,tau)`| `builder.build(Hier, kind, &f)` + `codec.compress(&f, &bound)` |
//! | `Sz3Like::new(eps).compress` / static decode | `builder.build(Sz3, ..)` — ε derived from the bound |
//! | `ZfpLike::new(precision)`                    | `builder.build(Zfp, ..)` — precision certified against the bound |
//! | `GbaeCompressor::compress(f, bin, tau)`      | `builder.build(Gbae, ..)` — now with a real decode path |
//! | `coordinator::stream_compress`               | `HierCodec::compress_streaming` — same archive as one-shot |

mod adaptive;
mod bound;
mod builder;
mod gbae;
mod hier;
mod sz3;
mod tiled;
mod zfp;

pub use adaptive::{with_tile_codec, AdaptiveCodec, TileCodec};
pub(crate) use adaptive::{forced_tile_codec, set_forced_tile_codec};
pub use bound::ErrorBound;
pub use builder::{CodecBuilder, CodecKind, CODEC_IDS};
pub use gbae::GbaeCodec;
pub use hier::HierCodec;
pub use sz3::Sz3Codec;
pub use zfp::ZfpCodec;

use crate::compressor::{compression_ratio, Archive, CompressStats};
use crate::config::DatasetConfig;
use crate::data::Region;
use crate::tensor::Tensor;
use crate::util::json::Value;
use crate::Result;

/// An error-bounded compressor behind the unified API.
pub trait Codec {
    /// Stable codec id, recorded in archive headers (`hier`, `sz3`, ...).
    fn id(&self) -> &str;

    /// Compress a field under a typed error bound into a self-describing
    /// archive.
    fn compress(&self, field: &Tensor, bound: &ErrorBound) -> Result<Archive>;

    /// Restore a field from an archive produced by this codec.
    fn decompress(&self, archive: &Archive) -> Result<Tensor>;

    /// Restore only `region` (a half-open hyper-rectangle) of a field.
    ///
    /// Bit-identical to cropping a full decode, on every codec and every
    /// archive version. The default decodes fully and crops — correct
    /// for v1/v2 archives, whose payloads are whole-stream coded; codecs
    /// with a v3 block index override this to decode only the blocks the
    /// region intersects.
    fn decompress_region(&self, archive: &Archive, region: &Region) -> Result<Tensor> {
        let full = self.decompress(archive)?;
        region.crop(&full)
    }

    /// Compress and also return the reconstruction. The default decodes
    /// the archive it just built; codecs whose forward pass already
    /// yields the reconstruction (hier, gbae) override this to avoid the
    /// second pass.
    fn compress_with_recon(
        &self,
        field: &Tensor,
        bound: &ErrorBound,
    ) -> Result<(Archive, Tensor)> {
        let archive = self.compress(field, bound)?;
        let recon = self.decompress(&archive)?;
        Ok((archive, recon))
    }

    /// Compress a *temporal residual* (current frame minus the previous
    /// frame's reconstruction) so that the absolute reconstructed frame
    /// satisfies `bound`. The bound is translated into residual terms by
    /// [`ErrorBound::for_residual`] using `frame_range` (the current
    /// frame's value range), and the archive is stamped
    /// `temporal: "residual"` so tooling can tell a residual archive
    /// from a keyframe one. Used by [`crate::stream::StreamWriter`];
    /// keyframes go through plain [`Codec::compress_with_recon`] and
    /// stay byte-identical to independently-compressed frames.
    fn compress_residual(
        &self,
        residual: &Tensor,
        bound: &ErrorBound,
        frame_range: f64,
    ) -> Result<(Archive, Tensor)> {
        let rb = bound.for_residual(frame_range);
        let (mut archive, recon) = self.compress_with_recon(residual, &rb)?;
        archive.set_header("temporal", crate::util::json::s("residual"));
        Ok((archive, recon))
    }
}

/// Common archive header fields every codec writes (codec id, bound,
/// dataset config) — the base of self-description.
pub(crate) fn base_header(
    id: &str,
    dataset: &DatasetConfig,
    bound: &ErrorBound,
) -> Vec<(String, Value)> {
    vec![
        ("codec".to_string(), crate::util::json::s(id)),
        ("bound".to_string(), bound.to_json()),
        ("dataset".to_string(), dataset.to_json()),
    ]
}

/// Compression statistics computed from a self-describing archive alone
/// (the dataset geometry comes from the header). Works for both v1
/// single-field archives and v2 multi-field containers — the CR
/// numerator of a set is `total_points x field_count`, the denominator
/// the summed per-field payloads.
pub fn archive_stats(archive: &Archive) -> Result<CompressStats> {
    let dataset = DatasetConfig::from_json(archive.header.req("dataset")?)?;
    let fields = if archive.is_multi_field() { archive.field_count().max(1) } else { 1 };
    let n_points = dataset.total_points() * fields;
    let payload = archive.cr_payload_bytes();
    let total = archive.total_bytes();
    Ok(CompressStats {
        archive_bytes: total,
        cr_payload_bytes: payload,
        cr: compression_ratio(n_points, payload),
        cr_total: compression_ratio(n_points, total),
        gae_corrected_blocks: 0,
        gae_total_coeffs: 0,
        section_sizes: archive.section_sizes(),
    })
}

/// The error bound an archive was written under (`None` for pre-codec
/// archives without the header field).
pub fn archive_bound(archive: &Archive) -> ErrorBound {
    archive
        .header
        .get("bound")
        .and_then(|v| ErrorBound::from_json(v).ok())
        .unwrap_or(ErrorBound::None)
}
