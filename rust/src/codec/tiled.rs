//! Shared v3 tiled-payload machinery for the block-granular codecs.
//!
//! A v3 archive's payload is a concatenation of independently-decodable
//! per-tile streams (tile = the dataset's AE block shape), described by a
//! [`BlockIndex`]. Encode fans the tiles out across the shared
//! [`Executor`] and concatenates in tile order — byte-identical at every
//! thread count, like every other parallel stage. Decode touches only
//! the entries of the requested tiles: a full decode asks for all of
//! them, a region decode for the intersecting ones, and both reassemble
//! through the `data::blocking` scatter helpers.
//!
//! Per-tile coding is allocation-light: the tile extract buffer and the
//! codecs' recon/code/entropy buffers all come from the worker's
//! per-thread [`Scratch`] arena, so the hot loop stops paying one fresh
//! `Vec` per tile per stage. The extract buffer is moved out of
//! `f32_b` for the duration of the encode callback, so tile encoders
//! use the remaining fields (sz3's row-base pass sits in `f32_c`).

use crate::compressor::BlockIndex;
use crate::data::{region_tile_ids, scatter_tile_into_region, Region};
use crate::engine::{reuse_f32, Executor, Scratch};
use crate::tensor::{block_origins, extract_block, Tensor};
use crate::Result;
use anyhow::ensure;

/// Tile a field and encode every tile independently. Returns the
/// concatenated payload plus the block index over it. `encode_tile`
/// receives `(tile shape, tile data, scratch)` — the data slice lives in
/// the per-thread arena, so implementations must not stash it.
pub(crate) fn encode_tiled<F>(
    field: &Tensor,
    tile: &[usize],
    encode_tile: F,
) -> Result<(Vec<u8>, BlockIndex)>
where
    F: Fn(&[usize], &[f32], &mut Scratch) -> Result<Vec<u8>> + Sync,
{
    // clamp each tile dim to the field dim: a tile larger than the field
    // only adds padding, and `BlockIndex::validate` bounds untrusted tile
    // shapes by the field geometry on decode
    let tile: Vec<usize> = tile
        .iter()
        .zip(field.shape())
        .map(|(&t, &d)| t.min(d).max(1))
        .collect();
    let origins = block_origins(field.shape(), &tile);
    let tile_len: usize = tile.iter().product();
    let parts: Vec<Vec<u8>> = Executor::global().try_par_map_scratch(origins.len(), |i, s| {
        let _span = crate::obs::stages::TILE_ENCODE.span();
        // the tile buffer is moved out of the arena for the call so the
        // encoder can use the remaining scratch fields freely
        let mut buf = std::mem::take(&mut s.f32_b);
        reuse_f32(&mut buf, tile_len);
        extract_block(field, &origins[i], &tile, &mut buf);
        let r = encode_tile(&tile, &buf, s);
        s.f32_b = buf;
        r
    })?;
    let mut payload = Vec::with_capacity(parts.iter().map(Vec::len).sum());
    let mut entries = Vec::with_capacity(parts.len());
    for p in &parts {
        entries.push((payload.len() as u64, p.len() as u64));
        payload.extend_from_slice(p);
    }
    Ok((payload, BlockIndex { tile, entries, codecs: None }))
}

/// Decode the tiles of a v3 payload that intersect `region` (all tiles
/// when `None`) and reassemble them into a tensor shaped as the region
/// (the full field when `None`). Only the indexed byte spans of the
/// selected tiles are ever sliced — the acceptance contract of the
/// region path. `decode_tile` receives `(tile id, tile bytes, scratch)`;
/// the id lets mixed-codec payloads dispatch on the index's per-tile
/// codec ids (homogeneous codecs ignore it).
pub(crate) fn decode_tiled<F>(
    payload: &[u8],
    index: &BlockIndex,
    dims: &[usize],
    region: Option<&Region>,
    decode_tile: F,
) -> Result<Tensor>
where
    F: Fn(usize, &[u8], &mut Scratch) -> Result<Tensor> + Sync,
{
    index.validate(dims, payload.len())?;
    let origins = block_origins(dims, &index.tile);
    let full = Region::full(dims);
    let r = match region {
        Some(r) => {
            r.validate_in(dims)?;
            r
        }
        None => &full,
    };
    let ids = region_tile_ids(dims, &index.tile, r);
    let tiles: Vec<Tensor> = Executor::global().try_par_map_scratch(ids.len(), |i, s| {
        let _span = crate::obs::stages::TILE_DECODE.span();
        let (off, len) = index.entry(ids[i])?;
        let t = decode_tile(ids[i], &payload[off..off + len], s)?;
        ensure!(
            t.shape() == &index.tile[..],
            "tile {} decoded to shape {:?}, index says {:?}",
            ids[i],
            t.shape(),
            index.tile
        );
        Ok(t)
    })?;
    let mut out = Tensor::zeros(r.shape());
    for (&id, t) in ids.iter().zip(&tiles) {
        scatter_tile_into_region(&mut out, r, &origins[id], &index.tile, t.data());
    }
    Ok(out)
}
