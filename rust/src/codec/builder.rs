//! [`CodecBuilder`] — one construction path for every codec.
//!
//! Resolves dataset/model presets, opens the PJRT runtime lazily (only
//! the learned codecs need it — `sz3`/`zfp` build and run without
//! artifacts), trains or loads cached checkpoints, and — the key piece
//! for self-describing archives — rebuilds the right codec **from an
//! archive header alone** via [`CodecBuilder::for_archive`], so
//! `attn-reduce decompress` needs no dataset or preset flags.

use std::path::PathBuf;
use std::rc::Rc;

use crate::baselines::GbaeCompressor;
use crate::compressor::{Archive, HierCompressor};
use crate::config::{
    dataset_preset, model_preset, DatasetConfig, DatasetKind, ModelConfig, PipelineConfig,
    Scale, TrainConfig,
};
use crate::model::ParamStore;
use crate::runtime::Runtime;
use crate::tensor::Tensor;
use crate::Result;
use anyhow::{bail, ensure, Context};

use super::{AdaptiveCodec, Codec, GbaeCodec, HierCodec, Sz3Codec, ZfpCodec};

/// The codecs the unified API can construct.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CodecKind {
    Hier,
    Sz3,
    Zfp,
    Gbae,
    /// Per-tile sz3 | zfp selection at equal bound (mixed-codec archives).
    Adaptive,
}

/// All codec ids, in CLI help order.
pub const CODEC_IDS: [&str; 5] = ["hier", "sz3", "zfp", "gbae", "adaptive"];

impl CodecKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "hier" => Ok(Self::Hier),
            "sz3" => Ok(Self::Sz3),
            "zfp" => Ok(Self::Zfp),
            "gbae" => Ok(Self::Gbae),
            "adaptive" => Ok(Self::Adaptive),
            other => bail!("unknown codec {other:?} (have: {CODEC_IDS:?})"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Hier => "hier",
            Self::Sz3 => "sz3",
            Self::Zfp => "zfp",
            Self::Gbae => "gbae",
            Self::Adaptive => "adaptive",
        }
    }
}

/// Builder resolving presets, runtime, and checkpoints into codecs.
pub struct CodecBuilder {
    artifacts: PathBuf,
    ckpt_dir: PathBuf,
    scale: Scale,
    train: TrainConfig,
    rt: Option<Rc<Runtime>>,
}

impl Default for CodecBuilder {
    fn default() -> Self {
        Self {
            artifacts: PathBuf::from("artifacts"),
            ckpt_dir: PathBuf::from("results/ckpt"),
            scale: Scale::Bench,
            train: TrainConfig::default(),
            rt: None,
        }
    }
}

impl CodecBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    /// AOT artifacts directory (default `artifacts`).
    pub fn artifacts(mut self, dir: impl Into<PathBuf>) -> Self {
        self.artifacts = dir.into();
        self
    }

    /// Checkpoint cache directory (default `results/ckpt`).
    pub fn ckpt_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.ckpt_dir = dir.into();
        self
    }

    /// Dataset scale preset (default [`Scale::Bench`]).
    pub fn scale(mut self, scale: Scale) -> Self {
        self.scale = scale;
        self
    }

    /// Training hyper-parameters used when checkpoints are absent.
    pub fn train(mut self, train: TrainConfig) -> Self {
        self.train = train;
        self
    }

    /// Inject an already-open runtime (shared across builders/codecs).
    pub fn runtime(mut self, rt: Rc<Runtime>) -> Self {
        self.rt = Some(rt);
        self
    }

    /// The runtime handle, opening `artifacts/` on first use.
    pub fn runtime_handle(&mut self) -> Result<Rc<Runtime>> {
        if let Some(rt) = &self.rt {
            return Ok(rt.clone());
        }
        let rt = Rc::new(Runtime::open(&self.artifacts)?);
        self.rt = Some(rt.clone());
        Ok(rt)
    }

    fn dataset(&self, kind: DatasetKind) -> DatasetConfig {
        dataset_preset(kind, self.scale)
    }

    /// Build a codec for a dataset preset. `field` is the training input
    /// for the learned codecs when no checkpoint is cached yet (the
    /// baselines ignore it).
    pub fn build(
        &mut self,
        codec: CodecKind,
        kind: DatasetKind,
        field: &Tensor,
    ) -> Result<Box<dyn Codec>> {
        Ok(match codec {
            CodecKind::Sz3 => Box::new(Sz3Codec::new(self.dataset(kind))),
            CodecKind::Zfp => Box::new(ZfpCodec::new(self.dataset(kind))),
            CodecKind::Adaptive => Box::new(AdaptiveCodec::new(self.dataset(kind))),
            CodecKind::Hier => Box::new(self.build_hier(kind, field)?),
            CodecKind::Gbae => Box::new(self.build_gbae(kind, field)?),
        })
    }

    /// Typed variant of [`Self::build`] for the hierarchical codec (the
    /// concrete type exposes [`HierCodec::compress_streaming`]).
    pub fn build_hier(&mut self, kind: DatasetKind, field: &Tensor) -> Result<HierCodec> {
        let rt = self.runtime_handle()?;
        let cfg = PipelineConfig {
            dataset: self.dataset(kind),
            model: model_preset(kind),
            train: self.train.clone(),
            tau: 0.0,
        };
        std::fs::create_dir_all(&self.ckpt_dir)?;
        let (comp, _reports) = HierCompressor::prepare(&rt, &cfg, &self.ckpt_dir, field)?;
        Ok(HierCodec::new(comp))
    }

    /// Typed variant of [`Self::build`] for the GBAE baseline codec.
    pub fn build_gbae(&mut self, kind: DatasetKind, field: &Tensor) -> Result<GbaeCodec> {
        let rt = self.runtime_handle()?;
        let dataset = self.dataset(kind);
        let model = model_preset(kind);
        std::fs::create_dir_all(&self.ckpt_dir)?;
        let (comp, _reports) = GbaeCompressor::prepare(
            &rt,
            &dataset,
            &model.bae_group,
            &self.ckpt_dir,
            field,
            &self.train,
            None,
        )?;
        Ok(GbaeCodec::new(comp, model.bin_bae))
    }

    /// Rebuild the codec an archive was written with, using only its
    /// header: codec id, dataset config, and model group names all come
    /// from the archive. Learned codecs load their cached checkpoints
    /// (decompression never trains — a missing checkpoint is an error).
    ///
    /// For a v2 multi-field container the codec is rebuilt from the
    /// first embedded field archive (all fields of a set share the codec,
    /// dataset config, and model groups); pair it with
    /// [`crate::engine::CodecExt::decompress_set`].
    pub fn for_archive(&mut self, archive: &Archive) -> Result<Box<dyn Codec>> {
        if archive.is_multi_field() {
            ensure!(
                archive.field_count() > 0,
                "v2 container holds no field archives"
            );
            return self.for_archive(&archive.field_archive(0)?);
        }
        let h = &archive.header;
        let id = archive
            .header_str("codec")
            .context("archive header missing codec id (pre-codec archive?)")?
            .to_string();
        let dataset = DatasetConfig::from_json(h.req("dataset")?)?;
        Ok(match id.as_str() {
            "sz3" => Box::new(Sz3Codec::new(dataset)),
            "zfp" => Box::new(ZfpCodec::new(dataset)),
            "adaptive" => Box::new(AdaptiveCodec::new(dataset)),
            "hier" => {
                let model = ModelConfig::from_json(h.req("model")?)?;
                let rt = self.runtime_handle()?;
                let hgroup = archive.header_str("hbae_group")?.to_string();
                let bgroups: Vec<String> = h
                    .req("bae_groups")?
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(|v| v.as_str().map(String::from))
                    .collect();
                let hbae = ParamStore::load(
                    ParamStore::default_path(&self.ckpt_dir, &hgroup),
                    &hgroup,
                )
                .context("loading HBAE checkpoint (run `attn-reduce train` first)")?;
                let baes: Vec<ParamStore> = bgroups
                    .iter()
                    .map(|g| ParamStore::load(ParamStore::default_path(&self.ckpt_dir, g), g))
                    .collect::<Result<_>>()
                    .context("loading BAE checkpoint (run `attn-reduce train` first)")?;
                Box::new(HierCodec::new(HierCompressor {
                    rt,
                    dataset,
                    model,
                    hbae,
                    baes,
                }))
            }
            "gbae" => {
                let rt = self.runtime_handle()?;
                let group = archive.header_str("ae_group")?.to_string();
                let bin = h.req("latent_bin")?.as_f64().unwrap_or(0.0) as f32;
                let ae = ParamStore::load(
                    GbaeCompressor::ckpt_path(&self.ckpt_dir, &group),
                    &group,
                )
                .context("loading GBAE checkpoint (compress with --codec gbae first)")?;
                let corrector = match h.get("corrector_group").and_then(|v| v.as_str()) {
                    Some(cg) => Some(ParamStore::load(
                        GbaeCompressor::corrector_ckpt_path(&self.ckpt_dir, cg),
                        cg,
                    )?),
                    None => None,
                };
                Box::new(GbaeCodec::new(
                    GbaeCompressor { rt, dataset, ae, corrector },
                    bin,
                ))
            }
            other => bail!("unknown codec {other:?} in archive header"),
        })
    }
}
