//! [`Codec`] adapter for *adaptive per-tile codec selection* (sz3 | zfp
//! per tile at one typed bound).
//!
//! The hybrid-compression observation (PAPERS.md, "Scalable Hybrid
//! Learning Techniques for Scientific Data Compression") is that the
//! biggest CR wins come from choosing the right compressor *per block*
//! rather than one codec per archive. This codec trial-compresses every
//! AE-block tile under the SZ3-like predictor and the ZFP-like transform
//! at the same pointwise ε and keeps the smaller stream, recording the
//! winner in the block index codec-id trailer (index minor version 1 —
//! see [`crate::compressor::BlockIndex`]). Decode dispatches per tile on
//! the recorded id, so mixed archives are first-class through full
//! decode, `decompress_region`, the v4 stream paths, and the serve
//! routes.
//!
//! **Bound semantics.** Both candidate encoders certify the same
//! pointwise ε derived from the typed [`ErrorBound`]
//! ([`ErrorBound::pointwise_eps`]): sz3 quantizes against ε directly,
//! and zfp binary-searches the smallest precision whose *tile*
//! reconstruction stays within ε pointwise. A per-tile pointwise
//! guarantee implies the global guarantee for every bound kind, so
//! mixing codecs never weakens the archive's bound.
//!
//! **Selection cost.** The sz3 pass is single-shot and always runs (it
//! is also the fallback when zfp cannot certify ε — the transform is
//! near-lossless, not lossless). The zfp certification is a
//! ~`log2(26)`-trial encode+decode search, so dense tiles gate it behind
//! a sampled scaled-size trial (the `coder/lossless.rs` mode-trial
//! pattern, one level up): a centered half-size window of the tile is
//! encoded both ways, sizes are scaled to the full tile with framing
//! treated as fixed cost, and the full zfp search only runs when the
//! sample says zfp is within [`GATE_SKIP_FACTOR`] of sz3. Small tiles
//! (< [`GATE_MIN_POINTS`] points) always pay both full encodes, so the
//! "adaptive ≤ min(forced sz3, forced zfp)" guarantee is exact there.
//!
//! **A/B pinning.** [`with_tile_codec`] forces the selection
//! thread-locally (mirroring
//! [`crate::coder::lossless::with_symbol_mode`]); the [`Executor`]
//! propagates the forcing context to its pool workers for the duration
//! of a batch, so forcing is byte-identical at every thread count. A
//! forced `Zfp` still degrades to sz3 for tiles the transform cannot
//! certify — same spirit as forced symbol modes degrading to plain.

use std::cell::Cell;

use crate::baselines::{Sz3Like, ZfpLike};
use crate::compressor::{Archive, BlockIndex};
use crate::config::DatasetConfig;
use crate::data::Region;
use crate::engine::{reuse_f32, Executor, Scratch};
use crate::tensor::{block_origins, extract_block, Tensor};
use crate::util::json;
use crate::Result;
use anyhow::{anyhow, bail, ensure};

use super::zfp::DEFAULT_PRECISION;
use super::{base_header, tiled, Codec, ErrorBound};

const MAX_PRECISION: u32 = 26;

/// Tiles below this point count pay both full encodes (both are cheap
/// there, and the size comparison is exact). At or above it, the zfp
/// certification search is gated behind the sampled trial.
const GATE_MIN_POINTS: usize = 4096;

/// Hysteresis of the sampled trial, in sz3's favor: the full zfp search
/// only runs when the scaled zfp estimate is within this factor of the
/// scaled sz3 estimate. Skipping requires zfp to look *decisively*
/// worse on the sample, so a winning zfp tile is essentially never
/// skipped.
const GATE_SKIP_FACTOR: f64 = 1.10;

/// Per-tile stream format recorded in the block index codec-id trailer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TileCodec {
    /// SZ3-like prediction stream (codec id 0).
    Sz3,
    /// ZFP-like transform stream (codec id 1).
    Zfp,
}

impl TileCodec {
    /// The on-disk codec id (the byte stored in the index trailer).
    pub const fn id(self) -> u8 {
        match self {
            Self::Sz3 => 0,
            Self::Zfp => 1,
        }
    }

    /// Parse an on-disk codec id; unknown ids are a typed error (fuzzed
    /// archives must never panic or dispatch to an undefined decoder).
    pub fn from_id(id: u8) -> Result<Self> {
        match id {
            0 => Ok(Self::Sz3),
            1 => Ok(Self::Zfp),
            other => bail!("unknown per-tile codec id {other}"),
        }
    }

    /// Human-readable name (`cli info` breakdowns).
    pub const fn name(self) -> &'static str {
        match self {
            Self::Sz3 => "sz3",
            Self::Zfp => "zfp",
        }
    }
}

thread_local! {
    static TILE_CODEC: Cell<Option<TileCodec>> = const { Cell::new(None) };
}

/// Force the per-tile codec for the duration of `f` on this thread (A/B
/// tests and benches; the previous setting is restored even if `f`
/// panics). The [`Executor`] captures the forcing context at batch
/// submission and installs it on its workers, so a force wrapped around
/// a parallel compress is byte-identical at every thread count. A forced
/// `Zfp` still falls back to sz3 for tiles the transform cannot certify
/// at the requested ε.
pub fn with_tile_codec<R>(codec: TileCodec, f: impl FnOnce() -> R) -> R {
    struct Restore(Option<TileCodec>);
    impl Drop for Restore {
        fn drop(&mut self) {
            let prev = self.0;
            TILE_CODEC.with(|m| m.set(prev));
        }
    }
    let _restore = Restore(TILE_CODEC.with(|m| m.replace(Some(codec))));
    f()
}

/// The thread's forced tile codec, if any (executor force-context capture).
pub(crate) fn forced_tile_codec() -> Option<TileCodec> {
    TILE_CODEC.with(|m| m.get())
}

/// Overwrite the thread's forced tile codec (executor force-context install).
pub(crate) fn set_forced_tile_codec(codec: Option<TileCodec>) {
    TILE_CODEC.with(|m| m.set(codec));
}

/// The zfp stream for one tile: fixed precision when the bound is
/// `None`, else the smallest precision whose tile reconstruction stays
/// within `eps` pointwise (`None` when even max precision cannot — the
/// caller falls back to sz3, which certifies ε by construction).
fn zfp_tile_stream(
    shape: &[usize],
    data: &[f32],
    eps: f32,
    fixed_precision: Option<u32>,
    s: &mut Scratch,
) -> Result<Option<Vec<u8>>> {
    if let Some(p) = fixed_precision {
        return Ok(Some(ZfpLike::new(p).compress_scratch(shape, data, s)?));
    }
    // binary search the smallest certifying precision in [1, 26]; the
    // error is monotone non-increasing in precision, so this is sound
    let (mut lo, mut hi) = (1u32, MAX_PRECISION);
    let mut best: Option<Vec<u8>> = None;
    while lo <= hi {
        let mid = (lo + hi) / 2;
        let stream = ZfpLike::new(mid).compress_scratch(shape, data, s)?;
        let recon = ZfpLike::decompress_capped_scratch(&stream, data.len(), s)?;
        let ok = recon
            .data()
            .iter()
            .zip(data)
            .all(|(&r, &v)| (r - v).abs() <= eps);
        if ok {
            best = Some(stream);
            if mid == 1 {
                break;
            }
            hi = mid - 1;
        } else {
            lo = mid + 1;
        }
    }
    Ok(best)
}

/// Centered half-size window of a tile, for the sampled selection trial
/// (contiguous inner rows, so the copy is cheap and the window keeps
/// the tile's local structure).
fn centered_window(shape: &[usize], data: &[f32]) -> (Vec<usize>, Vec<f32>) {
    let sub: Vec<usize> = shape.iter().map(|&d| (d / 2).max(1)).collect();
    let lo: Vec<usize> = shape.iter().zip(&sub).map(|(&d, &s)| (d - s) / 2).collect();
    let rank = shape.len();
    let mut strides = vec![1usize; rank];
    for i in (0..rank.saturating_sub(1)).rev() {
        strides[i] = strides[i + 1] * shape[i + 1];
    }
    let row = sub[rank - 1];
    let n: usize = sub.iter().product();
    let mut out = Vec::with_capacity(n);
    let mut idx = vec![0usize; rank - 1];
    'outer: loop {
        let base: usize = idx
            .iter()
            .zip(&lo)
            .zip(&strides)
            .map(|((&i, &l), &st)| (i + l) * st)
            .sum::<usize>()
            + lo[rank - 1];
        out.extend_from_slice(&data[base..base + row]);
        for d in (0..rank - 1).rev() {
            idx[d] += 1;
            if idx[d] < sub[d] {
                continue 'outer;
            }
            idx[d] = 0;
        }
        break;
    }
    debug_assert_eq!(out.len(), n);
    (sub, out)
}

/// Full-tile stream size estimated from the sampled window: per-stream
/// framing (magic/precision, rank, dims, section lengths) is a fixed
/// cost, the coded payload scales with the point ratio — the same shape
/// as `coder/lossless.rs`'s `scaled_estimate`, one level up.
fn scaled_stream_estimate(sample_bytes: usize, rank: usize, scale: f64) -> f64 {
    let fixed = 29 + 8 * rank;
    fixed as f64 + sample_bytes.saturating_sub(fixed) as f64 * scale
}

/// Encode one tile under the winning codec at equal pointwise ε,
/// returning the stream and the codec id to record. Each call is one
/// `adaptive.trial` span and bumps `attn_adaptive_tiles_total` for the
/// committed codec (forced tiles count too — they are committed tiles).
fn encode_tile_select(
    shape: &[usize],
    data: &[f32],
    eps: f32,
    fixed_precision: Option<u32>,
    s: &mut Scratch,
) -> Result<(Vec<u8>, TileCodec)> {
    let _span = crate::obs::stages::ADAPTIVE_TRIAL.span();
    let (stream, codec) = encode_tile_select_inner(shape, data, eps, fixed_precision, s)?;
    crate::obs::adaptive_tile(codec.name());
    Ok((stream, codec))
}

fn encode_tile_select_inner(
    shape: &[usize],
    data: &[f32],
    eps: f32,
    fixed_precision: Option<u32>,
    s: &mut Scratch,
) -> Result<(Vec<u8>, TileCodec)> {
    let sz3 = |s: &mut Scratch| Sz3Like::new(eps).compress_scratch(shape, data, s);
    match forced_tile_codec() {
        Some(TileCodec::Sz3) => return Ok((sz3(s)?, TileCodec::Sz3)),
        Some(TileCodec::Zfp) => {
            return match zfp_tile_stream(shape, data, eps, fixed_precision, s)? {
                Some(stream) => Ok((stream, TileCodec::Zfp)),
                // the transform cannot certify ε on this tile: degrade
                // to sz3 (which can, by construction) instead of failing
                None => Ok((sz3(s)?, TileCodec::Sz3)),
            };
        }
        None => {}
    }
    let sz3_stream = sz3(s)?;
    if data.len() >= GATE_MIN_POINTS {
        // sampled scaled-size trial: skip the zfp certification search
        // when zfp decisively loses on a centered half-size window
        let (sub_shape, sub_data) = centered_window(shape, data);
        let scale = data.len() as f64 / sub_data.len() as f64;
        let sz3_sample = Sz3Like::new(eps).compress_scratch(&sub_shape, &sub_data, s)?;
        let skip = match zfp_tile_stream(&sub_shape, &sub_data, eps, fixed_precision, s)? {
            None => true, // cannot even certify the sample
            Some(zfp_sample) => {
                scaled_stream_estimate(zfp_sample.len(), sub_shape.len(), scale)
                    > scaled_stream_estimate(sz3_sample.len(), sub_shape.len(), scale)
                        * GATE_SKIP_FACTOR
            }
        };
        if skip {
            crate::obs::adaptive_gate_skip();
            return Ok((sz3_stream, TileCodec::Sz3));
        }
    }
    match zfp_tile_stream(shape, data, eps, fixed_precision, s)? {
        Some(zfp_stream) if zfp_stream.len() < sz3_stream.len() => {
            Ok((zfp_stream, TileCodec::Zfp))
        }
        // ties go to sz3: its decode path is the cheaper of the two
        _ => Ok((sz3_stream, TileCodec::Sz3)),
    }
}

/// Decode a mixed-codec tiled payload (whole field, or only `region`),
/// dispatching every tile on its recorded codec id. The per-tile cap is
/// the validated tile volume, so a corrupt stream cannot allocate past
/// the geometry no matter which decoder its id routes it to.
pub(crate) fn decode(
    payload: &[u8],
    index: &BlockIndex,
    dims: &[usize],
    region: Option<&Region>,
) -> Result<Tensor> {
    let codecs = index
        .codecs
        .as_ref()
        .ok_or_else(|| anyhow!("adaptive archive missing per-tile codec ids"))?;
    tiled::decode_tiled(payload, index, dims, region, |id, b, s| {
        let cap = index.tile.iter().product();
        let &cid = codecs
            .get(id)
            .ok_or_else(|| anyhow!("tile {id} has no codec id"))?;
        match TileCodec::from_id(cid)? {
            TileCodec::Sz3 => Sz3Like::decompress_capped_scratch(b, cap, s),
            TileCodec::Zfp => ZfpLike::decompress_capped_scratch(b, cap, s),
        }
    })
}

/// Adaptive per-tile codec (sz3 | zfp per tile, equal typed bound).
pub struct AdaptiveCodec {
    dataset: DatasetConfig,
}

impl AdaptiveCodec {
    pub fn new(dataset: DatasetConfig) -> Self {
        Self { dataset }
    }

    fn decode(&self, archive: &Archive, region: Option<&Region>) -> Result<Tensor> {
        let payload = archive.section("ADPB")?;
        let index = archive
            .block_index()?
            .ok_or_else(|| anyhow!("adaptive archive missing block index"))?;
        decode(payload, &index, &self.dataset.dims, region)
    }
}

impl Codec for AdaptiveCodec {
    fn id(&self) -> &str {
        "adaptive"
    }

    fn compress(&self, field: &Tensor, bound: &ErrorBound) -> Result<Archive> {
        ensure!(
            field.shape() == &self.dataset.dims[..],
            "field shape {:?} != dataset dims {:?}",
            field.shape(),
            self.dataset.dims
        );
        let eps = bound.pointwise_eps(&self.dataset, field.range() as f64);
        ensure!(
            eps.is_finite() && eps > 0.0,
            "bound {bound} yields eps {eps} (constant field or zero bound?)"
        );
        // `None` has no ε to certify: zfp trials run at the bench-default
        // fixed precision (like ZfpCodec), sz3 still quantizes against
        // the best-effort ε
        let fixed_precision = matches!(bound, ErrorBound::None).then_some(DEFAULT_PRECISION);
        let tile: Vec<usize> = self
            .dataset
            .ae_block
            .iter()
            .zip(field.shape())
            .map(|(&t, &d)| t.min(d).max(1))
            .collect();
        let origins = block_origins(field.shape(), &tile);
        let tile_len: usize = tile.iter().product();
        let parts: Vec<(Vec<u8>, TileCodec)> =
            Executor::global().try_par_map_scratch(origins.len(), |i, s| {
                let mut buf = std::mem::take(&mut s.f32_b);
                reuse_f32(&mut buf, tile_len);
                extract_block(field, &origins[i], &tile, &mut buf);
                let r = encode_tile_select(&tile, &buf, eps, fixed_precision, s);
                s.f32_b = buf;
                r
            })?;
        let mut payload = Vec::with_capacity(parts.iter().map(|(p, _)| p.len()).sum());
        let mut entries = Vec::with_capacity(parts.len());
        let mut codecs = Vec::with_capacity(parts.len());
        for (p, c) in &parts {
            entries.push((payload.len() as u64, p.len() as u64));
            payload.extend_from_slice(p);
            codecs.push(c.id());
        }
        let index = BlockIndex { tile, entries, codecs: Some(codecs) };
        let mut header = base_header(self.id(), &self.dataset, bound);
        header.push(("eps".to_string(), json::num(eps as f64)));
        let mut archive = Archive::new_v3(crate::util::json::Value::Obj(header));
        archive.add_section("ADPB", payload);
        archive.add_block_index(&index);
        Ok(archive)
    }

    fn decompress(&self, archive: &Archive) -> Result<Tensor> {
        self.decode(archive, None)
    }

    fn decompress_region(&self, archive: &Archive, region: &Region) -> Result<Tensor> {
        self.decode(archive, Some(region))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn centered_window_is_the_middle_half() {
        // 1-D: dims 8 -> sub 4 starting at 2
        let data: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let (sub, w) = centered_window(&[8], &data);
        assert_eq!(sub, vec![4]);
        assert_eq!(w, vec![2.0, 3.0, 4.0, 5.0]);
        // 2-D: 4x6 -> 2x3, rows 1..3, cols 1..4 (contiguous inner rows)
        let data: Vec<f32> = (0..24).map(|i| i as f32).collect();
        let (sub, w) = centered_window(&[4, 6], &data);
        assert_eq!(sub, vec![2, 3]);
        assert_eq!(w, vec![7.0, 8.0, 9.0, 13.0, 14.0, 15.0]);
        // a dim of 1 stays 1
        let (sub, w) = centered_window(&[1, 3], &[5.0, 6.0, 7.0]);
        assert_eq!(sub, vec![1, 1]);
        assert_eq!(w, vec![6.0]);
    }

    #[test]
    fn tile_codec_ids_round_trip_and_reject_unknown() {
        for c in [TileCodec::Sz3, TileCodec::Zfp] {
            assert_eq!(TileCodec::from_id(c.id()).unwrap(), c);
        }
        for bad in [2u8, 7, 255] {
            let err = TileCodec::from_id(bad).unwrap_err().to_string();
            assert!(err.contains("unknown per-tile codec id"), "{err}");
        }
    }

    #[test]
    fn with_tile_codec_restores_on_panic() {
        assert_eq!(forced_tile_codec(), None);
        let r = std::panic::catch_unwind(|| {
            with_tile_codec(TileCodec::Zfp, || {
                assert_eq!(forced_tile_codec(), Some(TileCodec::Zfp));
                panic!("boom");
            })
        });
        assert!(r.is_err());
        assert_eq!(forced_tile_codec(), None);
    }
}
