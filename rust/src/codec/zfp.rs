//! [`Codec`] adapter for the ZFP-like transform compressor.
//!
//! ZFP's knob is fixed precision, which has no closed-form map to an
//! error bound — so the adapter *certifies* the bound instead: binary
//! search over precision, decompressing each trial and keeping the
//! smallest precision whose reconstruction measurably satisfies the
//! requested [`ErrorBound`]. The error is monotone non-increasing in
//! precision, so the search is sound.
//!
//! Writes **Archive v3** like the SZ3 adapter: one independent
//! [`ZfpLike`] stream per AE-block tile plus a `BIDX` block index, so
//! [`Codec::decompress_region`] touches only the intersecting tiles.
//! Legacy v1 whole-stream archives keep decoding unchanged. Coefficient
//! streams ride the symbol container (plain Huffman+LZSS, interleaved
//! rANS, or zero-run / const — picked per tile by trial sampling).

use crate::baselines::ZfpLike;
use crate::compressor::{Archive, BlockIndex};
use crate::config::DatasetConfig;
use crate::data::Region;
use crate::tensor::Tensor;
use crate::util::json;
use crate::Result;
use anyhow::{bail, ensure};

use super::{base_header, tiled, Codec, ErrorBound};

/// Precision used for `ErrorBound::None` (best effort; matches the old
/// bench default). Shared with the adaptive codec's zfp trials.
pub(crate) const DEFAULT_PRECISION: u32 = 12;
const MAX_PRECISION: u32 = 26;

/// ZFP-like codec (4^d block transform + fixed precision), bound-certified.
pub struct ZfpCodec {
    dataset: DatasetConfig,
}

impl ZfpCodec {
    pub fn new(dataset: DatasetConfig) -> Self {
        Self { dataset }
    }

    /// Tiled (v3) encode of the whole field at one precision.
    fn encode(&self, field: &Tensor, precision: u32) -> Result<(Vec<u8>, BlockIndex)> {
        tiled::encode_tiled(field, &self.dataset.ae_block, |shape, data, s| {
            ZfpLike::new(precision).compress_scratch(shape, data, s)
        })
    }

    /// Decode through the v3 block index when present (optionally only a
    /// region), else fall back to the v1 whole-stream path.
    fn decode_archive(&self, archive: &Archive, region: Option<&Region>) -> Result<Tensor> {
        let payload = archive.section("ZFPB")?;
        match archive.block_index()? {
            Some(index) => decode(payload, &index, &self.dataset.dims, region),
            None => {
                // v1 legacy archive: whole-field stream; the header
                // geometry caps what a corrupt stream may allocate
                let full =
                    ZfpLike::decompress_capped(payload, self.dataset.total_points())?;
                match region {
                    Some(r) => r.crop(&full),
                    None => Ok(full),
                }
            }
        }
    }

    /// Smallest precision whose reconstruction satisfies `bound`, with
    /// its tiled payload + index.
    fn certify(
        &self,
        field: &Tensor,
        bound: &ErrorBound,
    ) -> Result<(u32, Vec<u8>, BlockIndex)> {
        let meets = |p: u32| -> Result<Option<(Vec<u8>, BlockIndex)>> {
            let (payload, index) = self.encode(field, p)?;
            let recon = decode(&payload, &index, &self.dataset.dims, None)?;
            if bound.satisfied_by(field, &recon, &self.dataset) {
                Ok(Some((payload, index)))
            } else {
                Ok(None)
            }
        };
        // binary search the smallest satisfying precision in [1, 26]
        let (mut lo, mut hi) = (1u32, MAX_PRECISION);
        let mut best: Option<(u32, Vec<u8>, BlockIndex)> = None;
        while lo <= hi {
            let mid = (lo + hi) / 2;
            match meets(mid)? {
                Some((payload, index)) => {
                    best = Some((mid, payload, index));
                    if mid == 1 {
                        break;
                    }
                    hi = mid - 1;
                }
                None => lo = mid + 1,
            }
        }
        match best {
            Some(found) => Ok(found),
            None => bail!(
                "zfp-like codec cannot certify bound {bound} even at precision \
                 {MAX_PRECISION} (transform is near-lossless, not lossless)"
            ),
        }
    }
}

/// Decode a tiled ZFP payload (whole field, or only `region`). The
/// per-tile cap is computed inside the closure: it only runs after
/// `decode_tiled` has validated the (untrusted) tile shape against the
/// field dims.
fn decode(
    payload: &[u8],
    index: &BlockIndex,
    dims: &[usize],
    region: Option<&Region>,
) -> Result<Tensor> {
    tiled::decode_tiled(payload, index, dims, region, |_, b, s| {
        ZfpLike::decompress_capped_scratch(b, index.tile.iter().product(), s)
    })
}

impl Codec for ZfpCodec {
    fn id(&self) -> &str {
        "zfp"
    }

    fn compress(&self, field: &Tensor, bound: &ErrorBound) -> Result<Archive> {
        ensure!(
            field.shape() == &self.dataset.dims[..],
            "field shape {:?} != dataset dims {:?}",
            field.shape(),
            self.dataset.dims
        );
        let (precision, payload, index) = match bound {
            ErrorBound::None => {
                let (payload, index) = self.encode(field, DEFAULT_PRECISION)?;
                (DEFAULT_PRECISION, payload, index)
            }
            _ => self.certify(field, bound)?,
        };
        let mut header = base_header(self.id(), &self.dataset, bound);
        header.push(("precision".to_string(), json::num(precision as f64)));
        let mut archive = Archive::new_v3(crate::util::json::Value::Obj(header));
        archive.add_section("ZFPB", payload);
        archive.add_block_index(&index);
        Ok(archive)
    }

    fn decompress(&self, archive: &Archive) -> Result<Tensor> {
        self.decode_archive(archive, None)
    }

    fn decompress_region(&self, archive: &Archive, region: &Region) -> Result<Tensor> {
        self.decode_archive(archive, Some(region))
    }
}
