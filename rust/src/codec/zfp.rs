//! [`Codec`] adapter for the ZFP-like transform compressor.
//!
//! ZFP's knob is fixed precision, which has no closed-form map to an
//! error bound — so the adapter *certifies* the bound instead: binary
//! search over precision, decompressing each trial and keeping the
//! smallest precision whose reconstruction measurably satisfies the
//! requested [`ErrorBound`]. The error is monotone non-increasing in
//! precision, so the search is sound.

use crate::baselines::ZfpLike;
use crate::compressor::Archive;
use crate::config::DatasetConfig;
use crate::tensor::Tensor;
use crate::util::json;
use crate::Result;
use anyhow::{bail, ensure};

use super::{base_header, Codec, ErrorBound};

/// Precision used for `ErrorBound::None` (best effort; matches the old
/// bench default).
const DEFAULT_PRECISION: u32 = 12;
const MAX_PRECISION: u32 = 26;

/// ZFP-like codec (4^d block transform + fixed precision), bound-certified.
pub struct ZfpCodec {
    dataset: DatasetConfig,
}

impl ZfpCodec {
    pub fn new(dataset: DatasetConfig) -> Self {
        Self { dataset }
    }

    /// Smallest precision whose reconstruction satisfies `bound`, with its
    /// compressed bytes.
    fn certify(&self, field: &Tensor, bound: &ErrorBound) -> Result<(u32, Vec<u8>)> {
        let meets = |p: u32| -> Result<Option<Vec<u8>>> {
            let bytes = ZfpLike::new(p).compress(field)?;
            let recon = ZfpLike::decompress(&bytes)?;
            if bound.satisfied_by(field, &recon, &self.dataset) {
                Ok(Some(bytes))
            } else {
                Ok(None)
            }
        };
        // binary search the smallest satisfying precision in [1, 26]
        let (mut lo, mut hi) = (1u32, MAX_PRECISION);
        let mut best: Option<(u32, Vec<u8>)> = None;
        while lo <= hi {
            let mid = (lo + hi) / 2;
            match meets(mid)? {
                Some(bytes) => {
                    best = Some((mid, bytes));
                    if mid == 1 {
                        break;
                    }
                    hi = mid - 1;
                }
                None => lo = mid + 1,
            }
        }
        match best {
            Some(found) => Ok(found),
            None => bail!(
                "zfp-like codec cannot certify bound {bound} even at precision \
                 {MAX_PRECISION} (transform is near-lossless, not lossless)"
            ),
        }
    }
}

impl Codec for ZfpCodec {
    fn id(&self) -> &str {
        "zfp"
    }

    fn compress(&self, field: &Tensor, bound: &ErrorBound) -> Result<Archive> {
        ensure!(
            field.shape() == &self.dataset.dims[..],
            "field shape {:?} != dataset dims {:?}",
            field.shape(),
            self.dataset.dims
        );
        let (precision, bytes) = match bound {
            ErrorBound::None => {
                (DEFAULT_PRECISION, ZfpLike::new(DEFAULT_PRECISION).compress(field)?)
            }
            _ => self.certify(field, bound)?,
        };
        let mut header = base_header(self.id(), &self.dataset, bound);
        header.push(("precision".to_string(), json::num(precision as f64)));
        let mut archive = Archive::new(crate::util::json::Value::Obj(header));
        archive.add_section("ZFPB", bytes);
        Ok(archive)
    }

    fn decompress(&self, archive: &Archive) -> Result<Tensor> {
        ZfpLike::decompress(archive.section("ZFPB")?)
    }
}
