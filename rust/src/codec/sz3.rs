//! [`Codec`] adapter for the SZ3-like prediction-based compressor.
//!
//! Wraps [`Sz3Like`]'s raw byte stream into a self-describing [`Archive`]
//! (section `SZ3B`) and derives the pointwise ε from the typed
//! [`ErrorBound`], fixing the old asymmetric `new(eps).compress` /
//! static-`decompress` surface.

use crate::baselines::Sz3Like;
use crate::compressor::Archive;
use crate::config::DatasetConfig;
use crate::tensor::Tensor;
use crate::util::json;
use crate::Result;
use anyhow::ensure;

use super::{base_header, Codec, ErrorBound};

/// SZ3-like codec (Lorenzo predictor + error quantization + entropy).
pub struct Sz3Codec {
    dataset: DatasetConfig,
}

impl Sz3Codec {
    pub fn new(dataset: DatasetConfig) -> Self {
        Self { dataset }
    }
}

impl Codec for Sz3Codec {
    fn id(&self) -> &str {
        "sz3"
    }

    fn compress(&self, field: &Tensor, bound: &ErrorBound) -> Result<Archive> {
        ensure!(
            field.shape() == &self.dataset.dims[..],
            "field shape {:?} != dataset dims {:?}",
            field.shape(),
            self.dataset.dims
        );
        let eps = bound.pointwise_eps(&self.dataset, field.range() as f64);
        ensure!(
            eps.is_finite() && eps > 0.0,
            "bound {bound} yields eps {eps} (constant field or zero bound?)"
        );
        let bytes = Sz3Like::new(eps).compress(field)?;
        let mut header = base_header(self.id(), &self.dataset, bound);
        header.push(("eps".to_string(), json::num(eps as f64)));
        let mut archive = Archive::new(crate::util::json::Value::Obj(header));
        archive.add_section("SZ3B", bytes);
        Ok(archive)
    }

    fn decompress(&self, archive: &Archive) -> Result<Tensor> {
        Sz3Like::decompress(archive.section("SZ3B")?)
    }
}
