//! [`Codec`] adapter for the SZ3-like prediction-based compressor.
//!
//! Writes **Archive v3**: the field is tiled by the dataset's AE block
//! shape, every tile is an independent [`Sz3Like`] stream (encoded
//! block-parallel on the shared executor), and a `BIDX` block index maps
//! tile id → byte span inside the `SZ3B` section. A full decode streams
//! every tile; [`Codec::decompress_region`] slices only the tiles the
//! region intersects. Legacy v1 archives (one whole-field stream, no
//! index) keep decoding through the original path, so old data stays
//! readable.
//!
//! The pointwise ε derives from the typed [`ErrorBound`] exactly as
//! before — per-tile streams share one ε, so the bound semantics are
//! unchanged.
//!
//! Each tile's quantized codes ride the symbol container, which picks
//! its mode per stream (plain Huffman+LZSS, interleaved rANS for dense
//! tiles, zero-run / const for sparse ones); `cli info --in` breaks the
//! per-mode tile counts and byte classes out of the `SZ3B` section.

use crate::baselines::Sz3Like;
use crate::compressor::Archive;
use crate::config::DatasetConfig;
use crate::data::Region;
use crate::tensor::Tensor;
use crate::util::json;
use crate::Result;
use anyhow::ensure;

use super::{base_header, tiled, Codec, ErrorBound};

/// SZ3-like codec (Lorenzo predictor + error quantization + entropy).
pub struct Sz3Codec {
    dataset: DatasetConfig,
}

impl Sz3Codec {
    pub fn new(dataset: DatasetConfig) -> Self {
        Self { dataset }
    }

    /// Decode through the v3 block index when present (optionally only a
    /// region), else fall back to the v1 whole-stream path.
    fn decode(&self, archive: &Archive, region: Option<&Region>) -> Result<Tensor> {
        let payload = archive.section("SZ3B")?;
        match archive.block_index()? {
            Some(index) => {
                // the per-tile cap is computed inside the closure: it
                // only runs after decode_tiled has validated the
                // (untrusted) tile shape against the field dims
                tiled::decode_tiled(payload, &index, &self.dataset.dims, region, |_, b, s| {
                    Sz3Like::decompress_capped_scratch(b, index.tile.iter().product(), s)
                })
            }
            None => {
                // v1 legacy archive: whole-field stream, no index; the
                // header geometry caps what a corrupt stream may allocate
                let full = Sz3Like::decompress_capped(payload, self.dataset.total_points())?;
                match region {
                    Some(r) => r.crop(&full),
                    None => Ok(full),
                }
            }
        }
    }
}

impl Codec for Sz3Codec {
    fn id(&self) -> &str {
        "sz3"
    }

    fn compress(&self, field: &Tensor, bound: &ErrorBound) -> Result<Archive> {
        ensure!(
            field.shape() == &self.dataset.dims[..],
            "field shape {:?} != dataset dims {:?}",
            field.shape(),
            self.dataset.dims
        );
        let eps = bound.pointwise_eps(&self.dataset, field.range() as f64);
        ensure!(
            eps.is_finite() && eps > 0.0,
            "bound {bound} yields eps {eps} (constant field or zero bound?)"
        );
        let (payload, index) = tiled::encode_tiled(field, &self.dataset.ae_block, |shape, data, s| {
            Sz3Like::new(eps).compress_scratch(shape, data, s)
        })?;
        let mut header = base_header(self.id(), &self.dataset, bound);
        header.push(("eps".to_string(), json::num(eps as f64)));
        let mut archive = Archive::new_v3(crate::util::json::Value::Obj(header));
        archive.add_section("SZ3B", payload);
        archive.add_block_index(&index);
        Ok(archive)
    }

    fn decompress(&self, archive: &Archive) -> Result<Tensor> {
        self.decode(archive, None)
    }

    fn decompress_region(&self, archive: &Archive, region: &Region) -> Result<Tensor> {
        self.decode(archive, Some(region))
    }
}
