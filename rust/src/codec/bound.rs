//! Typed error bounds — the single vocabulary every codec speaks.
//!
//! Replaces the raw-`f32` `tau` / `eps` / `precision` trio that each
//! compressor used to take: callers state *what* accuracy they need, and
//! each codec derives its own knob from it (per-block ℓ2 τ for the
//! GAE-bounded codecs via Eq. 11, pointwise ε for the SZ3-like predictor,
//! a certified precision search for the ZFP-like transform).

use crate::compressor::nrmse;
use crate::config::{DatasetConfig, PipelineConfig};
use crate::linalg::norm2_f32;
use crate::tensor::{block_origins, extract_block, Tensor};
use crate::util::json::{self, Value};
use crate::Result;
use anyhow::bail;

/// A typed error-bound request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ErrorBound {
    /// Target dataset NRMSE (range-normalized RMSE), e.g. `1e-3`.
    Nrmse(f64),
    /// Per-GAE-block ℓ2 bound τ in original units (paper §II-D).
    L2Tau(f64),
    /// Pointwise absolute bound: every `|x - x̂| <= a`.
    PointwiseAbs(f64),
    /// Best effort, no guarantee (each codec's default fidelity).
    None,
}

impl ErrorBound {
    /// Parse the CLI syntax: `nrmse:1e-3`, `tau:0.5`, `abs:1e-4`, `none`.
    pub fn parse(s: &str) -> Result<Self> {
        let s = s.trim();
        if s.eq_ignore_ascii_case("none") {
            return Ok(Self::None);
        }
        let Some((kind, value)) = s.split_once(':') else {
            bail!("bad bound {s:?} (expected nrmse:X | tau:X | abs:X | none)");
        };
        let v: f64 = value
            .trim()
            .parse()
            .map_err(|_| anyhow::anyhow!("bad bound value {value:?} in {s:?}"))?;
        if !v.is_finite() || v <= 0.0 {
            bail!("bound value must be positive and finite, got {v}");
        }
        match kind.trim().to_ascii_lowercase().as_str() {
            "nrmse" => Ok(Self::Nrmse(v)),
            "tau" | "l2" => Ok(Self::L2Tau(v)),
            "abs" | "pointwise" => Ok(Self::PointwiseAbs(v)),
            other => bail!("unknown bound kind {other:?} (nrmse | tau | abs | none)"),
        }
    }

    /// The kind tag used in archive headers and CLI output.
    pub fn kind(&self) -> &'static str {
        match self {
            Self::Nrmse(_) => "nrmse",
            Self::L2Tau(_) => "tau",
            Self::PointwiseAbs(_) => "abs",
            Self::None => "none",
        }
    }

    /// The numeric bound (0 for `None`).
    pub fn value(&self) -> f64 {
        match *self {
            Self::Nrmse(v) | Self::L2Tau(v) | Self::PointwiseAbs(v) => v,
            Self::None => 0.0,
        }
    }

    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("kind", json::s(self.kind())),
            ("value", json::num(self.value())),
        ])
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        let kind = v.req("kind")?.as_str().unwrap_or("");
        let value = v.req("value")?.as_f64().unwrap_or(0.0);
        match kind {
            "none" => Ok(Self::None),
            "nrmse" => Ok(Self::Nrmse(value)),
            "tau" => Ok(Self::L2Tau(value)),
            "abs" => Ok(Self::PointwiseAbs(value)),
            other => bail!("unknown bound kind {other:?} in archive header"),
        }
    }

    /// Per-GAE-block ℓ2 bound τ (original units) that certifies this
    /// request for the GAE-bounded codecs (hier, gbae).
    ///
    /// * `Nrmse` uses Eq. 11: `τ = target · range · sqrt(D_block)` — if
    ///   every block meets τ, dataset NRMSE ≤ target.
    /// * `PointwiseAbs(a)` maps conservatively to `τ = a`: a block ℓ2
    ///   within `a` bounds every point in it by `a`.
    /// * `None` disables the GAE stage (τ = 0).
    pub fn gae_tau(&self, dataset: &DatasetConfig, field_range: f64) -> f32 {
        match *self {
            Self::Nrmse(t) => {
                PipelineConfig::tau_for_nrmse(t, field_range, dataset.gae_block_len())
            }
            Self::L2Tau(t) => t as f32,
            Self::PointwiseAbs(a) => a as f32,
            Self::None => 0.0,
        }
    }

    /// Pointwise ε certifying this request for the SZ3-like predictor.
    ///
    /// * `Nrmse(t)`: `|err| ≤ t·range` everywhere implies RMSE ≤ t·range,
    ///   i.e. NRMSE ≤ t.
    /// * `L2Tau(τ)`: `ε = τ / sqrt(D_block)` makes every GAE block's ℓ2 at
    ///   most τ.
    /// * `None`: best-effort default `1e-3 · range`.
    pub fn pointwise_eps(&self, dataset: &DatasetConfig, field_range: f64) -> f32 {
        match *self {
            Self::Nrmse(t) => (t * field_range) as f32,
            Self::L2Tau(t) => (t / (dataset.gae_block_len() as f64).sqrt()) as f32,
            Self::PointwiseAbs(a) => a as f32,
            Self::None => (1e-3 * field_range) as f32,
        }
    }

    /// The bound to compress a *temporal residual* under so that the
    /// absolute reconstructed frame satisfies `self`.
    ///
    /// A residual is coded against the previous **reconstructed** frame,
    /// so the error on the absolute frame equals the error on the
    /// residual exactly — no accumulation along the chain. Two variants
    /// need translation because their codec knobs derive from the
    /// *field's own range*, which for a residual is near zero:
    ///
    /// * `Nrmse(t)` wrt the frame means RMSE ≤ `t · frame_range`; a
    ///   pointwise bound of `t · frame_range` on the residual certifies
    ///   it (conservatively) without referencing the residual's range.
    /// * `None` (best effort) keeps the frame-relative default fidelity
    ///   `1e-3 · frame_range` instead of `1e-3 · residual_range` (a
    ///   near-constant residual would otherwise derive ε = 0).
    /// * `L2Tau` / `PointwiseAbs` are already absolute: per-block ℓ2 and
    ///   pointwise error of the frame equal those of the residual.
    pub fn for_residual(&self, frame_range: f64) -> ErrorBound {
        match *self {
            Self::Nrmse(t) => Self::PointwiseAbs(t * frame_range),
            Self::None if frame_range > 0.0 => Self::PointwiseAbs(1e-3 * frame_range),
            other => other,
        }
    }

    /// Measure whether a reconstruction satisfies this bound (used by the
    /// ZFP-like precision search and the integration tests).
    pub fn satisfied_by(
        &self,
        orig: &Tensor,
        recon: &Tensor,
        dataset: &DatasetConfig,
    ) -> bool {
        match *self {
            Self::None => true,
            Self::Nrmse(t) => nrmse(orig, recon) <= t,
            Self::PointwiseAbs(a) => orig
                .data()
                .iter()
                .zip(recon.data())
                .all(|(&x, &y)| (x - y).abs() as f64 <= a),
            Self::L2Tau(t) => {
                let d = dataset.gae_block_len();
                let origins = block_origins(&dataset.dims, &dataset.gae_block);
                let mut a = vec![0f32; d];
                let mut b = vec![0f32; d];
                origins.iter().all(|o| {
                    extract_block(orig, o, &dataset.gae_block, &mut a);
                    extract_block(recon, o, &dataset.gae_block, &mut b);
                    let diff: Vec<f32> = a.iter().zip(&b).map(|(&x, &y)| x - y).collect();
                    norm2_f32(&diff) <= t
                })
            }
        }
    }
}

impl std::fmt::Display for ErrorBound {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::None => write!(f, "none"),
            _ => write!(f, "{}:{:e}", self.kind(), self.value()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{dataset_preset, DatasetKind, Scale};

    #[test]
    fn parses_all_kinds() {
        assert_eq!(ErrorBound::parse("nrmse:1e-3").unwrap(), ErrorBound::Nrmse(1e-3));
        assert_eq!(ErrorBound::parse("tau:0.5").unwrap(), ErrorBound::L2Tau(0.5));
        assert_eq!(ErrorBound::parse("abs:1e-4").unwrap(), ErrorBound::PointwiseAbs(1e-4));
        assert_eq!(ErrorBound::parse("none").unwrap(), ErrorBound::None);
        assert_eq!(ErrorBound::parse(" NRMSE:2e-2 ").unwrap(), ErrorBound::Nrmse(2e-2));
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "nrmse", "nrmse:", "nrmse:x", "nrmse:-1", "nrmse:inf", "l3:0.5", "0.5"] {
            assert!(ErrorBound::parse(bad).is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn json_round_trip() {
        for b in [
            ErrorBound::Nrmse(1e-3),
            ErrorBound::L2Tau(0.25),
            ErrorBound::PointwiseAbs(1e-4),
            ErrorBound::None,
        ] {
            let back = ErrorBound::from_json(&b.to_json()).unwrap();
            assert_eq!(back, b);
        }
    }

    #[test]
    fn tau_and_eps_derivations() {
        let d = dataset_preset(DatasetKind::E3sm, Scale::Smoke); // gae block 16x16
        let range = 2.0;
        let tau = ErrorBound::Nrmse(1e-3).gae_tau(&d, range);
        assert!((tau as f64 - 1e-3 * 2.0 * 16.0).abs() < 1e-9); // sqrt(256) = 16
        assert_eq!(ErrorBound::L2Tau(0.5).gae_tau(&d, range), 0.5);
        assert_eq!(ErrorBound::PointwiseAbs(0.1).gae_tau(&d, range), 0.1);
        assert_eq!(ErrorBound::None.gae_tau(&d, range), 0.0);

        let eps = ErrorBound::L2Tau(1.6).pointwise_eps(&d, range);
        assert!((eps - 0.1).abs() < 1e-6); // 1.6 / 16
        assert_eq!(ErrorBound::Nrmse(1e-3).pointwise_eps(&d, range), 2e-3);
    }

    #[test]
    fn satisfied_by_measures_each_kind() {
        let d = dataset_preset(DatasetKind::E3sm, Scale::Smoke);
        let orig = crate::data::generate(&d);
        let mut recon = orig.clone();
        for v in recon.data_mut() {
            *v += 1e-4;
        }
        assert!(ErrorBound::PointwiseAbs(2e-4).satisfied_by(&orig, &recon, &d));
        assert!(!ErrorBound::PointwiseAbs(5e-5).satisfied_by(&orig, &recon, &d));
        assert!(ErrorBound::None.satisfied_by(&orig, &recon, &d));
        // block l2 of constant 1e-4 offset over 256 points = 1.6e-3
        assert!(ErrorBound::L2Tau(2e-3).satisfied_by(&orig, &recon, &d));
        assert!(!ErrorBound::L2Tau(1e-3).satisfied_by(&orig, &recon, &d));
    }

    #[test]
    fn residual_bound_translation() {
        // Nrmse wrt the frame becomes an absolute pointwise bound in
        // frame units — independent of the residual's own (tiny) range
        assert_eq!(
            ErrorBound::Nrmse(1e-3).for_residual(2000.0),
            ErrorBound::PointwiseAbs(2.0)
        );
        // absolute bounds pass through unchanged
        assert_eq!(ErrorBound::L2Tau(0.5).for_residual(10.0), ErrorBound::L2Tau(0.5));
        assert_eq!(
            ErrorBound::PointwiseAbs(1e-4).for_residual(10.0),
            ErrorBound::PointwiseAbs(1e-4)
        );
        // best-effort anchors to the frame range (a constant residual
        // must not derive ε = 0)
        assert_eq!(ErrorBound::None.for_residual(4.0), ErrorBound::PointwiseAbs(4e-3));
        assert_eq!(ErrorBound::None.for_residual(0.0), ErrorBound::None);
    }

    #[test]
    fn display_is_parseable() {
        for b in [ErrorBound::Nrmse(1e-3), ErrorBound::L2Tau(0.5), ErrorBound::None] {
            let s = b.to_string();
            assert_eq!(ErrorBound::parse(&s).unwrap(), b, "{s}");
        }
    }
}
