//! Configuration system.
//!
//! Presets mirror `python/compile/configs.py` (the manifest is the source
//! of truth for shapes; [`crate::runtime::Runtime`] validates group names
//! and dims against it at load time). Configs serialize to JSON (in-house
//! writer) for the archive header and experiment records.

use crate::util::json::{self, Value};
use anyhow::bail;

/// Which scientific application the data comes from (paper §III-A).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DatasetKind {
    S3d,
    E3sm,
    Xgc,
}

impl DatasetKind {
    pub fn parse(s: &str) -> crate::Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "s3d" => Ok(Self::S3d),
            "e3sm" => Ok(Self::E3sm),
            "xgc" => Ok(Self::Xgc),
            other => bail!("unknown dataset {other:?} (s3d|e3sm|xgc)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::S3d => "s3d",
            Self::E3sm => "e3sm",
            Self::Xgc => "xgc",
        }
    }
}

/// Paper §III-A normalizations.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Normalization {
    /// z-score over the whole field (E3SM, XGC).
    ZScore,
    /// per-species mean 0 / range 1 (S3D).
    PerSpeciesMeanRange,
}

impl Normalization {
    pub fn name(&self) -> &'static str {
        match self {
            Self::ZScore => "z_score",
            Self::PerSpeciesMeanRange => "per_species_mean_range",
        }
    }

    pub fn parse(s: &str) -> crate::Result<Self> {
        match s {
            "z_score" => Ok(Self::ZScore),
            "per_species_mean_range" => Ok(Self::PerSpeciesMeanRange),
            other => bail!("unknown normalization {other:?}"),
        }
    }
}

/// Geometry of one dataset instance plus how it is blocked / hyper-blocked.
#[derive(Debug, Clone)]
pub struct DatasetConfig {
    pub kind: DatasetKind,
    /// Full field shape, e.g. S3D `[species, t, x, y]`.
    pub dims: Vec<usize>,
    /// AE block shape (same rank as `dims`); flattens to the model's
    /// `block_dim`.
    pub ae_block: Vec<usize>,
    /// Blocks per hyper-block (grouped along `hyper_axis`).
    pub k: usize,
    /// Axis along which consecutive blocks form a hyper-block
    /// (S3D/E3SM: time; XGC: toroidal cross-section).
    pub hyper_axis: usize,
    /// GAE post-processing block shape (paper §II-D uses a different,
    /// usually smaller, blocking than the AE stage).
    pub gae_block: Vec<usize>,
    /// Normalization applied before the AE stage.
    pub normalization: Normalization,
    /// Generator seed (synthetic substitutes — DESIGN.md §4).
    pub seed: u64,
}

impl DatasetConfig {
    pub fn block_dim(&self) -> usize {
        self.ae_block.iter().product()
    }

    pub fn gae_block_len(&self) -> usize {
        self.gae_block.iter().product()
    }

    pub fn total_points(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("kind", json::s(self.kind.name())),
            ("dims", json::arr_usize(&self.dims)),
            ("ae_block", json::arr_usize(&self.ae_block)),
            ("k", json::num(self.k as f64)),
            ("hyper_axis", json::num(self.hyper_axis as f64)),
            ("gae_block", json::arr_usize(&self.gae_block)),
            ("normalization", json::s(self.normalization.name())),
            ("seed", json::num(self.seed as f64)),
        ])
    }

    pub fn from_json(v: &Value) -> crate::Result<Self> {
        Ok(Self {
            kind: DatasetKind::parse(v.req("kind")?.as_str().unwrap_or(""))?,
            dims: v.req("dims")?.usize_vec()?,
            ae_block: v.req("ae_block")?.usize_vec()?,
            k: v.req("k")?.as_usize().unwrap_or(0),
            hyper_axis: v.req("hyper_axis")?.as_usize().unwrap_or(0),
            gae_block: v.req("gae_block")?.usize_vec()?,
            normalization: Normalization::parse(
                v.req("normalization")?.as_str().unwrap_or(""),
            )?,
            seed: v.req("seed")?.as_f64().unwrap_or(0.0) as u64,
        })
    }
}

/// Model group names + quantization setup for one dataset preset.
#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub hbae_group: String,
    pub bae_group: String,
    pub pipe_group: Option<String>,
    /// Latent quantization bin sizes (paper §III-E: S3D 0.005/0.005,
    /// E3SM 0.01/0.1, XGC 0.1/0.1). `0.0` disables quantization.
    pub bin_hbae: f32,
    pub bin_bae: f32,
}

impl ModelConfig {
    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("hbae_group", json::s(&self.hbae_group)),
            ("bae_group", json::s(&self.bae_group)),
            (
                "pipe_group",
                self.pipe_group
                    .as_ref()
                    .map(|s| json::s(s.as_str()))
                    .unwrap_or(Value::Null),
            ),
            ("bin_hbae", json::num(self.bin_hbae as f64)),
            ("bin_bae", json::num(self.bin_bae as f64)),
        ])
    }

    pub fn from_json(v: &Value) -> crate::Result<Self> {
        Ok(Self {
            hbae_group: v.req("hbae_group")?.as_str().unwrap_or("").to_string(),
            bae_group: v.req("bae_group")?.as_str().unwrap_or("").to_string(),
            pipe_group: v
                .get("pipe_group")
                .and_then(|p| p.as_str())
                .map(|s| s.to_string()),
            bin_hbae: v.req("bin_hbae")?.as_f64().unwrap_or(0.0) as f32,
            bin_bae: v.req("bin_bae")?.as_f64().unwrap_or(0.0) as f32,
        })
    }
}

/// Training hyper-parameters (paper §III-C: Adam, lr 1e-3, MSE).
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub steps: usize,
    pub lr: f32,
    pub log_every: usize,
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self { steps: 300, lr: 1e-3, log_every: 25, seed: 0 }
    }
}

/// Full pipeline configuration for a compression run.
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    pub dataset: DatasetConfig,
    pub model: ModelConfig,
    pub train: TrainConfig,
    /// Per-GAE-block ℓ2 error bound τ. Usually derived from a target
    /// NRMSE via [`PipelineConfig::tau_for_nrmse`].
    pub tau: f32,
}

impl PipelineConfig {
    /// τ such that if every block hits it exactly, dataset NRMSE ≈ target
    /// (Eq. 11): `τ = nrmse · range · sqrt(D_block)`.
    pub fn tau_for_nrmse(nrmse: f64, value_range: f64, gae_block_len: usize) -> f32 {
        (nrmse * value_range * (gae_block_len as f64).sqrt()) as f32
    }
}

/// Scale of the synthetic datasets (DESIGN.md §4).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    /// CPU-box friendly default.
    Bench,
    /// Tiny: CI / unit tests.
    Smoke,
    /// The paper's full dims (S3D 58x50x640x640 — 9.5 GB).
    Paper,
}

impl Scale {
    pub fn parse(s: &str) -> crate::Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "bench" => Ok(Self::Bench),
            "smoke" => Ok(Self::Smoke),
            "paper" => Ok(Self::Paper),
            other => bail!("unknown scale {other:?} (bench|smoke|paper)"),
        }
    }
}

/// Dataset preset matching the python side's bench-scale geometry.
pub fn dataset_preset(kind: DatasetKind, scale: Scale) -> DatasetConfig {
    match kind {
        DatasetKind::S3d => {
            // paper: 58 species x 50 t x 640 x 640; AE block 58x5x4x4; k=10
            // hyper-block = 10 consecutive temporal blocks; GAE per species
            // with 5x4x4 blocks.
            // bench keeps T=50 so 10 temporal blocks form exactly one
            // hyper-block per spatial tile, as in the paper.
            let (species, t, x, y) = match scale {
                Scale::Paper => (58, 50, 640, 640),
                Scale::Bench => (16, 50, 64, 64),
                Scale::Smoke => (16, 10, 16, 16),
            };
            DatasetConfig {
                kind,
                dims: vec![species, t, x, y],
                ae_block: vec![species, 5, 4, 4],
                k: 10,
                hyper_axis: 1,
                gae_block: vec![1, 5, 4, 4],
                normalization: Normalization::PerSpeciesMeanRange,
                seed: 31,
            }
        }
        DatasetKind::E3sm => {
            // paper: 720 t x 240 x 1440; blocks 6x16x16; k=5; GAE 16x16.
            let (t, h, w) = match scale {
                Scale::Paper => (720, 240, 1440),
                Scale::Bench => (120, 96, 192),
                Scale::Smoke => (24, 32, 32),
            };
            DatasetConfig {
                kind,
                dims: vec![t, h, w],
                ae_block: vec![6, 16, 16],
                k: 5,
                hyper_axis: 0,
                gae_block: vec![1, 16, 16],
                normalization: Normalization::ZScore,
                seed: 47,
            }
        }
        DatasetKind::Xgc => {
            // paper: 8 planes x 16395 nodes x 39 x 39; block = one
            // histogram; hyper-block = 8 toroidal copies of one node.
            let nodes = match scale {
                Scale::Paper => 16395,
                Scale::Bench => 2048,
                Scale::Smoke => 128,
            };
            DatasetConfig {
                kind,
                dims: vec![8, nodes, 39, 39],
                ae_block: vec![1, 1, 39, 39],
                k: 8,
                hyper_axis: 0,
                gae_block: vec![1, 1, 39, 39],
                normalization: Normalization::ZScore,
                seed: 63,
            }
        }
    }
}

/// Per-frame geometry preset for the temporal stream subsystem
/// ([`crate::stream`]): one *timestep* of each application, i.e. the
/// dataset preset with the time/plane axis dropped. A v4 stream appends
/// frames of this shape; the dataset presets above keep describing the
/// whole space-time volume the one-shot codecs compress.
pub fn stream_frame_preset(kind: DatasetKind, scale: Scale) -> DatasetConfig {
    match kind {
        DatasetKind::S3d => {
            // one temporal snapshot: [species, x, y]
            let (species, x, y) = match scale {
                Scale::Paper => (58, 640, 640),
                Scale::Bench => (16, 64, 64),
                Scale::Smoke => (16, 16, 16),
            };
            DatasetConfig {
                kind,
                dims: vec![species, x, y],
                ae_block: vec![species, 4, 4],
                k: 4,
                hyper_axis: 1,
                gae_block: vec![1, 4, 4],
                normalization: Normalization::PerSpeciesMeanRange,
                seed: 131,
            }
        }
        DatasetKind::E3sm => {
            // one hourly snapshot: [lat, lon]
            let (h, w) = match scale {
                Scale::Paper => (240, 1440),
                Scale::Bench => (96, 192),
                Scale::Smoke => (32, 32),
            };
            DatasetConfig {
                kind,
                dims: vec![h, w],
                ae_block: vec![16, 16],
                k: 4,
                hyper_axis: 0,
                gae_block: vec![16, 16],
                normalization: Normalization::ZScore,
                seed: 147,
            }
        }
        DatasetKind::Xgc => {
            // one toroidal plane of velocity histograms: [nodes, vx, vy]
            let nodes = match scale {
                Scale::Paper => 16395,
                Scale::Bench => 2048,
                Scale::Smoke => 128,
            };
            DatasetConfig {
                kind,
                dims: vec![nodes, 39, 39],
                ae_block: vec![1, 39, 39],
                k: 4,
                hyper_axis: 0,
                gae_block: vec![1, 39, 39],
                normalization: Normalization::ZScore,
                seed: 163,
            }
        }
    }
}

/// Model preset matching `configs.default_groups()` on the python side.
pub fn model_preset(kind: DatasetKind) -> ModelConfig {
    match kind {
        DatasetKind::S3d => ModelConfig {
            hbae_group: "s3d_hbae_L128".into(),
            bae_group: "s3d_bae_L16".into(),
            pipe_group: Some("s3d_pipe_L128_16".into()),
            bin_hbae: 0.005,
            bin_bae: 0.005,
        },
        DatasetKind::E3sm => ModelConfig {
            hbae_group: "e3sm_hbae_L64".into(),
            bae_group: "e3sm_bae_L16".into(),
            pipe_group: Some("e3sm_pipe_L64_16".into()),
            bin_hbae: 0.01,
            bin_bae: 0.1,
        },
        DatasetKind::Xgc => ModelConfig {
            hbae_group: "xgc_hbae_L64".into(),
            bae_group: "xgc_bae_L16".into(),
            pipe_group: Some("xgc_pipe_L64_16".into()),
            bin_hbae: 0.1,
            bin_bae: 0.1,
        },
    }
}

/// Everything needed for `attn-reduce compress --dataset <kind>`.
pub fn pipeline_preset(kind: DatasetKind, scale: Scale, tau: f32) -> PipelineConfig {
    PipelineConfig {
        dataset: dataset_preset(kind, scale),
        model: model_preset(kind),
        train: TrainConfig::default(),
        tau,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_block_dims_match_manifest_groups() {
        // s3d bench: 16*5*4*4 = 1280 (the python preset's block_dim)
        let d = dataset_preset(DatasetKind::S3d, Scale::Bench);
        assert_eq!(d.block_dim(), 1280);
        let d = dataset_preset(DatasetKind::E3sm, Scale::Bench);
        assert_eq!(d.block_dim(), 1536);
        let d = dataset_preset(DatasetKind::Xgc, Scale::Bench);
        assert_eq!(d.block_dim(), 1521);
    }

    #[test]
    fn stream_frame_presets_drop_the_temporal_axis() {
        for kind in [DatasetKind::S3d, DatasetKind::E3sm, DatasetKind::Xgc] {
            for scale in [Scale::Bench, Scale::Smoke] {
                let f = stream_frame_preset(kind, scale);
                let d = dataset_preset(kind, scale);
                assert_eq!(f.dims.len() + 1, d.dims.len(), "{kind:?} rank");
                assert_eq!(f.dims.len(), f.ae_block.len());
                assert_eq!(f.dims.len(), f.gae_block.len());
                for (dim, b) in f.dims.iter().zip(&f.ae_block) {
                    assert!(b <= dim, "{kind:?} block fits frame");
                }
            }
        }
        // e3sm frame = one [h, w] snapshot of the volume preset
        let f = stream_frame_preset(DatasetKind::E3sm, Scale::Bench);
        let d = dataset_preset(DatasetKind::E3sm, Scale::Bench);
        assert_eq!(f.dims[..], d.dims[1..]);
    }

    #[test]
    fn tau_from_nrmse_scales_with_block() {
        let t1 = PipelineConfig::tau_for_nrmse(1e-3, 1.0, 80);
        let t2 = PipelineConfig::tau_for_nrmse(1e-3, 1.0, 320);
        assert!((t2 / t1 - 2.0).abs() < 1e-5);
    }

    #[test]
    fn quant_bins_match_paper() {
        assert_eq!(model_preset(DatasetKind::S3d).bin_hbae, 0.005);
        assert_eq!(model_preset(DatasetKind::E3sm).bin_hbae, 0.01);
        assert_eq!(model_preset(DatasetKind::E3sm).bin_bae, 0.1);
        assert_eq!(model_preset(DatasetKind::Xgc).bin_bae, 0.1);
    }

    #[test]
    fn kind_parse_round_trip() {
        for k in [DatasetKind::S3d, DatasetKind::E3sm, DatasetKind::Xgc] {
            assert_eq!(DatasetKind::parse(k.name()).unwrap(), k);
        }
        assert!(DatasetKind::parse("nope").is_err());
    }

    #[test]
    fn dataset_config_json_round_trip() {
        let d = dataset_preset(DatasetKind::S3d, Scale::Bench);
        let v = d.to_json();
        let text = v.to_string_pretty();
        let back = DatasetConfig::from_json(
            &crate::util::json::Value::parse(&text).unwrap(),
        )
        .unwrap();
        assert_eq!(back.dims, d.dims);
        assert_eq!(back.kind, d.kind);
        assert_eq!(back.normalization, d.normalization);
    }

    #[test]
    fn model_config_json_round_trip() {
        let m = model_preset(DatasetKind::E3sm);
        let back = ModelConfig::from_json(&m.to_json()).unwrap();
        assert_eq!(back.hbae_group, m.hbae_group);
        assert_eq!(back.pipe_group, m.pipe_group);
        assert_eq!(back.bin_bae, m.bin_bae);
    }
}
