//! Training driver (paper §III-C: Adam, lr 1e-3, MSE; HBAE first, then
//! the BAE on HBAE residuals).
//!
//! The rust side owns the loop — batching, shuffling, logging, checkpoint
//! cadence — and calls the AOT `train_step` artifact for the math. One
//! PJRT call per step; parameters stay host-side between steps (the perf
//! pass revisits this with device-resident buffers if it shows up in the
//! profile).

use std::time::Instant;

use crate::config::TrainConfig;
use crate::data::Blocking;
use crate::model::ParamStore;
use crate::runtime::{HostTensor, Runtime};
use crate::tensor::Tensor;
use crate::util::rng::Rng;
use crate::Result;
use anyhow::ensure;

/// Loss trace from one training run.
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub group: String,
    pub steps: usize,
    /// `(step, loss)` samples at `log_every` cadence plus the final step.
    pub losses: Vec<(usize, f32)>,
    pub final_loss: f32,
    pub wall_s: f64,
}

impl TrainReport {
    pub fn summary(&self) -> String {
        format!(
            "{}: {} steps, loss {:.3e} -> {:.3e} ({:.1}s)",
            self.group,
            self.steps,
            self.losses.first().map(|&(_, l)| l).unwrap_or(f32::NAN),
            self.final_loss,
            self.wall_s
        )
    }
}

/// Train any model group whose `train_step` signature is
/// `(theta, m, v, t, lr, batch)`; `fill_batch` provides each step's batch.
pub fn train_model(
    rt: &Runtime,
    store: &mut ParamStore,
    cfg: &TrainConfig,
    mut fill_batch: impl FnMut(usize, &mut [f32]),
) -> Result<TrainReport> {
    let step_exe = rt.load(&store.group, "train_step")?;
    ensure!(
        step_exe.info.inputs.len() == 6,
        "{}: unexpected train_step arity",
        store.group
    );
    let batch_sig = step_exe.info.inputs[5].clone();
    let mut batch = vec![0f32; batch_sig.len()];
    let lr = HostTensor::scalar(cfg.lr);

    let t0 = Instant::now();
    let mut losses = Vec::new();
    let mut final_loss = f32::NAN;
    for s in 0..cfg.steps {
        fill_batch(s, &mut batch);
        let [theta, m, v, t] = store.as_inputs();
        let outs = step_exe.run(&[
            theta,
            m,
            v,
            t,
            lr.clone(),
            HostTensor::new(batch_sig.shape.clone(), batch.clone()),
        ])?;
        let loss = store.absorb(outs)?;
        ensure!(loss.is_finite(), "{}: loss diverged at step {s}", store.group);
        if s % cfg.log_every.max(1) == 0 || s + 1 == cfg.steps {
            losses.push((s, loss));
            if std::env::var_os("ATTN_REDUCE_QUIET").is_none() {
                eprintln!("[train {}] step {s}: loss {loss:.4e}", store.group);
            }
        }
        final_loss = loss;
    }
    Ok(TrainReport {
        group: store.group.clone(),
        steps: cfg.steps,
        losses,
        final_loss,
        wall_s: t0.elapsed().as_secs_f64(),
    })
}

/// Train an HBAE on hyper-blocks sampled from a (normalized) field.
pub fn train_hbae(
    rt: &Runtime,
    store: &mut ParamStore,
    blocking: &Blocking,
    field: &Tensor,
    cfg: &TrainConfig,
) -> Result<TrainReport> {
    let step_exe = rt.load(&store.group, "train_step")?;
    let shape = &step_exe.info.inputs[5].shape;
    ensure!(shape.len() == 3, "hbae batch must be [Nh, k, bd]");
    let (nh, k, bd) = (shape[0], shape[1], shape[2]);
    ensure!(k == blocking.k && bd == blocking.block_dim(), "geometry mismatch");
    let total = blocking.num_hyperblocks();
    let mut rng = Rng::new(cfg.seed ^ 0x4842);
    let mut order: Vec<usize> = (0..total).collect();
    let mut cursor = usize::MAX; // force initial shuffle
    train_model(rt, store, cfg, move |_, batch| {
        for slot in 0..nh {
            if cursor >= total {
                rng.shuffle(&mut order);
                cursor = 0;
            }
            let h = order[cursor];
            cursor += 1;
            blocking.gather(field, h, 1, &mut batch[slot * k * bd..(slot + 1) * k * bd]);
        }
    })
}

/// Train a BAE on residual rows `[num_rows, bd]` (flattened).
pub fn train_bae(
    rt: &Runtime,
    store: &mut ParamStore,
    residuals: &[f32],
    bd: usize,
    cfg: &TrainConfig,
) -> Result<TrainReport> {
    ensure!(residuals.len() % bd == 0, "residual buffer not a multiple of bd");
    let rows = residuals.len() / bd;
    ensure!(rows > 0, "no residual rows");
    let step_exe = rt.load(&store.group, "train_step")?;
    let shape = &step_exe.info.inputs[5].shape;
    ensure!(shape.len() == 2 && shape[1] == bd, "bae batch must be [Nb, {bd}]");
    let nb = shape[0];
    let mut rng = Rng::new(cfg.seed ^ 0x4241);
    let mut order: Vec<usize> = (0..rows).collect();
    let mut cursor = usize::MAX;
    train_model(rt, store, cfg, move |_, batch| {
        for slot in 0..nb {
            if cursor >= rows {
                rng.shuffle(&mut order);
                cursor = 0;
            }
            let r = order[cursor];
            cursor += 1;
            batch[slot * bd..(slot + 1) * bd].copy_from_slice(&residuals[r * bd..(r + 1) * bd]);
        }
    })
}
