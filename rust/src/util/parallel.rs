//! Data-parallel entry points, routed through the shared
//! [`crate::engine::Executor`] pool (DESIGN.md §4).
//!
//! The GAE stage (Algorithm 1), the baselines, the lossless coder, and
//! the dataset generators are embarrassingly parallel over blocks;
//! `par_map` / `par_chunks_mut` / `par_flat_map_chunks` split that work
//! across the persistent worker pool. Outputs are order-preserving and
//! items independent, so every result is byte-identical at any thread
//! count.
//!
//! Thread-count precedence (satellite of the engine refactor):
//!
//! 1. [`with_thread_limit`] — thread-local, for scoped forcing (tests,
//!    the serial legs of benches);
//! 2. [`set_thread_override`] — process-wide, wired to the CLI
//!    `--threads N` flag;
//! 3. `ATTN_REDUCE_THREADS` environment variable;
//! 4. `std::thread::available_parallelism()`.

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};

use crate::engine::Executor;

/// Process-wide thread-count override (0 = unset). Set by `--threads`.
static THREAD_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    static THREAD_LIMIT: Cell<usize> = const { Cell::new(0) };
}

/// Set the process-wide thread count (the CLI `--threads N` flag). Takes
/// precedence over `ATTN_REDUCE_THREADS`; `0` clears the override.
pub fn set_thread_override(n: usize) {
    THREAD_OVERRIDE.store(n, Ordering::Relaxed);
}

/// Run `f` with parallelism forced to at most `n` on this thread (and
/// the pool batches it submits). Used by determinism tests and the
/// serial baselines of the fieldset bench. The previous limit is
/// restored even if `f` panics (asserting test closures must not leak a
/// serial limit into later tests on the same thread).
pub fn with_thread_limit<R>(n: usize, f: impl FnOnce() -> R) -> R {
    struct Restore(usize);
    impl Drop for Restore {
        fn drop(&mut self) {
            let prev = self.0;
            THREAD_LIMIT.with(|l| l.set(prev));
        }
    }
    let _restore = Restore(THREAD_LIMIT.with(|l| l.replace(n.max(1))));
    f()
}

/// Number of worker threads to use. Precedence: [`with_thread_limit`] >
/// [`set_thread_override`] (`--threads`) > `ATTN_REDUCE_THREADS` >
/// `available_parallelism()`.
pub fn num_threads() -> usize {
    let limit = THREAD_LIMIT.with(|l| l.get());
    if limit > 0 {
        return limit;
    }
    let over = THREAD_OVERRIDE.load(Ordering::Relaxed);
    if over > 0 {
        return over;
    }
    if let Ok(v) = std::env::var("ATTN_REDUCE_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Parallel map with work stealing over an index range; preserves order.
/// A panicking work item stops the batch and its original payload is
/// re-raised here.
pub fn par_map<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    Executor::global().par_map(n, f)
}

/// Parallel for-each over mutable chunks of a slice.
pub fn par_chunks_mut<T, F>(data: &mut [T], chunk: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(chunk > 0);
    let chunks: Vec<(usize, &mut [T])> = data.chunks_mut(chunk).enumerate().collect();
    let n = chunks.len();
    let work = std::sync::Mutex::new(chunks);
    // each work item takes exactly one (index, chunk) pair; chunk
    // identity rides with its index, so assignment order is irrelevant
    Executor::global().par_map(n, |_| {
        let item = work.lock().unwrap().pop();
        if let Some((i, c)) = item {
            f(i, c);
        }
    });
}

/// Map fixed-size chunks of `data` in parallel and concatenate the
/// results in chunk order. Chunk boundaries depend only on `chunk`, so
/// the output is identical at every thread count.
pub fn par_flat_map_chunks<T, U, F>(data: &[T], chunk: usize, f: F) -> Vec<U>
where
    T: Sync,
    U: Send,
    F: Fn(usize, &[T]) -> Vec<U> + Sync,
{
    assert!(chunk > 0);
    let chunks: Vec<&[T]> = data.chunks(chunk).collect();
    let parts = Executor::global().par_map(chunks.len(), |i| f(i, chunks[i]));
    let mut out = Vec::with_capacity(data.len());
    for p in parts {
        out.extend(p);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_preserves_order() {
        let out = par_map(1000, |i| i * i);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn par_map_empty_and_single() {
        assert_eq!(par_map(0, |i| i), Vec::<usize>::new());
        assert_eq!(par_map(1, |i| i + 5), vec![5]);
    }

    #[test]
    fn par_chunks_mut_touches_every_chunk_once() {
        let mut data = vec![0u32; 103]; // non-divisible length
        par_chunks_mut(&mut data, 10, |i, c| {
            for v in c.iter_mut() {
                *v += 1 + i as u32;
            }
        });
        assert!(data.iter().all(|&v| v >= 1));
        assert_eq!(data[0], 1);
        assert_eq!(data[102], 11); // chunk index 10
    }

    #[test]
    fn par_map_propagates_panic_payload() {
        // regression: a panicking worker used to leave `None` slots and
        // abort via `unwrap()` with a misleading message
        let err = std::panic::catch_unwind(|| {
            par_map(64, |i| {
                if i == 11 {
                    panic!("original payload {i}");
                }
                i
            })
        })
        .unwrap_err();
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
            .unwrap_or_default();
        assert!(msg.contains("original payload 11"), "got {msg:?}");
    }

    #[test]
    fn flat_map_chunks_concatenates_in_order() {
        let data: Vec<u32> = (0..1000).collect();
        let out = par_flat_map_chunks(&data, 37, |_, c| c.iter().map(|&v| v * 2).collect());
        assert_eq!(out.len(), data.len());
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i as u32 * 2);
        }
    }

    #[test]
    fn thread_limit_is_scoped_and_restored() {
        let before = num_threads();
        let inside = with_thread_limit(1, || {
            assert_eq!(num_threads(), 1);
            par_map(100, |i| i) // runs serially, same result
        });
        assert_eq!(inside, (0..100).collect::<Vec<_>>());
        assert_eq!(num_threads(), before);
    }

    #[test]
    fn results_identical_serial_vs_parallel() {
        let parallel = par_map(500, |i| (i as f64).sqrt());
        let serial = with_thread_limit(1, || par_map(500, |i| (i as f64).sqrt()));
        assert_eq!(parallel, serial);
    }
}
