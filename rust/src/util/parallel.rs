//! Scoped-thread data parallelism (rayon substitute; DESIGN.md §4).
//!
//! The GAE stage (Algorithm 1) and the baselines are embarrassingly
//! parallel over blocks; `par_chunks_mut` / `par_map` split work across
//! `available_parallelism()` OS threads with `std::thread::scope`.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use (env `ATTN_REDUCE_THREADS` overrides).
pub fn num_threads() -> usize {
    if let Ok(v) = std::env::var("ATTN_REDUCE_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Parallel map with work stealing over an index range; preserves order.
pub fn par_map<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = num_threads().min(n.max(1));
    if threads <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    struct SendPtr<T>(*mut Option<T>);
    unsafe impl<T: Send> Send for SendPtr<T> {}
    unsafe impl<T: Send> Sync for SendPtr<T> {}

    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let next = AtomicUsize::new(0);
    let slots = SendPtr(out.as_mut_ptr());
    let slots_ref = &slots;
    // SAFETY: each index is claimed exactly once via the atomic counter, so
    // every Option slot is written by at most one thread; the vec itself is
    // not resized while the scope is alive.
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let val = f(i);
                unsafe {
                    *slots_ref.0.add(i) = Some(val);
                }
            });
        }
    });
    out.into_iter().map(|x| x.unwrap()).collect()
}

/// Parallel for-each over mutable chunks of a slice.
pub fn par_chunks_mut<T, F>(data: &mut [T], chunk: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(chunk > 0);
    let chunks: Vec<(usize, &mut [T])> = data.chunks_mut(chunk).enumerate().collect();
    let threads = num_threads().min(chunks.len().max(1));
    if threads <= 1 {
        for (i, c) in chunks {
            f(i, c);
        }
        return;
    }
    let work = std::sync::Mutex::new(chunks);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let item = work.lock().unwrap().pop();
                match item {
                    Some((i, c)) => f(i, c),
                    None => break,
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_preserves_order() {
        let out = par_map(1000, |i| i * i);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn par_map_empty_and_single() {
        assert_eq!(par_map(0, |i| i), Vec::<usize>::new());
        assert_eq!(par_map(1, |i| i + 5), vec![5]);
    }

    #[test]
    fn par_chunks_mut_touches_every_chunk_once() {
        let mut data = vec![0u32; 103]; // non-divisible length
        par_chunks_mut(&mut data, 10, |i, c| {
            for v in c.iter_mut() {
                *v += 1 + i as u32;
            }
        });
        assert!(data.iter().all(|&v| v >= 1));
        assert_eq!(data[0], 1);
        assert_eq!(data[102], 11); // chunk index 10
    }
}
