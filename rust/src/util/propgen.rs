//! Seeded case generation + shrink-by-halving for the in-tree property
//! harness (`tests/prop_roundtrip.rs`).
//!
//! No external crates: cases derive from [`crate::util::rng::Rng`], so a
//! failure reproduces from `(seed, case index)` alone. CI pins the seed
//! via `ATTN_REDUCE_PROP_SEED`; local runs default to a fixed seed so
//! `cargo test` is deterministic everywhere. On failure the harness
//! halves the dims until the failure disappears and reports the smallest
//! still-failing geometry.

use crate::config::{DatasetConfig, DatasetKind, Normalization};
use crate::data::Region;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// The harness seed: `ATTN_REDUCE_PROP_SEED` when set (CI pins it),
/// otherwise `default`.
pub fn seed_from_env(default: u64) -> u64 {
    std::env::var("ATTN_REDUCE_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

/// Random-case generator over dataset geometries, fields, and regions.
pub struct CaseGen {
    rng: Rng,
}

impl CaseGen {
    pub fn new(seed: u64) -> Self {
        Self { rng: Rng::new(seed) }
    }

    /// A random dataset geometry: rank 2..=4, modest dims (decode cost
    /// is bounded so the zfp certification search stays test-sized),
    /// arbitrary AE blocking (tiles need not divide the dims — edge
    /// tiles are padded), and a small GAE block.
    pub fn dataset(&mut self) -> DatasetConfig {
        let rank = 2 + self.rng.below(3);
        // smaller per-dim extents at higher rank to bound total points
        let dim_max = if rank == 4 { 10 } else { 18 };
        let dims: Vec<usize> =
            (0..rank).map(|_| 4 + self.rng.below(dim_max - 3)).collect();
        let ae_block: Vec<usize> = dims
            .iter()
            .map(|&d| 1 + self.rng.below(d.min(6)))
            .collect();
        let gae_block: Vec<usize> = dims
            .iter()
            .map(|&d| 1 + self.rng.below(d.min(4)))
            .collect();
        let hyper_axis = self.rng.below(rank);
        DatasetConfig {
            kind: DatasetKind::E3sm,
            dims,
            ae_block,
            k: 1 + self.rng.below(3),
            hyper_axis,
            gae_block,
            normalization: Normalization::ZScore,
            seed: self.rng.next_u64(),
        }
    }

    /// A random field over `dims`: smooth multi-frequency structure plus
    /// mild noise, with a deterministic ramp so the range is never zero
    /// (a constant field has no derivable ε).
    pub fn field(&mut self, dims: &[usize]) -> Tensor {
        let n: usize = dims.iter().product();
        let mut rng = self.rng.fork(n as u64);
        let (a, b, amp) = (
            rng.range(1.0, 9.0),
            rng.range(5.0, 40.0),
            rng.range(0.5, 4.0),
        );
        let data: Vec<f32> = (0..n)
            .map(|i| {
                let x = i as f64 / n.max(1) as f64;
                (amp * ((a * x * std::f64::consts::PI).sin()
                    + 0.3 * (b * x).cos()
                    + 0.05 * rng.normal())
                    + x) as f32
            })
            .collect();
        Tensor::new(dims.to_vec(), data)
    }

    /// A random non-empty in-bounds region of `dims`.
    pub fn region(&mut self, dims: &[usize]) -> Region {
        let lo: Vec<usize> = dims.iter().map(|&d| self.rng.below(d)).collect();
        let hi: Vec<usize> = lo
            .iter()
            .zip(dims)
            .map(|(&l, &d)| l + 1 + self.rng.below(d - l))
            .collect();
        Region::new(lo, hi).expect("generated region is valid")
    }
}

/// Shrink a failing geometry by halving every dim (floor, min 2),
/// clamping the block shapes to the new dims. `None` once nothing can
/// shrink further — the current case is the minimal reproduction.
pub fn shrink(cfg: &DatasetConfig) -> Option<DatasetConfig> {
    if cfg.dims.iter().all(|&d| d <= 2) {
        return None;
    }
    let dims: Vec<usize> = cfg.dims.iter().map(|&d| (d / 2).max(2)).collect();
    let clamp = |block: &[usize]| -> Vec<usize> {
        block.iter().zip(&dims).map(|(&b, &d)| b.min(d).max(1)).collect()
    };
    Some(DatasetConfig {
        kind: cfg.kind,
        dims: dims.clone(),
        ae_block: clamp(&cfg.ae_block),
        k: cfg.k,
        hyper_axis: cfg.hyper_axis,
        gae_block: clamp(&cfg.gae_block),
        normalization: cfg.normalization,
        seed: cfg.seed,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generator_is_deterministic_per_seed() {
        let mut a = CaseGen::new(7);
        let mut b = CaseGen::new(7);
        for _ in 0..5 {
            let ca = a.dataset();
            let cb = b.dataset();
            assert_eq!(ca.dims, cb.dims);
            assert_eq!(ca.ae_block, cb.ae_block);
            assert_eq!(a.field(&ca.dims).data(), b.field(&cb.dims).data());
            let (ra, rb) = (a.region(&ca.dims), b.region(&cb.dims));
            assert_eq!(ra, rb);
            ra.validate_in(&ca.dims).unwrap();
            assert!(a.field(&ca.dims).range() > 0.0);
            // keep streams aligned after the extra field draw
            let _ = b.field(&cb.dims);
        }
    }

    #[test]
    fn shrink_halves_until_minimal() {
        let mut g = CaseGen::new(3);
        let mut cfg = g.dataset();
        let mut steps = 0;
        while let Some(smaller) = shrink(&cfg) {
            assert!(smaller.dims.iter().sum::<usize>() < cfg.dims.iter().sum::<usize>());
            for (b, d) in smaller.ae_block.iter().zip(&smaller.dims) {
                assert!(b <= d && *b >= 1);
            }
            cfg = smaller;
            steps += 1;
            assert!(steps < 32, "shrink must terminate");
        }
        assert!(cfg.dims.iter().all(|&d| d == 2));
    }
}
