//! Micro-benchmark harness (criterion substitute; DESIGN.md §4).
//!
//! Plain `harness = false` benches call [`Bench::run`] per case: warmup,
//! then timed iterations until a wall-clock budget or max-iter cap, then
//! mean / median / p95 / stddev over per-iteration times. Results print as
//! a table and can be appended to a CSV for EXPERIMENTS.md §Perf.

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
    pub stddev_ns: f64,
    /// Optional throughput denominator (elements/bytes per iteration).
    pub items_per_iter: Option<f64>,
}

impl Stats {
    pub fn throughput_per_sec(&self) -> Option<f64> {
        self.items_per_iter.map(|n| n / (self.mean_ns * 1e-9))
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

fn fmt_rate(r: f64) -> String {
    if r >= 1e9 {
        format!("{:.2} G/s", r / 1e9)
    } else if r >= 1e6 {
        format!("{:.2} M/s", r / 1e6)
    } else if r >= 1e3 {
        format!("{:.2} k/s", r / 1e3)
    } else {
        format!("{r:.1} /s")
    }
}

pub struct Bench {
    pub budget: Duration,
    pub warmup: Duration,
    pub max_iters: usize,
    pub results: Vec<Stats>,
}

impl Default for Bench {
    fn default() -> Self {
        Self {
            budget: Duration::from_secs(2),
            warmup: Duration::from_millis(300),
            max_iters: 10_000,
            results: Vec::new(),
        }
    }
}

impl Bench {
    pub fn new() -> Self {
        let mut b = Self::default();
        // quick mode for CI / smoke runs
        if std::env::var_os("BENCH_FAST").is_some() {
            b.budget = Duration::from_millis(300);
            b.warmup = Duration::from_millis(50);
        }
        b
    }

    /// Benchmark `f`, which performs ONE iteration of the workload.
    pub fn run<F: FnMut()>(&mut self, name: &str, mut f: F) -> &Stats {
        self.run_with_items(name, None, &mut f)
    }

    /// Benchmark with a throughput denominator (items or bytes per iter).
    pub fn run_items<F: FnMut()>(&mut self, name: &str, items: f64, mut f: F) -> &Stats {
        self.run_with_items(name, Some(items), &mut f)
    }

    fn run_with_items(&mut self, name: &str, items: Option<f64>, f: &mut dyn FnMut()) -> &Stats {
        // warmup
        let w0 = Instant::now();
        let mut warm_iters = 0usize;
        while w0.elapsed() < self.warmup && warm_iters < self.max_iters {
            f();
            warm_iters += 1;
        }
        // timed
        let mut times = Vec::with_capacity(256);
        let b0 = Instant::now();
        while b0.elapsed() < self.budget && times.len() < self.max_iters {
            let t0 = Instant::now();
            f();
            times.push(t0.elapsed().as_nanos() as f64);
        }
        if times.is_empty() {
            times.push(0.0);
        }
        let mut sorted = times.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = times.len();
        let mean = times.iter().sum::<f64>() / n as f64;
        let median = sorted[n / 2];
        let p95 = sorted[((n as f64) * 0.95) as usize % n];
        let var = times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>() / n as f64;
        let stats = Stats {
            name: name.to_string(),
            iters: n,
            mean_ns: mean,
            median_ns: median,
            p95_ns: p95,
            stddev_ns: var.sqrt(),
            items_per_iter: items,
        };
        let tp = stats
            .throughput_per_sec()
            .map(|r| format!("  [{}]", fmt_rate(r)))
            .unwrap_or_default();
        println!(
            "{:<44} {:>10}  median {:>10}  p95 {:>10}  ±{:>9}  n={}{}",
            stats.name,
            fmt_ns(stats.mean_ns),
            fmt_ns(stats.median_ns),
            fmt_ns(stats.p95_ns),
            fmt_ns(stats.stddev_ns),
            stats.iters,
            tp
        );
        self.results.push(stats);
        self.results.last().unwrap()
    }

    /// Append all results to a CSV (for EXPERIMENTS.md §Perf bookkeeping).
    pub fn write_csv(&self, path: &str) -> std::io::Result<()> {
        use std::io::Write;
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut w = std::fs::File::create(path)?;
        writeln!(w, "name,iters,mean_ns,median_ns,p95_ns,stddev_ns,items_per_iter")?;
        for s in &self.results {
            writeln!(
                w,
                "{},{},{:.1},{:.1},{:.1},{:.1},{}",
                s.name,
                s.iters,
                s.mean_ns,
                s.median_ns,
                s.p95_ns,
                s.stddev_ns,
                s.items_per_iter.map(|x| x.to_string()).unwrap_or_default()
            )?;
        }
        Ok(())
    }
}

/// Prevent the optimizer from eliding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Median wall-clock seconds of `iters` runs of `f` (after one warmup
/// run). The shared timing helper of the `harness = false` bench
/// binaries (`fieldset_throughput`, `region_decode`,
/// `stream_throughput`) — true median for even sample counts (with 2
/// samples, picking `times[1]` would report the worst case, not the
/// middle).
pub fn median_secs(mut f: impl FnMut(), iters: usize) -> f64 {
    f(); // warmup
    let mut times: Vec<f64> = (0..iters)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = times.len();
    if n % 2 == 1 {
        times[n / 2]
    } else {
        (times[n / 2 - 1] + times[n / 2]) / 2.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let mut b = Bench {
            budget: Duration::from_millis(30),
            warmup: Duration::from_millis(5),
            max_iters: 1000,
            results: vec![],
        };
        let mut acc = 0u64;
        let s = b.run("spin", || {
            for i in 0..1000u64 {
                acc = acc.wrapping_add(black_box(i));
            }
        });
        assert!(s.mean_ns > 0.0);
        assert!(s.iters >= 1);
    }

    #[test]
    fn csv_written(){
        let mut b = Bench {
            budget: Duration::from_millis(5),
            warmup: Duration::from_millis(1),
            max_iters: 10,
            results: vec![],
        };
        b.run_items("x", 100.0, || {});
        let path = std::env::temp_dir().join("attn_reduce_bench_test.csv");
        b.write_csv(path.to_str().unwrap()).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.lines().count() == 2);
        assert!(text.contains("x,"));
    }
}
