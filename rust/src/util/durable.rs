//! Crash-safe persistence: every archive/stream/CLI output funnels
//! through [`write_atomic`], so a final filename always names complete
//! bytes.
//!
//! The sequence is the classic temp-in-dir protocol: write to a
//! same-directory temp file, `fsync` it, `rename(2)` over the final
//! name, then `fsync` the parent directory so the rename itself is
//! durable. A crash at any point leaves either the old file (or
//! nothing) under the final name — never a torn prefix. Each step
//! carries a [`failpoint`](crate::util::failpoint) hook
//! (`durable.write`, `durable.fsync`, `durable.rename`,
//! `durable.dir_fsync`) so `tests/crash_recovery.rs` can prove that
//! claim byte-by-byte, and outcomes are counted in
//! `attn_durable_writes_total{outcome=...}`.

use std::io::Write;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::failpoint::{self, Consume};
use crate::Result;
use anyhow::Context;

/// Failpoint names, public so tests spell them consistently.
pub const FP_WRITE: &str = "durable.write";
pub const FP_FSYNC: &str = "durable.fsync";
pub const FP_RENAME: &str = "durable.rename";
pub const FP_DIR_FSYNC: &str = "durable.dir_fsync";

/// Write `bytes` through a failpoint-instrumented `write_all`: a torn
/// budget lands the partial prefix on disk (flushed to the OS) before
/// the injected failure fires — exactly the state a crash between two
/// `write(2)` calls leaves behind.
pub fn write_all_hooked(f: &mut std::fs::File, name: &str, bytes: &[u8]) -> std::io::Result<()> {
    match failpoint::consume(name, bytes.len()) {
        Consume::Pass => f.write_all(bytes),
        Consume::Partial(n) => {
            let _ = f.write_all(&bytes[..n]);
            let _ = f.sync_data();
            Err(failpoint::trigger(name))
        }
    }
}

/// `fsync` a directory so a rename inside it survives power loss.
/// Platforms where directories cannot be opened/synced (non-POSIX)
/// degrade to a no-op rather than failing the write.
pub fn fsync_dir(dir: &Path) -> std::io::Result<()> {
    failpoint::hit(FP_DIR_FSYNC)?;
    match std::fs::File::open(dir) {
        Ok(f) => f.sync_all(),
        Err(_) => Ok(()),
    }
}

/// A collision-free same-directory temp name for `path`.
fn temp_sibling(path: &Path) -> std::path::PathBuf {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let n = SEQ.fetch_add(1, Ordering::Relaxed);
    let file = path.file_name().map(|s| s.to_string_lossy()).unwrap_or_default();
    path.with_file_name(format!(".{file}.tmp-{}-{n}", std::process::id()))
}

/// Atomically persist `bytes` at `path`: temp file in the same
/// directory → write → fsync → rename → fsync the directory. On any
/// failure the temp file is removed and the final name is untouched
/// (the previous file, if any, survives intact). Parent directories
/// are created as needed.
pub fn write_atomic(path: impl AsRef<Path>, bytes: &[u8]) -> Result<()> {
    let path = path.as_ref();
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let tmp = temp_sibling(path);
    let result = (|| -> Result<()> {
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating temp file {}", tmp.display()))?;
        write_all_hooked(&mut f, FP_WRITE, bytes)
            .with_context(|| format!("writing {}", tmp.display()))?;
        failpoint::hit(FP_FSYNC)
            .and_then(|()| f.sync_all())
            .with_context(|| format!("fsyncing {}", tmp.display()))?;
        drop(f);
        failpoint::hit(FP_RENAME)
            .map_err(anyhow::Error::from)
            .and_then(|()| {
                std::fs::rename(&tmp, path).map_err(anyhow::Error::from)
            })
            .with_context(|| {
                format!("renaming {} -> {}", tmp.display(), path.display())
            })?;
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                fsync_dir(dir)
                    .with_context(|| format!("fsyncing directory {}", dir.display()))?;
            }
        }
        Ok(())
    })();
    match result {
        Ok(()) => {
            crate::obs::durable_write("committed");
            Ok(())
        }
        Err(e) => {
            let _ = std::fs::remove_file(&tmp);
            crate::obs::durable_write("failed");
            Err(e)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::failpoint::tests::test_lock;

    fn tmp_dir(name: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("attn_durable_{name}"));
        std::fs::remove_dir_all(&d).ok();
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn writes_land_complete_and_overwrite_atomically() {
        let _g = test_lock();
        failpoint::disarm_all();
        let d = tmp_dir("ok");
        let p = d.join("a.bin");
        write_atomic(&p, b"first version").unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"first version");
        write_atomic(&p, b"second").unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"second");
        // no temp litter
        assert_eq!(std::fs::read_dir(&d).unwrap().count(), 1);
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn failed_write_leaves_the_old_file_and_no_temp() {
        let _g = test_lock();
        failpoint::disarm_all();
        let d = tmp_dir("torn");
        let p = d.join("a.bin");
        write_atomic(&p, b"stable contents").unwrap();
        for spec in ["after:4", "error"] {
            failpoint::arm(FP_WRITE, spec).unwrap();
            let err = write_atomic(&p, b"replacement that tears").unwrap_err();
            failpoint::disarm_all();
            assert!(err.to_string().contains("writing"), "{err:#}");
            assert_eq!(std::fs::read(&p).unwrap(), b"stable contents", "{spec}");
            assert_eq!(std::fs::read_dir(&d).unwrap().count(), 1, "temp cleaned ({spec})");
        }
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn fsync_and_rename_failures_never_tear_the_final_name() {
        let _g = test_lock();
        failpoint::disarm_all();
        let d = tmp_dir("fsync");
        let p = d.join("a.bin");
        for fp in [FP_FSYNC, FP_RENAME] {
            failpoint::arm(fp, "error").unwrap();
            assert!(write_atomic(&p, b"never visible").is_err());
            failpoint::disarm_all();
            assert!(!p.exists(), "{fp}: final name must stay absent");
            assert_eq!(std::fs::read_dir(&d).unwrap().count(), 0, "{fp}: temp cleaned");
        }
        // a dir-fsync failure happens after the rename: the file is
        // complete under its final name, the caller just learns the
        // rename may not be durable yet
        failpoint::arm(FP_DIR_FSYNC, "error").unwrap();
        assert!(write_atomic(&p, b"complete").is_err());
        failpoint::disarm_all();
        assert_eq!(std::fs::read(&p).unwrap(), b"complete");
        std::fs::remove_dir_all(&d).ok();
    }
}
