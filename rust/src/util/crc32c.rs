//! CRC32C (Castagnoli) — the checksum behind the `XSUM` integrity
//! trailer and the v4 per-record CRCs.
//!
//! Software slicing-by-8 over the reflected polynomial `0x82F63B78`
//! (the same function iSCSI, ext4, and the SSE4.2 `crc32` instruction
//! compute), implemented in-tree per the offline-build policy. Tables
//! are built once on first use; the hot loop consumes 8 bytes per
//! iteration, which is plenty for write-path checksumming (the cost is
//! dwarfed by the entropy coder on every archive of interest).

use std::sync::OnceLock;

const POLY: u32 = 0x82F6_3B78;

/// 8 tables x 256 entries: `TABLES[0]` is the classic byte-at-a-time
/// table; `TABLES[k][b]` advances byte `b` through `k` additional zero
/// bytes, letting the loop fold 8 input bytes per step.
fn tables() -> &'static [[u32; 256]; 8] {
    static TABLES: OnceLock<[[u32; 256]; 8]> = OnceLock::new();
    TABLES.get_or_init(|| {
        let mut t = [[0u32; 256]; 8];
        for b in 0..256u32 {
            let mut crc = b;
            for _ in 0..8 {
                crc = if crc & 1 != 0 { (crc >> 1) ^ POLY } else { crc >> 1 };
            }
            t[0][b as usize] = crc;
        }
        for k in 1..8 {
            for b in 0..256usize {
                let prev = t[k - 1][b];
                t[k][b] = (prev >> 8) ^ t[0][(prev & 0xFF) as usize];
            }
        }
        t
    })
}

/// CRC32C of `bytes` (init/final XOR `0xFFFF_FFFF`, reflected).
pub fn crc32c(bytes: &[u8]) -> u32 {
    update(0, bytes)
}

/// Continue a running CRC32C: `update(update(0, a), b) == crc32c(a ++ b)`.
pub fn update(crc: u32, bytes: &[u8]) -> u32 {
    let t = tables();
    let mut crc = !crc;
    let mut chunks = bytes.chunks_exact(8);
    for c in chunks.by_ref() {
        let lo = u32::from_le_bytes(c[0..4].try_into().unwrap()) ^ crc;
        let hi = u32::from_le_bytes(c[4..8].try_into().unwrap());
        crc = t[7][(lo & 0xFF) as usize]
            ^ t[6][((lo >> 8) & 0xFF) as usize]
            ^ t[5][((lo >> 16) & 0xFF) as usize]
            ^ t[4][(lo >> 24) as usize]
            ^ t[3][(hi & 0xFF) as usize]
            ^ t[2][((hi >> 8) & 0xFF) as usize]
            ^ t[1][((hi >> 16) & 0xFF) as usize]
            ^ t[0][(hi >> 24) as usize];
    }
    for &b in chunks.remainder() {
        crc = (crc >> 8) ^ t[0][((crc ^ b as u32) & 0xFF) as usize];
    }
    !crc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // RFC 3720 / iSCSI test vectors
        assert_eq!(crc32c(b"123456789"), 0xE306_9283);
        assert_eq!(crc32c(&[0u8; 32]), 0x8A91_36AA);
        assert_eq!(crc32c(&[0xFFu8; 32]), 0x62A8_AB43);
        let ascending: Vec<u8> = (0u8..32).collect();
        assert_eq!(crc32c(&ascending), 0x46DD_794E);
        assert_eq!(crc32c(b""), 0);
    }

    #[test]
    fn incremental_update_matches_one_shot() {
        let data: Vec<u8> = (0..1000u32).map(|i| (i * 7 + 13) as u8).collect();
        for split in [0, 1, 7, 8, 9, 500, 999, 1000] {
            let inc = update(update(0, &data[..split]), &data[split..]);
            assert_eq!(inc, crc32c(&data), "split {split}");
        }
    }

    #[test]
    fn every_single_byte_flip_changes_the_crc() {
        let data: Vec<u8> = (0..257u32).map(|i| (i * 31 + 5) as u8).collect();
        let base = crc32c(&data);
        let mut flipped = data.clone();
        for i in 0..flipped.len() {
            for bit in 0..8 {
                flipped[i] ^= 1 << bit;
                assert_ne!(crc32c(&flipped), base, "flip byte {i} bit {bit}");
                flipped[i] ^= 1 << bit;
            }
        }
    }
}
