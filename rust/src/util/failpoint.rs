//! Deterministic fault injection for the durability layer.
//!
//! A *failpoint* is a named hook compiled into a write path
//! (`durable.write`, `stream.record`, `durable.fsync`, ...). Unarmed
//! hooks cost one relaxed atomic load. Armed hooks simulate the crash
//! and media failures `tests/crash_recovery.rs` sweeps:
//!
//! - `error` — the operation fails immediately (fsync/rename refusal);
//! - `exit:CODE` — the process exits on the spot (kill -9 mid-write:
//!   bytes written so far are in the page cache, nothing after them);
//! - `after:N` — the next `N` bytes succeed, then the write tears:
//!   the budget-crossing write lands **partially** (a short write)
//!   before the failure triggers, so the on-disk state is a torn
//!   prefix, exactly like a crash between two `write(2)` calls;
//! - `after:N:exit:CODE` — torn prefix, then process exit.
//!
//! Arming is either programmatic (tests in the same process:
//! [`arm`]/[`disarm`]/[`disarm_all`]) or inherited from the
//! environment: `ATTN_FAILPOINT="name=spec;name2=spec2"` — the
//! subprocess path, which is how the kill-9 smoke drives a real CLI
//! run to death mid-append.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, Once, OnceLock};

/// What an armed failpoint does once it fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Fire {
    /// Return an `io::Error` from the hook.
    Error,
    /// `std::process::exit(code)` — no unwinding, no cleanup.
    Exit(i32),
}

#[derive(Debug)]
struct Armed {
    /// Bytes the hook still lets through before firing (`u64::MAX`
    /// means "fire on the very next hit, byte budget irrelevant").
    remaining: u64,
    fire: Fire,
}

static ANY_ARMED: AtomicBool = AtomicBool::new(false);
static ENV_INIT: Once = Once::new();

fn registry() -> &'static Mutex<HashMap<String, Armed>> {
    static REG: OnceLock<Mutex<HashMap<String, Armed>>> = OnceLock::new();
    REG.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Parse one `spec` (`error` | `exit:C` | `after:N` | `after:N:exit:C`).
fn parse_spec(spec: &str) -> Result<Armed, String> {
    let parts: Vec<&str> = spec.split(':').collect();
    match parts.as_slice() {
        ["error"] => Ok(Armed { remaining: 0, fire: Fire::Error }),
        ["exit", c] => c
            .parse()
            .map(|code| Armed { remaining: 0, fire: Fire::Exit(code) })
            .map_err(|_| format!("bad exit code in failpoint spec {spec:?}")),
        ["after", n] => n
            .parse()
            .map(|remaining| Armed { remaining, fire: Fire::Error })
            .map_err(|_| format!("bad byte budget in failpoint spec {spec:?}")),
        ["after", n, "exit", c] => {
            let remaining = n
                .parse()
                .map_err(|_| format!("bad byte budget in failpoint spec {spec:?}"))?;
            let code = c
                .parse()
                .map_err(|_| format!("bad exit code in failpoint spec {spec:?}"))?;
            Ok(Armed { remaining, fire: Fire::Exit(code) })
        }
        _ => Err(format!("unknown failpoint spec {spec:?}")),
    }
}

fn init_from_env() {
    ENV_INIT.call_once(|| {
        let Ok(val) = std::env::var("ATTN_FAILPOINT") else {
            return;
        };
        let mut reg = registry().lock().unwrap();
        for pair in val.split(';').filter(|p| !p.is_empty()) {
            let Some((name, spec)) = pair.split_once('=') else {
                eprintln!("failpoint: ignoring malformed ATTN_FAILPOINT entry {pair:?}");
                continue;
            };
            match parse_spec(spec.trim()) {
                Ok(armed) => {
                    reg.insert(name.trim().to_string(), armed);
                }
                Err(e) => eprintln!("failpoint: {e}"),
            }
        }
        if !reg.is_empty() {
            ANY_ARMED.store(true, Ordering::SeqCst);
        }
    });
}

/// Arm failpoint `name` with `spec` (test use — same grammar as the
/// `ATTN_FAILPOINT` env var).
pub fn arm(name: &str, spec: &str) -> crate::Result<()> {
    init_from_env();
    let armed = parse_spec(spec).map_err(|e| anyhow::anyhow!(e))?;
    registry().lock().unwrap().insert(name.to_string(), armed);
    ANY_ARMED.store(true, Ordering::SeqCst);
    Ok(())
}

/// Disarm failpoint `name` (no-op when it was never armed).
pub fn disarm(name: &str) {
    init_from_env();
    let mut reg = registry().lock().unwrap();
    reg.remove(name);
    if reg.is_empty() {
        ANY_ARMED.store(false, Ordering::SeqCst);
    }
}

/// Disarm everything (test teardown).
pub fn disarm_all() {
    init_from_env();
    registry().lock().unwrap().clear();
    ANY_ARMED.store(false, Ordering::SeqCst);
}

fn fire(name: &str, fire: Fire) -> std::io::Error {
    match fire {
        Fire::Error => std::io::Error::other(format!("failpoint {name:?} injected failure")),
        Fire::Exit(code) => {
            eprintln!("failpoint {name:?}: exiting with code {code}");
            std::process::exit(code);
        }
    }
}

/// Non-byte hook (fsync, rename): fails/exits when `name` is armed
/// with an exhausted budget; passes otherwise. A still-positive
/// `after:N` budget does not fire here — byte budgets belong to
/// [`consume`] hooks.
pub fn hit(name: &str) -> std::io::Result<()> {
    if !ANY_ARMED.load(Ordering::Relaxed) {
        init_from_env();
        if !ANY_ARMED.load(Ordering::Relaxed) {
            return Ok(());
        }
    }
    let mut reg = registry().lock().unwrap();
    match reg.get_mut(name) {
        Some(armed) if armed.remaining == 0 => {
            let f = armed.fire;
            drop(reg);
            Err(fire(name, f))
        }
        _ => Ok(()),
    }
}

/// Outcome of a byte-budget check before writing `len` bytes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Consume {
    /// Write all `len` bytes normally.
    Pass,
    /// Write only the first `n` bytes (torn prefix), then call
    /// [`trigger`] to fail or exit.
    Partial(usize),
}

/// Byte hook: account `len` bytes against `name`'s budget. `Pass` when
/// unarmed or the budget covers the write; `Partial(n)` when the write
/// crosses the budget boundary (`n` may be 0 — the write tears at its
/// first byte).
pub fn consume(name: &str, len: usize) -> Consume {
    if !ANY_ARMED.load(Ordering::Relaxed) {
        init_from_env();
        if !ANY_ARMED.load(Ordering::Relaxed) {
            return Consume::Pass;
        }
    }
    let mut reg = registry().lock().unwrap();
    match reg.get_mut(name) {
        Some(armed) => {
            if (len as u64) <= armed.remaining {
                armed.remaining -= len as u64;
                Consume::Pass
            } else {
                let n = armed.remaining as usize;
                armed.remaining = 0;
                Consume::Partial(n)
            }
        }
        None => Consume::Pass,
    }
}

/// Fire `name` after a [`Consume::Partial`] write landed: returns the
/// injected error, or exits the process (kill -9 simulation).
pub fn trigger(name: &str) -> std::io::Error {
    let f = registry()
        .lock()
        .unwrap()
        .get(name)
        .map(|a| a.fire)
        .unwrap_or(Fire::Error);
    fire(name, f)
}

#[cfg(test)]
mod tests {
    use super::*;

    // failpoint state is process-global; tests that arm it serialize
    // through this lock so `cargo test`'s parallelism can't interleave
    pub(crate) fn test_lock() -> std::sync::MutexGuard<'static, ()> {
        static LOCK: Mutex<()> = Mutex::new(());
        LOCK.lock().unwrap_or_else(|p| p.into_inner())
    }

    #[test]
    fn unarmed_hooks_pass() {
        let _g = test_lock();
        disarm_all();
        assert!(hit("nope").is_ok());
        assert_eq!(consume("nope", 100), Consume::Pass);
    }

    #[test]
    fn error_spec_fires_on_hit() {
        let _g = test_lock();
        disarm_all();
        arm("x", "error").unwrap();
        assert!(hit("x").is_err());
        assert!(hit("other").is_ok(), "only the armed name fires");
        disarm("x");
        assert!(hit("x").is_ok());
    }

    #[test]
    fn byte_budget_tears_exactly_at_the_boundary() {
        let _g = test_lock();
        disarm_all();
        arm("w", "after:10").unwrap();
        assert_eq!(consume("w", 4), Consume::Pass);
        assert_eq!(consume("w", 6), Consume::Pass);
        assert_eq!(consume("w", 5), Consume::Partial(0), "budget exhausted");
        assert!(hit("w").is_err(), "exhausted budget also fails plain hits");
        disarm_all();

        arm("w", "after:10").unwrap();
        assert_eq!(consume("w", 7), Consume::Pass);
        assert_eq!(consume("w", 7), Consume::Partial(3), "short write of 3");
        let err = trigger("w");
        assert!(err.to_string().contains("injected"), "{err}");
        disarm_all();
    }

    #[test]
    fn bad_specs_are_rejected() {
        let _g = test_lock();
        disarm_all();
        assert!(arm("x", "afterwards").is_err());
        assert!(arm("x", "after:abc").is_err());
        assert!(arm("x", "exit:none").is_err());
        assert!(arm("x", "after:3:exit:zz").is_err());
        assert!(hit("x").is_ok(), "failed arm leaves the hook unarmed");
    }
}
