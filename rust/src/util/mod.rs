//! In-repo infrastructure substrate.
//!
//! This box builds offline against a minimal vendored crate set (xla,
//! anyhow). Everything one would normally pull from crates.io —
//! JSON, CLI parsing, RNG, a thread pool, a bench harness, property
//! testing — is implemented here instead (DESIGN.md §4).

pub mod bench;
pub mod cli;
pub mod crc32c;
pub mod durable;
pub mod failpoint;
pub mod json;
pub mod parallel;
pub mod propgen;
pub mod rng;
