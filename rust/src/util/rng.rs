//! Deterministic PRNG substrate (rand-crate substitute; DESIGN.md §4).
//!
//! SplitMix64 for seeding + xoshiro256** for the stream — the standard
//! pairing. Used by the synthetic data generators, the training shuffler
//! and the in-repo property-test harness; all runs are reproducible from
//! a config seed.

/// xoshiro256** seeded via SplitMix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion
        let mut x = seed;
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Self { s }
    }

    /// Independent child stream (for per-worker / per-field generators).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.uniform() * (hi - lo)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal (Box–Muller; one value per call, cache-free).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-300 {
                let u2 = self.uniform();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i + 1);
            items.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(8);
        assert_ne!(Rng::new(7).next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval_and_roughly_uniform() {
        let mut rng = Rng::new(42);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(1);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(3);
        let mut v: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_streams_decorrelate() {
        let mut root = Rng::new(9);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
