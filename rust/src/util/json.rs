//! Minimal JSON parser + writer (serde_json substitute; DESIGN.md §4).
//!
//! Covers the full JSON grammar; used for `artifacts/manifest.json`,
//! checkpoint metadata, experiment CSd/JSON outputs and the archive header.
//! Object key order is preserved (vec of pairs) so emitted files diff
//! cleanly.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::Result;
use anyhow::bail;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(Vec<(String, Value)>),
}

impl Value {
    pub fn parse(text: &str) -> Result<Value> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing data at byte {}", p.pos);
        }
        Ok(v)
    }

    // -- accessors ---------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Value> {
        match self {
            Value::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Value)]> {
        match self {
            Value::Obj(v) => Some(v),
            _ => None,
        }
    }

    /// `obj.get(key)` chain with a readable error.
    pub fn req(&self, key: &str) -> Result<&Value> {
        self.get(key).ok_or_else(|| anyhow::anyhow!("missing key {key:?}"))
    }

    pub fn usize_vec(&self) -> Result<Vec<usize>> {
        match self {
            Value::Arr(v) => v
                .iter()
                .map(|x| x.as_usize().ok_or_else(|| anyhow::anyhow!("not a number")))
                .collect(),
            _ => bail!("not an array"),
        }
    }

    // -- writer ------------------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Value::Str(s) => write_escaped(out, s),
            Value::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                        if pretty {
                            out.push(' ');
                        }
                    }
                    item.write(out, indent, pretty);
                }
                out.push(']');
            }
            Value::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        for _ in 0..(indent + 1) {
                            out.push(' ');
                        }
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if pretty && !pairs.is_empty() {
                    out.push('\n');
                    for _ in 0..indent {
                        out.push(' ');
                    }
                }
                out.push('}');
            }
        }
    }
}

/// Builder helpers so call sites stay terse.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Value {
    Value::Num(n)
}

pub fn s(v: impl Into<String>) -> Value {
    Value::Str(v.into())
}

pub fn arr_usize(v: &[usize]) -> Value {
    Value::Arr(v.iter().map(|&x| Value::Num(x as f64)).collect())
}

pub fn arr_f64(v: &[f64]) -> Value {
    Value::Arr(v.iter().map(|&x| Value::Num(x)).collect())
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            bail!("expected {:?} at byte {}", c as char, self.pos)
        }
    }

    fn value(&mut self) -> Result<Value> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {other:?} at byte {}", self.pos),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.pos)
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Value::Num(text.parse::<f64>()?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                self.bytes
                                    .get(self.pos + 1..self.pos + 5)
                                    .ok_or_else(|| anyhow::anyhow!("bad \\u"))?,
                            )?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        other => bail!("bad escape {other:?}"),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy a run of plain bytes (UTF-8 passes through)
                    let start = self.pos;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.pos += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.bytes[start..self.pos])?);
                }
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Arr(items));
                }
                other => bail!("expected , or ] got {other:?} at {}", self.pos),
            }
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            pairs.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Obj(pairs));
                }
                other => bail!("expected , or }} got {other:?} at {}", self.pos),
            }
        }
    }
}

/// Parse a JSON file into a sorted map of top-level keys (debug helper).
pub fn top_level_keys(v: &Value) -> BTreeMap<String, &'static str> {
    let mut out = BTreeMap::new();
    if let Value::Obj(pairs) = v {
        for (k, val) in pairs {
            let ty = match val {
                Value::Null => "null",
                Value::Bool(_) => "bool",
                Value::Num(_) => "num",
                Value::Str(_) => "str",
                Value::Arr(_) => "arr",
                Value::Obj(_) => "obj",
            };
            out.insert(k.clone(), ty);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trips_nested() {
        let text = r#"{"a": [1, 2.5, -3e2], "b": {"c": "x\ny", "d": null}, "e": true}"#;
        let v = Value::parse(text).unwrap();
        assert_eq!(v.get("a").unwrap().idx(1).unwrap().as_f64(), Some(2.5));
        assert_eq!(v.get("a").unwrap().idx(2).unwrap().as_f64(), Some(-300.0));
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_str(), Some("x\ny"));
        assert_eq!(v.get("e").unwrap().as_bool(), Some(true));
        let re = Value::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(re, v);
        let re2 = Value::parse(&v.to_string_compact()).unwrap();
        assert_eq!(re2, v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("{}extra").is_err());
        assert!(Value::parse("nul").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let v = Value::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }

    #[test]
    fn integers_stay_integers_in_output() {
        let v = Value::Num(42.0);
        assert_eq!(v.to_string_compact(), "42");
        let v = Value::Num(0.5);
        assert_eq!(v.to_string_compact(), "0.5");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Value::parse("[]").unwrap(), Value::Arr(vec![]));
        assert_eq!(Value::parse("{}").unwrap(), Value::Obj(vec![]));
        assert_eq!(Value::parse(" [ ] ").unwrap(), Value::Arr(vec![]));
    }

    #[test]
    fn usize_vec_helper() {
        let v = Value::parse("[1, 2, 3]").unwrap();
        assert_eq!(v.usize_vec().unwrap(), vec![1, 2, 3]);
        assert!(Value::parse("[\"x\"]").unwrap().usize_vec().is_err());
    }
}
