//! Tiny CLI argument parser (clap substitute; DESIGN.md §4).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.

use std::collections::HashMap;

use crate::Result;
use anyhow::bail;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: HashMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse raw argv (without the program name). `flag_names` lists
    /// options that take no value.
    pub fn parse(raw: &[String], flag_names: &[&str]) -> Result<Args> {
        let mut out = Args::default();
        let mut i = 0;
        while i < raw.len() {
            let a = &raw[i];
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&body) {
                    out.flags.push(body.to_string());
                } else if i + 1 < raw.len() {
                    out.options.insert(body.to_string(), raw[i + 1].clone());
                    i += 1;
                } else {
                    bail!("option --{body} needs a value");
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }

    pub fn get_f32(&self, name: &str, default: f32) -> Result<f32> {
        Ok(self.get_f64(name, default as f64)? as f32)
    }

    /// Comma-separated f64 list.
    pub fn get_f64_list(&self, name: &str, default: &[f64]) -> Result<Vec<f64>> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|x| x.trim().parse::<f64>().map_err(Into::into))
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_mixed_styles() {
        let a = Args::parse(
            &sv(&["compress", "--dataset", "s3d", "--tau=0.5", "--verbose", "out.ar"]),
            &["verbose"],
        )
        .unwrap();
        assert_eq!(a.positional, vec!["compress", "out.ar"]);
        assert_eq!(a.get("dataset"), Some("s3d"));
        assert_eq!(a.get_f64("tau", 0.0).unwrap(), 0.5);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(&sv(&["--dataset"]), &[]).is_err());
    }

    #[test]
    fn list_parsing() {
        let a = Args::parse(&sv(&["--taus", "0.1, 0.2,0.3"]), &[]).unwrap();
        assert_eq!(a.get_f64_list("taus", &[]).unwrap(), vec![0.1, 0.2, 0.3]);
        assert_eq!(a.get_f64_list("other", &[1.0]).unwrap(), vec![1.0]);
    }

    #[test]
    fn defaults() {
        let a = Args::parse(&sv(&[]), &[]).unwrap();
        assert_eq!(a.get_usize("steps", 7).unwrap(), 7);
        assert_eq!(a.get_or("name", "x"), "x");
    }
}
