//! attn-reduce CLI — the L3 launcher over the unified codec API.
//!
//! ```text
//! attn-reduce generate   --dataset s3d --scale bench --out field.f32
//! attn-reduce train      --dataset s3d [--steps N] [--ckpt-dir DIR]
//! attn-reduce compress   --codec hier|sz3|zfp|gbae|adaptive --bound nrmse:1e-3
//!                        [--dataset D] [--in field.f32] --out data.ardc
//! attn-reduce compress   --all-vars [--vars N]    # one Archive v2 per dataset
//! attn-reduce compress   --in a.f32,b.f32,...     # multi-input -> Archive v2
//! attn-reduce decompress --in data.ardc --out recon.f32
//! attn-reduce extract    --in data.ardc --region 0:8,16:48,0:64 --out sub.f32
//! attn-reduce stream append  --out run.tstr --codec sz3 --steps 16 [--keyint 8]
//! attn-reduce stream extract --in run.tstr --step 12 [--region 0:32,0:64]
//! attn-reduce stream info    --in run.tstr
//! attn-reduce experiment <table1|table2|fig4|fig5|fig6|fig7|fig8|fig9>
//! attn-reduce verify     --root DIR [--repair]   # offline fsck
//! attn-reduce info       # manifest + platform summary
//! attn-reduce info       --in data.ardc [--json]   # byte breakdown
//! attn-reduce serve      --root DIR --addr 127.0.0.1:8080
//! ```

use std::rc::Rc;

use attn_reduce::codec::{
    archive_stats, AdaptiveCodec, Codec, CodecBuilder, CodecKind, ErrorBound, Sz3Codec,
    ZfpCodec,
};
use attn_reduce::compressor::{self, Archive, HierCompressor};
use attn_reduce::config::{self, DatasetKind, Scale};
use attn_reduce::data;
use attn_reduce::engine::{CodecExt, FieldSet};
use attn_reduce::experiments;
use attn_reduce::model::ParamStore;
use attn_reduce::obs;
use attn_reduce::runtime::Runtime;
use attn_reduce::serve::{self, ServeConfig, Server};
use attn_reduce::stream::{StreamReader, StreamWriter};
use attn_reduce::util::cli::Args;
use attn_reduce::util::parallel;
use attn_reduce::Result;

const USAGE: &str = "\
attn-reduce — attention-based data reduction with guaranteed error bounds

USAGE:
  attn-reduce <command> [options]

COMMANDS:
  generate     synthesize a dataset (--dataset s3d|e3sm|xgc --scale bench --out F)
  train        train HBAE+BAE for a dataset preset (--dataset D --steps N)
  compress     compress (--codec hier|sz3|zfp|gbae|adaptive)
               (--bound nrmse:1e-3|tau:T|abs:A|none)
               [--dataset D] [--in F] [--stream Q] --out A
               multi-field (one Archive v2 per dataset):
                 --all-vars [--vars N]   synthesize N variables (default 8)
                 --in a.f32,b.f32,...    load several fields
  decompress   decompress an archive using only its header (--in A --out F;
               a v2 archive writes one F.<field>.f32 per field)
  extract      decode only a region of interest (--in A --region
               i0:i1,j0:j1,... --out F); v3 archives touch only the
               intersecting blocks, v1/v2 fall back to full decode + crop;
               multi-field archives take [--field NAME] or write one
               F.<field>.f32 per field
  stream       temporal streams (append-only v4 TSTR containers):
                 append  --out S [--codec sz3|zfp|adaptive] [--bound B] [--keyint K]
                         [--dataset D --scale SC] --steps N | --in a.f32,b.f32,...
                         creates S or appends to it (codec/bound/keyint
                         then come from the stream header)
                 extract --in S --step T [--region i0:i1,...] --out F
                         decodes keyframe + residual chain, region decodes
                         only the intersecting blocks of each chain step
                 info    --in S   timeline, CR, per-step sizes
  serve        long-running HTTP service over a directory of archives and
               streams (--root DIR --addr HOST:PORT [--cache-bytes B]
               [--max-pending N]  shed connections past N queued (503)):
               GET  /v1/archives                     paginated listing
               GET  /v1/archives/{name}/info        byte breakdown (JSON)
               GET  /v1/archives/{name}/extract?region=i0:i1,...[&field=N]
               GET  /v1/streams/{name}/steps        timeline page
               GET  /v1/streams/{name}/extract?step=S[&region=...]
               POST /v1/compress?name=N[&codec=C&bound=B]   raw f32 body
               GET  /v1/stats                       counters + cache
               GET  /v1/metrics[?format=json]       Prometheus exposition
  experiment   reproduce a paper table/figure (table1 table2 fig4..fig9)
  verify       offline fsck over a directory (or one file) of archives and
               streams (--root DIR [--repair]): validates framing, XSUM
               checksums, block indices and timelines; exits non-zero if
               anything is damaged. --repair truncates torn stream tails
               back to the last complete step record and quarantines
               unrecoverable files (renamed to <name>.quarantine);
               without it the walk is strictly read-only
  info         --in A: per-section byte breakdown of an archive or stream
               (payload vs index vs framing, plus the entropy table/symbol
               split for sz3/zfp/adaptive payloads and the per-tile codec
               split for adaptive ones); --json prints the same numbers
               as one JSON document; without --in: artifact
               manifest + platform
  help         show this message
COMMON OPTIONS:
  --artifacts DIR   (default: ./artifacts; only the learned codecs need it)
  --ckpt-dir DIR    (default: ./results/ckpt)
  --scale bench|smoke|paper
  --steps N         training steps (default 300)
  --threads N       worker threads (precedence: --threads >
                    ATTN_REDUCE_THREADS > available_parallelism)
  --log-level L     error|warn|info|debug (default info; --quiet drops to error)
  --trace FILE      write pipeline spans as Chrome trace_event JSON (Perfetto)
  --verbose         dump the metrics registry to stderr after the command
  --quiet
";

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    if raw.is_empty() {
        eprintln!("{USAGE}");
        std::process::exit(2);
    }
    if let Err(e) = run(&raw) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(raw: &[String]) -> Result<()> {
    let flags = ["quiet", "retrain", "full", "help", "all-vars", "json", "verbose", "repair"];
    let args = Args::parse(raw, &flags)?;
    if args.flag("quiet") {
        std::env::set_var("ATTN_REDUCE_QUIET", "1");
        obs::log::set_level(obs::log::Level::Error);
    }
    if let Some(lvl) = args.get("log-level") {
        let parsed = obs::log::Level::parse(lvl).ok_or_else(|| {
            anyhow::anyhow!("--log-level expects error|warn|info|debug, got {lvl:?}")
        })?;
        obs::log::set_level(parsed);
    }
    if let Some(t) = args.get("threads") {
        let n: usize = t
            .parse()
            .map_err(|_| anyhow::anyhow!("--threads expects a positive integer, got {t:?}"))?;
        anyhow::ensure!(n > 0, "--threads must be at least 1");
        parallel::set_thread_override(n);
    }
    if args.flag("help") {
        println!("{USAGE}");
        return Ok(());
    }
    if args.flag("verbose") {
        // materialize the full catalog so the post-command dump covers
        // stages the command never exercised (they read as zeros)
        obs::preregister();
    }
    if args.get("trace").is_some() {
        obs::trace::start_tracing();
    }
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let result = match cmd {
        "generate" => cmd_generate(&args),
        "train" => cmd_train(&args),
        "compress" => cmd_compress(&args),
        "decompress" => cmd_decompress(&args),
        "extract" => cmd_extract(&args),
        "stream" => cmd_stream(&args),
        "serve" => cmd_serve(&args),
        "experiment" => {
            let id = args
                .positional
                .get(1)
                .ok_or_else(|| anyhow::anyhow!("experiment id required"))?;
            experiments::run_experiment(id, &args)
        }
        "verify" => cmd_verify(&args),
        "info" => cmd_info(&args),
        "help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => {
            // unknown subcommand is a usage error: report + exit non-zero
            eprintln!("error: unknown command {other:?}");
            eprintln!("{USAGE}");
            std::process::exit(2);
        }
    };
    // the trace covers whatever ran, even a failed command (the spans
    // up to the failure are exactly what a debugger wants); serve gets
    // here after a clean StopHandle shutdown
    if let Some(path) = args.get("trace") {
        match obs::trace::finish_trace(std::path::Path::new(path)) {
            Ok(n) => eprintln!("trace: wrote {n} spans to {path}"),
            Err(e) => eprintln!("trace: failed to write {path}: {e}"),
        }
    }
    if args.flag("verbose") {
        eprint!("{}", obs::dump_text());
    }
    result
}

fn dataset_kind(args: &Args) -> Result<DatasetKind> {
    DatasetKind::parse(args.get_or("dataset", "s3d"))
}

fn scale(args: &Args) -> Result<Scale> {
    Scale::parse(args.get_or("scale", "bench"))
}

/// Builder wired to the common CLI options.
fn builder(args: &Args) -> Result<CodecBuilder> {
    let d = config::TrainConfig::default();
    let train = config::TrainConfig {
        steps: args.get_usize("steps", d.steps)?,
        lr: args.get_f32("lr", d.lr)?,
        ..d
    };
    Ok(CodecBuilder::new()
        .artifacts(args.get_or("artifacts", "artifacts"))
        .ckpt_dir(args.get_or("ckpt-dir", "results/ckpt"))
        .scale(scale(args)?)
        .train(train))
}

/// The typed bound from `--bound`, with `--nrmse` / `--tau` kept as
/// legacy spellings. Default: `nrmse:1e-3`.
fn bound(args: &Args) -> Result<ErrorBound> {
    if let Some(b) = args.get("bound") {
        return ErrorBound::parse(b);
    }
    if let Some(t) = args.get("tau") {
        return ErrorBound::parse(&format!("tau:{t}"));
    }
    if let Some(t) = args.get("nrmse") {
        return ErrorBound::parse(&format!("nrmse:{t}"));
    }
    Ok(ErrorBound::Nrmse(1e-3))
}

fn load_field(args: &Args, cfg: &config::DatasetConfig) -> Result<attn_reduce::tensor::Tensor> {
    match args.get("in") {
        Some(path) if path.ends_with(".f32") => data::read_f32_file(path, cfg.dims.clone()),
        _ => Ok(data::generate(cfg)),
    }
}

fn cmd_generate(args: &Args) -> Result<()> {
    let cfg = config::dataset_preset(dataset_kind(args)?, scale(args)?);
    let out = args.get_or("out", "field.f32");
    let t = data::generate(&cfg);
    data::write_f32_file(out, &t)?;
    println!(
        "wrote {} ({} points, {:.1} MB, range [{:.4}, {:.4}])",
        out,
        t.len(),
        (t.len() * 4) as f64 / 1e6,
        t.min(),
        t.max()
    );
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let kind = dataset_kind(args)?;
    let mut cfg = config::pipeline_preset(kind, scale(args)?, 0.0);
    cfg.train.steps = args.get_usize("steps", cfg.train.steps)?;
    cfg.train.lr = args.get_f32("lr", cfg.train.lr)?;
    let rt = Rc::new(Runtime::open(args.get_or("artifacts", "artifacts"))?);
    let ckpt = std::path::PathBuf::from(args.get_or("ckpt-dir", "results/ckpt"));
    if args.flag("retrain") {
        std::fs::remove_file(ParamStore::default_path(&ckpt, &cfg.model.hbae_group)).ok();
        std::fs::remove_file(ParamStore::default_path(&ckpt, &cfg.model.bae_group)).ok();
    }
    let field = load_field(args, &cfg.dataset)?;
    let (_, reports) = HierCompressor::prepare(&rt, &cfg, &ckpt, &field)?;
    if reports.is_empty() {
        println!("checkpoints already present in {} (use --retrain)", ckpt.display());
    }
    for r in &reports {
        println!("{}", r.summary());
    }
    Ok(())
}

fn cmd_compress(args: &Args) -> Result<()> {
    let kind = dataset_kind(args)?;
    let codec_kind = CodecKind::parse(args.get_or("codec", "hier"))?;
    let bound = bound(args)?;
    let cfg = config::dataset_preset(kind, scale(args)?);
    let out = args.get_or("out", "data.ardc");
    let mut b = builder(args)?;

    // multi-field mode: --all-vars (synthetic variables) or a
    // comma-separated --in list; one Archive v2 container per dataset
    let multi_in: Option<Vec<&str>> = args
        .get("in")
        .filter(|s| s.contains(','))
        .map(|s| s.split(',').map(str::trim).filter(|p| !p.is_empty()).collect());
    if args.flag("all-vars") || multi_in.is_some() {
        anyhow::ensure!(
            args.get("stream").is_none(),
            "--stream is not supported in multi-field mode"
        );
        anyhow::ensure!(
            !(args.flag("all-vars") && args.get("in").is_some()),
            "--all-vars synthesizes variables and cannot be combined with --in \
             (for multiple real inputs use --in a.f32,b.f32,... without --all-vars)"
        );
        let set = match multi_in {
            Some(paths) => FieldSet::from_files(cfg.clone(), &paths)?,
            None => FieldSet::generate(kind, scale(args)?, args.get_usize("vars", 8)?),
        };
        anyhow::ensure!(!set.is_empty(), "multi-field mode needs at least one field");
        let codec = b.build(codec_kind, kind, set.field(0))?;
        let archive = codec.compress_set(&set, &bound)?;
        archive.save(out)?;
        println!(
            "fields = {} [{}], codec = {}, bound = {bound}",
            set.len(),
            set.names().join(", "),
            codec.id()
        );
        report_archive(out, &archive, None)?;
        return Ok(());
    }

    let field = load_field(args, &cfg)?;

    // streaming path (hier only): pipelined coordinator, same archive
    if let Some(depth) = args.get("stream") {
        anyhow::ensure!(
            codec_kind == CodecKind::Hier,
            "--stream is only supported by the hier codec"
        );
        let hier = b.build_hier(kind, &field)?;
        let (archive, stats) = hier.compress_streaming(&field, &bound, depth.parse()?)?;
        archive.save(out)?;
        println!("streamed: {}", stats.summary());
        report_archive(out, &archive, None)?;
        return Ok(());
    }

    let codec = b.build(codec_kind, kind, &field)?;
    let (archive, recon) = codec.compress_with_recon(&field, &bound)?;
    archive.save(out)?;
    let e = compressor::nrmse(&field, &recon);
    println!("codec = {}, bound = {bound}", codec.id());
    report_archive(out, &archive, Some(e))?;
    Ok(())
}

fn report_archive(out: &str, archive: &Archive, nrmse: Option<f64>) -> Result<()> {
    let stats = archive_stats(archive)?;
    println!("archive: {out} ({} bytes)", stats.archive_bytes);
    println!(
        "CR (paper accounting) = {:.1}, CR (total bytes) = {:.1}",
        stats.cr, stats.cr_total
    );
    if let Some(e) = nrmse {
        println!("NRMSE = {e:.3e}");
    }
    for (tag, sz) in &stats.section_sizes {
        println!("  section {tag}: {sz} bytes");
    }
    Ok(())
}

fn cmd_decompress(args: &Args) -> Result<()> {
    let archive = Archive::load(
        args.get("in").ok_or_else(|| anyhow::anyhow!("--in archive required"))?,
    )?;
    // the archive header carries codec id + dataset + groups: no preset
    // flags needed, only --ckpt-dir/--artifacts for the learned codecs
    let mut b = builder(args)?;
    let codec = b.for_archive(&archive)?;
    let out = args.get_or("out", "recon.f32");
    if archive.is_multi_field() {
        let set = codec.decompress_set(&archive)?;
        let stem = out.strip_suffix(".f32").unwrap_or(out);
        for (name, field) in set.iter() {
            let path = format!("{stem}.{name}.f32");
            data::write_f32_file(&path, field)?;
            println!("  wrote {path} ({} points)", field.len());
        }
        println!("codec = {} -> {} fields restored", codec.id(), set.len());
        return Ok(());
    }
    let recon = codec.decompress(&archive)?;
    data::write_f32_file(out, &recon)?;
    println!("codec = {} -> wrote {out} ({} points)", codec.id(), recon.len());
    Ok(())
}

/// Parse `--region`, treating a malformed or reversed range (`i1 < i0`,
/// missing `:`) as a *usage* error: one clear line on stderr, exit 2 —
/// same contract as an unknown subcommand, not a runtime failure.
fn parse_region_arg(s: &str) -> attn_reduce::data::Region {
    match attn_reduce::data::Region::parse(s) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("error: bad --region {s:?}: {e:#}");
            std::process::exit(2);
        }
    }
}

fn cmd_extract(args: &Args) -> Result<()> {
    // validate the region spelling before touching the archive: a
    // malformed --region is a usage error whatever --in points at
    let region = parse_region_arg(
        args.get("region")
            .ok_or_else(|| anyhow::anyhow!("--region i0:i1,j0:j1,... required"))?,
    );
    let archive = Archive::load(
        args.get("in").ok_or_else(|| anyhow::anyhow!("--in archive required"))?,
    )?;
    let mut b = builder(args)?;
    let codec = b.for_archive(&archive)?;
    let out = args.get_or("out", "region.f32");
    anyhow::ensure!(
        archive.is_multi_field() || args.get("field").is_none(),
        "--field only applies to multi-field (v2) archives; this archive holds one field"
    );
    if archive.is_multi_field() {
        if let Some(name) = args.get("field") {
            let names = archive.field_names()?;
            // by name first, then as a numeric index; an out-of-range
            // index is a usage error (exit 2) like a malformed --region
            let i = match names.iter().position(|n| n == name) {
                Some(i) => i,
                None => match name.parse::<usize>() {
                    Ok(ix) if ix < names.len() => ix,
                    Ok(ix) => {
                        eprintln!(
                            "error: --field index {ix} out of range: archive has {} fields",
                            names.len()
                        );
                        std::process::exit(2);
                    }
                    Err(_) => anyhow::bail!("no field {name:?} (have: {names:?})"),
                },
            };
            let sub = archive.field_archive(i)?;
            let t = codec.decompress_region(&sub, &region)?;
            data::write_f32_file(out, &t)?;
            println!(
                "codec = {} -> wrote {out} (field {:?}, region {:?}, {} points)",
                codec.id(),
                names[i],
                region.shape(),
                t.len()
            );
            return Ok(());
        }
        let parts = codec.decompress_set_region(&archive, &region)?;
        let stem = out.strip_suffix(".f32").unwrap_or(out);
        for (name, t) in &parts {
            let path = format!("{stem}.{name}.f32");
            data::write_f32_file(&path, t)?;
            println!("  wrote {path} ({} points)", t.len());
        }
        println!(
            "codec = {} -> region {:?} of {} fields extracted",
            codec.id(),
            region.shape(),
            parts.len()
        );
        return Ok(());
    }
    let t = codec.decompress_region(&archive, &region)?;
    data::write_f32_file(out, &t)?;
    println!(
        "codec = {} -> wrote {out} (region {:?}, {} points)",
        codec.id(),
        region.shape(),
        t.len()
    );
    Ok(())
}

fn cmd_stream(args: &Args) -> Result<()> {
    let sub = args.positional.get(1).map(|s| s.as_str()).unwrap_or("");
    match sub {
        "append" => cmd_stream_append(args),
        "extract" => cmd_stream_extract(args),
        "info" => cmd_stream_info(args),
        other => {
            eprintln!("error: unknown stream subcommand {other:?} (append|extract|info)");
            std::process::exit(2);
        }
    }
}

fn cmd_stream_append(args: &Args) -> Result<()> {
    let out = args
        .get("out")
        .ok_or_else(|| anyhow::anyhow!("--out stream path required"))?;
    let exists = std::path::Path::new(out).exists();
    // codec / geometry / bound / cadence: from the stream header when
    // appending to an existing stream (the reader is threaded through to
    // the writer so the file is read once), from flags when creating one
    let (codec_id, cfg, bnd, keyint, reader) = if exists {
        let r = StreamReader::open(out)?;
        (
            r.codec_id().to_string(),
            r.dataset().clone(),
            r.bound(),
            r.keyframe_interval(),
            Some(r),
        )
    } else {
        let kind = dataset_kind(args)?;
        (
            args.get_or("codec", "sz3").to_ascii_lowercase(),
            config::stream_frame_preset(kind, scale(args)?),
            bound(args)?,
            args.get_usize("keyint", 8)?,
            None,
        )
    };
    match codec_id.as_str() {
        "sz3" => {
            stream_append_with(args, out, reader, Sz3Codec::new(cfg.clone()), cfg, bnd, keyint)
        }
        "zfp" => {
            stream_append_with(args, out, reader, ZfpCodec::new(cfg.clone()), cfg, bnd, keyint)
        }
        "adaptive" => stream_append_with(
            args,
            out,
            reader,
            AdaptiveCodec::new(cfg.clone()),
            cfg,
            bnd,
            keyint,
        ),
        other => anyhow::bail!(
            "stream append supports the pure-rust codecs (sz3|zfp|adaptive); \
             {other:?} streams go through the library API"
        ),
    }
}

fn stream_append_with<C: Codec + Sync>(
    args: &Args,
    out: &str,
    reader: Option<StreamReader>,
    codec: C,
    cfg: config::DatasetConfig,
    bnd: ErrorBound,
    keyint: usize,
) -> Result<()> {
    let mut w = match reader {
        Some(r) => StreamWriter::reopen_from(out, r, &codec)?,
        None => StreamWriter::create(out, codec.id(), cfg, bnd, keyint)?,
    };
    // frames: --in a.f32,b.f32,... or synthesized smoothly-evolving
    // steps continuing from the stream's current length (the generator
    // is closed-form in t, so increments across invocations line up)
    let frames: Vec<attn_reduce::tensor::Tensor> = match args.get("in") {
        Some(list) => list
            .split(',')
            .map(str::trim)
            .filter(|p| !p.is_empty())
            .map(|p| data::read_f32_file(p, w.dataset().dims.clone()))
            .collect::<Result<_>>()?,
        None => data::timeseries::generate_frames(
            &w.dataset().dims,
            w.dataset().seed,
            w.next_step(),
            args.get_usize("steps", 8)?,
        ),
    };
    anyhow::ensure!(!frames.is_empty(), "nothing to append (--steps N or --in files)");
    let first = w.next_step();
    let raw_mb = (frames.len() * w.dataset().total_points() * 4) as f64 / 1e6;
    let t0 = std::time::Instant::now();
    let stats = w.append_frames(&codec, &frames)?;
    let secs = t0.elapsed().as_secs_f64();
    let keyframes = stats.iter().filter(|s| s.keyframe).count();
    let summary = w.finish()?;
    println!(
        "appended steps {first}..{} ({keyframes} keyframes) at {:.1} MB/s",
        first + frames.len() - 1,
        raw_mb / secs.max(1e-9)
    );
    println!(
        "stream: {out} — {} steps, {} keyframes, {} bytes (payload {} bytes)",
        summary.steps, summary.keyframes, summary.file_bytes, summary.payload_bytes
    );
    Ok(())
}

fn cmd_stream_extract(args: &Args) -> Result<()> {
    let reader = StreamReader::open(
        args.get("in").ok_or_else(|| anyhow::anyhow!("--in stream required"))?,
    )?;
    let step: usize = args
        .get("step")
        .ok_or_else(|| anyhow::anyhow!("--step N required"))?
        .parse()
        .map_err(|_| anyhow::anyhow!("--step expects a step index"))?;
    // a step past the timeline is a usage error (exit 2), same contract
    // as a malformed --region: caught before any codec work starts
    if step >= reader.n_steps() {
        eprintln!(
            "error: --step {step} out of range ({} steps in stream)",
            reader.n_steps()
        );
        std::process::exit(2);
    }
    let mut b = builder(args)?;
    let codec = reader.build_codec(&mut b)?;
    let out = args.get_or("out", "frame.f32");
    match args.get("region") {
        Some(spec) => {
            let region = parse_region_arg(spec);
            let cost = reader.region_cost(step, &region)?;
            let t = reader.extract(&*codec, step, &region)?;
            data::write_f32_file(out, &t)?;
            println!(
                "codec = {} -> wrote {out} (step {step}, region {:?}, {} points)",
                codec.id(),
                region.shape(),
                t.len()
            );
            println!(
                "chain: {} steps, blocks {}/{}, payload bytes {}/{} ({:.1}%)",
                cost.steps,
                cost.blocks_touched,
                cost.blocks_total,
                cost.bytes_touched,
                cost.bytes_total,
                100.0 * cost.bytes_touched as f64 / cost.bytes_total.max(1) as f64
            );
        }
        None => {
            let t = reader.frame(&*codec, step)?;
            data::write_f32_file(out, &t)?;
            println!(
                "codec = {} -> wrote {out} (step {step}, {} points)",
                codec.id(),
                t.len()
            );
        }
    }
    Ok(())
}

fn cmd_stream_info(args: &Args) -> Result<()> {
    let reader = StreamReader::open(
        args.get("in").ok_or_else(|| anyhow::anyhow!("--in stream required"))?,
    )?;
    let stats = reader.stats()?;
    println!(
        "stream: codec = {}, bound = {}, frame dims {:?}, keyint {}{}",
        reader.codec_id(),
        reader.bound(),
        reader.dataset().dims,
        reader.keyframe_interval(),
        if reader.is_finished() { "" } else { " (unsealed — timeline recovered by scan)" }
    );
    println!(
        "steps = {} ({} keyframes), file {} bytes, payload {} bytes",
        stats.steps, stats.keyframes, stats.file_bytes, stats.payload_bytes
    );
    println!(
        "CR (paper accounting) = {:.1}, CR (total bytes) = {:.1}",
        stats.cr, stats.cr_total
    );
    const SHOW: usize = 24;
    for (s, e) in reader.timeline().entries.iter().enumerate().take(SHOW) {
        println!("  step {s:>4} {} {} bytes", if e.keyframe { "K" } else { "R" }, e.len);
    }
    if reader.n_steps() > SHOW {
        println!("  ... {} more steps", reader.n_steps() - SHOW);
    }
    Ok(())
}

/// `info --in`: per-section byte breakdown of an archive (payload vs
/// index vs framing), plus the entropy-stage split (tables vs symbols)
/// for sz3/zfp payloads — the numbers a ratio regression hides in. For
/// plain (LZSS-wrapped) streams the table/symbol numbers are measured in
/// the entropy domain; zero-run/const tiles as stored. The numbers come
/// from [`serve::info`], the same summaries the `/v1/.../info` route
/// serializes — this function only renders them as text.
fn archive_info(path: &str) -> Result<()> {
    let bytes = std::fs::read(path)
        .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
    if bytes.len() >= 4 && &bytes[0..4] == compressor::format::STREAM_MAGIC {
        return stream_file_info(&bytes);
    }
    let archive = Archive::from_bytes(&bytes)?;
    let codec = archive
        .header
        .get("codec")
        .and_then(|v| v.as_str())
        .unwrap_or("?")
        .to_string();
    println!(
        "archive: v{}, codec = {}, {} bytes",
        archive.version(),
        codec,
        bytes.len()
    );
    let sizes = archive.section_sizes();
    let mut sections_total = 0usize;
    for (tag, sz) in &sizes {
        let class = serve::info::section_class(tag);
        println!("  section {tag}: {sz} bytes [{class}]");
        sections_total += sz;
    }
    // v2 expands nested sections, so the framing delta only adds up for
    // single-field containers
    if archive.version() != 2 {
        println!(
            "  header + framing: {} bytes",
            bytes.len().saturating_sub(sections_total)
        );
    }
    if let Some(e) = serve::info::entropy_summary(&archive, &codec)? {
        println!(
            "entropy: {} tiles (plain {}, zero-run {}, const {}, rans {}): \
             tables {} B, symbols {} B, raw/exps {} B, tile framing {} B",
            e.tiles,
            e.plain,
            e.zero_run,
            e.constant,
            e.rans,
            e.table_bytes,
            e.symbol_bytes,
            e.aux_bytes,
            e.framing_bytes
        );
    }
    if let Some(cs) = serve::info::codec_split(&archive, &codec)? {
        println!(
            "tile codecs: sz3 {} tiles ({} B), zfp {} tiles ({} B)",
            cs.sz3_tiles, cs.sz3_bytes, cs.zfp_tiles, cs.zfp_bytes
        );
    }
    Ok(())
}

/// `info --in` on a v4 temporal stream: record/index/framing byte classes.
fn stream_file_info(bytes: &[u8]) -> Result<()> {
    let s = serve::info::stream_byte_summary(bytes)?;
    println!(
        "stream: v4, codec = {}, {} bytes, {} steps ({} keyframes)",
        s.codec, s.file_bytes, s.steps, s.keyframes
    );
    println!("  step records: {} bytes [payload]", s.record_payload_bytes);
    println!("  timeline (TIDX): {} bytes [index]", s.tidx_bytes);
    println!("  header + framing: {} bytes", s.framing_bytes);
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let mut cfg = ServeConfig::new(
        args.get_or("root", "."),
        args.get_or("addr", "127.0.0.1:8080"),
    );
    cfg.cache_bytes = args.get_usize("cache-bytes", cfg.cache_bytes)?;
    cfg.batch = args.get_usize("batch", cfg.batch)?;
    cfg.max_pending = args.get_usize("max-pending", cfg.max_pending)?;
    let server = Server::bind(cfg)?;
    println!(
        "serving {} on http://{} ({} worker threads)",
        std::fs::canonicalize(args.get_or("root", "."))
            .map(|p| p.display().to_string())
            .unwrap_or_else(|_| args.get_or("root", ".").to_string()),
        server.local_addr(),
        parallel::num_threads()
    );
    server.run()
}

/// `verify --root DIR [--repair]` — offline fsck. Clean (or fully
/// repaired) trees exit 0; anything still corrupt or quarantined makes
/// the command fail, so CI can gate on it.
fn cmd_verify(args: &Args) -> Result<()> {
    use attn_reduce::verify::{self, Action, Status};
    let root = std::path::PathBuf::from(args.get_or("root", "."));
    anyhow::ensure!(root.exists(), "verify root {} does not exist", root.display());
    let repair = args.flag("repair");
    let report = verify::verify_root(&root, repair)?;
    for f in &report.files {
        let state = match (&f.status, &f.action) {
            (Status::Clean, _) => "ok".to_string(),
            (Status::Torn { recover_len, steps_kept, tail_bytes }, a) => format!(
                "TORN ({tail_bytes} tail bytes; {steps_kept} steps recoverable at {recover_len} bytes){}",
                match a {
                    Action::Repaired => " -> repaired",
                    Action::Failed(_) => " -> repair FAILED",
                    _ => "",
                }
            ),
            (Status::Corrupt(why), a) => format!(
                "CORRUPT ({why}){}",
                match a {
                    Action::Quarantined(_) => " -> quarantined",
                    Action::Failed(_) => " -> quarantine FAILED",
                    _ => "",
                }
            ),
        };
        println!("  {} [{} — {}]: {state}", f.path.display(), f.kind, f.detail);
        if let Action::Failed(e) = &f.action {
            println!("    repair error: {e}");
        }
    }
    println!(
        "verify: {} files checked — {} clean, {} torn, {} corrupt{}",
        report.files.len(),
        report.clean,
        report.torn,
        report.corrupt,
        if repair {
            format!(" ({} repaired, {} quarantined)", report.repaired, report.quarantined)
        } else {
            String::new()
        }
    );
    anyhow::ensure!(
        report.all_ok(),
        "{} damaged file(s) under {}{}",
        report.torn + report.corrupt,
        root.display(),
        if repair { " (see quarantine)" } else { " (rerun with --repair to recover)" }
    );
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    // --json: the machine-readable document (identical to what the
    // serve layer's /v1/archives/{name}/info route returns)
    if args.flag("json") {
        let path = args
            .get("in")
            .ok_or_else(|| anyhow::anyhow!("info --json needs --in FILE"))?;
        let bytes = std::fs::read(path)
            .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
        println!("{}", serve::info::info_json(&bytes)?.to_string_pretty());
        return Ok(());
    }
    if let Some(path) = args.get("in") {
        return archive_info(path);
    }
    let rt = Runtime::open(args.get_or("artifacts", "artifacts"))?;
    println!("platform: {}", rt.platform());
    println!("jax: {}", rt.manifest.jax_version);
    let mut groups: Vec<_> = rt.manifest.groups.iter().collect();
    groups.sort_by_key(|(name, _)| name.to_string());
    for (name, g) in groups {
        println!(
            "  {name} [{}] param_dim={:?} entries={:?}",
            g.kind,
            g.param_dim,
            g.entries.keys().collect::<Vec<_>>()
        );
    }
    Ok(())
}
