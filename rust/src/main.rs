//! attn-reduce CLI — the L3 launcher.
//!
//! ```text
//! attn-reduce generate   --dataset s3d --scale bench --out field.f32
//! attn-reduce train      --dataset s3d [--steps N] [--ckpt-dir DIR]
//! attn-reduce compress   --dataset s3d --nrmse 1e-3 [--in field.f32]
//!                        --out data.ardc
//! attn-reduce decompress --in data.ardc --out recon.f32 [--ckpt-dir DIR]
//! attn-reduce experiment <table1|table2|fig4|fig5|fig6|fig7|fig8|fig9>
//! attn-reduce info       # manifest + platform summary
//! ```

use attn_reduce::compressor::{self, HierCompressor};
use attn_reduce::config::{self, DatasetKind, Scale};
use attn_reduce::data;
use attn_reduce::experiments;
use attn_reduce::model::ParamStore;
use attn_reduce::runtime::Runtime;
use attn_reduce::util::cli::Args;
use attn_reduce::Result;

const USAGE: &str = "\
attn-reduce — attention-based data reduction with guaranteed error bounds

USAGE:
  attn-reduce <command> [options]

COMMANDS:
  generate     synthesize a dataset (--dataset s3d|e3sm|xgc --scale bench --out F)
  train        train HBAE+BAE for a dataset preset (--dataset D --steps N)
  compress     compress (--dataset D --nrmse 1e-3 | --tau T) [--in F] --out A
  decompress   decompress an archive (--in A --out F)
  experiment   reproduce a paper table/figure (table1 table2 fig4..fig9)
  info         show artifact manifest + platform
COMMON OPTIONS:
  --artifacts DIR   (default: ./artifacts)
  --ckpt-dir DIR    (default: ./results/ckpt)
  --scale bench|smoke|paper
  --steps N         training steps (default 300)
  --quiet
";

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    if raw.is_empty() {
        eprintln!("{USAGE}");
        std::process::exit(2);
    }
    if let Err(e) = run(&raw) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(raw: &[String]) -> Result<()> {
    let args = Args::parse(raw, &["quiet", "retrain", "full"])?;
    if args.flag("quiet") {
        std::env::set_var("ATTN_REDUCE_QUIET", "1");
    }
    let cmd = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("help");
    match cmd {
        "generate" => cmd_generate(&args),
        "train" => cmd_train(&args),
        "compress" => cmd_compress(&args),
        "decompress" => cmd_decompress(&args),
        "experiment" => {
            let id = args
                .positional
                .get(1)
                .ok_or_else(|| anyhow::anyhow!("experiment id required"))?;
            experiments::run_experiment(id, &args)
        }
        "info" => cmd_info(&args),
        _ => {
            eprintln!("{USAGE}");
            Ok(())
        }
    }
}

fn pipeline_cfg(args: &Args) -> Result<config::PipelineConfig> {
    let kind = DatasetKind::parse(args.get_or("dataset", "s3d"))?;
    let scale = Scale::parse(args.get_or("scale", "bench"))?;
    let mut cfg = config::pipeline_preset(kind, scale, 0.0);
    cfg.train.steps = args.get_usize("steps", cfg.train.steps)?;
    cfg.train.lr = args.get_f32("lr", cfg.train.lr)?;
    Ok(cfg)
}

fn load_field(args: &Args, cfg: &config::DatasetConfig) -> Result<attn_reduce::tensor::Tensor> {
    match args.get("in") {
        Some(path) if path.ends_with(".f32") => {
            data::read_f32_file(path, cfg.dims.clone())
        }
        _ => Ok(data::generate(cfg)),
    }
}

fn cmd_generate(args: &Args) -> Result<()> {
    let cfg = pipeline_cfg(args)?;
    let out = args.get_or("out", "field.f32");
    let t = data::generate(&cfg.dataset);
    data::write_f32_file(out, &t)?;
    println!(
        "wrote {} ({} points, {:.1} MB, range [{:.4}, {:.4}])",
        out,
        t.len(),
        (t.len() * 4) as f64 / 1e6,
        t.min(),
        t.max()
    );
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = pipeline_cfg(args)?;
    let rt = Runtime::open(args.get_or("artifacts", "artifacts"))?;
    let ckpt = std::path::PathBuf::from(args.get_or("ckpt-dir", "results/ckpt"));
    if args.flag("retrain") {
        std::fs::remove_file(ParamStore::default_path(&ckpt, &cfg.model.hbae_group)).ok();
        std::fs::remove_file(ParamStore::default_path(&ckpt, &cfg.model.bae_group)).ok();
    }
    let field = load_field(args, &cfg.dataset)?;
    let (_, reports) = HierCompressor::prepare(&rt, &cfg, &ckpt, &field)?;
    if reports.is_empty() {
        println!("checkpoints already present in {} (use --retrain)", ckpt.display());
    }
    for r in &reports {
        println!("{}", r.summary());
    }
    Ok(())
}

fn cmd_compress(args: &Args) -> Result<()> {
    let cfg = pipeline_cfg(args)?;
    let rt = Runtime::open(args.get_or("artifacts", "artifacts"))?;
    let ckpt = std::path::PathBuf::from(args.get_or("ckpt-dir", "results/ckpt"));
    let field = load_field(args, &cfg.dataset)?;
    let (comp, _) = HierCompressor::prepare(&rt, &cfg, &ckpt, &field)?;
    // bound: --tau wins, else --nrmse target converted per Eq. 11
    let tau = if let Some(t) = args.get("tau") {
        t.parse::<f32>()?
    } else {
        let target = args.get_f64("nrmse", 1e-3)?;
        config::PipelineConfig::tau_for_nrmse(
            target,
            field.range() as f64,
            cfg.dataset.gae_block_len(),
        )
    };
    let (archive, recon) = comp.compress(&field, tau)?;
    let out = args.get_or("out", "data.ardc");
    archive.save(out)?;
    let stats = comp.stats(&archive);
    let e = compressor::nrmse(&field, &recon);
    println!("archive: {out} ({} bytes)", stats.archive_bytes);
    println!(
        "CR (paper accounting) = {:.1}, CR (total bytes) = {:.1}",
        stats.cr, stats.cr_total
    );
    println!("NRMSE = {e:.3e} (tau = {tau:.4e})");
    for (tag, sz) in &stats.section_sizes {
        println!("  section {tag}: {sz} bytes");
    }
    Ok(())
}

fn cmd_decompress(args: &Args) -> Result<()> {
    let rt = Runtime::open(args.get_or("artifacts", "artifacts"))?;
    let ckpt = std::path::PathBuf::from(args.get_or("ckpt-dir", "results/ckpt"));
    let archive = compressor::Archive::load(
        args.get("in").ok_or_else(|| anyhow::anyhow!("--in archive required"))?,
    )?;
    let hgroup = archive
        .header
        .req("hbae_group")?
        .as_str()
        .unwrap_or("")
        .to_string();
    let bgroups: Vec<String> = archive
        .header
        .req("bae_groups")?
        .as_arr()
        .unwrap_or(&[])
        .iter()
        .filter_map(|v| v.as_str().map(String::from))
        .collect();
    let hbae = ParamStore::load(ParamStore::default_path(&ckpt, &hgroup), &hgroup)?;
    let baes: Vec<ParamStore> = bgroups
        .iter()
        .map(|g| ParamStore::load(ParamStore::default_path(&ckpt, g), g))
        .collect::<Result<_>>()?;
    let recon = HierCompressor::decompress(&rt, &archive, &hbae, &baes)?;
    let out = args.get_or("out", "recon.f32");
    data::write_f32_file(out, &recon)?;
    println!("wrote {out} ({} points)", recon.len());
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let rt = Runtime::open(args.get_or("artifacts", "artifacts"))?;
    println!("platform: {}", rt.platform());
    println!("jax: {}", rt.manifest.jax_version);
    let mut groups: Vec<_> = rt.manifest.groups.iter().collect();
    groups.sort_by_key(|(name, _)| name.to_string());
    for (name, g) in groups {
        println!(
            "  {name} [{}] param_dim={:?} entries={:?}",
            g.kind,
            g.param_dim,
            g.entries.keys().collect::<Vec<_>>()
        );
    }
    Ok(())
}
