//! ASCII log-log curve plotting for terminal output of the figure
//! experiments (CR on x, NRMSE on y — the paper's Fig. 4/5/6/9 axes).

/// One labelled curve.
#[derive(Debug, Clone)]
pub struct Series {
    pub label: String,
    /// `(x, y)` points (e.g. compression ratio, NRMSE).
    pub points: Vec<(f64, f64)>,
}

impl Series {
    pub fn new(label: impl Into<String>, points: Vec<(f64, f64)>) -> Self {
        Self { label: label.into(), points }
    }
}

const GLYPHS: &[char] = &['*', 'o', '+', 'x', '#', '@', '%', '&'];

/// Render curves on a log-log grid.
pub fn ascii_curves(title: &str, xlabel: &str, ylabel: &str, series: &[Series]) -> String {
    let (w, h) = (72usize, 22usize);
    let pts: Vec<(f64, f64)> = series
        .iter()
        .flat_map(|s| s.points.iter().copied())
        .filter(|&(x, y)| x > 0.0 && y > 0.0)
        .collect();
    if pts.is_empty() {
        return format!("{title}: (no points)\n");
    }
    let (mut x0, mut x1) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut y0, mut y1) = (f64::INFINITY, f64::NEG_INFINITY);
    for &(x, y) in &pts {
        x0 = x0.min(x.log10());
        x1 = x1.max(x.log10());
        y0 = y0.min(y.log10());
        y1 = y1.max(y.log10());
    }
    if (x1 - x0).abs() < 1e-9 {
        x1 = x0 + 1.0;
    }
    if (y1 - y0).abs() < 1e-9 {
        y1 = y0 + 1.0;
    }
    let mut grid = vec![vec![' '; w]; h];
    for (si, s) in series.iter().enumerate() {
        let g = GLYPHS[si % GLYPHS.len()];
        for &(x, y) in &s.points {
            if x <= 0.0 || y <= 0.0 {
                continue;
            }
            let gx = ((x.log10() - x0) / (x1 - x0) * (w - 1) as f64).round() as usize;
            let gy = ((y.log10() - y0) / (y1 - y0) * (h - 1) as f64).round() as usize;
            grid[h - 1 - gy.min(h - 1)][gx.min(w - 1)] = g;
        }
    }
    let mut out = String::new();
    out.push_str(&format!("\n== {title} ==  (log-log; y: {ylabel}, x: {xlabel})\n"));
    for (i, row) in grid.iter().enumerate() {
        let ylab = if i == 0 {
            format!("{:8.1e}", 10f64.powf(y1))
        } else if i == h - 1 {
            format!("{:8.1e}", 10f64.powf(y0))
        } else {
            "        ".to_string()
        };
        out.push_str(&format!("{ylab} |{}|\n", row.iter().collect::<String>()));
    }
    out.push_str(&format!(
        "          {:<10.3e}{:>width$.3e}\n",
        10f64.powf(x0),
        10f64.powf(x1),
        width = w - 8
    ));
    for (si, s) in series.iter().enumerate() {
        out.push_str(&format!(
            "          {} = {} ({} pts)\n",
            GLYPHS[si % GLYPHS.len()],
            s.label,
            s.points.len()
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_without_panic() {
        let s = vec![
            Series::new("ours", vec![(10.0, 1e-3), (100.0, 1e-2), (1000.0, 1e-1)]),
            Series::new("sz3", vec![(5.0, 1e-3), (50.0, 1e-2)]),
        ];
        let out = ascii_curves("Fig 6", "CR", "NRMSE", &s);
        assert!(out.contains("ours"));
        assert!(out.contains('*'));
        assert!(out.lines().count() > 20);
    }

    #[test]
    fn empty_series_ok() {
        let out = ascii_curves("empty", "x", "y", &[Series::new("none", vec![])]);
        assert!(out.contains("no points"));
    }

    #[test]
    fn degenerate_single_point() {
        let out = ascii_curves("p", "x", "y", &[Series::new("one", vec![(1.0, 1.0)])]);
        assert!(out.contains("one"));
    }
}
