//! Experiment runners — one per table/figure of the paper (DESIGN.md §6).
//!
//! Every runner writes CSV rows under `results/<id>/` and prints an ASCII
//! rendering; EXPERIMENTS.md records paper-vs-measured for each. Default
//! training is shortened vs the paper (CPU box); `--steps` raises it.

use std::path::PathBuf;
use std::rc::Rc;

use crate::baselines::{GbaeCompressor, Sz3Like, ZfpLike};
use crate::codec::{archive_stats, Codec, CodecBuilder, CodecKind, ErrorBound};
use crate::compressor::{
    log_histogram, mean_channel_nrmse, nrmse, nrmse_per_channel, relative_point_errors,
    HierCompressor,
};
use crate::config::{
    dataset_preset, model_preset, DatasetConfig, DatasetKind, ModelConfig,
    PipelineConfig, Scale, TrainConfig,
};
use crate::data;
use crate::model::ParamStore;
use crate::runtime::Runtime;
use crate::tensor::Tensor;
use crate::train::train_bae;
use crate::util::cli::Args;
use crate::Result;

use super::{ascii_curves, Csv, Series};

/// Known experiment ids.
pub const EXPERIMENTS: &[&str] = &[
    "table1", "table2", "fig4", "fig5", "fig6", "fig7", "fig8", "fig9",
];

/// Dispatch an experiment by id.
pub fn run_experiment(id: &str, args: &Args) -> Result<()> {
    match id {
        "table1" => table1(args),
        "table2" => table2(args),
        "fig4" => fig4(args),
        "fig5" => fig5(args),
        "fig6" => fig6(args),
        "fig7" => fig7(args),
        "fig8" => fig8(args),
        "fig9" => fig9(args),
        _ => anyhow::bail!("unknown experiment {id:?} (have: {EXPERIMENTS:?})"),
    }
}

// ---------------------------------------------------------------------------
// shared context
// ---------------------------------------------------------------------------

struct Ctx {
    rt: Rc<Runtime>,
    ckpt: PathBuf,
    scale: Scale,
    train: TrainConfig,
}

fn ctx(args: &Args) -> Result<Ctx> {
    let rt = Rc::new(Runtime::open(args.get_or("artifacts", "artifacts"))?);
    let ckpt = PathBuf::from(args.get_or("ckpt-dir", "results/ckpt"));
    std::fs::create_dir_all(&ckpt)?;
    let scale = Scale::parse(args.get_or("scale", "bench"))?;
    let train = TrainConfig {
        steps: args.get_usize("steps", 200)?,
        log_every: 50,
        ..TrainConfig::default()
    };
    Ok(Ctx { rt, ckpt, scale, train })
}

/// NRMSE metric matching the paper's reporting (mean per-species for S3D).
fn report_nrmse(kind: DatasetKind, orig: &Tensor, recon: &Tensor) -> f64 {
    match kind {
        DatasetKind::S3d => mean_channel_nrmse(orig, recon),
        _ => nrmse(orig, recon),
    }
}

/// Train/load a custom (hbae, [baes...]) stack with checkpoint names that
/// encode the full stack (fig-4 sweeps share HBAEs across BAE variants).
fn prepare_stack(
    c: &Ctx,
    dataset: &DatasetConfig,
    hbae_group: &str,
    bae_groups: &[&str],
    field: &Tensor,
) -> Result<HierCompressor> {
    use crate::data::Normalizer;
    let stats = Normalizer::fit(dataset.normalization, field);
    let mut norm = field.clone();
    Normalizer::apply(&stats, &mut norm);

    let hpath = c.ckpt.join(format!("{hbae_group}.ckpt"));
    let hbae = if hpath.exists() {
        ParamStore::load(&hpath, hbae_group)?
    } else {
        let mut store = ParamStore::init(&c.rt, hbae_group)?;
        let blocking = crate::data::Blocking::new(dataset);
        let rep = crate::train::train_hbae(&c.rt, &mut store, &blocking, &norm, &c.train)?;
        eprintln!("[exp] {}", rep.summary());
        store.save(&hpath)?;
        store
    };
    let mut comp = HierCompressor {
        rt: c.rt.clone(),
        dataset: dataset.clone(),
        model: ModelConfig {
            hbae_group: hbae_group.to_string(),
            bae_group: bae_groups.first().unwrap_or(&"").to_string(),
            pipe_group: None,
            bin_hbae: 0.0,
            bin_bae: 0.0,
        },
        hbae,
        baes: Vec::new(),
    };
    let mut tag = hbae_group.to_string();
    for g in bae_groups {
        tag = format!("{tag}+{g}");
        let bpath = c.ckpt.join(format!("{tag}.ckpt"));
        let bae = if bpath.exists() {
            ParamStore::load(&bpath, g)?
        } else {
            let resid = comp.stack_residuals(&norm)?;
            let mut store = ParamStore::init(&c.rt, g)?;
            let rep = train_bae(&c.rt, &mut store, &resid, dataset.block_dim(), &c.train)?;
            eprintln!("[exp] {}", rep.summary());
            store.save(&bpath)?;
            store
        };
        comp.baes.push(bae);
    }
    Ok(comp)
}

/// One (CR, NRMSE) point from the hierarchical stack.
fn hier_point(
    kind: DatasetKind,
    comp: &HierCompressor,
    field: &Tensor,
    tau: f32,
) -> Result<(f64, f64)> {
    let (archive, recon) = comp.compress(field, tau)?;
    let stats = comp.stats(&archive);
    Ok((stats.cr, report_nrmse(kind, field, &recon)))
}

// ---------------------------------------------------------------------------
// Table I — dataset info
// ---------------------------------------------------------------------------

fn table1(_args: &Args) -> Result<()> {
    let mut csv = Csv::new("table1", "table1.csv", "application,domain,scale,dims,total_mb");
    println!("\nTable I: Datasets Information (paper vs bench substitutes)");
    println!("{:<8} {:<12} {:<7} {:<28} {:>10}", "app", "domain", "scale", "dims", "size");
    for (kind, domain) in [
        (DatasetKind::S3d, "Combustion"),
        (DatasetKind::E3sm, "Climate"),
        (DatasetKind::Xgc, "Plasma"),
    ] {
        for scale in [Scale::Paper, Scale::Bench] {
            let cfg = dataset_preset(kind, scale);
            let mb = cfg.total_points() as f64 * 4.0 / 1e6;
            let dims = format!("{:?}", cfg.dims);
            let sname = if scale == Scale::Paper { "paper" } else { "bench" };
            println!(
                "{:<8} {:<12} {:<7} {:<28} {:>8.1} MB",
                kind.name(), domain, sname, dims, mb
            );
            csv.row(&[
                kind.name().into(),
                domain.into(),
                sname.into(),
                format!("{:?}", cfg.dims).replace(',', "x"),
                format!("{mb:.1}"),
            ]);
        }
    }
    let p = csv.save()?;
    println!("-> {}", p.display());
    Ok(())
}

// ---------------------------------------------------------------------------
// Table II — quantization bin sweep, HBAE-only vs BAE-only
// ---------------------------------------------------------------------------

fn table2(args: &Args) -> Result<()> {
    let c = ctx(args)?;
    let mut csv = Csv::new("table2", "table2.csv", "dataset,quantized_ae,bin,nrmse");
    println!("\nTable II: reconstruction error vs quantization bin size");
    for kind in [DatasetKind::S3d, DatasetKind::E3sm, DatasetKind::Xgc] {
        let bins: &[f64] = match kind {
            DatasetKind::S3d => &[0.005, 0.01, 0.05, 0.1, 0.5],
            DatasetKind::E3sm => &[0.001, 0.005, 0.01, 0.05, 0.1],
            DatasetKind::Xgc => &[0.05, 0.1, 0.2, 0.4, 0.8],
        };
        let dataset = dataset_preset(kind, c.scale);
        let field = data::generate(&dataset);
        let model = model_preset(kind);
        let mut comp = prepare_stack(&c, &dataset, &model.hbae_group, &[&model.bae_group], &field)?;
        for which in ["HBAE", "BAE"] {
            print!("{:<5} {:<5}", kind.name(), which);
            for &bin in bins {
                comp.model.bin_hbae = if which == "HBAE" { bin as f32 } else { 0.0 };
                comp.model.bin_bae = if which == "BAE" { bin as f32 } else { 0.0 };
                let (_, recon) = comp.compress(&field, 0.0)?;
                let e = report_nrmse(kind, &field, &recon);
                print!("  {bin}:{e:.2e}");
                csv.row(&[
                    kind.name().into(),
                    which.into(),
                    bin.to_string(),
                    format!("{e:.4e}"),
                ]);
            }
            println!();
        }
    }
    let p = csv.save()?;
    println!("-> {}", p.display());
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 4 — latent-size ablation on S3D
// ---------------------------------------------------------------------------

// Trimmed vs the paper's grids (8..128 x 32..256) to keep the full
// battery CPU-tractable; pass --full for the complete sweep.
const BAE_SWEEP: &[usize] = &[8, 16, 64];
const HBAE_SWEEP: &[usize] = &[32, 128, 256];
const BAE_SWEEP_FULL: &[usize] = &[8, 16, 32, 64, 128];
const HBAE_SWEEP_FULL: &[usize] = &[32, 64, 128, 256];

fn sweeps(args: &Args) -> (&'static [usize], &'static [usize]) {
    if args.flag("full") {
        (BAE_SWEEP_FULL, HBAE_SWEEP_FULL)
    } else {
        (BAE_SWEEP, HBAE_SWEEP)
    }
}

fn fig4(args: &Args) -> Result<()> {
    let c = ctx(args)?;
    let (bae_sweep, hbae_sweep) = sweeps(args);
    let kind = DatasetKind::S3d;
    let dataset = dataset_preset(kind, c.scale);
    let field = data::generate(&dataset);
    let mut csv = Csv::new("fig4", "fig4.csv", "series,cr,nrmse");
    let mut series = Vec::new();

    // Baseline: block AE with latent sweep (no quant, no GAE — §III-D)
    let mut pts = Vec::new();
    for &lb in bae_sweep {
        let group = format!("s3d_bae_L{lb}");
        let (gb, _) = GbaeCompressor::prepare(
            &c.rt, &dataset, &group, &c.ckpt, &field, &c.train, None,
        )?;
        let res = gb.compress(&field, 0.0, 0.0)?;
        let cr = (dataset.total_points() * 4) as f64 / res.payload_bytes as f64;
        let e = report_nrmse(kind, &field, &res.recon);
        csv.row(&["Baseline".into(), format!("{cr:.2}"), format!("{e:.4e}")]);
        pts.push((cr, e));
    }
    series.push(Series::new("Baseline", pts));

    // HierAE-N: HBAE latent sweep x BAE latent sweep
    for &lh in hbae_sweep {
        let hbae_group = format!("s3d_hbae_L{lh}");
        let mut pts = Vec::new();
        for &lb in bae_sweep {
            let bae_group = format!("s3d_bae_L{lb}");
            let comp = prepare_stack(&c, &dataset, &hbae_group, &[&bae_group], &field)?;
            let (cr, e) = hier_point(kind, &comp, &field, 0.0)?;
            csv.row(&[format!("HierAE-{lh}"), format!("{cr:.2}"), format!("{e:.4e}")]);
            pts.push((cr, e));
        }
        series.push(Series::new(format!("HierAE-{lh}"), pts));
    }

    // StackAE: one HBAE-128 + two residual BAEs
    let mut pts = Vec::new();
    for &lb in &[8usize, 16] {
        let bg = format!("s3d_bae_L{lb}");
        let comp = prepare_stack(&c, &dataset, "s3d_hbae_L128", &[&bg, &bg], &field)?;
        let (cr, e) = hier_point(kind, &comp, &field, 0.0)?;
        csv.row(&["StackAE".into(), format!("{cr:.2}"), format!("{e:.4e}")]);
        pts.push((cr, e));
    }
    series.push(Series::new("StackAE", pts));

    println!("{}", ascii_curves("Fig. 4 — latent ablation (S3D)", "CR", "NRMSE", &series));
    let p = csv.save()?;
    println!("-> {}", p.display());
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 5 — component ablation on S3D
// ---------------------------------------------------------------------------

fn fig5(args: &Args) -> Result<()> {
    let c = ctx(args)?;
    let (bae_sweep, hbae_sweep) = sweeps(args);
    let kind = DatasetKind::S3d;
    let dataset = dataset_preset(kind, c.scale);
    let field = data::generate(&dataset);
    let mut csv = Csv::new("fig5", "fig5.csv", "series,cr,nrmse");
    let mut series = Vec::new();

    // Baseline (same as fig4)
    let mut pts = Vec::new();
    for &lb in bae_sweep {
        let group = format!("s3d_bae_L{lb}");
        let (gb, _) = GbaeCompressor::prepare(
            &c.rt, &dataset, &group, &c.ckpt, &field, &c.train, None,
        )?;
        let res = gb.compress(&field, 0.0, 0.0)?;
        let cr = (dataset.total_points() * 4) as f64 / res.payload_bytes as f64;
        let e = report_nrmse(kind, &field, &res.recon);
        csv.row(&["Baseline".into(), format!("{cr:.2}"), format!("{e:.4e}")]);
        pts.push((cr, e));
    }
    series.push(Series::new("Baseline", pts));

    // HBAE-woa and HBAE: hyper-block AE alone, latent sweep, +/- attention
    for (label, suffix) in [("HBAE-woa", "_woa"), ("HBAE", "")] {
        let mut pts = Vec::new();
        for &lh in hbae_sweep {
            let group = format!("s3d_hbae_L{lh}{suffix}");
            let comp = prepare_stack(&c, &dataset, &group, &[], &field)?;
            let (cr, e) = hier_point(kind, &comp, &field, 0.0)?;
            csv.row(&[label.into(), format!("{cr:.2}"), format!("{e:.4e}")]);
            pts.push((cr, e));
        }
        series.push(Series::new(label, pts));
    }

    // full HierAE (HBAE-128 + BAE sweep)
    let mut pts = Vec::new();
    for &lb in bae_sweep {
        let bg = format!("s3d_bae_L{lb}");
        let comp = prepare_stack(&c, &dataset, "s3d_hbae_L128", &[&bg], &field)?;
        let (cr, e) = hier_point(kind, &comp, &field, 0.0)?;
        csv.row(&["HierAE".into(), format!("{cr:.2}"), format!("{e:.4e}")]);
        pts.push((cr, e));
    }
    series.push(Series::new("HierAE", pts));

    println!("{}", ascii_curves("Fig. 5 — component ablation (S3D)", "CR", "NRMSE", &series));
    let p = csv.save()?;
    println!("-> {}", p.display());
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 6 — comparison vs SZ3-like / ZFP-like (+ GBAE/GAETC on S3D)
// ---------------------------------------------------------------------------

fn fig6_one(c: &Ctx, kind: DatasetKind, csv: &mut Csv) -> Result<Vec<Series>> {
    let dataset = dataset_preset(kind, c.scale);
    let field = data::generate(&dataset);
    let model = model_preset(kind);
    let mut series = Vec::new();

    // ours: trained stack + paper quant bins + tau sweep
    let mut comp =
        prepare_stack(c, &dataset, &model.hbae_group, &[&model.bae_group], &field)?;
    comp.model.bin_hbae = model.bin_hbae;
    comp.model.bin_bae = model.bin_bae;
    let mut pts = Vec::new();
    for target in [3e-3f64, 1e-3, 3e-4, 1e-4] {
        let tau = PipelineConfig::tau_for_nrmse(
            target,
            field.range() as f64,
            dataset.gae_block_len(),
        );
        let (cr, e) = hier_point(kind, &comp, &field, tau)?;
        csv.row(&[kind.name().into(), "ours".into(), format!("{cr:.2}"), format!("{e:.4e}")]);
        pts.push((cr, e));
    }
    series.push(Series::new("ours", pts));

    // SZ3-like / ZFP-like through the unified codec API at the SAME
    // NRMSE targets as ours — the shared-bound accounting Fig. 6 is about
    let mut builder = CodecBuilder::new().scale(c.scale);
    for (label, ck) in [("SZ3-like", CodecKind::Sz3), ("ZFP-like", CodecKind::Zfp)] {
        let codec = builder.build(ck, kind, &field)?;
        let mut pts = Vec::new();
        for target in [3e-3f64, 1e-3, 3e-4, 1e-4] {
            let (archive, back) =
                codec.compress_with_recon(&field, &ErrorBound::Nrmse(target))?;
            let cr = archive_stats(&archive)?.cr;
            let e = report_nrmse(kind, &field, &back);
            csv.row(&[
                kind.name().into(),
                codec.id().into(),
                format!("{cr:.2}"),
                format!("{e:.4e}"),
            ]);
            pts.push((cr, e));
        }
        series.push(Series::new(label, pts));
    }

    // S3D extra: GBAE and GAETC-like (block AE [+corrector] + GAE)
    if kind == DatasetKind::S3d {
        for (label, corrector) in [("GBAE", None), ("GAETC-like", Some("s3d_bae_L16"))] {
            let (gb, _) = GbaeCompressor::prepare(
                &c.rt, &dataset, "s3d_bae_L16", &c.ckpt, &field, &c.train, corrector,
            )?;
            let mut pts = Vec::new();
            for target in [3e-3f64, 1e-3, 3e-4, 1e-4] {
                let tau = PipelineConfig::tau_for_nrmse(
                    target,
                    field.range() as f64,
                    dataset.gae_block_len(),
                );
                let res = gb.compress(&field, model.bin_bae, tau)?;
                let cr = (dataset.total_points() * 4) as f64 / res.payload_bytes as f64;
                let e = report_nrmse(kind, &field, &res.recon);
                csv.row(&[
                    kind.name().into(),
                    label.to_lowercase(),
                    format!("{cr:.2}"),
                    format!("{e:.4e}"),
                ]);
                pts.push((cr, e));
            }
            series.push(Series::new(label, pts));
        }
    }
    Ok(series)
}

fn fig6(args: &Args) -> Result<()> {
    let c = ctx(args)?;
    let kinds: Vec<DatasetKind> = match args.get("dataset") {
        Some(d) => vec![DatasetKind::parse(d)?],
        None => vec![DatasetKind::S3d, DatasetKind::E3sm, DatasetKind::Xgc],
    };
    for kind in kinds {
        // one CSV per dataset so partial runs never clobber earlier ones
        let mut csv = Csv::new(
            "fig6",
            &format!("fig6_{}.csv", kind.name()),
            "dataset,series,cr,nrmse",
        );
        let series = fig6_one(&c, kind, &mut csv)?;
        println!(
            "{}",
            ascii_curves(
                &format!("Fig. 6 — comparison ({})", kind.name()),
                "CR",
                "NRMSE",
                &series
            )
        );
        let p = csv.save()?;
        println!("-> {}", p.display());
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 7/8 shared: three compressors tuned to CR ≈ 100 on S3D
// ---------------------------------------------------------------------------

struct Cr100 {
    label: String,
    recon: Tensor,
    cr: f64,
    nrmse: f64,
}

fn compress_at_cr100(c: &Ctx) -> Result<(Tensor, Vec<Cr100>)> {
    let kind = DatasetKind::S3d;
    let dataset = dataset_preset(kind, c.scale);
    let field = data::generate(&dataset);
    let model = model_preset(kind);
    let mut out = Vec::new();

    // ours: binary-search tau for CR in [80, 125]
    let mut comp =
        prepare_stack(c, &dataset, &model.hbae_group, &[&model.bae_group], &field)?;
    comp.model.bin_hbae = model.bin_hbae;
    comp.model.bin_bae = model.bin_bae;
    let range = field.range() as f64;
    let d = dataset.gae_block_len();
    let (mut lo, mut hi) = (1e-5f64, 1e-2f64);
    let mut best: Option<Cr100> = None;
    for _ in 0..8 {
        let mid = (lo * hi).sqrt(); // geometric bisection over NRMSE target
        let tau = PipelineConfig::tau_for_nrmse(mid, range, d);
        let (archive, recon) = comp.compress(&field, tau)?;
        let cr = comp.stats(&archive).cr;
        let e = report_nrmse(kind, &field, &recon);
        best = Some(Cr100 { label: "ours".into(), recon, cr, nrmse: e });
        if (80.0..=125.0).contains(&cr) {
            break;
        }
        if cr > 125.0 {
            hi = mid; // too compressed -> tighten bound
        } else {
            lo = mid;
        }
    }
    out.push(best.unwrap());

    // sz3: sweep eps to CR ~ 100
    let mut best: Option<Cr100> = None;
    for rel in [1e-4f32, 2e-4, 5e-4, 1e-3, 2e-3, 5e-3, 1e-2] {
        let eps = rel * field.range();
        let bytes = Sz3Like::new(eps).compress(&field)?;
        let cr = (field.len() * 4) as f64 / bytes.len() as f64;
        let keep = match &best {
            None => true,
            Some(b) => (cr - 100.0).abs() < (b.cr - 100.0).abs(),
        };
        if keep {
            let back = Sz3Like::decompress(&bytes)?;
            let e = report_nrmse(kind, &field, &back);
            best = Some(Cr100 { label: "sz3".into(), recon: back, cr, nrmse: e });
        }
    }
    out.push(best.unwrap());

    // zfp: precision sweep to CR ~ 100
    let mut best: Option<Cr100> = None;
    for p in [2u32, 3, 4, 5, 6, 8, 10] {
        let bytes = ZfpLike::new(p).compress(&field)?;
        let cr = (field.len() * 4) as f64 / bytes.len() as f64;
        let keep = match &best {
            None => true,
            Some(b) => (cr - 100.0).abs() < (b.cr - 100.0).abs(),
        };
        if keep {
            let back = ZfpLike::decompress(&bytes)?;
            let e = report_nrmse(kind, &field, &back);
            best = Some(Cr100 { label: "zfp".into(), recon: back, cr, nrmse: e });
        }
    }
    out.push(best.unwrap());
    Ok((field, out))
}

/// Write an 8-bit PGM of a 2-D slice normalized to the slice range.
fn write_pgm(path: &std::path::Path, img: &[f32], w: usize, h: usize) -> Result<()> {
    use std::io::Write;
    let lo = img.iter().copied().fold(f32::INFINITY, f32::min);
    let hi = img.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let scale = if hi > lo { 255.0 / (hi - lo) } else { 0.0 };
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "P5\n{w} {h}\n255")?;
    let bytes: Vec<u8> = img.iter().map(|&v| ((v - lo) * scale) as u8).collect();
    f.write_all(&bytes)?;
    Ok(())
}

/// Extract species-0 frame (mid-time) from an S3D tensor.
fn species0_frame(t: &Tensor) -> (Vec<f32>, usize, usize) {
    let dims = t.shape();
    let (ts, x, y) = (dims[1], dims[2], dims[3]);
    let mid = ts / 2;
    let off = mid * x * y; // species 0
    (t.data()[off..off + x * y].to_vec(), y, x)
}

fn fig7(args: &Args) -> Result<()> {
    let c = ctx(args)?;
    let (field, results) = compress_at_cr100(&c)?;
    let dir = std::path::Path::new("results/fig7");
    let (orig_img, w, h) = species0_frame(&field);
    write_pgm(&dir.join("original.pgm"), &orig_img, w, h)?;
    // zoomed crop (center quarter)
    let crop = |img: &[f32]| -> Vec<f32> {
        let (cw, ch) = (w / 4, h / 4);
        let (x0, y0) = (w * 3 / 8, h * 3 / 8);
        let mut out = Vec::with_capacity(cw * ch);
        for yy in 0..ch {
            for xx in 0..cw {
                out.push(img[(y0 + yy) * w + (x0 + xx)]);
            }
        }
        out
    };
    write_pgm(&dir.join("original_zoom.pgm"), &crop(&orig_img), w / 4, h / 4)?;
    let mut csv = Csv::new("fig7", "fig7.csv", "compressor,cr,nrmse,image");
    println!("\nFig. 7 — reconstructions at CR≈100 (S3D species 0):");
    for r in &results {
        let (img, _, _) = species0_frame(&r.recon);
        let p = dir.join(format!("{}.pgm", r.label));
        write_pgm(&p, &img, w, h)?;
        write_pgm(&dir.join(format!("{}_zoom.pgm", r.label)), &crop(&img), w / 4, h / 4)?;
        println!("  {:<6} CR={:7.1}  NRMSE={:.3e}  -> {}", r.label, r.cr, r.nrmse, p.display());
        csv.row(&[
            r.label.clone(),
            format!("{:.1}", r.cr),
            format!("{:.4e}", r.nrmse),
            p.display().to_string(),
        ]);
    }
    let p = csv.save()?;
    println!("-> {}", p.display());
    Ok(())
}

fn fig8(args: &Args) -> Result<()> {
    let c = ctx(args)?;
    let (field, results) = compress_at_cr100(&c)?;
    let mut csv = Csv::new("fig8", "fig8.csv", "compressor,bin_center,count");
    println!("\nFig. 8 — histogram of relative point error at CR≈100 (S3D):");
    for r in &results {
        let errs = relative_point_errors(&field, &r.recon);
        let hist = log_histogram(&errs, 1e-8, 1e-1, 28);
        let maxc = hist.iter().map(|&(_, n)| n).max().unwrap_or(1).max(1);
        println!("  {} (CR {:.0}, NRMSE {:.2e}):", r.label, r.cr, r.nrmse);
        for &(center, count) in &hist {
            if count == 0 {
                continue;
            }
            let bar = "#".repeat(1 + count * 50 / maxc);
            println!("    {center:9.1e} |{bar} {count}");
            csv.row(&[r.label.clone(), format!("{center:.3e}"), count.to_string()]);
        }
    }
    let p = csv.save()?;
    println!("-> {}", p.display());
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 9 — per-species NRMSE vs CR on S3D
// ---------------------------------------------------------------------------

fn fig9(args: &Args) -> Result<()> {
    let c = ctx(args)?;
    let kind = DatasetKind::S3d;
    let dataset = dataset_preset(kind, c.scale);
    let field = data::generate(&dataset);
    let model = model_preset(kind);
    let species = dataset.dims[0];
    let per = field.len() / species;
    let mut csv = Csv::new("fig9", "fig9.csv", "species,series,cr,nrmse");

    // ours: per-species CR = species raw bytes / (amortized latents +
    // that species' GAE payload) — the paper's accounting (§III-G)
    let mut comp =
        prepare_stack(&c, &dataset, &model.hbae_group, &[&model.bae_group], &field)?;
    comp.model.bin_hbae = model.bin_hbae;
    comp.model.bin_bae = model.bin_bae;
    let gae_blocks_per_species =
        crate::tensor::block_origins(&dataset.dims, &dataset.gae_block).len() / species;
    for target in [1e-3f64, 3e-4, 1e-4] {
        let tau = PipelineConfig::tau_for_nrmse(
            target,
            field.range() as f64,
            dataset.gae_block_len(),
        );
        let (archive, recon) = comp.compress(&field, tau)?;
        let per_species_err = nrmse_per_channel(&field, &recon);
        let latent_bytes = archive.section("HLAT")?.len() + archive.section("BLAT")?.len();
        // split GAE payload per species by re-encoding per-species streams
        let d = dataset.gae_block_len();
        let sets = crate::coder::decode_index_sets(
            archive.section("GIDX")?,
            crate::coder::indexset::max_raw_size(gae_blocks_per_species * species, d),
        )?;
        let (codes, _) = crate::coder::huffman_decode(archive.section("GCOF")?)?;
        let mut cursor = 0usize;
        for s in 0..species {
            let s_sets: Vec<Vec<usize>> =
                sets[s * gae_blocks_per_species..(s + 1) * gae_blocks_per_species].to_vec();
            let n_codes: usize = s_sets.iter().map(|x| x.len()).sum();
            let s_codes = &codes[cursor..cursor + n_codes];
            cursor += n_codes;
            // exact per-species Huffman size via the shared frequency
            // counter (no per-species bitstream materialized)
            let gae_bytes = crate::coder::huffman_encoded_size(s_codes)
                + crate::coder::encode_index_sets(&s_sets, d)?.len();
            let payload = latent_bytes / species + gae_bytes;
            let cr = (per * 4) as f64 / payload.max(1) as f64;
            csv.row(&[
                s.to_string(),
                "ours".into(),
                format!("{cr:.2}"),
                format!("{:.4e}", per_species_err[s]),
            ]);
        }
    }

    // sz3 / zfp: compress each species' [t, x, y] field separately
    for s in 0..species {
        let sub = Tensor::new(
            dataset.dims[1..].to_vec(),
            field.data()[s * per..(s + 1) * per].to_vec(),
        );
        for rel in [1e-3f32, 3e-4, 1e-4] {
            let eps = rel * sub.range();
            let bytes = Sz3Like::new(eps).compress(&sub)?;
            let back = Sz3Like::decompress(&bytes)?;
            let cr = (sub.len() * 4) as f64 / bytes.len() as f64;
            csv.row(&[
                s.to_string(),
                "sz3".into(),
                format!("{cr:.2}"),
                format!("{:.4e}", nrmse(&sub, &back)),
            ]);
        }
        for p in [6u32, 10, 14] {
            let bytes = ZfpLike::new(p).compress(&sub)?;
            let back = ZfpLike::decompress(&bytes)?;
            let cr = (sub.len() * 4) as f64 / bytes.len() as f64;
            csv.row(&[
                s.to_string(),
                "zfp".into(),
                format!("{cr:.2}"),
                format!("{:.4e}", nrmse(&sub, &back)),
            ]);
        }
    }
    let p = csv.save()?;
    // terminal rendering: first 4 species
    let text = std::fs::read_to_string(&p)?;
    let mut series: Vec<Series> = Vec::new();
    for s in 0..4.min(species) {
        for name in ["ours", "sz3", "zfp"] {
            let pts: Vec<(f64, f64)> = text
                .lines()
                .skip(1)
                .filter_map(|l| {
                    let c: Vec<&str> = l.split(',').collect();
                    if c[0] == s.to_string() && c[1] == name {
                        Some((c[2].parse().ok()?, c[3].parse().ok()?))
                    } else {
                        None
                    }
                })
                .collect();
            series.push(Series::new(format!("sp{s}-{name}"), pts));
        }
    }
    println!(
        "{}",
        ascii_curves("Fig. 9 — per-species (first 4 shown)", "CR", "NRMSE", &series)
    );
    println!("-> {}", p.display());
    Ok(())
}
