//! Experiment harness: one runner per table/figure of the paper
//! (DESIGN.md §6). Each writes CSV rows into `results/<id>/` and prints
//! an ASCII rendering.

mod plot;
mod runners;

pub use plot::{ascii_curves, Series};
pub use runners::{run_experiment, EXPERIMENTS};

use crate::Result;
use std::io::Write;

/// Append-or-create a CSV file with a header.
pub struct Csv {
    path: std::path::PathBuf,
    rows: Vec<String>,
    header: String,
}

impl Csv {
    pub fn new(dir: &str, name: &str, header: &str) -> Self {
        Self {
            path: std::path::Path::new("results").join(dir).join(name),
            rows: Vec::new(),
            header: header.to_string(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        self.rows.push(cells.join(","));
    }

    pub fn rowf(&mut self, cells: std::fmt::Arguments<'_>) {
        self.rows.push(format!("{cells}"));
    }

    pub fn save(&self) -> Result<std::path::PathBuf> {
        if let Some(dir) = self.path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(&self.path)?;
        writeln!(f, "{}", self.header)?;
        for r in &self.rows {
            writeln!(f, "{r}")?;
        }
        Ok(self.path.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_writes_rows() {
        let mut c = Csv::new("test_csv", "t.csv", "a,b");
        c.row(&["1".into(), "2".into()]);
        c.rowf(format_args!("{},{}", 3, 4.5));
        let path = c.save().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1,2\n3,4.5\n");
        std::fs::remove_dir_all("results/test_csv").ok();
    }
}
