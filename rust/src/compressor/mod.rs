//! The paper's compression system: hierarchical AE pipeline ([`pipeline`]),
//! PCA error-bound guarantee ([`gae`], Algorithm 1), archive container
//! ([`format`]) and evaluation metrics ([`metrics`]).

pub mod format;
pub mod gae;
pub mod metrics;
pub mod pipeline;

pub use format::Archive;
pub use gae::{coeff_bin, gae_apply, gae_decode, BlockCorrection, GaeOutput};
pub use metrics::{
    compression_ratio, log_histogram, mean_channel_nrmse, nrmse, nrmse_per_channel,
    psnr, relative_point_errors,
};
pub use pipeline::{gae_taus, CompressStats, HierCompressor};
