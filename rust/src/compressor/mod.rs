//! The paper's compression system: hierarchical AE pipeline ([`pipeline`]),
//! PCA error-bound guarantee ([`gae`], Algorithm 1), archive container
//! ([`format`]) and evaluation metrics ([`metrics`]).
//!
//! The unified entry point for callers is the [`crate::codec`] layer
//! (`Codec` trait + `CodecBuilder`); this module holds the hierarchical
//! machinery behind it.

pub mod format;
pub mod gae;
pub mod metrics;
pub mod pipeline;

pub use format::{Archive, BlockIndex};
pub use gae::{
    coeff_bin, gae_apply, gae_bound_stage, gae_decode, gae_restore_stage,
    gae_restore_stage_region, gae_taus, BlockCorrection, GaeOutput, GaeSections,
};
pub use metrics::{
    compression_ratio, log_histogram, mean_channel_nrmse, nrmse, nrmse_per_channel,
    psnr, relative_point_errors,
};
pub use pipeline::{CompressStats, HierCompressor};
