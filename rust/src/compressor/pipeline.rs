//! The hierarchical compression pipeline (paper Fig. 1).
//!
//! `HierCompressor` owns trained parameters for one HBAE plus zero or more
//! residual BAEs (0 = the Fig.-5 "HBAE" ablation, 1 = the paper's method,
//! 2 = the Fig.-4 "StackAE" variant) and drives:
//!
//! ```text
//!  compress:   normalize -> hyper-block batches -> HBAE encode -> quantize
//!              -> HBAE decode -> residual -> BAE encode -> quantize ->
//!              BAE decode -> recon -> GAE (Algorithm 1) -> entropy stage
//!              -> Archive
//!  decompress: Archive -> entropy decode -> HBAE/BAE decode -> GAE
//!              corrections -> denormalize
//! ```
//!
//! All tensor math runs in the AOT HLO artifacts through PJRT; this module
//! is pure orchestration + the entropy stage.

use crate::coder::{
    decode_index_sets, encode_index_sets, huffman_decode, huffman_encode, indexset,
    Quantizer,
};
use crate::config::{DatasetConfig, ModelConfig, Normalization, PipelineConfig};
use crate::data::{Blocking, NormStats, Normalizer};
use crate::linalg::Pca;
use crate::model::ParamStore;
use crate::runtime::{HostTensor, Runtime};
use crate::tensor::{block_origins, extract_block, scatter_block, Tensor};
use crate::train::{train_bae, train_hbae, TrainReport};
use crate::util::json::{self, Value};
use crate::Result;
use anyhow::{ensure, Context};

use super::format::Archive;
use super::gae::{gae_apply, gae_decode, BlockCorrection};

/// Latent payload encoding modes (HLAT/BLAT section headers).
const MODE_RAW: u8 = 0;
const MODE_HUFF: u8 = 1;

/// Compression statistics for reporting.
#[derive(Debug, Clone)]
pub struct CompressStats {
    pub archive_bytes: usize,
    pub cr_payload_bytes: usize,
    /// Paper-accounting CR (latents + GAE coeffs + indices).
    pub cr: f64,
    /// CR counting every archive byte incl. basis + header.
    pub cr_total: f64,
    pub gae_corrected_blocks: usize,
    pub gae_total_coeffs: usize,
    pub section_sizes: Vec<(String, usize)>,
}

/// Trained hierarchical compressor for one dataset config.
pub struct HierCompressor<'a> {
    pub rt: &'a Runtime,
    pub dataset: DatasetConfig,
    pub model: ModelConfig,
    pub hbae: ParamStore,
    /// 0, 1, or 2 stacked residual BAEs (group of each recorded in header).
    pub baes: Vec<ParamStore>,
}

impl<'a> HierCompressor<'a> {
    /// Train (or load cached checkpoints for) the full stack.
    pub fn prepare(
        rt: &'a Runtime,
        cfg: &PipelineConfig,
        ckpt_dir: &std::path::Path,
        field: &Tensor,
    ) -> Result<(Self, Vec<TrainReport>)> {
        let mut reports = Vec::new();
        let blocking = Blocking::new(&cfg.dataset);
        let stats = Normalizer::fit(cfg.dataset.normalization, field);
        let mut norm = field.clone();
        Normalizer::apply(&stats, &mut norm);

        // HBAE
        let hpath = ParamStore::default_path(ckpt_dir, &cfg.model.hbae_group);
        let hbae = if hpath.exists() {
            ParamStore::load(&hpath, &cfg.model.hbae_group)?
        } else {
            let mut store = ParamStore::init(rt, &cfg.model.hbae_group)?;
            let rep = train_hbae(rt, &mut store, &blocking, &norm, &cfg.train)?;
            reports.push(rep);
            store.save(&hpath)?;
            store
        };

        // BAE on HBAE residuals
        let bpath = ParamStore::default_path(ckpt_dir, &cfg.model.bae_group);
        let mut this = Self {
            rt,
            dataset: cfg.dataset.clone(),
            model: cfg.model.clone(),
            hbae,
            baes: Vec::new(),
        };
        let bae = if bpath.exists() {
            ParamStore::load(&bpath, &cfg.model.bae_group)?
        } else {
            let residuals = this.hbae_residuals(&norm)?;
            let mut store = ParamStore::init(rt, &cfg.model.bae_group)?;
            let rep = train_bae(
                rt,
                &mut store,
                &residuals,
                blocking.block_dim(),
                &cfg.train,
            )?;
            reports.push(rep);
            store.save(&bpath)?;
            store
        };
        this.baes.push(bae);
        Ok((this, reports))
    }

    /// Residual rows (valid blocks only) of the *current stack* (HBAE +
    /// any already-attached BAEs) over a normalized field — the training
    /// set for the next residual BAE (Eq. 7 input; also the StackAE
    /// second-corrector input).
    pub fn stack_residuals(&self, norm: &Tensor) -> Result<Vec<f32>> {
        if self.baes.is_empty() {
            return self.hbae_residuals(norm);
        }
        let blocking = Blocking::new(&self.dataset);
        let bd = blocking.block_dim();
        let (_, _, recon) =
            self.forward_all(norm, Quantizer::disabled(), Quantizer::disabled())?;
        let mut out = Vec::with_capacity(blocking.num_blocks() * bd);
        let mut a = vec![0f32; bd];
        let mut b = vec![0f32; bd];
        for h in 0..blocking.num_hyperblocks() {
            for j in 0..blocking.k {
                if let Some(origin) = blocking.origin(h, j) {
                    extract_block(norm, &origin, &blocking.ae_block, &mut a);
                    extract_block(&recon, &origin, &blocking.ae_block, &mut b);
                    out.extend(a.iter().zip(&b).map(|(&x, &y)| x - y));
                }
            }
        }
        Ok(out)
    }

    /// Residual rows (valid blocks only) of the HBAE over a normalized
    /// field — the BAE training set (Eq. 7 input).
    pub fn hbae_residuals(&self, norm: &Tensor) -> Result<Vec<f32>> {
        let blocking = Blocking::new(&self.dataset);
        let bd = blocking.block_dim();
        let enc = self.rt.load(&self.hbae.group, "encode")?;
        let dec = self.rt.load(&self.hbae.group, "decode")?;
        let nh_batch = enc.info.inputs[1].shape[0];
        let k = blocking.k;
        let total_hb = blocking.num_hyperblocks();
        let mut out = Vec::with_capacity(blocking.num_blocks() * bd);
        let mut batch = vec![0f32; nh_batch * k * bd];
        let theta = HostTensor::vec(self.hbae.theta.clone());
        for h0 in (0..total_hb).step_by(nh_batch) {
            blocking.gather(norm, h0, nh_batch, &mut batch);
            let bt = HostTensor::new(vec![nh_batch, k, bd], batch.clone());
            let lat = enc.run(&[theta.clone(), bt.clone()])?.remove(0);
            let y = dec.run(&[theta.clone(), lat])?.remove(0);
            for hi in 0..nh_batch {
                let h = h0 + hi;
                if h >= total_hb {
                    break;
                }
                for j in 0..k {
                    if blocking.is_valid(h, j) {
                        let o = (hi * k + j) * bd;
                        out.extend(
                            batch[o..o + bd]
                                .iter()
                                .zip(&y.data[o..o + bd])
                                .map(|(&x, &yy)| x - yy),
                        );
                    }
                }
            }
        }
        Ok(out)
    }

    /// Does the fused `pipe/forward` artifact apply to this stack?
    /// (§Perf: one PJRT call per batch instead of four, with the residual
    /// and quantization computed in-graph — no intermediate host copies.)
    fn fused_pipe(&self) -> Option<std::rc::Rc<crate::runtime::Executable>> {
        if self.baes.len() != 1 || std::env::var_os("ATTN_REDUCE_NO_FUSE").is_some() {
            return None;
        }
        let pg = self.model.pipe_group.as_ref()?;
        let ginfo = self.rt.manifest.groups.get(pg)?;
        if ginfo.hbae_group.as_deref() != Some(self.hbae.group.as_str())
            || ginfo.bae_group.as_deref() != Some(self.baes[0].group.as_str())
        {
            return None;
        }
        self.rt.load(pg, "forward").ok()
    }

    /// Forward the full AE stack over a normalized field.
    ///
    /// Returns `(hbae latent rows, per-BAE latent rows for valid blocks,
    /// reconstruction in the normalized domain)`.
    fn forward_all(
        &self,
        norm: &Tensor,
        qh: Quantizer,
        qb: Quantizer,
    ) -> Result<(Vec<f32>, Vec<Vec<f32>>, Tensor)> {
        if let Some(fwd) = self.fused_pipe() {
            return self.forward_all_fused(&fwd, norm, qh, qb);
        }
        let blocking = Blocking::new(&self.dataset);
        let bd = blocking.block_dim();
        let k = blocking.k;
        let enc = self.rt.load(&self.hbae.group, "encode")?;
        let dec = self.rt.load(&self.hbae.group, "decode")?;
        let nh_batch = enc.info.inputs[1].shape[0];
        let lh_dim = enc.info.outputs[0].shape[1];
        let total_hb = blocking.num_hyperblocks();
        let theta = HostTensor::vec(self.hbae.theta.clone());

        let mut lh_all = Vec::with_capacity(total_hb * lh_dim);
        let mut lb_all: Vec<Vec<f32>> = self.baes.iter().map(|_| Vec::new()).collect();
        let mut recon = Tensor::zeros(self.dataset.dims.clone());
        let mut batch = vec![0f32; nh_batch * k * bd];

        for h0 in (0..total_hb).step_by(nh_batch) {
            blocking.gather(norm, h0, nh_batch, &mut batch);
            let bt = HostTensor::new(vec![nh_batch, k, bd], batch.clone());
            let mut lh = enc.run(&[theta.clone(), bt])?.remove(0);
            qh.snap(&mut lh.data);
            let y = dec.run(&[theta.clone(), lh.clone()])?.remove(0);

            // residual cascade through the stacked BAEs
            let mut resid: Vec<f32> =
                batch.iter().zip(&y.data).map(|(&x, &yy)| x - yy).collect();
            let mut recon_batch = y.data.clone();
            for (bi, bae) in self.baes.iter().enumerate() {
                let benc = self.rt.load(&bae.group, "encode")?;
                let bdec = self.rt.load(&bae.group, "decode")?;
                let nb = benc.info.inputs[1].shape[0];
                ensure!(nb == nh_batch * k, "bae batch mismatch");
                let phi = HostTensor::vec(bae.theta.clone());
                let rt_in = HostTensor::new(vec![nb, bd], resid.clone());
                let mut lb = benc.run(&[phi.clone(), rt_in])?.remove(0);
                qb.snap(&mut lb.data);
                let rhat = bdec.run(&[phi, lb.clone()])?.remove(0);
                for i in 0..resid.len() {
                    recon_batch[i] += rhat.data[i];
                    resid[i] -= rhat.data[i];
                }
                // collect latents of valid blocks
                let lb_dim = lb.shape[1];
                for hi in 0..nh_batch {
                    let h = h0 + hi;
                    if h >= total_hb {
                        break;
                    }
                    for j in 0..k {
                        if blocking.is_valid(h, j) {
                            let r = hi * k + j;
                            lb_all[bi]
                                .extend_from_slice(&lb.data[r * lb_dim..(r + 1) * lb_dim]);
                        }
                    }
                }
            }
            // collect hyper-block latents + scatter recon
            let n_here = (total_hb - h0).min(nh_batch);
            lh_all.extend_from_slice(&lh.data[..n_here * lh_dim]);
            blocking.scatter(&mut recon, h0, nh_batch, &recon_batch);
        }
        Ok((lh_all, lb_all, recon))
    }

    /// Hot-path variant of [`Self::forward_all`] over the fused artifact.
    fn forward_all_fused(
        &self,
        fwd: &crate::runtime::Executable,
        norm: &Tensor,
        qh: Quantizer,
        qb: Quantizer,
    ) -> Result<(Vec<f32>, Vec<Vec<f32>>, Tensor)> {
        let blocking = Blocking::new(&self.dataset);
        let bd = blocking.block_dim();
        let k = blocking.k;
        let nh_batch = fwd.info.inputs[2].shape[0];
        let lh_dim = fwd.info.outputs[0].shape[1];
        let lb_dim = fwd.info.outputs[1].shape[1];
        let total_hb = blocking.num_hyperblocks();
        let theta = HostTensor::vec(self.hbae.theta.clone());
        let phi = HostTensor::vec(self.baes[0].theta.clone());
        // bin <= 0 disables quantization inside the graph (model.py)
        let bin_h = HostTensor::scalar(if qh.enabled() { qh.bin } else { 0.0 });
        let bin_b = HostTensor::scalar(if qb.enabled() { qb.bin } else { 0.0 });

        let mut lh_all = Vec::with_capacity(total_hb * lh_dim);
        let mut lb_all: Vec<Vec<f32>> = vec![Vec::new()];
        let mut recon = Tensor::zeros(self.dataset.dims.clone());
        let mut batch = vec![0f32; nh_batch * k * bd];
        for h0 in (0..total_hb).step_by(nh_batch) {
            blocking.gather(norm, h0, nh_batch, &mut batch);
            let outs = fwd.run(&[
                theta.clone(),
                phi.clone(),
                HostTensor::new(vec![nh_batch, k, bd], batch.clone()),
                bin_h.clone(),
                bin_b.clone(),
            ])?;
            let (lh, lb, rc) = (&outs[0], &outs[1], &outs[2]);
            let n_here = (total_hb - h0).min(nh_batch);
            lh_all.extend_from_slice(&lh.data[..n_here * lh_dim]);
            for hi in 0..n_here {
                for j in 0..k {
                    if blocking.is_valid(h0 + hi, j) {
                        let r = hi * k + j;
                        lb_all[0].extend_from_slice(&lb.data[r * lb_dim..(r + 1) * lb_dim]);
                    }
                }
            }
            blocking.scatter(&mut recon, h0, nh_batch, &rc.data);
        }
        Ok((lh_all, lb_all, recon))
    }

    /// Decode latent rows back into a normalized-domain reconstruction.
    fn decode_all(
        rt: &Runtime,
        dataset: &DatasetConfig,
        hbae: &ParamStore,
        baes: &[ParamStore],
        lh_all: &[f32],
        lb_all: &[Vec<f32>],
    ) -> Result<Tensor> {
        let blocking = Blocking::new(dataset);
        let k = blocking.k;
        let dec = rt.load(&hbae.group, "decode")?;
        let nh_batch = dec.info.inputs[1].shape[0];
        let lh_dim = dec.info.inputs[1].shape[1];
        let total_hb = blocking.num_hyperblocks();
        ensure!(lh_all.len() == total_hb * lh_dim, "HLAT length mismatch");
        let theta = HostTensor::vec(hbae.theta.clone());

        let mut recon = Tensor::zeros(dataset.dims.clone());
        // per-BAE read cursors over valid-block latents
        let mut cursors = vec![0usize; baes.len()];
        for h0 in (0..total_hb).step_by(nh_batch) {
            let n_here = (total_hb - h0).min(nh_batch);
            let mut lh = vec![0f32; nh_batch * lh_dim];
            lh[..n_here * lh_dim]
                .copy_from_slice(&lh_all[h0 * lh_dim..(h0 + n_here) * lh_dim]);
            let y = dec
                .run(&[theta.clone(), HostTensor::new(vec![nh_batch, lh_dim], lh)])?
                .remove(0);
            let mut recon_batch = y.data.clone();
            for (bi, bae) in baes.iter().enumerate() {
                let bdec = rt.load(&bae.group, "decode")?;
                let nb = bdec.info.inputs[1].shape[0];
                let lb_dim = bdec.info.inputs[1].shape[1];
                let mut lb = vec![0f32; nb * lb_dim];
                for hi in 0..nh_batch {
                    let h = h0 + hi;
                    if h >= total_hb {
                        break;
                    }
                    for j in 0..k {
                        if blocking.is_valid(h, j) {
                            let r = hi * k + j;
                            let c = cursors[bi];
                            lb[r * lb_dim..(r + 1) * lb_dim].copy_from_slice(
                                &lb_all[bi][c..c + lb_dim],
                            );
                            cursors[bi] += lb_dim;
                        }
                    }
                }
                let phi = HostTensor::vec(bae.theta.clone());
                let rhat = bdec
                    .run(&[phi, HostTensor::new(vec![nb, lb_dim], lb)])?
                    .remove(0);
                for i in 0..recon_batch.len() {
                    recon_batch[i] += rhat.data[i];
                }
            }
            blocking.scatter(&mut recon, h0, nh_batch, &recon_batch);
        }
        Ok(recon)
    }

    /// Compress a field with per-GAE-block ℓ2 bound `tau` (original
    /// units; `tau <= 0` disables GAE). Returns the archive and the final
    /// reconstruction in the **original** domain.
    pub fn compress(&self, field: &Tensor, tau: f32) -> Result<(Archive, Tensor)> {
        ensure!(field.shape() == &self.dataset.dims[..], "field shape mismatch");
        let stats = Normalizer::fit(self.dataset.normalization, field);
        let mut norm = field.clone();
        Normalizer::apply(&stats, &mut norm);

        let qh = Quantizer::new(self.model.bin_hbae.max(0.0));
        let qb = Quantizer::new(self.model.bin_bae.max(0.0));
        let (lh_all, lb_all, mut recon) = self.forward_all(&norm, qh, qb)?;

        // ---- GAE stage (normalized domain; per-block tau from channel
        // scale so the bound transfers exactly to original units) ----
        let gae_sections = if tau > 0.0 {
            let d = self.dataset.gae_block_len();
            let origins = block_origins(&self.dataset.dims, &self.dataset.gae_block);
            let taus = gae_taus(&self.dataset, &stats, tau, &origins);
            let mut orig_rows = vec![0f32; origins.len() * d];
            let mut recon_rows = vec![0f32; origins.len() * d];
            for (bi, o) in origins.iter().enumerate() {
                extract_block(&norm, o, &self.dataset.gae_block, &mut orig_rows[bi * d..(bi + 1) * d]);
                extract_block(&recon, o, &self.dataset.gae_block, &mut recon_rows[bi * d..(bi + 1) * d]);
            }
            let out = gae_apply(&orig_rows, &mut recon_rows, d, &taus)?;
            for (bi, o) in origins.iter().enumerate() {
                scatter_block(&mut recon, o, &self.dataset.gae_block, &recon_rows[bi * d..(bi + 1) * d]);
            }
            Some((out, origins.len()))
        } else {
            None
        };

        // ---- entropy stage + archive ----
        let mut header = vec![
            ("dataset", self.dataset.to_json()),
            ("model", self.model.to_json()),
            ("norm", stats.to_json()),
            ("tau", json::num(tau as f64)),
            (
                "bae_groups",
                Value::Arr(self.baes.iter().map(|b| json::s(b.group.as_str())).collect()),
            ),
            ("hbae_group", json::s(self.hbae.group.as_str())),
        ];
        let (gae_out, n_gae_blocks) = match &gae_sections {
            Some((o, n)) => (Some(o), *n),
            None => (None, 0),
        };
        header.push(("gae_blocks", json::num(n_gae_blocks as f64)));
        let mut archive = Archive::new(json::obj(header));
        archive.add_section("HLAT", encode_latents(&lh_all, qh));
        archive.add_section("BLAT", encode_latent_groups(&lb_all, qb));
        if let Some(out) = gae_out {
            let codes: Vec<i32> = out
                .corrections
                .iter()
                .flat_map(|c| c.codes.iter().copied())
                .collect();
            archive.add_section("GCOF", huffman_encode(&codes));
            let sets: Vec<Vec<usize>> =
                out.corrections.iter().map(|c| c.indices.clone()).collect();
            archive.add_section(
                "GIDX",
                encode_index_sets(&sets, self.dataset.gae_block_len())?,
            );
            archive.add_section("GBAS", out.pca.basis_f32_bytes());
        }

        Normalizer::invert(&stats, &mut recon);
        Ok((archive, recon))
    }

    /// Compression statistics for an archive produced by [`Self::compress`].
    pub fn stats(&self, archive: &Archive) -> CompressStats {
        let n_points = self.dataset.total_points();
        let payload = archive.cr_payload_bytes();
        let total = archive.total_bytes();
        CompressStats {
            archive_bytes: total,
            cr_payload_bytes: payload,
            cr: super::metrics::compression_ratio(n_points, payload),
            cr_total: super::metrics::compression_ratio(n_points, total),
            gae_corrected_blocks: 0, // filled by compress_with_stats
            gae_total_coeffs: 0,
            section_sizes: archive.section_sizes(),
        }
    }

    /// Decompress an archive (static: only needs the trained params).
    pub fn decompress(
        rt: &Runtime,
        archive: &Archive,
        hbae: &ParamStore,
        baes: &[ParamStore],
    ) -> Result<Tensor> {
        let h = &archive.header;
        let dataset = DatasetConfig::from_json(h.req("dataset")?)?;
        let model = ModelConfig::from_json(h.req("model")?)?;
        let stats = NormStats::from_json(h.req("norm")?)?;
        let tau = h.req("tau")?.as_f64().unwrap_or(0.0) as f32;
        ensure!(hbae.group == h.req("hbae_group")?.as_str().unwrap_or(""), "hbae group mismatch");

        let qh = Quantizer::new(model.bin_hbae.max(0.0));
        let qb = Quantizer::new(model.bin_bae.max(0.0));
        let lh_all = decode_latents(archive.section("HLAT")?, qh)?;
        let lb_all = decode_latent_groups(archive.section("BLAT")?, qb, baes.len())?;

        let mut recon = Self::decode_all(rt, &dataset, hbae, baes, &lh_all, &lb_all)?;

        if tau > 0.0 && archive.has_section("GBAS") {
            let d = dataset.gae_block_len();
            let origins = block_origins(&dataset.dims, &dataset.gae_block);
            let taus = gae_taus(&dataset, &stats, tau, &origins);
            let pca = Pca::from_f32_bytes(archive.section("GBAS")?, d)?;
            let sets = decode_index_sets(
                archive.section("GIDX")?,
                indexset::max_raw_size(origins.len(), d),
            )?;
            ensure!(sets.len() == origins.len(), "GIDX count mismatch");
            let (codes, _) = huffman_decode(archive.section("GCOF")?)?;
            let mut corrections = Vec::with_capacity(sets.len());
            let mut cur = 0usize;
            for set in sets {
                let n = set.len();
                ensure!(cur + n <= codes.len(), "GCOF underrun");
                corrections.push(BlockCorrection {
                    indices: set,
                    codes: codes[cur..cur + n].to_vec(),
                });
                cur += n;
            }
            let mut rows = vec![0f32; origins.len() * d];
            for (bi, o) in origins.iter().enumerate() {
                extract_block(&recon, o, &dataset.gae_block, &mut rows[bi * d..(bi + 1) * d]);
            }
            gae_decode(&mut rows, d, &taus, &pca, &corrections)?;
            for (bi, o) in origins.iter().enumerate() {
                scatter_block(&mut recon, o, &dataset.gae_block, &rows[bi * d..(bi + 1) * d]);
            }
        }

        Normalizer::invert(&stats, &mut recon);
        Ok(recon)
    }
}

/// Per-GAE-block bounds in the normalized domain: `τ_norm = τ / scale_ch`
/// (the GAE block lies within one channel, so the bound transfers exactly
/// back to original units).
pub fn gae_taus(
    dataset: &DatasetConfig,
    stats: &NormStats,
    tau_orig: f32,
    origins: &[Vec<usize>],
) -> Vec<f32> {
    match dataset.normalization {
        Normalization::ZScore => {
            let s = stats.channels[0].1.max(1e-30);
            vec![(tau_orig as f64 / s) as f32; origins.len()]
        }
        Normalization::PerSpeciesMeanRange => origins
            .iter()
            .map(|o| {
                let ch = o[0].min(stats.channels.len() - 1);
                let s = stats.channels[ch].1.max(1e-30);
                (tau_orig as f64 / s) as f32
            })
            .collect(),
    }
}

// ---------------------------------------------------------------------------
// Latent section codecs
// ---------------------------------------------------------------------------

/// Encode latent rows: Huffman over integer codes when quantized, raw f32
/// otherwise (the ablation configs disable quantization).
fn encode_latents(values: &[f32], q: Quantizer) -> Vec<u8> {
    let mut out = Vec::new();
    if q.enabled() {
        out.push(MODE_HUFF);
        let codes: Vec<i32> = values.iter().map(|&v| q.code(v)).collect();
        out.extend(huffman_encode(&codes));
    } else {
        out.push(MODE_RAW);
        out.extend_from_slice(&(values.len() as u64).to_le_bytes());
        for &v in values {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }
    out
}

fn decode_latents(bytes: &[u8], q: Quantizer) -> Result<Vec<f32>> {
    ensure!(!bytes.is_empty(), "latent section empty");
    match bytes[0] {
        MODE_HUFF => {
            ensure!(q.enabled(), "archive quantized but config bin is 0");
            let (codes, _) = huffman_decode(&bytes[1..])?;
            Ok(q.dequant_all(&codes))
        }
        MODE_RAW => {
            ensure!(bytes.len() >= 9, "raw latent header");
            let n = u64::from_le_bytes(bytes[1..9].try_into().unwrap()) as usize;
            ensure!(bytes.len() == 9 + n * 4, "raw latent length");
            Ok(bytes[9..]
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect())
        }
        m => anyhow::bail!("unknown latent mode {m}"),
    }
}

/// Concatenate one latent stream per stacked BAE (u32 count prefix).
fn encode_latent_groups(groups: &[Vec<f32>], q: Quantizer) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(&(groups.len() as u32).to_le_bytes());
    for g in groups {
        let payload = encode_latents(g, q);
        out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
        out.extend(payload);
    }
    out
}

fn decode_latent_groups(bytes: &[u8], q: Quantizer, expect: usize) -> Result<Vec<Vec<f32>>> {
    ensure!(bytes.len() >= 4, "BLAT header");
    let n = u32::from_le_bytes(bytes[0..4].try_into().unwrap()) as usize;
    ensure!(n == expect, "archive has {n} BAE streams, loaded {expect} BAEs");
    let mut off = 4;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let len = u64::from_le_bytes(
            bytes
                .get(off..off + 8)
                .context("BLAT length")?
                .try_into()
                .unwrap(),
        ) as usize;
        off += 8;
        out.push(decode_latents(bytes.get(off..off + len).context("BLAT body")?, q)?);
        off += len;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latent_codec_round_trips_quantized() {
        let q = Quantizer::new(0.05);
        let vals: Vec<f32> = (0..100).map(|i| (i as f32 * 0.31).sin()).collect();
        let enc = encode_latents(&vals, q);
        let dec = decode_latents(&enc, q).unwrap();
        for (a, b) in vals.iter().zip(&dec) {
            assert!((a - b).abs() <= 0.025 + 1e-6);
        }
        // snapped values round-trip exactly
        let mut snapped = vals.clone();
        q.snap(&mut snapped);
        let enc2 = encode_latents(&snapped, q);
        let dec2 = decode_latents(&enc2, q).unwrap();
        assert_eq!(snapped, dec2);
    }

    #[test]
    fn latent_codec_round_trips_raw() {
        let q = Quantizer::disabled();
        let vals: Vec<f32> = (0..50).map(|i| (i as f32).exp() % 7.0).collect();
        let dec = decode_latents(&encode_latents(&vals, q), q).unwrap();
        assert_eq!(vals, dec);
    }

    #[test]
    fn latent_groups_round_trip() {
        let q = Quantizer::new(0.1);
        let mut g1: Vec<f32> = (0..30).map(|i| i as f32 * 0.3).collect();
        let mut g2: Vec<f32> = (0..10).map(|i| -(i as f32) * 0.7).collect();
        q.snap(&mut g1);
        q.snap(&mut g2);
        let groups = vec![g1.clone(), g2.clone()];
        let enc = encode_latent_groups(&groups, q);
        let dec = decode_latent_groups(&enc, q, 2).unwrap();
        assert_eq!(dec, groups);
        assert!(decode_latent_groups(&enc, q, 1).is_err());
    }

    #[test]
    fn gae_taus_scale_per_species() {
        use crate::config::{dataset_preset, DatasetKind, Scale};
        let d = dataset_preset(DatasetKind::S3d, Scale::Smoke);
        let stats = NormStats {
            kind: Normalization::PerSpeciesMeanRange,
            channels: (0..16).map(|i| (0.0, 1.0 + i as f64)).collect(),
        };
        let origins = block_origins(&d.dims, &d.gae_block);
        let taus = gae_taus(&d, &stats, 2.0, &origins);
        // block for species 0 has scale 1 -> tau 2; species 1 -> tau 1
        let per_species = origins.len() / 16;
        assert!((taus[0] - 2.0).abs() < 1e-6);
        assert!((taus[per_species] - 1.0).abs() < 1e-6);
    }
}
