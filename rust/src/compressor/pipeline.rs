//! The hierarchical compression pipeline (paper Fig. 1).
//!
//! `HierCompressor` owns the runtime handle plus trained parameters for
//! one HBAE and zero or more residual BAEs (0 = the Fig.-5 "HBAE"
//! ablation, 1 = the paper's method, 2 = the Fig.-4 "StackAE" variant)
//! and drives:
//!
//! ```text
//!  compress:   normalize -> hyper-block batches -> HBAE encode -> quantize
//!              -> HBAE decode -> residual -> BAE encode -> quantize ->
//!              BAE decode -> recon -> GAE (Algorithm 1) -> entropy stage
//!              -> Archive
//!  decompress: Archive -> entropy decode -> HBAE/BAE decode -> GAE
//!              corrections -> denormalize
//! ```
//!
//! All tensor math runs in the AOT HLO artifacts through PJRT; this module
//! is pure orchestration + the entropy stage. Most callers should reach it
//! through [`crate::codec::HierCodec`] / [`crate::codec::CodecBuilder`],
//! which wrap it behind the unified [`crate::codec::Codec`] trait.

use std::rc::Rc;

use crate::coder::{
    decode_latent_groups, decode_latents, encode_latent_groups, encode_latents, Quantizer,
};
use crate::config::{DatasetConfig, ModelConfig, PipelineConfig};
use crate::data::{Blocking, NormStats, Normalizer};
use crate::model::ParamStore;
use crate::runtime::{HostTensor, Runtime};
use crate::tensor::{extract_block, Tensor};
use crate::train::{train_bae, train_hbae, TrainReport};
use crate::util::json::{self, Value};
use crate::Result;
use anyhow::ensure;

use super::format::Archive;
use super::gae::{gae_bound_stage, gae_restore_stage_region, GaeSections};

/// Compression statistics for reporting.
#[derive(Debug, Clone)]
pub struct CompressStats {
    pub archive_bytes: usize,
    pub cr_payload_bytes: usize,
    /// Paper-accounting CR (latents + GAE coeffs + indices).
    pub cr: f64,
    /// CR counting every archive byte incl. basis + header.
    pub cr_total: f64,
    pub gae_corrected_blocks: usize,
    pub gae_total_coeffs: usize,
    pub section_sizes: Vec<(String, usize)>,
}

/// Trained hierarchical compressor for one dataset config.
///
/// Owns its [`Runtime`] handle (`Rc`, the PJRT client is `!Send`), so the
/// value is self-contained — callers no longer thread a runtime borrow
/// through every call site.
pub struct HierCompressor {
    pub rt: Rc<Runtime>,
    pub dataset: DatasetConfig,
    pub model: ModelConfig,
    pub hbae: ParamStore,
    /// 0, 1, or 2 stacked residual BAEs (group of each recorded in header).
    pub baes: Vec<ParamStore>,
}

impl HierCompressor {
    /// Train (or load cached checkpoints for) the full stack.
    pub fn prepare(
        rt: &Rc<Runtime>,
        cfg: &PipelineConfig,
        ckpt_dir: &std::path::Path,
        field: &Tensor,
    ) -> Result<(Self, Vec<TrainReport>)> {
        let mut reports = Vec::new();
        let blocking = Blocking::new(&cfg.dataset);
        let stats = Normalizer::fit(cfg.dataset.normalization, field);
        let mut norm = field.clone();
        Normalizer::apply(&stats, &mut norm);

        // HBAE
        let hpath = ParamStore::default_path(ckpt_dir, &cfg.model.hbae_group);
        let hbae = if hpath.exists() {
            ParamStore::load(&hpath, &cfg.model.hbae_group)?
        } else {
            let mut store = ParamStore::init(rt, &cfg.model.hbae_group)?;
            let rep = train_hbae(rt, &mut store, &blocking, &norm, &cfg.train)?;
            reports.push(rep);
            store.save(&hpath)?;
            store
        };

        // BAE on HBAE residuals
        let bpath = ParamStore::default_path(ckpt_dir, &cfg.model.bae_group);
        let mut this = Self {
            rt: rt.clone(),
            dataset: cfg.dataset.clone(),
            model: cfg.model.clone(),
            hbae,
            baes: Vec::new(),
        };
        let bae = if bpath.exists() {
            ParamStore::load(&bpath, &cfg.model.bae_group)?
        } else {
            let residuals = this.hbae_residuals(&norm)?;
            let mut store = ParamStore::init(rt, &cfg.model.bae_group)?;
            let rep = train_bae(
                rt,
                &mut store,
                &residuals,
                blocking.block_dim(),
                &cfg.train,
            )?;
            reports.push(rep);
            store.save(&bpath)?;
            store
        };
        this.baes.push(bae);
        Ok((this, reports))
    }

    /// Residual rows (valid blocks only) of the *current stack* (HBAE +
    /// any already-attached BAEs) over a normalized field — the training
    /// set for the next residual BAE (Eq. 7 input; also the StackAE
    /// second-corrector input).
    pub fn stack_residuals(&self, norm: &Tensor) -> Result<Vec<f32>> {
        if self.baes.is_empty() {
            return self.hbae_residuals(norm);
        }
        let blocking = Blocking::new(&self.dataset);
        let bd = blocking.block_dim();
        let (_, _, recon) =
            self.forward_all(norm, Quantizer::disabled(), Quantizer::disabled())?;
        let mut out = Vec::with_capacity(blocking.num_blocks() * bd);
        let mut a = vec![0f32; bd];
        let mut b = vec![0f32; bd];
        for h in 0..blocking.num_hyperblocks() {
            for j in 0..blocking.k {
                if let Some(origin) = blocking.origin(h, j) {
                    extract_block(norm, &origin, &blocking.ae_block, &mut a);
                    extract_block(&recon, &origin, &blocking.ae_block, &mut b);
                    out.extend(a.iter().zip(&b).map(|(&x, &y)| x - y));
                }
            }
        }
        Ok(out)
    }

    /// Residual rows (valid blocks only) of the HBAE over a normalized
    /// field — the BAE training set (Eq. 7 input).
    pub fn hbae_residuals(&self, norm: &Tensor) -> Result<Vec<f32>> {
        let blocking = Blocking::new(&self.dataset);
        let bd = blocking.block_dim();
        let enc = self.rt.load(&self.hbae.group, "encode")?;
        let dec = self.rt.load(&self.hbae.group, "decode")?;
        let nh_batch = enc.info.inputs[1].shape[0];
        let k = blocking.k;
        let total_hb = blocking.num_hyperblocks();
        let mut out = Vec::with_capacity(blocking.num_blocks() * bd);
        let mut batch = vec![0f32; nh_batch * k * bd];
        let theta = HostTensor::vec(self.hbae.theta.clone());
        for h0 in (0..total_hb).step_by(nh_batch) {
            blocking.gather(norm, h0, nh_batch, &mut batch);
            let bt = HostTensor::new(vec![nh_batch, k, bd], batch.clone());
            let lat = enc.run(&[theta.clone(), bt.clone()])?.remove(0);
            let y = dec.run(&[theta.clone(), lat])?.remove(0);
            for hi in 0..nh_batch {
                let h = h0 + hi;
                if h >= total_hb {
                    break;
                }
                for j in 0..k {
                    if blocking.is_valid(h, j) {
                        let o = (hi * k + j) * bd;
                        out.extend(
                            batch[o..o + bd]
                                .iter()
                                .zip(&y.data[o..o + bd])
                                .map(|(&x, &yy)| x - yy),
                        );
                    }
                }
            }
        }
        Ok(out)
    }

    /// Does the fused `pipe/forward` artifact apply to this stack?
    /// (§Perf: one PJRT call per batch instead of four, with the residual
    /// and quantization computed in-graph — no intermediate host copies.)
    fn fused_pipe(&self) -> Option<std::rc::Rc<crate::runtime::Executable>> {
        if self.baes.len() != 1 || std::env::var_os("ATTN_REDUCE_NO_FUSE").is_some() {
            return None;
        }
        let pg = self.model.pipe_group.as_ref()?;
        let ginfo = self.rt.manifest.groups.get(pg)?;
        if ginfo.hbae_group.as_deref() != Some(self.hbae.group.as_str())
            || ginfo.bae_group.as_deref() != Some(self.baes[0].group.as_str())
        {
            return None;
        }
        self.rt.load(pg, "forward").ok()
    }

    /// Forward the full AE stack over a normalized field.
    ///
    /// Returns `(hbae latent rows, per-BAE latent rows for valid blocks,
    /// reconstruction in the normalized domain)`.
    fn forward_all(
        &self,
        norm: &Tensor,
        qh: Quantizer,
        qb: Quantizer,
    ) -> Result<(Vec<f32>, Vec<Vec<f32>>, Tensor)> {
        if let Some(fwd) = self.fused_pipe() {
            return self.forward_all_fused(&fwd, norm, qh, qb);
        }
        let blocking = Blocking::new(&self.dataset);
        let bd = blocking.block_dim();
        let k = blocking.k;
        let enc = self.rt.load(&self.hbae.group, "encode")?;
        let dec = self.rt.load(&self.hbae.group, "decode")?;
        let nh_batch = enc.info.inputs[1].shape[0];
        let lh_dim = enc.info.outputs[0].shape[1];
        let total_hb = blocking.num_hyperblocks();
        let theta = HostTensor::vec(self.hbae.theta.clone());

        let mut lh_all = Vec::with_capacity(total_hb * lh_dim);
        let mut lb_all: Vec<Vec<f32>> = self.baes.iter().map(|_| Vec::new()).collect();
        let mut recon = Tensor::zeros(self.dataset.dims.clone());
        let mut batch = vec![0f32; nh_batch * k * bd];

        for h0 in (0..total_hb).step_by(nh_batch) {
            blocking.gather(norm, h0, nh_batch, &mut batch);
            let bt = HostTensor::new(vec![nh_batch, k, bd], batch.clone());
            let mut lh = enc.run(&[theta.clone(), bt])?.remove(0);
            qh.snap(&mut lh.data);
            let y = dec.run(&[theta.clone(), lh.clone()])?.remove(0);

            // residual cascade through the stacked BAEs
            let mut resid: Vec<f32> =
                batch.iter().zip(&y.data).map(|(&x, &yy)| x - yy).collect();
            let mut recon_batch = y.data.clone();
            for (bi, bae) in self.baes.iter().enumerate() {
                let benc = self.rt.load(&bae.group, "encode")?;
                let bdec = self.rt.load(&bae.group, "decode")?;
                let nb = benc.info.inputs[1].shape[0];
                ensure!(nb == nh_batch * k, "bae batch mismatch");
                let phi = HostTensor::vec(bae.theta.clone());
                let rt_in = HostTensor::new(vec![nb, bd], resid.clone());
                let mut lb = benc.run(&[phi.clone(), rt_in])?.remove(0);
                qb.snap(&mut lb.data);
                let rhat = bdec.run(&[phi, lb.clone()])?.remove(0);
                for i in 0..resid.len() {
                    recon_batch[i] += rhat.data[i];
                    resid[i] -= rhat.data[i];
                }
                // collect latents of valid blocks
                let lb_dim = lb.shape[1];
                for hi in 0..nh_batch {
                    let h = h0 + hi;
                    if h >= total_hb {
                        break;
                    }
                    for j in 0..k {
                        if blocking.is_valid(h, j) {
                            let r = hi * k + j;
                            lb_all[bi]
                                .extend_from_slice(&lb.data[r * lb_dim..(r + 1) * lb_dim]);
                        }
                    }
                }
            }
            // collect hyper-block latents + scatter recon
            let n_here = (total_hb - h0).min(nh_batch);
            lh_all.extend_from_slice(&lh.data[..n_here * lh_dim]);
            blocking.scatter(&mut recon, h0, nh_batch, &recon_batch);
        }
        Ok((lh_all, lb_all, recon))
    }

    /// Hot-path variant of [`Self::forward_all`] over the fused artifact.
    fn forward_all_fused(
        &self,
        fwd: &crate::runtime::Executable,
        norm: &Tensor,
        qh: Quantizer,
        qb: Quantizer,
    ) -> Result<(Vec<f32>, Vec<Vec<f32>>, Tensor)> {
        let blocking = Blocking::new(&self.dataset);
        let bd = blocking.block_dim();
        let k = blocking.k;
        let nh_batch = fwd.info.inputs[2].shape[0];
        let lh_dim = fwd.info.outputs[0].shape[1];
        let lb_dim = fwd.info.outputs[1].shape[1];
        let total_hb = blocking.num_hyperblocks();
        let theta = HostTensor::vec(self.hbae.theta.clone());
        let phi = HostTensor::vec(self.baes[0].theta.clone());
        // bin <= 0 disables quantization inside the graph (model.py)
        let bin_h = HostTensor::scalar(if qh.enabled() { qh.bin } else { 0.0 });
        let bin_b = HostTensor::scalar(if qb.enabled() { qb.bin } else { 0.0 });

        let mut lh_all = Vec::with_capacity(total_hb * lh_dim);
        let mut lb_all: Vec<Vec<f32>> = vec![Vec::new()];
        let mut recon = Tensor::zeros(self.dataset.dims.clone());
        let mut batch = vec![0f32; nh_batch * k * bd];
        for h0 in (0..total_hb).step_by(nh_batch) {
            blocking.gather(norm, h0, nh_batch, &mut batch);
            let outs = fwd.run(&[
                theta.clone(),
                phi.clone(),
                HostTensor::new(vec![nh_batch, k, bd], batch.clone()),
                bin_h.clone(),
                bin_b.clone(),
            ])?;
            let (lh, lb, rc) = (&outs[0], &outs[1], &outs[2]);
            let n_here = (total_hb - h0).min(nh_batch);
            lh_all.extend_from_slice(&lh.data[..n_here * lh_dim]);
            for hi in 0..n_here {
                for j in 0..k {
                    if blocking.is_valid(h0 + hi, j) {
                        let r = hi * k + j;
                        lb_all[0].extend_from_slice(&lb.data[r * lb_dim..(r + 1) * lb_dim]);
                    }
                }
            }
            blocking.scatter(&mut recon, h0, nh_batch, &rc.data);
        }
        Ok((lh_all, lb_all, recon))
    }

    /// Decode latent rows back into a normalized-domain reconstruction.
    fn decode_all(
        rt: &Runtime,
        dataset: &DatasetConfig,
        hbae: &ParamStore,
        baes: &[ParamStore],
        lh_all: &[f32],
        lb_all: &[Vec<f32>],
    ) -> Result<Tensor> {
        let blocking = Blocking::new(dataset);
        let k = blocking.k;
        let dec = rt.load(&hbae.group, "decode")?;
        let nh_batch = dec.info.inputs[1].shape[0];
        let lh_dim = dec.info.inputs[1].shape[1];
        let total_hb = blocking.num_hyperblocks();
        ensure!(lh_all.len() == total_hb * lh_dim, "HLAT length mismatch");
        let theta = HostTensor::vec(hbae.theta.clone());

        let mut recon = Tensor::zeros(dataset.dims.clone());
        // per-BAE read cursors over valid-block latents
        let mut cursors = vec![0usize; baes.len()];
        for h0 in (0..total_hb).step_by(nh_batch) {
            let n_here = (total_hb - h0).min(nh_batch);
            let mut lh = vec![0f32; nh_batch * lh_dim];
            lh[..n_here * lh_dim]
                .copy_from_slice(&lh_all[h0 * lh_dim..(h0 + n_here) * lh_dim]);
            let y = dec
                .run(&[theta.clone(), HostTensor::new(vec![nh_batch, lh_dim], lh)])?
                .remove(0);
            let mut recon_batch = y.data.clone();
            for (bi, bae) in baes.iter().enumerate() {
                let bdec = rt.load(&bae.group, "decode")?;
                let nb = bdec.info.inputs[1].shape[0];
                let lb_dim = bdec.info.inputs[1].shape[1];
                let mut lb = vec![0f32; nb * lb_dim];
                for hi in 0..nh_batch {
                    let h = h0 + hi;
                    if h >= total_hb {
                        break;
                    }
                    for j in 0..k {
                        if blocking.is_valid(h, j) {
                            let r = hi * k + j;
                            let c = cursors[bi];
                            lb[r * lb_dim..(r + 1) * lb_dim].copy_from_slice(
                                &lb_all[bi][c..c + lb_dim],
                            );
                            cursors[bi] += lb_dim;
                        }
                    }
                }
                let phi = HostTensor::vec(bae.theta.clone());
                let rhat = bdec
                    .run(&[phi, HostTensor::new(vec![nb, lb_dim], lb)])?
                    .remove(0);
                for i in 0..recon_batch.len() {
                    recon_batch[i] += rhat.data[i];
                }
            }
            blocking.scatter(&mut recon, h0, nh_batch, &recon_batch);
        }
        Ok(recon)
    }

    /// Assemble the self-describing archive from forward-pass outputs.
    /// Shared by the sequential path and the streaming coordinator path
    /// ([`crate::codec::HierCodec::compress_streaming`]).
    pub fn build_archive(
        &self,
        stats: &NormStats,
        tau: f32,
        lh_all: &[f32],
        lb_all: &[Vec<f32>],
        gae: Option<GaeSections>,
    ) -> Archive {
        let qh = Quantizer::new(self.model.bin_hbae.max(0.0));
        let qb = Quantizer::new(self.model.bin_bae.max(0.0));
        let header = vec![
            ("codec", json::s("hier")),
            ("dataset", self.dataset.to_json()),
            ("model", self.model.to_json()),
            ("norm", stats.to_json()),
            ("tau", json::num(tau as f64)),
            (
                "bae_groups",
                Value::Arr(self.baes.iter().map(|b| json::s(b.group.as_str())).collect()),
            ),
            ("hbae_group", json::s(self.hbae.group.as_str())),
            ("gae_blocks", json::num(gae.as_ref().map_or(0, |g| g.n_blocks) as f64)),
        ];
        let mut archive = Archive::new(json::obj(header));
        archive.add_section("HLAT", encode_latents(lh_all, qh));
        archive.add_section("BLAT", encode_latent_groups(lb_all, qb));
        if let Some(g) = gae {
            archive.add_section("GCOF", g.gcof);
            archive.add_section("GIDX", g.gidx);
            archive.add_section("GBAS", g.gbas);
        }
        archive
    }

    /// Compress a field with per-GAE-block ℓ2 bound `tau` (original
    /// units; `tau <= 0` disables GAE). Returns the archive and the final
    /// reconstruction in the **original** domain.
    pub fn compress(&self, field: &Tensor, tau: f32) -> Result<(Archive, Tensor)> {
        ensure!(field.shape() == &self.dataset.dims[..], "field shape mismatch");
        let stats = Normalizer::fit(self.dataset.normalization, field);
        let mut norm = field.clone();
        Normalizer::apply(&stats, &mut norm);

        let qh = Quantizer::new(self.model.bin_hbae.max(0.0));
        let qb = Quantizer::new(self.model.bin_bae.max(0.0));
        let (lh_all, lb_all, mut recon) = self.forward_all(&norm, qh, qb)?;

        // GAE stage (normalized domain; per-block tau from channel scale
        // so the bound transfers exactly to original units)
        let gae = gae_bound_stage(&self.dataset, &stats, tau, &norm, &mut recon)?;
        let archive = self.build_archive(&stats, tau, &lh_all, &lb_all, gae);

        Normalizer::invert(&stats, &mut recon);
        Ok((archive, recon))
    }

    /// Compression statistics for an archive produced by [`Self::compress`].
    pub fn stats(&self, archive: &Archive) -> CompressStats {
        let n_points = self.dataset.total_points();
        let payload = archive.cr_payload_bytes();
        let total = archive.total_bytes();
        CompressStats {
            archive_bytes: total,
            cr_payload_bytes: payload,
            cr: super::metrics::compression_ratio(n_points, payload),
            cr_total: super::metrics::compression_ratio(n_points, total),
            gae_corrected_blocks: 0, // filled by compress_with_stats
            gae_total_coeffs: 0,
            section_sizes: archive.section_sizes(),
        }
    }

    /// Decompress an archive with this compressor's trained parameters,
    /// verifying they match the groups recorded in the archive header.
    /// (The method twin of [`Self::decompress_with_params`] — the codec
    /// trait's symmetric `compress`/`decompress` surface routes here.)
    pub fn decompress(&self, archive: &Archive) -> Result<Tensor> {
        self.verify_groups(archive)?;
        Self::decompress_with_params(&self.rt, archive, &self.hbae, &self.baes)
    }

    /// Region-of-interest decompress: the AE stack still decodes in its
    /// fixed-shape batches (the latent sections are whole-stream entropy
    /// coded), but the GAE correction stage — O(d²) per corrected block —
    /// runs only on the blocks intersecting `region`, and the result is
    /// cropped. Bit-identical to cropping [`Self::decompress`].
    pub fn decompress_region(
        &self,
        archive: &Archive,
        region: &crate::data::Region,
    ) -> Result<Tensor> {
        self.verify_groups(archive)?;
        let full = Self::decompress_inner(
            &self.rt,
            archive,
            &self.hbae,
            &self.baes,
            Some(region),
        )?;
        region.crop(&full)
    }

    fn verify_groups(&self, archive: &Archive) -> Result<()> {
        let want: Vec<&str> = archive
            .header
            .req("bae_groups")?
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .filter_map(|v| v.as_str())
            .collect();
        let have: Vec<&str> = self.baes.iter().map(|b| b.group.as_str()).collect();
        ensure!(want == have, "archive BAE stack {want:?} != loaded {have:?}");
        Ok(())
    }

    /// Decompress an archive given explicitly-loaded parameters (static:
    /// used by [`crate::codec::CodecBuilder::for_archive`] when restoring
    /// from the header's recorded groups).
    pub fn decompress_with_params(
        rt: &Runtime,
        archive: &Archive,
        hbae: &ParamStore,
        baes: &[ParamStore],
    ) -> Result<Tensor> {
        Self::decompress_inner(rt, archive, hbae, baes, None)
    }

    fn decompress_inner(
        rt: &Runtime,
        archive: &Archive,
        hbae: &ParamStore,
        baes: &[ParamStore],
        region: Option<&crate::data::Region>,
    ) -> Result<Tensor> {
        let h = &archive.header;
        let dataset = DatasetConfig::from_json(h.req("dataset")?)?;
        let model = ModelConfig::from_json(h.req("model")?)?;
        let stats = NormStats::from_json(h.req("norm")?)?;
        let tau = h.req("tau")?.as_f64().unwrap_or(0.0) as f32;
        ensure!(
            hbae.group == h.req("hbae_group")?.as_str().unwrap_or(""),
            "hbae group mismatch"
        );
        if let Some(r) = region {
            r.validate_in(&dataset.dims)?;
        }

        let qh = Quantizer::new(model.bin_hbae.max(0.0));
        let qb = Quantizer::new(model.bin_bae.max(0.0));
        let lh_all = decode_latents(archive.section("HLAT")?, qh)?;
        let lb_all = decode_latent_groups(archive.section("BLAT")?, qb, baes.len())?;

        let mut recon = Self::decode_all(rt, &dataset, hbae, baes, &lh_all, &lb_all)?;
        gae_restore_stage_region(&dataset, &stats, tau, archive, &mut recon, region)?;
        Normalizer::invert(&stats, &mut recon);
        Ok(recon)
    }
}
