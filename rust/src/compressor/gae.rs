//! GAE — the PCA-based error-bound guarantee (paper §II-D, Algorithm 1).
//!
//! After the autoencoders produce Ω^R, PCA is fit on the residuals
//! Ω − Ω^R of the whole dataset (each flattened GAE block is one
//! instance). For every block whose ℓ2 residual exceeds its bound τ_b,
//! coefficients `c = Uᵀ(x − x^R)` are sorted by energy and the top-M
//! (quantized) are added back (Eq. 10) until `‖x − x^G‖₂ ≤ τ_b`.
//!
//! Quantization of the selected coefficients uses a per-block bin derived
//! deterministically from the bound, `bin_b = τ_b / (2·√D)`, so the
//! decoder recomputes it from the header — no extra storage — and a full
//! selection always lands within τ_b/4 of the exact residual, making the
//! greedy loop guaranteed to terminate (§7 of DESIGN.md).

use crate::coder::{
    decode_index_sets, encode_index_sets, huffman_decode, huffman_encode, indexset, Quantizer,
};
use crate::config::{DatasetConfig, Normalization};
use crate::data::NormStats;
use crate::engine::Executor;
use crate::linalg::{norm2_f32, Pca};
use crate::tensor::{block_origins, extract_block, scatter_block, Tensor};
use crate::util::parallel::par_map;
use crate::Result;
use anyhow::ensure;

use super::format::Archive;

/// Per-block output of Algorithm 1.
#[derive(Debug, Clone, Default)]
pub struct BlockCorrection {
    /// Selected basis indices, ascending (for the Fig.-3 index codec).
    pub indices: Vec<usize>,
    /// Quantized coefficient codes, aligned with `indices`.
    pub codes: Vec<i32>,
}

/// Output of the GAE pass over all blocks.
#[derive(Debug)]
pub struct GaeOutput {
    pub pca: Pca,
    pub corrections: Vec<BlockCorrection>,
    /// Blocks that needed correction.
    pub corrected_blocks: usize,
    /// Total stored coefficients.
    pub total_coeffs: usize,
}

/// The deterministic coefficient bin for a block bound (shared
/// encoder/decoder convention).
pub fn coeff_bin(tau: f32, d: usize) -> f32 {
    tau / (2.0 * (d as f64).sqrt()) as f32
}

/// Run Algorithm 1. `orig`/`recon` hold `n_blocks` rows of length `d`
/// (flattened GAE blocks); `recon` is corrected **in place** so that every
/// row satisfies `‖orig_row − recon_row‖₂ ≤ taus[row]`.
pub fn gae_apply(
    orig: &[f32],
    recon: &mut [f32],
    d: usize,
    taus: &[f32],
) -> Result<GaeOutput> {
    let _span = crate::obs::stages::GAE_POSTPROCESS.span();
    ensure!(d > 0 && orig.len() == recon.len() && orig.len() % d == 0);
    let n_blocks = orig.len() / d;
    ensure!(taus.len() == n_blocks, "one tau per block");

    // residuals for the PCA fit
    let mut residuals = vec![0f32; orig.len()];
    for i in 0..orig.len() {
        residuals[i] = orig[i] - recon[i];
    }
    let pca = Pca::fit(&residuals, d)?;

    // Algorithm 1 per block, in parallel on the shared executor (scratch
    // arenas hold the per-block coefficient vector); corrections are
    // applied to the recon rows afterwards (each row owned by exactly
    // one result).
    let results: Vec<(BlockCorrection, Vec<f32>)> =
        Executor::global().par_map_scratch(n_blocks, |b, scratch| {
            let x = &orig[b * d..(b + 1) * d];
            let xr = &recon[b * d..(b + 1) * d];
            let tau = taus[b] as f64;
            let r = &residuals[b * d..(b + 1) * d];
            let delta = norm2_f32(r);
            if delta <= tau {
                return (BlockCorrection::default(), Vec::new());
            }
            let q = Quantizer::new(coeff_bin(taus[b], d));
            // project and sort coefficients by energy (Alg. 1 line 6)
            scratch.f64_a.clear();
            scratch.f64_a.resize(d, 0.0);
            let c = &mut scratch.f64_a;
            pca.project(r, c);
            let mut order: Vec<usize> = (0..d).collect();
            order.sort_by(|&i, &j| (c[j] * c[j]).partial_cmp(&(c[i] * c[i])).unwrap());

            // greedy: add quantized coefficients until the bound holds
            let mut corrected: Vec<f32> = xr.to_vec();
            let mut sel_idx: Vec<usize> = Vec::new();
            let mut sel_codes: Vec<i32> = Vec::new();
            let mut m = 0usize;
            loop {
                // extend selection (Alg. 1 lines 9-13); batch a few per exact
                // norm check to amortize the O(d) reconstruction cost
                let add = ((d - m) / 8).clamp(1, 16);
                let mut grew = false;
                for &j in order.iter().skip(m).take(add) {
                    let code = q.code(c[j] as f32);
                    if code == 0 {
                        continue; // contributes nothing after quantization
                    }
                    let cq = q.dequant(code) as f64;
                    for i in 0..d {
                        corrected[i] += (pca.basis[i * d + j] * cq) as f32;
                    }
                    sel_idx.push(j);
                    sel_codes.push(code);
                    grew = true;
                }
                m += add;
                // exact bound check (Alg. 1 line 12)
                let mut sq = 0.0f64;
                for i in 0..d {
                    let e = x[i] as f64 - corrected[i] as f64;
                    sq += e * e;
                }
                if sq.sqrt() <= tau {
                    break;
                }
                if m >= d {
                    // with bin = tau/(2*sqrt(d)) a full selection is within
                    // tau/4 of exact recovery; reaching here means the basis
                    // itself is degenerate — grew guards infinite loops.
                    if !grew {
                        break;
                    }
                }
            }
            // sort selection ascending for the index-set codec
            let mut pairs: Vec<(usize, i32)> =
                sel_idx.into_iter().zip(sel_codes).collect();
            pairs.sort_unstable_by_key(|&(j, _)| j);
            let corr = BlockCorrection {
                indices: pairs.iter().map(|&(j, _)| j).collect(),
                codes: pairs.iter().map(|&(_, code)| code).collect(),
            };
            (corr, corrected)
        });

    let mut corrections = Vec::with_capacity(n_blocks);
    let mut corrected_blocks = 0;
    let mut total_coeffs = 0;
    for (b, (corr, new_row)) in results.into_iter().enumerate() {
        if !new_row.is_empty() {
            recon[b * d..(b + 1) * d].copy_from_slice(&new_row);
            corrected_blocks += 1;
        }
        total_coeffs += corr.codes.len();
        corrections.push(corr);
    }
    Ok(GaeOutput { pca, corrections, corrected_blocks, total_coeffs })
}

/// Decoder side: apply stored corrections to reconstructed rows.
pub fn gae_decode(
    recon: &mut [f32],
    d: usize,
    taus: &[f32],
    pca: &Pca,
    corrections: &[BlockCorrection],
) -> Result<()> {
    let _span = crate::obs::stages::GAE_POSTPROCESS.span();
    ensure!(recon.len() % d == 0);
    let n_blocks = recon.len() / d;
    ensure!(corrections.len() == n_blocks && taus.len() == n_blocks);
    let rows: Vec<Option<Vec<f32>>> = par_map(n_blocks, |b| {
        let corr = &corrections[b];
        if corr.indices.is_empty() {
            return None;
        }
        let q = Quantizer::new(coeff_bin(taus[b], d));
        let mut row = recon[b * d..(b + 1) * d].to_vec();
        let sel: Vec<(usize, f64)> = corr
            .indices
            .iter()
            .zip(&corr.codes)
            .map(|(&j, &code)| (j, q.dequant(code) as f64))
            .collect();
        pca.add_reconstruction(&sel, &mut row);
        Some(row)
    });
    for (b, row) in rows.into_iter().enumerate() {
        if let Some(r) = row {
            recon[b * d..(b + 1) * d].copy_from_slice(&r);
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Archive-level GAE stage, shared by every error-bounded codec
// (hierarchical pipeline, GBAE baseline, streaming coordinator)
// ---------------------------------------------------------------------------

/// Per-GAE-block bounds in the normalized domain: `τ_norm = τ / scale_ch`
/// (the GAE block lies within one channel, so the bound transfers exactly
/// back to original units).
pub fn gae_taus(
    dataset: &DatasetConfig,
    stats: &NormStats,
    tau_orig: f32,
    origins: &[Vec<usize>],
) -> Vec<f32> {
    match dataset.normalization {
        Normalization::ZScore => {
            let s = stats.channels[0].1.max(1e-30);
            vec![(tau_orig as f64 / s) as f32; origins.len()]
        }
        Normalization::PerSpeciesMeanRange => origins
            .iter()
            .map(|o| {
                let ch = o[0].min(stats.channels.len() - 1);
                let s = stats.channels[ch].1.max(1e-30);
                (tau_orig as f64 / s) as f32
            })
            .collect(),
    }
}

/// Encoded GAE sections ready to append to an [`Archive`].
#[derive(Debug)]
pub struct GaeSections {
    pub gcof: Vec<u8>,
    pub gidx: Vec<u8>,
    pub gbas: Vec<u8>,
    pub n_blocks: usize,
    pub corrected_blocks: usize,
    pub total_coeffs: usize,
}

/// Run Algorithm 1 over a normalized field and its reconstruction:
/// corrects `recon` **in place** so every GAE block meets the ℓ2 bound
/// `tau` (original units), and returns the entropy-coded sections.
/// `tau <= 0` disables the stage (`None`).
pub fn gae_bound_stage(
    dataset: &DatasetConfig,
    stats: &NormStats,
    tau: f32,
    norm: &Tensor,
    recon: &mut Tensor,
) -> Result<Option<GaeSections>> {
    if tau <= 0.0 {
        return Ok(None);
    }
    let d = dataset.gae_block_len();
    let origins = block_origins(&dataset.dims, &dataset.gae_block);
    let taus = gae_taus(dataset, stats, tau, &origins);
    let mut orig_rows = vec![0f32; origins.len() * d];
    let mut recon_rows = vec![0f32; origins.len() * d];
    for (bi, o) in origins.iter().enumerate() {
        extract_block(norm, o, &dataset.gae_block, &mut orig_rows[bi * d..(bi + 1) * d]);
        extract_block(recon, o, &dataset.gae_block, &mut recon_rows[bi * d..(bi + 1) * d]);
    }
    let out = gae_apply(&orig_rows, &mut recon_rows, d, &taus)?;
    for (bi, o) in origins.iter().enumerate() {
        scatter_block(recon, o, &dataset.gae_block, &recon_rows[bi * d..(bi + 1) * d]);
    }
    let codes: Vec<i32> =
        out.corrections.iter().flat_map(|c| c.codes.iter().copied()).collect();
    let sets: Vec<Vec<usize>> = out.corrections.iter().map(|c| c.indices.clone()).collect();
    Ok(Some(GaeSections {
        gcof: huffman_encode(&codes),
        gidx: encode_index_sets(&sets, d)?,
        gbas: out.pca.basis_f32_bytes(),
        n_blocks: origins.len(),
        corrected_blocks: out.corrected_blocks,
        total_coeffs: out.total_coeffs,
    }))
}

/// Decoder side of [`gae_bound_stage`]: read the GCOF/GIDX/GBAS sections
/// and apply the stored corrections to `recon` (normalized domain) in
/// place. A `tau <= 0` archive or one without GAE sections is a no-op.
pub fn gae_restore_stage(
    dataset: &DatasetConfig,
    stats: &NormStats,
    tau: f32,
    archive: &Archive,
    recon: &mut Tensor,
) -> Result<()> {
    gae_restore_stage_region(dataset, stats, tau, archive, recon, None)
}

/// Region-of-interest variant of [`gae_restore_stage`]: when `region` is
/// set, only the GAE blocks intersecting it are corrected — blocks the
/// caller will crop away skip the O(d²) coefficient reconstruction. The
/// GIDX index sets decode fully either way (they carry the per-block
/// coefficient extents into GCOF, so the cursor walk cannot be skipped),
/// and the corrected values inside the region are bit-identical to a
/// full restore.
pub fn gae_restore_stage_region(
    dataset: &DatasetConfig,
    stats: &NormStats,
    tau: f32,
    archive: &Archive,
    recon: &mut Tensor,
    region: Option<&crate::data::Region>,
) -> Result<()> {
    if tau <= 0.0 || !archive.has_section("GBAS") {
        return Ok(());
    }
    let d = dataset.gae_block_len();
    let origins = block_origins(&dataset.dims, &dataset.gae_block);
    let taus = gae_taus(dataset, stats, tau, &origins);
    let pca = Pca::from_f32_bytes(archive.section("GBAS")?, d)?;
    let sets = decode_index_sets(
        archive.section("GIDX")?,
        indexset::max_raw_size(origins.len(), d),
    )?;
    ensure!(sets.len() == origins.len(), "GIDX count mismatch");
    let (codes, _) = huffman_decode(archive.section("GCOF")?)?;
    let mut corrections = Vec::with_capacity(sets.len());
    let mut cur = 0usize;
    for set in sets {
        let n = set.len();
        ensure!(cur + n <= codes.len(), "GCOF underrun");
        corrections.push(BlockCorrection {
            indices: set,
            codes: codes[cur..cur + n].to_vec(),
        });
        cur += n;
    }
    // blocks to restore: all of them, or only the region's
    let keep: Vec<usize> = match region {
        Some(r) => {
            r.validate_in(&dataset.dims)?;
            (0..origins.len())
                .filter(|&bi| r.intersects(&origins[bi], &dataset.gae_block))
                .collect()
        }
        None => (0..origins.len()).collect(),
    };
    let mut rows = vec![0f32; keep.len() * d];
    for (ri, &bi) in keep.iter().enumerate() {
        extract_block(
            recon,
            &origins[bi],
            &dataset.gae_block,
            &mut rows[ri * d..(ri + 1) * d],
        );
    }
    if keep.len() == origins.len() {
        // full restore: use the decoded corrections as-is (no copies)
        gae_decode(&mut rows, d, &taus, &pca, &corrections)?;
    } else {
        let kept_taus: Vec<f32> = keep.iter().map(|&bi| taus[bi]).collect();
        let kept_corr: Vec<BlockCorrection> =
            keep.iter().map(|&bi| corrections[bi].clone()).collect();
        gae_decode(&mut rows, d, &kept_taus, &pca, &kept_corr)?;
    }
    for (ri, &bi) in keep.iter().enumerate() {
        scatter_block(
            recon,
            &origins[bi],
            &dataset.gae_block,
            &rows[ri * d..(ri + 1) * d],
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn make_case(
        n_blocks: usize,
        d: usize,
        resid_scale: f64,
        seed: u64,
    ) -> (Vec<f32>, Vec<f32>) {
        // orig = recon + structured residual (low-rank + noise)
        let mut rng = Rng::new(seed);
        let rank = 3.min(d);
        let dirs: Vec<f64> = (0..rank * d).map(|_| rng.normal()).collect();
        let mut orig = vec![0f32; n_blocks * d];
        let mut recon = vec![0f32; n_blocks * d];
        for b in 0..n_blocks {
            for i in 0..d {
                recon[b * d + i] = rng.normal() as f32;
            }
            let mut r = vec![0.0f64; d];
            for k in 0..rank {
                let w = rng.normal() * resid_scale / (k + 1) as f64;
                for i in 0..d {
                    r[i] += w * dirs[k * d + i];
                }
            }
            for i in 0..d {
                orig[b * d + i] =
                    recon[b * d + i] + r[i] as f32 + (0.02 * resid_scale * rng.normal()) as f32;
            }
        }
        (orig, recon)
    }

    fn check_bound(orig: &[f32], recon: &[f32], d: usize, taus: &[f32]) {
        for b in 0..taus.len() {
            let mut sq = 0.0f64;
            for i in 0..d {
                let e = (orig[b * d + i] - recon[b * d + i]) as f64;
                sq += e * e;
            }
            assert!(
                sq.sqrt() <= taus[b] as f64 * (1.0 + 1e-5),
                "block {b}: {} > {}",
                sq.sqrt(),
                taus[b]
            );
        }
    }

    #[test]
    fn guarantees_bound_for_every_block() {
        let d = 40;
        let (orig, mut recon) = make_case(64, d, 1.0, 5);
        let taus = vec![0.5f32; 64];
        let out = gae_apply(&orig, &mut recon, d, &taus).unwrap();
        check_bound(&orig, &recon, d, &taus);
        assert!(out.corrected_blocks > 0, "case should need correction");
    }

    #[test]
    fn tight_bound_still_guaranteed() {
        let d = 24;
        let (orig, mut recon) = make_case(32, d, 2.0, 9);
        let taus = vec![0.01f32; 32];
        gae_apply(&orig, &mut recon, d, &taus).unwrap();
        check_bound(&orig, &recon, d, &taus);
    }

    #[test]
    fn blocks_within_bound_untouched() {
        let d = 16;
        let (orig, recon0) = make_case(8, d, 0.001, 3);
        let mut recon = recon0.clone();
        let taus = vec![10.0f32; 8];
        let out = gae_apply(&orig, &mut recon, d, &taus).unwrap();
        assert_eq!(out.corrected_blocks, 0);
        assert_eq!(recon, recon0);
        assert!(out.corrections.iter().all(|c| c.indices.is_empty()));
    }

    #[test]
    fn decode_reproduces_encoder_correction() {
        let d = 32;
        let (orig, recon0) = make_case(40, d, 1.5, 11);
        let mut enc_recon = recon0.clone();
        let taus = vec![0.3f32; 40];
        let out = gae_apply(&orig, &mut enc_recon, d, &taus).unwrap();
        let mut dec_recon = recon0.clone();
        gae_decode(&mut dec_recon, d, &taus, &out.pca, &out.corrections).unwrap();
        for (a, b) in enc_recon.iter().zip(&dec_recon) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn decode_with_f32_basis_still_bounded() {
        // the archive stores the basis as f32 — decode must match encode
        let d = 20;
        let (orig, recon0) = make_case(30, d, 1.0, 13);
        let mut enc_recon = recon0.clone();
        let taus = vec![0.2f32; 30];
        let out = gae_apply(&orig, &mut enc_recon, d, &taus).unwrap();
        let pca32 = Pca::from_f32_bytes(&out.pca.basis_f32_bytes(), d).unwrap();
        let mut dec_recon = recon0.clone();
        gae_decode(&mut dec_recon, d, &taus, &pca32, &out.corrections).unwrap();
        check_bound(&orig, &dec_recon, d, &taus);
    }

    #[test]
    fn per_block_taus_respected() {
        let d = 16;
        let (orig, mut recon) = make_case(20, d, 1.0, 17);
        let taus: Vec<f32> = (0..20).map(|b| 0.05 + 0.1 * b as f32).collect();
        gae_apply(&orig, &mut recon, d, &taus).unwrap();
        check_bound(&orig, &recon, d, &taus);
    }

    #[test]
    fn property_random_cases_never_violate_bound() {
        // in-repo property harness: sweep sizes/scales/bounds
        let mut rng = Rng::new(99);
        for case in 0..15 {
            let d = [4, 8, 25, 80][case % 4];
            let n = 8 + rng.below(24);
            let scale = [0.1, 1.0, 10.0][case % 3];
            let (orig, mut recon) = make_case(n, d, scale, 1000 + case as u64);
            let tau = (0.02 + rng.uniform() * scale) as f32;
            let taus = vec![tau; n];
            gae_apply(&orig, &mut recon, d, &taus).unwrap();
            check_bound(&orig, &recon, d, &taus);
        }
    }

    #[test]
    fn gae_taus_scale_per_species() {
        use crate::config::{dataset_preset, DatasetKind, Scale};
        let d = dataset_preset(DatasetKind::S3d, Scale::Smoke);
        let stats = NormStats {
            kind: Normalization::PerSpeciesMeanRange,
            channels: (0..16).map(|i| (0.0, 1.0 + i as f64)).collect(),
        };
        let origins = block_origins(&d.dims, &d.gae_block);
        let taus = gae_taus(&d, &stats, 2.0, &origins);
        // block for species 0 has scale 1 -> tau 2; species 1 -> tau 1
        let per_species = origins.len() / 16;
        assert!((taus[0] - 2.0).abs() < 1e-6);
        assert!((taus[per_species] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn bound_and_restore_stages_round_trip() {
        use crate::config::{dataset_preset, DatasetKind, Scale};
        use crate::util::json;
        let cfg = dataset_preset(DatasetKind::E3sm, Scale::Smoke);
        let norm = crate::data::generate(&cfg); // any field works as "normalized"
        let stats = NormStats { kind: Normalization::ZScore, channels: vec![(0.0, 1.0)] };
        // a lossy reconstruction: smooth the field
        let mut recon = norm.clone();
        for v in recon.data_mut() {
            *v *= 0.97;
        }
        let base = recon.clone();
        let tau = 0.5f32;
        let sections = gae_bound_stage(&cfg, &stats, tau, &norm, &mut recon)
            .unwrap()
            .expect("stage should run");
        assert!(sections.corrected_blocks > 0);
        let mut archive = Archive::new(json::obj(vec![]));
        archive.add_section("GCOF", sections.gcof);
        archive.add_section("GIDX", sections.gidx);
        archive.add_section("GBAS", sections.gbas);
        let mut restored = base.clone();
        gae_restore_stage(&cfg, &stats, tau, &archive, &mut restored).unwrap();
        for (a, b) in recon.data().iter().zip(restored.data()) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
        // every block within tau
        let d = cfg.gae_block_len();
        let origins = block_origins(&cfg.dims, &cfg.gae_block);
        let (mut x, mut y) = (vec![0f32; d], vec![0f32; d]);
        for o in &origins {
            extract_block(&norm, o, &cfg.gae_block, &mut x);
            extract_block(&restored, o, &cfg.gae_block, &mut y);
            let diff: Vec<f32> = x.iter().zip(&y).map(|(&a, &b)| a - b).collect();
            assert!(norm2_f32(&diff) <= tau as f64 * 1.001);
        }
        // tau = 0 is a no-op on both sides
        let mut untouched = base.clone();
        assert!(gae_bound_stage(&cfg, &stats, 0.0, &norm, &mut untouched).unwrap().is_none());
        assert_eq!(untouched.data(), base.data());
    }

    #[test]
    fn region_restore_matches_full_restore_inside_region() {
        use crate::config::{dataset_preset, DatasetKind, Scale};
        use crate::data::Region;
        use crate::util::json;
        let cfg = dataset_preset(DatasetKind::E3sm, Scale::Smoke); // dims [24,32,32]
        let norm = crate::data::generate(&cfg);
        let stats = NormStats { kind: Normalization::ZScore, channels: vec![(0.0, 1.0)] };
        let mut recon = norm.clone();
        for v in recon.data_mut() {
            *v *= 0.97;
        }
        let base = recon.clone();
        let tau = 0.5f32;
        let sections = gae_bound_stage(&cfg, &stats, tau, &norm, &mut recon)
            .unwrap()
            .expect("stage should run");
        assert!(sections.corrected_blocks > 0);
        let mut archive = Archive::new(json::obj(vec![]));
        archive.add_section("GCOF", sections.gcof);
        archive.add_section("GIDX", sections.gidx);
        archive.add_section("GBAS", sections.gbas);
        let mut full = base.clone();
        gae_restore_stage(&cfg, &stats, tau, &archive, &mut full).unwrap();
        let region = Region::parse("3:17,0:32,8:24").unwrap();
        let mut partial = base.clone();
        gae_restore_stage_region(&cfg, &stats, tau, &archive, &mut partial, Some(&region))
            .unwrap();
        // bit-identical inside the region
        assert_eq!(
            region.crop(&partial).unwrap().data(),
            region.crop(&full).unwrap().data()
        );
        // and blocks fully outside were genuinely skipped
        let outside = Region::parse("20:24,0:32,0:8").unwrap();
        assert_eq!(
            outside.crop(&partial).unwrap().data(),
            outside.crop(&base).unwrap().data()
        );
    }

    #[test]
    fn stored_coeffs_grow_as_tau_shrinks() {
        let d = 32;
        let (orig, recon0) = make_case(50, d, 1.0, 21);
        let mut loose = recon0.clone();
        let mut tight = recon0.clone();
        let o1 = gae_apply(&orig, &mut loose, d, &vec![1.0f32; 50]).unwrap();
        let o2 = gae_apply(&orig, &mut tight, d, &vec![0.05f32; 50]).unwrap();
        assert!(
            o2.total_coeffs > o1.total_coeffs,
            "{} !> {}",
            o2.total_coeffs,
            o1.total_coeffs
        );
    }
}
