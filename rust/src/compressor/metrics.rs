//! Evaluation metrics (paper §III-A/B).

use crate::tensor::Tensor;

/// NRMSE (Eq. 11): `sqrt(||Ω − Ω^G||² / N) / (max(Ω) − min(Ω))`.
pub fn nrmse(orig: &Tensor, recon: &Tensor) -> f64 {
    assert_eq!(orig.shape(), recon.shape());
    let n = orig.len() as f64;
    let sq: f64 = orig
        .data()
        .iter()
        .zip(recon.data())
        .map(|(&a, &b)| {
            let d = a as f64 - b as f64;
            d * d
        })
        .sum();
    let range = (orig.range() as f64).max(1e-30);
    (sq / n).sqrt() / range
}

/// Per-channel NRMSE along the first axis (Fig. 9: one value per species).
pub fn nrmse_per_channel(orig: &Tensor, recon: &Tensor) -> Vec<f64> {
    assert_eq!(orig.shape(), recon.shape());
    let channels = orig.shape()[0];
    let per = orig.len() / channels;
    (0..channels)
        .map(|c| {
            let a = &orig.data()[c * per..(c + 1) * per];
            let b = &recon.data()[c * per..(c + 1) * per];
            let sq: f64 = a
                .iter()
                .zip(b)
                .map(|(&x, &y)| {
                    let d = x as f64 - y as f64;
                    d * d
                })
                .sum();
            let lo = a.iter().copied().fold(f32::INFINITY, f32::min) as f64;
            let hi = a.iter().copied().fold(f32::NEG_INFINITY, f32::max) as f64;
            (sq / per as f64).sqrt() / (hi - lo).max(1e-30)
        })
        .collect()
}

/// Mean of per-channel NRMSE (the paper's reported S3D metric).
pub fn mean_channel_nrmse(orig: &Tensor, recon: &Tensor) -> f64 {
    let per = nrmse_per_channel(orig, recon);
    per.iter().sum::<f64>() / per.len() as f64
}

/// Compression ratio (Eq. 12): raw f32 bytes / compressed bytes.
pub fn compression_ratio(n_points: usize, compressed_bytes: usize) -> f64 {
    (n_points * 4) as f64 / compressed_bytes.max(1) as f64
}

/// PSNR in dB relative to the data range.
pub fn psnr(orig: &Tensor, recon: &Tensor) -> f64 {
    let e = nrmse(orig, recon);
    -20.0 * e.max(1e-30).log10()
}

/// Maximum per-point relative error |a-b| / range (Fig. 8's histogram is
/// built from these values).
pub fn relative_point_errors(orig: &Tensor, recon: &Tensor) -> Vec<f64> {
    let range = (orig.range() as f64).max(1e-30);
    orig.data()
        .iter()
        .zip(recon.data())
        .map(|(&a, &b)| ((a as f64 - b as f64) / range).abs())
        .collect()
}

/// Histogram of values in log10 space between `lo` and `hi` (Fig. 8).
pub fn log_histogram(values: &[f64], lo: f64, hi: f64, bins: usize) -> Vec<(f64, usize)> {
    assert!(lo > 0.0 && hi > lo && bins > 0);
    let (llo, lhi) = (lo.log10(), hi.log10());
    let mut counts = vec![0usize; bins];
    for &v in values {
        if v <= 0.0 {
            continue;
        }
        let f = ((v.log10() - llo) / (lhi - llo) * bins as f64).floor();
        let idx = (f.max(0.0) as usize).min(bins - 1);
        counts[idx] += 1;
    }
    (0..bins)
        .map(|i| {
            let center = 10f64.powf(llo + (i as f64 + 0.5) / bins as f64 * (lhi - llo));
            (center, counts[i])
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(v: Vec<f32>) -> Tensor {
        Tensor::from_vec(v)
    }

    #[test]
    fn identical_data_zero_nrmse() {
        let a = t(vec![1.0, 2.0, 3.0]);
        assert_eq!(nrmse(&a, &a.clone()), 0.0);
        assert!(psnr(&a, &a.clone()) > 200.0);
    }

    #[test]
    fn nrmse_matches_hand_computation() {
        let a = t(vec![0.0, 2.0]); // range 2
        let b = t(vec![1.0, 2.0]); // mse = 0.5, rmse = sqrt(0.5)
        let e = nrmse(&a, &b);
        assert!((e - (0.5f64).sqrt() / 2.0).abs() < 1e-12);
    }

    #[test]
    fn per_channel_isolates_errors() {
        let a = Tensor::new(vec![2, 2], vec![0.0, 1.0, 0.0, 1.0]);
        let b = Tensor::new(vec![2, 2], vec![0.0, 1.0, 0.5, 1.0]);
        let per = nrmse_per_channel(&a, &b);
        assert_eq!(per[0], 0.0);
        assert!(per[1] > 0.0);
        assert!((mean_channel_nrmse(&a, &b) - per[1] / 2.0).abs() < 1e-12);
    }

    #[test]
    fn cr_accounting() {
        assert_eq!(compression_ratio(100, 4), 100.0);
        assert_eq!(compression_ratio(100, 400), 1.0);
    }

    #[test]
    fn log_histogram_counts_everything_in_range() {
        let vals = vec![1e-5, 1e-4, 1e-3, 5e-4, 2e-5];
        let h = log_histogram(&vals, 1e-6, 1e-2, 8);
        let total: usize = h.iter().map(|&(_, c)| c).sum();
        assert_eq!(total, 5);
        // out-of-range clamps to edge bins rather than dropping
        let h2 = log_histogram(&[1e-9, 1.0], 1e-6, 1e-2, 8);
        let total2: usize = h2.iter().map(|&(_, c)| c).sum();
        assert_eq!(total2, 2);
    }
}
