//! Compressed archive container (DESIGN.md §5).
//!
//! Layout (little-endian), shared by both container versions:
//! ```text
//!   "ARDC" | u16 version | u32 header_len | header JSON (UTF-8) |
//!   u32 n_sections | n x ( [u8;4] tag | u64 len | bytes )
//! ```
//!
//! **Version 1** is a single-field archive. Sections used by the codecs:
//!   HLAT — HBAE latent codes (Huffman)        } counted in CR
//!   BLAT — BAE latent codes (Huffman)         } counted in CR
//!   GLAT — GBAE primary latent codes          } counted in CR
//!   GCLT — GBAE corrector latent codes        } counted in CR
//!   GCOF — GAE coefficient codes (Huffman)    } counted in CR
//!   GIDX — GAE index sets (Fig. 3 + LZSS)     } counted in CR
//!   SZ3B — SZ3-like whole-stream payload      } counted in CR
//!   ZFPB — ZFP-like whole-stream payload      } counted in CR
//!   ADPB — adaptive mixed-codec tiled payload } counted in CR
//!   GBAS — PCA basis, f32 (amortized like model params — the paper's CR
//!          counts latents + coefficients + index info; §III-C)
//!
//! **Version 2** is the multi-field *dataset container* produced by
//! [`crate::engine::CodecExt::compress_set`]: section `F000`..`F999`
//! holds field *i*'s complete single-field archive, and the header
//! carries the field-name list (`fields`) plus the shared per-field
//! stats dictionary (`stats`). CR accounting recurses into the embedded
//! field archives — payload sections only, headers excluded — so
//! multi-field ratios match the paper's accounting.
//!
//! **Version 3** is a single-field archive whose payload section is a
//! concatenation of independently-decodable per-block streams, described
//! by a [`BlockIndex`] in section `BIDX` (block id → byte offset/length).
//! [`crate::codec::Codec::decompress_region`] uses the index to decode
//! only the blocks intersecting a requested hyper-rectangle. v3 bumps
//! the container version because the payload *layout* changed — a v1
//! reader must not misparse a chunked stream as a whole stream. The
//! index carries an optional per-block *codec-id* trailer (index minor
//! version 1, see [`BlockIndex`]) so a mixed-codec payload (`ADPB`,
//! written by the adaptive codec) records which stream format each
//! block used; homogeneous archives omit it and stay byte-identical to
//! pre-extension writers.
//!
//! **Version 4** is the *temporal stream* container — a different magic
//! (`TSTR`, not `ARDC`) because its framing is append-only rather than
//! section-counted: a header, then a sequence of self-delimiting records
//! (`KSTP` keyframe step / `RSTP` residual step, each holding a complete
//! single-field v1/v3 archive), then a `TIDX` timeline-index record and
//! a fixed 12-byte footer locating it. A crashed or still-growing stream
//! simply lacks the footer; readers recover by scanning complete
//! records. The writer/reader live in [`crate::stream`]; this module
//! owns the byte-level framing so all container formats stay in one
//! place.
//!
//! Unknown section tags are preserved verbatim by the parser, so newer
//! writers stay readable by older readers (forward compatibility), and
//! v1/v2 archives parse and decompress unchanged (backward
//! compatibility, pinned by the golden corpus in `tests/golden/`).
//!
//! **Entropy-stream framing inside payload sections** (`SZ3B` / `ZFPB`):
//! the quantized code streams dispatch on a one-byte magic —
//! 0xB3/0xB4 plain LZSS'd Huffman (the only mode pre-overhaul archives
//! contain), 0xB5 zero-run, 0xB6 constant (see
//! [`crate::coder::lossless`]). The new magics appear only in newly
//! written payloads; every committed golden decodes byte-identically
//! through the 0xB3/0xB4 path.

use crate::util::crc32c;
use crate::util::json::Value;
use crate::Result;
use anyhow::{bail, ensure};

/// Typed integrity failure: checksummed bytes did not verify, or framing
/// carries bytes no writer of this format produces. Kept as a concrete
/// `std::error::Error` (not just an anyhow message) so callers can react
/// to corruption specifically — the serve layer downcasts it to answer
/// HTTP 422 instead of a generic 400/500, and `cli verify` counts it.
/// Constructing one increments `attn_corruption_detected_total`.
#[derive(Debug, Clone)]
pub struct Corruption(pub String);

impl Corruption {
    pub fn new(msg: impl Into<String>) -> Self {
        crate::obs::corruption_detected();
        Self(msg.into())
    }
}

impl std::fmt::Display for Corruption {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "corruption detected: {}", self.0)
    }
}

impl std::error::Error for Corruption {}

/// Shorthand: a [`Corruption`] wrapped as `anyhow::Error` (the root type
/// survives for `downcast_ref::<Corruption>()`).
pub(crate) fn corrupt(msg: impl Into<String>) -> anyhow::Error {
    anyhow::Error::from(Corruption::new(msg))
}

/// Is this error a detected integrity failure (as opposed to malformed
/// input, I/O trouble, or a plain bug)?
pub fn is_corruption(err: &anyhow::Error) -> bool {
    err.is::<Corruption>()
}

const MAGIC: &[u8; 4] = b"ARDC";
/// Single-field archive (the seed format — whole-stream payloads).
pub const VERSION_V1: u16 = 1;
/// Multi-field dataset container (engine `compress_set`).
pub const VERSION_V2: u16 = 2;
/// Single-field archive with a block index (`BIDX`): the payload is a
/// concatenation of independently-decodable per-block streams, so a
/// region of interest decodes without touching the rest of the payload.
pub const VERSION_V3: u16 = 3;

/// Temporal stream container (`TSTR` magic, append-only record framing —
/// see [`crate::stream`]). Not an `ARDC` section container: the version
/// number continues the series so headers and docs can name it "v4".
pub const VERSION_V4: u16 = 4;

/// Section tag of the v3 block index.
pub const BLOCK_INDEX_TAG: &str = "BIDX";

// ---------------------------------------------------------------------------
// XSUM integrity trailer (optional, declared in the header).
//
// A checksummed archive appends after the section container:
// ```text
//   "XSUM" | u8 ver=1 | u32 n | n x ( [u8;4] tag | u32 crc32c(section) )
//   | u32 file_crc | "XEND"
// ```
// where `file_crc` covers every byte before itself (container + trailer
// prefix). Presence is declared by the header key `"xsum": 1`, written
// only at serialization time by `to_bytes_checked` — so an in-memory
// `Archive` never carries the key, `to_bytes()` stays byte-identical to
// every pre-trailer writer, and the legacy corpus parses unchanged. The
// header declaration (rather than sniffing the file tail) is what makes
// single-byte flips airtight: a flip that grows a section length to
// swallow the trailer still leaves the declaration, and the then-missing
// trailer is corruption; a flip that garbles the declaration makes the
// trailer look like trailing garbage, which strict parsing rejects.
// ---------------------------------------------------------------------------

/// Header key declaring an XSUM trailer follows the section container.
pub const XSUM_HEADER_KEY: &str = "xsum";
const XSUM_MAGIC: &[u8; 4] = b"XSUM";
const XSUM_END: &[u8; 4] = b"XEND";
const XSUM_VERSION: u8 = 1;

/// Exact byte length of an XSUM trailer over `n` sections.
pub fn xsum_trailer_len(n: usize) -> usize {
    4 + 1 + 4 + 8 * n + 4 + 4
}

fn append_xsum_trailer(out: &mut Vec<u8>, sections: &[(String, Vec<u8>)]) {
    out.extend_from_slice(XSUM_MAGIC);
    out.push(XSUM_VERSION);
    out.extend_from_slice(&(sections.len() as u32).to_le_bytes());
    for (tag, bytes) in sections {
        out.extend_from_slice(tag.as_bytes());
        out.extend_from_slice(&crc32c::crc32c(bytes).to_le_bytes());
    }
    let file_crc = crc32c::crc32c(out);
    out.extend_from_slice(&file_crc.to_le_bytes());
    out.extend_from_slice(XSUM_END);
}

/// Verify the XSUM trailer a header declared. `container_end` is the
/// first byte after the section container; `sections` are the parsed
/// sections in file order. Every failure is a typed [`Corruption`].
fn verify_xsum_trailer(
    bytes: &[u8],
    container_end: usize,
    sections: &[(String, Vec<u8>)],
) -> Result<()> {
    let n = sections.len();
    if bytes.len() != container_end + xsum_trailer_len(n) {
        return Err(corrupt(format!(
            "header declares checksums but the XSUM trailer is missing or mis-sized \
             ({} bytes after the container, trailer needs {})",
            bytes.len().saturating_sub(container_end),
            xsum_trailer_len(n)
        )));
    }
    // The whole-file CRC is verified first: it covers the header, every
    // section length, and the trailer itself, so any single flipped byte
    // anywhere in the file fails here even when the structural fields
    // still happen to parse.
    let l = bytes.len();
    let stored = u32::from_le_bytes(bytes[l - 8..l - 4].try_into().unwrap());
    if crc32c::crc32c(&bytes[..l - 8]) != stored {
        return Err(corrupt("archive file checksum mismatch"));
    }
    if &bytes[l - 4..] != XSUM_END {
        return Err(corrupt("XSUM trailer end magic missing"));
    }
    let t = &bytes[container_end..];
    if &t[0..4] != XSUM_MAGIC {
        return Err(corrupt("XSUM trailer magic missing"));
    }
    if t[4] != XSUM_VERSION {
        return Err(corrupt(format!("XSUM trailer version {} unsupported", t[4])));
    }
    let tn = u32::from_le_bytes(t[5..9].try_into().unwrap()) as usize;
    if tn != n {
        return Err(corrupt(format!("XSUM trailer covers {tn} of {n} sections")));
    }
    let mut p = 9usize;
    for (tag, data) in sections {
        if &t[p..p + 4] != tag.as_bytes() {
            return Err(corrupt(format!("XSUM trailer tag order mismatch at {tag}")));
        }
        let crc = u32::from_le_bytes(t[p + 4..p + 8].try_into().unwrap());
        if crc32c::crc32c(data) != crc {
            return Err(corrupt(format!("section {tag} checksum mismatch")));
        }
        p += 8;
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// v4 temporal-stream framing (magic TSTR): header + self-delimiting
// records + footer. Byte-level only — the timeline index, writer, and
// reader live in `crate::stream`.
// ---------------------------------------------------------------------------

/// Magic of the v4 temporal stream container.
pub const STREAM_MAGIC: &[u8; 4] = b"TSTR";
/// Record tag: a keyframe step (payload = complete v1/v3 archive of the
/// absolute frame).
pub const STREAM_KEY_TAG: &[u8; 4] = b"KSTP";
/// Record tag: a residual step (payload = complete v1/v3 archive of the
/// temporal residual against the previous *reconstructed* frame).
pub const STREAM_RES_TAG: &[u8; 4] = b"RSTP";
/// Record tag: the timeline index written by `finish()`.
pub const STREAM_TIDX_TAG: &[u8; 4] = b"TIDX";
/// Footer magic: the last 12 bytes of a finished stream are
/// `u64 tidx_record_offset | "TEND"`.
pub const STREAM_END_MAGIC: &[u8; 4] = b"TEND";

/// Serialize the v4 stream header:
/// `"TSTR" | u16 version | u32 header_len | header JSON`.
pub fn stream_header_bytes(header: &Value) -> Vec<u8> {
    let json = header.to_string_compact().into_bytes();
    let mut out = Vec::with_capacity(10 + json.len());
    out.extend_from_slice(STREAM_MAGIC);
    out.extend_from_slice(&VERSION_V4.to_le_bytes());
    out.extend_from_slice(&(json.len() as u32).to_le_bytes());
    out.extend_from_slice(&json);
    out
}

/// Parse a v4 stream header, returning `(header, records_start_offset)`.
/// Untrusted input: truncation and bad magic/version are clean errors.
pub fn parse_stream_header(bytes: &[u8]) -> Result<(Value, usize)> {
    ensure!(bytes.len() >= 10, "stream truncated (no header)");
    if &bytes[0..4] != STREAM_MAGIC {
        if &bytes[0..4] == MAGIC {
            bail!("this is an ARDC archive, not a TSTR stream — use Archive::from_bytes");
        }
        bail!("not a TSTR temporal stream");
    }
    let version = u16::from_le_bytes(bytes[4..6].try_into().unwrap());
    ensure!(version == VERSION_V4, "unsupported stream version {version}");
    let hlen = u32::from_le_bytes(bytes[6..10].try_into().unwrap()) as usize;
    let end = 10usize
        .checked_add(hlen)
        .ok_or_else(|| anyhow::anyhow!("stream header length overflow"))?;
    ensure!(bytes.len() >= end, "stream header truncated");
    let header = Value::parse(std::str::from_utf8(&bytes[10..end])?)?;
    Ok((header, end))
}

/// Frame one stream record: `tag | u64 len | payload`.
pub fn stream_record_bytes(tag: &[u8; 4], payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(12 + payload.len());
    out.extend_from_slice(tag);
    out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    out.extend_from_slice(payload);
    out
}

/// Parse the record at `off`, returning `(tag, payload_offset,
/// payload_len, next_record_offset)`. Errors on truncation or a length
/// that overflows the buffer — the recovery scan stops at the first
/// incomplete record.
pub fn parse_stream_record(bytes: &[u8], off: usize) -> Result<([u8; 4], usize, usize, usize)> {
    ensure!(bytes.len() >= off + 12, "stream record header truncated");
    let tag: [u8; 4] = bytes[off..off + 4].try_into().unwrap();
    let len = u64::from_le_bytes(bytes[off + 4..off + 12].try_into().unwrap());
    let len = usize::try_from(len)
        .map_err(|_| anyhow::anyhow!("stream record length overflow"))?;
    let payload = off + 12;
    let next = payload
        .checked_add(len)
        .ok_or_else(|| anyhow::anyhow!("stream record length overflow"))?;
    ensure!(bytes.len() >= next, "stream record payload truncated");
    Ok((tag, payload, len, next))
}

/// Record tag of the stream integrity record: written right after the
/// header of a checked (`"xsum": 1`) stream, its payload is the u32
/// CRC32C of the header bytes (magic through header JSON).
pub const STREAM_XSUM_TAG: &[u8; 4] = b"XSUM";

/// Frame one *checked* stream record: `tag | u64 len | payload |
/// u32 crc32c(tag|len|payload)`. Checked streams (header `"xsum": 1`)
/// use this for every record; legacy streams keep the 12-byte framing.
pub fn stream_record_bytes_checked(tag: &[u8; 4], payload: &[u8]) -> Vec<u8> {
    let mut out = stream_record_bytes(tag, payload);
    let crc = crc32c::crc32c(&out);
    out.extend_from_slice(&crc.to_le_bytes());
    out
}

/// Parse and verify the checked record at `off`, returning `(tag,
/// payload_offset, payload_len, next_record_offset)` with `next` past
/// the trailing CRC. Truncation is a plain error (the recovery scan
/// treats it as a torn tail); a present-but-wrong CRC is a typed
/// [`Corruption`].
pub fn parse_stream_record_checked(
    bytes: &[u8],
    off: usize,
) -> Result<([u8; 4], usize, usize, usize)> {
    let (tag, payload, len, body_end) = parse_stream_record(bytes, off)?;
    let next = body_end
        .checked_add(4)
        .ok_or_else(|| anyhow::anyhow!("stream record length overflow"))?;
    ensure!(bytes.len() >= next, "stream record checksum truncated");
    let stored = u32::from_le_bytes(bytes[body_end..next].try_into().unwrap());
    if crc32c::crc32c(&bytes[off..body_end]) != stored {
        return Err(corrupt(format!(
            "stream record {} at byte {off} failed its checksum",
            String::from_utf8_lossy(&tag)
        )));
    }
    Ok((tag, payload, len, next))
}

/// Sections whose bytes count toward the paper's compression ratio.
pub const CR_SECTIONS: [&str; 9] =
    ["HLAT", "BLAT", "GLAT", "GCLT", "GCOF", "GIDX", "SZ3B", "ZFPB", "ADPB"];

/// Index minor version of the per-block codec-id extension (the one
/// defined extension so far — see [`BlockIndex`]).
pub const BLOCK_INDEX_EXT_CODECS: u8 = 1;

/// The Archive v3 block index: where each block's independently-coded
/// stream lives inside the payload section.
///
/// `tile` is the block shape the field was tiled with (ceil division;
/// row-major block ids, matching [`crate::tensor::block_origins`]), and
/// `entries[id]` is that block's `(byte offset, byte length)` into the
/// codec's payload section. Region decodes slice exactly the entries of
/// the intersecting blocks — the rest of the payload is never touched.
///
/// Serialized layout (little-endian, section `BIDX`):
/// ```text
///   u32 rank | rank x u32 tile_dim | u64 n_blocks | n x (u64 off, u64 len)
///     [ u8 minor_version (=1) | n x u8 codec_id ]
/// ```
///
/// The bracketed trailer is the *codec-id extension* (index minor
/// version [`BLOCK_INDEX_EXT_CODECS`]), written only by mixed-codec
/// (adaptive) archives: `codecs[id]` names the per-block stream format
/// (`0` = sz3-like, `1` = zfp-like — see `crate::codec::TileCodec`).
/// Homogeneous archives omit it, so every pre-extension v3/v4 archive
/// keeps parsing byte-identically and new homogeneous archives stay
/// readable by pre-extension readers.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BlockIndex {
    pub tile: Vec<usize>,
    pub entries: Vec<(u64, u64)>,
    /// Per-block codec ids (one per entry) for mixed-codec payloads;
    /// `None` for homogeneous archives (every pre-extension archive).
    pub codecs: Option<Vec<u8>>,
}

/// Sanity cap on index rank (fields are rank 1..4 in practice).
const MAX_INDEX_RANK: usize = 16;

impl BlockIndex {
    pub fn to_bytes(&self) -> Vec<u8> {
        let ext = self.codecs.as_ref().map_or(0, |c| 1 + c.len());
        let mut out =
            Vec::with_capacity(4 + self.tile.len() * 4 + 8 + self.entries.len() * 16 + ext);
        out.extend_from_slice(&(self.tile.len() as u32).to_le_bytes());
        for &t in &self.tile {
            out.extend_from_slice(&(t as u32).to_le_bytes());
        }
        out.extend_from_slice(&(self.entries.len() as u64).to_le_bytes());
        for &(off, len) in &self.entries {
            out.extend_from_slice(&off.to_le_bytes());
            out.extend_from_slice(&len.to_le_bytes());
        }
        if let Some(codecs) = &self.codecs {
            assert_eq!(codecs.len(), self.entries.len(), "one codec id per entry");
            out.push(BLOCK_INDEX_EXT_CODECS);
            out.extend_from_slice(codecs);
        }
        out
    }

    /// Parse an index section. Untrusted input: every length is checked
    /// before it sizes an allocation, so corrupt archives error instead
    /// of panicking or ballooning memory.
    pub fn from_bytes(bytes: &[u8]) -> Result<Self> {
        ensure!(bytes.len() >= 4, "block index truncated");
        let rank = u32::from_le_bytes(bytes[0..4].try_into().unwrap()) as usize;
        ensure!(
            (1..=MAX_INDEX_RANK).contains(&rank),
            "block index rank {rank} out of range"
        );
        let mut off = 4usize;
        ensure!(bytes.len() >= off + rank * 4 + 8, "block index truncated");
        let mut tile = Vec::with_capacity(rank);
        for _ in 0..rank {
            let t = u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap()) as usize;
            ensure!(t >= 1, "block index tile dim is zero");
            tile.push(t);
            off += 4;
        }
        let n = u64::from_le_bytes(bytes[off..off + 8].try_into().unwrap());
        off += 8;
        let n = usize::try_from(n)
            .map_err(|_| anyhow::anyhow!("block index entry count overflow"))?;
        // allocation cap from the actual bytes present: 16 B per entry
        ensure!(
            n <= (bytes.len() - off) / 16,
            "block index declares {n} entries, impossible in {} bytes",
            bytes.len()
        );
        let mut entries = Vec::with_capacity(n);
        for _ in 0..n {
            let o = u64::from_le_bytes(bytes[off..off + 8].try_into().unwrap());
            let l = u64::from_le_bytes(bytes[off + 8..off + 16].try_into().unwrap());
            entries.push((o, l));
            off += 16;
        }
        // optional codec-id extension: exactly `1 + n` trailing bytes
        // (minor version + one id per entry); anything else is corrupt —
        // the slice below is bounded by the bytes actually present
        let codecs = if off == bytes.len() {
            None
        } else {
            let minor = bytes[off];
            ensure!(
                minor == BLOCK_INDEX_EXT_CODECS,
                "block index extension version {minor} unsupported"
            );
            off += 1;
            ensure!(
                bytes.len() - off == n,
                "block index codec-id extension has {} of {n} ids",
                bytes.len() - off
            );
            let c = bytes[off..off + n].to_vec();
            off += n;
            Some(c)
        };
        ensure!(off == bytes.len(), "block index has trailing bytes");
        Ok(Self { tile, entries, codecs })
    }

    /// Check the index is consistent with the field geometry and payload
    /// it claims to describe: one entry per tile of `dims`, every entry
    /// inside `payload_len`, and every tile dim within the field dim —
    /// the tile shape is untrusted input, and it later sizes per-tile
    /// decode allocations, so the trusted `dims` must bound it.
    pub fn validate(&self, dims: &[usize], payload_len: usize) -> Result<()> {
        ensure!(
            self.tile.len() == dims.len(),
            "block index rank {} != field rank {}",
            self.tile.len(),
            dims.len()
        );
        let mut expect = 1usize;
        for (d, (&dim, &t)) in dims.iter().zip(&self.tile).enumerate() {
            ensure!(
                (1..=dim.max(1)).contains(&t),
                "block index tile dim {d} ({t}) outside field dim {dim}"
            );
            expect = expect
                .checked_mul(dim.div_ceil(t))
                .ok_or_else(|| anyhow::anyhow!("block index tile count overflow"))?;
        }
        ensure!(
            self.entries.len() == expect,
            "block index has {} entries, geometry needs {expect}",
            self.entries.len()
        );
        if let Some(codecs) = &self.codecs {
            ensure!(
                codecs.len() == self.entries.len(),
                "block index has {} codec ids for {} entries",
                codecs.len(),
                self.entries.len()
            );
        }
        for (id, &(off, len)) in self.entries.iter().enumerate() {
            let end = off
                .checked_add(len)
                .ok_or_else(|| anyhow::anyhow!("block {id} extent overflow"))?;
            ensure!(
                end <= payload_len as u64,
                "block {id} extent {off}+{len} exceeds payload {payload_len}"
            );
        }
        Ok(())
    }

    /// Byte span of block `id` as usize offsets.
    pub fn entry(&self, id: usize) -> Result<(usize, usize)> {
        let &(off, len) = self
            .entries
            .get(id)
            .ok_or_else(|| anyhow::anyhow!("block id {id} out of index range"))?;
        Ok((off as usize, len as usize))
    }

    /// Total payload bytes a decode of exactly `ids` touches.
    pub fn bytes_for(&self, ids: &[usize]) -> usize {
        ids.iter()
            .filter_map(|&id| self.entries.get(id))
            .map(|&(_, len)| len as usize)
            .sum()
    }

    /// Total payload bytes covered by the index.
    pub fn total_bytes(&self) -> usize {
        self.entries.iter().map(|&(_, len)| len as usize).sum()
    }
}

/// A tagged-section archive with a JSON header.
#[derive(Debug, Clone)]
pub struct Archive {
    pub header: Value,
    version: u16,
    sections: Vec<(String, Vec<u8>)>,
    /// Parsed from bytes that carried a verified XSUM trailer. Purely
    /// informational (reported by `cli verify` / `info`); serialization
    /// is governed by which `to_bytes*` the caller picks, not this flag.
    checksummed: bool,
}

impl Archive {
    pub fn new(header: Value) -> Self {
        Self { header, version: VERSION_V1, sections: Vec::new(), checksummed: false }
    }

    /// A new (empty) multi-field v2 container.
    pub fn new_v2(header: Value) -> Self {
        Self { header, version: VERSION_V2, sections: Vec::new(), checksummed: false }
    }

    /// A new (empty) v3 single-field archive (block-indexed payload).
    pub fn new_v3(header: Value) -> Self {
        Self { header, version: VERSION_V3, sections: Vec::new(), checksummed: false }
    }

    /// Did these bytes carry a verified XSUM integrity trailer?
    pub fn checksummed(&self) -> bool {
        self.checksummed
    }

    /// Container version (1 = single field, 2 = multi-field set,
    /// 3 = single field with block index).
    pub fn version(&self) -> u16 {
        self.version
    }

    /// Attach the v3 block index (requires a [`Self::new_v3`] archive).
    pub fn add_block_index(&mut self, index: &BlockIndex) {
        assert_eq!(self.version, VERSION_V3, "block index only in v3 archives");
        self.add_section(BLOCK_INDEX_TAG, index.to_bytes());
    }

    /// The block index of a v3 archive (`None` for v1/v2 — callers fall
    /// back to full decode + crop, keeping the region API uniform).
    pub fn block_index(&self) -> Result<Option<BlockIndex>> {
        if !self.has_section(BLOCK_INDEX_TAG) {
            return Ok(None);
        }
        Ok(Some(BlockIndex::from_bytes(self.section(BLOCK_INDEX_TAG)?)?))
    }

    /// Is this a multi-field dataset container?
    pub fn is_multi_field(&self) -> bool {
        self.version == VERSION_V2
    }

    /// Section tag of field `i` in a v2 container. Tags are `F` + three
    /// digits, so a container holds at most [`Self::MAX_FIELDS`] fields;
    /// [`Self::add_field_archive`] enforces the cap with a typed error
    /// before any tag could collide or garble.
    pub fn field_tag(i: usize) -> String {
        assert!(i < Self::MAX_FIELDS, "v2 containers hold at most 1000 fields");
        format!("F{i:03}")
    }

    /// `F000`..`F999`: the most fields one v2 container can hold.
    pub const MAX_FIELDS: usize = 1000;

    /// Field names recorded in a v2 header, in section order. Every
    /// entry must be a string — silently dropping a malformed entry
    /// would misalign names with `F`-section indices.
    pub fn field_names(&self) -> Result<Vec<String>> {
        ensure!(self.version == VERSION_V2, "not a multi-field container");
        self.header
            .req("fields")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("v2 header `fields` is not an array"))?
            .iter()
            .enumerate()
            .map(|(i, v)| {
                v.as_str().map(String::from).ok_or_else(|| {
                    anyhow::anyhow!("v2 header `fields[{i}]` is not a string")
                })
            })
            .collect()
    }

    /// Number of embedded field archives in a v2 container.
    pub fn field_count(&self) -> usize {
        self.sections
            .iter()
            .filter(|(t, _)| Self::is_field_tag(t))
            .count()
    }

    fn is_field_tag(tag: &str) -> bool {
        tag.len() == 4
            && tag.starts_with('F')
            && tag[1..].bytes().all(|b| b.is_ascii_digit())
    }

    /// Append a field's complete single-field (v1 or v3) archive to a v2
    /// container. Errors with a clear message once the `F000`..`F999` tag
    /// space is exhausted instead of producing colliding tags.
    pub fn add_field_archive(&mut self, sub: &Archive) -> Result<()> {
        assert_eq!(self.version, VERSION_V2, "field sections only in v2");
        let i = self.field_count();
        ensure!(
            i < Self::MAX_FIELDS,
            "v2 containers hold at most {} fields (F000..F999 tag space)",
            Self::MAX_FIELDS
        );
        self.add_section(&Self::field_tag(i), sub.to_bytes());
        Ok(())
    }

    /// Parse the embedded single-field (v1 or v3) archive of field `i`
    /// in a v2 container.
    pub fn field_archive(&self, i: usize) -> Result<Archive> {
        ensure!(self.version == VERSION_V2, "not a multi-field container");
        ensure!(i < Self::MAX_FIELDS, "field index {i} out of tag space");
        let sub = Archive::from_bytes(self.section(&Self::field_tag(i))?)?;
        ensure!(
            sub.version == VERSION_V1 || sub.version == VERSION_V3,
            "nested multi-field containers are not supported"
        );
        Ok(sub)
    }

    pub fn add_section(&mut self, tag: &str, bytes: Vec<u8>) {
        assert_eq!(tag.len(), 4, "tags are 4 ASCII chars");
        assert!(
            !self.sections.iter().any(|(t, _)| t == tag),
            "duplicate section {tag}"
        );
        self.sections.push((tag.to_string(), bytes));
    }

    pub fn section(&self, tag: &str) -> Result<&[u8]> {
        self.sections
            .iter()
            .find(|(t, _)| t == tag)
            .map(|(_, b)| b.as_slice())
            .ok_or_else(|| anyhow::anyhow!("archive missing section {tag}"))
    }

    pub fn has_section(&self, tag: &str) -> bool {
        self.sections.iter().any(|(t, _)| t == tag)
    }

    /// Set (insert or replace) a header field. Codec wrappers use this to
    /// stamp the codec id and error bound into pipeline-built archives.
    pub fn set_header(&mut self, key: &str, val: Value) {
        match &mut self.header {
            Value::Obj(pairs) => {
                if let Some(pair) = pairs.iter_mut().find(|(k, _)| k == key) {
                    pair.1 = val;
                } else {
                    pairs.push((key.to_string(), val));
                }
            }
            other => {
                *other = Value::Obj(vec![(key.to_string(), val)]);
            }
        }
    }

    /// Required string header field (readable error on absence/mistype).
    pub fn header_str(&self, key: &str) -> Result<&str> {
        self.header
            .req(key)?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("header field {key:?} is not a string"))
    }

    /// Per-section sizes. In a v2 container the embedded field archives
    /// are expanded, entries namespaced `"<field>/<TAG>"` (field name
    /// from the header, falling back to the section tag), so multi-field
    /// reports stay per-section like single-field ones.
    pub fn section_sizes(&self) -> Vec<(String, usize)> {
        if self.version != VERSION_V2 {
            return self.sections.iter().map(|(t, b)| (t.clone(), b.len())).collect();
        }
        let names = self.field_names().unwrap_or_default();
        let mut out = Vec::new();
        let mut fi = 0usize;
        for (tag, bytes) in &self.sections {
            if Self::is_field_tag(tag) {
                let field = names.get(fi).cloned().unwrap_or_else(|| tag.clone());
                fi += 1;
                match Archive::from_bytes(bytes) {
                    Ok(sub) => {
                        for (t, sz) in sub.section_sizes() {
                            out.push((format!("{field}/{t}"), sz));
                        }
                    }
                    Err(_) => out.push((tag.clone(), bytes.len())),
                }
            } else {
                out.push((tag.clone(), bytes.len()));
            }
        }
        out
    }

    /// Bytes counted toward the paper's CR (latents + GAE coeffs + index
    /// info; basis and header excluded, like the paper's accounting).
    ///
    /// For a v2 container this recurses into every embedded field
    /// archive and sums *their* payload sections — the per-field headers
    /// and the container framing are excluded, so the set's CR equals
    /// `total_points(all fields) / sum(per-field payload)` exactly as if
    /// each field were measured alone.
    pub fn cr_payload_bytes(&self) -> usize {
        if self.version == VERSION_V2 {
            return self
                .sections
                .iter()
                .filter(|(t, _)| Self::is_field_tag(t))
                .filter_map(|(_, b)| Archive::from_bytes(b).ok())
                .map(|sub| sub.cr_payload_bytes())
                .sum();
        }
        self.sections
            .iter()
            .filter(|(t, _)| CR_SECTIONS.contains(&t.as_str()))
            .map(|(_, b)| b.len())
            .sum()
    }

    /// Total on-disk bytes (honest accounting, reported alongside).
    pub fn total_bytes(&self) -> usize {
        let header = self.header.to_string_compact().into_bytes();
        4 + 2
            + 4
            + header.len()
            + 4
            + self
                .sections
                .iter()
                .map(|(_, b)| 4 + 8 + b.len())
                .sum::<usize>()
    }

    pub fn to_bytes(&self) -> Vec<u8> {
        let header = self.header.to_string_compact().into_bytes();
        let mut out = Vec::with_capacity(self.total_bytes());
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&self.version.to_le_bytes());
        out.extend_from_slice(&(header.len() as u32).to_le_bytes());
        out.extend_from_slice(&header);
        out.extend_from_slice(&(self.sections.len() as u32).to_le_bytes());
        for (tag, bytes) in &self.sections {
            out.extend_from_slice(tag.as_bytes());
            out.extend_from_slice(&(bytes.len() as u64).to_le_bytes());
            out.extend_from_slice(bytes);
        }
        out
    }

    /// Serialize with the XSUM integrity trailer. The `"xsum": 1` header
    /// declaration is stamped on a clone at serialization time, so the
    /// in-memory archive (and plain [`Self::to_bytes`]) are untouched —
    /// embedded field archives and legacy comparisons stay byte-stable.
    pub fn to_bytes_checked(&self) -> Vec<u8> {
        let mut declared = self.clone();
        declared.set_header(XSUM_HEADER_KEY, crate::util::json::num(1.0));
        let mut out = declared.to_bytes();
        append_xsum_trailer(&mut out, &declared.sections);
        out
    }

    /// Parse an archive. Corrupt or truncated input always returns `Err`
    /// (all offset arithmetic is overflow-checked — never panics), and
    /// unknown section tags are preserved for forward compatibility.
    pub fn from_bytes(bytes: &[u8]) -> Result<Self> {
        ensure!(bytes.len() >= 10, "archive truncated");
        if &bytes[0..4] != MAGIC {
            if &bytes[0..4] == STREAM_MAGIC {
                bail!(
                    "this is a v4 temporal stream container — \
                     use stream::StreamReader, not Archive::from_bytes"
                );
            }
            bail!("not an ARDC archive");
        }
        let version = u16::from_le_bytes(bytes[4..6].try_into().unwrap());
        ensure!(
            version == VERSION_V1 || version == VERSION_V2 || version == VERSION_V3,
            "unsupported archive version {version}"
        );
        let hlen = u32::from_le_bytes(bytes[6..10].try_into().unwrap()) as usize;
        let header_end = 10usize
            .checked_add(hlen)
            .ok_or_else(|| anyhow::anyhow!("archive header length overflow"))?;
        ensure!(
            bytes.len() >= header_end + 4,
            "archive header truncated ({} of {} bytes)",
            bytes.len(),
            header_end + 4
        );
        let header = Value::parse(std::str::from_utf8(&bytes[10..header_end])?)?;
        let mut off = header_end;
        let n = u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap()) as usize;
        off += 4;
        // cheap sanity cap: every section needs at least a 12-byte header
        ensure!(
            n <= bytes.len().saturating_sub(off) / 12,
            "archive declares {n} sections, impossible in {} bytes",
            bytes.len()
        );
        let mut sections = Vec::with_capacity(n);
        for _ in 0..n {
            ensure!(bytes.len() >= off + 12, "section header truncated");
            let tag = std::str::from_utf8(&bytes[off..off + 4])?.to_string();
            let len = u64::from_le_bytes(bytes[off + 4..off + 12].try_into().unwrap());
            let len = usize::try_from(len)
                .map_err(|_| anyhow::anyhow!("section {tag} length overflow"))?;
            off += 12;
            let end = off
                .checked_add(len)
                .ok_or_else(|| anyhow::anyhow!("section {tag} length overflow"))?;
            ensure!(bytes.len() >= end, "section {tag} truncated");
            sections.push((tag, bytes[off..end].to_vec()));
            off = end;
        }
        // Past the section container: either the header declared an XSUM
        // trailer (which must then verify), or the container must end the
        // buffer exactly — no writer of this format emits trailing bytes,
        // so any surplus is corruption, not forward compatibility.
        let checksummed = header.get(XSUM_HEADER_KEY).is_some();
        if checksummed {
            verify_xsum_trailer(bytes, off, &sections)?;
        } else if off != bytes.len() {
            return Err(corrupt(format!(
                "{} trailing bytes after the section container",
                bytes.len() - off
            )));
        }
        let mut header = header;
        if checksummed {
            // The declaration is a wire-format flag, not archive content:
            // dropping it here makes parse(to_bytes_checked(a)) yield an
            // archive whose to_bytes() equals a.to_bytes() exactly.
            if let Value::Obj(pairs) = &mut header {
                pairs.retain(|(k, _)| k != XSUM_HEADER_KEY);
            }
        }
        Ok(Self { header, version, sections, checksummed })
    }

    /// Persist atomically with the XSUM integrity trailer: bytes go
    /// through [`crate::util::durable::write_atomic`], so a crash at any
    /// point leaves either the previous file or nothing under `path` —
    /// never a torn prefix.
    pub fn save(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        crate::util::durable::write_atomic(path, &self.to_bytes_checked())
    }

    pub fn load(path: impl AsRef<std::path::Path>) -> Result<Self> {
        Self::from_bytes(&std::fs::read(path)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    fn sample() -> Archive {
        let mut a = Archive::new(json::obj(vec![
            ("tau", json::num(0.5)),
            ("dataset", json::s("s3d")),
        ]));
        a.add_section("HLAT", vec![1, 2, 3]);
        a.add_section("GBAS", vec![9; 100]);
        a.add_section("GIDX", vec![]);
        a
    }

    #[test]
    fn round_trip() {
        let a = sample();
        let bytes = a.to_bytes();
        assert_eq!(bytes.len(), a.total_bytes());
        let b = Archive::from_bytes(&bytes).unwrap();
        assert_eq!(b.header.get("dataset").unwrap().as_str(), Some("s3d"));
        assert_eq!(b.section("HLAT").unwrap(), &[1, 2, 3]);
        assert_eq!(b.section("GBAS").unwrap().len(), 100);
        assert_eq!(b.section("GIDX").unwrap().len(), 0);
        assert!(b.section("NOPE").is_err());
    }

    #[test]
    fn cr_payload_excludes_basis() {
        let a = sample();
        assert_eq!(a.cr_payload_bytes(), 3); // HLAT + GIDX only
    }

    #[test]
    fn rejects_corruption() {
        let mut bytes = sample().to_bytes();
        bytes[0] = b'X';
        assert!(Archive::from_bytes(&bytes).is_err());
        let bytes2 = sample().to_bytes();
        assert!(Archive::from_bytes(&bytes2[..bytes2.len() - 5]).is_err());
        assert!(Archive::from_bytes(&[]).is_err());
    }

    #[test]
    fn file_round_trip() {
        let dir = std::env::temp_dir().join("attn_reduce_fmt_test");
        let path = dir.join("a.ardc");
        sample().save(&path).unwrap();
        let back = Archive::load(&path).unwrap();
        assert_eq!(back.section("HLAT").unwrap(), &[1, 2, 3]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    #[should_panic(expected = "duplicate")]
    fn duplicate_sections_panic() {
        let mut a = sample();
        a.add_section("HLAT", vec![]);
    }

    #[test]
    fn set_header_inserts_and_replaces() {
        let mut a = sample();
        a.set_header("codec", json::s("sz3"));
        assert_eq!(a.header_str("codec").unwrap(), "sz3");
        a.set_header("codec", json::s("zfp"));
        assert_eq!(a.header_str("codec").unwrap(), "zfp");
        // existing keys untouched
        assert_eq!(a.header_str("dataset").unwrap(), "s3d");
        assert!(a.header_str("nope").is_err());
    }

    fn sample_v2() -> Archive {
        // two embedded single-field archives with different payloads
        let mut f0 = Archive::new(json::obj(vec![("codec", json::s("sz3"))]));
        f0.add_section("SZ3B", vec![7; 10]);
        f0.add_section("GBAS", vec![1; 40]); // basis: never counted
        let mut f1 = Archive::new(json::obj(vec![("codec", json::s("sz3"))]));
        f1.add_section("SZ3B", vec![8; 25]);
        let mut v2 = Archive::new_v2(json::obj(vec![
            ("codec", json::s("sz3")),
            (
                "fields",
                Value::Arr(vec![json::s("temp"), json::s("pressure")]),
            ),
        ]));
        v2.add_field_archive(&f0).unwrap();
        v2.add_field_archive(&f1).unwrap();
        v2
    }

    #[test]
    fn v2_round_trips_with_version_and_fields() {
        let v2 = sample_v2();
        assert_eq!(v2.version(), VERSION_V2);
        assert!(v2.is_multi_field());
        let back = Archive::from_bytes(&v2.to_bytes()).unwrap();
        assert_eq!(back.version(), VERSION_V2);
        assert_eq!(back.field_count(), 2);
        assert_eq!(back.field_names().unwrap(), vec!["temp", "pressure"]);
        let f1 = back.field_archive(1).unwrap();
        assert_eq!(f1.section("SZ3B").unwrap(), &[8; 25]);
        assert!(back.field_archive(2).is_err());
    }

    #[test]
    fn v2_accounting_counts_per_field_payload_only() {
        // pins the paper accounting for multi-field containers: the CR
        // payload is the sum of the embedded archives' payload sections
        // (10 + 25 here) — per-field headers, the GBAS basis, and the
        // container framing are all excluded
        let v2 = sample_v2();
        assert_eq!(v2.cr_payload_bytes(), 10 + 25);
        // and it survives serialization
        let back = Archive::from_bytes(&v2.to_bytes()).unwrap();
        assert_eq!(back.cr_payload_bytes(), 35);
        // total bytes count everything (framing + embedded headers)
        assert!(back.total_bytes() > 35 + 40);
        // section sizes are expanded and namespaced by field name
        let sizes = back.section_sizes();
        assert!(sizes.contains(&("temp/SZ3B".to_string(), 10)));
        assert!(sizes.contains(&("temp/GBAS".to_string(), 40)));
        assert!(sizes.contains(&("pressure/SZ3B".to_string(), 25)));
    }

    #[test]
    fn v1_archives_still_parse_as_single_field() {
        let a = sample();
        assert_eq!(a.version(), VERSION_V1);
        assert!(!a.is_multi_field());
        let back = Archive::from_bytes(&a.to_bytes()).unwrap();
        assert_eq!(back.version(), VERSION_V1);
        assert!(back.field_names().is_err());
        // the F-tag filter never hides ordinary v1 sections
        assert_eq!(back.cr_payload_bytes(), 3);
    }

    #[test]
    fn block_index_round_trips_and_validates() {
        let idx = BlockIndex {
            tile: vec![4, 8],
            entries: vec![(0, 10), (10, 7), (17, 0), (17, 3)],
            codecs: None,
        };
        let back = BlockIndex::from_bytes(&idx.to_bytes()).unwrap();
        assert_eq!(back, idx);
        // geometry 7 x 16 with 4 x 8 tiles -> 2 x 2 = 4 entries
        back.validate(&[7, 16], 20).unwrap();
        assert!(back.validate(&[7, 16], 19).is_err(), "extent past payload");
        assert!(back.validate(&[9, 16], 20).is_err(), "wrong entry count");
        assert!(back.validate(&[7, 16, 2], 20).is_err(), "rank mismatch");
        assert_eq!(back.entry(1).unwrap(), (10, 7));
        assert!(back.entry(4).is_err());
        assert_eq!(back.bytes_for(&[0, 3]), 13);
        assert_eq!(back.total_bytes(), 20);
    }

    #[test]
    fn block_index_rejects_corrupt_input() {
        let idx = BlockIndex { tile: vec![4], entries: vec![(0, 5), (5, 5)], codecs: None };
        let bytes = idx.to_bytes();
        for cut in 0..bytes.len() {
            assert!(BlockIndex::from_bytes(&bytes[..cut]).is_err(), "cut {cut}");
        }
        // absurd entry count must not allocate
        let mut b = bytes.clone();
        let n_off = 4 + 4; // rank + one tile dim
        b[n_off..n_off + 8].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(BlockIndex::from_bytes(&b).is_err());
        // zero tile dim
        let mut b = bytes.clone();
        b[4..8].copy_from_slice(&0u32.to_le_bytes());
        assert!(BlockIndex::from_bytes(&b).is_err());
        // absurd rank
        let mut b = bytes;
        b[0..4].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(BlockIndex::from_bytes(&b).is_err());
    }

    #[test]
    fn block_index_rejects_tile_dims_outside_field() {
        // tile dims are untrusted and later size per-tile decode
        // allocations: anything beyond the trusted field dims must error
        // before a decoder can use it as a cap
        let huge = BlockIndex {
            tile: vec![u32::MAX as usize, u32::MAX as usize],
            entries: vec![(0, 4)],
            codecs: None,
        };
        assert!(huge.validate(&[7, 16], 4).is_err());
        // count arithmetic is overflow-checked even for absurd dims
        let tiny = BlockIndex { tile: vec![1, 1], entries: vec![(0, 4)], codecs: None };
        assert!(tiny.validate(&[usize::MAX, usize::MAX], 4).is_err());
        // boundary: tile == dims is one tile and valid
        let exact = BlockIndex { tile: vec![7, 16], entries: vec![(0, 4)], codecs: None };
        exact.validate(&[7, 16], 4).unwrap();
    }

    #[test]
    fn block_index_codec_id_extension_round_trips() {
        let idx = BlockIndex {
            tile: vec![4, 8],
            entries: vec![(0, 10), (10, 7), (17, 3)],
            codecs: Some(vec![0, 1, 0]),
        };
        let bytes = idx.to_bytes();
        // the extension is exactly `u8 minor + n ids` past the legacy layout
        let legacy = BlockIndex { codecs: None, ..idx.clone() };
        assert_eq!(bytes.len(), legacy.to_bytes().len() + 1 + 3);
        let back = BlockIndex::from_bytes(&bytes).unwrap();
        assert_eq!(back, idx);
        // geometry 4 x 24 with 4 x 8 tiles -> 1 x 3 = 3 entries
        back.validate(&[4, 24], 20).unwrap();
        // codec-id count must match the entry count
        let bad = BlockIndex { codecs: Some(vec![0]), ..idx.clone() };
        assert!(bad.validate(&[4, 24], 20).is_err(), "id/entry count mismatch");
        // a legacy (extension-free) serialization still parses as before
        assert_eq!(BlockIndex::from_bytes(&legacy.to_bytes()).unwrap(), legacy);
    }

    #[test]
    fn block_index_rejects_corrupt_codec_extension() {
        let idx = BlockIndex {
            tile: vec![4],
            entries: vec![(0, 5), (5, 5)],
            codecs: Some(vec![1, 0]),
        };
        let bytes = idx.to_bytes();
        let legacy_len = BlockIndex { codecs: None, ..idx.clone() }.to_bytes().len();
        // dropping the whole trailer yields a valid legacy index (by design)
        let cut = BlockIndex::from_bytes(&bytes[..legacy_len]).unwrap();
        assert_eq!(cut.codecs, None);
        // any partial trailer is a typed error, never a panic
        for cut in legacy_len + 1..bytes.len() {
            assert!(BlockIndex::from_bytes(&bytes[..cut]).is_err(), "cut {cut}");
        }
        // unknown extension minor version
        let mut b = bytes.clone();
        b[legacy_len] = 2;
        assert!(BlockIndex::from_bytes(&b).is_err());
        // surplus trailer bytes
        let mut b = bytes;
        b.push(0);
        assert!(BlockIndex::from_bytes(&b).is_err());
    }

    #[test]
    fn v3_archives_round_trip_with_index() {
        let mut a = Archive::new_v3(json::obj(vec![("codec", json::s("sz3"))]));
        a.add_section("SZ3B", vec![1; 12]);
        a.add_block_index(&BlockIndex { tile: vec![4], entries: vec![(0, 12)], codecs: None });
        assert_eq!(a.version(), VERSION_V3);
        assert!(!a.is_multi_field());
        let back = Archive::from_bytes(&a.to_bytes()).unwrap();
        assert_eq!(back.version(), VERSION_V3);
        let idx = back.block_index().unwrap().expect("index present");
        assert_eq!(idx.tile, vec![4]);
        assert_eq!(idx.entries, vec![(0, 12)]);
        // v1 archives report no index
        assert!(sample().block_index().unwrap().is_none());
        // v3 payload sections still count toward CR, the index does not
        assert_eq!(back.cr_payload_bytes(), 12);
    }

    #[test]
    fn v2_can_embed_v3_field_archives() {
        let mut f = Archive::new_v3(json::obj(vec![("codec", json::s("sz3"))]));
        f.add_section("SZ3B", vec![3; 9]);
        f.add_block_index(&BlockIndex { tile: vec![2], entries: vec![(0, 9)], codecs: None });
        let mut v2 = Archive::new_v2(json::obj(vec![(
            "fields",
            Value::Arr(vec![json::s("t")]),
        )]));
        v2.add_field_archive(&f).unwrap();
        let back = Archive::from_bytes(&v2.to_bytes()).unwrap();
        let sub = back.field_archive(0).unwrap();
        assert_eq!(sub.version(), VERSION_V3);
        assert!(sub.block_index().unwrap().is_some());
        assert_eq!(back.cr_payload_bytes(), 9);
    }

    #[test]
    fn field_archive_cap_is_a_clear_error_not_a_collision() {
        // fill the full F000..F999 tag space with tiny field archives;
        // the 1001st append must error, not panic or collide
        let mut sub = Archive::new(json::obj(vec![("codec", json::s("sz3"))]));
        sub.add_section("SZ3B", vec![1, 2, 3]);
        let sub_bytes = sub.to_bytes();
        let mut v2 = Archive::new_v2(json::obj(vec![("fields", Value::Arr(vec![]))]));
        for _ in 0..Archive::MAX_FIELDS {
            v2.add_field_archive(&sub).unwrap();
        }
        assert_eq!(v2.field_count(), Archive::MAX_FIELDS);
        let err = v2.add_field_archive(&sub).unwrap_err();
        assert!(err.to_string().contains("at most"), "{err}");
        // count unchanged, existing sections intact
        assert_eq!(v2.field_count(), Archive::MAX_FIELDS);
        assert_eq!(v2.field_archive(999).unwrap().to_bytes(), sub_bytes);
        assert!(v2.field_archive(1000).is_err(), "index out of tag space");
    }

    #[test]
    fn stream_header_round_trips_and_rejects_corruption() {
        let h = json::obj(vec![("codec", json::s("sz3")), ("keyint", json::num(4.0))]);
        let bytes = stream_header_bytes(&h);
        let (back, off) = parse_stream_header(&bytes).unwrap();
        assert_eq!(back.req("codec").unwrap().as_str(), Some("sz3"));
        assert_eq!(off, bytes.len());
        for cut in 0..bytes.len() {
            assert!(parse_stream_header(&bytes[..cut]).is_err(), "cut {cut}");
        }
        let mut bad = bytes.clone();
        bad[0] = b'X';
        assert!(parse_stream_header(&bad).is_err());
        // version mismatch
        let mut bad = bytes;
        bad[4..6].copy_from_slice(&9u16.to_le_bytes());
        assert!(parse_stream_header(&bad).is_err());
        // an ARDC archive is a readable misuse error, and vice versa
        let ar = sample().to_bytes();
        let err = parse_stream_header(&ar).unwrap_err().to_string();
        assert!(err.contains("ARDC archive"), "{err}");
        let mut ts = stream_header_bytes(&json::obj(vec![]));
        ts.extend_from_slice(&stream_record_bytes(STREAM_KEY_TAG, &[1, 2, 3]));
        let err = Archive::from_bytes(&ts).unwrap_err().to_string();
        assert!(err.contains("StreamReader"), "{err}");
    }

    #[test]
    fn stream_records_parse_in_sequence_and_stop_at_truncation() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&stream_record_bytes(STREAM_KEY_TAG, &[9; 5]));
        buf.extend_from_slice(&stream_record_bytes(STREAM_RES_TAG, &[]));
        let (tag, p, len, next) = parse_stream_record(&buf, 0).unwrap();
        assert_eq!(&tag, STREAM_KEY_TAG);
        assert_eq!((p, len), (12, 5));
        let (tag2, _, len2, next2) = parse_stream_record(&buf, next).unwrap();
        assert_eq!(&tag2, STREAM_RES_TAG);
        assert_eq!(len2, 0);
        assert_eq!(next2, buf.len());
        assert!(parse_stream_record(&buf, next2).is_err(), "past the end");
        // any truncation inside a record is a clean error
        for cut in 0..buf.len() {
            if cut < 12 {
                assert!(parse_stream_record(&buf[..cut], 0).is_err(), "cut {cut}");
            }
        }
        assert!(parse_stream_record(&buf[..16], 0).is_err(), "payload cut");
    }

    #[test]
    fn checked_serialization_round_trips_and_stays_byte_stable() {
        let a = sample();
        let legacy = a.to_bytes();
        let checked = a.to_bytes_checked();
        // trailer + the `"xsum":1` header declaration are the only growth
        assert_eq!(checked.len(), legacy.len() + xsum_trailer_len(3) + r#","xsum":1"#.len());
        let back = Archive::from_bytes(&checked).unwrap();
        assert!(back.checksummed());
        assert!(back.header.get(XSUM_HEADER_KEY).is_none(), "wire flag stripped");
        // parse(checked).to_bytes() == legacy bytes exactly
        assert_eq!(back.to_bytes(), legacy);
        assert_eq!(back.section("HLAT").unwrap(), &[1, 2, 3]);
        // legacy bytes still parse, reporting unchecksummed
        assert!(!Archive::from_bytes(&legacy).unwrap().checksummed());
        // and to_bytes_checked is deterministic
        assert_eq!(a.to_bytes_checked(), checked);
    }

    #[test]
    fn every_single_byte_flip_in_a_checked_archive_is_detected() {
        let checked = sample().to_bytes_checked();
        let mut bytes = checked.clone();
        for i in 0..bytes.len() {
            for bit in [0x01u8, 0x80] {
                bytes[i] ^= bit;
                assert!(
                    Archive::from_bytes(&bytes).is_err(),
                    "flip at byte {i} (bit {bit:#x}) parsed clean"
                );
                bytes[i] ^= bit;
            }
        }
        assert_eq!(bytes, checked, "sweep restored the buffer");
        // flips inside section payloads are typed corruption specifically
        let payload_pos = checked
            .windows(3)
            .position(|w| w == [1, 2, 3])
            .expect("HLAT payload present");
        bytes[payload_pos] ^= 0x40;
        let err = Archive::from_bytes(&bytes).unwrap_err();
        assert!(is_corruption(&err), "{err:#}");
        assert!(format!("{err:#}").contains("checksum mismatch"), "{err:#}");
    }

    #[test]
    fn legacy_archives_reject_trailing_garbage_as_corruption() {
        let mut bytes = sample().to_bytes();
        assert!(Archive::from_bytes(&bytes).is_ok());
        bytes.push(0);
        let err = Archive::from_bytes(&bytes).unwrap_err();
        assert!(is_corruption(&err), "{err:#}");
        assert!(format!("{err:#}").contains("trailing"), "{err:#}");
    }

    #[test]
    fn checked_stream_records_verify_and_detect_flips() {
        let rec = stream_record_bytes_checked(STREAM_KEY_TAG, &[5, 6, 7, 8, 9]);
        assert_eq!(rec.len(), 12 + 5 + 4);
        let (tag, p, len, next) = parse_stream_record_checked(&rec, 0).unwrap();
        assert_eq!((&tag, p, len, next), (STREAM_KEY_TAG, 12, 5, rec.len()));
        let mut bytes = rec.clone();
        for i in 0..bytes.len() {
            bytes[i] ^= 0x10;
            assert!(
                parse_stream_record_checked(&bytes, 0).is_err(),
                "flip at byte {i} parsed clean"
            );
            bytes[i] ^= 0x10;
        }
        // any truncation is a plain (torn-tail) error, never a panic
        for cut in 0..rec.len() {
            assert!(parse_stream_record_checked(&rec[..cut], 0).is_err(), "cut {cut}");
        }
        // a payload flip is typed corruption
        bytes[13] ^= 0xFF;
        let err = parse_stream_record_checked(&bytes, 0).unwrap_err();
        assert!(is_corruption(&err), "{err:#}");
    }

    #[test]
    fn unknown_sections_survive_round_trip() {
        let mut a = sample();
        a.add_section("ZZZZ", vec![42; 7]); // future writer's section
        let b = Archive::from_bytes(&a.to_bytes()).unwrap();
        assert_eq!(b.section("ZZZZ").unwrap(), &[42; 7]);
        assert_eq!(b.section("HLAT").unwrap(), &[1, 2, 3]);
    }
}
