//! Compressed archive container (DESIGN.md §5).
//!
//! Layout (little-endian):
//! ```text
//!   "ARDC" | u16 version | u32 header_len | header JSON (UTF-8) |
//!   u32 n_sections | n x ( [u8;4] tag | u64 len | bytes )
//! ```
//!
//! Sections used by the codecs:
//!   HLAT — HBAE latent codes (Huffman)        } counted in CR
//!   BLAT — BAE latent codes (Huffman)         } counted in CR
//!   GLAT — GBAE primary latent codes          } counted in CR
//!   GCLT — GBAE corrector latent codes        } counted in CR
//!   GCOF — GAE coefficient codes (Huffman)    } counted in CR
//!   GIDX — GAE index sets (Fig. 3 + LZSS)     } counted in CR
//!   SZ3B — SZ3-like whole-stream payload      } counted in CR
//!   ZFPB — ZFP-like whole-stream payload      } counted in CR
//!   GBAS — PCA basis, f32 (amortized like model params — the paper's CR
//!          counts latents + coefficients + index info; §III-C)
//!
//! Unknown section tags are preserved verbatim by the parser, so newer
//! writers stay readable by older readers (forward compatibility).

use crate::util::json::Value;
use crate::Result;
use anyhow::{bail, ensure};

const MAGIC: &[u8; 4] = b"ARDC";
const VERSION: u16 = 1;

/// Sections whose bytes count toward the paper's compression ratio.
pub const CR_SECTIONS: [&str; 8] =
    ["HLAT", "BLAT", "GLAT", "GCLT", "GCOF", "GIDX", "SZ3B", "ZFPB"];

/// A tagged-section archive with a JSON header.
#[derive(Debug, Clone)]
pub struct Archive {
    pub header: Value,
    sections: Vec<(String, Vec<u8>)>,
}

impl Archive {
    pub fn new(header: Value) -> Self {
        Self { header, sections: Vec::new() }
    }

    pub fn add_section(&mut self, tag: &str, bytes: Vec<u8>) {
        assert_eq!(tag.len(), 4, "tags are 4 ASCII chars");
        assert!(
            !self.sections.iter().any(|(t, _)| t == tag),
            "duplicate section {tag}"
        );
        self.sections.push((tag.to_string(), bytes));
    }

    pub fn section(&self, tag: &str) -> Result<&[u8]> {
        self.sections
            .iter()
            .find(|(t, _)| t == tag)
            .map(|(_, b)| b.as_slice())
            .ok_or_else(|| anyhow::anyhow!("archive missing section {tag}"))
    }

    pub fn has_section(&self, tag: &str) -> bool {
        self.sections.iter().any(|(t, _)| t == tag)
    }

    /// Set (insert or replace) a header field. Codec wrappers use this to
    /// stamp the codec id and error bound into pipeline-built archives.
    pub fn set_header(&mut self, key: &str, val: Value) {
        match &mut self.header {
            Value::Obj(pairs) => {
                if let Some(pair) = pairs.iter_mut().find(|(k, _)| k == key) {
                    pair.1 = val;
                } else {
                    pairs.push((key.to_string(), val));
                }
            }
            other => {
                *other = Value::Obj(vec![(key.to_string(), val)]);
            }
        }
    }

    /// Required string header field (readable error on absence/mistype).
    pub fn header_str(&self, key: &str) -> Result<&str> {
        self.header
            .req(key)?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("header field {key:?} is not a string"))
    }

    pub fn section_sizes(&self) -> Vec<(String, usize)> {
        self.sections.iter().map(|(t, b)| (t.clone(), b.len())).collect()
    }

    /// Bytes counted toward the paper's CR (latents + GAE coeffs + index
    /// info; basis and header excluded, like the paper's accounting).
    pub fn cr_payload_bytes(&self) -> usize {
        self.sections
            .iter()
            .filter(|(t, _)| CR_SECTIONS.contains(&t.as_str()))
            .map(|(_, b)| b.len())
            .sum()
    }

    /// Total on-disk bytes (honest accounting, reported alongside).
    pub fn total_bytes(&self) -> usize {
        let header = self.header.to_string_compact().into_bytes();
        4 + 2
            + 4
            + header.len()
            + 4
            + self
                .sections
                .iter()
                .map(|(_, b)| 4 + 8 + b.len())
                .sum::<usize>()
    }

    pub fn to_bytes(&self) -> Vec<u8> {
        let header = self.header.to_string_compact().into_bytes();
        let mut out = Vec::with_capacity(self.total_bytes());
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&(header.len() as u32).to_le_bytes());
        out.extend_from_slice(&header);
        out.extend_from_slice(&(self.sections.len() as u32).to_le_bytes());
        for (tag, bytes) in &self.sections {
            out.extend_from_slice(tag.as_bytes());
            out.extend_from_slice(&(bytes.len() as u64).to_le_bytes());
            out.extend_from_slice(bytes);
        }
        out
    }

    /// Parse an archive. Corrupt or truncated input always returns `Err`
    /// (all offset arithmetic is overflow-checked — never panics), and
    /// unknown section tags are preserved for forward compatibility.
    pub fn from_bytes(bytes: &[u8]) -> Result<Self> {
        ensure!(bytes.len() >= 10, "archive truncated");
        if &bytes[0..4] != MAGIC {
            bail!("not an ARDC archive");
        }
        let version = u16::from_le_bytes(bytes[4..6].try_into().unwrap());
        ensure!(version == VERSION, "unsupported archive version {version}");
        let hlen = u32::from_le_bytes(bytes[6..10].try_into().unwrap()) as usize;
        let header_end = 10usize
            .checked_add(hlen)
            .ok_or_else(|| anyhow::anyhow!("archive header length overflow"))?;
        ensure!(
            bytes.len() >= header_end + 4,
            "archive header truncated ({} of {} bytes)",
            bytes.len(),
            header_end + 4
        );
        let header = Value::parse(std::str::from_utf8(&bytes[10..header_end])?)?;
        let mut off = header_end;
        let n = u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap()) as usize;
        off += 4;
        // cheap sanity cap: every section needs at least a 12-byte header
        ensure!(
            n <= bytes.len().saturating_sub(off) / 12,
            "archive declares {n} sections, impossible in {} bytes",
            bytes.len()
        );
        let mut sections = Vec::with_capacity(n);
        for _ in 0..n {
            ensure!(bytes.len() >= off + 12, "section header truncated");
            let tag = std::str::from_utf8(&bytes[off..off + 4])?.to_string();
            let len = u64::from_le_bytes(bytes[off + 4..off + 12].try_into().unwrap());
            let len = usize::try_from(len)
                .map_err(|_| anyhow::anyhow!("section {tag} length overflow"))?;
            off += 12;
            let end = off
                .checked_add(len)
                .ok_or_else(|| anyhow::anyhow!("section {tag} length overflow"))?;
            ensure!(bytes.len() >= end, "section {tag} truncated");
            sections.push((tag, bytes[off..end].to_vec()));
            off = end;
        }
        Ok(Self { header, sections })
    }

    pub fn save(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_bytes())?;
        Ok(())
    }

    pub fn load(path: impl AsRef<std::path::Path>) -> Result<Self> {
        Self::from_bytes(&std::fs::read(path)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    fn sample() -> Archive {
        let mut a = Archive::new(json::obj(vec![
            ("tau", json::num(0.5)),
            ("dataset", json::s("s3d")),
        ]));
        a.add_section("HLAT", vec![1, 2, 3]);
        a.add_section("GBAS", vec![9; 100]);
        a.add_section("GIDX", vec![]);
        a
    }

    #[test]
    fn round_trip() {
        let a = sample();
        let bytes = a.to_bytes();
        assert_eq!(bytes.len(), a.total_bytes());
        let b = Archive::from_bytes(&bytes).unwrap();
        assert_eq!(b.header.get("dataset").unwrap().as_str(), Some("s3d"));
        assert_eq!(b.section("HLAT").unwrap(), &[1, 2, 3]);
        assert_eq!(b.section("GBAS").unwrap().len(), 100);
        assert_eq!(b.section("GIDX").unwrap().len(), 0);
        assert!(b.section("NOPE").is_err());
    }

    #[test]
    fn cr_payload_excludes_basis() {
        let a = sample();
        assert_eq!(a.cr_payload_bytes(), 3); // HLAT + GIDX only
    }

    #[test]
    fn rejects_corruption() {
        let mut bytes = sample().to_bytes();
        bytes[0] = b'X';
        assert!(Archive::from_bytes(&bytes).is_err());
        let bytes2 = sample().to_bytes();
        assert!(Archive::from_bytes(&bytes2[..bytes2.len() - 5]).is_err());
        assert!(Archive::from_bytes(&[]).is_err());
    }

    #[test]
    fn file_round_trip() {
        let dir = std::env::temp_dir().join("attn_reduce_fmt_test");
        let path = dir.join("a.ardc");
        sample().save(&path).unwrap();
        let back = Archive::load(&path).unwrap();
        assert_eq!(back.section("HLAT").unwrap(), &[1, 2, 3]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    #[should_panic(expected = "duplicate")]
    fn duplicate_sections_panic() {
        let mut a = sample();
        a.add_section("HLAT", vec![]);
    }

    #[test]
    fn set_header_inserts_and_replaces() {
        let mut a = sample();
        a.set_header("codec", json::s("sz3"));
        assert_eq!(a.header_str("codec").unwrap(), "sz3");
        a.set_header("codec", json::s("zfp"));
        assert_eq!(a.header_str("codec").unwrap(), "zfp");
        // existing keys untouched
        assert_eq!(a.header_str("dataset").unwrap(), "s3d");
        assert!(a.header_str("nope").is_err());
    }

    #[test]
    fn unknown_sections_survive_round_trip() {
        let mut a = sample();
        a.add_section("ZZZZ", vec![42; 7]); // future writer's section
        let b = Archive::from_bytes(&a.to_bytes()).unwrap();
        assert_eq!(b.section("ZZZZ").unwrap(), &[42; 7]);
        assert_eq!(b.section("HLAT").unwrap(), &[1, 2, 3]);
    }
}
