//! Compressed archive container (DESIGN.md §5).
//!
//! Layout (little-endian), shared by both container versions:
//! ```text
//!   "ARDC" | u16 version | u32 header_len | header JSON (UTF-8) |
//!   u32 n_sections | n x ( [u8;4] tag | u64 len | bytes )
//! ```
//!
//! **Version 1** is a single-field archive. Sections used by the codecs:
//!   HLAT — HBAE latent codes (Huffman)        } counted in CR
//!   BLAT — BAE latent codes (Huffman)         } counted in CR
//!   GLAT — GBAE primary latent codes          } counted in CR
//!   GCLT — GBAE corrector latent codes        } counted in CR
//!   GCOF — GAE coefficient codes (Huffman)    } counted in CR
//!   GIDX — GAE index sets (Fig. 3 + LZSS)     } counted in CR
//!   SZ3B — SZ3-like whole-stream payload      } counted in CR
//!   ZFPB — ZFP-like whole-stream payload      } counted in CR
//!   GBAS — PCA basis, f32 (amortized like model params — the paper's CR
//!          counts latents + coefficients + index info; §III-C)
//!
//! **Version 2** is the multi-field *dataset container* produced by
//! [`crate::engine::CodecExt::compress_set`]: section `F000`..`F999`
//! holds field *i*'s complete v1 archive, and the header carries the
//! field-name list (`fields`) plus the shared per-field stats dictionary
//! (`stats`). CR accounting recurses into the embedded field archives —
//! payload sections only, headers excluded — so multi-field ratios match
//! the paper's accounting.
//!
//! Unknown section tags are preserved verbatim by the parser, so newer
//! writers stay readable by older readers (forward compatibility), and
//! v1 archives parse and decompress unchanged (backward compatibility).

use crate::util::json::Value;
use crate::Result;
use anyhow::{bail, ensure};

const MAGIC: &[u8; 4] = b"ARDC";
/// Single-field archive (the seed format — still written by every codec).
pub const VERSION_V1: u16 = 1;
/// Multi-field dataset container (engine `compress_set`).
pub const VERSION_V2: u16 = 2;

/// Sections whose bytes count toward the paper's compression ratio.
pub const CR_SECTIONS: [&str; 8] =
    ["HLAT", "BLAT", "GLAT", "GCLT", "GCOF", "GIDX", "SZ3B", "ZFPB"];

/// A tagged-section archive with a JSON header.
#[derive(Debug, Clone)]
pub struct Archive {
    pub header: Value,
    version: u16,
    sections: Vec<(String, Vec<u8>)>,
}

impl Archive {
    pub fn new(header: Value) -> Self {
        Self { header, version: VERSION_V1, sections: Vec::new() }
    }

    /// A new (empty) multi-field v2 container.
    pub fn new_v2(header: Value) -> Self {
        Self { header, version: VERSION_V2, sections: Vec::new() }
    }

    /// Container version (1 = single field, 2 = multi-field set).
    pub fn version(&self) -> u16 {
        self.version
    }

    /// Is this a multi-field dataset container?
    pub fn is_multi_field(&self) -> bool {
        self.version == VERSION_V2
    }

    /// Section tag of field `i` in a v2 container.
    pub fn field_tag(i: usize) -> String {
        assert!(i < 1000, "v2 containers hold at most 1000 fields");
        format!("F{i:03}")
    }

    /// Field names recorded in a v2 header, in section order. Every
    /// entry must be a string — silently dropping a malformed entry
    /// would misalign names with `F`-section indices.
    pub fn field_names(&self) -> Result<Vec<String>> {
        ensure!(self.version == VERSION_V2, "not a multi-field container");
        self.header
            .req("fields")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("v2 header `fields` is not an array"))?
            .iter()
            .enumerate()
            .map(|(i, v)| {
                v.as_str().map(String::from).ok_or_else(|| {
                    anyhow::anyhow!("v2 header `fields[{i}]` is not a string")
                })
            })
            .collect()
    }

    /// Number of embedded field archives in a v2 container.
    pub fn field_count(&self) -> usize {
        self.sections
            .iter()
            .filter(|(t, _)| Self::is_field_tag(t))
            .count()
    }

    fn is_field_tag(tag: &str) -> bool {
        tag.len() == 4
            && tag.starts_with('F')
            && tag[1..].bytes().all(|b| b.is_ascii_digit())
    }

    /// Append a field's complete v1 archive to a v2 container.
    pub fn add_field_archive(&mut self, sub: &Archive) {
        assert_eq!(self.version, VERSION_V2, "field sections only in v2");
        let tag = Self::field_tag(self.field_count());
        self.add_section(&tag, sub.to_bytes());
    }

    /// Parse the embedded v1 archive of field `i` in a v2 container.
    pub fn field_archive(&self, i: usize) -> Result<Archive> {
        ensure!(self.version == VERSION_V2, "not a multi-field container");
        let sub = Archive::from_bytes(self.section(&Self::field_tag(i))?)?;
        ensure!(
            sub.version == VERSION_V1,
            "nested multi-field containers are not supported"
        );
        Ok(sub)
    }

    pub fn add_section(&mut self, tag: &str, bytes: Vec<u8>) {
        assert_eq!(tag.len(), 4, "tags are 4 ASCII chars");
        assert!(
            !self.sections.iter().any(|(t, _)| t == tag),
            "duplicate section {tag}"
        );
        self.sections.push((tag.to_string(), bytes));
    }

    pub fn section(&self, tag: &str) -> Result<&[u8]> {
        self.sections
            .iter()
            .find(|(t, _)| t == tag)
            .map(|(_, b)| b.as_slice())
            .ok_or_else(|| anyhow::anyhow!("archive missing section {tag}"))
    }

    pub fn has_section(&self, tag: &str) -> bool {
        self.sections.iter().any(|(t, _)| t == tag)
    }

    /// Set (insert or replace) a header field. Codec wrappers use this to
    /// stamp the codec id and error bound into pipeline-built archives.
    pub fn set_header(&mut self, key: &str, val: Value) {
        match &mut self.header {
            Value::Obj(pairs) => {
                if let Some(pair) = pairs.iter_mut().find(|(k, _)| k == key) {
                    pair.1 = val;
                } else {
                    pairs.push((key.to_string(), val));
                }
            }
            other => {
                *other = Value::Obj(vec![(key.to_string(), val)]);
            }
        }
    }

    /// Required string header field (readable error on absence/mistype).
    pub fn header_str(&self, key: &str) -> Result<&str> {
        self.header
            .req(key)?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("header field {key:?} is not a string"))
    }

    /// Per-section sizes. In a v2 container the embedded field archives
    /// are expanded, entries namespaced `"<field>/<TAG>"` (field name
    /// from the header, falling back to the section tag), so multi-field
    /// reports stay per-section like single-field ones.
    pub fn section_sizes(&self) -> Vec<(String, usize)> {
        if self.version != VERSION_V2 {
            return self.sections.iter().map(|(t, b)| (t.clone(), b.len())).collect();
        }
        let names = self.field_names().unwrap_or_default();
        let mut out = Vec::new();
        let mut fi = 0usize;
        for (tag, bytes) in &self.sections {
            if Self::is_field_tag(tag) {
                let field = names.get(fi).cloned().unwrap_or_else(|| tag.clone());
                fi += 1;
                match Archive::from_bytes(bytes) {
                    Ok(sub) => {
                        for (t, sz) in sub.section_sizes() {
                            out.push((format!("{field}/{t}"), sz));
                        }
                    }
                    Err(_) => out.push((tag.clone(), bytes.len())),
                }
            } else {
                out.push((tag.clone(), bytes.len()));
            }
        }
        out
    }

    /// Bytes counted toward the paper's CR (latents + GAE coeffs + index
    /// info; basis and header excluded, like the paper's accounting).
    ///
    /// For a v2 container this recurses into every embedded field
    /// archive and sums *their* payload sections — the per-field headers
    /// and the container framing are excluded, so the set's CR equals
    /// `total_points(all fields) / sum(per-field payload)` exactly as if
    /// each field were measured alone.
    pub fn cr_payload_bytes(&self) -> usize {
        if self.version == VERSION_V2 {
            return self
                .sections
                .iter()
                .filter(|(t, _)| Self::is_field_tag(t))
                .filter_map(|(_, b)| Archive::from_bytes(b).ok())
                .map(|sub| sub.cr_payload_bytes())
                .sum();
        }
        self.sections
            .iter()
            .filter(|(t, _)| CR_SECTIONS.contains(&t.as_str()))
            .map(|(_, b)| b.len())
            .sum()
    }

    /// Total on-disk bytes (honest accounting, reported alongside).
    pub fn total_bytes(&self) -> usize {
        let header = self.header.to_string_compact().into_bytes();
        4 + 2
            + 4
            + header.len()
            + 4
            + self
                .sections
                .iter()
                .map(|(_, b)| 4 + 8 + b.len())
                .sum::<usize>()
    }

    pub fn to_bytes(&self) -> Vec<u8> {
        let header = self.header.to_string_compact().into_bytes();
        let mut out = Vec::with_capacity(self.total_bytes());
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&self.version.to_le_bytes());
        out.extend_from_slice(&(header.len() as u32).to_le_bytes());
        out.extend_from_slice(&header);
        out.extend_from_slice(&(self.sections.len() as u32).to_le_bytes());
        for (tag, bytes) in &self.sections {
            out.extend_from_slice(tag.as_bytes());
            out.extend_from_slice(&(bytes.len() as u64).to_le_bytes());
            out.extend_from_slice(bytes);
        }
        out
    }

    /// Parse an archive. Corrupt or truncated input always returns `Err`
    /// (all offset arithmetic is overflow-checked — never panics), and
    /// unknown section tags are preserved for forward compatibility.
    pub fn from_bytes(bytes: &[u8]) -> Result<Self> {
        ensure!(bytes.len() >= 10, "archive truncated");
        if &bytes[0..4] != MAGIC {
            bail!("not an ARDC archive");
        }
        let version = u16::from_le_bytes(bytes[4..6].try_into().unwrap());
        ensure!(
            version == VERSION_V1 || version == VERSION_V2,
            "unsupported archive version {version}"
        );
        let hlen = u32::from_le_bytes(bytes[6..10].try_into().unwrap()) as usize;
        let header_end = 10usize
            .checked_add(hlen)
            .ok_or_else(|| anyhow::anyhow!("archive header length overflow"))?;
        ensure!(
            bytes.len() >= header_end + 4,
            "archive header truncated ({} of {} bytes)",
            bytes.len(),
            header_end + 4
        );
        let header = Value::parse(std::str::from_utf8(&bytes[10..header_end])?)?;
        let mut off = header_end;
        let n = u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap()) as usize;
        off += 4;
        // cheap sanity cap: every section needs at least a 12-byte header
        ensure!(
            n <= bytes.len().saturating_sub(off) / 12,
            "archive declares {n} sections, impossible in {} bytes",
            bytes.len()
        );
        let mut sections = Vec::with_capacity(n);
        for _ in 0..n {
            ensure!(bytes.len() >= off + 12, "section header truncated");
            let tag = std::str::from_utf8(&bytes[off..off + 4])?.to_string();
            let len = u64::from_le_bytes(bytes[off + 4..off + 12].try_into().unwrap());
            let len = usize::try_from(len)
                .map_err(|_| anyhow::anyhow!("section {tag} length overflow"))?;
            off += 12;
            let end = off
                .checked_add(len)
                .ok_or_else(|| anyhow::anyhow!("section {tag} length overflow"))?;
            ensure!(bytes.len() >= end, "section {tag} truncated");
            sections.push((tag, bytes[off..end].to_vec()));
            off = end;
        }
        Ok(Self { header, version, sections })
    }

    pub fn save(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_bytes())?;
        Ok(())
    }

    pub fn load(path: impl AsRef<std::path::Path>) -> Result<Self> {
        Self::from_bytes(&std::fs::read(path)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    fn sample() -> Archive {
        let mut a = Archive::new(json::obj(vec![
            ("tau", json::num(0.5)),
            ("dataset", json::s("s3d")),
        ]));
        a.add_section("HLAT", vec![1, 2, 3]);
        a.add_section("GBAS", vec![9; 100]);
        a.add_section("GIDX", vec![]);
        a
    }

    #[test]
    fn round_trip() {
        let a = sample();
        let bytes = a.to_bytes();
        assert_eq!(bytes.len(), a.total_bytes());
        let b = Archive::from_bytes(&bytes).unwrap();
        assert_eq!(b.header.get("dataset").unwrap().as_str(), Some("s3d"));
        assert_eq!(b.section("HLAT").unwrap(), &[1, 2, 3]);
        assert_eq!(b.section("GBAS").unwrap().len(), 100);
        assert_eq!(b.section("GIDX").unwrap().len(), 0);
        assert!(b.section("NOPE").is_err());
    }

    #[test]
    fn cr_payload_excludes_basis() {
        let a = sample();
        assert_eq!(a.cr_payload_bytes(), 3); // HLAT + GIDX only
    }

    #[test]
    fn rejects_corruption() {
        let mut bytes = sample().to_bytes();
        bytes[0] = b'X';
        assert!(Archive::from_bytes(&bytes).is_err());
        let bytes2 = sample().to_bytes();
        assert!(Archive::from_bytes(&bytes2[..bytes2.len() - 5]).is_err());
        assert!(Archive::from_bytes(&[]).is_err());
    }

    #[test]
    fn file_round_trip() {
        let dir = std::env::temp_dir().join("attn_reduce_fmt_test");
        let path = dir.join("a.ardc");
        sample().save(&path).unwrap();
        let back = Archive::load(&path).unwrap();
        assert_eq!(back.section("HLAT").unwrap(), &[1, 2, 3]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    #[should_panic(expected = "duplicate")]
    fn duplicate_sections_panic() {
        let mut a = sample();
        a.add_section("HLAT", vec![]);
    }

    #[test]
    fn set_header_inserts_and_replaces() {
        let mut a = sample();
        a.set_header("codec", json::s("sz3"));
        assert_eq!(a.header_str("codec").unwrap(), "sz3");
        a.set_header("codec", json::s("zfp"));
        assert_eq!(a.header_str("codec").unwrap(), "zfp");
        // existing keys untouched
        assert_eq!(a.header_str("dataset").unwrap(), "s3d");
        assert!(a.header_str("nope").is_err());
    }

    fn sample_v2() -> Archive {
        // two embedded single-field archives with different payloads
        let mut f0 = Archive::new(json::obj(vec![("codec", json::s("sz3"))]));
        f0.add_section("SZ3B", vec![7; 10]);
        f0.add_section("GBAS", vec![1; 40]); // basis: never counted
        let mut f1 = Archive::new(json::obj(vec![("codec", json::s("sz3"))]));
        f1.add_section("SZ3B", vec![8; 25]);
        let mut v2 = Archive::new_v2(json::obj(vec![
            ("codec", json::s("sz3")),
            (
                "fields",
                Value::Arr(vec![json::s("temp"), json::s("pressure")]),
            ),
        ]));
        v2.add_field_archive(&f0);
        v2.add_field_archive(&f1);
        v2
    }

    #[test]
    fn v2_round_trips_with_version_and_fields() {
        let v2 = sample_v2();
        assert_eq!(v2.version(), VERSION_V2);
        assert!(v2.is_multi_field());
        let back = Archive::from_bytes(&v2.to_bytes()).unwrap();
        assert_eq!(back.version(), VERSION_V2);
        assert_eq!(back.field_count(), 2);
        assert_eq!(back.field_names().unwrap(), vec!["temp", "pressure"]);
        let f1 = back.field_archive(1).unwrap();
        assert_eq!(f1.section("SZ3B").unwrap(), &[8; 25]);
        assert!(back.field_archive(2).is_err());
    }

    #[test]
    fn v2_accounting_counts_per_field_payload_only() {
        // pins the paper accounting for multi-field containers: the CR
        // payload is the sum of the embedded archives' payload sections
        // (10 + 25 here) — per-field headers, the GBAS basis, and the
        // container framing are all excluded
        let v2 = sample_v2();
        assert_eq!(v2.cr_payload_bytes(), 10 + 25);
        // and it survives serialization
        let back = Archive::from_bytes(&v2.to_bytes()).unwrap();
        assert_eq!(back.cr_payload_bytes(), 35);
        // total bytes count everything (framing + embedded headers)
        assert!(back.total_bytes() > 35 + 40);
        // section sizes are expanded and namespaced by field name
        let sizes = back.section_sizes();
        assert!(sizes.contains(&("temp/SZ3B".to_string(), 10)));
        assert!(sizes.contains(&("temp/GBAS".to_string(), 40)));
        assert!(sizes.contains(&("pressure/SZ3B".to_string(), 25)));
    }

    #[test]
    fn v1_archives_still_parse_as_single_field() {
        let a = sample();
        assert_eq!(a.version(), VERSION_V1);
        assert!(!a.is_multi_field());
        let back = Archive::from_bytes(&a.to_bytes()).unwrap();
        assert_eq!(back.version(), VERSION_V1);
        assert!(back.field_names().is_err());
        // the F-tag filter never hides ordinary v1 sections
        assert_eq!(back.cr_payload_bytes(), 3);
    }

    #[test]
    fn unknown_sections_survive_round_trip() {
        let mut a = sample();
        a.add_section("ZZZZ", vec![42; 7]); // future writer's section
        let b = Archive::from_bytes(&a.to_bytes()).unwrap();
        assert_eq!(b.section("ZZZZ").unwrap(), &[42; 7]);
        assert_eq!(b.section("HLAT").unwrap(), &[1, 2, 3]);
    }
}
