//! Streaming coordinator (L3).
//!
//! PJRT wrapper types are `!Send`, so the orchestrator pins the PJRT stage
//! to the calling thread and pipelines the CPU-side stages around it with
//! scoped worker threads + bounded channels (backpressure):
//!
//! ```text
//!   [gather thread] --(batches, cap Q)--> [PJRT stage, this thread]
//!        --(latents+recon, cap Q)--> [sink thread: quantize codes,
//!                                     scatter recon, entropy accounting]
//! ```
//!
//! The bounded channels keep the PJRT executor saturated while the gather
//! and entropy stages overlap with it; `queue_depth` trades memory for
//! smoothing. Used by the `climate_stream` example and the pipeline
//! bench; per-stage busy times are reported for the perf log.

use std::sync::mpsc::{Receiver, SyncSender};
use std::time::Instant;

use crate::coder::Quantizer;
use crate::compressor::HierCompressor;
use crate::data::{Blocking, Normalizer};
use crate::runtime::HostTensor;
use crate::tensor::Tensor;
use crate::Result;
use anyhow::ensure;

/// Per-stage timing + throughput of one streaming pass.
#[derive(Debug, Clone, Default)]
pub struct StreamStats {
    pub hyperblocks: usize,
    pub batches: usize,
    pub raw_bytes: usize,
    pub latent_bytes: usize,
    pub wall_s: f64,
    pub gather_busy_s: f64,
    pub pjrt_busy_s: f64,
    pub sink_busy_s: f64,
}

impl StreamStats {
    pub fn throughput_mb_s(&self) -> f64 {
        self.raw_bytes as f64 / 1e6 / self.wall_s.max(1e-9)
    }

    pub fn summary(&self) -> String {
        format!(
            "{} hyper-blocks in {} batches, {:.1} MB in {:.2}s ({:.1} MB/s); busy: gather {:.2}s, pjrt {:.2}s, sink {:.2}s",
            self.hyperblocks,
            self.batches,
            self.raw_bytes as f64 / 1e6,
            self.wall_s,
            self.throughput_mb_s(),
            self.gather_busy_s,
            self.pjrt_busy_s,
            self.sink_busy_s
        )
    }
}

struct BatchMsg {
    h0: usize,
    data: Vec<f32>, // [nh, k, bd]
    gather_s: f64,
}

struct LatentMsg {
    h0: usize,
    lh: Vec<f32>,
    lb: Vec<f32>,
    recon: Vec<f32>,
    gather_s: f64,
    pjrt_s: f64,
}

/// Output of a streaming compression pass.
pub struct StreamOutput {
    /// Reconstruction in the normalized domain (pre-GAE).
    pub recon: Tensor,
    /// Quantized latent codes (HBAE then BAE streams).
    pub lh_codes: Vec<i32>,
    pub lb_codes: Vec<i32>,
    pub stats: StreamStats,
}

/// Stream a normalized field through the AE stack with pipelined stages.
///
/// Functionally equivalent to the sequential path in
/// [`HierCompressor::compress`] up to the entropy stage; exists to
/// demonstrate + measure the overlapped L3 design. The unified-codec
/// entry point is [`crate::codec::HierCodec::compress_streaming`], which
/// runs this and then assembles the same self-describing archive as the
/// one-shot path.
pub fn stream_forward(
    comp: &HierCompressor,
    norm: &Tensor,
    queue_depth: usize,
) -> Result<StreamOutput> {
    ensure!(comp.baes.len() == 1, "streaming path expects exactly one BAE");
    let blocking = Blocking::new(&comp.dataset);
    let bd = blocking.block_dim();
    let k = blocking.k;
    let enc = comp.rt.load(&comp.hbae.group, "encode")?;
    let dec = comp.rt.load(&comp.hbae.group, "decode")?;
    let benc = comp.rt.load(&comp.baes[0].group, "encode")?;
    let bdec = comp.rt.load(&comp.baes[0].group, "decode")?;
    let nh_batch = enc.info.inputs[1].shape[0];
    let lh_dim = enc.info.outputs[0].shape[1];
    let lb_dim = benc.info.outputs[0].shape[1];
    let total_hb = blocking.num_hyperblocks();
    let qh = Quantizer::new(comp.model.bin_hbae.max(0.0));
    let qb = Quantizer::new(comp.model.bin_bae.max(0.0));

    let theta = HostTensor::vec(comp.hbae.theta.clone());
    let phi = HostTensor::vec(comp.baes[0].theta.clone());

    let t0 = Instant::now();
    let mut stats = StreamStats {
        raw_bytes: norm.len() * 4,
        ..Default::default()
    };

    let (batch_tx, batch_rx): (SyncSender<BatchMsg>, Receiver<BatchMsg>) =
        std::sync::mpsc::sync_channel(queue_depth);
    let (lat_tx, lat_rx): (SyncSender<LatentMsg>, Receiver<LatentMsg>) =
        std::sync::mpsc::sync_channel(queue_depth);

    let mut recon = Tensor::zeros(comp.dataset.dims.clone());
    let mut lh_codes: Vec<i32> = Vec::new();
    let mut lb_codes: Vec<i32> = Vec::new();
    let mut sink_busy = 0.0f64;
    let mut gather_busy = 0.0f64;
    let mut pjrt_busy = 0.0f64;

    std::thread::scope(|scope| -> Result<()> {
        // ---- stage 1: gather (worker thread) ----
        let blocking_ref = &blocking;
        scope.spawn(move || {
            for h0 in (0..total_hb).step_by(nh_batch) {
                let g0 = Instant::now();
                let mut data = vec![0f32; nh_batch * k * bd];
                blocking_ref.gather(norm, h0, nh_batch, &mut data);
                let gather_s = g0.elapsed().as_secs_f64();
                if batch_tx.send(BatchMsg { h0, data, gather_s }).is_err() {
                    return; // downstream hung up
                }
            }
        });

        // ---- stage 3: sink (worker thread) ----
        let sink = scope.spawn(move || {
            let mut recon = Tensor::zeros(blocking_ref.dims.clone());
            let mut lh_codes = Vec::new();
            let mut lb_codes = Vec::new();
            let mut busy = 0.0f64;
            let mut gather_busy = 0.0;
            let mut pjrt_busy = 0.0;
            let mut batches = 0usize;
            for msg in lat_rx {
                let s0 = Instant::now();
                gather_busy += msg.gather_s;
                pjrt_busy += msg.pjrt_s;
                batches += 1;
                let n_here = (total_hb - msg.h0).min(nh_batch);
                if qh.enabled() {
                    // block-parallel on the shared executor (Quantizer::codes
                    // chunks deterministically)
                    lh_codes.extend(qh.codes(&msg.lh[..n_here * lh_dim]));
                }
                if qb.enabled() {
                    for hi in 0..n_here {
                        for j in 0..k {
                            if blocking_ref.is_valid(msg.h0 + hi, j) {
                                let r = hi * k + j;
                                lb_codes.extend(
                                    msg.lb[r * lb_dim..(r + 1) * lb_dim]
                                        .iter()
                                        .map(|&v| qb.code(v)),
                                );
                            }
                        }
                    }
                }
                blocking_ref.scatter(&mut recon, msg.h0, nh_batch, &msg.recon);
                busy += s0.elapsed().as_secs_f64();
            }
            (recon, lh_codes, lb_codes, busy, gather_busy, pjrt_busy, batches)
        });

        // ---- stage 2: PJRT (this thread — the client is !Send) ----
        for msg in batch_rx {
            let p0 = Instant::now();
            let bt = HostTensor::new(vec![nh_batch, k, bd], msg.data.clone());
            let mut lh = enc.run(&[theta.clone(), bt])?.remove(0);
            qh.snap(&mut lh.data);
            let y = dec.run(&[theta.clone(), lh.clone()])?.remove(0);
            let resid: Vec<f32> =
                msg.data.iter().zip(&y.data).map(|(&a, &b)| a - b).collect();
            let mut lb = benc
                .run(&[phi.clone(), HostTensor::new(vec![nh_batch * k, bd], resid)])?
                .remove(0);
            qb.snap(&mut lb.data);
            let rhat = bdec.run(&[phi.clone(), lb.clone()])?.remove(0);
            let recon_batch: Vec<f32> =
                y.data.iter().zip(&rhat.data).map(|(&a, &b)| a + b).collect();
            let pjrt_s = p0.elapsed().as_secs_f64();
            let _ = lat_tx.send(LatentMsg {
                h0: msg.h0,
                lh: lh.data,
                lb: lb.data,
                recon: recon_batch,
                gather_s: msg.gather_s,
                pjrt_s,
            });
        }
        drop(lat_tx);
        let (r, lh, lb, busy, g, p, batches) =
            sink.join().map_err(|_| anyhow::anyhow!("sink panicked"))?;
        recon = r;
        lh_codes = lh;
        lb_codes = lb;
        sink_busy = busy;
        gather_busy = g;
        pjrt_busy = p;
        stats.batches = batches;
        Ok(())
    })?;

    stats.hyperblocks = total_hb;
    stats.wall_s = t0.elapsed().as_secs_f64();
    stats.gather_busy_s = gather_busy;
    stats.pjrt_busy_s = pjrt_busy;
    stats.sink_busy_s = sink_busy;
    stats.latent_bytes = lh_codes.len() * 4 + lb_codes.len() * 4;

    Ok(StreamOutput { recon, lh_codes, lb_codes, stats })
}

/// Convenience wrapper: normalize, stream, report.
pub fn stream_compress(
    comp: &HierCompressor,
    field: &Tensor,
    queue_depth: usize,
) -> Result<StreamOutput> {
    let stats = Normalizer::fit(comp.dataset.normalization, field);
    let mut norm = field.clone();
    Normalizer::apply(&stats, &mut norm);
    stream_forward(comp, &norm, queue_depth)
}
