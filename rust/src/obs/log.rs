//! Leveled structured logging: one `key=value` line per record.
//!
//! Records go to stderr as a single pre-formatted line
//! (`ts=<unix.millis> level=<lvl> target=<module> <message>`), written
//! under one lock acquisition so concurrent handler threads can no
//! longer interleave fragments (the old ad-hoc `eprintln!` request and
//! panic logging could). Filtering happens before formatting — a
//! disabled level costs one relaxed atomic load; use the
//! [`crate::log_at!`] macro so the `format!` is skipped entirely.
//!
//! The level comes from `--log-level` (error|warn|info|debug), default
//! `info`; `--quiet` / `ATTN_REDUCE_QUIET=1` drops to `error`.

use std::io::Write as _;
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

impl Level {
    pub fn as_str(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }

    pub fn parse(s: &str) -> Option<Level> {
        match s {
            "error" => Some(Level::Error),
            "warn" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            _ => None,
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);
static NEXT_REQUEST_ID: AtomicU64 = AtomicU64::new(1);

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        _ => Level::Debug,
    }
}

#[inline]
pub fn enabled(lvl: Level) -> bool {
    lvl as u8 <= LEVEL.load(Ordering::Relaxed)
}

/// Monotonic per-process request id for correlating log lines.
pub fn next_request_id() -> u64 {
    NEXT_REQUEST_ID.fetch_add(1, Ordering::Relaxed)
}

/// Emit one record. `message` should already be `key=value` formatted;
/// prefer [`crate::log_at!`], which skips formatting below the level.
pub fn write(lvl: Level, target: &str, message: &str) {
    let ts = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .unwrap_or_default();
    let line = format!(
        "ts={}.{:03} level={} target={} {}\n",
        ts.as_secs(),
        ts.subsec_millis(),
        lvl.as_str(),
        target,
        message
    );
    let mut err = std::io::stderr().lock();
    let _ = err.write_all(line.as_bytes());
}

/// Log at `level` under `target`, formatting lazily: the `format!` only
/// runs when the level is enabled.
#[macro_export]
macro_rules! log_at {
    ($lvl:expr, $target:expr, $($arg:tt)*) => {{
        if $crate::obs::log::enabled($lvl) {
            $crate::obs::log::write($lvl, $target, &format!($($arg)*));
        }
    }};
}
