//! Metric exposition: Prometheus text format and a JSON mirror.
//!
//! Both renderers consume [`FamilySnapshot`]s, so callers can compose
//! one exposition out of several sources (a server's per-instance
//! registry, the process-global registry, and hand-built families such
//! as the LRU cache's snapshot counters) — see
//! `serve/server.rs::metrics`.

use std::fmt::Write as _;

use super::registry::{FamilySnapshot, Kind, SeriesSnapshot, SeriesValue};
use crate::util::json;

/// Format a float the way Prometheus expects: integers without a
/// decimal point, `+Inf` for infinity, shortest-round-trip otherwise.
fn fmt_f64(v: f64) -> String {
    if v.is_infinite() {
        return if v > 0.0 { "+Inf".into() } else { "-Inf".into() };
    }
    if v == v.trunc() && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

/// Escape a label value per the text-format rules.
fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

fn label_block(labels: &[(String, String)], extra: Option<(&str, &str)>) -> String {
    let mut parts: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("{k}=\"{}\"", escape_label(v)))
        .collect();
    if let Some((k, v)) = extra {
        parts.push(format!("{k}=\"{}\"", escape_label(v)));
    }
    if parts.is_empty() {
        String::new()
    } else {
        format!("{{{}}}", parts.join(","))
    }
}

/// Render families (sorted by name first — callers may concatenate
/// several sources) as Prometheus text exposition format.
pub fn render_text(families: &[FamilySnapshot]) -> String {
    let mut order: Vec<&FamilySnapshot> = families.iter().collect();
    order.sort_by(|a, b| a.name.cmp(&b.name));
    let mut out = String::new();
    for fam in order {
        let _ = writeln!(out, "# HELP {} {}", fam.name, fam.help);
        let _ = writeln!(out, "# TYPE {} {}", fam.name, fam.kind.as_str());
        for s in &fam.series {
            match &s.value {
                SeriesValue::Counter(v) => {
                    let _ = writeln!(out, "{}{} {}", fam.name, label_block(&s.labels, None), v);
                }
                SeriesValue::Gauge(v) => {
                    let _ = writeln!(
                        out,
                        "{}{} {}",
                        fam.name,
                        label_block(&s.labels, None),
                        fmt_f64(*v)
                    );
                }
                SeriesValue::Histogram { buckets, sum, count } => {
                    for (le, cum) in buckets {
                        let le_s = fmt_f64(*le);
                        let _ = writeln!(
                            out,
                            "{}_bucket{} {}",
                            fam.name,
                            label_block(&s.labels, Some(("le", le_s.as_str()))),
                            cum
                        );
                    }
                    let _ = writeln!(
                        out,
                        "{}_sum{} {}",
                        fam.name,
                        label_block(&s.labels, None),
                        fmt_f64(*sum)
                    );
                    let _ = writeln!(
                        out,
                        "{}_count{} {}",
                        fam.name,
                        label_block(&s.labels, None),
                        count
                    );
                }
            }
        }
    }
    out
}

fn labels_json(s: &SeriesSnapshot) -> json::Value {
    json::Value::Obj(
        s.labels
            .iter()
            .map(|(k, v)| (k.clone(), json::s(v.clone())))
            .collect(),
    )
}

/// The same snapshot as a JSON document (`/v1/metrics?format=json`):
/// `{"families": [{"name", "type", "help", "series": [...]}]}`.
pub fn render_json(families: &[FamilySnapshot]) -> json::Value {
    let mut order: Vec<&FamilySnapshot> = families.iter().collect();
    order.sort_by(|a, b| a.name.cmp(&b.name));
    let fams = order
        .iter()
        .map(|fam| {
            let series = fam
                .series
                .iter()
                .map(|s| match &s.value {
                    SeriesValue::Counter(v) => json::obj(vec![
                        ("labels", labels_json(s)),
                        ("value", json::num(*v as f64)),
                    ]),
                    SeriesValue::Gauge(v) => {
                        json::obj(vec![("labels", labels_json(s)), ("value", json::num(*v))])
                    }
                    SeriesValue::Histogram { buckets, sum, count } => json::obj(vec![
                        ("labels", labels_json(s)),
                        (
                            "buckets",
                            json::Value::Arr(
                                buckets
                                    .iter()
                                    .map(|(le, cum)| {
                                        json::obj(vec![
                                            (
                                                "le",
                                                if le.is_infinite() {
                                                    json::s("+Inf")
                                                } else {
                                                    json::num(*le)
                                                },
                                            ),
                                            ("count", json::num(*cum as f64)),
                                        ])
                                    })
                                    .collect(),
                            ),
                        ),
                        ("sum", json::num(*sum)),
                        ("count", json::num(*count as f64)),
                    ]),
                })
                .collect();
            json::obj(vec![
                ("name", json::s(fam.name.clone())),
                ("type", json::s(fam.kind.as_str())),
                ("help", json::s(fam.help.clone())),
                ("series", json::Value::Arr(series)),
            ])
        })
        .collect();
    json::obj(vec![("families", json::Value::Arr(fams))])
}

/// Build a counter family from an already-aggregated value (sources
/// that keep their own counters, e.g. the serve LRU cache snapshot).
pub fn counter_family(name: &str, help: &str, value: u64) -> FamilySnapshot {
    FamilySnapshot {
        name: name.to_string(),
        help: help.to_string(),
        kind: Kind::Counter,
        series: vec![SeriesSnapshot { labels: Vec::new(), value: SeriesValue::Counter(value) }],
    }
}

/// Build a gauge family from an already-aggregated value.
pub fn gauge_family(name: &str, help: &str, value: f64) -> FamilySnapshot {
    FamilySnapshot {
        name: name.to_string(),
        help: help.to_string(),
        kind: Kind::Gauge,
        series: vec![SeriesSnapshot { labels: Vec::new(), value: SeriesValue::Gauge(value) }],
    }
}
