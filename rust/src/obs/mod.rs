//! Observability: metrics registry, per-stage spans, Chrome tracing,
//! and structured logging — std-only, no external deps.
//!
//! Three consumers share the same data:
//! - `GET /v1/metrics` renders the registries as Prometheus text (or
//!   JSON with `?format=json`);
//! - `--trace FILE` writes the recorded spans as Chrome `trace_event`
//!   JSON for Perfetto;
//! - `--verbose` dumps the global registry to stderr after one-shot
//!   CLI commands.
//!
//! Stable-name policy: every exported family below is API — renames
//! are breaking changes and get called out in README "Observability".

pub mod expo;
pub mod log;
pub mod registry;
pub mod trace;

pub use registry::{
    Counter, FamilySnapshot, Gauge, Histogram, Kind, Registry, SeriesSnapshot, SeriesValue,
    DURATION_BOUNDS_NS, SCALE_NS_TO_SECONDS,
};
pub use trace::{Span, SpanContext, StageTimer};

/// The pipeline's stage timers — one static per stage so every module
/// shares the same `attn_stage_duration_seconds{stage=...}` series.
pub mod stages {
    use super::trace::StageTimer;

    /// sz3 Lorenzo predict + quantize over the tile lattice (encode).
    pub static SZ3_PREDICT_QUANTIZE: StageTimer = StageTimer::new("sz3.predict_quantize");
    /// sz3 code-stream reconstruction (decode).
    pub static SZ3_RECONSTRUCT: StageTimer = StageTimer::new("sz3.reconstruct");
    /// zfp block transform + quantize (encode).
    pub static ZFP_TRANSFORM: StageTimer = StageTimer::new("zfp.transform");
    /// zfp block reconstruction (decode).
    pub static ZFP_RECONSTRUCT: StageTimer = StageTimer::new("zfp.reconstruct");
    /// Symbol-container entropy encode (mode select + code).
    pub static ENTROPY_ENCODE: StageTimer = StageTimer::new("entropy.encode");
    /// Symbol-container entropy decode.
    pub static ENTROPY_DECODE: StageTimer = StageTimer::new("entropy.decode");
    /// Adaptive per-tile codec trial compress (`codec/adaptive.rs`).
    pub static ADAPTIVE_TRIAL: StageTimer = StageTimer::new("adaptive.trial");
    /// One tile through its codec (encode side, executor workers).
    pub static TILE_ENCODE: StageTimer = StageTimer::new("tile.encode");
    /// One tile through its codec (decode side, executor workers).
    pub static TILE_DECODE: StageTimer = StageTimer::new("tile.decode");
    /// GAE/PCA guaranteed-error-bound post-process (residual pass).
    pub static GAE_POSTPROCESS: StageTimer = StageTimer::new("gae.postprocess");
    /// One GOP appended to a v4 stream.
    pub static STREAM_APPEND_GOP: StageTimer = StageTimer::new("stream.append_gop");
    /// One `(step, region)` extracted from a v4 stream.
    pub static STREAM_EXTRACT: StageTimer = StageTimer::new("stream.extract");
    /// Serve LRU probe.
    pub static CACHE_GET: StageTimer = StageTimer::new("cache.get");
    /// Serve LRU admission (including evictions it triggers).
    pub static CACHE_INSERT: StageTimer = StageTimer::new("cache.insert");
    /// One HTTP request end-to-end (also in the per-server route
    /// histogram `attn_request_duration_seconds`).
    pub static SERVE_REQUEST: StageTimer = StageTimer::new("serve.request");

    pub fn all() -> [&'static StageTimer; 15] {
        [
            &SZ3_PREDICT_QUANTIZE,
            &SZ3_RECONSTRUCT,
            &ZFP_TRANSFORM,
            &ZFP_RECONSTRUCT,
            &ENTROPY_ENCODE,
            &ENTROPY_DECODE,
            &ADAPTIVE_TRIAL,
            &TILE_ENCODE,
            &TILE_DECODE,
            &GAE_POSTPROCESS,
            &STREAM_APPEND_GOP,
            &STREAM_EXTRACT,
            &CACHE_GET,
            &CACHE_INSERT,
            &SERVE_REQUEST,
        ]
    }
}

const ENTROPY_HELP: &str = "Symbol streams by container mode and direction";
const CORRUPTION_HELP: &str =
    "Integrity failures detected on read (bad XSUM/CRC, torn framing)";
const DURABLE_HELP: &str = "Atomic write attempts by outcome (committed|failed)";
const SHED_HELP: &str = "Connections shed with 503 by serve overload backpressure";
const ADAPTIVE_TILES_HELP: &str = "Tiles committed per codec by adaptive selection";
const ADAPTIVE_SKIPS_HELP: &str =
    "Tiles where the sampled gate skipped the zfp trial (sz3 taken without certification)";

/// Count one symbol stream through the entropy coder.
/// `mode` ∈ plain|zero_run|const|rans, `dir` ∈ encode|decode.
pub fn entropy_stream(mode: &'static str, dir: &'static str) {
    if !trace::enabled() {
        return;
    }
    Registry::global()
        .counter("attn_entropy_streams_total", ENTROPY_HELP, &[("mode", mode), ("dir", dir)])
        .inc();
}

/// Count one tile committed by adaptive selection. `codec` ∈ sz3|zfp.
pub fn adaptive_tile(codec: &'static str) {
    if !trace::enabled() {
        return;
    }
    Registry::global()
        .counter("attn_adaptive_tiles_total", ADAPTIVE_TILES_HELP, &[("codec", codec)])
        .inc();
}

/// Count one tile where the sampled gate skipped the zfp trial.
pub fn adaptive_gate_skip() {
    if !trace::enabled() {
        return;
    }
    Registry::global()
        .counter("attn_adaptive_gate_skips_total", ADAPTIVE_SKIPS_HELP, &[])
        .inc();
}

/// Count one detected integrity failure. Unlike the stage counters
/// this is NOT trace-gated: corruption must be visible in production.
pub fn corruption_detected() {
    Registry::global()
        .counter("attn_corruption_detected_total", CORRUPTION_HELP, &[])
        .inc();
}

/// Count one atomic-write attempt. `outcome` ∈ committed|failed.
/// Not trace-gated — durability outcomes must always be visible.
pub fn durable_write(outcome: &'static str) {
    Registry::global()
        .counter("attn_durable_writes_total", DURABLE_HELP, &[("outcome", outcome)])
        .inc();
}

/// Count one connection shed by serve backpressure (global registry;
/// the per-server registry keeps its own copy for `/v1/metrics`).
pub fn request_shed() {
    Registry::global()
        .counter("attn_requests_shed_total", SHED_HELP, &[])
        .inc();
}

/// Help string for the per-server shed counter (serve registers the
/// same family in its own registry so `/v1/metrics` exports it).
pub const REQUESTS_SHED_HELP: &str = SHED_HELP;

/// Materialize every global family with zero values so scrapers (and
/// the CI metrics smoke leg) see the full catalog before traffic.
/// Idempotent; called from `serve` startup and `--verbose` dumps.
pub fn preregister() {
    for t in stages::all() {
        t.hist();
    }
    let reg = Registry::global();
    for mode in ["plain", "zero_run", "const", "rans"] {
        for dir in ["encode", "decode"] {
            reg.counter(
                "attn_entropy_streams_total",
                ENTROPY_HELP,
                &[("mode", mode), ("dir", dir)],
            );
        }
    }
    for codec in ["sz3", "zfp"] {
        reg.counter("attn_adaptive_tiles_total", ADAPTIVE_TILES_HELP, &[("codec", codec)]);
    }
    reg.counter("attn_adaptive_gate_skips_total", ADAPTIVE_SKIPS_HELP, &[]);
    reg.counter("attn_corruption_detected_total", CORRUPTION_HELP, &[]);
    for outcome in ["committed", "failed"] {
        reg.counter("attn_durable_writes_total", DURABLE_HELP, &[("outcome", outcome)]);
    }
    reg.counter("attn_requests_shed_total", SHED_HELP, &[]);
}

/// The global registry rendered as Prometheus text (the `--verbose`
/// post-command dump).
pub fn dump_text() -> String {
    expo::render_text(&Registry::global().snapshot())
}
