//! Process-global metrics registry: typed counters, gauges, and
//! fixed-bucket histograms behind `&'static` handles.
//!
//! Registration takes a lock and leaks the metric (`Box::leak`) so the
//! returned handle is `&'static` and every subsequent update is a bare
//! relaxed atomic — no locking, formatting, or allocation on the hot
//! path. Callers cache handles (see [`crate::obs::StageTimer`]) so the
//! registry lock is only touched once per call site.
//!
//! Histograms store raw integer observations (nanoseconds for
//! durations, bytes for sizes) in ascending `le` buckets plus an
//! implicit `+Inf` bucket; `unit_scale` converts raw units to the
//! exposition unit (e.g. `1e-9` renders nanoseconds as seconds, the
//! Prometheus base unit).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Monotonically increasing counter.
#[derive(Default)]
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    pub const fn new() -> Counter {
        Counter { v: AtomicU64::new(0) }
    }

    #[inline]
    pub fn inc(&self) {
        self.v.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Last-write-wins integer gauge (entries, bytes, capacities).
#[derive(Default)]
pub struct Gauge {
    v: AtomicU64,
}

impl Gauge {
    pub const fn new() -> Gauge {
        Gauge { v: AtomicU64::new(0) }
    }

    #[inline]
    pub fn set(&self, v: u64) {
        self.v.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Duration bucket upper bounds in nanoseconds: 1 µs → 10 s, roughly
/// ×4 per step. Covers a cache probe (~µs) through a paper-scale
/// compress (~s) in 13 buckets.
pub const DURATION_BOUNDS_NS: &[u64] = &[
    1_000,
    4_000,
    16_000,
    64_000,
    250_000,
    1_000_000,
    4_000_000,
    16_000_000,
    64_000_000,
    250_000_000,
    1_000_000_000,
    4_000_000_000,
    10_000_000_000,
];

/// Renders nanosecond observations as seconds (Prometheus base unit).
pub const SCALE_NS_TO_SECONDS: f64 = 1e-9;

/// Fixed-bucket histogram over non-negative integer observations.
pub struct Histogram {
    bounds: Vec<u64>,
    /// Per-bucket (non-cumulative) counts; `buckets[bounds.len()]` is
    /// the `+Inf` bucket.
    buckets: Vec<AtomicU64>,
    sum: AtomicU64,
    count: AtomicU64,
    unit_scale: f64,
}

impl Histogram {
    fn new(bounds: &[u64], unit_scale: f64) -> Histogram {
        assert!(bounds.windows(2).all(|w| w[0] < w[1]), "histogram bounds must ascend");
        Histogram {
            bounds: bounds.to_vec(),
            buckets: (0..=bounds.len()).map(|_| AtomicU64::new(0)).collect(),
            sum: AtomicU64::new(0),
            count: AtomicU64::new(0),
            unit_scale,
        }
    }

    /// Record one raw-unit observation (`le` semantics: a value equal
    /// to a bound lands in that bound's bucket).
    #[inline]
    pub fn observe(&self, raw: u64) {
        let i = self.bounds.partition_point(|&b| raw > b);
        self.buckets[i].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(raw, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of observations in raw units.
    pub fn sum_raw(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Sum in exposition units.
    pub fn sum_scaled(&self) -> f64 {
        self.sum_raw() as f64 * self.unit_scale
    }

    /// Per-bucket non-cumulative counts (last entry is `+Inf`).
    pub fn bucket_counts(&self) -> Vec<u64> {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }

    /// Bucket upper bounds in raw units (without the `+Inf` bucket).
    pub fn bounds_raw(&self) -> &[u64] {
        &self.bounds
    }

    pub fn unit_scale(&self) -> f64 {
        self.unit_scale
    }

    /// Estimate the `q`-quantile (0..=1) in exposition units by linear
    /// interpolation inside the containing bucket — the standard
    /// bucketed estimate, exact only at bucket boundaries. Observations
    /// in the `+Inf` bucket clamp to the largest finite bound. Returns
    /// 0.0 on an empty histogram.
    pub fn quantile(&self, q: f64) -> f64 {
        let counts = self.bucket_counts();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let target = q.clamp(0.0, 1.0) * total as f64;
        let mut cum = 0u64;
        for (i, &c) in counts.iter().enumerate() {
            let prev = cum;
            cum += c;
            if (cum as f64) < target || c == 0 {
                continue;
            }
            let lo = if i == 0 { 0 } else { self.bounds[i - 1] };
            let hi = if i < self.bounds.len() {
                self.bounds[i]
            } else {
                // +Inf bucket: clamp at the largest finite bound
                return self.bounds.last().copied().unwrap_or(0) as f64 * self.unit_scale;
            };
            let frac = (target - prev as f64) / c as f64;
            return (lo as f64 + frac * (hi - lo) as f64) * self.unit_scale;
        }
        self.bounds.last().copied().unwrap_or(0) as f64 * self.unit_scale
    }
}

/// Metric family type, matching the Prometheus `# TYPE` keyword.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    Counter,
    Gauge,
    Histogram,
}

impl Kind {
    pub fn as_str(self) -> &'static str {
        match self {
            Kind::Counter => "counter",
            Kind::Gauge => "gauge",
            Kind::Histogram => "histogram",
        }
    }
}

#[derive(Clone, Copy)]
enum Handle {
    C(&'static Counter),
    G(&'static Gauge),
    H(&'static Histogram),
}

type Labels = Vec<(&'static str, String)>;

struct Family {
    help: &'static str,
    kind: Kind,
    series: BTreeMap<Labels, Handle>,
}

/// A named set of metric families. Most code talks to
/// [`Registry::global`]; the serving layer additionally keeps one
/// registry per server instance so request counters stay test-isolated
/// when several servers share a process.
#[derive(Default)]
pub struct Registry {
    families: Mutex<BTreeMap<&'static str, Family>>,
}

impl Registry {
    pub const fn new() -> Registry {
        Registry { families: Mutex::new(BTreeMap::new()) }
    }

    /// The process-wide registry (pipeline stages, entropy/codec
    /// counters).
    pub fn global() -> &'static Registry {
        static GLOBAL: Registry = Registry::new();
        &GLOBAL
    }

    fn register(
        &self,
        name: &'static str,
        help: &'static str,
        kind: Kind,
        labels: &[(&'static str, &str)],
        make: impl FnOnce() -> Handle,
    ) -> Handle {
        let mut fams = self.families.lock().unwrap();
        let fam = fams
            .entry(name)
            .or_insert_with(|| Family { help, kind, series: BTreeMap::new() });
        assert!(
            fam.kind == kind,
            "metric {name} registered as {} and {}",
            fam.kind.as_str(),
            kind.as_str()
        );
        let key: Labels = labels.iter().map(|(k, v)| (*k, v.to_string())).collect();
        *fam.series.entry(key).or_insert_with(make)
    }

    /// Register-or-fetch a counter series. The handle is `&'static`;
    /// cache it at the call site when the path is hot.
    pub fn counter(
        &self,
        name: &'static str,
        help: &'static str,
        labels: &[(&'static str, &str)],
    ) -> &'static Counter {
        match self.register(name, help, Kind::Counter, labels, || {
            Handle::C(Box::leak(Box::new(Counter::new())))
        }) {
            Handle::C(c) => c,
            _ => unreachable!("kind checked in register"),
        }
    }

    /// Register-or-fetch a gauge series.
    pub fn gauge(
        &self,
        name: &'static str,
        help: &'static str,
        labels: &[(&'static str, &str)],
    ) -> &'static Gauge {
        match self.register(name, help, Kind::Gauge, labels, || {
            Handle::G(Box::leak(Box::new(Gauge::new())))
        }) {
            Handle::G(g) => g,
            _ => unreachable!("kind checked in register"),
        }
    }

    /// Register-or-fetch a histogram series with the given raw-unit
    /// bucket bounds and exposition scale.
    pub fn histogram(
        &self,
        name: &'static str,
        help: &'static str,
        labels: &[(&'static str, &str)],
        bounds: &[u64],
        unit_scale: f64,
    ) -> &'static Histogram {
        match self.register(name, help, Kind::Histogram, labels, || {
            Handle::H(Box::leak(Box::new(Histogram::new(bounds, unit_scale))))
        }) {
            Handle::H(h) => h,
            _ => unreachable!("kind checked in register"),
        }
    }

    /// A point-in-time copy of every family, sorted by name (and label
    /// set within a family) for deterministic exposition.
    pub fn snapshot(&self) -> Vec<FamilySnapshot> {
        let fams = self.families.lock().unwrap();
        fams.iter()
            .map(|(name, fam)| FamilySnapshot {
                name: name.to_string(),
                help: fam.help.to_string(),
                kind: fam.kind,
                series: fam
                    .series
                    .iter()
                    .map(|(labels, h)| SeriesSnapshot {
                        labels: labels
                            .iter()
                            .map(|(k, v)| (k.to_string(), v.clone()))
                            .collect(),
                        value: match h {
                            Handle::C(c) => SeriesValue::Counter(c.get()),
                            Handle::G(g) => SeriesValue::Gauge(g.get() as f64),
                            Handle::H(hist) => {
                                let counts = hist.bucket_counts();
                                let mut cum = 0u64;
                                let mut buckets = Vec::with_capacity(counts.len());
                                for (i, &c) in counts.iter().enumerate() {
                                    cum += c;
                                    let le = if i < hist.bounds.len() {
                                        hist.bounds[i] as f64 * hist.unit_scale
                                    } else {
                                        f64::INFINITY
                                    };
                                    buckets.push((le, cum));
                                }
                                SeriesValue::Histogram {
                                    buckets,
                                    sum: hist.sum_scaled(),
                                    count: hist.count(),
                                }
                            }
                        },
                    })
                    .collect(),
            })
            .collect()
    }
}

/// One exposition-ready series: labels plus a typed value. Histogram
/// buckets are cumulative (`le`-style) in exposition units.
pub struct SeriesSnapshot {
    pub labels: Vec<(String, String)>,
    pub value: SeriesValue,
}

pub enum SeriesValue {
    Counter(u64),
    Gauge(f64),
    Histogram { buckets: Vec<(f64, u64)>, sum: f64, count: u64 },
}

/// One exposition-ready metric family.
pub struct FamilySnapshot {
    pub name: String,
    pub help: String,
    pub kind: Kind,
    pub series: Vec<SeriesSnapshot>,
}
