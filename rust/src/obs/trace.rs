//! RAII spans with per-stage histograms and an optional Chrome
//! `trace_event` sink.
//!
//! A [`StageTimer`] is a `static` naming one pipeline stage; its
//! [`StageTimer::span`] returns a guard that records elapsed wall time
//! into the global `attn_stage_duration_seconds{stage=...}` histogram
//! on drop. The histogram handle is resolved once per call site
//! (`OnceLock`), so the steady-state cost of a span is two `Instant`
//! reads and three relaxed atomic adds — cheap enough to leave on in
//! production (pinned ≤2% on the dense entropy-decode bench leg).
//!
//! When tracing is armed (`--trace FILE`), every span additionally
//! buffers a complete (`"ph":"X"`) event; [`write_chrome_trace`]
//! serializes the buffer as Chrome `trace_event` JSON, loadable in
//! Perfetto / `about:tracing`. Span parentage is tracked through a
//! thread-local, and the [`crate::engine::Executor`] captures the
//! submitting thread's span context at batch submission and installs
//! it on its pool workers (exactly like codec forcing), so worker-side
//! spans nest under the request or CLI command that spawned them.

use std::cell::Cell;
use std::io::Write as _;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use super::registry::{Histogram, Registry, DURATION_BOUNDS_NS, SCALE_NS_TO_SECONDS};

/// Master switch for span recording (on by default; the overhead bench
/// turns it off to measure the instrumentation's cost).
static ENABLED: AtomicBool = AtomicBool::new(true);
/// Whether spans also buffer trace events (off unless `--trace`).
static TRACING: AtomicBool = AtomicBool::new(false);
static NEXT_SPAN_ID: AtomicU64 = AtomicU64::new(1);
static NEXT_TID: AtomicU64 = AtomicU64::new(1);
static EVENTS: Mutex<Vec<TraceEvent>> = Mutex::new(Vec::new());

thread_local! {
    /// Innermost open span on this thread (0 = none) — the parent for
    /// the next span opened here. Installed onto pool workers for the
    /// duration of a batch via [`SpanContext`].
    static CURRENT_PARENT: Cell<u64> = const { Cell::new(0) };
    /// Small dense thread id for trace events (0 = unassigned).
    static TRACE_TID: Cell<u64> = const { Cell::new(0) };
}

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Turn span recording on or off process-wide.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Arm the trace sink: spans buffer Chrome trace events from now on.
pub fn start_tracing() {
    epoch(); // pin t=0 before the first event
    TRACING.store(true, Ordering::Relaxed);
}

pub fn tracing_active() -> bool {
    TRACING.load(Ordering::Relaxed)
}

/// Disarm the sink and drain the buffered events.
pub fn take_events() -> Vec<TraceEvent> {
    TRACING.store(false, Ordering::Relaxed);
    std::mem::take(&mut *EVENTS.lock().unwrap())
}

/// One completed span, ready for `trace_event` serialization.
pub struct TraceEvent {
    pub name: &'static str,
    /// Start, nanoseconds since the trace epoch.
    pub ts_ns: u64,
    pub dur_ns: u64,
    pub tid: u64,
    pub id: u64,
    pub parent: u64,
}

/// Serialize events as Chrome `trace_event` JSON (object form, with
/// `displayTimeUnit`), loadable in Perfetto and `about:tracing`.
pub fn write_chrome_trace(path: &std::path::Path, events: &[TraceEvent]) -> std::io::Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "{{\"displayTimeUnit\":\"ms\",\"traceEvents\":[")?;
    write!(
        f,
        "{{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":1,\"tid\":0,\
         \"args\":{{\"name\":\"attn-reduce\"}}}}"
    )?;
    for e in events {
        write!(
            f,
            ",\n{{\"name\":\"{}\",\"cat\":\"stage\",\"ph\":\"X\",\"pid\":1,\"tid\":{},\
             \"ts\":{:.3},\"dur\":{:.3},\"args\":{{\"id\":{},\"parent\":{}}}}}",
            e.name,
            e.tid,
            e.ts_ns as f64 / 1e3,
            e.dur_ns as f64 / 1e3,
            e.id,
            e.parent
        )?;
    }
    writeln!(f, "\n]}}")?;
    f.flush()
}

/// Drain the sink and write it to `path`, reporting the event count.
pub fn finish_trace(path: &std::path::Path) -> std::io::Result<usize> {
    let events = take_events();
    write_chrome_trace(path, &events)?;
    Ok(events.len())
}

/// A static naming one pipeline stage; the single source of the stage's
/// histogram handle. `const`-constructible so stages live in statics.
pub struct StageTimer {
    name: &'static str,
    hist: OnceLock<&'static Histogram>,
}

impl StageTimer {
    pub const fn new(name: &'static str) -> StageTimer {
        StageTimer { name, hist: OnceLock::new() }
    }

    pub fn name(&self) -> &'static str {
        self.name
    }

    /// The stage's histogram in the global registry (registered on
    /// first use, then cached).
    pub fn hist(&self) -> &'static Histogram {
        *self.hist.get_or_init(|| {
            Registry::global().histogram(
                "attn_stage_duration_seconds",
                "Wall time per pipeline stage (spans; see README Observability)",
                &[("stage", self.name)],
                DURATION_BOUNDS_NS,
                SCALE_NS_TO_SECONDS,
            )
        })
    }

    /// Open a span; elapsed time is recorded when the guard drops.
    #[inline]
    pub fn span(&'static self) -> Span {
        if !enabled() {
            return Span { timer: None, start: Instant::now(), id: 0, parent: 0 };
        }
        let (id, parent) = if TRACING.load(Ordering::Relaxed) {
            let id = NEXT_SPAN_ID.fetch_add(1, Ordering::Relaxed);
            let parent = CURRENT_PARENT.with(|c| c.replace(id));
            (id, parent)
        } else {
            (0, 0)
        };
        Span { timer: Some(self), start: Instant::now(), id, parent }
    }
}

/// RAII span guard; see [`StageTimer::span`].
pub struct Span {
    timer: Option<&'static StageTimer>,
    start: Instant,
    id: u64,
    parent: u64,
}

impl Drop for Span {
    fn drop(&mut self) {
        let Some(timer) = self.timer else { return };
        let dur_ns = self.start.elapsed().as_nanos() as u64;
        timer.hist().observe(dur_ns);
        if self.id != 0 {
            CURRENT_PARENT.with(|c| c.set(self.parent));
            let tid = TRACE_TID.with(|c| {
                if c.get() == 0 {
                    c.set(NEXT_TID.fetch_add(1, Ordering::Relaxed));
                }
                c.get()
            });
            let ts_ns = self.start.duration_since(epoch()).as_nanos() as u64;
            EVENTS.lock().unwrap().push(TraceEvent {
                name: timer.name,
                ts_ns,
                dur_ns,
                tid,
                id: self.id,
                parent: self.parent,
            });
        }
    }
}

/// The submitting thread's span context, captured at `Executor` batch
/// submission and installed on pool workers so their spans nest under
/// the batch's request/command (mirrors the codec `ForceContext`).
#[derive(Clone, Copy, Default)]
pub struct SpanContext {
    parent: u64,
}

impl SpanContext {
    /// Capture the calling thread's innermost open span.
    pub fn capture() -> SpanContext {
        SpanContext { parent: CURRENT_PARENT.with(|c| c.get()) }
    }

    /// Overwrite the current thread's context (capture the previous one
    /// first to restore it — the `Executor` pairs `capture`/`set` inside
    /// its panic-safe force guard).
    pub fn set(self) {
        CURRENT_PARENT.with(|c| c.set(self.parent));
    }

    /// Install on the current (worker) thread; the guard restores the
    /// previous context on drop.
    pub fn install(self) -> SpanContextGuard {
        let prev = CURRENT_PARENT.with(|c| c.replace(self.parent));
        SpanContextGuard { prev }
    }
}

pub struct SpanContextGuard {
    prev: u64,
}

impl Drop for SpanContextGuard {
    fn drop(&mut self) {
        CURRENT_PARENT.with(|c| c.set(self.prev));
    }
}
