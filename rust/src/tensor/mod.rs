//! Dense row-major nd-array substrate.
//!
//! Deliberately minimal: the compute-heavy math lives in the AOT HLO
//! artifacts; the coordinator only needs shape bookkeeping, block
//! extraction/scatter (the paper's §II blocking), and a few reductions.

use std::fmt;

/// A dense row-major f32 tensor.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}[{} elems]", self.shape, self.data.len())
    }
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} does not match data length {}",
            data.len()
        );
        Self { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Self { shape, data: vec![0.0; n] }
    }

    pub fn from_vec(data: Vec<f32>) -> Self {
        Self { shape: vec![data.len()], data }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Reshape in place (element count must match).
    pub fn reshape(mut self, shape: Vec<usize>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape;
        self
    }

    /// Row-major strides.
    pub fn strides(&self) -> Vec<usize> {
        let mut s = vec![1; self.shape.len()];
        for i in (0..self.shape.len().saturating_sub(1)).rev() {
            s[i] = s[i + 1] * self.shape[i + 1];
        }
        s
    }

    pub fn min(&self) -> f32 {
        self.data.iter().copied().fold(f32::INFINITY, f32::min)
    }

    pub fn max(&self) -> f32 {
        self.data.iter().copied().fold(f32::NEG_INFINITY, f32::max)
    }

    pub fn mean(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().map(|&x| x as f64).sum::<f64>() / self.data.len() as f64
    }

    pub fn std(&self) -> f64 {
        if self.data.len() < 2 {
            return 0.0;
        }
        let mu = self.mean();
        let var = self
            .data
            .iter()
            .map(|&x| {
                let d = x as f64 - mu;
                d * d
            })
            .sum::<f64>()
            / self.data.len() as f64;
        var.sqrt()
    }

    /// Range max - min (the NRMSE denominator, Eq. 11).
    pub fn range(&self) -> f32 {
        if self.data.is_empty() {
            0.0
        } else {
            self.max() - self.min()
        }
    }
}

/// Extract a hyper-rectangular block starting at `origin` with `size`,
/// flattened row-major into `out`. Out-of-range positions are zero-padded
/// so edge blocks keep a fixed shape (the AOT batch shapes are static).
pub fn extract_block(t: &Tensor, origin: &[usize], size: &[usize], out: &mut [f32]) {
    let rank = t.shape.len();
    assert_eq!(origin.len(), rank);
    assert_eq!(size.len(), rank);
    assert_eq!(out.len(), size.iter().product::<usize>());
    let strides = t.strides();
    let mut idx = vec![0usize; rank];
    for (oi, slot) in out.iter_mut().enumerate() {
        // decode oi -> multi-index within the block
        let mut rem = oi;
        for d in (0..rank).rev() {
            idx[d] = rem % size[d];
            rem /= size[d];
        }
        let mut pos = 0usize;
        let mut inside = true;
        for d in 0..rank {
            let p = origin[d] + idx[d];
            if p >= t.shape[d] {
                inside = false;
                break;
            }
            pos += p * strides[d];
        }
        *slot = if inside { t.data[pos] } else { 0.0 };
    }
}

/// Scatter a flattened block back into the tensor (inverse of
/// [`extract_block`]; positions outside the tensor are dropped).
pub fn scatter_block(t: &mut Tensor, origin: &[usize], size: &[usize], block: &[f32]) {
    let rank = t.shape.len();
    let strides = t.strides();
    let mut idx = vec![0usize; rank];
    for (oi, &val) in block.iter().enumerate() {
        let mut rem = oi;
        for d in (0..rank).rev() {
            idx[d] = rem % size[d];
            rem /= size[d];
        }
        let mut pos = 0usize;
        let mut inside = true;
        for d in 0..rank {
            let p = origin[d] + idx[d];
            if p >= t.shape[d] {
                inside = false;
                break;
            }
            pos += p * strides[d];
        }
        if inside {
            t.data[pos] = val;
        }
    }
}

/// All block origins for tiling `shape` with `size` (ceil division — edge
/// blocks are padded by [`extract_block`]). Row-major order.
pub fn block_origins(shape: &[usize], size: &[usize]) -> Vec<Vec<usize>> {
    assert_eq!(shape.len(), size.len());
    let counts: Vec<usize> = shape
        .iter()
        .zip(size)
        .map(|(&s, &b)| s.div_ceil(b))
        .collect();
    let total: usize = counts.iter().product();
    let mut out = Vec::with_capacity(total);
    for i in 0..total {
        let mut rem = i;
        let mut origin = vec![0usize; shape.len()];
        for d in (0..shape.len()).rev() {
            origin[d] = (rem % counts[d]) * size[d];
            rem /= counts[d];
        }
        out.push(origin);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strides_row_major() {
        let t = Tensor::zeros(vec![2, 3, 4]);
        assert_eq!(t.strides(), vec![12, 4, 1]);
    }

    #[test]
    fn extract_then_scatter_round_trips() {
        let data: Vec<f32> = (0..24).map(|x| x as f32).collect();
        let t = Tensor::new(vec![4, 6], data);
        let mut block = vec![0.0; 6];
        extract_block(&t, &[1, 2], &[2, 3], &mut block);
        assert_eq!(block, vec![8.0, 9.0, 10.0, 14.0, 15.0, 16.0]);
        let mut t2 = Tensor::zeros(vec![4, 6]);
        scatter_block(&mut t2, &[1, 2], &[2, 3], &block);
        let mut back = vec![0.0; 6];
        extract_block(&t2, &[1, 2], &[2, 3], &mut back);
        assert_eq!(back, block);
    }

    #[test]
    fn edge_blocks_zero_padded() {
        let t = Tensor::new(vec![3], vec![1.0, 2.0, 3.0]);
        let mut block = vec![9.0; 2];
        extract_block(&t, &[2], &[2], &mut block);
        assert_eq!(block, vec![3.0, 0.0]);
    }

    #[test]
    fn block_origins_cover_with_ceil() {
        let origins = block_origins(&[5, 4], &[2, 2]);
        assert_eq!(origins.len(), 3 * 2);
        assert_eq!(origins[0], vec![0, 0]);
        assert_eq!(origins[5], vec![4, 2]);
    }

    #[test]
    fn stats() {
        let t = Tensor::from_vec(vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(t.min(), 1.0);
        assert_eq!(t.max(), 4.0);
        assert_eq!(t.range(), 3.0);
        assert!((t.mean() - 2.5).abs() < 1e-9);
    }
}
