//! SZ3-like prediction-based error-bounded compressor (DESIGN.md §4).
//!
//! The core SZ pipeline: visit points in row-major order, predict each
//! from already-reconstructed neighbors with an N-D Lorenzo predictor
//! (inclusion–exclusion over the corner hypercube, up to 3 fastest-moving
//! dims), quantize the prediction error to `code = round(err / (2·eps))`
//! — which guarantees the pointwise bound |x − x̂| ≤ eps — and entropy-
//! code the (heavily zero-peaked) codes through the symbol container
//! ([`crate::coder::compress_symbols`]): Huffman + LZSS, interleaved
//! rANS for dense streams, or the zero-run / constant modes when trial
//! sampling says they win (residual tiles, overwhelmingly). Values whose
//! code exceeds the code range are stored raw ("unpredictable", as SZ
//! does).
//!
//! This is the same algorithm family and error-control mechanism as SZ3's
//! default path (SZ3 adds regression predictors and adaptive selection;
//! crossover *shapes* against learned compressors are preserved).
//!
//! Lorenzo prediction is serial *within* a lattice (each point depends on
//! already-reconstructed neighbors), but the leading batch dims are
//! independent — encode and decode fan batches out across the shared
//! [`crate::engine::Executor`], concatenating per-batch streams in batch
//! order, so the byte stream is identical to the serial one at every
//! thread count. The `_scratch` entry points are the v3 per-tile hot
//! path: recon, code, and entropy buffers come from the caller's
//! [`Scratch`] arena instead of fresh `Vec`s per tile.
//!
//! The inner loops are row-structured: the inclusion–exclusion terms that
//! do not involve the in-row predecessor (x−1) are precomputed per row by
//! [`lorenzo_row_base`] as a branch-free fixed-stride pass over up to
//! three contiguous neighbor rows (autovectorizable), and the serial
//! x-sweep folds in the remaining x−1 terms with loop-invariant
//! conditions. Term order reproduces the per-point mask-order accumulation
//! of [`lorenzo_predict`] (kept as the bit-equivalence oracle) exactly, so
//! codes, raw values, and reconstructions are bit-identical to the
//! pre-restructure encoder/decoder.

use crate::coder::{compress_symbols, decompress_symbols_into, symbol_stream_stats};
use crate::engine::{reuse_f32, Executor, Scratch};
use crate::tensor::Tensor;
use crate::Result;
use anyhow::ensure;

use super::StreamBreakdown;

const UNPRED: i32 = i32::MIN; // sentinel code for raw-stored values
const MAX_CODE: i32 = 1 << 20;
/// Default decode cap: large enough for paper-scale fields (S3D full is
/// ~1.2e9 points) while stopping a corrupt header's 2^60-point claim
/// from sizing an allocation. Callers that know the real geometry pass
/// a tight cap via [`Sz3Like::decompress_capped`].
const MAX_POINTS_DEFAULT: usize = 1 << 31;
const MAX_RANK: usize = 16;

/// Length-checked little-endian u64 read (corrupt input errors, never
/// panics on a short slice).
fn read_u64(bytes: &[u8], off: &mut usize) -> Result<u64> {
    ensure!(bytes.len() >= *off + 8, "sz3: truncated");
    let v = u64::from_le_bytes(bytes[*off..*off + 8].try_into().unwrap());
    *off += 8;
    Ok(v)
}

/// Validated stream header: geometry, raw-value span, entropy span.
struct Header {
    eps: f32,
    shape: Vec<usize>,
    n_points: usize,
    raws_off: usize,
    n_raw: usize,
    z_off: usize,
    z_len: usize,
}

/// SZ3-like compressor with pointwise absolute error bound `eps`.
#[derive(Debug, Clone, Copy)]
pub struct Sz3Like {
    pub eps: f32,
}

impl Sz3Like {
    pub fn new(eps: f32) -> Self {
        assert!(eps > 0.0);
        Self { eps }
    }

    /// Serialize geometry + raw values + the entropy-coded code stream.
    fn serialize(&self, shape: &[usize], raws: &[f32], codes: &[i32]) -> Result<Vec<u8>> {
        let mut out = Vec::new();
        out.extend_from_slice(&self.eps.to_le_bytes());
        out.extend_from_slice(&(shape.len() as u32).to_le_bytes());
        for &d in shape {
            out.extend_from_slice(&(d as u64).to_le_bytes());
        }
        out.extend_from_slice(&(raws.len() as u64).to_le_bytes());
        for &r in raws {
            out.extend_from_slice(&r.to_le_bytes());
        }
        let z = compress_symbols(codes)?;
        out.extend_from_slice(&(z.len() as u64).to_le_bytes());
        out.extend(z);
        Ok(out)
    }

    /// Compress; returns the archive bytes.
    pub fn compress(&self, t: &Tensor) -> Result<Vec<u8>> {
        let (codes, raws) = self.encode_codes(t);
        self.serialize(t.shape(), &raws, &codes)
    }

    /// Single-lattice compress on the caller's scratch arena — the v3
    /// per-tile hot path (serial: tiles are already the parallel grain).
    /// Byte-identical to [`Sz3Like::compress`] of the same data.
    pub fn compress_scratch(
        &self,
        shape: &[usize],
        data: &[f32],
        scratch: &mut Scratch,
    ) -> Result<Vec<u8>> {
        ensure!(
            shape.iter().product::<usize>() == data.len(),
            "sz3: shape {:?} does not match {} values",
            shape,
            data.len()
        );
        let rank = shape.len();
        let lor = rank.min(3);
        let lattice = &shape[rank - lor..];
        let batch: usize = shape[..rank - lor].iter().product();
        let vol: usize = lattice.iter().product();
        let Scratch { f32_a, f32_c, i32_a, .. } = scratch;
        let codes = i32_a;
        codes.clear();
        let mut raws = Vec::new();
        if vol > 0 {
            let _span = crate::obs::stages::SZ3_PREDICT_QUANTIZE.span();
            for b in 0..batch {
                let recon = reuse_f32(f32_a, vol);
                let src = &data[b * vol..(b + 1) * vol];
                self.encode_lattice(src, lattice, recon, f32_c, codes, &mut raws);
            }
        }
        self.serialize(shape, &raws, codes)
    }

    /// Parse + validate the header. Every field is untrusted: lengths are
    /// bounds-checked before they size an allocation, so corrupt or
    /// truncated streams return `Err` — never panic, never balloon memory.
    fn parse_header(bytes: &[u8], max_points: usize) -> Result<Header> {
        ensure!(bytes.len() >= 8, "sz3: truncated");
        let eps = f32::from_le_bytes(bytes[0..4].try_into().unwrap());
        ensure!(eps.is_finite() && eps > 0.0, "sz3: corrupt eps {eps}");
        let rank = u32::from_le_bytes(bytes[4..8].try_into().unwrap()) as usize;
        ensure!(rank <= MAX_RANK, "sz3: corrupt rank {rank}");
        let mut off = 8;
        let mut shape = Vec::with_capacity(rank);
        let mut n_points = 1usize;
        for _ in 0..rank {
            let d = usize::try_from(read_u64(bytes, &mut off)?)
                .map_err(|_| anyhow::anyhow!("sz3: shape dim overflow"))?;
            n_points = n_points
                .checked_mul(d)
                .filter(|&n| n <= max_points)
                .ok_or_else(|| anyhow::anyhow!("sz3: declared points exceed cap {max_points}"))?;
            shape.push(d);
        }
        let n_raw = usize::try_from(read_u64(bytes, &mut off)?)
            .map_err(|_| anyhow::anyhow!("sz3: raw count overflow"))?;
        ensure!(
            n_raw <= n_points && n_raw <= bytes.len().saturating_sub(off) / 4,
            "sz3: corrupt raw count {n_raw}"
        );
        let raws_off = off;
        off += n_raw * 4;
        let z_len = usize::try_from(read_u64(bytes, &mut off)?)
            .map_err(|_| anyhow::anyhow!("sz3: stream length overflow"))?;
        ensure!(z_len <= bytes.len() - off, "sz3: entropy stream truncated");
        ensure!(off + z_len == bytes.len(), "sz3: trailing bytes");
        Ok(Header { eps, shape, n_points, raws_off, n_raw, z_off: off, z_len })
    }

    pub fn decompress(bytes: &[u8]) -> Result<Tensor> {
        Self::decompress_capped(bytes, MAX_POINTS_DEFAULT)
    }

    /// Decompress with an explicit cap on the decoded point count.
    pub fn decompress_capped(bytes: &[u8], max_points: usize) -> Result<Tensor> {
        Self::decompress_capped_scratch(bytes, max_points, &mut Scratch::default())
    }

    /// [`Sz3Like::decompress_capped`] on the caller's scratch arena — the
    /// v3 per-tile hot path (entropy table/LUT and code buffers reused
    /// across tiles).
    pub fn decompress_capped_scratch(
        bytes: &[u8],
        max_points: usize,
        scratch: &mut Scratch,
    ) -> Result<Tensor> {
        let h = Self::parse_header(bytes, max_points)?;
        let mut raws = Vec::with_capacity(h.n_raw);
        for i in 0..h.n_raw {
            let o = h.raws_off + i * 4;
            raws.push(f32::from_le_bytes(bytes[o..o + 4].try_into().unwrap()));
        }
        let Scratch { i32_a, symbols, .. } = scratch;
        decompress_symbols_into(
            &bytes[h.z_off..h.z_off + h.z_len],
            h.n_points,
            i32_a,
            symbols,
        )?;
        let _span = crate::obs::stages::SZ3_RECONSTRUCT.span();
        Self::decode_codes(i32_a, &raws, h.shape, h.eps)
    }

    /// Byte breakdown of one stream for `cli info` (see
    /// [`StreamBreakdown`]): framing vs raw values vs entropy table vs
    /// coded symbols.
    pub fn stream_breakdown(bytes: &[u8], max_points: usize) -> Result<StreamBreakdown> {
        let h = Self::parse_header(bytes, max_points)?;
        let stats = symbol_stream_stats(&bytes[h.z_off..h.z_off + h.z_len], h.n_points)?;
        Ok(StreamBreakdown {
            mode: stats.mode,
            framing_bytes: bytes.len() - h.n_raw * 4 - h.z_len,
            aux_bytes: h.n_raw * 4,
            table_bytes: stats.table_bytes,
            symbol_bytes: stats.symbol_bytes,
            lanes: stats.lanes,
        })
    }

    /// Lorenzo-predict + quantize one lattice, row-structured: per-row
    /// base terms come from [`lorenzo_row_base`], the x-sweep adds the
    /// serial x−1 terms. `recon` is a scratch buffer of `vol` zeros,
    /// `base` a reusable row buffer; appends to `codes` / `raws`.
    fn encode_lattice(
        &self,
        src: &[f32],
        lattice: &[usize],
        recon: &mut [f32],
        base: &mut Vec<f32>,
        codes: &mut Vec<i32>,
        raws: &mut Vec<f32>,
    ) {
        let (d, h, w) = lattice_dhw(lattice);
        let two_eps = 2.0 * self.eps;
        base.clear();
        base.resize(w, 0.0);
        for z in 0..d {
            for y in 0..h {
                let row_start = (z * h + y) * w;
                let (before, rest) = recon.split_at_mut(row_start);
                let row = &mut rest[..w];
                lorenzo_row_base(before, z, y, h, w, base);
                let pp = if z > 0 { &before[((z - 1) * h + y) * w..][..w] } else { &[][..] };
                let prev = if y > 0 { &before[(z * h + y - 1) * w..][..w] } else { &[][..] };
                let ppz = if z > 0 && y > 0 {
                    &before[((z - 1) * h + y - 1) * w..][..w]
                } else {
                    &[][..]
                };
                for x in 0..w {
                    let mut pred = base[x];
                    if x > 0 {
                        pred += row[x - 1];
                        if z > 0 {
                            pred -= pp[x - 1];
                        }
                        if y > 0 {
                            pred -= prev[x - 1];
                        }
                        if z > 0 && y > 0 {
                            pred += ppz[x - 1];
                        }
                    }
                    let s = src[row_start + x];
                    let err = s - pred;
                    let code = (err / two_eps).round();
                    let mut stored = false;
                    if code.is_finite() && code.abs() < MAX_CODE as f32 {
                        let c = code as i32;
                        let rec = pred + c as f32 * two_eps;
                        // verify after f32 rounding — SZ falls back to the
                        // unpredictable path whenever quantization cannot
                        // certify the bound exactly
                        if (s - rec).abs() <= self.eps {
                            codes.push(c);
                            row[x] = rec;
                            stored = true;
                        }
                    }
                    if !stored {
                        codes.push(UNPRED);
                        raws.push(s);
                        row[x] = s;
                    }
                }
            }
        }
    }

    /// Lorenzo-predict + quantize. Returns (codes, raw values). Batches
    /// (leading dims) run block-parallel; streams concatenate in batch
    /// order, so the output matches the serial encoder byte for byte.
    fn encode_codes(&self, t: &Tensor) -> (Vec<i32>, Vec<f32>) {
        let shape = t.shape();
        let rank = shape.len();
        // treat the last up-to-3 dims as the Lorenzo lattice, leading dims
        // as batch (matches SZ handling of high-rank data)
        let lor = rank.min(3);
        let lattice = &shape[rank - lor..];
        let batch: usize = shape[..rank - lor].iter().product();
        let vol: usize = lattice.iter().product();
        let _span = crate::obs::stages::SZ3_PREDICT_QUANTIZE.span();
        let parts: Vec<(Vec<i32>, Vec<f32>)> =
            Executor::global().par_map_scratch(batch, |b, scratch| {
                let recon = reuse_f32(&mut scratch.f32_a, vol);
                let src = &t.data()[b * vol..(b + 1) * vol];
                let mut codes = Vec::with_capacity(vol);
                let mut raws = Vec::new();
                self.encode_lattice(src, lattice, recon, &mut scratch.f32_c, &mut codes, &mut raws);
                (codes, raws)
            });
        let mut codes = Vec::with_capacity(t.len());
        let mut raws = Vec::new();
        for (c, r) in parts {
            codes.extend(c);
            raws.extend(r);
        }
        (codes, raws)
    }

    fn decode_codes(
        codes: &[i32],
        raws: &[f32],
        shape: Vec<usize>,
        eps: f32,
    ) -> Result<Tensor> {
        let rank = shape.len();
        let lor = rank.min(3);
        let lattice: Vec<usize> = shape[rank - lor..].to_vec();
        let batch: usize = shape[..rank - lor].iter().product();
        let vol: usize = lattice.iter().product();
        ensure!(codes.len() == batch * vol, "sz3: code count mismatch");
        // per-batch raw-value offsets, so batches decode independently
        let mut raw_starts = Vec::with_capacity(batch + 1);
        let mut acc = 0usize;
        raw_starts.push(0);
        for b in 0..batch {
            acc += codes[b * vol..(b + 1) * vol]
                .iter()
                .filter(|&&c| c == UNPRED)
                .count();
            raw_starts.push(acc);
        }
        ensure!(acc == raws.len(), "sz3: raw count mismatch");
        let two_eps = 2.0 * eps;
        let mut data = vec![0f32; batch * vol];
        if vol == 0 {
            return Ok(Tensor::new(shape, data));
        }
        let (d, h, w) = lattice_dhw(&lattice);
        crate::util::parallel::par_chunks_mut(&mut data, vol, |b, dst| {
            let braws = &raws[raw_starts[b]..raw_starts[b + 1]];
            let bcodes = &codes[b * vol..(b + 1) * vol];
            let mut base = vec![0f32; w];
            let mut ri = 0usize;
            for z in 0..d {
                for y in 0..h {
                    let row_start = (z * h + y) * w;
                    let (before, rest) = dst.split_at_mut(row_start);
                    let row = &mut rest[..w];
                    lorenzo_row_base(before, z, y, h, w, &mut base);
                    let pp =
                        if z > 0 { &before[((z - 1) * h + y) * w..][..w] } else { &[][..] };
                    let prev =
                        if y > 0 { &before[(z * h + y - 1) * w..][..w] } else { &[][..] };
                    let ppz = if z > 0 && y > 0 {
                        &before[((z - 1) * h + y - 1) * w..][..w]
                    } else {
                        &[][..]
                    };
                    for x in 0..w {
                        let mut pred = base[x];
                        if x > 0 {
                            pred += row[x - 1];
                            if z > 0 {
                                pred -= pp[x - 1];
                            }
                            if y > 0 {
                                pred -= prev[x - 1];
                            }
                            if z > 0 && y > 0 {
                                pred += ppz[x - 1];
                            }
                        }
                        let code = bcodes[row_start + x];
                        row[x] = if code == UNPRED {
                            let v = braws[ri];
                            ri += 1;
                            v
                        } else {
                            pred + code as f32 * two_eps
                        };
                    }
                }
            }
        });
        Ok(Tensor::new(shape, data))
    }
}

/// Interpret the up-to-rank-3 Lorenzo lattice as (depth, height, width),
/// last dim fastest-moving; missing leading dims are size 1.
fn lattice_dhw(lattice: &[usize]) -> (usize, usize, usize) {
    match *lattice {
        [] => (1, 1, 1),
        [w] => (1, 1, w),
        [h, w] => (1, h, w),
        [d, h, w] => (d, h, w),
        _ => unreachable!("lorenzo lattice is at most rank 3"),
    }
}

/// Fill `base` with the x-independent Lorenzo terms for row `(z, y)`:
/// the inclusion–exclusion neighbors of each `x` that live in earlier
/// rows. `before` is the reconstruction up to (exclusive) this row's
/// start. Each arm is a fixed-stride pass over contiguous rows, so the
/// compiler can vectorize it; the accumulation order (and the leading
/// `0.0 +`, which matters for −0.0 inputs) reproduces the mask-order sum
/// of [`lorenzo_predict`] bit for bit.
fn lorenzo_row_base(before: &[f32], z: usize, y: usize, h: usize, w: usize, base: &mut [f32]) {
    match (z > 0, y > 0) {
        (true, true) => {
            let pp = &before[((z - 1) * h + y) * w..][..w];
            let prev = &before[(z * h + y - 1) * w..][..w];
            let ppz = &before[((z - 1) * h + y - 1) * w..][..w];
            for (((b, &a), &c), &e) in base.iter_mut().zip(pp).zip(prev).zip(ppz) {
                *b = ((0.0 + a) + c) - e;
            }
        }
        (true, false) => {
            let pp = &before[((z - 1) * h + y) * w..][..w];
            for (b, &a) in base.iter_mut().zip(pp) {
                *b = 0.0 + a;
            }
        }
        (false, true) => {
            let prev = &before[(z * h + y - 1) * w..][..w];
            for (b, &a) in base.iter_mut().zip(prev) {
                *b = 0.0 + a;
            }
        }
        (false, false) => base.fill(0.0),
    }
}

/// N-D Lorenzo prediction from already-filled lower-index neighbors:
/// inclusion–exclusion over the corner hypercube. Superseded in the hot
/// paths by the row-structured sweep ([`lorenzo_row_base`] + serial x−1
/// terms); kept as the per-point bit-equivalence oracle.
#[doc(hidden)]
pub fn lorenzo_predict(recon: &[f32], lattice: &[usize], flat: usize) -> f32 {
    let rank = lattice.len();
    // decode multi-index
    let mut idx = [0usize; 3];
    let mut rem = flat;
    for d in (0..rank).rev() {
        idx[d] = rem % lattice[d];
        rem /= lattice[d];
    }
    // strides
    let mut strides = [0usize; 3];
    let mut s = 1;
    for d in (0..rank).rev() {
        strides[d] = s;
        s *= lattice[d];
    }
    let mut pred = 0.0f32;
    // iterate over non-empty subsets of dims with idx>0
    for mask in 1u32..(1 << rank) {
        let mut ok = true;
        let mut off = flat;
        for d in 0..rank {
            if mask & (1 << d) != 0 {
                if idx[d] == 0 {
                    ok = false;
                    break;
                }
                off -= strides[d];
            }
        }
        if !ok {
            continue;
        }
        let sign = if mask.count_ones() % 2 == 1 { 1.0 } else { -1.0 };
        pred += sign * recon[off];
    }
    pred
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn smooth_field(shape: Vec<usize>, seed: u64) -> Tensor {
        let n: usize = shape.iter().product();
        let mut rng = Rng::new(seed);
        let (a, b, c) = (rng.uniform() * 5.0, rng.uniform() * 3.0, rng.uniform());
        let data: Vec<f32> = (0..n)
            .map(|i| {
                let x = i as f64 / n as f64;
                ((a * x * 7.0).sin() + (b * x * 23.0).cos() * 0.3 + c) as f32
            })
            .collect();
        Tensor::new(shape, data)
    }

    #[test]
    fn pointwise_error_bound_holds() {
        for &eps in &[1e-2f32, 1e-3, 1e-4] {
            let t = smooth_field(vec![4, 16, 16], 3);
            let sz = Sz3Like::new(eps);
            let bytes = sz.compress(&t).unwrap();
            let back = Sz3Like::decompress(&bytes).unwrap();
            assert_eq!(back.shape(), t.shape());
            let max_err = t
                .data()
                .iter()
                .zip(back.data())
                .map(|(&a, &b)| (a - b).abs())
                .fold(0f32, f32::max);
            assert!(max_err <= eps * 1.0001, "eps={eps} max={max_err}");
        }
    }

    #[test]
    fn smooth_data_compresses_well() {
        let t = smooth_field(vec![64, 64], 1);
        let bytes = Sz3Like::new(1e-3).compress(&t).unwrap();
        let cr = (t.len() * 4) as f64 / bytes.len() as f64;
        assert!(cr > 4.0, "cr={cr}");
    }

    #[test]
    fn looser_bound_higher_ratio() {
        let t = smooth_field(vec![32, 32, 8], 5);
        let tight = Sz3Like::new(1e-5).compress(&t).unwrap();
        let loose = Sz3Like::new(1e-2).compress(&t).unwrap();
        assert!(loose.len() < tight.len());
    }

    #[test]
    fn random_noise_round_trips() {
        let mut rng = Rng::new(9);
        let data: Vec<f32> = (0..512).map(|_| rng.normal() as f32 * 100.0).collect();
        let t = Tensor::new(vec![8, 8, 8], data);
        let eps = 0.5f32;
        let back = Sz3Like::decompress(&Sz3Like::new(eps).compress(&t).unwrap()).unwrap();
        let max_err = t
            .data()
            .iter()
            .zip(back.data())
            .map(|(&a, &b)| (a - b).abs())
            .fold(0f32, f32::max);
        assert!(max_err <= eps * 1.0001);
    }

    #[test]
    fn handles_extreme_values_via_unpredictable_path() {
        let mut data = vec![0f32; 64];
        data[10] = 1e30;
        data[11] = -1e30;
        let t = Tensor::new(vec![64], data);
        let back = Sz3Like::decompress(&Sz3Like::new(1e-6).compress(&t).unwrap()).unwrap();
        assert_eq!(back.data()[10], 1e30);
        assert_eq!(back.data()[11], -1e30);
    }

    #[test]
    fn rank_one_and_high_rank() {
        for shape in [vec![100], vec![2, 3, 4, 5, 6]] {
            let t = smooth_field(shape, 11);
            let back =
                Sz3Like::decompress(&Sz3Like::new(1e-3).compress(&t).unwrap()).unwrap();
            assert_eq!(back.shape(), t.shape());
        }
    }

    #[test]
    fn scratch_compress_matches_plain_compress() {
        // the per-tile scratch path must be byte-identical to the
        // batch-parallel path on the same data
        let mut scratch = Scratch::default();
        for (seed, shape) in [(3u64, vec![4, 16, 16]), (7, vec![30]), (9, vec![2, 5, 8, 8])] {
            let t = smooth_field(shape, seed);
            let sz = Sz3Like::new(1e-3);
            let a = sz.compress(&t).unwrap();
            let b = sz.compress_scratch(t.shape(), t.data(), &mut scratch).unwrap();
            assert_eq!(a, b);
            // and the scratch decode round-trips it
            let back =
                Sz3Like::decompress_capped_scratch(&b, t.len(), &mut scratch).unwrap();
            assert_eq!(back.shape(), t.shape());
        }
    }

    #[test]
    fn stream_breakdown_accounts_for_the_container() {
        let t = smooth_field(vec![6, 16, 16], 5);
        let bytes = Sz3Like::new(1e-3).compress(&t).unwrap();
        let b = Sz3Like::stream_breakdown(&bytes, t.len()).unwrap();
        assert!(
            b.mode == "plain" || b.mode == "zero-run" || b.mode == "const" || b.mode == "rans"
        );
        // framing is exactly the header fields: eps + rank + 3 dims +
        // raw count + entropy length
        assert_eq!(b.framing_bytes, 4 + 4 + 3 * 8 + 8 + 8);
        assert!(b.table_bytes > 0);
        assert!(b.symbol_bytes > 0);
    }

    /// Smooth field with occasional huge spikes, to drive both the
    /// quantized and the unpredictable/raw paths.
    fn spiky_field(shape: Vec<usize>, seed: u64) -> Tensor {
        let base = smooth_field(shape.clone(), seed);
        let mut data = base.data().to_vec();
        let mut rng = Rng::new(seed.wrapping_mul(31) + 7);
        for _ in 0..data.len() / 16 + 2 {
            let i = rng.below(data.len());
            data[i] = (rng.normal() * 1e25) as f32;
        }
        Tensor::new(shape, data)
    }

    const ORACLE_SHAPES: [&[usize]; 9] = [
        &[100],
        &[30],
        &[16, 16],
        &[1, 9],
        &[9, 1],
        &[4, 16, 16],
        &[1, 1, 7],
        &[5, 1, 5],
        &[5, 5, 1],
    ];

    /// The pre-restructure per-point encoder, built on the
    /// [`lorenzo_predict`] oracle. Returns (recon, codes, raws).
    fn reference_encode(
        sz: &Sz3Like,
        src: &[f32],
        lattice: &[usize],
    ) -> (Vec<f32>, Vec<i32>, Vec<f32>) {
        let two_eps = 2.0 * sz.eps;
        let mut recon = vec![0f32; src.len()];
        let mut codes = Vec::new();
        let mut raws = Vec::new();
        for i in 0..src.len() {
            let pred = lorenzo_predict(&recon, lattice, i);
            let err = src[i] - pred;
            let code = (err / two_eps).round();
            let mut stored = false;
            if code.is_finite() && code.abs() < MAX_CODE as f32 {
                let c = code as i32;
                let rec = pred + c as f32 * two_eps;
                if (src[i] - rec).abs() <= sz.eps {
                    codes.push(c);
                    recon[i] = rec;
                    stored = true;
                }
            }
            if !stored {
                codes.push(UNPRED);
                raws.push(src[i]);
                recon[i] = src[i];
            }
        }
        (recon, codes, raws)
    }

    /// The pre-restructure per-point decoder, same oracle.
    fn reference_decode(codes: &[i32], raws: &[f32], lattice: &[usize], eps: f32) -> Vec<f32> {
        let two_eps = 2.0 * eps;
        let mut dst = vec![0f32; codes.len()];
        let mut ri = 0usize;
        for i in 0..codes.len() {
            let pred = lorenzo_predict(&dst, lattice, i);
            dst[i] = if codes[i] == UNPRED {
                let v = raws[ri];
                ri += 1;
                v
            } else {
                pred + codes[i] as f32 * two_eps
            };
        }
        dst
    }

    fn bits_equal(a: &[f32], b: &[f32]) -> bool {
        a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
    }

    #[test]
    fn row_pass_encoder_matches_the_per_point_oracle() {
        // the restructured row-structured encoder must agree bit for bit
        // with the mask-order per-point oracle: codes, raw values, and
        // the reconstruction it leaves behind
        for (seed, shape) in ORACLE_SHAPES.iter().enumerate() {
            for &eps in &[1e-2f32, 1e-4] {
                let sz = Sz3Like::new(eps);
                let t = spiky_field(shape.to_vec(), seed as u64 + 1);
                let mut recon = vec![0f32; t.len()];
                let mut base = Vec::new();
                let mut codes = Vec::new();
                let mut raws = Vec::new();
                sz.encode_lattice(t.data(), shape, &mut recon, &mut base, &mut codes, &mut raws);
                let (ref_recon, ref_codes, ref_raws) = reference_encode(&sz, t.data(), shape);
                assert_eq!(codes, ref_codes, "shape={shape:?} eps={eps}");
                assert!(bits_equal(&raws, &ref_raws), "shape={shape:?} eps={eps}");
                assert!(bits_equal(&recon, &ref_recon), "shape={shape:?} eps={eps}");
                assert!(raws.iter().any(|r| r.abs() > 1e10), "spikes must hit raw path");
            }
        }
    }

    #[test]
    fn row_pass_decoder_matches_the_per_point_oracle() {
        for (seed, shape) in ORACLE_SHAPES.iter().enumerate() {
            let sz = Sz3Like::new(1e-3);
            let t = spiky_field(shape.to_vec(), seed as u64 + 40);
            let (_, codes, raws) = reference_encode(&sz, t.data(), shape);
            let back = Sz3Like::decode_codes(&codes, &raws, shape.to_vec(), sz.eps).unwrap();
            let oracle = reference_decode(&codes, &raws, shape, sz.eps);
            assert!(bits_equal(back.data(), &oracle), "shape={shape:?}");
        }
    }
}
