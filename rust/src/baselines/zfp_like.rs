//! ZFP-like transform-based fixed-precision compressor (DESIGN.md §4).
//!
//! ZFP's pipeline: tile into 4^d blocks, align each block to a common
//! exponent (block floating point), apply the separable integer lifting
//! transform along every axis to decorrelate, then code coefficient
//! bit-planes. We keep the exact ZFP lifting transform and block-exponent
//! stage, and replace the negabinary bit-plane coder with a
//! shift-truncate stage + the symbol container
//! ([`crate::coder::compress_symbols`]: Huffman/LZSS, interleaved rANS,
//! or the zero-run / constant modes — trial sampling picks per stream)
//! controlled by `precision` (bits kept per coefficient) — the same
//! fixed-precision rate-distortion knob.

//! Every 4^d block is independent, so both directions run block-parallel
//! on the shared [`crate::engine::Executor`]: compression fans out over
//! batches (or origin chunks when there is a single batch) and
//! decompression over individual blocks, with streams concatenated in
//! block order — byte-identical to the serial path at every thread count.
//! The `_scratch` entry points are the v3 per-tile hot path: block,
//! coefficient, and entropy buffers come from the caller's [`Scratch`]
//! arena instead of fresh `Vec`s per tile.

use crate::coder::{
    compress_symbols, decompress_symbols_into, lossless_compress, lossless_decompress,
    symbol_stream_stats,
};
use crate::engine::{reuse_f32, reuse_i64, Executor, Scratch};
use crate::tensor::Tensor;
use crate::Result;
use anyhow::ensure;

use super::StreamBreakdown;

const BLOCK: usize = 4;
/// Fixed-point fraction bits when converting to integers.
const FRAC_BITS: u32 = 26;
/// Default decode cap on declared points (same policy as the SZ3-like
/// decoder): big enough for paper-scale fields, small enough that a
/// corrupt header cannot size an absurd allocation.
const MAX_POINTS_DEFAULT: usize = 1 << 31;
const MAX_RANK: usize = 16;

/// Length-checked little-endian u64 read.
fn read_u64(bytes: &[u8], off: &mut usize) -> Result<u64> {
    ensure!(bytes.len() >= *off + 8, "zfp: truncated");
    let v = u64::from_le_bytes(bytes[*off..*off + 8].try_into().unwrap());
    *off += 8;
    Ok(v)
}

/// ZFP-like compressor: `precision` = bits retained per transform
/// coefficient (1..=26); smaller = higher compression, larger error.
#[derive(Debug, Clone, Copy)]
pub struct ZfpLike {
    pub precision: u32,
}

impl ZfpLike {
    pub fn new(precision: u32) -> Self {
        assert!((1..=FRAC_BITS).contains(&precision));
        Self { precision }
    }

    /// Transform + truncate the blocks at `origins` of one lattice,
    /// appending one exponent and `bsz` codes per block.
    fn encode_blocks(
        &self,
        sub: &Tensor,
        origins: &[Vec<usize>],
        d: usize,
        blk: &mut [f32],
        ints: &mut [i64],
        exps: &mut Vec<i16>,
        codes: &mut Vec<i32>,
    ) {
        let bsz = blk.len();
        for o in origins {
            crate::tensor::extract_block(sub, o, &vec![BLOCK; d], blk);
            // block exponent
            let maxabs = blk.iter().fold(0f32, |a, &x| a.max(x.abs()));
            let e = if maxabs > 0.0 { maxabs.log2().ceil() as i32 } else { 0 };
            exps.push(e as i16);
            let scale = 2f64.powi(FRAC_BITS as i32 - e);
            // zip-form fixed-point conversion: no bounds checks in the
            // loop body, so the convert+round vectorizes
            for (v, &b) in ints.iter_mut().zip(blk.iter()) {
                *v = (b as f64 * scale).round() as i64;
            }
            fwd_transform(ints, d);
            // keep `precision` MSBs (relative to FRAC_BITS), rounding
            // to nearest to avoid floor bias
            let shift = FRAC_BITS - self.precision;
            let half = if shift > 0 { 1i64 << (shift - 1) } else { 0 };
            codes.extend(ints.iter().map(|&v| ((v + half) >> shift) as i32));
        }
    }

    pub fn compress(&self, t: &Tensor) -> Result<Vec<u8>> {
        let shape = t.shape().to_vec();
        let rank = shape.len();
        let d = rank.min(3);
        let lattice: Vec<usize> = shape[rank - d..].to_vec();
        let batch: usize = shape[..rank - d].iter().product();
        let vol: usize = lattice.iter().product();
        let bsz = BLOCK.pow(d as u32);
        let origins = crate::tensor::block_origins(&lattice, &vec![BLOCK; d]);

        // block-parallel: over batches when there are several, over
        // origin chunks of the single lattice otherwise; parts
        // concatenate in block order either way
        let _span = crate::obs::stages::ZFP_TRANSFORM.span();
        let parts: Vec<(Vec<i16>, Vec<i32>)> = if batch == 0 || vol == 0 {
            Vec::new()
        } else if batch > 1 {
            Executor::global().par_map_scratch(batch, |b, s| {
                let sub =
                    Tensor::new(lattice.clone(), t.data()[b * vol..(b + 1) * vol].to_vec());
                let blk = reuse_f32(&mut s.f32_a, bsz);
                let ints = reuse_i64(&mut s.i64_a, bsz);
                let mut exps = Vec::with_capacity(origins.len());
                let mut codes = Vec::with_capacity(origins.len() * bsz);
                self.encode_blocks(&sub, &origins, d, blk, ints, &mut exps, &mut codes);
                (exps, codes)
            })
        } else {
            const ORIGIN_CHUNK: usize = 64;
            let chunks: Vec<&[Vec<usize>]> = origins.chunks(ORIGIN_CHUNK).collect();
            let sub = Tensor::new(lattice.clone(), t.data().to_vec());
            Executor::global().par_map_scratch(chunks.len(), |ci, s| {
                let blk = reuse_f32(&mut s.f32_a, bsz);
                let ints = reuse_i64(&mut s.i64_a, bsz);
                let mut exps = Vec::with_capacity(chunks[ci].len());
                let mut codes = Vec::with_capacity(chunks[ci].len() * bsz);
                self.encode_blocks(&sub, chunks[ci], d, blk, ints, &mut exps, &mut codes);
                (exps, codes)
            })
        };
        let mut exps: Vec<i16> = Vec::with_capacity(batch * origins.len());
        let mut codes: Vec<i32> = Vec::with_capacity(t.len());
        for (e, c) in parts {
            exps.extend(e);
            codes.extend(c);
        }

        self.serialize(&shape, &exps, &codes)
    }

    /// Serialize geometry + compressed exponents + the entropy-coded
    /// coefficient stream.
    fn serialize(&self, shape: &[usize], exps: &[i16], codes: &[i32]) -> Result<Vec<u8>> {
        let mut out = Vec::new();
        out.push(self.precision as u8);
        out.extend_from_slice(&(shape.len() as u32).to_le_bytes());
        for &s in shape {
            out.extend_from_slice(&(s as u64).to_le_bytes());
        }
        out.extend_from_slice(&(exps.len() as u64).to_le_bytes());
        let exp_bytes: Vec<u8> = exps.iter().flat_map(|e| e.to_le_bytes()).collect();
        let zexp = lossless_compress(&exp_bytes)?;
        out.extend_from_slice(&(zexp.len() as u64).to_le_bytes());
        out.extend(zexp);
        let z = compress_symbols(codes)?;
        out.extend_from_slice(&(z.len() as u64).to_le_bytes());
        out.extend(z);
        Ok(out)
    }

    /// Single-lattice compress on the caller's scratch arena — the v3
    /// per-tile hot path (serial: tiles are already the parallel grain).
    /// Byte-identical to [`ZfpLike::compress`] of the same data.
    pub fn compress_scratch(
        &self,
        shape: &[usize],
        data: &[f32],
        scratch: &mut Scratch,
    ) -> Result<Vec<u8>> {
        ensure!(
            shape.iter().product::<usize>() == data.len(),
            "zfp: shape {:?} does not match {} values",
            shape,
            data.len()
        );
        let rank = shape.len();
        let d = rank.min(3);
        let lattice: Vec<usize> = shape[rank - d..].to_vec();
        let batch: usize = shape[..rank - d].iter().product();
        let vol: usize = lattice.iter().product();
        let bsz = BLOCK.pow(d as u32);
        let origins = crate::tensor::block_origins(&lattice, &vec![BLOCK; d]);
        let Scratch { f32_a, i64_a, i32_a, .. } = scratch;
        let codes = i32_a;
        codes.clear();
        let mut exps: Vec<i16> = Vec::with_capacity(batch * origins.len());
        if batch > 0 && vol > 0 {
            let _span = crate::obs::stages::ZFP_TRANSFORM.span();
            for b in 0..batch {
                let sub =
                    Tensor::new(lattice.clone(), data[b * vol..(b + 1) * vol].to_vec());
                let blk = reuse_f32(f32_a, bsz);
                let ints = reuse_i64(i64_a, bsz);
                self.encode_blocks(&sub, &origins, d, blk, ints, &mut exps, codes);
            }
        }
        self.serialize(shape, &exps, codes)
    }

    pub fn decompress(bytes: &[u8]) -> Result<Tensor> {
        Self::decompress_capped(bytes, MAX_POINTS_DEFAULT)
    }

    /// Decompress with an explicit cap on the decoded point count.
    pub fn decompress_capped(bytes: &[u8], max_points: usize) -> Result<Tensor> {
        Self::decompress_capped_scratch(bytes, max_points, &mut Scratch::default())
    }

    /// [`ZfpLike::decompress_capped`] on the caller's scratch arena — the
    /// v3 per-tile hot path (entropy table/LUT and code buffers reused
    /// across tiles). All header fields are untrusted: lengths are
    /// bounds-checked before sizing any allocation, so corrupt or
    /// truncated streams return `Err` — never panic, never balloon
    /// memory.
    pub fn decompress_capped_scratch(
        bytes: &[u8],
        max_points: usize,
        scratch: &mut Scratch,
    ) -> Result<Tensor> {
        ensure!(bytes.len() > 5, "zfp: truncated");
        let precision = bytes[0] as u32;
        ensure!(
            (1..=FRAC_BITS).contains(&precision),
            "zfp: corrupt precision {precision}"
        );
        let rank = u32::from_le_bytes(bytes[1..5].try_into().unwrap()) as usize;
        ensure!((1..=MAX_RANK).contains(&rank), "zfp: corrupt rank {rank}");
        let mut off = 5;
        let mut shape = Vec::with_capacity(rank);
        let mut n_points = 1usize;
        for _ in 0..rank {
            let dim = usize::try_from(read_u64(bytes, &mut off)?)
                .map_err(|_| anyhow::anyhow!("zfp: shape dim overflow"))?;
            n_points = n_points
                .checked_mul(dim)
                .filter(|&n| n <= max_points)
                .ok_or_else(|| anyhow::anyhow!("zfp: declared points exceed cap {max_points}"))?;
            shape.push(dim);
        }
        // geometry the stream must be consistent with (checked before any
        // length-derived allocation)
        let d = rank.min(3);
        let lattice: Vec<usize> = shape[rank - d..].to_vec();
        let batch: usize = shape[..rank - d].iter().product();
        let vol: usize = lattice.iter().product();
        let bsz = BLOCK.pow(d as u32);
        // bound the origin-grid size before materializing it: a zero
        // batch dim zeroes n_points, which must not let huge lattice
        // dims smuggle an astronomic origin allocation past the cap
        let n_lattice_blocks = lattice
            .iter()
            .try_fold(1usize, |a, &dim| a.checked_mul(dim.div_ceil(BLOCK)))
            .ok_or_else(|| anyhow::anyhow!("zfp: block count overflow"))?;
        ensure!(
            n_lattice_blocks <= n_points.max(1),
            "zfp: {n_lattice_blocks} lattice blocks inconsistent with {n_points} points"
        );
        let origins = crate::tensor::block_origins(&lattice, &vec![BLOCK; d]);
        let n_blocks = batch
            .checked_mul(origins.len())
            .ok_or_else(|| anyhow::anyhow!("zfp: block count overflow"))?;
        let n_codes = n_blocks
            .checked_mul(bsz)
            .ok_or_else(|| anyhow::anyhow!("zfp: code count overflow"))?;

        let n_exp = usize::try_from(read_u64(bytes, &mut off)?)
            .map_err(|_| anyhow::anyhow!("zfp: exponent count overflow"))?;
        ensure!(n_exp == n_blocks, "zfp: exponent count {n_exp} != {n_blocks} blocks");
        let zel = usize::try_from(read_u64(bytes, &mut off)?)
            .map_err(|_| anyhow::anyhow!("zfp: exponent stream overflow"))?;
        ensure!(zel <= bytes.len() - off, "zfp: exponent stream truncated");
        let exp_bytes = lossless_decompress(&bytes[off..off + zel], n_exp * 2 + 16)?;
        off += zel;
        ensure!(exp_bytes.len() == n_exp * 2, "zfp: exponent bytes corrupt");
        let exps: Vec<i16> = exp_bytes
            .chunks_exact(2)
            .map(|b| i16::from_le_bytes([b[0], b[1]]))
            .collect();
        let zl = usize::try_from(read_u64(bytes, &mut off)?)
            .map_err(|_| anyhow::anyhow!("zfp: entropy stream overflow"))?;
        ensure!(zl <= bytes.len() - off, "zfp: entropy stream truncated");
        ensure!(off + zl == bytes.len(), "zfp: trailing bytes");
        // symbol container: plain streams from old archives and the new
        // zero-run/const modes all dispatch on the leading magic
        let Scratch { i32_a, symbols, .. } = scratch;
        decompress_symbols_into(&bytes[off..off + zl], n_codes, i32_a, symbols)?;
        let codes: &[i32] = i32_a;
        ensure!(codes.len() == n_codes, "zfp: code count");

        let shift = FRAC_BITS - precision;
        // every block decodes independently (codes/exps are indexed by
        // global block number); blocks are decoded in groups to amortize
        // allocations, then scattered serially
        const DEC_GROUP: usize = 64;
        let n_groups = n_blocks.div_ceil(DEC_GROUP);
        let _span = crate::obs::stages::ZFP_RECONSTRUCT.span();
        let groups: Vec<Vec<f32>> = Executor::global().par_map_scratch(n_groups, |g, s| {
            let lo = g * DEC_GROUP;
            let hi = (lo + DEC_GROUP).min(n_blocks);
            let mut out = vec![0f32; (hi - lo) * bsz];
            for bi in lo..hi {
                let ints = reuse_i64(&mut s.i64_a, bsz);
                for (v, &c) in ints.iter_mut().zip(&codes[bi * bsz..(bi + 1) * bsz]) {
                    *v = (c as i64) << shift;
                }
                inv_transform(ints, d);
                let e = exps[bi] as i32;
                let scale = 2f64.powi(e - FRAC_BITS as i32);
                let dst = &mut out[(bi - lo) * bsz..(bi - lo + 1) * bsz];
                for (o, &v) in dst.iter_mut().zip(ints.iter()) {
                    *o = (v as f64 * scale) as f32;
                }
            }
            out
        });
        let mut data = vec![0f32; batch * vol];
        for b in 0..batch {
            let mut sub = Tensor::new(lattice.clone(), vec![0f32; vol]);
            for (oi, o) in origins.iter().enumerate() {
                let bi = b * origins.len() + oi;
                let (g, r) = (bi / DEC_GROUP, bi % DEC_GROUP);
                crate::tensor::scatter_block(
                    &mut sub,
                    o,
                    &vec![BLOCK; d],
                    &groups[g][r * bsz..(r + 1) * bsz],
                );
            }
            data[b * vol..(b + 1) * vol].copy_from_slice(sub.data());
        }
        Ok(Tensor::new(shape, data))
    }

    /// Byte breakdown of one stream for `cli info` (see
    /// [`StreamBreakdown`]): framing vs compressed exponents vs entropy
    /// table vs coded symbols.
    pub fn stream_breakdown(bytes: &[u8], max_points: usize) -> Result<StreamBreakdown> {
        ensure!(bytes.len() > 5, "zfp: truncated");
        let rank = u32::from_le_bytes(bytes[1..5].try_into().unwrap()) as usize;
        ensure!((1..=MAX_RANK).contains(&rank), "zfp: corrupt rank {rank}");
        let mut off = 5;
        let mut shape = Vec::with_capacity(rank);
        let mut n_points = 1usize;
        for _ in 0..rank {
            let dim = usize::try_from(read_u64(bytes, &mut off)?)
                .map_err(|_| anyhow::anyhow!("zfp: shape dim overflow"))?;
            n_points = n_points
                .checked_mul(dim)
                .filter(|&n| n <= max_points)
                .ok_or_else(|| anyhow::anyhow!("zfp: declared points exceed cap {max_points}"))?;
            shape.push(dim);
        }
        let d = rank.min(3);
        let lattice: Vec<usize> = shape[rank - d..].to_vec();
        let batch: usize = shape[..rank - d].iter().product();
        let bsz = BLOCK.pow(d as u32);
        // same origin-grid bound as the decoder: a zero batch dim must
        // not let huge lattice dims size the origin allocation
        let n_lattice_blocks = lattice
            .iter()
            .try_fold(1usize, |a, &dim| a.checked_mul(dim.div_ceil(BLOCK)))
            .ok_or_else(|| anyhow::anyhow!("zfp: block count overflow"))?;
        ensure!(
            n_lattice_blocks <= n_points.max(1),
            "zfp: {n_lattice_blocks} lattice blocks inconsistent with {n_points} points"
        );
        let origins = crate::tensor::block_origins(&lattice, &vec![BLOCK; d]);
        let n_codes = batch
            .checked_mul(origins.len())
            .and_then(|b| b.checked_mul(bsz))
            .ok_or_else(|| anyhow::anyhow!("zfp: code count overflow"))?;
        let _ = read_u64(bytes, &mut off)?; // n_exp
        let zel = usize::try_from(read_u64(bytes, &mut off)?)
            .map_err(|_| anyhow::anyhow!("zfp: exponent stream overflow"))?;
        ensure!(zel <= bytes.len() - off, "zfp: exponent stream truncated");
        off += zel;
        let zl = usize::try_from(read_u64(bytes, &mut off)?)
            .map_err(|_| anyhow::anyhow!("zfp: entropy stream overflow"))?;
        ensure!(zl <= bytes.len() - off, "zfp: entropy stream truncated");
        ensure!(off + zl == bytes.len(), "zfp: trailing bytes");
        let stats = symbol_stream_stats(&bytes[off..off + zl], n_codes)?;
        Ok(StreamBreakdown {
            mode: stats.mode,
            framing_bytes: bytes.len() - zel - zl,
            aux_bytes: zel,
            table_bytes: stats.table_bytes,
            symbol_bytes: stats.symbol_bytes,
            lanes: stats.lanes,
        })
    }
}

/// ZFP forward lifting on a 4-vector.
fn lift4(v: &mut [i64; 4]) {
    let [mut x, mut y, mut z, mut w] = *v;
    x += w;
    x >>= 1;
    w -= x;
    z += y;
    z >>= 1;
    y -= z;
    x += z;
    x >>= 1;
    z -= x;
    w += y;
    w >>= 1;
    y -= w;
    w += y >> 1;
    y -= w >> 1;
    *v = [x, y, z, w];
}

/// ZFP inverse lifting on a 4-vector.
fn unlift4(v: &mut [i64; 4]) {
    let [mut x, mut y, mut z, mut w] = *v;
    y += w >> 1;
    w -= y >> 1;
    y += w;
    w <<= 1;
    w -= y;
    z += x;
    x <<= 1;
    x -= z;
    y += z;
    z <<= 1;
    z -= y;
    w += x;
    x <<= 1;
    x -= w;
    *v = [x, y, z, w];
}

/// Forward-lift one line of 4 values at `base` with constant `stride`.
#[inline]
fn lift_line(ints: &mut [i64], base: usize, stride: usize) {
    let mut v = [
        ints[base],
        ints[base + stride],
        ints[base + 2 * stride],
        ints[base + 3 * stride],
    ];
    lift4(&mut v);
    ints[base] = v[0];
    ints[base + stride] = v[1];
    ints[base + 2 * stride] = v[2];
    ints[base + 3 * stride] = v[3];
}

/// Inverse-lift one line of 4 values at `base` with constant `stride`.
#[inline]
fn unlift_line(ints: &mut [i64], base: usize, stride: usize) {
    let mut v = [
        ints[base],
        ints[base + stride],
        ints[base + 2 * stride],
        ints[base + 3 * stride],
    ];
    unlift4(&mut v);
    ints[base] = v[0];
    ints[base + stride] = v[1];
    ints[base + 2 * stride] = v[2];
    ints[base + 3 * stride] = v[3];
}

/// Separable forward transform, dimension-specialized: each axis pass
/// enumerates its line bases directly with compile-time strides instead
/// of scanning all 4^d positions with a per-element div/mod filter
/// ([`fwd_transform_reference`], kept as the bit-equivalence oracle).
/// Lifting is exact integer arithmetic on disjoint lines, so the
/// specialization is bit-identical.
fn fwd_transform(ints: &mut [i64], d: usize) {
    match d {
        0 => {}
        1 => lift_line(ints, 0, 1),
        2 => {
            for x in 0..4 {
                lift_line(ints, x, 4);
            }
            for y in 0..4 {
                lift_line(ints, y * 4, 1);
            }
        }
        3 => {
            for i in 0..16 {
                lift_line(ints, i, 16);
            }
            for z in 0..4 {
                for x in 0..4 {
                    lift_line(ints, z * 16 + x, 4);
                }
            }
            for i in 0..16 {
                lift_line(ints, i * 4, 1);
            }
        }
        _ => unreachable!("zfp block rank is at most 3"),
    }
}

/// Separable inverse transform, dimension-specialized (axes in reverse
/// order of [`fwd_transform`]; see there for the equivalence argument).
fn inv_transform(ints: &mut [i64], d: usize) {
    match d {
        0 => {}
        1 => unlift_line(ints, 0, 1),
        2 => {
            for y in 0..4 {
                unlift_line(ints, y * 4, 1);
            }
            for x in 0..4 {
                unlift_line(ints, x, 4);
            }
        }
        3 => {
            for i in 0..16 {
                unlift_line(ints, i * 4, 1);
            }
            for z in 0..4 {
                for x in 0..4 {
                    unlift_line(ints, z * 16 + x, 4);
                }
            }
            for i in 0..16 {
                unlift_line(ints, i, 16);
            }
        }
        _ => unreachable!("zfp block rank is at most 3"),
    }
}

fn for_each_line(d: usize, axis: usize, mut f: impl FnMut(usize, usize)) {
    // iterate lines along `axis` of a 4^d block; call f(base, stride)
    let stride = BLOCK.pow((d - 1 - axis) as u32);
    let total = BLOCK.pow(d as u32);
    let mut base = 0;
    while base < total {
        // skip bases inside a line
        let along = (base / stride) % BLOCK;
        if along == 0 {
            f(base, stride);
        }
        base += 1;
    }
}

/// The pre-restructure generic axis walker. Oracle only: the
/// dimension-specialized [`fwd_transform`] must match it bit for bit.
#[doc(hidden)]
pub fn fwd_transform_reference(ints: &mut [i64], d: usize) {
    for axis in 0..d {
        for_each_line(d, axis, |base, stride| lift_line(ints, base, stride));
    }
}

/// The pre-restructure generic inverse walker. Oracle only: the
/// dimension-specialized [`inv_transform`] must match it bit for bit.
#[doc(hidden)]
pub fn inv_transform_reference(ints: &mut [i64], d: usize) {
    for axis in (0..d).rev() {
        for_each_line(d, axis, |base, stride| unlift_line(ints, base, stride));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn lift_unlift_near_inverse() {
        // zfp's lifting is near-orthogonal, not exactly invertible: the
        // >>1 stages drop low bits, so inv∘fwd may differ by a few LSBs
        // (real zfp absorbs this in guard bits). At FRAC_BITS=26 a few
        // LSBs are ~1e-7 relative — far below any precision setting.
        let mut rng = Rng::new(1);
        for _ in 0..200 {
            let orig = [
                rng.next_u64() as i32 as i64,
                rng.next_u64() as i32 as i64,
                rng.next_u64() as i32 as i64,
                rng.next_u64() as i32 as i64,
            ];
            let mut v = orig;
            lift4(&mut v);
            unlift4(&mut v);
            for (a, b) in v.iter().zip(&orig) {
                assert!((a - b).abs() <= 4, "{v:?} vs {orig:?}");
            }
        }
    }

    #[test]
    fn transform_near_inverse_3d() {
        let mut rng = Rng::new(2);
        let orig: Vec<i64> = (0..64).map(|_| rng.next_u64() as i32 as i64).collect();
        let mut v = orig.clone();
        fwd_transform(&mut v, 3);
        inv_transform(&mut v, 3);
        for (a, b) in v.iter().zip(&orig) {
            assert!((a - b).abs() <= 64, "3d transform drift too large");
        }
    }

    fn smooth(shape: Vec<usize>, seed: u64) -> Tensor {
        let n: usize = shape.iter().product();
        let mut rng = Rng::new(seed);
        let (a, b) = (rng.uniform() * 4.0 + 1.0, rng.uniform());
        Tensor::new(
            shape,
            (0..n)
                .map(|i| {
                    let x = i as f64 / 37.0;
                    ((a * x).sin() * 2.0 + b) as f32
                })
                .collect(),
        )
    }

    #[test]
    fn round_trip_error_shrinks_with_precision() {
        let t = smooth(vec![16, 16, 16], 3);
        let mut last_err = f64::INFINITY;
        for &p in &[6u32, 12, 20] {
            let bytes = ZfpLike::new(p).compress(&t).unwrap();
            let back = ZfpLike::decompress(&bytes).unwrap();
            let err: f64 = t
                .data()
                .iter()
                .zip(back.data())
                .map(|(&x, &y)| ((x - y) as f64).powi(2))
                .sum::<f64>()
                .sqrt();
            assert!(err < last_err, "p={p}: {err} !< {last_err}");
            last_err = err;
        }
        assert!(last_err < 1e-2);
    }

    #[test]
    fn lower_precision_smaller_archive() {
        let t = smooth(vec![32, 32], 5);
        let lo = ZfpLike::new(4).compress(&t).unwrap();
        let hi = ZfpLike::new(20).compress(&t).unwrap();
        assert!(lo.len() < hi.len());
    }

    #[test]
    fn non_multiple_of_4_shapes() {
        let t = smooth(vec![5, 7, 9], 7);
        let back = ZfpLike::decompress(&ZfpLike::new(18).compress(&t).unwrap()).unwrap();
        assert_eq!(back.shape(), t.shape());
        // padded positions don't corrupt interior values
        let err = t
            .data()
            .iter()
            .zip(back.data())
            .map(|(&x, &y)| (x - y).abs())
            .fold(0f32, f32::max);
        assert!(err < 1e-3, "max err {err}");
    }

    #[test]
    fn zero_block_handled() {
        let t = Tensor::new(vec![4, 4], vec![0.0; 16]);
        let back = ZfpLike::decompress(&ZfpLike::new(10).compress(&t).unwrap()).unwrap();
        assert_eq!(back.data(), t.data());
    }

    #[test]
    fn scratch_compress_matches_plain_compress() {
        // the per-tile scratch path must be byte-identical to the
        // batch-parallel path on the same data
        let mut scratch = Scratch::default();
        for (seed, shape) in [(3u64, vec![16, 16, 16]), (5, vec![9]), (7, vec![2, 3, 8, 8])] {
            let t = smooth(shape, seed);
            let z = ZfpLike::new(14);
            let a = z.compress(&t).unwrap();
            let b = z.compress_scratch(t.shape(), t.data(), &mut scratch).unwrap();
            assert_eq!(a, b);
            let back = ZfpLike::decompress_capped_scratch(&b, t.len(), &mut scratch).unwrap();
            assert_eq!(back.shape(), t.shape());
        }
    }

    #[test]
    fn stream_breakdown_reports_the_entropy_split() {
        let t = smooth(vec![12, 12, 12], 11);
        let bytes = ZfpLike::new(14).compress(&t).unwrap();
        let b = ZfpLike::stream_breakdown(&bytes, t.len()).unwrap();
        assert!(b.aux_bytes > 0, "exponent stream present");
        // framing is exactly the header fields: precision + rank +
        // 3 dims + exponent count + two stream lengths
        assert_eq!(b.framing_bytes, 1 + 4 + 3 * 8 + 8 + 8 + 8);
        assert!(b.table_bytes + b.symbol_bytes > 0);
        // lanes only ever reported for the rANS container mode
        assert!(b.lanes == 0 || b.mode == "rans");
    }

    #[test]
    fn specialized_transforms_match_the_generic_oracle() {
        // the dimension-specialized axis passes must agree exactly with
        // the div/mod line walker they replaced, in both directions
        let mut rng = Rng::new(17);
        for d in 0..=3usize {
            let n = BLOCK.pow(d as u32);
            for _ in 0..50 {
                let orig: Vec<i64> = (0..n).map(|_| rng.next_u64() as i32 as i64).collect();
                let mut a = orig.clone();
                let mut b = orig.clone();
                fwd_transform(&mut a, d);
                fwd_transform_reference(&mut b, d);
                assert_eq!(a, b, "fwd d={d}");
                inv_transform(&mut a, d);
                inv_transform_reference(&mut b, d);
                assert_eq!(a, b, "inv d={d}");
            }
        }
    }
}
