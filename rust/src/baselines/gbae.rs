//! GBAE — the block-autoencoder baseline (Fig. 4/5 "Baseline", ref [16]).
//!
//! "A block-based compressor which divides the original data into blocks
//! and compresses the block data with a set of cascaded fully connected
//! layers" (paper §III-D). We reuse the BAE artifact groups (same
//! architecture: FC encoder/decoder with ReLU) trained directly on raw
//! normalized blocks instead of residuals, plus optionally the GAE bound
//! (ref [16]'s GBAE) and a stacked residual corrector (the GAETC
//! stand-in — DESIGN.md §4).
//!
//! [`crate::codec::GbaeCodec`] wraps this into the unified `Codec` trait
//! with full archive round trips; the [`GbaeCompressor::compress`] path
//! below keeps the paper-accounting payload numbers the Fig. 4/5/6
//! experiment runners report.

use std::rc::Rc;

use crate::coder::{huffman_encoded_size, Quantizer};
use crate::compressor::gae_bound_stage;
use crate::config::{DatasetConfig, TrainConfig};
use crate::data::{Blocking, Normalizer};
use crate::model::ParamStore;
use crate::runtime::{HostTensor, Runtime};
use crate::tensor::Tensor;
use crate::train::{train_bae, TrainReport};
use crate::Result;
use anyhow::ensure;

/// Block-AE baseline compressor. Owns its runtime handle, like
/// [`crate::compressor::HierCompressor`].
pub struct GbaeCompressor {
    pub rt: Rc<Runtime>,
    pub dataset: DatasetConfig,
    /// Primary block AE (trained on raw blocks).
    pub ae: ParamStore,
    /// Optional residual corrector (GAETC-like stack).
    pub corrector: Option<ParamStore>,
}

/// Result of a baseline compression pass.
#[derive(Debug)]
pub struct GbaeResult {
    /// Reconstruction in the original domain.
    pub recon: Tensor,
    /// Paper-accounting compressed bytes (latents [+ GAE sections]).
    pub payload_bytes: usize,
    pub gae_coeffs: usize,
}

impl GbaeCompressor {
    /// Canonical checkpoint path for a baseline AE group.
    pub fn ckpt_path(ckpt_dir: &std::path::Path, group: &str) -> std::path::PathBuf {
        ckpt_dir.join(format!("gbae_{group}.ckpt"))
    }

    /// Canonical checkpoint path for a corrector group.
    pub fn corrector_ckpt_path(ckpt_dir: &std::path::Path, group: &str) -> std::path::PathBuf {
        ckpt_dir.join(format!("gbae_corr_{group}.ckpt"))
    }

    /// Gather all valid blocks of a normalized field as rows.
    fn block_rows(dataset: &DatasetConfig, norm: &Tensor) -> (Blocking, Vec<f32>) {
        let blocking = Blocking::new(dataset);
        let bd = blocking.block_dim();
        let total = blocking.num_hyperblocks();
        let mut rows = Vec::with_capacity(blocking.num_blocks() * bd);
        let mut buf = vec![0f32; blocking.k * bd];
        for h in 0..total {
            blocking.gather(norm, h, 1, &mut buf);
            for j in 0..blocking.k {
                if blocking.is_valid(h, j) {
                    rows.extend_from_slice(&buf[j * bd..(j + 1) * bd]);
                }
            }
        }
        (blocking, rows)
    }

    /// Train (or load) the baseline AE on raw blocks.
    pub fn prepare(
        rt: &Rc<Runtime>,
        dataset: &DatasetConfig,
        group: &str,
        ckpt_dir: &std::path::Path,
        field: &Tensor,
        train: &TrainConfig,
        with_corrector: Option<&str>,
    ) -> Result<(Self, Vec<TrainReport>)> {
        let mut reports = Vec::new();
        let stats = Normalizer::fit(dataset.normalization, field);
        let mut norm = field.clone();
        Normalizer::apply(&stats, &mut norm);
        let (_, rows) = Self::block_rows(dataset, &norm);
        let bd: usize = dataset.block_dim();

        let path = Self::ckpt_path(ckpt_dir, group);
        let ae = if path.exists() {
            ParamStore::load(&path, group)?
        } else {
            let mut store = ParamStore::init(rt, group)?;
            let rep = train_bae(rt, &mut store, &rows, bd, train)?;
            reports.push(rep);
            store.save(&path)?;
            store
        };

        let corrector = if let Some(cg) = with_corrector {
            let cpath = Self::corrector_ckpt_path(ckpt_dir, cg);
            if cpath.exists() {
                Some(ParamStore::load(&cpath, cg)?)
            } else {
                // residuals of the primary AE
                let enc = rt.load(&ae.group, "encode")?;
                let dec = rt.load(&ae.group, "decode")?;
                let nb = enc.info.inputs[1].shape[0];
                let n_rows = rows.len() / bd;
                let phi = HostTensor::vec(ae.theta.clone());
                let mut resid = Vec::with_capacity(rows.len());
                for r0 in (0..n_rows).step_by(nb) {
                    let mut batch = vec![0f32; nb * bd];
                    let n_here = (n_rows - r0).min(nb);
                    batch[..n_here * bd]
                        .copy_from_slice(&rows[r0 * bd..(r0 + n_here) * bd]);
                    let lat = enc
                        .run(&[phi.clone(), HostTensor::new(vec![nb, bd], batch.clone())])?
                        .remove(0);
                    let y = dec.run(&[phi.clone(), lat])?.remove(0);
                    for i in 0..n_here * bd {
                        resid.push(batch[i] - y.data[i]);
                    }
                }
                let mut store = ParamStore::init(rt, cg)?;
                let rep = train_bae(rt, &mut store, &resid, bd, train)?;
                reports.push(rep);
                store.save(&cpath)?;
                Some(store)
            }
        } else {
            None
        };

        Ok((
            Self { rt: rt.clone(), dataset: dataset.clone(), ae, corrector },
            reports,
        ))
    }

    /// Forward the AE (+ optional corrector) over a **normalized** field.
    ///
    /// Returns `(primary latent rows, corrector latent rows, recon)` with
    /// latent rows collected for valid blocks only, quantizer-snapped, and
    /// the reconstruction still in the normalized domain.
    pub fn forward(
        &self,
        norm: &Tensor,
        q: Quantizer,
    ) -> Result<(Vec<f32>, Option<Vec<f32>>, Tensor)> {
        let blocking = Blocking::new(&self.dataset);
        let bd = blocking.block_dim();
        let enc = self.rt.load(&self.ae.group, "encode")?;
        let dec = self.rt.load(&self.ae.group, "decode")?;
        let nb = enc.info.inputs[1].shape[0];
        let lat_dim = enc.info.outputs[0].shape[1];
        let phi = HostTensor::vec(self.ae.theta.clone());

        let total_hb = blocking.num_hyperblocks();
        let k = blocking.k;
        ensure!(nb % k == 0, "bae batch not a multiple of k");
        let hb_per_batch = nb / k;

        let mut recon = Tensor::zeros(self.dataset.dims.clone());
        let mut lat_rows: Vec<f32> = Vec::new();
        let mut corr_rows: Vec<f32> = Vec::new();
        let mut batch = vec![0f32; nb * bd];
        for h0 in (0..total_hb).step_by(hb_per_batch) {
            blocking.gather(norm, h0, hb_per_batch, &mut batch);
            let mut lat = enc
                .run(&[phi.clone(), HostTensor::new(vec![nb, bd], batch.clone())])?
                .remove(0);
            q.snap(&mut lat.data);
            let y = dec.run(&[phi.clone(), lat.clone()])?.remove(0);
            let mut recon_batch = y.data.clone();

            let clat = if let Some(corr) = &self.corrector {
                let cenc = self.rt.load(&corr.group, "encode")?;
                let cdec = self.rt.load(&corr.group, "decode")?;
                let cphi = HostTensor::vec(corr.theta.clone());
                let resid: Vec<f32> =
                    batch.iter().zip(&recon_batch).map(|(&a, &b)| a - b).collect();
                let mut clat = cenc
                    .run(&[cphi.clone(), HostTensor::new(vec![nb, bd], resid)])?
                    .remove(0);
                q.snap(&mut clat.data);
                let rhat = cdec.run(&[cphi, clat.clone()])?.remove(0);
                for i in 0..recon_batch.len() {
                    recon_batch[i] += rhat.data[i];
                }
                Some(clat)
            } else {
                None
            };

            // collect valid-block latent rows in block order
            for hi in 0..hb_per_batch {
                let h = h0 + hi;
                if h >= total_hb {
                    break;
                }
                for j in 0..k {
                    if blocking.is_valid(h, j) {
                        let r = hi * k + j;
                        lat_rows.extend_from_slice(&lat.data[r * lat_dim..(r + 1) * lat_dim]);
                        if let Some(c) = &clat {
                            let cd = c.shape[1];
                            corr_rows.extend_from_slice(&c.data[r * cd..(r + 1) * cd]);
                        }
                    }
                }
            }
            blocking.scatter(&mut recon, h0, hb_per_batch, &recon_batch);
        }
        let corr = if self.corrector.is_some() { Some(corr_rows) } else { None };
        Ok((lat_rows, corr, recon))
    }

    /// Decode latent rows (valid blocks, block order) back into a
    /// **normalized**-domain reconstruction — the inverse of
    /// [`Self::forward`]'s latent collection.
    pub fn decode(&self, lat_rows: &[f32], corr_rows: Option<&[f32]>) -> Result<Tensor> {
        let blocking = Blocking::new(&self.dataset);
        let dec = self.rt.load(&self.ae.group, "decode")?;
        let nb = dec.info.inputs[1].shape[0];
        let lat_dim = dec.info.inputs[1].shape[1];
        let phi = HostTensor::vec(self.ae.theta.clone());

        let total_hb = blocking.num_hyperblocks();
        let k = blocking.k;
        ensure!(nb % k == 0, "bae batch not a multiple of k");
        let hb_per_batch = nb / k;
        ensure!(
            lat_rows.len() == blocking.num_blocks() * lat_dim,
            "GLAT length mismatch: {} != {} blocks x {lat_dim}",
            lat_rows.len(),
            blocking.num_blocks()
        );
        ensure!(
            corr_rows.is_some() == self.corrector.is_some(),
            "archive corrector stream does not match loaded corrector"
        );

        let mut recon = Tensor::zeros(self.dataset.dims.clone());
        let mut cursor = 0usize;
        let mut ccursor = 0usize;
        for h0 in (0..total_hb).step_by(hb_per_batch) {
            // fill the batch's latent rows (padding rows stay zero)
            let mut lb = vec![0f32; nb * lat_dim];
            let row_of = |hi: usize, j: usize| hi * k + j;
            let mut valid: Vec<(usize, usize)> = Vec::new();
            for hi in 0..hb_per_batch {
                let h = h0 + hi;
                if h >= total_hb {
                    break;
                }
                for j in 0..k {
                    if blocking.is_valid(h, j) {
                        valid.push((hi, j));
                    }
                }
            }
            for &(hi, j) in &valid {
                let r = row_of(hi, j);
                lb[r * lat_dim..(r + 1) * lat_dim]
                    .copy_from_slice(&lat_rows[cursor..cursor + lat_dim]);
                cursor += lat_dim;
            }
            let y = dec
                .run(&[phi.clone(), HostTensor::new(vec![nb, lat_dim], lb)])?
                .remove(0);
            let mut recon_batch = y.data;

            if let (Some(corr), Some(crows)) = (&self.corrector, corr_rows) {
                let cdec = self.rt.load(&corr.group, "decode")?;
                let cd = cdec.info.inputs[1].shape[1];
                ensure!(cdec.info.inputs[1].shape[0] == nb, "corrector batch mismatch");
                let mut cb = vec![0f32; nb * cd];
                for &(hi, j) in &valid {
                    let r = row_of(hi, j);
                    ensure!(ccursor + cd <= crows.len(), "GCLT underrun");
                    cb[r * cd..(r + 1) * cd].copy_from_slice(&crows[ccursor..ccursor + cd]);
                    ccursor += cd;
                }
                let cphi = HostTensor::vec(corr.theta.clone());
                let rhat = cdec
                    .run(&[cphi, HostTensor::new(vec![nb, cd], cb)])?
                    .remove(0);
                for i in 0..recon_batch.len() {
                    recon_batch[i] += rhat.data[i];
                }
            }
            blocking.scatter(&mut recon, h0, hb_per_batch, &recon_batch);
        }
        Ok(recon)
    }

    /// Compress + reconstruct with paper-accounting payload bytes.
    /// `latent_bin` 0 disables quantization (Fig. 4/5 ablation accounting:
    /// raw f32 latents); `tau` 0 disables the GAE bound.
    pub fn compress(&self, field: &Tensor, latent_bin: f32, tau: f32) -> Result<GbaeResult> {
        let stats = Normalizer::fit(self.dataset.normalization, field);
        let mut norm = field.clone();
        Normalizer::apply(&stats, &mut norm);

        let q = Quantizer::new(latent_bin.max(0.0));
        let (lat_rows, corr_rows, mut recon) = self.forward(&norm, q)?;

        // latent payload (Quantizer::codes fans out over the shared
        // executor with fixed chunking — order-identical at any thread
        // count)
        let n_latents = lat_rows.len() + corr_rows.as_ref().map_or(0, |c| c.len());
        let mut payload = if q.enabled() {
            let mut codes = q.codes(&lat_rows);
            if let Some(c) = &corr_rows {
                codes.extend(q.codes(c));
            }
            // exact size via the shared frequency counter — no bitstream
            // needs to be materialized for accounting
            huffman_encoded_size(&codes)
        } else {
            n_latents * 4
        };

        // optional GAE bound (same machinery as the main pipeline)
        let mut gae_coeffs = 0usize;
        if let Some(g) = gae_bound_stage(&self.dataset, &stats, tau, &norm, &mut recon)? {
            payload += g.gcof.len() + g.gidx.len();
            gae_coeffs = g.total_coeffs;
        }

        Normalizer::invert(&stats, &mut recon);
        Ok(GbaeResult { recon, payload_bytes: payload, gae_coeffs })
    }
}
