//! GBAE — the block-autoencoder baseline (Fig. 4/5 "Baseline", ref [16]).
//!
//! "A block-based compressor which divides the original data into blocks
//! and compresses the block data with a set of cascaded fully connected
//! layers" (paper §III-D). We reuse the BAE artifact groups (same
//! architecture: FC encoder/decoder with ReLU) trained directly on raw
//! normalized blocks instead of residuals, plus optionally the GAE bound
//! (ref [16]'s GBAE) and a stacked residual corrector (the GAETC
//! stand-in — DESIGN.md §4).

use crate::coder::Quantizer;
use crate::config::{DatasetConfig, TrainConfig};
use crate::data::{Blocking, Normalizer};
use crate::model::ParamStore;
use crate::runtime::{HostTensor, Runtime};
use crate::tensor::Tensor;
use crate::train::{train_bae, TrainReport};
use crate::Result;
use anyhow::ensure;

/// Block-AE baseline compressor.
pub struct GbaeCompressor<'a> {
    pub rt: &'a Runtime,
    pub dataset: DatasetConfig,
    /// Primary block AE (trained on raw blocks).
    pub ae: ParamStore,
    /// Optional residual corrector (GAETC-like stack).
    pub corrector: Option<ParamStore>,
}

/// Result of a baseline compression pass.
#[derive(Debug)]
pub struct GbaeResult {
    /// Reconstruction in the original domain.
    pub recon: Tensor,
    /// Paper-accounting compressed bytes (latents [+ GAE sections]).
    pub payload_bytes: usize,
    pub gae_coeffs: usize,
}

impl<'a> GbaeCompressor<'a> {
    /// Gather all valid blocks of a normalized field as rows.
    fn block_rows(dataset: &DatasetConfig, norm: &Tensor) -> (Blocking, Vec<f32>) {
        let blocking = Blocking::new(dataset);
        let bd = blocking.block_dim();
        let total = blocking.num_hyperblocks();
        let mut rows = Vec::with_capacity(blocking.num_blocks() * bd);
        let mut buf = vec![0f32; blocking.k * bd];
        for h in 0..total {
            blocking.gather(norm, h, 1, &mut buf);
            for j in 0..blocking.k {
                if blocking.is_valid(h, j) {
                    rows.extend_from_slice(&buf[j * bd..(j + 1) * bd]);
                }
            }
        }
        (blocking, rows)
    }

    /// Train (or load) the baseline AE on raw blocks.
    pub fn prepare(
        rt: &'a Runtime,
        dataset: &DatasetConfig,
        group: &str,
        ckpt_dir: &std::path::Path,
        field: &Tensor,
        train: &TrainConfig,
        with_corrector: Option<&str>,
    ) -> Result<(Self, Vec<TrainReport>)> {
        let mut reports = Vec::new();
        let stats = Normalizer::fit(dataset.normalization, field);
        let mut norm = field.clone();
        Normalizer::apply(&stats, &mut norm);
        let (_, rows) = Self::block_rows(dataset, &norm);
        let bd: usize = dataset.block_dim();

        let path = ckpt_dir.join(format!("gbae_{group}.ckpt"));
        let ae = if path.exists() {
            ParamStore::load(&path, group)?
        } else {
            let mut store = ParamStore::init(rt, group)?;
            let rep = train_bae(rt, &mut store, &rows, bd, train)?;
            reports.push(rep);
            store.save(&path)?;
            store
        };

        let corrector = if let Some(cg) = with_corrector {
            let cpath = ckpt_dir.join(format!("gbae_corr_{cg}.ckpt"));
            if cpath.exists() {
                Some(ParamStore::load(&cpath, cg)?)
            } else {
                // residuals of the primary AE
                let enc = rt.load(&ae.group, "encode")?;
                let dec = rt.load(&ae.group, "decode")?;
                let nb = enc.info.inputs[1].shape[0];
                let n_rows = rows.len() / bd;
                let phi = HostTensor::vec(ae.theta.clone());
                let mut resid = Vec::with_capacity(rows.len());
                for r0 in (0..n_rows).step_by(nb) {
                    let mut batch = vec![0f32; nb * bd];
                    let n_here = (n_rows - r0).min(nb);
                    batch[..n_here * bd]
                        .copy_from_slice(&rows[r0 * bd..(r0 + n_here) * bd]);
                    let lat = enc
                        .run(&[phi.clone(), HostTensor::new(vec![nb, bd], batch.clone())])?
                        .remove(0);
                    let y = dec.run(&[phi.clone(), lat])?.remove(0);
                    for i in 0..n_here * bd {
                        resid.push(batch[i] - y.data[i]);
                    }
                }
                let mut store = ParamStore::init(rt, cg)?;
                let rep = train_bae(rt, &mut store, &resid, bd, train)?;
                reports.push(rep);
                store.save(&cpath)?;
                Some(store)
            }
        } else {
            None
        };

        Ok((
            Self { rt, dataset: dataset.clone(), ae, corrector },
            reports,
        ))
    }

    /// Compress + reconstruct. `latent_bin` 0 disables quantization
    /// (Fig. 4/5 ablation accounting: raw f32 latents); `tau` 0 disables
    /// the GAE bound.
    pub fn compress(&self, field: &Tensor, latent_bin: f32, tau: f32) -> Result<GbaeResult> {
        let stats = Normalizer::fit(self.dataset.normalization, field);
        let mut norm = field.clone();
        Normalizer::apply(&stats, &mut norm);

        let blocking = Blocking::new(&self.dataset);
        let bd = blocking.block_dim();
        let enc = self.rt.load(&self.ae.group, "encode")?;
        let dec = self.rt.load(&self.ae.group, "decode")?;
        let nb = enc.info.inputs[1].shape[0];
        let lat_dim = enc.info.outputs[0].shape[1];
        let q = Quantizer::new(latent_bin.max(0.0));
        let phi = HostTensor::vec(self.ae.theta.clone());

        let total_hb = blocking.num_hyperblocks();
        let k = blocking.k;
        ensure!(nb % k == 0, "bae batch not a multiple of k");
        let hb_per_batch = nb / k;

        let mut recon = Tensor::zeros(self.dataset.dims.clone());
        let mut latent_codes: Vec<i32> = Vec::new();
        let mut n_latents = 0usize;
        let mut batch = vec![0f32; nb * bd];
        for h0 in (0..total_hb).step_by(hb_per_batch) {
            blocking.gather(&norm, h0, hb_per_batch, &mut batch);
            let mut lat = enc
                .run(&[phi.clone(), HostTensor::new(vec![nb, bd], batch.clone())])?
                .remove(0);
            q.snap(&mut lat.data);
            let y = dec.run(&[phi.clone(), lat.clone()])?.remove(0);
            let mut recon_batch = y.data.clone();
            if let Some(corr) = &self.corrector {
                let cenc = self.rt.load(&corr.group, "encode")?;
                let cdec = self.rt.load(&corr.group, "decode")?;
                let cphi = HostTensor::vec(corr.theta.clone());
                let resid: Vec<f32> =
                    batch.iter().zip(&recon_batch).map(|(&a, &b)| a - b).collect();
                let mut clat = cenc
                    .run(&[cphi.clone(), HostTensor::new(vec![nb, bd], resid)])?
                    .remove(0);
                q.snap(&mut clat.data);
                let rhat = cdec.run(&[cphi, clat.clone()])?.remove(0);
                for i in 0..recon_batch.len() {
                    recon_batch[i] += rhat.data[i];
                }
                for hi in 0..hb_per_batch {
                    let h = h0 + hi;
                    if h >= total_hb {
                        break;
                    }
                    for j in 0..k {
                        if blocking.is_valid(h, j) {
                            let r = hi * k + j;
                            n_latents += lat_dim;
                            if q.enabled() {
                                latent_codes.extend(
                                    clat.data[r * lat_dim..(r + 1) * lat_dim]
                                        .iter()
                                        .map(|&v| q.code(v)),
                                );
                            }
                        }
                    }
                }
            }
            // primary latents of valid blocks
            for hi in 0..hb_per_batch {
                let h = h0 + hi;
                if h >= total_hb {
                    break;
                }
                for j in 0..k {
                    if blocking.is_valid(h, j) {
                        let r = hi * k + j;
                        n_latents += lat_dim;
                        if q.enabled() {
                            latent_codes.extend(
                                lat.data[r * lat_dim..(r + 1) * lat_dim]
                                    .iter()
                                    .map(|&v| q.code(v)),
                            );
                        }
                    }
                }
            }
            blocking.scatter(&mut recon, h0, hb_per_batch, &recon_batch);
        }

        // latent payload
        let mut payload = if q.enabled() {
            crate::coder::huffman_encode(&latent_codes).len()
        } else {
            n_latents * 4
        };

        // optional GAE bound (same machinery as the main pipeline)
        let mut gae_coeffs = 0usize;
        if tau > 0.0 {
            let d = self.dataset.gae_block_len();
            let origins =
                crate::tensor::block_origins(&self.dataset.dims, &self.dataset.gae_block);
            let taus = crate::compressor::gae_taus(&self.dataset, &stats, tau, &origins);
            let mut orig_rows = vec![0f32; origins.len() * d];
            let mut rec_rows = vec![0f32; origins.len() * d];
            for (bi, o) in origins.iter().enumerate() {
                crate::tensor::extract_block(
                    &norm,
                    o,
                    &self.dataset.gae_block,
                    &mut orig_rows[bi * d..(bi + 1) * d],
                );
                crate::tensor::extract_block(
                    &recon,
                    o,
                    &self.dataset.gae_block,
                    &mut rec_rows[bi * d..(bi + 1) * d],
                );
            }
            let out = crate::compressor::gae_apply(&orig_rows, &mut rec_rows, d, &taus)?;
            for (bi, o) in origins.iter().enumerate() {
                crate::tensor::scatter_block(
                    &mut recon,
                    o,
                    &self.dataset.gae_block,
                    &rec_rows[bi * d..(bi + 1) * d],
                );
            }
            let codes: Vec<i32> =
                out.corrections.iter().flat_map(|c| c.codes.iter().copied()).collect();
            payload += crate::coder::huffman_encode(&codes).len();
            let sets: Vec<Vec<usize>> =
                out.corrections.iter().map(|c| c.indices.clone()).collect();
            payload += crate::coder::encode_index_sets(&sets, d)?.len();
            gae_coeffs = out.total_coeffs;
        }

        Normalizer::invert(&stats, &mut recon);
        Ok(GbaeResult { recon, payload_bytes: payload, gae_coeffs })
    }
}
