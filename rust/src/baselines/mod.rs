//! Baseline compressors the paper compares against (§III-E).
//!
//! * [`sz3_like`] — prediction-based, error-bounded: N-D Lorenzo
//!   predictor + linear error quantization + Huffman + ZSTD (the
//!   algorithmic core of SZ/SZ3; DESIGN.md §4 documents the substitution
//!   for the real SZ3 binary).
//! * [`zfp_like`] — transform-based, fixed precision: 4^d block
//!   decorrelating lift (ZFP's transform) + per-block exponent + scaled
//!   integer coefficients + Huffman.
//! * [`gbae`] — the block-autoencoder baseline of Fig. 4/5 ("Baseline")
//!   and ref [16] (GBAE: block AE + GAE bound). With a stacked residual
//!   corrector it also stands in for GAETC.

pub mod gbae;
pub mod sz3_like;
pub mod zfp_like;

pub use gbae::GbaeCompressor;
pub use sz3_like::Sz3Like;
pub use zfp_like::ZfpLike;
