//! Baseline compressors the paper compares against (§III-E).
//!
//! * [`sz3_like`] — prediction-based, error-bounded: N-D Lorenzo
//!   predictor + linear error quantization + Huffman + ZSTD (the
//!   algorithmic core of SZ/SZ3; DESIGN.md §4 documents the substitution
//!   for the real SZ3 binary).
//! * [`zfp_like`] — transform-based, fixed precision: 4^d block
//!   decorrelating lift (ZFP's transform) + per-block exponent + scaled
//!   integer coefficients + Huffman.
//! * [`gbae`] — the block-autoencoder baseline of Fig. 4/5 ("Baseline")
//!   and ref [16] (GBAE: block AE + GAE bound). With a stacked residual
//!   corrector it also stands in for GAETC.

pub mod gbae;
pub mod sz3_like;
pub mod zfp_like;

pub use gbae::GbaeCompressor;
pub use sz3_like::Sz3Like;
pub use zfp_like::ZfpLike;

/// Byte breakdown of one baseline stream (`cli info` diagnostics):
/// container framing, auxiliary payload (sz3 raw values / zfp exponent
/// stream), and the entropy stage's table/symbol split. For plain
/// (LZSS-wrapped) entropy streams the table/symbol numbers are measured
/// in the entropy domain — the compressed split is not byte-attributable.
#[derive(Debug, Clone, Copy)]
pub struct StreamBreakdown {
    /// Entropy container mode: `"plain"`, `"zero-run"`, `"const"`, or
    /// `"rans"`.
    pub mode: &'static str,
    /// Header/length fields of the stream container.
    pub framing_bytes: usize,
    /// sz3 raw ("unpredictable") values / zfp compressed exponents.
    pub aux_bytes: usize,
    /// Serialized entropy table bytes (Huffman code lengths or rANS
    /// frequencies).
    pub table_bytes: usize,
    /// Coded symbol payload bytes.
    pub symbol_bytes: usize,
    /// Interleaved rANS lanes (0 for every non-rANS mode).
    pub lanes: usize,
}
