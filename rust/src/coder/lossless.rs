//! Lossless byte compression backend for the concatenated index bitmaps
//! (§II-E, Fig. 3) and the baseline compressors' entropy streams.
//!
//! The paper uses ZSTD; the build container has no zstd crate, so this is
//! an in-tree LZSS (LZ77 + flag-bit literals) with a 64 KiB window,
//! hash-chain matching, and unbounded match lengths (varint-coded), which
//! captures the long-run / repeated-period structure those streams have.
//! The format is self-framing (magic + raw length) and every decode path
//! returns `Err` on corrupt input — never panics.
//!
//! Layout:
//! ```text
//!   0xB3 | varint raw_len | groups of: flags u8 (LSB first, 1 = literal)
//!        then 8 tokens: literal = raw byte,
//!                       match   = u16 LE distance | varint (len - 4)
//! ```
//!
//! Streams larger than [`PAR_CHUNK`] use the chunked container instead
//! (magic 0xB4): fixed-size input chunks compressed independently on the
//! shared [`crate::engine::Executor`] and framed back to back. Chunk
//! boundaries depend only on the input length, so the bytes are
//! identical at every thread count; the decoder dispatches on the magic,
//! so 0xB3 streams from v1 archives keep decoding unchanged.
//! ```text
//!   0xB4 | varint raw_len | varint n_chunks |
//!   n x ( varint chunk_compressed_len | 0xB3 stream )
//! ```

use crate::engine::Executor;
use crate::Result;
use anyhow::{bail, ensure, Context};

const MAGIC_LZ: u8 = 0xB3;
const MAGIC_LZ_CHUNKED: u8 = 0xB4;
const MIN_MATCH: usize = 4;
const MAX_DIST: usize = 65_535;
const HASH_BITS: u32 = 15;
const MAX_CHAIN: usize = 64;

/// Input-chunk size of the parallel container. Each chunk restarts the
/// LZ window, trading a sliver of ratio for block parallelism.
pub const PAR_CHUNK: usize = 256 * 1024;

fn push_varint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let b = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(b);
            return;
        }
        out.push(b | 0x80);
    }
}

fn read_varint(data: &[u8], pos: &mut usize) -> Result<u64> {
    let mut v = 0u64;
    let mut shift = 0u32;
    loop {
        let b = *data.get(*pos).context("lossless: varint truncated")?;
        *pos += 1;
        v |= ((b & 0x7f) as u64) << shift;
        if b & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
        ensure!(shift < 64, "lossless: varint overflow");
    }
}

#[inline]
fn hash4(data: &[u8], i: usize) -> usize {
    let v = u32::from_le_bytes([data[i], data[i + 1], data[i + 2], data[i + 3]]);
    (v.wrapping_mul(0x9E37_79B1) >> (32 - HASH_BITS)) as usize
}

/// Token writer: buffers up to 8 tokens so the flags byte precedes them.
struct TokenWriter<'a> {
    out: &'a mut Vec<u8>,
    flags: u8,
    n: u32,
    buf: Vec<u8>,
}

impl<'a> TokenWriter<'a> {
    fn new(out: &'a mut Vec<u8>) -> Self {
        Self { out, flags: 0, n: 0, buf: Vec::with_capacity(64) }
    }

    fn literal(&mut self, b: u8) {
        self.flags |= 1 << self.n;
        self.buf.push(b);
        self.bump();
    }

    fn matched(&mut self, dist: u16, len: usize) {
        self.buf.extend_from_slice(&dist.to_le_bytes());
        push_varint(&mut self.buf, (len - MIN_MATCH) as u64);
        self.bump();
    }

    fn bump(&mut self) {
        self.n += 1;
        if self.n == 8 {
            self.flush();
        }
    }

    fn flush(&mut self) {
        if self.n > 0 {
            self.out.push(self.flags);
            self.out.extend_from_slice(&self.buf);
            self.flags = 0;
            self.n = 0;
            self.buf.clear();
        }
    }
}

/// Compress bytes (LZSS). Worst case ~12.5% expansion on random data.
/// Inputs above [`PAR_CHUNK`] use the chunked block-parallel container.
pub fn lossless_compress(data: &[u8]) -> Result<Vec<u8>> {
    if data.len() > PAR_CHUNK {
        return lossless_compress_chunked(data);
    }
    lossless_compress_single(data)
}

fn lossless_compress_chunked(data: &[u8]) -> Result<Vec<u8>> {
    let chunks: Vec<&[u8]> = data.chunks(PAR_CHUNK).collect();
    let parts =
        Executor::global().try_par_map(chunks.len(), |i| lossless_compress_single(chunks[i]))?;
    let mut out = vec![MAGIC_LZ_CHUNKED];
    push_varint(&mut out, data.len() as u64);
    push_varint(&mut out, parts.len() as u64);
    for p in &parts {
        push_varint(&mut out, p.len() as u64);
        out.extend_from_slice(p);
    }
    Ok(out)
}

fn lossless_compress_single(data: &[u8]) -> Result<Vec<u8>> {
    let mut out = vec![MAGIC_LZ];
    push_varint(&mut out, data.len() as u64);
    if data.is_empty() {
        return Ok(out);
    }

    // hash chains: head[h] = most recent position with that 4-byte hash,
    // prev is a window-sized ring (slot i & WMASK holds the previous
    // position in i's chain) — fixed 512 KiB of bookkeeping regardless of
    // input size, valid because matches beyond MAX_DIST are discarded
    // before any slot can be overwritten by a newer position
    const WINDOW: usize = MAX_DIST + 1; // power of two (1 << 16)
    const WMASK: usize = WINDOW - 1;
    let mut head = vec![usize::MAX; 1 << HASH_BITS];
    let mut prev = vec![usize::MAX; WINDOW];
    let mut w = TokenWriter::new(&mut out);

    let mut i = 0usize;
    while i < data.len() {
        let mut best_len = 0usize;
        let mut best_dist = 0usize;
        if i + MIN_MATCH <= data.len() {
            let mut cand = head[hash4(data, i)];
            let mut chain = 0usize;
            while cand != usize::MAX && chain < MAX_CHAIN {
                let dist = i - cand;
                if dist > MAX_DIST {
                    break; // chains go from recent to old: all further are too far
                }
                let max_len = data.len() - i;
                let mut l = 0usize;
                // overlap (dist < len) is fine: cand + l only reads bytes
                // the decoder will already have produced
                while l < max_len && data[cand + l] == data[i + l] {
                    l += 1;
                }
                if l > best_len {
                    best_len = l;
                    best_dist = dist;
                    if l == max_len {
                        break;
                    }
                }
                let next = prev[cand & WMASK];
                if next == usize::MAX || next >= cand {
                    break; // end of chain, or the ring slot was recycled
                }
                cand = next;
                chain += 1;
            }
        }

        if best_len >= MIN_MATCH {
            w.matched(best_dist as u16, best_len);
            let end = i + best_len;
            while i < end {
                if i + MIN_MATCH <= data.len() {
                    let h = hash4(data, i);
                    prev[i & WMASK] = head[h];
                    head[h] = i;
                }
                i += 1;
            }
        } else {
            w.literal(data[i]);
            if i + MIN_MATCH <= data.len() {
                let h = hash4(data, i);
                prev[i & WMASK] = head[h];
                head[h] = i;
            }
            i += 1;
        }
    }
    w.flush();
    Ok(out)
}

/// Decompress a [`lossless_compress`] stream; `max_size` caps the output
/// as a safety bound against corrupt archives. Dispatches on the magic:
/// plain 0xB3 streams (v1 archives) and chunked 0xB4 containers both
/// decode.
pub fn lossless_decompress(data: &[u8], max_size: usize) -> Result<Vec<u8>> {
    ensure!(!data.is_empty(), "lossless: empty input");
    match data[0] {
        MAGIC_LZ => lossless_decompress_single(data, max_size),
        MAGIC_LZ_CHUNKED => lossless_decompress_chunked(data, max_size),
        m => bail!("lossless: bad magic {m:#04x}"),
    }
}

fn lossless_decompress_chunked(data: &[u8], max_size: usize) -> Result<Vec<u8>> {
    let mut pos = 1usize;
    let raw_len = read_varint(data, &mut pos)? as usize;
    ensure!(
        raw_len <= max_size,
        "lossless: declared size {raw_len} exceeds cap {max_size}"
    );
    let n_chunks = read_varint(data, &mut pos)? as usize;
    // every chunk needs at least its length varint + magic + raw varint
    ensure!(
        n_chunks <= data.len().saturating_sub(pos).max(1),
        "lossless: {n_chunks} chunks impossible in {} bytes",
        data.len()
    );
    ensure!(
        n_chunks == raw_len.div_ceil(PAR_CHUNK).max(1),
        "lossless: chunk count {n_chunks} inconsistent with size {raw_len}"
    );
    let mut spans = Vec::with_capacity(n_chunks);
    for _ in 0..n_chunks {
        let clen = read_varint(data, &mut pos)? as usize;
        let end = pos
            .checked_add(clen)
            .ok_or_else(|| anyhow::anyhow!("lossless: chunk length overflow"))?;
        ensure!(end <= data.len(), "lossless: chunk truncated");
        spans.push(&data[pos..end]);
        pos = end;
    }
    ensure!(pos == data.len(), "lossless: {} trailing bytes", data.len() - pos);
    let parts = Executor::global().try_par_map(spans.len(), |i| {
        lossless_decompress_single(spans[i], PAR_CHUNK)
    })?;
    let mut out = Vec::with_capacity(raw_len);
    for p in parts {
        out.extend(p);
    }
    ensure!(
        out.len() == raw_len,
        "lossless: chunked payload {} != declared {raw_len}",
        out.len()
    );
    Ok(out)
}

fn lossless_decompress_single(data: &[u8], max_size: usize) -> Result<Vec<u8>> {
    ensure!(!data.is_empty(), "lossless: empty input");
    if data[0] != MAGIC_LZ {
        bail!("lossless: bad magic {:#04x}", data[0]);
    }
    let mut pos = 1usize;
    let raw_len = read_varint(data, &mut pos)? as usize;
    ensure!(
        raw_len <= max_size,
        "lossless: declared size {raw_len} exceeds cap {max_size}"
    );
    let mut out = Vec::with_capacity(raw_len);
    while out.len() < raw_len {
        let flags = *data.get(pos).context("lossless: flags truncated")?;
        pos += 1;
        for bit in 0..8u8 {
            if out.len() == raw_len {
                break;
            }
            if flags & (1 << bit) != 0 {
                out.push(*data.get(pos).context("lossless: literal truncated")?);
                pos += 1;
            } else {
                let lo = *data.get(pos).context("lossless: match truncated")?;
                let hi = *data.get(pos + 1).context("lossless: match truncated")?;
                pos += 2;
                let dist = u16::from_le_bytes([lo, hi]) as usize;
                ensure!(dist >= 1 && dist <= out.len(), "lossless: bad distance {dist}");
                let extra = read_varint(data, &mut pos)?;
                // bound-check BEFORE widening arithmetic: an adversarial
                // varint must not overflow `+ MIN_MATCH` below
                ensure!(extra <= raw_len as u64, "lossless: match length {extra} absurd");
                let len = extra as usize + MIN_MATCH;
                ensure!(out.len() + len <= raw_len, "lossless: match overruns output");
                let start = out.len() - dist;
                for k in 0..len {
                    let b = out[start + k];
                    out.push(b);
                }
            }
        }
    }
    ensure!(pos == data.len(), "lossless: {} trailing bytes", data.len() - pos);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn round_trip_structured() {
        // runs of 1s/0s like the Fig.-3 bitmaps
        let mut data = Vec::new();
        for i in 0..200 {
            data.extend(std::iter::repeat(0xFFu8).take(i % 7));
            data.extend(std::iter::repeat(0x00u8).take(13 - i % 7));
        }
        let c = lossless_compress(&data).unwrap();
        assert!(c.len() < data.len() / 4, "{} vs {}", c.len(), data.len());
        let d = lossless_decompress(&c, data.len()).unwrap();
        assert_eq!(d, data);
    }

    #[test]
    fn round_trip_random() {
        let mut rng = Rng::new(4);
        let data: Vec<u8> = (0..10_000).map(|_| rng.next_u64() as u8).collect();
        let c = lossless_compress(&data).unwrap();
        let d = lossless_decompress(&c, data.len()).unwrap();
        assert_eq!(d, data);
        // flag-bit scheme bounds expansion on incompressible data
        assert!(c.len() <= data.len() + data.len() / 8 + 16);
    }

    #[test]
    fn empty_round_trip() {
        let c = lossless_compress(&[]).unwrap();
        let d = lossless_decompress(&c, 16).unwrap();
        assert!(d.is_empty());
    }

    #[test]
    fn corrupt_stream_errors() {
        assert!(lossless_decompress(&[1, 2, 3, 4], 100).is_err());
        assert!(lossless_decompress(&[], 100).is_err());
    }

    #[test]
    fn truncations_error_never_panic() {
        let data: Vec<u8> = (0..500u32).map(|i| (i % 91) as u8).collect();
        let c = lossless_compress(&data).unwrap();
        for cut in 0..c.len() {
            assert!(lossless_decompress(&c[..cut], data.len()).is_err(), "cut {cut}");
        }
    }

    #[test]
    fn cap_is_enforced() {
        let data = vec![7u8; 1000];
        let c = lossless_compress(&data).unwrap();
        assert!(lossless_decompress(&c, 999).is_err());
        assert!(lossless_decompress(&c, 1000).is_ok());
    }

    #[test]
    fn adversarial_match_length_errors_not_panics() {
        // one literal then a match whose varint length is u64::MAX: the
        // decoder must reject it before any widening arithmetic
        let mut s = vec![super::MAGIC_LZ, 10]; // raw_len = 10
        s.push(0b0000_0001); // token 0 literal, token 1 match
        s.push(b'A');
        s.extend_from_slice(&1u16.to_le_bytes()); // dist 1
        s.extend_from_slice(&[0xFF; 9]); // varint u64::MAX ...
        s.push(0x01);
        assert!(lossless_decompress(&s, 100).is_err());
    }

    #[test]
    fn long_overlapping_runs() {
        // dist-1 match of length far beyond 255 exercises the varint path
        let data = vec![0xABu8; 100_000];
        let c = lossless_compress(&data).unwrap();
        assert!(c.len() < 64, "run should collapse, got {}", c.len());
        assert_eq!(lossless_decompress(&c, data.len()).unwrap(), data);
    }

    fn big_structured(len: usize) -> Vec<u8> {
        let mut rng = Rng::new(21);
        let mut data = Vec::with_capacity(len);
        while data.len() < len {
            let run = 1 + (rng.next_u64() % 32) as usize;
            let byte = (rng.next_u64() % 7) as u8 * 31;
            data.extend(std::iter::repeat(byte).take(run.min(len - data.len())));
        }
        data
    }

    #[test]
    fn chunked_container_round_trips() {
        // > PAR_CHUNK triggers the block-parallel 0xB4 container
        let data = big_structured(PAR_CHUNK * 2 + 12_345);
        let c = lossless_compress(&data).unwrap();
        assert_eq!(c[0], super::MAGIC_LZ_CHUNKED);
        assert!(c.len() < data.len());
        assert_eq!(lossless_decompress(&c, data.len()).unwrap(), data);
        // cap enforced on the container too
        assert!(lossless_decompress(&c, data.len() - 1).is_err());
    }

    #[test]
    fn chunked_bytes_identical_at_any_thread_count() {
        let data = big_structured(PAR_CHUNK + 999);
        let parallel = lossless_compress(&data).unwrap();
        let serial =
            crate::util::parallel::with_thread_limit(1, || lossless_compress(&data).unwrap());
        assert_eq!(parallel, serial);
    }

    #[test]
    fn chunked_truncation_errors_never_panic() {
        let data = big_structured(PAR_CHUNK + 10);
        let c = lossless_compress(&data).unwrap();
        for cut in [0, 1, 2, c.len() / 2, c.len() - 1] {
            assert!(lossless_decompress(&c[..cut], data.len()).is_err(), "cut {cut}");
        }
    }

    #[test]
    fn matches_beyond_window_still_round_trip() {
        // two identical 1 KiB blocks separated by > 64 KiB of noise still
        // round-trip (the second block simply doesn't reference the first)
        let mut rng = Rng::new(9);
        let block: Vec<u8> = (0..1024).map(|_| rng.next_u64() as u8).collect();
        let mut data = block.clone();
        data.extend((0..70_000).map(|_| rng.next_u64() as u8));
        data.extend_from_slice(&block);
        let c = lossless_compress(&data).unwrap();
        assert_eq!(lossless_decompress(&c, data.len()).unwrap(), data);
    }
}
