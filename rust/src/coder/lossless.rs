//! Lossless byte compression backend for the concatenated index bitmaps
//! (§II-E, Fig. 3) and the baseline compressors' entropy streams.
//!
//! The paper uses ZSTD; the build container has no zstd crate, so this is
//! an in-tree LZSS (LZ77 + flag-bit literals) with a 64 KiB window,
//! hash-chain matching, and unbounded match lengths (varint-coded), which
//! captures the long-run / repeated-period structure those streams have.
//! The format is self-framing (magic + raw length) and every decode path
//! returns `Err` on corrupt input — never panics.
//!
//! Layout:
//! ```text
//!   0xB3 | varint raw_len | groups of: flags u8 (LSB first, 1 = literal)
//!        then 8 tokens: literal = raw byte,
//!                       match   = u16 LE distance | varint (len - 4)
//! ```
//!
//! Streams larger than [`PAR_CHUNK`] use the chunked container instead
//! (magic 0xB4): fixed-size input chunks compressed independently on the
//! shared [`crate::engine::Executor`] and framed back to back. Chunk
//! boundaries depend only on the input length, so the bytes are
//! identical at every thread count; the decoder dispatches on the magic,
//! so 0xB3 streams from v1 archives keep decoding unchanged.
//! ```text
//!   0xB4 | varint raw_len | varint n_chunks |
//!   n x ( varint chunk_compressed_len | 0xB3 stream )
//! ```
//!
//! ## The symbol container (quantized-stream entropy framing)
//!
//! The baselines' quantized i32 code streams go through
//! [`compress_symbols`] / [`decompress_symbols`], which extend the same
//! one-byte magic dispatch with two symbol-level modes:
//!
//! * **Plain** (magic 0xB3/0xB4): `lossless(huffman(values))` — byte
//!   identical to the pre-overhaul framing, and the only mode older
//!   archives contain, so every existing stream keeps decoding.
//! * **Zero-run** (magic [`MAGIC_ZRUN`] = 0xB5): residual tiles are
//!   heavily zero-peaked, and plain Huffman pays ≥ 1 bit per zero. The
//!   stream is RLE0-transformed first — a run of L zeros becomes the
//!   single symbol `-L`, a nonzero literal v becomes `zigzag(v) ≥ 0` —
//!   and one Huffman table covers both, so a run costs one code instead
//!   of L. Layout: `0xB5 | u64 n_values | huffman(transformed)`.
//!   Literals are capped at ±2^29 so the zigzag stays in i32; streams
//!   carrying wider symbols (e.g. the sz3 `UNPRED` sentinel) simply stay
//!   plain.
//! * **Constant** (magic [`MAGIC_CONST`] = 0xB6): an all-same stream
//!   (the all-zero residual tile, overwhelmingly) collapses to
//!   `0xB6 | varint n_values | i32 value` — no table at all.
//! * **rANS** (magic [`crate::coder::rans::MAGIC_RANS`] = 0xB7): dense
//!   near-uniform streams (keyframe quantization codes, multi-species
//!   residuals) where Huffman's integer code lengths waste up to half a
//!   bit per symbol. A static-frequency interleaved 4-lane rANS coder
//!   (see [`crate::coder::rans`]) codes fractional bits and decodes as
//!   four independent branch-light dependency chains. Streams with more
//!   than 4096 distinct symbols stay plain.
//!
//! Mid-sparse zero-run streams additionally pick between the exact
//! run-length alphabet and a geometric-bucketed one (each run split
//! into power-of-two pieces, capping the run alphabet at ~31 symbols)
//! by exact Huffman sizing — the decoder is oblivious, because both
//! spell runs as negative symbols that sum to the same zero count.
//!
//! Mode selection is automatic: a contiguous ≤ 4 Ki-symbol window is
//! sized each way ([`crate::coder::huffman_encoded_size`] /
//! `rans_scaled_estimate`, with the coded payload scaled to the stream
//! length and the table kept fixed); zero-run is taken only when it
//! beats plain by ≥ 10% (hysteresis for LZSS's own gains on sparse
//! bitstreams), then rANS when it is within 1% of plain (it decodes
//! several times faster at equal size, and typically shaves the
//! fractional-bit slack too). [`with_symbol_mode`] forces a mode
//! thread-locally for A/B tests and benches; the
//! [`crate::engine::Executor`] propagates the forcing to its pool
//! workers per batch, so forcing applies at every thread count.

use std::cell::Cell;

use super::freq::symbol_freqs;
use super::huffman::{
    huffman_decode_capped, huffman_encode, huffman_encoded_size, huffman_stream_layout,
    HuffScratch,
};
use super::rans::{
    rans_decode_into, rans_encode, rans_scaled_estimate, rans_stream_layout, RansScratch,
    MAGIC_RANS,
};
use crate::engine::Executor;
use crate::Result;
use anyhow::{bail, ensure, Context};

const MAGIC_LZ: u8 = 0xB3;
const MAGIC_LZ_CHUNKED: u8 = 0xB4;
/// Symbol-container magic: zero-run (RLE0 + zigzag) coded stream.
pub const MAGIC_ZRUN: u8 = 0xB5;
/// Symbol-container magic: constant (all-same) stream.
pub const MAGIC_CONST: u8 = 0xB6;
const MIN_MATCH: usize = 4;
const MAX_DIST: usize = 65_535;
const HASH_BITS: u32 = 15;
const MAX_CHAIN: usize = 64;

/// Largest literal magnitude the zero-run transform can carry (zigzag
/// must stay inside i32).
const ZRUN_MAX_ABS: i32 = 1 << 29;

/// Input-chunk size of the parallel container. Each chunk restarts the
/// LZ window, trading a sliver of ratio for block parallelism.
pub const PAR_CHUNK: usize = 256 * 1024;

fn push_varint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let b = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(b);
            return;
        }
        out.push(b | 0x80);
    }
}

fn read_varint(data: &[u8], pos: &mut usize) -> Result<u64> {
    let mut v = 0u64;
    let mut shift = 0u32;
    loop {
        let b = *data.get(*pos).context("lossless: varint truncated")?;
        *pos += 1;
        v |= ((b & 0x7f) as u64) << shift;
        if b & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
        ensure!(shift < 64, "lossless: varint overflow");
    }
}

#[inline]
fn hash4(data: &[u8], i: usize) -> usize {
    let v = u32::from_le_bytes([data[i], data[i + 1], data[i + 2], data[i + 3]]);
    (v.wrapping_mul(0x9E37_79B1) >> (32 - HASH_BITS)) as usize
}

/// Token writer: buffers up to 8 tokens so the flags byte precedes them.
struct TokenWriter<'a> {
    out: &'a mut Vec<u8>,
    flags: u8,
    n: u32,
    buf: Vec<u8>,
}

impl<'a> TokenWriter<'a> {
    fn new(out: &'a mut Vec<u8>) -> Self {
        Self { out, flags: 0, n: 0, buf: Vec::with_capacity(64) }
    }

    fn literal(&mut self, b: u8) {
        self.flags |= 1 << self.n;
        self.buf.push(b);
        self.bump();
    }

    fn matched(&mut self, dist: u16, len: usize) {
        self.buf.extend_from_slice(&dist.to_le_bytes());
        push_varint(&mut self.buf, (len - MIN_MATCH) as u64);
        self.bump();
    }

    fn bump(&mut self) {
        self.n += 1;
        if self.n == 8 {
            self.flush();
        }
    }

    fn flush(&mut self) {
        if self.n > 0 {
            self.out.push(self.flags);
            self.out.extend_from_slice(&self.buf);
            self.flags = 0;
            self.n = 0;
            self.buf.clear();
        }
    }
}

/// Compress bytes (LZSS). Worst case ~12.5% expansion on random data.
/// Inputs above [`PAR_CHUNK`] use the chunked block-parallel container.
pub fn lossless_compress(data: &[u8]) -> Result<Vec<u8>> {
    if data.len() > PAR_CHUNK {
        return lossless_compress_chunked(data);
    }
    lossless_compress_single(data)
}

fn lossless_compress_chunked(data: &[u8]) -> Result<Vec<u8>> {
    let chunks: Vec<&[u8]> = data.chunks(PAR_CHUNK).collect();
    let parts =
        Executor::global().try_par_map(chunks.len(), |i| lossless_compress_single(chunks[i]))?;
    let mut out = vec![MAGIC_LZ_CHUNKED];
    push_varint(&mut out, data.len() as u64);
    push_varint(&mut out, parts.len() as u64);
    for p in &parts {
        push_varint(&mut out, p.len() as u64);
        out.extend_from_slice(p);
    }
    Ok(out)
}

fn lossless_compress_single(data: &[u8]) -> Result<Vec<u8>> {
    let mut out = vec![MAGIC_LZ];
    push_varint(&mut out, data.len() as u64);
    if data.is_empty() {
        return Ok(out);
    }

    // hash chains: head[h] = most recent position with that 4-byte hash,
    // prev is a window-sized ring (slot i & WMASK holds the previous
    // position in i's chain) — fixed 512 KiB of bookkeeping regardless of
    // input size, valid because matches beyond MAX_DIST are discarded
    // before any slot can be overwritten by a newer position
    const WINDOW: usize = MAX_DIST + 1; // power of two (1 << 16)
    const WMASK: usize = WINDOW - 1;
    let mut head = vec![usize::MAX; 1 << HASH_BITS];
    let mut prev = vec![usize::MAX; WINDOW];
    let mut w = TokenWriter::new(&mut out);

    let mut i = 0usize;
    while i < data.len() {
        let mut best_len = 0usize;
        let mut best_dist = 0usize;
        if i + MIN_MATCH <= data.len() {
            let mut cand = head[hash4(data, i)];
            let mut chain = 0usize;
            while cand != usize::MAX && chain < MAX_CHAIN {
                let dist = i - cand;
                if dist > MAX_DIST {
                    break; // chains go from recent to old: all further are too far
                }
                let max_len = data.len() - i;
                let mut l = 0usize;
                // overlap (dist < len) is fine: cand + l only reads bytes
                // the decoder will already have produced
                while l < max_len && data[cand + l] == data[i + l] {
                    l += 1;
                }
                if l > best_len {
                    best_len = l;
                    best_dist = dist;
                    if l == max_len {
                        break;
                    }
                }
                let next = prev[cand & WMASK];
                if next == usize::MAX || next >= cand {
                    break; // end of chain, or the ring slot was recycled
                }
                cand = next;
                chain += 1;
            }
        }

        if best_len >= MIN_MATCH {
            w.matched(best_dist as u16, best_len);
            let end = i + best_len;
            while i < end {
                if i + MIN_MATCH <= data.len() {
                    let h = hash4(data, i);
                    prev[i & WMASK] = head[h];
                    head[h] = i;
                }
                i += 1;
            }
        } else {
            w.literal(data[i]);
            if i + MIN_MATCH <= data.len() {
                let h = hash4(data, i);
                prev[i & WMASK] = head[h];
                head[h] = i;
            }
            i += 1;
        }
    }
    w.flush();
    Ok(out)
}

/// Decompress a [`lossless_compress`] stream; `max_size` caps the output
/// as a safety bound against corrupt archives. Dispatches on the magic:
/// plain 0xB3 streams (v1 archives) and chunked 0xB4 containers both
/// decode.
pub fn lossless_decompress(data: &[u8], max_size: usize) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    lossless_decompress_into(data, max_size, &mut out)?;
    Ok(out)
}

/// [`lossless_decompress`] into a reusable buffer (cleared first) — the
/// per-tile hot path skips one allocation per stream.
pub fn lossless_decompress_into(data: &[u8], max_size: usize, out: &mut Vec<u8>) -> Result<()> {
    ensure!(!data.is_empty(), "lossless: empty input");
    match data[0] {
        MAGIC_LZ => lossless_decompress_single_into(data, max_size, out),
        MAGIC_LZ_CHUNKED => lossless_decompress_chunked_into(data, max_size, out),
        m => bail!("lossless: bad magic {m:#04x}"),
    }
}

fn lossless_decompress_chunked_into(
    data: &[u8],
    max_size: usize,
    out: &mut Vec<u8>,
) -> Result<()> {
    let mut pos = 1usize;
    let raw_len = read_varint(data, &mut pos)? as usize;
    ensure!(
        raw_len <= max_size,
        "lossless: declared size {raw_len} exceeds cap {max_size}"
    );
    let n_chunks = read_varint(data, &mut pos)? as usize;
    // every chunk needs at least its length varint + magic + raw varint
    ensure!(
        n_chunks <= data.len().saturating_sub(pos).max(1),
        "lossless: {n_chunks} chunks impossible in {} bytes",
        data.len()
    );
    ensure!(
        n_chunks == raw_len.div_ceil(PAR_CHUNK).max(1),
        "lossless: chunk count {n_chunks} inconsistent with size {raw_len}"
    );
    let mut spans = Vec::with_capacity(n_chunks);
    for _ in 0..n_chunks {
        let clen = read_varint(data, &mut pos)? as usize;
        let end = pos
            .checked_add(clen)
            .ok_or_else(|| anyhow::anyhow!("lossless: chunk length overflow"))?;
        ensure!(end <= data.len(), "lossless: chunk truncated");
        spans.push(&data[pos..end]);
        pos = end;
    }
    ensure!(pos == data.len(), "lossless: {} trailing bytes", data.len() - pos);
    let parts = Executor::global().try_par_map(spans.len(), |i| {
        let mut part = Vec::new();
        lossless_decompress_single_into(spans[i], PAR_CHUNK, &mut part)?;
        Ok(part)
    })?;
    out.clear();
    out.reserve(raw_len);
    for p in &parts {
        out.extend_from_slice(p);
    }
    ensure!(
        out.len() == raw_len,
        "lossless: chunked payload {} != declared {raw_len}",
        out.len()
    );
    Ok(())
}

fn lossless_decompress_single_into(
    data: &[u8],
    max_size: usize,
    out: &mut Vec<u8>,
) -> Result<()> {
    ensure!(!data.is_empty(), "lossless: empty input");
    if data[0] != MAGIC_LZ {
        bail!("lossless: bad magic {:#04x}", data[0]);
    }
    let mut pos = 1usize;
    let raw_len = read_varint(data, &mut pos)? as usize;
    ensure!(
        raw_len <= max_size,
        "lossless: declared size {raw_len} exceeds cap {max_size}"
    );
    out.clear();
    out.reserve(raw_len);
    while out.len() < raw_len {
        let flags = *data.get(pos).context("lossless: flags truncated")?;
        pos += 1;
        for bit in 0..8u8 {
            if out.len() == raw_len {
                break;
            }
            if flags & (1 << bit) != 0 {
                out.push(*data.get(pos).context("lossless: literal truncated")?);
                pos += 1;
            } else {
                let lo = *data.get(pos).context("lossless: match truncated")?;
                let hi = *data.get(pos + 1).context("lossless: match truncated")?;
                pos += 2;
                let dist = u16::from_le_bytes([lo, hi]) as usize;
                ensure!(dist >= 1 && dist <= out.len(), "lossless: bad distance {dist}");
                let extra = read_varint(data, &mut pos)?;
                // bound-check BEFORE widening arithmetic: an adversarial
                // varint must not overflow `+ MIN_MATCH` below
                ensure!(extra <= raw_len as u64, "lossless: match length {extra} absurd");
                let len = extra as usize + MIN_MATCH;
                ensure!(out.len() + len <= raw_len, "lossless: match overruns output");
                let start = out.len() - dist;
                for k in 0..len {
                    let b = out[start + k];
                    out.push(b);
                }
            }
        }
    }
    ensure!(pos == data.len(), "lossless: {} trailing bytes", data.len() - pos);
    Ok(())
}

// ---------------------------------------------------------------------------
// Symbol container: plain (LZSS'd Huffman) / zero-run / constant modes
// ---------------------------------------------------------------------------

/// Entropy-coding mode of one quantized symbol stream (see the module
/// docs for the byte layouts and when each wins).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SymbolMode {
    /// `lossless(huffman(values))` — the pre-overhaul framing.
    Plain,
    /// RLE0 + zigzag transform under one Huffman table (magic 0xB5).
    ZeroRun,
    /// All-same stream: varint count + the value (magic 0xB6).
    Const,
    /// Interleaved 4-lane static-frequency rANS (magic 0xB7).
    Rans,
}

thread_local! {
    static SYMBOL_MODE: Cell<Option<SymbolMode>> = const { Cell::new(None) };
}

/// Force the symbol-container mode for the duration of `f` on this
/// thread (A/B tests and benches; the previous setting is restored even
/// if `f` panics). Thread-local, but the [`crate::engine::Executor`]
/// captures the forcing context at batch submission and installs it on
/// its pool workers for the batch's duration — so a force wrapped
/// around a parallel compress applies to every tile and the output is
/// byte-identical at 1 and N threads. A forced `ZeroRun` still falls
/// back to plain for streams the transform cannot carry (literals
/// beyond ±2^29), and a forced `Rans` falls back to plain for streams
/// with more than 4096 distinct symbols.
pub fn with_symbol_mode<R>(mode: SymbolMode, f: impl FnOnce() -> R) -> R {
    struct Restore(Option<SymbolMode>);
    impl Drop for Restore {
        fn drop(&mut self) {
            let prev = self.0;
            SYMBOL_MODE.with(|m| m.set(prev));
        }
    }
    let _restore = Restore(SYMBOL_MODE.with(|m| m.replace(Some(mode))));
    f()
}

/// The thread's forced symbol mode, if any (executor force-context capture).
pub(crate) fn forced_symbol_mode() -> Option<SymbolMode> {
    SYMBOL_MODE.with(|m| m.get())
}

/// Overwrite the thread's forced symbol mode (executor force-context install).
pub(crate) fn set_forced_symbol_mode(mode: Option<SymbolMode>) {
    SYMBOL_MODE.with(|m| m.set(mode));
}

#[inline]
fn zigzag(v: i32) -> u32 {
    ((v << 1) ^ (v >> 31)) as u32
}

#[inline]
fn unzigzag(z: u32) -> i32 {
    ((z >> 1) as i32) ^ -((z & 1) as i32)
}

/// RLE0 transform: zero runs become negative run-length symbols, nonzero
/// literals their (non-negative) zigzag code — one shared alphabet, so a
/// run of L zeros costs one Huffman code instead of L. `None` when a
/// literal is outside ±[`ZRUN_MAX_ABS`] (the stream must stay plain).
/// Caller guarantees `values.len() <= i32::MAX`.
fn zero_run_transform(values: &[i32]) -> Option<Vec<i32>> {
    let mut out = Vec::with_capacity(values.len() / 4 + 8);
    let mut run = 0i64;
    for &v in values {
        if v == 0 {
            run += 1;
            continue;
        }
        if !(-ZRUN_MAX_ABS..=ZRUN_MAX_ABS).contains(&v) {
            return None;
        }
        if run > 0 {
            out.push(-(run as i32));
            run = 0;
        }
        out.push(zigzag(v) as i32);
    }
    if run > 0 {
        out.push(-(run as i32));
    }
    Some(out)
}

/// Geometric bucketing: split every run-length symbol into power-of-two
/// pieces (`-13` becomes `-8, -4, -1`), capping the run alphabet at ~31
/// symbols. Mid-sparse tiles with many distinct run lengths pay one
/// Huffman table entry per length under the exact transform; bucketing
/// trades ≤ `popcount` codes per run for a far smaller table. The
/// decoder needs no dispatch — runs are still negative symbols whose
/// zero counts sum.
fn bucket_runs(exact: &[i32]) -> Vec<i32> {
    let mut out = Vec::with_capacity(exact.len() + exact.len() / 2);
    for &s in exact {
        if s < 0 {
            let mut run = (-(s as i64)) as u64;
            while run > 0 {
                let k = 63 - run.leading_zeros();
                out.push(-(1i64 << k) as i32); // run <= i32::MAX, so 1<<k fits
                run -= 1u64 << k;
            }
        } else {
            out.push(s);
        }
    }
    out
}

/// Fewest distinct run-length symbols before the bucketed alternative is
/// even sized (small alphabets cannot win — the table is already tiny).
const BUCKET_MIN_DISTINCT_RUNS: usize = 16;

/// The RLE0 transform that actually ships: exact run lengths, or the
/// geometric-bucketed variant when the stream has enough distinct run
/// lengths for the table savings to matter *and* exact Huffman sizing
/// says it is strictly smaller. Deterministic, so archives stay
/// byte-identical at any thread count.
fn zero_run_best_transform(values: &[i32]) -> Option<Vec<i32>> {
    let exact = zero_run_transform(values)?;
    let mut runs: Vec<i32> = exact.iter().copied().filter(|&s| s < 0).collect();
    runs.sort_unstable();
    runs.dedup();
    if runs.len() < BUCKET_MIN_DISTINCT_RUNS {
        return Some(exact);
    }
    let bucketed = bucket_runs(&exact);
    if huffman_encoded_size(&bucketed) < huffman_encoded_size(&exact) {
        Some(bucketed)
    } else {
        Some(exact)
    }
}

/// Expand an RLE0 stream back to exactly `n_total` symbols.
fn zero_run_invert(stream: &[i32], n_total: usize, out: &mut Vec<i32>) -> Result<()> {
    out.reserve(n_total);
    for &s in stream {
        if s < 0 {
            let run = (-(s as i64)) as usize;
            ensure!(
                out.len() + run <= n_total,
                "symbols: zero-run overruns declared count"
            );
            out.resize(out.len() + run, 0);
        } else {
            ensure!(out.len() < n_total, "symbols: literal overruns declared count");
            out.push(unzigzag(s as u32));
        }
    }
    ensure!(
        out.len() == n_total,
        "symbols: zero-run stream expands to {} of {n_total} values",
        out.len()
    );
    Ok(())
}

/// Pick the container mode: thread-local override first, then constant
/// folding, then a size trial on a contiguous sample window with a 10%
/// hysteresis in plain's favor (plain additionally enjoys LZSS).
fn select_mode(values: &[i32]) -> SymbolMode {
    let forced = SYMBOL_MODE.with(|m| m.get());
    if forced == Some(SymbolMode::Plain) {
        return SymbolMode::Plain;
    }
    if values.is_empty() || values.len() > i32::MAX as usize {
        return SymbolMode::Plain;
    }
    let mut min = i32::MAX;
    let mut max = i32::MIN;
    for &v in values {
        min = min.min(v);
        max = max.max(v);
    }
    let eligible = min >= -ZRUN_MAX_ABS && max <= ZRUN_MAX_ABS;
    match forced {
        Some(SymbolMode::ZeroRun) => {
            return if eligible { SymbolMode::ZeroRun } else { SymbolMode::Plain };
        }
        Some(SymbolMode::Const) => {
            return if min == max { SymbolMode::Const } else { SymbolMode::Plain };
        }
        // eligibility (<= 4096 distinct symbols) needs a full frequency
        // pass; the encoder does it anyway, so [`compress_symbols`]
        // degrades a forced rANS to plain on the encoder's verdict
        Some(SymbolMode::Rans) => return SymbolMode::Rans,
        _ => {}
    }
    if min == max {
        return SymbolMode::Const;
    }
    // trial sampling: a contiguous middle window preserves the zero-run
    // structure (a strided sample would shorten every run by the
    // stride); tables and framing are fixed costs while the coded
    // payload scales with the stream length, so the estimate models the
    // table amortization large streams actually get
    const SAMPLE: usize = 4096;
    let (sample, scale): (&[i32], f64) = if values.len() <= SAMPLE {
        (values, 1.0)
    } else {
        let start = (values.len() - SAMPLE) / 2;
        (&values[start..start + SAMPLE], values.len() as f64 / SAMPLE as f64)
    };
    let plain_est = scaled_estimate(sample, scale);
    if eligible {
        let zrun_est = match zero_run_best_transform(sample) {
            Some(t) => 9.0 + scaled_estimate(&t, scale),
            None => f64::INFINITY,
        };
        if zrun_est < plain_est * 0.9 {
            return SymbolMode::ZeroRun;
        }
    }
    // dense-stream trial: rANS wins ties — it decodes several times
    // faster, so it is taken whenever its size lands within 1% of
    // plain's (the 1% slack keeps the compression-ratio guarantee while
    // letting small fractional-bit losses through)
    match rans_scaled_estimate(sample, scale) {
        Some(r) if r <= plain_est * 1.01 => SymbolMode::Rans,
        _ => SymbolMode::Plain,
    }
}

/// Full-stream Huffman size estimated from a sample: table + framing
/// are fixed costs, the coded payload scales with the length ratio.
fn scaled_estimate(sample: &[i32], scale: f64) -> f64 {
    let distinct = symbol_freqs(sample).len();
    let total = huffman_encoded_size(sample);
    let fixed = 12 + distinct * 5;
    fixed as f64 + total.saturating_sub(fixed) as f64 * scale
}

/// Entropy-code a quantized symbol stream, selecting the container mode
/// automatically (see [`SymbolMode`] and the module docs). Decoders
/// dispatch on the leading magic byte, so plain streams written by older
/// versions keep decoding unchanged — the new magics appear only in
/// newly written payloads.
pub fn compress_symbols(values: &[i32]) -> Result<Vec<u8>> {
    let _span = crate::obs::stages::ENTROPY_ENCODE.span();
    let out = match select_mode(values) {
        // the sampled trial (or a thread-local force) can pick rANS on a
        // stream whose full alphabet turns out wider than 4096 symbols;
        // the encoder's own eligibility check is the authority, and the
        // fallback is deterministic
        SymbolMode::Rans => rans_encode(values)
            .or_else(|_| compress_symbols_mode(values, SymbolMode::Plain)),
        mode => compress_symbols_mode(values, mode),
    }?;
    if let Some(&magic) = out.first() {
        crate::obs::entropy_stream(container_mode_name(magic), "encode");
    }
    Ok(out)
}

/// Metric label for a container magic byte (unknown magics report as
/// "plain"; the decoder rejects them immediately anyway).
fn container_mode_name(magic: u8) -> &'static str {
    match magic {
        MAGIC_RANS => "rans",
        MAGIC_ZRUN => "zero_run",
        MAGIC_CONST => "const",
        _ => "plain",
    }
}

/// [`compress_symbols`] with an explicit mode (tests / benches). Errors
/// when the stream cannot be represented in the requested mode
/// (`ZeroRun` with literals beyond ±2^29, `Const` on a non-constant
/// stream, `Rans` with more than 4096 distinct symbols).
pub fn compress_symbols_mode(values: &[i32], mode: SymbolMode) -> Result<Vec<u8>> {
    match mode {
        SymbolMode::Plain => lossless_compress(&huffman_encode(values)),
        SymbolMode::Rans => rans_encode(values),
        SymbolMode::ZeroRun => {
            ensure!(
                values.len() <= i32::MAX as usize,
                "zero-run mode caps at {} symbols",
                i32::MAX
            );
            let transformed = zero_run_best_transform(values).ok_or_else(|| {
                anyhow::anyhow!("zero-run mode cannot carry literals beyond ±2^29")
            })?;
            let mut out = Vec::with_capacity(16 + transformed.len());
            out.push(MAGIC_ZRUN);
            out.extend_from_slice(&(values.len() as u64).to_le_bytes());
            out.extend(huffman_encode(&transformed));
            Ok(out)
        }
        SymbolMode::Const => {
            ensure!(!values.is_empty(), "constant mode needs at least one symbol");
            let v = values[0];
            ensure!(
                values.iter().all(|&x| x == v),
                "constant mode on a non-constant stream"
            );
            let mut out = vec![MAGIC_CONST];
            push_varint(&mut out, values.len() as u64);
            out.extend_from_slice(&v.to_le_bytes());
            Ok(out)
        }
    }
}

/// Reusable decode state for [`decompress_symbols_into`]: Huffman
/// table/LUT, the RLE0 staging buffer, the LZSS output buffer, and the
/// rANS decode tables — one per pool thread via
/// [`crate::engine::Scratch`], so per-tile decodes stop allocating.
#[derive(Default)]
pub struct SymbolScratch {
    huff: HuffScratch,
    tmp: Vec<i32>,
    bytes: Vec<u8>,
    rans: RansScratch,
}

/// Decode a [`compress_symbols`] stream. `max_values` caps every
/// declared count before it sizes an allocation.
pub fn decompress_symbols(data: &[u8], max_values: usize) -> Result<Vec<i32>> {
    let mut out = Vec::new();
    decompress_symbols_into(data, max_values, &mut out, &mut SymbolScratch::default())?;
    Ok(out)
}

/// [`decompress_symbols`] into reusable buffers (cleared first) — the
/// per-tile hot path.
pub fn decompress_symbols_into(
    data: &[u8],
    max_values: usize,
    out: &mut Vec<i32>,
    scratch: &mut SymbolScratch,
) -> Result<()> {
    out.clear();
    ensure!(!data.is_empty(), "symbols: empty input");
    let _span = crate::obs::stages::ENTROPY_DECODE.span();
    crate::obs::entropy_stream(container_mode_name(data[0]), "decode");
    let SymbolScratch { huff, tmp, bytes, rans } = scratch;
    match data[0] {
        MAGIC_RANS => rans_decode_into(data, max_values, out, rans),
        MAGIC_LZ | MAGIC_LZ_CHUNKED => {
            // plain mode: the huffman stream is at most 5 B/table entry +
            // ~8 B/value; the cap stops a corrupt header from ballooning
            let cap = max_values.saturating_mul(13).saturating_add(1 << 20);
            lossless_decompress_into(data, cap, bytes)?;
            huffman_decode_capped(bytes, max_values, out, huff)?;
            Ok(())
        }
        MAGIC_ZRUN => {
            ensure!(data.len() >= 9, "symbols: zero-run header truncated");
            let n = u64::from_le_bytes(data[1..9].try_into().unwrap());
            let n = usize::try_from(n)
                .map_err(|_| anyhow::anyhow!("symbols: count overflow"))?;
            ensure!(
                n <= max_values,
                "symbols: declared count {n} exceeds cap {max_values}"
            );
            // every transformed symbol expands to >= 1 value
            let used = huffman_decode_capped(&data[9..], n, tmp, huff)?;
            ensure!(9 + used == data.len(), "symbols: trailing bytes");
            zero_run_invert(tmp, n, out)
        }
        MAGIC_CONST => {
            let mut pos = 1usize;
            let n = read_varint(data, &mut pos)? as usize;
            ensure!(
                n <= max_values,
                "symbols: declared count {n} exceeds cap {max_values}"
            );
            ensure!(pos + 4 == data.len(), "symbols: constant container malformed");
            let v = i32::from_le_bytes(data[pos..pos + 4].try_into().unwrap());
            out.resize(n, v);
            Ok(())
        }
        m => bail!("symbols: bad magic {m:#04x}"),
    }
}

/// Byte breakdown of one symbol stream for `cli info`: the mode, the
/// declared value count, and the entropy table/payload split. Plain
/// streams are measured in the entropy domain (after LZSS) — their
/// compressed split is not byte-attributable; zero-run and rANS streams
/// as stored.
pub struct SymbolStreamStats {
    pub mode: &'static str,
    pub n_values: usize,
    pub table_bytes: usize,
    pub symbol_bytes: usize,
    /// Interleaved rANS lanes (0 for every non-rANS mode).
    pub lanes: usize,
}

/// Inspect a [`compress_symbols`] stream without decoding its values.
pub fn symbol_stream_stats(data: &[u8], max_values: usize) -> Result<SymbolStreamStats> {
    ensure!(!data.is_empty(), "symbols: empty input");
    match data[0] {
        MAGIC_LZ | MAGIC_LZ_CHUNKED => {
            let cap = max_values.saturating_mul(13).saturating_add(1 << 20);
            let huff = lossless_decompress(data, cap)?;
            let (table_bytes, symbol_bytes, n_values) = huffman_stream_layout(&huff)?;
            Ok(SymbolStreamStats { mode: "plain", n_values, table_bytes, symbol_bytes, lanes: 0 })
        }
        MAGIC_ZRUN => {
            ensure!(data.len() >= 9, "symbols: zero-run header truncated");
            let n_values = u64::from_le_bytes(data[1..9].try_into().unwrap()) as usize;
            let (table_bytes, symbol_bytes, _) = huffman_stream_layout(&data[9..])?;
            Ok(SymbolStreamStats {
                mode: "zero-run",
                n_values,
                table_bytes,
                symbol_bytes,
                lanes: 0,
            })
        }
        MAGIC_CONST => {
            let mut pos = 1usize;
            let n_values = read_varint(data, &mut pos)? as usize;
            Ok(SymbolStreamStats {
                mode: "const",
                n_values,
                table_bytes: 0,
                symbol_bytes: 4,
                lanes: 0,
            })
        }
        MAGIC_RANS => {
            let (table_bytes, symbol_bytes, n_values, lanes) = rans_stream_layout(data)?;
            Ok(SymbolStreamStats { mode: "rans", n_values, table_bytes, symbol_bytes, lanes })
        }
        m => bail!("symbols: bad magic {m:#04x}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn round_trip_structured() {
        // runs of 1s/0s like the Fig.-3 bitmaps
        let mut data = Vec::new();
        for i in 0..200 {
            data.extend(std::iter::repeat(0xFFu8).take(i % 7));
            data.extend(std::iter::repeat(0x00u8).take(13 - i % 7));
        }
        let c = lossless_compress(&data).unwrap();
        assert!(c.len() < data.len() / 4, "{} vs {}", c.len(), data.len());
        let d = lossless_decompress(&c, data.len()).unwrap();
        assert_eq!(d, data);
    }

    #[test]
    fn round_trip_random() {
        let mut rng = Rng::new(4);
        let data: Vec<u8> = (0..10_000).map(|_| rng.next_u64() as u8).collect();
        let c = lossless_compress(&data).unwrap();
        let d = lossless_decompress(&c, data.len()).unwrap();
        assert_eq!(d, data);
        // flag-bit scheme bounds expansion on incompressible data
        assert!(c.len() <= data.len() + data.len() / 8 + 16);
    }

    #[test]
    fn empty_round_trip() {
        let c = lossless_compress(&[]).unwrap();
        let d = lossless_decompress(&c, 16).unwrap();
        assert!(d.is_empty());
    }

    #[test]
    fn corrupt_stream_errors() {
        assert!(lossless_decompress(&[1, 2, 3, 4], 100).is_err());
        assert!(lossless_decompress(&[], 100).is_err());
    }

    #[test]
    fn truncations_error_never_panic() {
        let data: Vec<u8> = (0..500u32).map(|i| (i % 91) as u8).collect();
        let c = lossless_compress(&data).unwrap();
        for cut in 0..c.len() {
            assert!(lossless_decompress(&c[..cut], data.len()).is_err(), "cut {cut}");
        }
    }

    #[test]
    fn cap_is_enforced() {
        let data = vec![7u8; 1000];
        let c = lossless_compress(&data).unwrap();
        assert!(lossless_decompress(&c, 999).is_err());
        assert!(lossless_decompress(&c, 1000).is_ok());
    }

    #[test]
    fn adversarial_match_length_errors_not_panics() {
        // one literal then a match whose varint length is u64::MAX: the
        // decoder must reject it before any widening arithmetic
        let mut s = vec![super::MAGIC_LZ, 10]; // raw_len = 10
        s.push(0b0000_0001); // token 0 literal, token 1 match
        s.push(b'A');
        s.extend_from_slice(&1u16.to_le_bytes()); // dist 1
        s.extend_from_slice(&[0xFF; 9]); // varint u64::MAX ...
        s.push(0x01);
        assert!(lossless_decompress(&s, 100).is_err());
    }

    #[test]
    fn long_overlapping_runs() {
        // dist-1 match of length far beyond 255 exercises the varint path
        let data = vec![0xABu8; 100_000];
        let c = lossless_compress(&data).unwrap();
        assert!(c.len() < 64, "run should collapse, got {}", c.len());
        assert_eq!(lossless_decompress(&c, data.len()).unwrap(), data);
    }

    fn big_structured(len: usize) -> Vec<u8> {
        let mut rng = Rng::new(21);
        let mut data = Vec::with_capacity(len);
        while data.len() < len {
            let run = 1 + (rng.next_u64() % 32) as usize;
            let byte = (rng.next_u64() % 7) as u8 * 31;
            data.extend(std::iter::repeat(byte).take(run.min(len - data.len())));
        }
        data
    }

    #[test]
    fn chunked_container_round_trips() {
        // > PAR_CHUNK triggers the block-parallel 0xB4 container
        let data = big_structured(PAR_CHUNK * 2 + 12_345);
        let c = lossless_compress(&data).unwrap();
        assert_eq!(c[0], super::MAGIC_LZ_CHUNKED);
        assert!(c.len() < data.len());
        assert_eq!(lossless_decompress(&c, data.len()).unwrap(), data);
        // cap enforced on the container too
        assert!(lossless_decompress(&c, data.len() - 1).is_err());
    }

    #[test]
    fn chunked_bytes_identical_at_any_thread_count() {
        let data = big_structured(PAR_CHUNK + 999);
        let parallel = lossless_compress(&data).unwrap();
        let serial =
            crate::util::parallel::with_thread_limit(1, || lossless_compress(&data).unwrap());
        assert_eq!(parallel, serial);
    }

    #[test]
    fn chunked_truncation_errors_never_panic() {
        let data = big_structured(PAR_CHUNK + 10);
        let c = lossless_compress(&data).unwrap();
        for cut in [0, 1, 2, c.len() / 2, c.len() - 1] {
            assert!(lossless_decompress(&c[..cut], data.len()).is_err(), "cut {cut}");
        }
    }

    #[test]
    fn matches_beyond_window_still_round_trip() {
        // two identical 1 KiB blocks separated by > 64 KiB of noise still
        // round-trip (the second block simply doesn't reference the first)
        let mut rng = Rng::new(9);
        let block: Vec<u8> = (0..1024).map(|_| rng.next_u64() as u8).collect();
        let mut data = block.clone();
        data.extend((0..70_000).map(|_| rng.next_u64() as u8));
        data.extend_from_slice(&block);
        let c = lossless_compress(&data).unwrap();
        assert_eq!(lossless_decompress(&c, data.len()).unwrap(), data);
    }

    // --- symbol container ------------------------------------------------

    fn peaked_stream(n: usize, seed: u64) -> Vec<i32> {
        // ~92% zeros, small literal alphabet — residual-tile shaped
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| {
                if rng.below(12) == 0 {
                    (rng.below(5) as i32) - 2
                } else {
                    0
                }
            })
            .collect()
    }

    #[test]
    fn zero_run_mode_round_trips_and_shrinks_peaked_streams() {
        let vals = peaked_stream(32_768, 7);
        let plain = compress_symbols_mode(&vals, SymbolMode::Plain).unwrap();
        let zrun = compress_symbols_mode(&vals, SymbolMode::ZeroRun).unwrap();
        assert_eq!(zrun[0], MAGIC_ZRUN);
        assert_eq!(decompress_symbols(&plain, vals.len()).unwrap(), vals);
        assert_eq!(decompress_symbols(&zrun, vals.len()).unwrap(), vals);
        assert!(
            (zrun.len() as f64) < plain.len() as f64 * 0.8,
            "zero-run {} should be >=20% under plain {}",
            zrun.len(),
            plain.len()
        );
        // auto selection takes the win
        let auto = compress_symbols(&vals).unwrap();
        assert_eq!(auto[0], MAGIC_ZRUN);
    }

    #[test]
    fn uniform_streams_pick_rans_and_round_trip() {
        // dense near-uniform alphabet: Huffman's integer code lengths
        // leave fractional-bit slack, so the trial lands on rANS
        let mut rng = Rng::new(8);
        let vals: Vec<i32> = (0..8000).map(|_| rng.below(200) as i32 - 100).collect();
        let auto = compress_symbols(&vals).unwrap();
        assert_eq!(auto[0], MAGIC_RANS, "dense uniform data picks rans");
        assert_eq!(decompress_symbols(&auto, vals.len()).unwrap(), vals);
        // every forced mode still round-trips
        let plain = compress_symbols_mode(&vals, SymbolMode::Plain).unwrap();
        assert_eq!(decompress_symbols(&plain, vals.len()).unwrap(), vals);
        let zrun = compress_symbols_mode(&vals, SymbolMode::ZeroRun).unwrap();
        assert_eq!(decompress_symbols(&zrun, vals.len()).unwrap(), vals);
        // the auto pick keeps the size guarantee: within 1% of plain
        assert!(
            (auto.len() as f64) <= plain.len() as f64 * 1.01,
            "rans {} vs plain {}",
            auto.len(),
            plain.len()
        );
    }

    #[test]
    fn rans_mode_round_trips_and_forcing_degrades_when_ineligible() {
        let vals = peaked_stream(16_384, 21);
        let rans = compress_symbols_mode(&vals, SymbolMode::Rans).unwrap();
        assert_eq!(rans[0], MAGIC_RANS);
        assert_eq!(decompress_symbols(&rans, vals.len()).unwrap(), vals);
        // > 4096 distinct symbols: explicit mode errors, forced degrades
        let wide: Vec<i32> = (0..5000).collect();
        assert!(compress_symbols_mode(&wide, SymbolMode::Rans).is_err());
        let forced = with_symbol_mode(SymbolMode::Rans, || compress_symbols(&wide).unwrap());
        assert!(forced[0] == 0xB3 || forced[0] == 0xB4, "degrades to plain");
        assert_eq!(decompress_symbols(&forced, wide.len()).unwrap(), wide);
    }

    #[test]
    fn constant_streams_collapse_to_a_few_bytes() {
        let vals = vec![0i32; 10_000];
        let auto = compress_symbols(&vals).unwrap();
        assert_eq!(auto[0], MAGIC_CONST);
        assert!(auto.len() <= 8, "constant container is tiny, got {}", auto.len());
        assert_eq!(decompress_symbols(&auto, vals.len()).unwrap(), vals);
        // non-zero constants too
        let vals = vec![-9i32; 500];
        let auto = compress_symbols(&vals).unwrap();
        assert_eq!(auto[0], MAGIC_CONST);
        assert_eq!(decompress_symbols(&auto, vals.len()).unwrap(), vals);
    }

    #[test]
    fn wide_literals_fall_back_to_dense_modes() {
        // the sz3 UNPRED sentinel (i32::MIN) cannot ride the zigzag, but
        // rANS carries any i32 symbol — the auto pick lands there now
        let mut vals = peaked_stream(4096, 3);
        vals[100] = i32::MIN;
        let auto = compress_symbols(&vals).unwrap();
        assert_eq!(auto[0], MAGIC_RANS, "wide literals ride rans, not zigzag");
        assert_eq!(decompress_symbols(&auto, vals.len()).unwrap(), vals);
        assert!(compress_symbols_mode(&vals, SymbolMode::ZeroRun).is_err());
        // forced zero-run degrades to plain rather than failing
        let forced = with_symbol_mode(SymbolMode::ZeroRun, || compress_symbols(&vals).unwrap());
        assert!(forced[0] == 0xB3 || forced[0] == 0xB4);
        assert_eq!(decompress_symbols(&forced, vals.len()).unwrap(), vals);
    }

    #[test]
    fn forced_plain_reproduces_the_legacy_framing() {
        let vals = peaked_stream(10_000, 5);
        let legacy = lossless_compress(&huffman_encode(&vals)).unwrap();
        let forced = with_symbol_mode(SymbolMode::Plain, || compress_symbols(&vals).unwrap());
        assert_eq!(forced, legacy, "forced plain must match the PR-4 bytes");
    }

    #[test]
    fn symbol_container_decode_caps_and_empty() {
        let vals = peaked_stream(1000, 11);
        let enc = compress_symbols(&vals).unwrap();
        assert!(decompress_symbols(&enc, vals.len() - 1).is_err(), "cap enforced");
        let empty = compress_symbols(&[]).unwrap();
        assert!(decompress_symbols(&empty, 0).unwrap().is_empty());
    }

    #[test]
    fn symbol_scratch_reuse_across_modes() {
        let mut scratch = SymbolScratch::default();
        let mut out = Vec::new();
        for (i, vals) in [
            peaked_stream(5000, 1),
            vec![4i32; 300],
            (0..2000).map(|i| (i % 17) - 8).collect::<Vec<i32>>(),
        ]
        .iter()
        .enumerate()
        {
            let enc = compress_symbols(vals).unwrap();
            decompress_symbols_into(&enc, vals.len(), &mut out, &mut scratch).unwrap();
            assert_eq!(&out, vals, "stream {i}");
        }
    }

    #[test]
    fn symbol_stream_stats_report_modes() {
        let peaked = peaked_stream(32_768, 9);
        let zrun = compress_symbols_mode(&peaked, SymbolMode::ZeroRun).unwrap();
        let st = symbol_stream_stats(&zrun, peaked.len()).unwrap();
        assert_eq!(st.mode, "zero-run");
        assert_eq!(st.n_values, peaked.len());
        assert!(st.table_bytes > 0 && st.symbol_bytes > 0);
        let plain = compress_symbols_mode(&peaked, SymbolMode::Plain).unwrap();
        let st = symbol_stream_stats(&plain, peaked.len()).unwrap();
        assert_eq!(st.mode, "plain");
        assert_eq!(st.n_values, peaked.len());
        let zeros = vec![0i32; 64];
        let konst = compress_symbols(&zeros).unwrap();
        assert_eq!(symbol_stream_stats(&konst, 64).unwrap().mode, "const");
        // rans streams report the lane count and account for every byte
        let rans = compress_symbols_mode(&peaked, SymbolMode::Rans).unwrap();
        let st = symbol_stream_stats(&rans, peaked.len()).unwrap();
        assert_eq!(st.mode, "rans");
        assert_eq!(st.n_values, peaked.len());
        assert_eq!(st.lanes, crate::coder::rans::RANS_LANES);
        assert!(st.table_bytes > 0 && st.symbol_bytes > 0);
    }

    #[test]
    fn bucketed_runs_match_the_exact_oracle_and_shrink_mid_sparse_tiles() {
        // mid-sparse tile: hundreds of distinct run lengths, each rare —
        // the exact transform pays a table entry per length
        let mut rng = Rng::new(29);
        let mut vals = Vec::new();
        for run in 1..=300usize {
            vals.resize(vals.len() + run, 0);
            vals.push(1 + rng.below(3) as i32);
        }
        let enc = compress_symbols_mode(&vals, SymbolMode::ZeroRun).unwrap();
        assert_eq!(enc[0], MAGIC_ZRUN);
        assert_eq!(decompress_symbols(&enc, vals.len()).unwrap(), vals);
        // oracle: the pre-bucketing framing (exact run lengths) decodes
        // to the same values through the same 0xB5 decoder
        let exact = zero_run_transform(&vals).unwrap();
        let mut oracle = vec![MAGIC_ZRUN];
        oracle.extend_from_slice(&(vals.len() as u64).to_le_bytes());
        oracle.extend(huffman_encode(&exact));
        assert_eq!(decompress_symbols(&oracle, vals.len()).unwrap(), vals);
        assert!(
            enc.len() < oracle.len(),
            "bucketed {} should beat exact {} on mid-sparse runs",
            enc.len(),
            oracle.len()
        );
        // small sparse streams round-trip through the same chooser
        let few: Vec<i32> = peaked_stream(512, 31)
            .iter()
            .map(|&v| if v == 0 { 0 } else { 1 })
            .collect();
        let enc = compress_symbols_mode(&few, SymbolMode::ZeroRun).unwrap();
        assert_eq!(decompress_symbols(&enc, few.len()).unwrap(), few);
    }

    #[test]
    fn zero_run_truncations_and_flips_never_panic() {
        let vals = peaked_stream(4096, 13);
        let enc = compress_symbols_mode(&vals, SymbolMode::ZeroRun).unwrap();
        for cut in 0..enc.len().min(128) {
            if let Ok(out) = decompress_symbols(&enc[..cut], vals.len()) {
                assert_eq!(out.len(), vals.len());
            }
        }
        let mut rng = Rng::new(17);
        for _ in 0..400 {
            let mut m = enc.clone();
            let pos = rng.below(m.len());
            m[pos] ^= 1 << rng.below(8);
            if let Ok(out) = decompress_symbols(&m, vals.len()) {
                assert!(out.len() <= vals.len());
            }
        }
    }
}
