//! Lossless byte compression backend — ZSTD, exactly as the paper uses
//! for the concatenated index bitmaps (§II-E, Fig. 3).

use crate::Result;
use anyhow::Context;

/// Compress bytes with ZSTD (level 19 — these are tiny metadata streams,
//  so we favor ratio over speed).
pub fn zstd_compress(data: &[u8]) -> Result<Vec<u8>> {
    zstd::bulk::compress(data, 19).context("zstd compress")
}

/// Decompress a [`zstd_compress`] stream; `max_size` caps the output as a
/// safety bound against corrupt archives.
pub fn zstd_decompress(data: &[u8], max_size: usize) -> Result<Vec<u8>> {
    zstd::bulk::decompress(data, max_size).context("zstd decompress")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn round_trip_structured() {
        // runs of 1s/0s like the Fig.-3 bitmaps
        let mut data = Vec::new();
        for i in 0..200 {
            data.extend(std::iter::repeat(0xFFu8).take(i % 7));
            data.extend(std::iter::repeat(0x00u8).take(13 - i % 7));
        }
        let c = zstd_compress(&data).unwrap();
        assert!(c.len() < data.len() / 4, "{} vs {}", c.len(), data.len());
        let d = zstd_decompress(&c, data.len()).unwrap();
        assert_eq!(d, data);
    }

    #[test]
    fn round_trip_random() {
        let mut rng = Rng::new(4);
        let data: Vec<u8> = (0..10_000).map(|_| rng.next_u64() as u8).collect();
        let c = zstd_compress(&data).unwrap();
        let d = zstd_decompress(&c, data.len()).unwrap();
        assert_eq!(d, data);
    }

    #[test]
    fn empty_round_trip() {
        let c = zstd_compress(&[]).unwrap();
        let d = zstd_decompress(&c, 16).unwrap();
        assert!(d.is_empty());
    }

    #[test]
    fn corrupt_stream_errors() {
        assert!(zstd_decompress(&[1, 2, 3, 4], 100).is_err());
    }
}
