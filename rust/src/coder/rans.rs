//! Static-frequency interleaved multi-lane rANS coder (magic 0xB7) for
//! dense quantized-symbol streams.
//!
//! Huffman (the plain container mode) pays an integer number of bits per
//! symbol, so dense near-uniform alphabets — keyframe quantization codes,
//! multi-species residual streams — lose up to half a bit per symbol and
//! the table-driven decode chases a LUT per code. rANS closes both gaps:
//! it codes fractional bits against a 12-bit normalized frequency table,
//! and the decoder is a short branch-light dependency chain (mask, table
//! lookup, multiply-add, byte-wise refill) that interleaves across
//! [`RANS_LANES`] independent u32 states so the CPU overlaps the chains.
//!
//! Layout (all little-endian):
//! ```text
//!   0xB7 | u64 n_values | u8 scale_bits (= 12) | u32 n_syms |
//!   n_syms x ( i32 symbol | u16 freq ) |
//!   4 x u32 final_state | 4 x u32 lane_len |
//!   lane 0 bytes | lane 1 bytes | lane 2 bytes | lane 3 bytes
//! ```
//!
//! Lane `j % 4` owns value `j`. Each lane is encoded back-to-front (rANS
//! is LIFO) and its bytes are reversed afterwards, so the decoder reads
//! every lane strictly forward. Frequencies are normalized to sum exactly
//! [`RANS_SCALE`] with every surviving symbol >= 1 (deterministic
//! largest-first correction, so archives are byte-identical at any thread
//! count). Streams with more than [`RANS_MAX_SYMS`] distinct symbols are
//! ineligible and stay in the plain mode.
//!
//! Every decode-side count is validated against the bytes actually
//! present *before* it sizes an allocation, and the final lane states
//! must land back on [`RANS_L`] with every lane byte consumed — a
//! truncated or desynced stream cannot decode silently.

use super::freq::symbol_freqs;
use crate::Result;
use anyhow::{bail, ensure};

/// Number of interleaved rANS states (and independent byte lanes).
pub const RANS_LANES: usize = 4;
/// log2 of the frequency normalization total.
pub const RANS_SCALE_BITS: u32 = 12;
/// Frequency normalization total: all table freqs sum to exactly this.
pub const RANS_SCALE: u32 = 1 << RANS_SCALE_BITS;
/// Renormalization lower bound: states live in `[RANS_L, RANS_L << 8)`.
pub const RANS_L: u32 = 1 << 23;
/// Most distinct symbols a stream may carry and stay eligible.
pub const RANS_MAX_SYMS: usize = RANS_SCALE as usize;

/// Symbol-container magic for rANS streams (dispatched in
/// [`crate::coder::lossless`]).
pub const MAGIC_RANS: u8 = 0xB7;

/// Fixed header bytes before the frequency table.
const HEADER_BYTES: usize = 1 + 8 + 1 + 4;
/// Final states + lane lengths.
const LANE_HEADER_BYTES: usize = RANS_LANES * 4 * 2;

/// Normalize raw counts to sum exactly [`RANS_SCALE`] with every entry
/// >= 1. Proportional floor first, then a deterministic correction:
/// excess is taken largest-first (ties by index), deficit is handed to
/// the single most frequent symbol. `None` when the alphabet is empty or
/// wider than [`RANS_MAX_SYMS`].
fn normalize_freqs(counts: &[(i32, u64)]) -> Option<Vec<u32>> {
    let n = counts.len();
    if n == 0 || n > RANS_MAX_SYMS {
        return None;
    }
    let total: u64 = counts.iter().map(|&(_, c)| c).sum();
    // counts come from a <= i32::MAX-long stream, so c * SCALE fits u64
    let mut norm: Vec<u32> = counts
        .iter()
        .map(|&(_, c)| (((c * RANS_SCALE as u64) / total) as u32).max(1))
        .collect();
    let sum: u64 = norm.iter().map(|&f| f as u64).sum();
    match sum.cmp(&(RANS_SCALE as u64)) {
        std::cmp::Ordering::Greater => {
            let mut order: Vec<usize> = (0..n).collect();
            order.sort_unstable_by(|&a, &b| norm[b].cmp(&norm[a]).then(a.cmp(&b)));
            let mut excess = sum - RANS_SCALE as u64;
            for &i in &order {
                if excess == 0 {
                    break;
                }
                let take = excess.min((norm[i] - 1) as u64) as u32;
                norm[i] -= take;
                excess -= take as u64;
            }
            if excess > 0 {
                return None; // unreachable for n <= SCALE; defensive
            }
        }
        std::cmp::Ordering::Less => {
            let mut best = 0usize;
            for i in 1..n {
                if norm[i] > norm[best] {
                    best = i;
                }
            }
            norm[best] += (RANS_SCALE as u64 - sum) as u32;
        }
        std::cmp::Ordering::Equal => {}
    }
    Some(norm)
}

/// Map each value to its table index. Dense offset table when the symbol
/// range is compact (the common quantized-stream case), binary search
/// otherwise.
fn index_values(values: &[i32], syms: &[i32]) -> Vec<u32> {
    let lo = syms[0] as i64;
    let hi = syms[syms.len() - 1] as i64;
    let range = (hi - lo + 1) as u64;
    if range <= (RANS_MAX_SYMS as u64) * 4 {
        let mut map = vec![0u16; range as usize];
        for (e, &s) in syms.iter().enumerate() {
            map[(s as i64 - lo) as usize] = e as u16;
        }
        values.iter().map(|&v| map[(v as i64 - lo) as usize] as u32).collect()
    } else {
        values
            .iter()
            .map(|&v| syms.binary_search(&v).expect("symbol in table") as u32)
            .collect()
    }
}

/// Encode a symbol stream into the 0xB7 container. Errors when the
/// stream is empty, longer than `i32::MAX`, or carries more than
/// [`RANS_MAX_SYMS`] distinct symbols (callers fall back to plain).
pub fn rans_encode(values: &[i32]) -> Result<Vec<u8>> {
    ensure!(!values.is_empty(), "rans: empty stream");
    ensure!(
        values.len() <= i32::MAX as usize,
        "rans: stream longer than {} symbols",
        i32::MAX
    );
    let counts = symbol_freqs(values);
    let norm = match normalize_freqs(&counts) {
        Some(n) => n,
        None => bail!("rans: {} distinct symbols exceed {}", counts.len(), RANS_MAX_SYMS),
    };
    let syms: Vec<i32> = counts.iter().map(|&(s, _)| s).collect();
    let mut cum = vec![0u32; norm.len()];
    let mut acc = 0u32;
    for (c, &f) in cum.iter_mut().zip(&norm) {
        *c = acc;
        acc += f;
    }
    let idx = index_values(values, &syms);

    // each lane owns values at positions j % RANS_LANES == lane and is
    // encoded back-to-front (rANS is LIFO); lanes are independent, so
    // per-lane passes keep the state in a register
    let mut states = [RANS_L; RANS_LANES];
    let mut lane_bytes: [Vec<u8>; RANS_LANES] = Default::default();
    for (lane, (state, bytes)) in states.iter_mut().zip(&mut lane_bytes).enumerate() {
        let mut x = RANS_L;
        for &e in idx[lane..].iter().step_by(RANS_LANES).rev() {
            let f = norm[e as usize];
            let c = cum[e as usize];
            // largest x that still renormalizes into [L, L << 8) after
            // the state update: ((L >> 12) << 8) * f <= 2^31, fits u32
            let x_max = ((RANS_L >> RANS_SCALE_BITS) << 8) * f;
            while x >= x_max {
                bytes.push(x as u8);
                x >>= 8;
            }
            x = ((x / f) << RANS_SCALE_BITS) + (x % f) + c;
        }
        bytes.reverse(); // decoder reads this lane strictly forward
        *state = x;
    }

    let payload: usize = lane_bytes.iter().map(|b| b.len()).sum();
    let mut out = Vec::with_capacity(
        HEADER_BYTES + syms.len() * 6 + LANE_HEADER_BYTES + payload,
    );
    out.push(MAGIC_RANS);
    out.extend_from_slice(&(values.len() as u64).to_le_bytes());
    out.push(RANS_SCALE_BITS as u8);
    out.extend_from_slice(&(syms.len() as u32).to_le_bytes());
    for (&s, &f) in syms.iter().zip(&norm) {
        out.extend_from_slice(&s.to_le_bytes());
        out.extend_from_slice(&(f as u16).to_le_bytes());
    }
    for &s in &states {
        out.extend_from_slice(&s.to_le_bytes());
    }
    for b in &lane_bytes {
        ensure!(b.len() <= u32::MAX as usize, "rans: lane overflow");
        out.extend_from_slice(&(b.len() as u32).to_le_bytes());
    }
    for b in &lane_bytes {
        out.extend_from_slice(b);
    }
    Ok(out)
}

/// Reusable decode tables: one `(freq, cum, symbol)` row per table entry
/// plus the 4096-slot slot→entry map. One per pool thread via
/// [`crate::engine::Scratch`], so per-tile decodes stop allocating.
#[derive(Default)]
pub struct RansScratch {
    rows: Vec<(u32, u32, i32)>,
    cum2sym: Vec<u16>,
}

/// Decode a 0xB7 stream into `out` (cleared first). `max_values` caps
/// the declared count before any allocation; every header field is
/// validated against the bytes actually present, and the final lane
/// states must equal [`RANS_L`] with every lane byte consumed.
pub fn rans_decode_into(
    data: &[u8],
    max_values: usize,
    out: &mut Vec<i32>,
    scratch: &mut RansScratch,
) -> Result<()> {
    out.clear();
    ensure!(data.len() >= HEADER_BYTES, "rans: header truncated");
    ensure!(data[0] == MAGIC_RANS, "rans: bad magic {:#04x}", data[0]);
    let n = u64::from_le_bytes(data[1..9].try_into().unwrap());
    let n = usize::try_from(n).map_err(|_| anyhow::anyhow!("rans: count overflow"))?;
    ensure!(n >= 1, "rans: zero-value stream");
    ensure!(n <= max_values, "rans: declared count {n} exceeds cap {max_values}");
    ensure!(
        data[9] as u32 == RANS_SCALE_BITS,
        "rans: unsupported scale_bits {}",
        data[9]
    );
    let n_syms = u32::from_le_bytes(data[10..14].try_into().unwrap()) as usize;
    ensure!(
        n_syms >= 1 && n_syms <= RANS_MAX_SYMS,
        "rans: table size {n_syms} out of range"
    );
    let table_end = HEADER_BYTES + n_syms * 6;
    let lanes_start = table_end + LANE_HEADER_BYTES;
    ensure!(data.len() >= lanes_start, "rans: table truncated");

    let RansScratch { rows, cum2sym } = scratch;
    rows.clear();
    rows.reserve(n_syms);
    cum2sym.clear();
    cum2sym.resize(RANS_SCALE as usize, 0);
    let mut acc = 0u32;
    for e in 0..n_syms {
        let off = HEADER_BYTES + e * 6;
        let sym = i32::from_le_bytes(data[off..off + 4].try_into().unwrap());
        let f = u16::from_le_bytes(data[off + 4..off + 6].try_into().unwrap()) as u32;
        ensure!(f >= 1, "rans: zero frequency in table");
        ensure!(acc + f <= RANS_SCALE, "rans: frequencies exceed {RANS_SCALE}");
        for slot in cum2sym[acc as usize..(acc + f) as usize].iter_mut() {
            *slot = e as u16;
        }
        rows.push((f, acc, sym));
        acc += f;
    }
    ensure!(acc == RANS_SCALE, "rans: frequencies sum to {acc}, not {RANS_SCALE}");

    let mut states = [0u32; RANS_LANES];
    let mut lane_lens = [0usize; RANS_LANES];
    for (lane, s) in states.iter_mut().enumerate() {
        let off = table_end + lane * 4;
        *s = u32::from_le_bytes(data[off..off + 4].try_into().unwrap());
        // valid encoder states live below 2^31; the bound also keeps the
        // decode multiply-add inside u32
        ensure!(*s < 1 << 31, "rans: lane {lane} state out of range");
    }
    let mut total = 0u64;
    for (lane, l) in lane_lens.iter_mut().enumerate() {
        let off = table_end + (RANS_LANES + lane) * 4;
        *l = u32::from_le_bytes(data[off..off + 4].try_into().unwrap()) as usize;
        total += *l as u64;
    }
    ensure!(
        total == (data.len() - lanes_start) as u64,
        "rans: lane lengths {total} != {} payload bytes",
        data.len() - lanes_start
    );
    let mut lanes: [&[u8]; RANS_LANES] = [&[]; RANS_LANES];
    let mut pos = lanes_start;
    for (lane, len) in lanes.iter_mut().zip(&lane_lens) {
        *lane = &data[pos..pos + len];
        pos += len;
    }

    out.reserve(n);
    let mut cursors = [0usize; RANS_LANES];
    let rows = rows.as_slice();
    let cum2sym = cum2sym.as_slice();

    #[inline(always)]
    fn step(
        x: &mut u32,
        lane: &[u8],
        cursor: &mut usize,
        rows: &[(u32, u32, i32)],
        cum2sym: &[u16],
    ) -> Result<i32> {
        let slot = *x & (RANS_SCALE - 1);
        let e = cum2sym[slot as usize] as usize;
        let (f, c, sym) = rows[e];
        *x = f * (*x >> RANS_SCALE_BITS) + slot - c;
        while *x < RANS_L {
            let Some(&b) = lane.get(*cursor) else {
                bail!("rans: lane bytes exhausted");
            };
            *cursor += 1;
            *x = (*x << 8) | b as u32;
        }
        Ok(sym)
    }

    // interleaved main loop: 4 independent dependency chains per round
    let rounds = n / RANS_LANES;
    for _ in 0..rounds {
        let s0 = step(&mut states[0], lanes[0], &mut cursors[0], rows, cum2sym)?;
        let s1 = step(&mut states[1], lanes[1], &mut cursors[1], rows, cum2sym)?;
        let s2 = step(&mut states[2], lanes[2], &mut cursors[2], rows, cum2sym)?;
        let s3 = step(&mut states[3], lanes[3], &mut cursors[3], rows, cum2sym)?;
        out.extend_from_slice(&[s0, s1, s2, s3]);
    }
    let tail = n % RANS_LANES;
    for ((x, lane), cursor) in states.iter_mut().zip(&lanes).zip(&mut cursors).take(tail) {
        let s = step(x, lane, cursor, rows, cum2sym)?;
        out.push(s);
    }

    for (lane, ((&x, &cur), &len)) in
        states.iter().zip(&cursors).zip(&lane_lens).enumerate()
    {
        ensure!(x == RANS_L, "rans: lane {lane} final state {x:#x} desynced");
        ensure!(cur == len, "rans: lane {lane} left {} bytes unconsumed", len - cur);
    }
    Ok(())
}

/// Estimated full-stream 0xB7 size from a sample window: header + table
/// are fixed costs, the cross-entropy payload scales with the length
/// ratio (mirrors `scaled_estimate` for the plain trial). `None` when
/// the sample alphabet is already ineligible.
pub(crate) fn rans_scaled_estimate(sample: &[i32], scale: f64) -> Option<f64> {
    let counts = symbol_freqs(sample);
    let norm = normalize_freqs(&counts)?;
    let mut bits = 0.0f64;
    for (&(_, c), &f) in counts.iter().zip(&norm) {
        bits += c as f64 * (RANS_SCALE_BITS as f64 - (f as f64).log2());
    }
    let fixed = (HEADER_BYTES + counts.len() * 6 + LANE_HEADER_BYTES) as f64;
    Some(fixed + (bits / 8.0) * scale)
}

/// Layout of a 0xB7 stream without decoding it:
/// `(table_bytes, symbol_bytes, n_values, lanes)`.
pub fn rans_stream_layout(data: &[u8]) -> Result<(usize, usize, usize, usize)> {
    ensure!(data.len() >= HEADER_BYTES, "rans: header truncated");
    ensure!(data[0] == MAGIC_RANS, "rans: bad magic {:#04x}", data[0]);
    let n_values = u64::from_le_bytes(data[1..9].try_into().unwrap()) as usize;
    let n_syms = u32::from_le_bytes(data[10..14].try_into().unwrap()) as usize;
    ensure!(
        n_syms >= 1 && n_syms <= RANS_MAX_SYMS,
        "rans: table size {n_syms} out of range"
    );
    let table_bytes = n_syms * 6;
    let lanes_start = HEADER_BYTES + table_bytes + LANE_HEADER_BYTES;
    ensure!(data.len() >= lanes_start, "rans: table truncated");
    Ok((table_bytes, data.len() - lanes_start, n_values, RANS_LANES))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn decode(data: &[u8], max: usize) -> Result<Vec<i32>> {
        let mut out = Vec::new();
        rans_decode_into(data, max, &mut out, &mut RansScratch::default())?;
        Ok(out)
    }

    fn gaussish(n: usize, spread: usize, seed: u64) -> Vec<i32> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| {
                let s = (0..4).map(|_| rng.below(spread) as i64).sum::<i64>();
                (s - 2 * (spread as i64 - 1)) as i32
            })
            .collect()
    }

    #[test]
    fn round_trips_across_shapes() {
        let cases: Vec<Vec<i32>> = vec![
            gaussish(100_000, 32, 1),
            gaussish(257, 5, 2),
            vec![7],
            vec![7, -3],
            vec![7, -3, 9],
            vec![7, -3, 9, 9, 9],
            vec![5; 4096],
            (0..4096).collect(), // exactly RANS_MAX_SYMS distinct
            {
                let mut v = vec![0i32; 65_537];
                v[65_536] = 1; // extreme skew: freq 4095 / 1
                v
            },
        ];
        for (i, vals) in cases.iter().enumerate() {
            let enc = rans_encode(vals).unwrap();
            assert_eq!(enc[0], MAGIC_RANS, "case {i}");
            assert_eq!(&decode(&enc, vals.len()).unwrap(), vals, "case {i}");
        }
    }

    #[test]
    fn payload_tracks_entropy() {
        // 8-bit-ish gaussian: huffman rounds code lengths up, rans should
        // land within a fraction of a percent of the sample entropy
        let vals = gaussish(200_000, 64, 3);
        let enc = rans_encode(&vals).unwrap();
        let counts = symbol_freqs(&vals);
        let n = vals.len() as f64;
        let entropy_bytes: f64 = counts
            .iter()
            .map(|&(_, c)| -(c as f64) * ((c as f64 / n).log2()) / 8.0)
            .sum();
        let (table, payload, _, _) = rans_stream_layout(&enc).unwrap();
        assert!(
            (payload as f64) < entropy_bytes * 1.01 + 16.0,
            "payload {payload} vs entropy {entropy_bytes:.0}"
        );
        assert!(table > 0);
    }

    #[test]
    fn wide_alphabets_are_rejected() {
        let vals: Vec<i32> = (0..5000).collect();
        assert!(rans_encode(&vals).is_err());
        assert!(rans_encode(&[]).is_err());
    }

    #[test]
    fn normalization_is_exact_and_deterministic() {
        for seed in 0..8u64 {
            let vals = gaussish(10_000, 8 + seed as usize, seed);
            let counts = symbol_freqs(&vals);
            let norm = normalize_freqs(&counts).unwrap();
            assert_eq!(norm.iter().map(|&f| f as u64).sum::<u64>(), RANS_SCALE as u64);
            assert!(norm.iter().all(|&f| f >= 1));
            assert_eq!(norm, normalize_freqs(&counts).unwrap());
        }
    }

    #[test]
    fn truncations_and_flips_error_never_panic() {
        let vals = gaussish(10_000, 16, 5);
        let enc = rans_encode(&vals).unwrap();
        for cut in 0..enc.len().min(96) {
            assert!(decode(&enc[..cut], vals.len()).is_err(), "cut {cut}");
        }
        // dropping payload bytes breaks the lane-length accounting
        assert!(decode(&enc[..enc.len() - 1], vals.len()).is_err());
        let mut rng = Rng::new(6);
        for _ in 0..500 {
            let mut m = enc.clone();
            let pos = rng.below(m.len());
            m[pos] ^= 1 << rng.below(8);
            if let Ok(out) = decode(&m, vals.len()) {
                assert!(out.len() <= vals.len());
            }
        }
    }

    #[test]
    fn count_cap_checked_before_allocation() {
        let vals = gaussish(1000, 8, 7);
        let mut enc = rans_encode(&vals).unwrap();
        assert!(decode(&enc, vals.len() - 1).is_err(), "cap enforced");
        // an absurd declared count is refused against the caller's cap
        // before anything is allocated for it
        enc[1..9].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(decode(&enc, 1 << 30).is_err());
    }

    #[test]
    fn lane_desync_is_detected() {
        let vals = gaussish(4096, 16, 8);
        let enc = rans_encode(&vals).unwrap();
        let table_end = HEADER_BYTES + symbol_freqs(&vals).len() * 6;
        // corrupt lane 2's initial state: decode must error via the
        // refill/final-state checks, never panic
        let mut m = enc.clone();
        m[table_end + 8] ^= 0x41;
        assert!(decode(&m, vals.len()).is_err());
        // swap two unequal lane byte-lengths: the payload total still
        // matches, but every lane now reads the wrong span
        let l0 = table_end + RANS_LANES * 4;
        let lens: Vec<u32> = (0..RANS_LANES)
            .map(|i| u32::from_le_bytes(enc[l0 + 4 * i..l0 + 4 * i + 4].try_into().unwrap()))
            .collect();
        let pair = (0..RANS_LANES)
            .flat_map(|a| (a + 1..RANS_LANES).map(move |b| (a, b)))
            .find(|&(a, b)| lens[a] != lens[b]);
        if let Some((a, b)) = pair {
            let mut m = enc.clone();
            for k in 0..4 {
                m.swap(l0 + 4 * a + k, l0 + 4 * b + k);
            }
            assert!(decode(&m, vals.len()).is_err());
        }
    }

    #[test]
    fn layout_accounts_for_every_byte() {
        let vals = gaussish(50_000, 32, 9);
        let enc = rans_encode(&vals).unwrap();
        let (table, payload, n, lanes) = rans_stream_layout(&enc).unwrap();
        assert_eq!(n, vals.len());
        assert_eq!(lanes, RANS_LANES);
        assert_eq!(
            HEADER_BYTES + table + LANE_HEADER_BYTES + payload,
            enc.len(),
            "layout must account for the whole stream"
        );
    }
}
