//! Canonical Huffman codec over i32 symbols (paper §II-E).
//!
//! Quantized latent / PCA coefficients are heavily peaked around zero, so
//! Huffman over the integer codes is the entropy stage the paper uses.
//! The table is serialized canonically: sorted (code-length, symbol)
//! pairs, so the decoder rebuilds the exact same codebook.
//!
//! Stream layout (all little-endian):
//!   u32 n_symbols | n_symbols x (i32 symbol, u8 bitlen) | u64 n_values |
//!   padding to byte | bitstream
//!
//! Degenerate case (single distinct symbol): bitlen 0, no payload bits.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};

use super::bitstream::{BitReader, BitWriter};
use crate::Result;
use anyhow::{bail, ensure};

const MAX_CODE_LEN: u32 = 58; // fits a u64 accumulator comfortably

/// Compute canonical code lengths for `symbols` (must be non-empty).
fn code_lengths(freqs: &HashMap<i32, u64>) -> Vec<(i32, u32)> {
    // package into a heap of (weight, tie, node); standard Huffman tree.
    #[derive(PartialEq, Eq, PartialOrd, Ord)]
    struct Node {
        weight: u64,
        tie: u64,
        idx: usize,
    }
    let mut syms: Vec<(i32, u64)> = freqs.iter().map(|(&s, &f)| (s, f)).collect();
    syms.sort_unstable();
    if syms.len() == 1 {
        return vec![(syms[0].0, 0)];
    }
    // leaves 0..n, internal nodes appended
    let n = syms.len();
    let mut parent = vec![usize::MAX; n];
    let mut heap: BinaryHeap<Reverse<Node>> = syms
        .iter()
        .enumerate()
        .map(|(i, &(_, f))| Reverse(Node { weight: f, tie: i as u64, idx: i }))
        .collect();
    let mut next_tie = n as u64;
    let mut nodes_parent: Vec<usize> = Vec::new(); // parents of internal nodes
    while heap.len() > 1 {
        let a = heap.pop().unwrap().0;
        let b = heap.pop().unwrap().0;
        let new_idx = n + nodes_parent.len();
        nodes_parent.push(usize::MAX);
        for idx in [a.idx, b.idx] {
            if idx < n {
                parent[idx] = new_idx;
            } else {
                nodes_parent[idx - n] = new_idx;
            }
        }
        heap.push(Reverse(Node {
            weight: a.weight + b.weight,
            tie: next_tie,
            idx: new_idx,
        }));
        next_tie += 1;
    }
    // depth of each leaf
    let mut out = Vec::with_capacity(n);
    for (i, &(sym, _)) in syms.iter().enumerate() {
        let mut depth = 0u32;
        let mut p = parent[i];
        while p != usize::MAX {
            depth += 1;
            p = nodes_parent[p - n];
        }
        out.push((sym, depth.max(1)));
    }
    // cap pathological lengths (then re-normalize via canonical assignment;
    // with u64 freqs over realistic data this never triggers)
    for e in &mut out {
        e.1 = e.1.min(MAX_CODE_LEN);
    }
    out
}

/// Assign canonical codes from (symbol, len) pairs.
/// Returns map symbol -> (code, len); codes are MSB-first per canonical
/// convention, emitted LSB-first bit-reversed for the LSB bitstream.
fn canonical_codes(lens: &[(i32, u32)]) -> HashMap<i32, (u64, u32)> {
    let mut sorted: Vec<(u32, i32)> = lens.iter().map(|&(s, l)| (l, s)).collect();
    sorted.sort_unstable();
    let mut map = HashMap::with_capacity(sorted.len());
    let mut code = 0u64;
    let mut prev_len = sorted.first().map(|&(l, _)| l).unwrap_or(0);
    for &(len, sym) in &sorted {
        code <<= len - prev_len;
        prev_len = len;
        map.insert(sym, (code, len));
        code += 1;
    }
    map
}

fn reverse_bits(v: u64, n: u32) -> u64 {
    if n == 0 {
        return 0;
    }
    v.reverse_bits() >> (64 - n)
}

/// Encode values into a self-contained byte stream.
pub fn huffman_encode(values: &[i32]) -> Vec<u8> {
    let mut freqs: HashMap<i32, u64> = HashMap::new();
    for &v in values {
        *freqs.entry(v).or_insert(0) += 1;
    }
    let mut out = Vec::new();
    if values.is_empty() {
        out.extend_from_slice(&0u32.to_le_bytes());
        out.extend_from_slice(&0u64.to_le_bytes());
        return out;
    }
    let lens = code_lengths(&freqs);
    out.extend_from_slice(&(lens.len() as u32).to_le_bytes());
    // canonical table: sort by (len, symbol) so decoder derivation matches
    let mut table = lens.clone();
    table.sort_unstable_by_key(|&(s, l)| (l, s));
    for &(sym, len) in &table {
        out.extend_from_slice(&sym.to_le_bytes());
        out.push(len as u8);
    }
    out.extend_from_slice(&(values.len() as u64).to_le_bytes());
    let codes = canonical_codes(&lens);
    let mut w = BitWriter::new();
    for &v in values {
        let (code, len) = codes[&v];
        if len > 0 {
            w.write_bits(reverse_bits(code, len), len);
        }
    }
    out.extend_from_slice(w.as_bytes());
    out
}

/// Decode a stream produced by [`huffman_encode`]. Returns the values and
/// the number of bytes consumed.
pub fn huffman_decode(bytes: &[u8]) -> Result<(Vec<i32>, usize)> {
    ensure!(bytes.len() >= 4, "huffman: truncated header");
    let n_sym = u32::from_le_bytes(bytes[0..4].try_into().unwrap()) as usize;
    let mut off = 4;
    let mut table: Vec<(i32, u32)> = Vec::with_capacity(n_sym);
    ensure!(bytes.len() >= off + n_sym * 5 + 8, "huffman: truncated table");
    for _ in 0..n_sym {
        let sym = i32::from_le_bytes(bytes[off..off + 4].try_into().unwrap());
        let len = bytes[off + 4] as u32;
        table.push((sym, len));
        off += 5;
    }
    let n_vals = u64::from_le_bytes(bytes[off..off + 8].try_into().unwrap()) as usize;
    off += 8;
    if n_vals == 0 {
        return Ok((vec![], off));
    }
    if n_sym == 1 {
        // degenerate: all values are the single symbol
        return Ok((vec![table[0].0; n_vals], off));
    }
    // rebuild canonical codes; decode via a (len-bucketed) lookup
    let codes = canonical_codes(&table);
    // invert: sorted by (len, canonical code) for sequential decode
    let mut dec: HashMap<(u32, u64), i32> = HashMap::with_capacity(codes.len());
    let mut max_len = 0;
    for (&sym, &(code, len)) in &codes {
        dec.insert((len, code), sym);
        max_len = max_len.max(len);
    }
    let payload = &bytes[off..];
    let mut r = BitReader::new(payload);
    let mut out = Vec::with_capacity(n_vals);
    'outer: for _ in 0..n_vals {
        let mut code = 0u64;
        for len in 1..=max_len {
            let Some(bit) = r.read_bit() else {
                bail!("huffman: bitstream underrun");
            };
            code = (code << 1) | bit as u64;
            if let Some(&sym) = dec.get(&(len, code)) {
                out.push(sym);
                continue 'outer;
            }
        }
        bail!("huffman: invalid code in stream");
    }
    let consumed = off + r.bit_pos().div_ceil(8);
    Ok((out, consumed))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn round_trip(vals: &[i32]) {
        let enc = huffman_encode(vals);
        let (dec, used) = huffman_decode(&enc).unwrap();
        assert_eq!(dec, vals);
        assert_eq!(used, enc.len());
    }

    #[test]
    fn empty_and_single() {
        round_trip(&[]);
        round_trip(&[42]);
        round_trip(&[7; 1000]);
    }

    #[test]
    fn two_symbols() {
        round_trip(&[0, 1, 0, 0, 1, 0, 0, 0, 1]);
    }

    #[test]
    fn random_peaked_distribution() {
        // shape matches quantized latents: concentrated near 0
        let mut rng = Rng::new(9);
        let vals: Vec<i32> = (0..20_000)
            .map(|_| (rng.normal() * 3.0).round() as i32)
            .collect();
        round_trip(&vals);
        // compression vs raw 4 bytes/value should be significant
        let enc = huffman_encode(&vals);
        assert!(
            enc.len() < vals.len() * 2,
            "expected < 16 bits/sym, got {} bytes for {} vals",
            enc.len(),
            vals.len()
        );
    }

    #[test]
    fn uniform_distribution_still_round_trips() {
        let mut rng = Rng::new(10);
        let vals: Vec<i32> = (0..5000).map(|_| rng.below(256) as i32 - 128).collect();
        round_trip(&vals);
    }

    #[test]
    fn extreme_symbol_values() {
        round_trip(&[i32::MAX, i32::MIN, 0, i32::MAX, -1, 1]);
    }

    #[test]
    fn concatenated_streams_decode_sequentially() {
        let a = vec![1, 2, 3, 1, 1];
        let b = vec![-5; 17];
        let mut buf = huffman_encode(&a);
        let len_a = buf.len();
        buf.extend(huffman_encode(&b));
        let (da, ua) = huffman_decode(&buf).unwrap();
        assert_eq!(da, a);
        assert_eq!(ua, len_a);
        let (db, _) = huffman_decode(&buf[ua..]).unwrap();
        assert_eq!(db, b);
    }

    #[test]
    fn rejects_truncation() {
        let enc = huffman_encode(&[1, 2, 3, 4, 5, 6, 7, 8, 1, 1, 1]);
        assert!(huffman_decode(&enc[..enc.len() - 1]).is_err());
        assert!(huffman_decode(&enc[..3]).is_err());
    }

    #[test]
    fn near_optimal_for_skewed_data() {
        // H(p) for p = [0.9, 0.05, 0.05] ≈ 0.569 bits; huffman gives ~1.1
        let mut vals = vec![0i32; 9000];
        vals.extend(vec![1i32; 500]);
        vals.extend(vec![2i32; 500]);
        let mut rng = Rng::new(3);
        rng.shuffle(&mut vals);
        let enc = huffman_encode(&vals);
        let bits_per_sym = (enc.len() * 8) as f64 / vals.len() as f64;
        assert!(bits_per_sym < 1.3, "bits/sym = {bits_per_sym}");
    }
}
