//! Canonical Huffman codec over i32 symbols (paper §II-E).
//!
//! Quantized latent / PCA coefficients are heavily peaked around zero, so
//! Huffman over the integer codes is the entropy stage the paper uses.
//! The table is serialized canonically: sorted (code-length, symbol)
//! pairs, so the decoder rebuilds the exact same codebook.
//!
//! Stream layout (all little-endian):
//!   u32 n_symbols | n_symbols x (i32 symbol, u8 bitlen) | u64 n_values |
//!   padding to byte | bitstream
//!
//! Degenerate case (single distinct symbol): bitlen 0, no payload bits.
//!
//! Hot-path design (the entropy-coder overhaul):
//!
//! * **No hashing anywhere.** Frequencies come from the shared dense /
//!   sort-based counter in [`super::freq`]; encode looks codes up through
//!   a dense `symbol - min` table (compact alphabets) or binary search;
//!   decode is table-driven.
//! * **Table-driven decode.** A flat first-level LUT resolves every code
//!   of up to [`LUT_BITS`] bits with one peek + one lookup; longer codes
//!   (rare by construction — canonical codes sort short-first) fall back
//!   to a canonical bit-at-a-time walk over per-length
//!   `first_code`/`first_index` arrays. The old `HashMap`-per-bit
//!   decoder survives as [`huffman_decode_bitwise`] (now backed by a
//!   sorted table) purely as the equivalence/speedup oracle.
//! * **Reusable decode state.** [`huffman_decode_into`] threads a
//!   [`HuffScratch`] so per-tile decodes reuse the table and LUT buffers
//!   instead of allocating per call (wired through the engine's
//!   per-thread [`crate::engine::Scratch`] arenas).
//!
//! Untrusted input: every declared count is validated against the bytes
//! actually present *before* it sizes an allocation.

use super::bitstream::BitReader;
use super::bitstream::BitWriter;
use super::freq::{dense_range_cap, symbol_freqs};
use crate::Result;
use anyhow::{bail, ensure};
use std::cmp::Reverse;
use std::collections::BinaryHeap;

const MAX_CODE_LEN: u32 = 58; // fits a u64 accumulator comfortably

/// First-level decode LUT width: one `peek` resolves any code of up to
/// this many bits. 12 bits covers every code the peaked streams produce
/// while the 4096-entry table still fills fast and stays cache-resident.
const LUT_BITS: u32 = 12;

/// Default cap on the declared value count (mirrors the baselines'
/// `MAX_POINTS_DEFAULT`): large enough for paper-scale streams, small
/// enough that a corrupt 2^60 claim cannot size an allocation.
const MAX_VALUES_DEFAULT: usize = 1 << 31;

/// Compute canonical code lengths for symbol frequencies (sorted by
/// symbol, non-empty).
fn code_lengths(freqs: &[(i32, u64)]) -> Vec<(i32, u32)> {
    // package into a heap of (weight, tie, node); standard Huffman tree.
    #[derive(PartialEq, Eq, PartialOrd, Ord)]
    struct Node {
        weight: u64,
        tie: u64,
        idx: usize,
    }
    if freqs.len() == 1 {
        return vec![(freqs[0].0, 0)];
    }
    // leaves 0..n, internal nodes appended
    let n = freqs.len();
    let mut parent = vec![usize::MAX; n];
    let mut heap: BinaryHeap<Reverse<Node>> = freqs
        .iter()
        .enumerate()
        .map(|(i, &(_, f))| Reverse(Node { weight: f, tie: i as u64, idx: i }))
        .collect();
    let mut next_tie = n as u64;
    let mut nodes_parent: Vec<usize> = Vec::new(); // parents of internal nodes
    while heap.len() > 1 {
        let a = heap.pop().unwrap().0;
        let b = heap.pop().unwrap().0;
        let new_idx = n + nodes_parent.len();
        nodes_parent.push(usize::MAX);
        for idx in [a.idx, b.idx] {
            if idx < n {
                parent[idx] = new_idx;
            } else {
                nodes_parent[idx - n] = new_idx;
            }
        }
        heap.push(Reverse(Node {
            weight: a.weight + b.weight,
            tie: next_tie,
            idx: new_idx,
        }));
        next_tie += 1;
    }
    // depth of each leaf
    let mut out = Vec::with_capacity(n);
    for (i, &(sym, _)) in freqs.iter().enumerate() {
        let mut depth = 0u32;
        let mut p = parent[i];
        while p != usize::MAX {
            depth += 1;
            p = nodes_parent[p - n];
        }
        out.push((sym, depth.max(1)));
    }
    // cap pathological lengths (then re-normalize via canonical assignment;
    // with u64 freqs over realistic data this never triggers)
    for e in &mut out {
        e.1 = e.1.min(MAX_CODE_LEN);
    }
    out
}

/// Assign canonical codes from (symbol, len) pairs. Returns
/// `(symbol, code, len)` in (len, symbol) order; codes are MSB-first per
/// canonical convention, emitted LSB-first bit-reversed for the LSB
/// bitstream.
fn canonical_table(lens: &[(i32, u32)]) -> Vec<(i32, u64, u32)> {
    let mut sorted: Vec<(u32, i32)> = lens.iter().map(|&(s, l)| (l, s)).collect();
    sorted.sort_unstable();
    let mut out = Vec::with_capacity(sorted.len());
    let mut code = 0u64;
    let mut prev_len = sorted.first().map(|&(l, _)| l).unwrap_or(0);
    for &(len, sym) in &sorted {
        code <<= len - prev_len;
        prev_len = len;
        out.push((sym, code, len));
        code += 1;
    }
    out
}

fn reverse_bits(v: u64, n: u32) -> u64 {
    if n == 0 {
        return 0;
    }
    v.reverse_bits() >> (64 - n)
}

/// Exact byte length of [`huffman_encode`]'s output without building the
/// bitstream — the shared size accountant (per-species CR splits, GBAE
/// payload accounting, the zero-run mode trials).
pub fn huffman_encoded_size(values: &[i32]) -> usize {
    if values.is_empty() {
        return 4 + 8;
    }
    let freqs = symbol_freqs(values);
    let lens = code_lengths(&freqs);
    // freqs and lens share symbol order, so zip them for the bit total
    let bits: u64 = freqs
        .iter()
        .zip(&lens)
        .map(|(&(_, f), &(_, l))| f * l as u64)
        .sum();
    4 + lens.len() * 5 + 8 + bits.div_ceil(8) as usize
}

/// Encode values into a self-contained byte stream.
pub fn huffman_encode(values: &[i32]) -> Vec<u8> {
    let mut out = Vec::new();
    if values.is_empty() {
        out.extend_from_slice(&0u32.to_le_bytes());
        out.extend_from_slice(&0u64.to_le_bytes());
        return out;
    }
    let freqs = symbol_freqs(values);
    let lens = code_lengths(&freqs);
    out.extend_from_slice(&(lens.len() as u32).to_le_bytes());
    // canonical table: sort by (len, symbol) so decoder derivation matches
    let table = canonical_table(&lens);
    for &(sym, _, len) in &table {
        out.extend_from_slice(&sym.to_le_bytes());
        out.push(len as u8);
    }
    out.extend_from_slice(&(values.len() as u64).to_le_bytes());

    // symbol -> (reversed code, len) lookup: dense over `sym - min` for
    // compact alphabets, sorted-by-symbol binary search otherwise
    let min_sym = freqs.first().map(|&(s, _)| s).unwrap();
    let max_sym = freqs.last().map(|&(s, _)| s).unwrap();
    let range = (max_sym as i64) - (min_sym as i64) + 1;
    let mut w = BitWriter::new();
    if range <= dense_range_cap(freqs.len()) {
        let mut lut = vec![(0u64, 0u32); range as usize];
        for &(sym, code, len) in &table {
            lut[((sym as i64) - (min_sym as i64)) as usize] = (reverse_bits(code, len), len);
        }
        for &v in values {
            let (rc, len) = lut[((v as i64) - (min_sym as i64)) as usize];
            if len > 0 {
                w.write_bits(rc, len);
            }
        }
    } else {
        let mut by_sym: Vec<(i32, u64, u32)> = table
            .iter()
            .map(|&(s, c, l)| (s, reverse_bits(c, l), l))
            .collect();
        by_sym.sort_unstable_by_key(|&(s, _, _)| s);
        for &v in values {
            let i = by_sym
                .binary_search_by_key(&v, |&(s, _, _)| s)
                .expect("symbol missing from its own frequency table");
            let (_, rc, len) = by_sym[i];
            if len > 0 {
                w.write_bits(rc, len);
            }
        }
    }
    out.extend_from_slice(w.as_bytes());
    out
}

/// Reusable decoder state: the parsed `(symbol, len)` table and the
/// first-level LUT, recycled across calls so per-tile decodes stop
/// allocating (lives inside the engine's per-thread
/// [`crate::engine::Scratch`]).
#[derive(Default)]
pub struct HuffScratch {
    table: Vec<(i32, u32)>,
    lut: Vec<u32>,
}

/// Fast LSB-first bit cursor over the payload (u64 refill buffer).
struct Bits<'a> {
    data: &'a [u8],
    byte: usize,
    buf: u64,
    n: u32,
}

impl<'a> Bits<'a> {
    fn new(data: &'a [u8]) -> Self {
        Self { data, byte: 0, buf: 0, n: 0 }
    }

    #[inline]
    fn refill(&mut self) {
        while self.n <= 56 && self.byte < self.data.len() {
            self.buf |= (self.data[self.byte] as u64) << self.n;
            self.byte += 1;
            self.n += 8;
        }
    }

    /// Low `k` bits of the buffer (zero-padded past the stream end);
    /// `k <= 57` so the refill always covers it.
    #[inline]
    fn peek(&mut self, k: u32) -> u64 {
        if self.n < k {
            self.refill();
        }
        self.buf & ((1u64 << k) - 1)
    }

    #[inline]
    fn consume(&mut self, k: u32) {
        debug_assert!(k <= self.n);
        self.buf >>= k;
        self.n -= k;
    }

    #[inline]
    fn take_bit(&mut self) -> Option<u64> {
        if self.n == 0 {
            self.refill();
            if self.n == 0 {
                return None;
            }
        }
        let b = self.buf & 1;
        self.consume(1);
        Some(b)
    }

    fn consumed_bits(&self) -> usize {
        self.byte * 8 - self.n as usize
    }
}

/// Parse and validate the stream header. Returns `(n_values, payload
/// offset)` with the `(symbol, len)` table written into `table`. The
/// declared table size is checked against the bytes present *before* it
/// sizes the allocation (untrusted input).
fn read_header(
    bytes: &[u8],
    max_values: usize,
    table: &mut Vec<(i32, u32)>,
) -> Result<(usize, usize)> {
    ensure!(bytes.len() >= 4, "huffman: truncated header");
    let n_sym = u32::from_le_bytes(bytes[0..4].try_into().unwrap()) as usize;
    let mut off = 4usize;
    let need = n_sym
        .checked_mul(5)
        .and_then(|t| t.checked_add(off + 8))
        .ok_or_else(|| anyhow::anyhow!("huffman: table length overflow"))?;
    ensure!(bytes.len() >= need, "huffman: truncated table");
    table.clear();
    table.reserve(n_sym);
    for _ in 0..n_sym {
        let sym = i32::from_le_bytes(bytes[off..off + 4].try_into().unwrap());
        let len = bytes[off + 4] as u32;
        table.push((sym, len));
        off += 5;
    }
    let n_vals = u64::from_le_bytes(bytes[off..off + 8].try_into().unwrap());
    off += 8;
    let n_vals = usize::try_from(n_vals)
        .map_err(|_| anyhow::anyhow!("huffman: value count overflow"))?;
    ensure!(
        n_vals <= max_values,
        "huffman: declared count {n_vals} exceeds cap {max_values}"
    );
    Ok((n_vals, off))
}

/// Decode a stream produced by [`huffman_encode`]. Returns the values and
/// the number of bytes consumed.
pub fn huffman_decode(bytes: &[u8]) -> Result<(Vec<i32>, usize)> {
    let mut out = Vec::new();
    let mut hs = HuffScratch::default();
    let used = huffman_decode_capped(bytes, MAX_VALUES_DEFAULT, &mut out, &mut hs)?;
    Ok((out, used))
}

/// [`huffman_decode`] into reusable buffers (the per-tile hot path):
/// decoded values land in `out` (cleared first), table/LUT state in
/// `hs`. Returns the bytes consumed.
pub fn huffman_decode_into(
    bytes: &[u8],
    out: &mut Vec<i32>,
    hs: &mut HuffScratch,
) -> Result<usize> {
    huffman_decode_capped(bytes, MAX_VALUES_DEFAULT, out, hs)
}

/// [`huffman_decode_into`] with an explicit cap on the declared value
/// count — callers that know the real geometry pass a tight cap so a
/// corrupt count cannot size an allocation.
pub fn huffman_decode_capped(
    bytes: &[u8],
    max_values: usize,
    out: &mut Vec<i32>,
    hs: &mut HuffScratch,
) -> Result<usize> {
    out.clear();
    let HuffScratch { table, lut } = hs;
    let (n_vals, off) = read_header(bytes, max_values, table)?;
    if n_vals == 0 {
        return Ok(off);
    }
    if table.len() == 1 {
        // degenerate: all values are the single symbol
        out.resize(n_vals, table[0].0);
        return Ok(off);
    }
    ensure!(!table.is_empty(), "huffman: empty table with {n_vals} values");
    // every value consumes at least one bit
    ensure!(
        n_vals <= (bytes.len() - off).saturating_mul(8),
        "huffman: declared count {n_vals} exceeds payload bits"
    );
    for &(_, len) in table.iter() {
        ensure!(
            (1..=MAX_CODE_LEN).contains(&len),
            "huffman: invalid code length {len}"
        );
    }
    table.sort_unstable_by_key(|&(s, l)| (l, s));

    // canonical per-length metadata: codes of length L are
    // first_code[L] .. first_code[L] + count[L], mapping onto table
    // entries first_idx[L] ..
    const L: usize = MAX_CODE_LEN as usize + 1;
    let mut count = [0u64; L];
    for &(_, len) in table.iter() {
        count[len as usize] += 1;
    }
    let mut first_code = [0u64; L];
    let mut first_idx = [0usize; L];
    let mut code = 0u64;
    let mut idx = 0usize;
    let mut max_len = 0u32;
    for len in 1..L {
        first_code[len] = code;
        first_idx[len] = idx;
        let c = count[len];
        if c > 0 {
            ensure!((code + (c - 1)) >> len == 0, "huffman: corrupt code table");
            max_len = len as u32;
        }
        idx += c as usize;
        code = (code + c) << 1;
    }

    // first-level LUT: for every lut_bits-wide (LSB-first) window, the
    // (table index, len) of the code occupying its low bits; u32::MAX
    // marks codes longer than the LUT (resolved by the canonical walk)
    let lut_bits = max_len.min(LUT_BITS);
    let lut_size = 1usize << lut_bits;
    lut.clear();
    lut.resize(lut_size, u32::MAX);
    for (i, &(_, len)) in table.iter().enumerate() {
        if len > lut_bits || i >= (1 << 26) {
            continue;
        }
        let code = first_code[len as usize] + (i - first_idx[len as usize]) as u64;
        let rev = reverse_bits(code, len) as usize;
        let entry = ((i as u32) << 6) | len;
        let step = 1usize << len;
        let mut j = rev;
        while j < lut_size {
            lut[j] = entry;
            j += step;
        }
    }

    let payload = &bytes[off..];
    let mut bits = Bits::new(payload);
    out.reserve(n_vals);
    for _ in 0..n_vals {
        let entry = lut[bits.peek(lut_bits) as usize];
        if entry != u32::MAX {
            let len = entry & 63;
            ensure!(len <= bits.n, "huffman: bitstream underrun");
            bits.consume(len);
            out.push(table[(entry >> 6) as usize].0);
            continue;
        }
        // rare: code longer than the LUT — canonical bit-at-a-time walk
        let mut code = 0u64;
        let mut found = false;
        for len in 1..=max_len {
            let Some(bit) = bits.take_bit() else {
                bail!("huffman: bitstream underrun");
            };
            code = (code << 1) | bit;
            let l = len as usize;
            if count[l] > 0 && code >= first_code[l] && code - first_code[l] < count[l] {
                out.push(table[first_idx[l] + (code - first_code[l]) as usize].0);
                found = true;
                break;
            }
        }
        ensure!(found, "huffman: invalid code in stream");
    }
    Ok(off + bits.consumed_bits().div_ceil(8))
}

/// The pre-overhaul bit-at-a-time decoder (one `(len, code)` lookup per
/// bit), kept as the oracle for the LUT-equivalence tests and the
/// decode-speedup ratio in the `coder_throughput` bench. Do not use on
/// hot paths.
#[doc(hidden)]
pub fn huffman_decode_bitwise(bytes: &[u8]) -> Result<(Vec<i32>, usize)> {
    let mut table = Vec::new();
    let (n_vals, off) = read_header(bytes, MAX_VALUES_DEFAULT, &mut table)?;
    if n_vals == 0 {
        return Ok((vec![], off));
    }
    if table.len() == 1 {
        return Ok((vec![table[0].0; n_vals], off));
    }
    ensure!(!table.is_empty(), "huffman: empty table with {n_vals} values");
    // rebuild canonical codes; decode via a sorted (len, code) lookup
    let codes = canonical_table(&table);
    let mut dec: Vec<((u32, u64), i32)> =
        codes.iter().map(|&(sym, code, len)| ((len, code), sym)).collect();
    dec.sort_unstable_by_key(|&(key, _)| key);
    let max_len = codes.iter().map(|&(_, _, len)| len).max().unwrap_or(0);
    let payload = &bytes[off..];
    let mut r = BitReader::new(payload);
    let mut out = Vec::with_capacity(n_vals.min(1 << 20));
    'outer: for _ in 0..n_vals {
        let mut code = 0u64;
        for len in 1..=max_len {
            let Some(bit) = r.read_bit() else {
                bail!("huffman: bitstream underrun");
            };
            code = (code << 1) | bit as u64;
            if let Ok(i) = dec.binary_search_by_key(&(len, code), |&(key, _)| key) {
                out.push(dec[i].1);
                continue 'outer;
            }
        }
        bail!("huffman: invalid code in stream");
    }
    let consumed = off + r.bit_pos().div_ceil(8);
    Ok((out, consumed))
}

/// Byte layout of one stream for `cli info` diagnostics:
/// `(table_bytes, payload_bytes, n_values)` where `table_bytes` covers
/// the serialized (symbol, len) pairs and `payload_bytes` the coded
/// bits; the fixed framing (u32 count + u64 n_values) is neither.
/// Reads only the header — nothing is decoded.
pub fn huffman_stream_layout(bytes: &[u8]) -> Result<(usize, usize, usize)> {
    let mut table = Vec::new();
    let (n_vals, off) = read_header(bytes, usize::MAX, &mut table)?;
    let table_bytes = table.len() * 5;
    Ok((table_bytes, bytes.len().saturating_sub(off), n_vals))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn round_trip(vals: &[i32]) {
        let enc = huffman_encode(vals);
        let (dec, used) = huffman_decode(&enc).unwrap();
        assert_eq!(dec, vals);
        assert_eq!(used, enc.len());
        // the bitwise oracle agrees on values and consumed bytes
        let (dec2, used2) = huffman_decode_bitwise(&enc).unwrap();
        assert_eq!(dec2, vals);
        assert_eq!(used2, used);
        // and the size accountant predicts the exact encoded size
        assert_eq!(huffman_encoded_size(vals), enc.len());
    }

    #[test]
    fn empty_and_single() {
        round_trip(&[]);
        round_trip(&[42]);
        round_trip(&[7; 1000]);
    }

    #[test]
    fn two_symbols() {
        round_trip(&[0, 1, 0, 0, 1, 0, 0, 0, 1]);
    }

    #[test]
    fn random_peaked_distribution() {
        // shape matches quantized latents: concentrated near 0
        let mut rng = Rng::new(9);
        let vals: Vec<i32> = (0..20_000)
            .map(|_| (rng.normal() * 3.0).round() as i32)
            .collect();
        round_trip(&vals);
        // compression vs raw 4 bytes/value should be significant
        let enc = huffman_encode(&vals);
        assert!(
            enc.len() < vals.len() * 2,
            "expected < 16 bits/sym, got {} bytes for {} vals",
            enc.len(),
            vals.len()
        );
    }

    #[test]
    fn uniform_distribution_still_round_trips() {
        let mut rng = Rng::new(10);
        let vals: Vec<i32> = (0..5000).map(|_| rng.below(256) as i32 - 128).collect();
        round_trip(&vals);
    }

    #[test]
    fn wide_alphabet_exercises_long_codes() {
        // tens of thousands of near-distinct symbols force code lengths
        // past LUT_BITS, covering the canonical fallback walk
        let mut rng = Rng::new(11);
        let vals: Vec<i32> = (0..60_000)
            .map(|_| (rng.next_u64() % 40_000) as i32 - 20_000)
            .collect();
        round_trip(&vals);
    }

    #[test]
    fn extreme_symbol_values() {
        round_trip(&[i32::MAX, i32::MIN, 0, i32::MAX, -1, 1]);
    }

    #[test]
    fn concatenated_streams_decode_sequentially() {
        let a = vec![1, 2, 3, 1, 1];
        let b = vec![-5; 17];
        let mut buf = huffman_encode(&a);
        let len_a = buf.len();
        buf.extend(huffman_encode(&b));
        let (da, ua) = huffman_decode(&buf).unwrap();
        assert_eq!(da, a);
        assert_eq!(ua, len_a);
        let (db, _) = huffman_decode(&buf[ua..]).unwrap();
        assert_eq!(db, b);
    }

    #[test]
    fn rejects_truncation() {
        let enc = huffman_encode(&[1, 2, 3, 4, 5, 6, 7, 8, 1, 1, 1]);
        assert!(huffman_decode(&enc[..enc.len() - 1]).is_err());
        assert!(huffman_decode(&enc[..3]).is_err());
    }

    #[test]
    fn hostile_counts_error_before_allocating() {
        // table count far beyond the bytes present
        let mut s = Vec::new();
        s.extend_from_slice(&u32::MAX.to_le_bytes());
        s.extend_from_slice(&[0u8; 64]);
        assert!(huffman_decode(&s).is_err());
        // degenerate single-symbol stream claiming u64::MAX values
        let mut s = Vec::new();
        s.extend_from_slice(&1u32.to_le_bytes());
        s.extend_from_slice(&7i32.to_le_bytes());
        s.push(0);
        s.extend_from_slice(&u64::MAX.to_le_bytes());
        assert!(huffman_decode(&s).is_err());
        // a tight explicit cap rejects a count the default cap allows
        let enc = huffman_encode(&[3; 100]);
        let mut out = Vec::new();
        let mut hs = HuffScratch::default();
        assert!(huffman_decode_capped(&enc, 99, &mut out, &mut hs).is_err());
        assert!(huffman_decode_capped(&enc, 100, &mut out, &mut hs).is_ok());
        assert_eq!(out, vec![3; 100]);
    }

    #[test]
    fn scratch_reuse_decodes_repeatedly() {
        let mut hs = HuffScratch::default();
        let mut out = Vec::new();
        for seed in 0..4u64 {
            let mut rng = Rng::new(seed + 1);
            let vals: Vec<i32> = (0..3000).map(|_| (rng.normal() * 4.0) as i32).collect();
            let enc = huffman_encode(&vals);
            let used = huffman_decode_into(&enc, &mut out, &mut hs).unwrap();
            assert_eq!(out, vals);
            assert_eq!(used, enc.len());
        }
    }

    #[test]
    fn near_optimal_for_skewed_data() {
        // H(p) for p = [0.9, 0.05, 0.05] ≈ 0.569 bits; huffman gives ~1.1
        let mut vals = vec![0i32; 9000];
        vals.extend(vec![1i32; 500]);
        vals.extend(vec![2i32; 500]);
        let mut rng = Rng::new(3);
        rng.shuffle(&mut vals);
        let enc = huffman_encode(&vals);
        let bits_per_sym = (enc.len() * 8) as f64 / vals.len() as f64;
        assert!(bits_per_sym < 1.3, "bits/sym = {bits_per_sym}");
    }

    #[test]
    fn stream_layout_reports_table_and_payload_split() {
        let vals = vec![0, 0, 1, 0, 2, 0, 0, 1];
        let enc = huffman_encode(&vals);
        let (table, payload, n) = huffman_stream_layout(&enc).unwrap();
        assert_eq!(n, vals.len());
        assert_eq!(table, 3 * 5); // symbols 0, 1, 2
        assert_eq!(4 + table + 8 + payload, enc.len());
    }
}
