//! Bit-level writer/reader (LSB-first within each byte).

/// Append-only bit writer.
#[derive(Debug, Default, Clone)]
pub struct BitWriter {
    bytes: Vec<u8>,
    /// bits already used in the last byte (0..8; 0 means byte-aligned)
    fill: u32,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Write the low `n` bits of `v` (n <= 64), LSB first.
    pub fn write_bits(&mut self, mut v: u64, mut n: u32) {
        debug_assert!(n <= 64);
        while n > 0 {
            if self.fill == 0 {
                self.bytes.push(0);
            }
            let free = 8 - self.fill;
            let take = free.min(n);
            let mask = if take == 64 { u64::MAX } else { (1u64 << take) - 1 };
            let last = self.bytes.last_mut().unwrap();
            *last |= ((v & mask) as u8) << self.fill;
            self.fill = (self.fill + take) % 8;
            v >>= take;
            n -= take;
        }
    }

    pub fn write_bit(&mut self, b: bool) {
        self.write_bits(b as u64, 1);
    }

    /// Total bits written so far.
    pub fn bit_len(&self) -> usize {
        self.bytes.len() * 8 - if self.fill == 0 { 0 } else { (8 - self.fill) as usize }
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.bytes
    }

    pub fn as_bytes(&self) -> &[u8] {
        &self.bytes
    }
}

/// Sequential bit reader over a byte slice (LSB-first).
#[derive(Debug, Clone)]
pub struct BitReader<'a> {
    bytes: &'a [u8],
    pos: usize, // bit position
}

impl<'a> BitReader<'a> {
    pub fn new(bytes: &'a [u8]) -> Self {
        Self { bytes, pos: 0 }
    }

    /// Read `n` bits (LSB-first). Returns None past the end.
    pub fn read_bits(&mut self, n: u32) -> Option<u64> {
        debug_assert!(n <= 64);
        if self.pos + n as usize > self.bytes.len() * 8 {
            return None;
        }
        let mut out = 0u64;
        let mut got = 0u32;
        while got < n {
            let byte = self.bytes[self.pos / 8];
            let off = (self.pos % 8) as u32;
            let avail = 8 - off;
            let take = avail.min(n - got);
            let mask = ((1u16 << take) - 1) as u8;
            let bits = (byte >> off) & mask;
            out |= (bits as u64) << got;
            got += take;
            self.pos += take as usize;
        }
        Some(out)
    }

    pub fn read_bit(&mut self) -> Option<bool> {
        self.read_bits(1).map(|b| b != 0)
    }

    pub fn bit_pos(&self) -> usize {
        self.pos
    }

    /// Skip to the next byte boundary.
    pub fn align_byte(&mut self) {
        self.pos = self.pos.div_ceil(8) * 8;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn single_bits_round_trip() {
        let mut w = BitWriter::new();
        let pattern = [true, false, true, true, false, false, true, false, true];
        for &b in &pattern {
            w.write_bit(b);
        }
        assert_eq!(w.bit_len(), 9);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        for &b in &pattern {
            assert_eq!(r.read_bit(), Some(b));
        }
    }

    #[test]
    fn multi_bit_values_round_trip() {
        let mut rng = Rng::new(2);
        let vals: Vec<(u64, u32)> = (0..500)
            .map(|_| {
                let n = 1 + rng.below(64) as u32;
                let v = rng.next_u64() & if n == 64 { u64::MAX } else { (1 << n) - 1 };
                (v, n)
            })
            .collect();
        let mut w = BitWriter::new();
        for &(v, n) in &vals {
            w.write_bits(v, n);
        }
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        for &(v, n) in &vals {
            assert_eq!(r.read_bits(n), Some(v), "n={n}");
        }
    }

    #[test]
    fn read_past_end_is_none() {
        let mut w = BitWriter::new();
        w.write_bits(0b101, 3);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read_bits(8), Some(0b101)); // padded zeros within byte
        assert_eq!(r.read_bits(1), None);
    }

    #[test]
    fn align_byte() {
        let mut w = BitWriter::new();
        w.write_bits(0x3, 2);
        w.write_bits(0xAB, 8);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        r.read_bits(2).unwrap();
        r.align_byte();
        assert_eq!(r.bit_pos(), 8);
    }
}
