//! Uniform mid-tread quantization (paper §II-E).
//!
//! Continuous coefficients are discretized into bins of width `bin`; every
//! value in a bin is represented by the bin's central value, i.e.
//! `code = round(x / bin)`, `dequant = code * bin`. Matches the
//! `_quantize` op baked into the fused AOT pipeline (model.py), so integer
//! codes recovered here agree exactly with the latents the reconstruction
//! used.

/// Uniform scalar quantizer.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Quantizer {
    pub bin: f32,
}

impl Quantizer {
    pub fn new(bin: f32) -> Self {
        assert!(bin >= 0.0, "bin must be non-negative");
        Self { bin }
    }

    /// Disabled quantizer (identity; codes are invalid to request).
    pub fn disabled() -> Self {
        Self { bin: 0.0 }
    }

    pub fn enabled(&self) -> bool {
        self.bin > 0.0
    }

    /// Integer code for a value. Branch-free: Rust's float→int `as`
    /// already saturates (and maps NaN to 0), and f32 cannot represent
    /// any value strictly between `i32::MAX as f32 = 2^31` and the next
    /// float below it (2147483520), so the cast lands on exactly the
    /// same codes as the old explicit-comparison path
    /// ([`Self::code_reference`], kept as the bit-equivalence oracle) —
    /// while compiling to a single convert the vectorizer can use.
    #[inline]
    pub fn code(&self, x: f32) -> i32 {
        debug_assert!(self.enabled());
        (x / self.bin).round() as i32
    }

    /// The pre-vectorization [`Self::code`] with explicit saturation
    /// comparisons. Oracle only: `code` must match it bit for bit on
    /// every input (including ±inf, NaN and overflowing magnitudes).
    #[doc(hidden)]
    #[inline]
    pub fn code_reference(&self, x: f32) -> i32 {
        let c = (x / self.bin).round();
        if c >= i32::MAX as f32 {
            i32::MAX
        } else if c <= i32::MIN as f32 {
            i32::MIN
        } else {
            c as i32
        }
    }

    /// Bin center for a code.
    #[inline]
    pub fn dequant(&self, code: i32) -> f32 {
        code as f32 * self.bin
    }

    /// Quantize a whole slice to codes. Large slices fan out over the
    /// shared executor in fixed 16 Ki-element chunks, so the code stream
    /// is identical at every thread count.
    pub fn codes(&self, xs: &[f32]) -> Vec<i32> {
        crate::util::parallel::par_flat_map_chunks(xs, 16 * 1024, |_, chunk| {
            chunk.iter().map(|&x| self.code(x)).collect()
        })
    }

    /// Dequantize a whole slice.
    pub fn dequant_all(&self, codes: &[i32]) -> Vec<f32> {
        codes.iter().map(|&c| self.dequant(c)).collect()
    }

    /// Snap values to bin centers in place (code+dequant fused).
    pub fn snap(&self, xs: &mut [f32]) {
        if !self.enabled() {
            return;
        }
        for x in xs {
            *x = self.dequant(self.code(*x));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn quantization_error_bounded_by_half_bin() {
        let mut rng = Rng::new(1);
        for &bin in &[0.005f32, 0.1, 0.5, 2.0] {
            let q = Quantizer::new(bin);
            for _ in 0..2000 {
                let x = rng.range(-100.0, 100.0) as f32;
                let xq = q.dequant(q.code(x));
                assert!(
                    (x - xq).abs() <= bin / 2.0 + 1e-5,
                    "bin={bin} x={x} xq={xq}"
                );
            }
        }
    }

    #[test]
    fn codes_round_trip_through_dequant() {
        let q = Quantizer::new(0.25);
        let xs: Vec<f32> = (-40..40).map(|i| i as f32 * 0.17).collect();
        let codes = q.codes(&xs);
        let deq = q.dequant_all(&codes);
        // re-deriving codes from dequantized values is exact (the rust side
        // does this to recover integer codes from the AOT pipe output)
        let codes2 = q.codes(&deq);
        assert_eq!(codes, codes2);
    }

    #[test]
    fn zero_maps_to_zero() {
        let q = Quantizer::new(0.01);
        assert_eq!(q.code(0.0), 0);
        assert_eq!(q.dequant(0), 0.0);
    }

    #[test]
    fn snap_identity_when_disabled() {
        let q = Quantizer::disabled();
        let mut xs = vec![0.123f32, -4.56];
        let orig = xs.clone();
        q.snap(&mut xs);
        assert_eq!(xs, orig);
        assert!(!q.enabled());
    }

    #[test]
    fn saturates_instead_of_overflow() {
        let q = Quantizer::new(1e-30);
        assert_eq!(q.code(1e10), i32::MAX);
        assert_eq!(q.code(-1e10), i32::MIN);
    }

    #[test]
    fn branchless_code_matches_the_reference_oracle() {
        // extremes, saturation boundaries, non-finite inputs
        let q = Quantizer::new(1.0);
        for x in [
            0.0f32,
            -0.0,
            0.49,
            0.5,
            -0.5,
            2147483520.0, // largest f32 below 2^31
            2147483648.0, // 2^31 exactly
            -2147483648.0,
            -2147483904.0, // first f32 below -2^31
            1e30,
            -1e30,
            f32::INFINITY,
            f32::NEG_INFINITY,
            f32::NAN,
            f32::MIN_POSITIVE,
        ] {
            assert_eq!(q.code(x), q.code_reference(x), "x={x}");
        }
        // random sweep across bins and magnitudes
        let mut rng = Rng::new(3);
        for &bin in &[1e-30f32, 1e-3, 0.7, 1e6] {
            let q = Quantizer::new(bin);
            for _ in 0..5000 {
                let x = (rng.range(-1.0, 1.0) * 10f64.powi(rng.below(39) as i32 - 19)) as f32;
                assert_eq!(q.code(x), q.code_reference(x), "bin={bin} x={x}");
            }
        }
    }
}
