//! Latent-row payload codec (HLAT/BLAT/GLAT section bodies).
//!
//! Shared by the hierarchical pipeline and the GBAE baseline codec:
//! Huffman over integer codes when a quantizer is active, raw f32
//! otherwise (the ablation configs disable quantization).

use super::huffman::{huffman_decode, huffman_encode};
use super::quantizer::Quantizer;
use crate::Result;
use anyhow::{ensure, Context};

/// Latent payload encoding modes (section body headers).
const MODE_RAW: u8 = 0;
const MODE_HUFF: u8 = 1;

/// Encode latent rows: Huffman over integer codes when quantized, raw f32
/// otherwise.
pub fn encode_latents(values: &[f32], q: Quantizer) -> Vec<u8> {
    let mut out = Vec::new();
    if q.enabled() {
        out.push(MODE_HUFF);
        // chunk-parallel on the shared executor, order-identical at any
        // thread count (the largest quantization site in the codebase)
        let codes = q.codes(values);
        out.extend(huffman_encode(&codes));
    } else {
        out.push(MODE_RAW);
        out.extend_from_slice(&(values.len() as u64).to_le_bytes());
        for &v in values {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }
    out
}

/// Decode an [`encode_latents`] payload.
pub fn decode_latents(bytes: &[u8], q: Quantizer) -> Result<Vec<f32>> {
    ensure!(!bytes.is_empty(), "latent section empty");
    match bytes[0] {
        MODE_HUFF => {
            ensure!(q.enabled(), "archive quantized but config bin is 0");
            let (codes, _) = huffman_decode(&bytes[1..])?;
            Ok(q.dequant_all(&codes))
        }
        MODE_RAW => {
            ensure!(bytes.len() >= 9, "raw latent header");
            let n = u64::from_le_bytes(bytes[1..9].try_into().unwrap()) as usize;
            // guard the multiply against adversarial counts before using it
            ensure!(n <= (bytes.len() - 9) / 4, "raw latent length");
            ensure!(bytes.len() == 9 + n * 4, "raw latent length");
            Ok(bytes[9..]
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect())
        }
        m => anyhow::bail!("unknown latent mode {m}"),
    }
}

/// Concatenate one latent stream per stacked AE (u32 count prefix).
pub fn encode_latent_groups(groups: &[Vec<f32>], q: Quantizer) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(&(groups.len() as u32).to_le_bytes());
    for g in groups {
        let payload = encode_latents(g, q);
        out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
        out.extend(payload);
    }
    out
}

/// Decode an [`encode_latent_groups`] payload, checking the stream count.
pub fn decode_latent_groups(bytes: &[u8], q: Quantizer, expect: usize) -> Result<Vec<Vec<f32>>> {
    ensure!(bytes.len() >= 4, "latent group header");
    let n = u32::from_le_bytes(bytes[0..4].try_into().unwrap()) as usize;
    ensure!(n == expect, "archive has {n} latent streams, loaded {expect} decoders");
    let mut off = 4;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let len = u64::from_le_bytes(
            bytes
                .get(off..off + 8)
                .context("latent group length")?
                .try_into()
                .unwrap(),
        ) as usize;
        off += 8;
        let end = off.checked_add(len).context("latent group length overflow")?;
        out.push(decode_latents(bytes.get(off..end).context("latent group body")?, q)?);
        off = end;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latent_codec_round_trips_quantized() {
        let q = Quantizer::new(0.05);
        let vals: Vec<f32> = (0..100).map(|i| (i as f32 * 0.31).sin()).collect();
        let enc = encode_latents(&vals, q);
        let dec = decode_latents(&enc, q).unwrap();
        for (a, b) in vals.iter().zip(&dec) {
            assert!((a - b).abs() <= 0.025 + 1e-6);
        }
        // snapped values round-trip exactly
        let mut snapped = vals.clone();
        q.snap(&mut snapped);
        let enc2 = encode_latents(&snapped, q);
        let dec2 = decode_latents(&enc2, q).unwrap();
        assert_eq!(snapped, dec2);
    }

    #[test]
    fn latent_codec_round_trips_raw() {
        let q = Quantizer::disabled();
        let vals: Vec<f32> = (0..50).map(|i| (i as f32).exp() % 7.0).collect();
        let dec = decode_latents(&encode_latents(&vals, q), q).unwrap();
        assert_eq!(vals, dec);
    }

    #[test]
    fn latent_groups_round_trip() {
        let q = Quantizer::new(0.1);
        let mut g1: Vec<f32> = (0..30).map(|i| i as f32 * 0.3).collect();
        let mut g2: Vec<f32> = (0..10).map(|i| -(i as f32) * 0.7).collect();
        q.snap(&mut g1);
        q.snap(&mut g2);
        let groups = vec![g1.clone(), g2.clone()];
        let enc = encode_latent_groups(&groups, q);
        let dec = decode_latent_groups(&enc, q, 2).unwrap();
        assert_eq!(dec, groups);
        assert!(decode_latent_groups(&enc, q, 1).is_err());
    }

    #[test]
    fn adversarial_raw_count_errors_not_panics() {
        // MODE_RAW with a u64::MAX element count must error before the
        // `9 + n * 4` length arithmetic
        let mut bytes = vec![0u8]; // MODE_RAW
        bytes.extend_from_slice(&u64::MAX.to_le_bytes());
        assert!(decode_latents(&bytes, Quantizer::disabled()).is_err());
        // and a group whose declared length overflows the offset
        let mut g = vec![1, 0, 0, 0]; // one group
        g.extend_from_slice(&u64::MAX.to_le_bytes());
        assert!(decode_latent_groups(&g, Quantizer::disabled(), 1).is_err());
    }

    #[test]
    fn truncated_latents_error() {
        let q = Quantizer::new(0.1);
        let enc = encode_latents(&[1.0, 2.0, 3.0], q);
        for cut in 0..enc.len() {
            assert!(decode_latents(&enc[..cut], q).is_err(), "cut {cut}");
        }
    }
}
