//! Entropy-coding substrate (paper §II-E).
//!
//! * [`quantizer`] — uniform mid-tread quantization of latent/PCA
//!   coefficients to bin centers.
//! * [`huffman`] — canonical Huffman codec over i32 symbols.
//! * [`bitstream`] — bit-level reader/writer used by the Huffman codec,
//!   the index-set codec, and the ZFP-like baseline.
//! * [`indexset`] — Fig. 3 shortest-prefix bitmap encoding of PCA basis
//!   index sets, concatenated and ZSTD-compressed.
//! * [`lossless`] — ZSTD wrapper (the paper's lossless backend).

pub mod bitstream;
pub mod huffman;
pub mod indexset;
pub mod lossless;
pub mod quantizer;

pub use bitstream::{BitReader, BitWriter};
pub use huffman::{huffman_decode, huffman_encode};
pub use indexset::{decode_index_sets, encode_index_sets};
pub use lossless::{zstd_compress, zstd_decompress};
pub use quantizer::Quantizer;
