//! Entropy-coding substrate (paper §II-E).
//!
//! * [`quantizer`] — uniform mid-tread quantization of latent/PCA
//!   coefficients to bin centers.
//! * [`huffman`] — canonical Huffman codec over i32 symbols.
//! * [`bitstream`] — bit-level reader/writer used by the Huffman codec,
//!   the index-set codec, and the ZFP-like baseline.
//! * [`indexset`] — Fig. 3 shortest-prefix bitmap encoding of PCA basis
//!   index sets, concatenated and lossless-compressed.
//! * [`lossless`] — LZSS lossless backend (in-tree ZSTD substitute).
//! * [`latents`] — latent-row payload codec shared by the hierarchical
//!   pipeline and the GBAE baseline codec.

pub mod bitstream;
pub mod huffman;
pub mod indexset;
pub mod latents;
pub mod lossless;
pub mod quantizer;

pub use bitstream::{BitReader, BitWriter};
pub use huffman::{huffman_decode, huffman_encode};
pub use indexset::{decode_index_sets, encode_index_sets};
pub use latents::{decode_latent_groups, decode_latents, encode_latent_groups, encode_latents};
pub use lossless::{lossless_compress, lossless_decompress};
pub use quantizer::Quantizer;
