//! Entropy-coding substrate (paper §II-E).
//!
//! * [`quantizer`] — uniform mid-tread quantization of latent/PCA
//!   coefficients to bin centers.
//! * [`huffman`] — canonical Huffman codec over i32 symbols.
//! * [`bitstream`] — bit-level reader/writer used by the Huffman codec,
//!   the index-set codec, and the ZFP-like baseline.
//! * [`indexset`] — Fig. 3 shortest-prefix bitmap encoding of PCA basis
//!   index sets, concatenated and lossless-compressed.
//! * [`lossless`] — LZSS lossless backend (in-tree ZSTD substitute) plus
//!   the symbol container (plain / zero-run / constant / rANS modes) the
//!   baselines' quantized streams ride in.
//! * [`rans`] — static-frequency interleaved 4-lane rANS coder for the
//!   dense symbol streams (magic 0xB7 in the symbol container).
//! * [`freq`] — the shared symbol-frequency histogram (dense or
//!   sort-based, never hashed).
//! * [`latents`] — latent-row payload codec shared by the hierarchical
//!   pipeline and the GBAE baseline codec.

pub mod bitstream;
pub mod freq;
pub mod huffman;
pub mod indexset;
pub mod latents;
pub mod lossless;
pub mod quantizer;
pub mod rans;

pub use bitstream::{BitReader, BitWriter};
pub use freq::symbol_freqs;
pub use huffman::{
    huffman_decode, huffman_decode_bitwise, huffman_decode_capped, huffman_decode_into,
    huffman_encode, huffman_encoded_size, HuffScratch,
};
pub use indexset::{decode_index_sets, encode_index_sets};
pub use latents::{decode_latent_groups, decode_latents, encode_latent_groups, encode_latents};
pub use lossless::{
    compress_symbols, compress_symbols_mode, decompress_symbols, decompress_symbols_into,
    lossless_compress, lossless_decompress, symbol_stream_stats, with_symbol_mode, SymbolMode,
    SymbolScratch, SymbolStreamStats,
};
pub use quantizer::Quantizer;
pub use rans::{
    rans_decode_into, rans_encode, rans_stream_layout, RansScratch, MAGIC_RANS, RANS_LANES,
};
