//! Shared symbol-frequency counting (the one histogram everybody uses).
//!
//! Three call sites used to hand-roll this (`huffman_encode`'s
//! `HashMap` counter, the sz3 quantized-stream stats, and the
//! experiments runners' per-species re-encoding); they all route through
//! [`symbol_freqs`] now. The common case — quantized prediction errors /
//! transform coefficients, a compact alphabet peaked at zero — takes a
//! dense-array path; wide alphabets (e.g. streams carrying the sz3
//! `UNPRED` sentinel at `i32::MIN`) fall back to sort-and-run-length.
//! No hashing on either path, and both produce the same symbol-sorted
//! output, so encoders are byte-identical whichever path ran.

/// Dense-window threshold shared by the counter and the Huffman
/// encoder's symbol-code lookup: dense when the table stays small next
/// to the input (the cap keeps a hostile spread from sizing a huge
/// table).
pub(crate) fn dense_range_cap(n_values: usize) -> i64 {
    (n_values as i64 * 4).max(4096).min(1 << 21)
}

/// Count symbol occurrences. Returns `(symbol, count)` pairs sorted by
/// symbol ascending, one entry per distinct symbol.
pub fn symbol_freqs(values: &[i32]) -> Vec<(i32, u64)> {
    if values.is_empty() {
        return Vec::new();
    }
    let mut min = i32::MAX;
    let mut max = i32::MIN;
    for &v in values {
        min = min.min(v);
        max = max.max(v);
    }
    let range = (max as i64) - (min as i64) + 1;
    if range <= dense_range_cap(values.len()) {
        let mut counts = vec![0u64; range as usize];
        for &v in values {
            counts[((v as i64) - (min as i64)) as usize] += 1;
        }
        counts
            .iter()
            .enumerate()
            .filter(|&(_, &c)| c > 0)
            .map(|(i, &c)| (((i as i64) + (min as i64)) as i32, c))
            .collect()
    } else {
        let mut sorted = values.to_vec();
        sorted.sort_unstable();
        let mut out = Vec::new();
        let mut cur = sorted[0];
        let mut n = 0u64;
        for &v in &sorted {
            if v == cur {
                n += 1;
            } else {
                out.push((cur, n));
                cur = v;
                n = 1;
            }
        }
        out.push((cur, n));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn reference(values: &[i32]) -> Vec<(i32, u64)> {
        let mut sorted = values.to_vec();
        sorted.sort_unstable();
        let mut out: Vec<(i32, u64)> = Vec::new();
        for v in sorted {
            match out.last_mut() {
                Some(last) if last.0 == v => last.1 += 1,
                _ => out.push((v, 1)),
            }
        }
        out
    }

    #[test]
    fn empty_and_single() {
        assert!(symbol_freqs(&[]).is_empty());
        assert_eq!(symbol_freqs(&[5]), vec![(5, 1)]);
        assert_eq!(symbol_freqs(&[-3; 10]), vec![(-3, 10)]);
    }

    #[test]
    fn dense_and_sparse_paths_agree() {
        let mut rng = Rng::new(5);
        // compact alphabet: dense path
        let peaked: Vec<i32> = (0..5000).map(|_| (rng.normal() * 2.0) as i32).collect();
        assert_eq!(symbol_freqs(&peaked), reference(&peaked));
        // wide spread (sentinel at i32::MIN): sort path
        let mut wide = peaked.clone();
        wide.push(i32::MIN);
        wide.push(i32::MAX);
        assert_eq!(symbol_freqs(&wide), reference(&wide));
    }

    #[test]
    fn counts_sum_to_input_length() {
        let vals: Vec<i32> = (0..1000).map(|i| (i % 7) - 3).collect();
        let freqs = symbol_freqs(&vals);
        assert_eq!(freqs.iter().map(|&(_, c)| c).sum::<u64>(), 1000);
        // sorted by symbol, no duplicates
        for w in freqs.windows(2) {
            assert!(w[0].0 < w[1].0);
        }
    }
}
