//! PCA basis index-set codec (paper §II-E, Fig. 3).
//!
//! Each GAE block stores which basis vectors its correction used. Entropy
//! coding the raw integer indices gains little, so — following the paper —
//! each set becomes a binary sequence ('1' = vector selected), truncated
//! to the **shortest prefix containing all the 1s**; we store that prefix
//! length plus the prefix bits. All blocks' prefixes are concatenated and
//! the whole stream is lossless-compressed (LZSS).
//!
//! Uncompressed layout (little-endian):
//!   u32 n_blocks | u32 dim | n_blocks x u32 prefix_len | bit-packed
//!   prefixes (LSB-first, contiguous)

use super::bitstream::{BitReader, BitWriter};
use super::lossless::{lossless_compress, lossless_decompress};
use crate::Result;
use anyhow::{bail, ensure};

/// Encode per-block selected index sets (each sorted ascending, indices
/// `< dim`).
pub fn encode_index_sets(sets: &[Vec<usize>], dim: usize) -> Result<Vec<u8>> {
    let mut raw = Vec::new();
    raw.extend_from_slice(&(sets.len() as u32).to_le_bytes());
    raw.extend_from_slice(&(dim as u32).to_le_bytes());
    let mut prefix_lens = Vec::with_capacity(sets.len());
    for set in sets {
        let plen = match set.last() {
            None => 0usize,
            Some(&m) => {
                ensure!(m < dim, "index {m} out of range (dim {dim})");
                m + 1
            }
        };
        prefix_lens.push(plen);
        raw.extend_from_slice(&(plen as u32).to_le_bytes());
    }
    let mut bits = BitWriter::new();
    for (set, &plen) in sets.iter().zip(&prefix_lens) {
        let mut mask = vec![false; plen];
        for &j in set {
            ensure!(j < plen, "unsorted index set");
            mask[j] = true;
        }
        for b in mask {
            bits.write_bit(b);
        }
    }
    raw.extend_from_slice(bits.as_bytes());
    lossless_compress(&raw)
}

/// Decode an [`encode_index_sets`] stream.
pub fn decode_index_sets(bytes: &[u8], max_raw: usize) -> Result<Vec<Vec<usize>>> {
    let raw = lossless_decompress(bytes, max_raw)?;
    ensure!(raw.len() >= 8, "indexset: truncated header");
    let n_blocks = u32::from_le_bytes(raw[0..4].try_into().unwrap()) as usize;
    let _dim = u32::from_le_bytes(raw[4..8].try_into().unwrap()) as usize;
    let mut off = 8;
    ensure!(raw.len() >= off + n_blocks * 4, "indexset: truncated lens");
    let mut prefix_lens = Vec::with_capacity(n_blocks);
    for _ in 0..n_blocks {
        prefix_lens.push(u32::from_le_bytes(raw[off..off + 4].try_into().unwrap()) as usize);
        off += 4;
    }
    let total_bits: usize = prefix_lens.iter().sum();
    if raw[off..].len() * 8 < total_bits {
        bail!("indexset: truncated bitstream");
    }
    let mut r = BitReader::new(&raw[off..]);
    let mut out = Vec::with_capacity(n_blocks);
    for &plen in &prefix_lens {
        let mut set = Vec::new();
        for j in 0..plen {
            if r.read_bit().unwrap_or(false) {
                set.push(j);
            }
        }
        out.push(set);
    }
    Ok(out)
}

/// Upper bound for the decompressed stream (decode safety cap).
pub fn max_raw_size(n_blocks: usize, dim: usize) -> usize {
    8 + n_blocks * 4 + (n_blocks * dim).div_ceil(8) + 64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn round_trip(sets: &[Vec<usize>], dim: usize) {
        let enc = encode_index_sets(sets, dim).unwrap();
        let dec = decode_index_sets(&enc, max_raw_size(sets.len(), dim)).unwrap();
        assert_eq!(dec, sets);
    }

    #[test]
    fn empty_sets() {
        round_trip(&[vec![], vec![], vec![]], 80);
        round_trip(&[], 80);
    }

    #[test]
    fn leading_coefficients_compress_well() {
        // typical GAE pattern: each block selects the top-M indices
        let sets: Vec<Vec<usize>> = (0..500).map(|i| (0..(i % 7)).collect()).collect();
        let enc = encode_index_sets(&sets, 1521).unwrap();
        // raw storage of u32 indices would be Σ|set|*4 ≈ 6 KB; prefixes are
        // tiny because the 1s are leading
        assert!(enc.len() < 1200, "got {} bytes", enc.len());
        round_trip(&sets, 1521);
    }

    #[test]
    fn scattered_indices() {
        let mut rng = Rng::new(8);
        let dim = 256;
        let sets: Vec<Vec<usize>> = (0..100)
            .map(|_| {
                let m = rng.below(12);
                let mut s: Vec<usize> = (0..m).map(|_| rng.below(dim)).collect();
                s.sort_unstable();
                s.dedup();
                s
            })
            .collect();
        round_trip(&sets, dim);
    }

    #[test]
    fn full_selection() {
        let sets = vec![(0..80).collect::<Vec<_>>()];
        round_trip(&sets, 80);
    }

    #[test]
    fn out_of_range_rejected() {
        assert!(encode_index_sets(&[vec![80]], 80).is_err());
    }

    #[test]
    fn prefix_property_matches_paper() {
        // the stored prefix ends at the last '1' — verify via size ordering:
        // a set {0} costs less than {255} at the same cardinality
        let small = encode_index_sets(&vec![vec![0]; 200], 256).unwrap();
        let large = encode_index_sets(&vec![vec![255]; 200], 256).unwrap();
        assert!(small.len() <= large.len());
    }
}
