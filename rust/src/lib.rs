//! # attn-reduce
//!
//! Production reproduction of *“Attention Based Machine Learning Methods
//! for Data Reduction with Guaranteed Error Bounds”* (Li, Lee, Rangarajan,
//! Ranka — 2024): an attention-based hierarchical compressor for scientific
//! data with per-block ℓ2 error guarantees.
//!
//! Three-layer architecture (see `DESIGN.md`):
//! * **L1** — Pallas kernels (attention / fused linear / layernorm),
//!   authored in `python/compile/kernels/`, lowered once into HLO.
//! * **L2** — JAX model (HBAE, BAE, Adam train steps, fused pipeline),
//!   AOT-lowered by `python/compile/aot.py` into `artifacts/`.
//! * **L3** — this crate: the coordinator that loads those artifacts via
//!   PJRT ([`runtime`]), drives training ([`train`]), runs the
//!   compression pipeline with the GAE error-bound stage ([`compressor`]),
//!   and reproduces every table/figure of the paper ([`experiments`]).
//!
//! Python never runs on the request path; after `make artifacts` the
//! binary is self-contained.

pub mod baselines;
pub mod coder;
pub mod compressor;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod linalg;
pub mod model;
pub mod runtime;
pub mod tensor;
pub mod train;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
