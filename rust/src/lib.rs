//! # attn-reduce
//!
//! Production reproduction of *“Attention Based Machine Learning Methods
//! for Data Reduction with Guaranteed Error Bounds”* (Li, Lee, Rangarajan,
//! Ranka — 2024): an attention-based hierarchical compressor for scientific
//! data with per-block ℓ2 error guarantees, plus the baselines it is
//! compared against — all behind one unified, error-bounded API.
//!
//! ## The unified `Codec` API
//!
//! Every compressor in the crate — the paper's hierarchical pipeline
//! (`hier`), the SZ3-like predictor (`sz3`), the ZFP-like transform
//! (`zfp`), and the block-AE baseline (`gbae`) — implements
//! [`codec::Codec`]:
//!
//! ```ignore
//! use attn_reduce::codec::{Codec, CodecBuilder, CodecKind, ErrorBound};
//!
//! let mut builder = CodecBuilder::new().scale(Scale::Smoke);
//! let codec = builder.build(CodecKind::Sz3, DatasetKind::E3sm, &field)?;
//! let archive = codec.compress(&field, &ErrorBound::Nrmse(1e-3))?;
//! archive.save("data.ardc")?;
//!
//! // later, from the bytes alone — the archive is self-describing:
//! let archive = attn_reduce::compressor::Archive::load("data.ardc")?;
//! let restored = CodecBuilder::new().for_archive(&archive)?.decompress(&archive)?;
//! ```
//!
//! Bounds are typed ([`codec::ErrorBound`]): `Nrmse(1e-3)`, `L2Tau(0.5)`
//! (the paper's per-GAE-block ℓ2 τ), `PointwiseAbs(1e-4)`, or `None`.
//! Each codec derives its own knob from the bound (Eq.-11 τ, pointwise ε,
//! or a certified precision search) instead of taking a raw `f32`.
//!
//! Archives written by the pure-rust codecs carry a **block index**
//! (Archive v3): [`codec::Codec::decompress_region`] decodes only the
//! blocks a requested [`data::Region`] hyper-rectangle intersects,
//! bit-identical to cropping a full decode; v1/v2 archives transparently
//! fall back to full decode + crop.
//!
//! ## The dataset engine
//!
//! [`engine`] scales the codec API from field-level to dataset-level:
//! [`engine::FieldSet`] groups named variables over one geometry,
//! [`engine::CodecExt::compress_set`] packs them into one multi-field
//! Archive v2 container (v1 archives stay readable), and
//! [`engine::Executor`] — a persistent worker pool with per-thread
//! scratch arenas — runs every block-parallel stage (baselines, GAE,
//! lossless coder, streaming sink) with byte-deterministic output at any
//! thread count (`--threads` > `ATTN_REDUCE_THREADS` >
//! `available_parallelism`).
//!
//! ## The temporal stream subsystem
//!
//! [`stream`] adds the time axis as a first-class workload: a
//! [`stream::StreamWriter`] appends timesteps to one append-only **v4
//! `TSTR` container** — every K-th step a keyframe compressed with any
//! codec, intermediate steps temporal residuals against the previous
//! *reconstruction* (so the typed bound holds on every absolute frame,
//! with no error accumulation along the chain) — and a
//! [`stream::StreamReader`] gives `(step, region)` random access that
//! decodes only the chain `keyframe..=step`, and within each chain
//! archive only the blocks the region intersects. Smoothly-evolving
//! output compresses several times better than independent per-step
//! archives at the same bound (see the `stream_throughput` bench).
//!
//! ## The serving layer
//!
//! [`serve`] turns the library into a long-running service (`cli
//! serve`): a dependency-free HTTP/1.1 server over a root directory of
//! archives and streams, with `(step, region)` extraction, JSON `info`,
//! and compression over POST. Open readers and decoded keyframes are
//! reused across requests through a byte-bounded LRU
//! ([`serve::LruCache`]), and request handling fans out onto the same
//! [`engine::Executor`] pool (and per-thread scratch arenas) as the
//! decode kernels it calls.
//!
//! ## Observability
//!
//! [`obs`] is the cross-cutting measurement layer: a process-global
//! metrics [`obs::Registry`] (counters / gauges / fixed-bucket
//! histograms), RAII [`obs::Span`]s recording per-stage wall time with
//! executor-propagated parentage, Chrome `trace_event` export
//! (`--trace FILE`, Perfetto-loadable), structured `key=value` logging
//! ([`obs::log`]), and Prometheus text exposition on `GET /v1/metrics`.
//!
//! ### Migrating from the pre-codec entry points
//!
//! | old                                                     | new |
//! |---------------------------------------------------------|-----|
//! | `HierCompressor::prepare(&rt, &cfg, &ckpt, &field)`     | `CodecBuilder::new().runtime(rt).build_hier(kind, &field)` |
//! | `comp.compress(&field, tau)`                            | `codec.compress_with_recon(&field, &ErrorBound::L2Tau(tau))` |
//! | `HierCompressor::decompress(&rt, &ar, &hbae, &baes)`    | `builder.for_archive(&ar)?.decompress(&ar)` |
//! | `Sz3Like::new(eps).compress(&f)` / `Sz3Like::decompress`| `builder.build(CodecKind::Sz3, kind, &f)` + trait calls |
//! | `ZfpLike::new(precision).compress(&f)`                  | `builder.build(CodecKind::Zfp, kind, &f)` (bound-certified) |
//! | `GbaeCompressor::compress(&f, bin, tau)`                | `builder.build(CodecKind::Gbae, kind, &f)` (adds decode) |
//! | `coordinator::stream_compress(&comp, &f, depth)`        | `HierCodec::compress_streaming(&f, &bound, depth)` |
//!
//! The low-level types remain public for experiment runners that sweep
//! internals (quantization bins, custom AE stacks).
//!
//! ## Three-layer architecture (see README.md)
//!
//! * **L1** — Pallas kernels (attention / fused linear / layernorm),
//!   authored in `python/compile/kernels/`, lowered once into HLO.
//! * **L2** — JAX model (HBAE, BAE, Adam train steps, fused pipeline),
//!   AOT-lowered by `python/compile/aot.py` into `artifacts/`.
//! * **L3** — this crate: the coordinator that loads those artifacts via
//!   PJRT ([`runtime`]), drives training ([`train`]), runs the
//!   compression codecs ([`codec`], [`compressor`], [`baselines`]),
//!   streams through [`coordinator`], and reproduces every table/figure
//!   of the paper ([`experiments`]).
//!
//! Python never runs on the request path; after `make artifacts` the
//! binary is self-contained. Without artifacts the crate still builds
//! and the pure-rust codecs (`sz3`, `zfp`) are fully functional — the
//! learned codecs error at runtime until the real `xla` backend and
//! artifacts are present.

// Hot-loop indexing idioms used deliberately throughout the numeric code.
#![allow(
    clippy::needless_range_loop,
    clippy::manual_memcpy,
    clippy::too_many_arguments,
    clippy::useless_vec
)]

pub mod baselines;
pub mod codec;
pub mod coder;
pub mod compressor;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod experiments;
pub mod linalg;
pub mod model;
pub mod obs;
pub mod runtime;
pub mod serve;
pub mod stream;
pub mod tensor;
pub mod train;
pub mod util;
pub mod verify;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
