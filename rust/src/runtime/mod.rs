//! PJRT runtime: load AOT artifacts (`artifacts/*.hlo.txt`) and execute
//! them on the CPU client.
//!
//! Interchange is HLO **text** — `HloModuleProto::from_text_file` reassigns
//! instruction ids, which sidesteps xla_extension 0.5.1's rejection of
//! jax ≥ 0.5 64-bit-id protos (see /opt/xla-example/README.md).
//!
//! PJRT wrapper types hold raw pointers and are `!Send`; the
//! [`crate::coordinator`] keeps one [`Runtime`] on a dedicated worker
//! thread and feeds it plain `Vec<f32>` payloads over channels.

mod manifest;

pub use manifest::{EntryInfo, GroupInfo, LayoutEntry, Manifest, TensorSig};

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::time::Instant;

use crate::Result;
use anyhow::{anyhow, bail, Context};

/// Host-side tensor crossing the PJRT boundary.
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl HostTensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Self { shape, data }
    }

    pub fn scalar(v: f32) -> Self {
        Self { shape: vec![], data: vec![v] }
    }

    pub fn vec(data: Vec<f32>) -> Self {
        Self { shape: vec![data.len()], data }
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Self { shape, data: vec![0.0; n] }
    }

    pub fn scalar_value(&self) -> f32 {
        self.data[0]
    }
}

fn to_literal(t: &HostTensor) -> Result<xla::Literal> {
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(t.data.as_ptr() as *const u8, t.data.len() * 4)
    };
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, &t.shape, bytes)
        .map_err(|e| anyhow!("literal create failed: {e:?}"))
}

fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
    let shape = lit.array_shape().map_err(|e| anyhow!("literal shape: {e:?}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = lit.to_vec::<f32>().map_err(|e| anyhow!("literal read: {e:?}"))?;
    Ok(HostTensor::new(dims, data))
}

/// Cumulative execution statistics for one entry point.
#[derive(Debug, Default, Clone, Copy)]
pub struct ExecStats {
    pub calls: u64,
    pub total_us: u64,
}

/// A compiled entry point.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub info: EntryInfo,
    pub group: String,
    pub name: String,
    stats: RefCell<ExecStats>,
}

impl Executable {
    /// Execute with host tensors; returns the decomposed output tuple.
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        if inputs.len() != self.info.inputs.len() {
            bail!(
                "{}/{}: expected {} inputs, got {}",
                self.group, self.name, self.info.inputs.len(), inputs.len()
            );
        }
        for (i, (t, sig)) in inputs.iter().zip(&self.info.inputs).enumerate() {
            if t.shape != sig.shape {
                bail!(
                    "{}/{} input {i}: shape {:?} != manifest {:?}",
                    self.group, self.name, t.shape, sig.shape
                );
            }
        }
        let t0 = Instant::now();
        let lits: Vec<xla::Literal> =
            inputs.iter().map(to_literal).collect::<Result<_>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow!("execute {}/{}: {e:?}", self.group, self.name))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        // aot.py lowers every entry with return_tuple=True.
        let outs = tuple.to_tuple().map_err(|e| anyhow!("to_tuple: {e:?}"))?;
        let hosts: Vec<HostTensor> =
            outs.iter().map(from_literal).collect::<Result<_>>()?;
        let mut st = self.stats.borrow_mut();
        st.calls += 1;
        st.total_us += t0.elapsed().as_micros() as u64;
        Ok(hosts)
    }

    pub fn stats(&self) -> ExecStats {
        *self.stats.borrow()
    }
}

/// Artifact registry + executable cache over one PJRT CPU client.
pub struct Runtime {
    client: xla::PjRtClient,
    root: PathBuf,
    pub manifest: Manifest,
    cache: RefCell<HashMap<(String, String), Rc<Executable>>>,
}

impl Runtime {
    /// Open `artifacts/` (reads `manifest.json`, creates the CPU client).
    pub fn open(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let root = artifacts_dir.as_ref().to_path_buf();
        let manifest = Manifest::load(root.join("manifest.json"))
            .context("run `make artifacts` first")?;
        let client =
            xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Self { client, root, manifest, cache: RefCell::new(HashMap::new()) })
    }

    /// Default artifacts location relative to the crate root.
    pub fn open_default() -> Result<Self> {
        Self::open(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load (or fetch cached) compiled executable for `group/entry`.
    pub fn load(&self, group: &str, entry: &str) -> Result<Rc<Executable>> {
        let key = (group.to_string(), entry.to_string());
        if let Some(e) = self.cache.borrow().get(&key) {
            return Ok(e.clone());
        }
        let ginfo = self
            .manifest
            .groups
            .get(group)
            .ok_or_else(|| anyhow!("group {group:?} not in manifest"))?;
        let einfo = ginfo
            .entries
            .get(entry)
            .ok_or_else(|| anyhow!("entry {group}/{entry} not in manifest"))?;
        let path = self.root.join(&einfo.file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {group}/{entry}: {e:?}"))?;
        let compiled = Rc::new(Executable {
            exe,
            info: einfo.clone(),
            group: group.to_string(),
            name: entry.to_string(),
            stats: RefCell::new(ExecStats::default()),
        });
        tracing_compile(group, entry, t0);
        self.cache.borrow_mut().insert(key, compiled.clone());
        Ok(compiled)
    }

    /// Group metadata (kind, param_dim, config echo).
    pub fn group(&self, group: &str) -> Result<&GroupInfo> {
        self.manifest
            .groups
            .get(group)
            .ok_or_else(|| anyhow!("group {group:?} not in manifest"))
    }

    /// Total flat parameter dimension for a model group.
    pub fn param_dim(&self, group: &str) -> Result<usize> {
        self.group(group)?
            .param_dim
            .ok_or_else(|| anyhow!("group {group:?} has no param_dim"))
    }

    /// Aggregate execution stats across every cached executable.
    pub fn all_stats(&self) -> Vec<(String, ExecStats)> {
        self.cache
            .borrow()
            .iter()
            .map(|((g, e), exe)| (format!("{g}/{e}"), exe.stats()))
            .collect()
    }
}

fn tracing_compile(group: &str, entry: &str, t0: Instant) {
    if std::env::var_os("ATTN_REDUCE_QUIET").is_none() {
        eprintln!(
            "[runtime] compiled {group}/{entry} in {:.2}s",
            t0.elapsed().as_secs_f64()
        );
    }
}
