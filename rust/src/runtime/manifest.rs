//! `artifacts/manifest.json` schema — written by `python/compile/aot.py`.

use std::collections::HashMap;
use std::path::Path;

use crate::util::json::Value;
use crate::Result;
use anyhow::{anyhow, Context};

/// Shape + dtype of one literal crossing the PJRT boundary.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSig {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSig {
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn from_json(v: &Value) -> Result<Self> {
        Ok(Self {
            shape: v.req("shape")?.usize_vec()?,
            dtype: v.req("dtype")?.as_str().unwrap_or("float32").to_string(),
        })
    }
}

/// One AOT-lowered entry point.
#[derive(Debug, Clone)]
pub struct EntryInfo {
    pub file: String,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
    pub hlo_bytes: usize,
}

impl EntryInfo {
    fn from_json(v: &Value) -> Result<Self> {
        let sigs = |key: &str| -> Result<Vec<TensorSig>> {
            v.req(key)?
                .as_arr()
                .ok_or_else(|| anyhow!("{key} not an array"))?
                .iter()
                .map(TensorSig::from_json)
                .collect()
        };
        Ok(Self {
            file: v.req("file")?.as_str().unwrap_or("").to_string(),
            inputs: sigs("inputs")?,
            outputs: sigs("outputs")?,
            hlo_bytes: v.get("hlo_bytes").and_then(|b| b.as_usize()).unwrap_or(0),
        })
    }
}

/// One parameter-layout element (name/shape/offset into the flat vector).
#[derive(Debug, Clone)]
pub struct LayoutEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
}

/// A model group (one HBAE / BAE / fused-pipe config).
#[derive(Debug, Clone)]
pub struct GroupInfo {
    pub kind: String,
    pub entries: HashMap<String, EntryInfo>,
    pub param_dim: Option<usize>,
    pub layout: Vec<LayoutEntry>,
    pub config: Option<Value>,
    pub hbae_group: Option<String>,
    pub bae_group: Option<String>,
}

impl GroupInfo {
    fn from_json(v: &Value) -> Result<Self> {
        let mut entries = HashMap::new();
        for (name, ev) in v
            .req("entries")?
            .as_obj()
            .ok_or_else(|| anyhow!("entries not an object"))?
        {
            entries.insert(name.clone(), EntryInfo::from_json(ev)?);
        }
        let layout = v
            .get("layout")
            .and_then(|l| l.as_arr())
            .map(|items| {
                items
                    .iter()
                    .map(|e| -> Result<LayoutEntry> {
                        Ok(LayoutEntry {
                            name: e.req("name")?.as_str().unwrap_or("").to_string(),
                            shape: e.req("shape")?.usize_vec()?,
                            offset: e.req("offset")?.as_usize().unwrap_or(0),
                        })
                    })
                    .collect::<Result<Vec<_>>>()
            })
            .transpose()?
            .unwrap_or_default();
        Ok(Self {
            kind: v.req("kind")?.as_str().unwrap_or("").to_string(),
            entries,
            param_dim: v.get("param_dim").and_then(|p| p.as_usize()),
            layout,
            config: v.get("config").cloned(),
            hbae_group: v
                .get("hbae_group")
                .and_then(|g| g.as_str())
                .map(String::from),
            bae_group: v
                .get("bae_group")
                .and_then(|g| g.as_str())
                .map(String::from),
        })
    }
}

/// Top-level manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub version: u32,
    pub fingerprint: String,
    pub jax_version: String,
    pub groups: HashMap<String, GroupInfo>,
}

impl Manifest {
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("parsing {}", path.display()))
    }

    pub fn parse(text: &str) -> Result<Self> {
        let v = Value::parse(text)?;
        let mut groups = HashMap::new();
        for (name, gv) in v
            .req("groups")?
            .as_obj()
            .ok_or_else(|| anyhow!("groups not an object"))?
        {
            groups.insert(
                name.clone(),
                GroupInfo::from_json(gv).with_context(|| format!("group {name}"))?,
            );
        }
        Ok(Self {
            version: v.req("version")?.as_usize().unwrap_or(0) as u32,
            fingerprint: v.req("fingerprint")?.as_str().unwrap_or("").to_string(),
            jax_version: v.req("jax_version")?.as_str().unwrap_or("").to_string(),
            groups,
        })
    }

    /// Convenience: a numeric field from a group's config echo.
    pub fn group_config_usize(&self, group: &str, key: &str) -> Option<usize> {
        self.groups
            .get(group)?
            .config
            .as_ref()?
            .get(key)?
            .as_usize()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_minimal_manifest() {
        let text = r#"{
          "version": 1, "fingerprint": "abc", "jax_version": "0.9",
          "groups": {
            "g": {"kind": "bae", "param_dim": 10,
                  "layout": [{"name": "w", "shape": [2, 5], "offset": 0}],
                  "entries": {"encode": {"file": "g/encode.hlo.txt",
                    "inputs": [{"shape": [2, 3], "dtype": "float32"}],
                    "outputs": [{"shape": [2], "dtype": "float32"}]}}}
          }}"#;
        let m = Manifest::parse(text).unwrap();
        assert_eq!(m.groups["g"].param_dim, Some(10));
        assert_eq!(m.groups["g"].layout[0].shape, vec![2, 5]);
        let e = &m.groups["g"].entries["encode"];
        assert_eq!(e.inputs[0].len(), 6);
        assert_eq!(e.outputs[0].shape, vec![2]);
    }

    #[test]
    fn scalar_shapes_parse_as_empty() {
        let sig = TensorSig::from_json(
            &Value::parse(r#"{"shape": [], "dtype": "float32"}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(sig.shape, Vec::<usize>::new());
        assert_eq!(sig.len(), 1);
    }
}
